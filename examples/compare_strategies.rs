//! Compare every draft strategy head-to-head on the three task families:
//! tokens/call, simulated paper-scale speedup, and CPU throughput.
//! This is the "which negligible-cost draft should I use?" decision table
//! a downstream user actually wants.
//!
//!     cargo run --release --example compare_strategies -- [n_prompts] [max_new]

use anyhow::Result;

use ngrammys::bench::{run_cell, BenchCtx};
use ngrammys::config::{default_artifacts_dir, Manifest};
use ngrammys::scheduler::StrategyName;
use ngrammys::workload::{task_analog, TASKS};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_prompts: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(8);
    let max_new: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(48);

    let manifest = Manifest::load(&default_artifacts_dir())?;
    let ctx = BenchCtx::load(manifest, "base")?;

    let strategies = [
        (StrategyName::Mixed, 10, 10),
        (StrategyName::Context, 10, 10),
        (StrategyName::ExtBigram, 10, 10),
        (StrategyName::Bigram, 10, 1),
        (StrategyName::Unigram, 10, 1),
        (StrategyName::Jacobi, 1, 10),
        (StrategyName::Session, 10, 10),
        (StrategyName::None, 1, 0),
    ];

    println!("== strategy comparison, model 'base' ({} prompts/task, {} tokens) ==\n",
             n_prompts, max_new);
    println!("{:<22} {:>24} {:>24} {:>24}",
             "strategy (k,w)", task_analog("chat"), task_analog("code"),
             task_analog("math"));
    println!("{:<22} {:>14} {:>9} {:>14} {:>9} {:>14} {:>9}",
             "", "tok/call", "sim-spd", "tok/call", "sim-spd", "tok/call", "sim-spd");
    for (s, k, w) in strategies {
        let mut line = format!("{:<22}", format!("{} ({k},{w})", s.label()));
        for task in TASKS {
            let prompts = ctx.prompts(task, n_prompts, 128)?;
            let c = run_cell(&ctx, s, &prompts, k, w, 1, max_new)?;
            line.push_str(&format!(" {:>14.2} {:>9.2}", c.tokens_per_call, c.sim_speedup));
        }
        println!("{line}");
    }
    println!("\nsim-spd = wall-time speedup at Mistral-7B/A100 scale from the");
    println!("cost model driven by this run's real acceptance trace; greedy = 1.0");
    Ok(())
}
