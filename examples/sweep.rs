//! (k, w) sweep for one model/task: prints the tokens/call and simulated
//! speedup surface — a CLI-sized slice of the paper's Figure 3 — and flags
//! the optimal (k*, w*) cell.
//!
//!     cargo run --release --example sweep -- [model] [task]

use anyhow::Result;

use ngrammys::bench::{render_grid, run_cell, BenchCtx};
use ngrammys::config::{default_artifacts_dir, Manifest};
use ngrammys::scheduler::StrategyName;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(|s| s.as_str()).unwrap_or("base");
    let task = args.get(1).map(|s| s.as_str()).unwrap_or("code");

    let manifest = Manifest::load(&default_artifacts_dir())?;
    let ctx = BenchCtx::load(manifest, model)?;
    let prompts = ctx.prompts(task, 8, 128)?;

    let ks = [1usize, 5, 10, 20, 25];
    let ws = [2usize, 6, 10, 14];
    let mut cells = Vec::new();
    let mut best = ((0, 0), f64::MIN);
    for &k in &ks {
        for &w in &ws {
            let c = run_cell(&ctx, StrategyName::Mixed, &prompts, k, w, 1, 48)?;
            if c.sim_speedup > best.1 {
                best = ((k, w), c.sim_speedup);
            }
            cells.push(((k, w), c));
        }
    }
    let get = |k: usize, w: usize| -> &ngrammys::bench::CellStats {
        &cells.iter().find(|((ck, cw), _)| *ck == k && *cw == w).unwrap().1
    };
    println!("{}", render_grid(
        &format!("tokens/call — model '{model}', task '{task}'"),
        &ks, &ws, |k, w| get(k, w).tokens_per_call));
    println!("{}", render_grid(
        "simulated speedup (A100 scale)", &ks, &ws, |k, w| get(k, w).sim_speedup));
    println!("optimal (k*, w*) = {:?} with {:.2}x simulated speedup", best.0, best.1);
    Ok(())
}
