//! END-TO-END serving driver (the repo's headline validation run — see
//! EXPERIMENTS.md §E2E): starts the full serving stack (scheduler + HTTP
//! server) on a real trained nano model, replays a Poisson request trace
//! from the eval corpora through actual HTTP round-trips, and reports
//! latency percentiles, throughput and the aggregate tokens/call.
//!
//!     cargo run --release --example serve -- [--requests N] [--rate R]
//!         [--batch LANES] [--engines E] [--no-elastic]
//!
//! `--batch N` (N >= 2) switches the scheduler to the continuous-batching
//! engine pool; `--engines E` (default 1) caps how many engine worker
//! threads — each with its own runtime and KV lane pool — serve behind
//! the shared queue, with requests routed depth-aware (greedy vs
//! speculative). By default the pool is ELASTIC: N is the per-engine cap
//! of a demand-autoscaled lane range, whole engines spawn/retire on
//! sustained pressure/quiet, the per-step row budget is derived online
//! from the cost model, and admissions are ordered by expected
//! accepted-tokens-per-cost (watch `ngrammys_engines`, `ngrammys_lanes`,
//! `ngrammys_derived_budget` and `ngrammys_admission_reorders` in the
//! final metrics dump). `--no-elastic` pins E engines x N fixed lanes,
//! FIFO, no budget — the pre-elastic behavior.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use ngrammys::config::{default_artifacts_dir, EngineConfig, Manifest, ServeConfig};
use ngrammys::scheduler::Scheduler;
use ngrammys::server::{client, Server};
use ngrammys::tokenizer::BpeTokenizer;
use ngrammys::util::cli::Args;
use ngrammys::util::json::Json;
use ngrammys::util::stats;
use ngrammys::workload::{self, RequestTrace};

fn main() -> Result<()> {
    let args = Args::from_env(&["no-elastic"]).map_err(|e| anyhow!(e))?;
    let n_requests = args.get_usize("requests", 24).map_err(|e| anyhow!(e))?;
    let rate = args.get_f64("rate", 4.0).map_err(|e| anyhow!(e))?;
    let batch = args.get_usize("batch", 0).map_err(|e| anyhow!(e))?;
    let engines = args.get_usize("engines", 1).map_err(|e| anyhow!(e))?;
    let max_tokens = 48usize;

    // --- bring up the full stack on an ephemeral port
    let manifest = Manifest::load(&default_artifacts_dir())?;
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 128,
        batch,
        engines,
        elastic: !args.has_flag("no-elastic"),
        default_engine: EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: max_tokens },
        ..ServeConfig::default()
    };
    let elastic = cfg.elastic;
    let scheduler = Arc::new(Scheduler::start(&manifest, "base", &cfg)?);
    let tokenizer = Arc::new(BpeTokenizer::load(&manifest.tokenizer_path)?);
    let metrics = scheduler.metrics.clone();
    let (addr, _h) = Server { scheduler, tokenizer: tokenizer.clone(), cfg }.spawn()?;
    let addr = addr.to_string();
    eprintln!("serving on {addr}; warming up...");

    // --- prompts from all three eval tasks
    let mut prompts = Vec::new();
    for task in workload::TASKS {
        let ex = workload::load_examples(&manifest, task, 8)?;
        for p in workload::build_prompts(&tokenizer, &ex, 0.4, 96) {
            prompts.push(p);
        }
    }
    // one warmup request compiles the executables before timing starts
    let (code, _) = client::post(
        &addr, "/generate",
        &format!("{{\"prompt\": {:?}, \"max_tokens\": 8}}", prompts[0].text),
    )?;
    assert_eq!(code, 200);

    // --- replay a Poisson trace over real HTTP
    let trace = RequestTrace::poisson(42, n_requests, rate, prompts.len());
    let mode = if batch >= 2 && elastic {
        format!(
            "elastic engine pool (cap {engines} engines x {batch} lanes), derived budget, \
             depth-aware routing"
        )
    } else if batch >= 2 {
        format!("engine pool, {engines} x {batch} fixed KV lanes")
    } else {
        "request-batch 1".to_string()
    };
    eprintln!("replaying {n_requests} requests at ~{rate}/s (Poisson), {mode}...");
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (at, pidx) in trace.arrivals {
        let addr = addr.clone();
        let body = format!(
            "{{\"prompt\": {:?}, \"max_tokens\": {max_tokens}}}",
            prompts[pidx].text
        );
        handles.push(std::thread::spawn(move || -> Result<(f64, f64, f64)> {
            let now = Instant::now() - t0;
            if at > now.as_secs_f64() {
                std::thread::sleep(Duration::from_secs_f64(at - now.as_secs_f64()));
            }
            let sent = Instant::now();
            let (code, body) = client::post(&addr, "/generate", &body)?;
            anyhow::ensure!(code == 200, "status {code}: {body}");
            let j = Json::parse(&body).map_err(|e| anyhow::anyhow!("{e}"))?;
            Ok((
                sent.elapsed().as_secs_f64() * 1e3,
                j.req("tokens")?.as_f64().unwrap_or(0.0),
                j.req("tokens_per_call")?.as_f64().unwrap_or(0.0),
            ))
        }));
    }
    let mut lat = Vec::new();
    let mut tokens = 0.0;
    let mut tpcs = Vec::new();
    for h in handles {
        let (l, t, tpc) = h.join().unwrap()?;
        lat.push(l);
        tokens += t;
        tpcs.push(tpc);
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\n== end-to-end serving results (model 'base', mixed (10,10)) ==");
    println!("requests:        {n_requests} ({rate}/s Poisson offered)");
    println!("wall time:       {wall:.1} s");
    println!("throughput:      {:.2} req/s, {:.1} tok/s", n_requests as f64 / wall,
             tokens / wall);
    println!("latency ms:      mean {:.0}  p50 {:.0}  p90 {:.0}  p99 {:.0}",
             stats::mean(&lat), stats::percentile(&lat, 50.0),
             stats::percentile(&lat, 90.0), stats::percentile(&lat, 99.0));
    println!("tokens/call:     {:.2} (mean over requests)", stats::mean(&tpcs));
    println!("\nserver metrics:\n{}", metrics.render());
    Ok(())
}
