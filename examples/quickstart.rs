//! Quickstart: load a trained nano model through the public API and run
//! speculative generation with the paper's mixed strategy, verifying the
//! core invariant (speculative output == greedy output) along the way.
//!
//!     cargo run --release --example quickstart

use std::sync::Arc;

use anyhow::Result;

use ngrammys::config::{default_artifacts_dir, EngineConfig, Manifest};
use ngrammys::draft::{MixedStrategy, NgramTables};
use ngrammys::engine::{greedy_config, NoDraft, SpecDecoder};
use ngrammys::runtime::ModelRuntime;
use ngrammys::tokenizer::BpeTokenizer;

fn main() -> Result<()> {
    // 1. load artifacts (built once by `make artifacts`)
    let manifest = Manifest::load(&default_artifacts_dir())?;
    let art = manifest.model("base")?;
    let runtime = ModelRuntime::load(art)?;
    let tokenizer = BpeTokenizer::load(&manifest.tokenizer_path)?;
    let tables = Arc::new(NgramTables::load(art)?);

    // 2. a prompt in the model's training distribution
    let prompt_text = "Question: Mia has 24 coins. Mia buys 13 more. ";
    let prompt = tokenizer.encode(prompt_text);
    println!("prompt: {prompt_text:?}\n");

    // 3. speculative decoding with the paper's mixed strategy, (k,w)=(10,10)
    let strategy = Box::new(MixedStrategy::paper(tables, 1));
    let cfg = EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: 48 };
    let mut dec = SpecDecoder::new(&runtime, strategy, cfg);
    let spec = dec.generate(&prompt)?;
    println!("speculative ({} calls, {:.2} tokens/call):", spec.calls,
             spec.tokens_per_call());
    println!("  {}\n", tokenizer.decode(&spec.tokens).replace('\n', "\n  "));

    // 4. greedy baseline — MUST produce the identical stream
    let mut greedy = SpecDecoder::new(&runtime, Box::new(NoDraft), greedy_config(48));
    let base = greedy.generate(&prompt)?;
    assert_eq!(base.tokens, spec.tokens, "speculation changed the output!");
    println!(
        "greedy needed {} calls for the same {} tokens -> {:.1}% fewer model calls",
        base.calls,
        base.tokens.len(),
        100.0 * (1.0 - spec.calls as f64 / base.calls as f64)
    );
    Ok(())
}
