//! Byte-level BPE tokenizer: applies the merges trained by
//! `python/compile/tokenizer.py` (shared artifact `tokenizer.json`).
//!
//! The piece-splitting rule MUST match the python side exactly (a word
//! keeps one leading space; whitespace runs are their own pieces); the
//! cross-language agreement is covered by `rust/tests/tokenizer_parity.rs`
//! which round-trips corpus text through both implementations' artifacts.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Token identifier: index into the byte-BPE vocabulary.
pub type TokenId = u32;

/// Byte-level BPE tokenizer loaded from the shared tokenizer.json.
#[derive(Debug)]
pub struct BpeTokenizer {
    merges: Vec<(u32, u32)>,
    ranks: HashMap<(u32, u32), u32>,
    expansions: Vec<Vec<u8>>,
    /// 256 byte tokens plus one per merge
    pub vocab_size: usize,
}

impl BpeTokenizer {
    /// Parse the tokenizer.json artifact text.
    pub fn from_json_text(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("tokenizer.json: {e}"))?;
        if j.req("type")?.as_str() != Some("byte_bpe") {
            return Err(anyhow!("unsupported tokenizer type"));
        }
        let mut merges = Vec::new();
        for (i, m) in j.req("merges")?.as_arr().unwrap_or(&[]).iter().enumerate() {
            let pair = m.as_arr().ok_or_else(|| anyhow!("bad merge entry"))?;
            if pair.len() != 2 {
                return Err(anyhow!("merge entry must have 2 ids"));
            }
            let (a, b) = (
                pair[0].as_usize().unwrap_or(usize::MAX) as u32,
                pair[1].as_usize().unwrap_or(usize::MAX) as u32,
            );
            // each merge may only reference bytes or earlier merge products
            let limit = 256 + i as u32;
            if a >= limit || b >= limit {
                return Err(anyhow!(
                    "merge {i} references id {} before it exists (limit {limit})",
                    a.max(b)
                ));
            }
            merges.push((a, b));
        }
        Ok(Self::from_merges(merges))
    }

    /// Build directly from a merge list (tests and fixtures).
    pub fn from_merges(merges: Vec<(u32, u32)>) -> Self {
        let ranks = merges
            .iter()
            .enumerate()
            .map(|(i, &m)| (m, i as u32))
            .collect();
        let mut expansions: Vec<Vec<u8>> = (0..=255u8).map(|b| vec![b]).collect();
        for &(a, b) in &merges {
            let mut e = expansions[a as usize].clone();
            e.extend_from_slice(&expansions[b as usize]);
            expansions.push(e);
        }
        BpeTokenizer {
            vocab_size: 256 + merges.len(),
            merges,
            ranks,
            expansions,
        }
    }

    /// Read and parse tokenizer.json from disk.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading tokenizer {path:?}"))?;
        Self::from_json_text(&text)
    }

    /// Number of learned merges.
    pub fn n_merges(&self) -> usize {
        self.merges.len()
    }

    fn encode_piece(&self, piece: &[u8], out: &mut Vec<TokenId>) {
        let mut ids: Vec<u32> = piece.iter().map(|&b| b as u32).collect();
        while ids.len() >= 2 {
            let mut best: Option<(u32, usize)> = None;
            for i in 0..ids.len() - 1 {
                if let Some(&r) = self.ranks.get(&(ids[i], ids[i + 1])) {
                    if best.map_or(true, |(br, _)| r < br) {
                        best = Some((r, i));
                    }
                }
            }
            match best {
                None => break,
                Some((r, i)) => {
                    ids[i] = 256 + r;
                    ids.remove(i + 1);
                }
            }
        }
        out.extend(ids);
    }

    /// Tokenize text: piece-split, then greedy lowest-rank merges.
    pub fn encode(&self, text: &str) -> Vec<TokenId> {
        let mut out = Vec::with_capacity(text.len() / 3 + 4);
        for piece in split_pieces(text.as_bytes()) {
            self.encode_piece(piece, &mut out);
        }
        out
    }

    /// Byte-expand ids back to (lossily UTF-8) text.
    pub fn decode(&self, ids: &[TokenId]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if let Some(e) = self.expansions.get(id as usize) {
                bytes.extend_from_slice(e);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Byte expansion of a single token (for streaming decode).
    pub fn token_bytes(&self, id: TokenId) -> &[u8] {
        &self.expansions[id as usize]
    }
}

fn is_ws(b: u8) -> bool {
    matches!(b, 0x20 | 0x09 | 0x0A | 0x0D)
}

/// Split into pieces: `(optional single leading space) + non-ws run`, with
/// leftover whitespace runs as their own pieces. Mirrors
/// `python/compile/tokenizer.py::split_pieces` byte-for-byte.
pub fn split_pieces(data: &[u8]) -> Vec<&[u8]> {
    let mut pieces = Vec::new();
    let n = data.len();
    let mut i = 0;
    while i < n {
        let c = data[i];
        if c == 0x20 && i + 1 < n && !is_ws(data[i + 1]) {
            let mut j = i + 1;
            while j < n && !is_ws(data[j]) {
                j += 1;
            }
            pieces.push(&data[i..j]);
            i = j;
        } else if is_ws(c) {
            let mut j = i;
            while j < n && is_ws(data[j]) {
                j += 1;
            }
            if j < n && data[j - 1] == 0x20 {
                if j - 1 > i {
                    pieces.push(&data[i..j - 1]);
                }
                i = j - 1;
            } else {
                pieces.push(&data[i..j]);
                i = j;
            }
        } else {
            let mut j = i;
            while j < n && !is_ws(data[j]) {
                j += 1;
            }
            pieces.push(&data[i..j]);
            i = j;
        }
    }
    pieces
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pieces_reassemble() {
        let cases = [
            "hello world",
            "  leading",
            "trailing  ",
            "a\nb\n\n c",
            "tabs\tand  spaces   x",
            "",
            " ",
            "  ",
        ];
        for c in cases {
            let pieces = split_pieces(c.as_bytes());
            let joined: Vec<u8> = pieces.concat();
            assert_eq!(joined, c.as_bytes(), "case {c:?} pieces {pieces:?}");
        }
    }

    #[test]
    fn word_keeps_leading_space() {
        let p = split_pieces(b"a b");
        assert_eq!(p, vec![b"a".as_ref(), b" b".as_ref()]);
    }

    #[test]
    fn byte_fallback_roundtrip() {
        let t = BpeTokenizer::from_merges(vec![]);
        let ids = t.encode("héllo ☃");
        assert_eq!(t.decode(&ids), "héllo ☃");
        assert!(ids.iter().all(|&i| i < 256));
    }

    #[test]
    fn merges_apply_by_rank() {
        // merges: (h,e) -> 256, (256, l) -> 257
        let t = BpeTokenizer::from_merges(vec![(b'h' as u32, b'e' as u32), (256, b'l' as u32)]);
        let ids = t.encode("hell");
        assert_eq!(ids, vec![257, b'l' as u32]);
        assert_eq!(t.decode(&ids), "hell");
    }

    #[test]
    fn json_roundtrip() {
        let t = BpeTokenizer::from_merges(vec![(104, 101), (256, 108)]);
        let json = format!(
            "{{\"type\": \"byte_bpe\", \"vocab_size\": {}, \"merges\": [[104, 101], [256, 108]]}}",
            t.vocab_size
        );
        let t2 = BpeTokenizer::from_json_text(&json).unwrap();
        assert_eq!(t2.encode("hello"), t.encode("hello"));
    }
}
