//! Work-stealing dispatch: per-engine scored work queues drained by the
//! engine workers themselves, with idle-engine stealing — no dispatcher
//! thread between submit and admit.
//!
//! The central pool ([`super::pool`]) routes every request through one
//! dispatcher thread that owns a single [`AdmissionQueue`]. That thread
//! is a serialization point: at high connection counts every admission
//! waits for the dispatcher's next routing pass. This module keeps the
//! SAME ordering policy — scored admission with FIFO tie-break and the
//! [`STARVATION_DEFERRALS`] anti-starvation fallback — but makes it a
//! property of the shared queue structure ([`WorkQueues`]) rather than of
//! a dispatcher loop:
//!
//! - **Submit** scores the request ([`request_score`]; with a paged KV
//!   pool the same ordering contract extends to
//!   [`super::admission::request_score_paged`], which is bitwise-identical
//!   at zero shared prefix) and pushes it onto the queue of the
//!   least-loaded depth-compatible engine.
//! - **Pickup** is by the engine workers: each pops the best eligible
//!   entry from its OWN queue first; an engine with free lanes and no
//!   eligible local work steals the best eligible entry from its most
//!   loaded peer (counted in `ngrammys_steals`).
//! - **Depth classes** ([`super::DepthClass`]) stay segregated exactly as
//!   under central routing: a worker only takes a request whose class
//!   matches its resident population, until the request has been passed
//!   over [`STARVATION_DEFERRALS`] times — then any engine with room
//!   takes it (counted in `ngrammys_routing_fallbacks`).
//!
//! CORRECTNESS: like central routing, stealing only decides WHERE and
//! alongside WHOM a sequence decodes. Every stream is still exactly the
//! base model's greedy continuation of its prompt; byte-identity between
//! `--dispatch steal` and `--dispatch central` at concurrency 1/4/8 is
//! pinned by `bench serve --smoke` and `rust/tests/server_integration.rs`.
//! Engine-COUNT autoscaling is a central-mode feature: this mode boots
//! the full fixed fleet (`--engines`) so there is no spawn/retire owner
//! to serialize behind; per-engine LANE autoscaling still runs.
//!
//! The queue structure is usable on its own:
//!
//! ```
//! use ngrammys::scheduler::WorkQueues;
//!
//! let q: WorkQueues<&str> = WorkQueues::new(2, 8);
//! q.push(0, "greedy", 1.0).unwrap();
//! q.push(1, "spec", 2.0).unwrap();
//! // an owner pops the best eligible entry from its own queue...
//! let (item, _score, _seq) = q.pop_where(0, |_| true).unwrap();
//! assert_eq!(item, "greedy");
//! // ...and an idle peer steals from the most loaded other queue
//! let (from, item, _score, _seq) = q.steal_where(0, |_| true).unwrap();
//! assert_eq!((from, item), (1, "spec"));
//! assert!(q.is_empty());
//! ```

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::config::{ModelArtifacts, ServeConfig};
use crate::costmodel::CostModel;
use crate::draft::{NgramTables, SharedDraftStore};
use crate::engine::SeqId;
use crate::metrics::Metrics;
use crate::runtime::ModelRuntime;
use crate::trace::TraceHub;

use super::admission::{request_score, strategy_prior_tpc, AdmissionQueue};
use super::autoscale::{Autoscaler, Demand};
use super::pool::{
    admit_pool_job, fresh_engine, publish_statuses, store_page_stats, sweep_cancelled,
    EngineStatus, Inflight, PoolJob, STARVATION_DEFERRALS,
};
use super::{finish_response, mirror_shared_metrics, record_fingerprint_fp, DepthClass, Job};

/// Pause between gauge-publisher iterations, and the bound on how long a
/// worker waits for a wakeup that raced its queue check. Correctness
/// never depends on it.
const STEAL_TICK: Duration = Duration::from_millis(1);

/// How long a fully idle worker (empty queues everywhere) parks before
/// re-checking; pushes wake it immediately via the condvar.
const IDLE_PARK: Duration = Duration::from_millis(25);

/// Why [`WorkQueues::push`] refused an item (handed back to the caller).
#[derive(Debug)]
pub enum PushError<T> {
    /// The shared queued-entry cap is reached — backpressure: reject the
    /// request rather than queueing unboundedly.
    Full(T),
    /// [`WorkQueues::close`] was called: the serving loop is shutting
    /// down and accepts no new work.
    Closed(T),
}

/// N scored admission queues — one per engine — sharing one queued-entry
/// cap, one closed flag, and one wakeup condvar.
///
/// Each inner queue is an [`AdmissionQueue`], so every pop (local or
/// steal) is the scored pop with FIFO tie-break and the per-entry
/// anti-starvation overtake bound. Ordering is therefore a property of
/// the queue an entry sits in, not of any dispatcher loop: whichever
/// worker gets to a queue first takes its best eligible entry.
///
/// All methods take `&self`; internal locking is per-queue, so pushes and
/// pops on different queues never contend.
pub struct WorkQueues<T> {
    queues: Vec<Mutex<AdmissionQueue<T>>>,
    /// entries currently queued across all queues (the backpressure cap
    /// compares against this, so the bound is shared like the central
    /// mode's bounded channel)
    queued: AtomicUsize,
    cap: usize,
    closed: AtomicBool,
    park: Mutex<()>,
    wake: Condvar,
}

impl<T> WorkQueues<T> {
    /// `n` queues (floored at 1) sharing a total queued-entry cap of
    /// `cap` entries.
    pub fn new(n: usize, cap: usize) -> Self {
        let n = n.max(1);
        WorkQueues {
            queues: (0..n).map(|_| Mutex::new(AdmissionQueue::new())).collect(),
            queued: AtomicUsize::new(0),
            cap: cap.max(1),
            closed: AtomicBool::new(false),
            park: Mutex::new(()),
            wake: Condvar::new(),
        }
    }

    /// How many queues there are.
    pub fn queues(&self) -> usize {
        self.queues.len()
    }

    /// Entries currently queued across all queues.
    pub fn len(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }

    /// Whether no entry is queued anywhere.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Entries currently queued on queue `queue` (panics if out of
    /// range).
    pub fn queue_len(&self, queue: usize) -> usize {
        self.queues[queue].lock().unwrap().len()
    }

    /// Enqueue `item` with `score` onto queue `queue` (panics if out of
    /// range) and wake waiting workers. Fails with the item handed back
    /// when the shared cap is reached or the structure is closed.
    pub fn push(&self, queue: usize, item: T, score: f64) -> Result<(), PushError<T>> {
        if self.closed.load(Ordering::Relaxed) {
            return Err(PushError::Closed(item));
        }
        if self.queued.load(Ordering::Relaxed) >= self.cap {
            return Err(PushError::Full(item));
        }
        // count BEFORE the entry becomes poppable so a racing pop's
        // decrement can never precede this increment
        self.queued.fetch_add(1, Ordering::Relaxed);
        self.queues[queue].lock().unwrap().push(item, score);
        let _guard = self.park.lock().unwrap();
        self.wake.notify_all();
        Ok(())
    }

    /// Pop the best eligible entry from queue `queue` (panics if out of
    /// range): highest score wins, ties go to the earliest arrival, and
    /// the oldest eligible waiter is force-popped once it has been
    /// overtaken [`AdmissionQueue`]'s anti-starvation bound times.
    /// Returns the entry with its score and arrival stamp.
    pub fn pop_where(
        &self,
        queue: usize,
        eligible: impl FnMut(&T) -> bool,
    ) -> Option<(T, f64, u64)> {
        let hit = self.queues[queue].lock().unwrap().pop_best_where(eligible);
        if hit.is_some() {
            self.queued.fetch_sub(1, Ordering::Relaxed);
        }
        hit
    }

    /// Steal the best eligible entry from another queue, scanning the
    /// most loaded queue first. Returns the source queue index alongside
    /// the entry.
    pub fn steal_where(
        &self,
        thief: usize,
        mut eligible: impl FnMut(&T) -> bool,
    ) -> Option<(usize, T, f64, u64)> {
        let mut order: Vec<usize> = (0..self.queues.len()).filter(|&j| j != thief).collect();
        order.sort_by_key(|&j| std::cmp::Reverse(self.queue_len(j)));
        for j in order {
            if let Some((item, score, seq)) = self.pop_where(j, &mut eligible) {
                return Some((j, item, score, seq));
            }
        }
        None
    }

    /// Visit every entry of queue `queue` mutably (panics if out of
    /// range) — the workers use this to age passed-over entries toward
    /// the anti-starvation fallback.
    pub fn for_each_mut(&self, queue: usize, f: impl FnMut(&mut T)) {
        self.queues[queue].lock().unwrap().for_each_mut(f);
    }

    /// Total score-over-FIFO reorders across all queues (the
    /// `ngrammys_admission_reorders` gauge).
    pub fn reorders(&self) -> u64 {
        self.queues.iter().map(|q| q.lock().unwrap().reorders()).sum()
    }

    /// Remove and return every queued entry, best-first per queue.
    pub fn drain_all(&self) -> Vec<T> {
        let mut out = Vec::new();
        for q in &self.queues {
            let mut q = q.lock().unwrap();
            while let Some((item, _, _)) = q.pop_best_entry() {
                self.queued.fetch_sub(1, Ordering::Relaxed);
                out.push(item);
            }
        }
        out
    }

    /// Refuse further pushes and wake every parked worker. Entries
    /// already queued stay poppable so shutdown can drain them.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Relaxed);
        let _guard = self.park.lock().unwrap();
        self.wake.notify_all();
    }

    /// Whether [`Self::close`] was called.
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Relaxed)
    }

    /// Park until a push or [`Self::close`] wakes the caller, at most
    /// `timeout`. Returns immediately if entries are queued or the
    /// structure is already closed, so a wakeup that raced the caller's
    /// own queue check is never lost for longer than `timeout`.
    pub fn wait_for_work(&self, timeout: Duration) {
        let guard = self.park.lock().unwrap();
        if !self.is_empty() || self.is_closed() {
            return;
        }
        let _ = self.wake.wait_timeout(guard, timeout).unwrap();
    }
}

/// The submit-side handle for `--dispatch steal`: scores a request,
/// places it on the least-loaded depth-compatible engine's queue, and
/// applies the shared backpressure cap. Shared by the scheduler handle
/// and every worker thread.
pub(crate) struct StealDispatch {
    queues: WorkQueues<PoolJob>,
    /// `(engine id, gauges)` for the fixed fleet, in spawn order
    statuses: Vec<(u64, Arc<EngineStatus>)>,
    /// workers still running (drives publisher shutdown)
    live: AtomicUsize,
    metrics: Arc<Metrics>,
    cm: CostModel,
    elastic: bool,
    /// the fleet draft store (`--shared-draft fleet`), shared by every
    /// worker's strategy wrapper and mirrored to `/metrics` by the
    /// publisher
    shared: Option<Arc<SharedDraftStore>>,
}

impl StealDispatch {
    /// Score and enqueue one job. Error strings match the central path
    /// exactly: `"queue full"` under backpressure, `"scheduler stopped"`
    /// after close, and a no-engine error when every runtime failed to
    /// load.
    pub(crate) fn submit(&self, job: Job) -> Result<()> {
        let class = DepthClass::of(job.req.strategy, &job.req.engine);
        let score = if self.elastic {
            request_score(
                &self.cm,
                strategy_prior_tpc(&self.metrics, job.req.strategy),
                job.req.strategy,
                &job.req.engine,
                job.req.prompt.len(),
            )
        } else {
            0.0
        };
        let live: Vec<usize> = (0..self.statuses.len())
            .filter(|&i| {
                let st = &self.statuses[i].1;
                !st.draining.load(Ordering::Relaxed) && !st.load_failed.load(Ordering::Relaxed)
            })
            .collect();
        if live.is_empty() {
            return Err(anyhow!("no engine available (runtime load failed)"));
        }
        let load = |i: usize| self.statuses[i].1.held() + self.queues.queue_len(i);
        let target = live
            .iter()
            .copied()
            .filter(|&i| self.statuses[i].1.compatible(class))
            .min_by_key(|&i| load(i))
            .or_else(|| live.iter().copied().min_by_key(|&i| load(i)))
            .expect("live is non-empty");
        match self.queues.push(target, PoolJob { job, class, deferrals: 0 }, score) {
            Ok(()) => {
                self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(PushError::Full(_)) => {
                let n = self.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed) + 1;
                eprintln!("scheduler: queue full, rejecting request ({n} rejected total)");
                Err(anyhow!("queue full"))
            }
            Err(PushError::Closed(_)) => Err(anyhow!("scheduler stopped")),
        }
    }

    /// Graceful shutdown: refuse new work and wake the workers; each
    /// drains the queues and its in-flight sequences before exiting.
    pub(crate) fn close(&self) {
        self.queues.close();
    }
}

/// Boot the work-stealing fleet: `engines` worker threads (each loading
/// its own `ModelRuntime`, like the central pool's spawn) plus one gauge
/// publisher. Returns the submit handle and every thread to join on
/// shutdown.
pub(crate) fn start(
    art: ModelArtifacts,
    tables: Arc<NgramTables>,
    metrics: Arc<Metrics>,
    trace: Arc<TraceHub>,
    scfg: ServeConfig,
    shared: Option<Arc<SharedDraftStore>>,
) -> (Arc<StealDispatch>, Vec<JoinHandle<()>>) {
    let fleet = scfg.engines.max(1);
    let lane_cap = scfg.batch.max(2);
    let cm = CostModel::for_analog(&art.dims.analog);
    let statuses: Vec<(u64, Arc<EngineStatus>)> =
        (0..fleet as u64).map(|id| (id, Arc::new(EngineStatus::new()))).collect();
    let dispatch = Arc::new(StealDispatch {
        queues: WorkQueues::new(fleet, scfg.queue_cap.max(1)),
        statuses,
        live: AtomicUsize::new(fleet),
        metrics: metrics.clone(),
        cm,
        elastic: scfg.elastic,
        shared,
    });
    let mut handles = Vec::new();
    for i in 0..fleet {
        let d = dispatch.clone();
        let art = art.clone();
        let tables = tables.clone();
        let metrics = metrics.clone();
        let trace = trace.clone();
        let scfg = scfg.clone();
        let handle = std::thread::Builder::new()
            .name(format!("ngrammys-steal-{i}"))
            .spawn(move || {
                let (id, status) = (d.statuses[i].0, d.statuses[i].1.clone());
                match ModelRuntime::load(&art) {
                    Ok(runtime) => {
                        steal_worker_loop(
                            i, id, &runtime, &d, &tables, &metrics, &trace, &scfg, &status,
                            lane_cap,
                        );
                    }
                    Err(e) => {
                        eprintln!("engine {id}: runtime load failed: {e:#}");
                        status.load_failed.store(true, Ordering::Relaxed);
                    }
                }
                status.draining.store(true, Ordering::Relaxed);
                d.live.fetch_sub(1, Ordering::Relaxed);
            })
            .expect("spawning steal worker");
        handles.push(handle);
    }
    let d = dispatch.clone();
    let handle = std::thread::Builder::new()
        .name("ngrammys-steal-publish".to_string())
        .spawn(move || publisher(&d, fleet))
        .expect("spawning steal publisher");
    handles.push(handle);
    (dispatch, handles)
}

/// Gauge publisher: the central dispatcher snapshots gauges every routing
/// pass; here no such thread exists, so a dedicated (cheap) one exports
/// the per-engine statuses, keeps `engines_target` at the fixed fleet
/// size, and fails queued work fast once every runtime load has failed.
fn publisher(d: &StealDispatch, fleet: usize) {
    loop {
        let live =
            d.statuses.iter().filter(|(_, st)| !st.draining.load(Ordering::Relaxed)).count();
        d.metrics.engines_target.store(fleet as u64, Ordering::Relaxed);
        d.metrics.admission_reorders.store(d.queues.reorders(), Ordering::Relaxed);
        publish_statuses(&d.metrics, live, d.statuses.iter().map(|(id, st)| (*id, st.as_ref())));
        if let Some(store) = d.shared.as_deref() {
            mirror_shared_metrics(&d.metrics, store);
        }
        if d.statuses.iter().all(|(_, st)| st.load_failed.load(Ordering::Relaxed)) {
            for pj in d.queues.drain_all() {
                d.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                d.metrics.admissions_failed.fetch_add(1, Ordering::Relaxed);
                pj.job
                    .reply
                    .send(Err(anyhow!("engine pool: no engine available (runtime load failed)")));
            }
        }
        if d.queues.is_closed() && d.live.load(Ordering::Relaxed) == 0 {
            let live = d
                .statuses
                .iter()
                .filter(|(_, st)| !st.draining.load(Ordering::Relaxed))
                .count();
            publish_statuses(
                &d.metrics,
                live,
                d.statuses.iter().map(|(id, st)| (*id, st.as_ref())),
            );
            // the workers have exited (their engine drops flushed any
            // buffered tails): mirror the final store counters
            if let Some(store) = d.shared.as_deref() {
                mirror_shared_metrics(&d.metrics, store);
            }
            return;
        }
        std::thread::sleep(STEAL_TICK);
    }
}

/// One work-stealing engine worker: the continuous-batching loop of
/// `pool::engine_worker_loop`, but pulling straight from the shared
/// queues (own queue first, then the most loaded peer) instead of a
/// routed channel. Exits when the dispatch is closed and every queue and
/// lane has drained — graceful shutdown completes in-flight requests.
#[allow(clippy::too_many_arguments)]
fn steal_worker_loop(
    i: usize,
    id: u64,
    runtime: &ModelRuntime,
    d: &StealDispatch,
    tables: &Arc<NgramTables>,
    metrics: &Arc<Metrics>,
    trace: &Arc<TraceHub>,
    scfg: &ServeConfig,
    status: &EngineStatus,
    lane_cap: usize,
) {
    let analog = runtime.artifacts().dims.analog.clone();
    let recorder = trace.recorder_for_engine(id);
    let mut au_cfg = scfg.autoscale.clone();
    au_cfg.max_lanes = lane_cap;
    au_cfg.min_lanes = au_cfg.min_lanes.clamp(1, lane_cap);
    let boot_lanes = if scfg.elastic { au_cfg.min_lanes } else { lane_cap };
    let mut scaler = Autoscaler::new(au_cfg);

    let mut eng = fresh_engine(runtime, boot_lanes, scfg, &analog);
    eng.recorder = Some(recorder.clone());
    status.lanes.store(eng.capacity(), Ordering::Relaxed);
    status.lanes_target.store(eng.capacity(), Ordering::Relaxed);
    status.kv_bytes.store(eng.kv_bytes() as u64, Ordering::Relaxed);
    store_page_stats(status, &eng);
    let mut inflight: HashMap<SeqId, Inflight> = HashMap::new();
    loop {
        // ---- fill free lanes: own queue first, then steal from peers
        let mut starved = false;
        loop {
            if !eng.has_capacity() {
                let want = (eng.active() + d.queues.queue_len(i)).min(lane_cap);
                if scfg.elastic && eng.capacity() < want {
                    let lanes = eng.set_capacity(want);
                    status.lanes.store(lanes, Ordering::Relaxed);
                }
                if !eng.has_capacity() {
                    break;
                }
            }
            let mut pred = |pj: &PoolJob| {
                status.compatible(pj.class) || pj.deferrals >= STARVATION_DEFERRALS
            };
            let popped = match d.queues.pop_where(i, &mut pred) {
                Some(hit) => Some(hit),
                None => match d.queues.steal_where(i, &mut pred) {
                    Some((_, pj, score, seq)) => {
                        metrics.steals.fetch_add(1, Ordering::Relaxed);
                        Some((pj, score, seq))
                    }
                    None => None,
                },
            };
            let Some((pj, _, _)) = popped else {
                starved = true;
                break;
            };
            if !status.compatible(pj.class) {
                metrics.routing_fallbacks.fetch_add(1, Ordering::Relaxed);
            }
            // pre-charge backlog + class so admit_pool_job's accounting
            // (shared with the central dispatcher, which charges these at
            // route time) balances, and so held() never dips mid-admit
            status.backlog.fetch_add(1, Ordering::Relaxed);
            status.class_counter(pj.class).fetch_add(1, Ordering::Relaxed);
            admit_pool_job(
                &mut eng, pj, tables, metrics, &mut inflight, scfg, runtime, status, lane_cap,
                d.shared.as_ref(),
            );
        }
        // reclaim lanes whose client disconnected before stepping
        sweep_cancelled(&mut eng, &mut inflight, metrics, status);
        if starved && !d.queues.is_empty() {
            // every waiter this worker could see was depth-incompatible:
            // age the local queue so the anti-starvation fallback
            // eventually lets any engine take its entries (the mirror of
            // the central route() pass's deferral bump)
            d.queues.for_each_mut(i, |pj| pj.deferrals += 1);
        }
        if eng.active() == 0 {
            if d.queues.is_closed() && d.queues.is_empty() {
                return; // graceful drain complete
            }
            if scfg.elastic {
                // idle: give the lane memory back NOW, like the central
                // worker does before parking in recv()
                let min = scaler.config().min_lanes;
                let lanes = eng.set_capacity(min);
                status.lanes.store(lanes, Ordering::Relaxed);
                status.lanes_target.store(min, Ordering::Relaxed);
                status.heat_milli.store(0, Ordering::Relaxed);
                status.kv_bytes.store(eng.kv_bytes() as u64, Ordering::Relaxed);
                store_page_stats(status, &eng);
            }
            let timeout = if d.queues.is_empty() { IDLE_PARK } else { STEAL_TICK };
            d.queues.wait_for_work(timeout);
            continue;
        }
        // lane-level autoscale (level 1): own queue depth is the local
        // pressure signal
        if scfg.elastic {
            let target = scaler.target_lanes(&Demand {
                queue_depth: d.queues.queue_len(i),
                active: eng.active(),
                lanes: eng.capacity(),
                mean_heat: eng.mean_heat(),
            });
            let achieved = eng.set_capacity(target);
            status.lanes_target.store(target, Ordering::Relaxed);
            status.lanes.store(achieved, Ordering::Relaxed);
        } else {
            status.lanes_target.store(lane_cap, Ordering::Relaxed);
            status.lanes.store(eng.capacity(), Ordering::Relaxed);
        }
        match eng.step() {
            Ok(done) => {
                if let Some(b) = eng.last_step_budget() {
                    metrics.derived_budget.store(b as u64, Ordering::Relaxed);
                }
                for (sid, r) in done {
                    if let Some(inf) = inflight.remove(&sid) {
                        status.active.fetch_sub(1, Ordering::Relaxed);
                        status.class_counter(inf.class).fetch_sub(1, Ordering::Relaxed);
                        record_fingerprint_fp(d.shared.as_deref(), inf.fp, &r);
                        let resp =
                            finish_response(metrics, trace, inf.t_submit, inf.queue_wait, r);
                        inf.reply.send(Ok(resp));
                    }
                }
            }
            Err(e) => {
                // a step error poisons the whole batch (shared call):
                // fail every in-flight request and restart fresh at the
                // capacity the autoscaler had reached
                eprintln!("engine pool: step failed: {e:#}");
                for (_, inf) in inflight.drain() {
                    status.active.fetch_sub(1, Ordering::Relaxed);
                    status.class_counter(inf.class).fetch_sub(1, Ordering::Relaxed);
                    inf.reply.send(Err(anyhow!("batched engine step failed: {e:#}")));
                }
                let lanes = eng.capacity();
                eng = fresh_engine(runtime, lanes, scfg, &analog);
                eng.recorder = Some(recorder.clone());
            }
        }
        status.heat_milli.store(
            (eng.mean_heat().unwrap_or(0.0).max(0.0) * 1e3) as u64,
            Ordering::Relaxed,
        );
        status.kv_bytes.store(eng.kv_bytes() as u64, Ordering::Relaxed);
        store_page_stats(status, &eng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_scored_entry_pops_first_within_a_queue() {
        let q: WorkQueues<&str> = WorkQueues::new(1, 8);
        q.push(0, "low", 1.0).unwrap();
        q.push(0, "high", 2.0).unwrap();
        let (item, score, _) = q.pop_where(0, |_| true).unwrap();
        assert_eq!((item, score), ("high", 2.0));
        let (item, _, _) = q.pop_where(0, |_| true).unwrap();
        assert_eq!(item, "low");
        assert!(q.pop_where(0, |_| true).is_none());
    }

    #[test]
    fn steal_scans_most_loaded_peer_first() {
        let q: WorkQueues<u32> = WorkQueues::new(3, 16);
        q.push(1, 10, 0.0).unwrap();
        q.push(2, 20, 0.0).unwrap();
        q.push(2, 21, 5.0).unwrap();
        // queue 2 holds two entries, so the thief visits it first and
        // takes its best-scored entry
        let (from, item, _, _) = q.steal_where(0, |_| true).unwrap();
        assert_eq!((from, item), (2, 21));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn steal_respects_eligibility_like_depth_routing() {
        let q: WorkQueues<&str> = WorkQueues::new(2, 8);
        q.push(1, "spec", 9.0).unwrap();
        q.push(1, "greedy", 1.0).unwrap();
        // a "greedy-resident" thief skips the higher-scored spec entry
        let (from, item, _, _) = q.steal_where(0, |it| *it == "greedy").unwrap();
        assert_eq!((from, item), (1, "greedy"));
        // the spec entry is still there for a compatible taker
        let (item, _, _) = q.pop_where(1, |_| true).unwrap();
        assert_eq!(item, "spec");
    }

    #[test]
    fn shared_cap_applies_across_queues_and_close_refuses_pushes() {
        let q: WorkQueues<u32> = WorkQueues::new(2, 2);
        q.push(0, 1, 0.0).unwrap();
        q.push(1, 2, 0.0).unwrap();
        assert!(matches!(q.push(0, 3, 0.0), Err(PushError::Full(3))));
        // popping frees shared capacity no matter which queue it came from
        q.pop_where(1, |_| true).unwrap();
        q.push(0, 3, 0.0).unwrap();
        q.close();
        assert!(matches!(q.push(0, 4, 0.0), Err(PushError::Closed(4))));
        // queued entries stay drainable after close (shutdown drain)
        assert_eq!(q.drain_all().len(), 2);
        assert!(q.is_empty());
    }
}
