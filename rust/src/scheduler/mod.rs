//! Request scheduler: bounded admission queue + decode workers.
//!
//! Two execution modes, selected by `ServeConfig::batch`:
//!
//! - **Per-sequence workers** (`batch <= 1`, the paper's §3 setting): each
//!   worker owns a private `ModelRuntime` and decodes one request at a time
//!   with `SpecDecoder` — the model-call batch dimension is spent entirely
//!   on that request's speculation rows.
//! - **Engine pool** (`batch >= 2`): a [`pool`] of up to
//!   `ServeConfig::engines` continuous-batching worker threads, each
//!   driving its own [`crate::engine::BatchedEngine`] over its own
//!   `ModelRuntime` and resizable KV lane pool, behind ONE scored
//!   [`admission::AdmissionQueue`]. Requests are routed depth-aware —
//!   greedy (w = 0) and speculative traffic land on different engines
//!   while capacity allows — admitted as lanes free up, and every engine
//!   verifies its active sequences' draft rows in packed calls per step;
//!   responses complete out of order. By default the pool is **elastic**
//!   (`ServeConfig::elastic`), autoscaled at TWO levels: each engine's
//!   lane pool scales between `autoscale.min_lanes` and the `batch`
//!   per-engine cap ([`autoscale::Autoscaler`]), and whole engines are
//!   spawned/retired between 1 and the `engines` cap on sustained
//!   pressure/quiet ([`autoscale::EngineScaler`]); the per-step row
//!   budget is derived online from the cost model (`--budget` caps it)
//!   and admissions are ordered by expected accepted-tokens-per-cost with
//!   per-strategy priors ([`admission::strategy_prior_tpc`]) rather than
//!   FIFO.
//!
//! Both modes share the same bounded-queue backpressure: `submit` fails
//! fast — counting and logging the rejection — when the queue is full.

pub mod admission;
pub mod autoscale;
pub mod pool;

pub use admission::{request_score, strategy_prior_tpc, AdmissionQueue};
pub use autoscale::{AutoscaleConfig, Autoscaler, Demand, EngineScaleConfig, EngineScaler};

use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::adaptive::{self, SeqController};
use crate::config::{EngineConfig, Manifest, ServeConfig, SessionCacheConfig};
use crate::draft::{
    ContextNgram, DraftStrategy, ExtendedBigram, JacobiDraft, MixedStrategy, ModelBigram,
    ModelUnigram, NgramTables, SessionNgramCache, StrategyKind,
};
use crate::engine::{GenResult, NoDraft, SpecDecoder};
use crate::metrics::Metrics;
use crate::runtime::ModelRuntime;
use crate::tokenizer::TokenId;
use crate::trace::{RequestEvent, TraceHub, DEFAULT_RING_CAPACITY};

/// Strategy selector exposed through the API / CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyName {
    /// the paper's SS4.3 mixed policy (context n-gram + extended bigram)
    Mixed,
    /// context n-gram (SS4.2)
    Context,
    /// model bigram top-k (SS4.1)
    Bigram,
    /// model unigram (App. B.1)
    Unigram,
    /// extended bigram chains (SS4.1)
    ExtBigram,
    /// Jacobi decoding baseline
    Jacobi,
    /// online session n-gram cache (extension beyond the paper)
    Session,
    /// online (k, w) + strategy selection (`crate::adaptive`)
    Adaptive,
    /// no drafting (plain greedy decoding)
    None,
}

impl StrategyName {
    /// Every variant. `parse` and its error message derive from this plus
    /// `label()` (whose match the compiler keeps exhaustive), so the name
    /// set lives in exactly one place per direction.
    pub const ALL: [StrategyName; 9] = [
        Self::Mixed,
        Self::Context,
        Self::Bigram,
        Self::Unigram,
        Self::ExtBigram,
        Self::Jacobi,
        Self::Session,
        Self::Adaptive,
        Self::None,
    ];

    /// Parse a CLI/API strategy name (long-form aliases accepted).
    pub fn parse(s: &str) -> Result<Self> {
        // long-form aliases kept for back-compat with existing clients
        let canon = match s {
            "context-ngram" => "context",
            "model-bigram" => "bigram",
            "model-unigram" => "unigram",
            "extended-bigram" => "ext-bigram",
            "session-cache" => "session",
            "greedy" => "none",
            other => other,
        };
        Self::ALL
            .iter()
            .copied()
            .find(|v| v.label() == canon)
            .ok_or_else(|| {
                let valid: Vec<&str> = Self::ALL.iter().map(|v| v.label()).collect();
                anyhow!("unknown strategy '{s}' (valid: {})", valid.join(", "))
            })
    }

    /// Canonical short name (the CLI/API spelling).
    pub fn label(&self) -> &'static str {
        match self {
            Self::Mixed => "mixed",
            Self::Context => "context",
            Self::Bigram => "bigram",
            Self::Unigram => "unigram",
            Self::ExtBigram => "ext-bigram",
            Self::Jacobi => "jacobi",
            Self::Session => "session",
            Self::Adaptive => "adaptive",
            Self::None => "none",
        }
    }

    /// The draft-row [`StrategyKind`]s this strategy actually produces —
    /// the key set for the per-strategy admission prior
    /// ([`strategy_prior_tpc`]): a request's expected tokens/call should
    /// come from its own draft sources' acceptance record, not the
    /// fleet-wide average. `Adaptive` spans its default arm set
    /// ([`crate::adaptive::DEFAULT_ARMS`]); `None` drafts nothing.
    pub fn kinds(&self) -> &'static [StrategyKind] {
        match self {
            Self::Mixed => &[StrategyKind::ContextNgram, StrategyKind::ExtendedBigram],
            Self::Context => &[StrategyKind::ContextNgram],
            Self::Bigram => &[StrategyKind::ModelBigram],
            Self::Unigram => &[StrategyKind::ModelUnigram],
            Self::ExtBigram => &[StrategyKind::ExtendedBigram],
            Self::Jacobi => &[StrategyKind::Jacobi],
            Self::Session => &[StrategyKind::SessionCache],
            Self::Adaptive => &[
                StrategyKind::ContextNgram,
                StrategyKind::ExtendedBigram,
                StrategyKind::SessionCache,
            ],
            Self::None => &[],
        }
    }
}

/// Speculation-depth class of a request — the engine pool's routing
/// bucket. Greedy (w = 0) and speculative traffic are kept on different
/// engines while capacity allows, so a greedy request can only collapse
/// the packed depth of a group that is already greedy (the in-engine
/// per-class depth split covers the forced-mixing fallback).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DepthClass {
    /// speculation disabled: strategy `none` or a w = 0 shape
    Greedy,
    /// every other request (drafts ride verification calls)
    Speculative,
}

impl DepthClass {
    /// Classify a request the same way [`request_score`] prices it: it is
    /// greedy exactly when speculation cannot emit more than one token
    /// per call by construction.
    pub fn of(strategy: StrategyName, engine: &EngineConfig) -> Self {
        if strategy == StrategyName::None || engine.w == 0 {
            DepthClass::Greedy
        } else {
            DepthClass::Speculative
        }
    }
}

/// Build a boxed strategy (used by workers, benches and examples alike)
/// with default session-cache bounds.
pub fn make_strategy(
    name: StrategyName,
    tables: &Arc<NgramTables>,
    q: usize,
) -> Box<dyn DraftStrategy> {
    make_strategy_with_cache(name, tables, q, &SessionCacheConfig::default())
}

/// [`make_strategy`] with explicit session-cache bounds (`ServeConfig::
/// session_cache`). `Adaptive` is a control MODE, not a drafting source:
/// every real adaptive path attaches a [`SeqController`] to the engine
/// (which owns the drafting arms and ignores the engine's strategy slot),
/// so `Adaptive` maps to the no-op placeholder here rather than building
/// a strategy that would never be consulted.
pub fn make_strategy_with_cache(
    name: StrategyName,
    tables: &Arc<NgramTables>,
    q: usize,
    cache: &SessionCacheConfig,
) -> Box<dyn DraftStrategy> {
    match name {
        StrategyName::Mixed => Box::new(MixedStrategy::paper(tables.clone(), q)),
        StrategyName::Context => Box::new(ContextNgram::new(q)),
        StrategyName::Bigram => Box::new(ModelBigram::new(tables.clone())),
        StrategyName::Unigram => Box::new(ModelUnigram::new(tables.clone())),
        StrategyName::ExtBigram => Box::new(ExtendedBigram::new(tables.clone())),
        StrategyName::Jacobi => Box::new(JacobiDraft::new(0)),
        StrategyName::Session => {
            Box::new(SessionNgramCache::new(cache.per_query, cache.max_chain, cache.cap))
        }
        StrategyName::Adaptive | StrategyName::None => Box::new(NoDraft),
    }
}

/// The adaptive controller for one request, when the request asked for
/// adaptive mode — warm-started from the fleet's per-strategy acceptance
/// counters so its bandit arms do not boot uniform (the serving half of
/// the ROADMAP "cross-request bandit priors"; `strategy_prior_tpc` is the
/// admission half).
fn controller_for_request(
    name: StrategyName,
    tables: &Arc<NgramTables>,
    q: usize,
    cfg: &ServeConfig,
    runtime: &ModelRuntime,
    metrics: &Metrics,
) -> Option<SeqController> {
    (name == StrategyName::Adaptive).then(|| {
        adaptive::controller_for_seeded(
            tables,
            q,
            &cfg.session_cache,
            &runtime.artifacts().dims.analog,
            metrics,
        )
    })
}

/// One generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// tokenized prompt
    pub prompt: Vec<TokenId>,
    /// per-request engine settings
    pub engine: EngineConfig,
    /// draft strategy for this request
    pub strategy: StrategyName,
}

/// Completed response.
#[derive(Debug)]
pub struct GenResponse {
    /// emitted tokens (the first comes from prefill)
    pub tokens: Vec<TokenId>,
    /// the paper's acceptance metric for this request
    pub tokens_per_call: f64,
    /// verification calls spent
    pub calls: usize,
    /// submit-to-reply latency in milliseconds
    pub latency_ms: f64,
}

struct Job {
    req: GenRequest,
    reply: Sender<Result<GenResponse>>,
    /// stamped in [`Scheduler::submit`]; queue-wait and TTFT spans are
    /// measured from here
    t_submit: Instant,
}

/// The scheduler handle: cheap to clone, submits jobs to the pool.
pub struct Scheduler {
    tx: SyncSender<Job>,
    /// shared serving metrics (rendered at GET /metrics)
    pub metrics: Arc<Metrics>,
    /// flight-recorder hub: per-engine step rings + request spans
    /// (served at GET /trace and summarized at GET /stats)
    pub trace: Arc<TraceHub>,
    workers: Vec<JoinHandle<()>>,
}

impl Scheduler {
    /// Spin up workers for `model`: `cfg.workers` per-sequence workers, or
    /// (when `cfg.batch >= 2`) an engine-[`pool`] dispatcher thread
    /// driving up to `cfg.engines` batched engine workers — each with
    /// `cfg.batch` pooled KV lanes when `cfg.elastic` is off, or a
    /// demand-autoscaled lane pool capped at `cfg.batch` when it is on
    /// (the default, which also spawns/retires whole engines on sustained
    /// pressure/quiet). Each engine thread loads its own ModelRuntime.
    pub fn start(manifest: &Manifest, model: &str, cfg: &ServeConfig) -> Result<Scheduler> {
        let art = manifest.model(model)?.clone();
        let tables = Arc::new(NgramTables::load(&art)?);
        let metrics = Arc::new(Metrics::new());
        let trace = Arc::new(TraceHub::with_metrics(DEFAULT_RING_CAPACITY, metrics.clone()));
        let (tx, rx) = sync_channel::<Job>(cfg.queue_cap);
        let rx = Arc::new(Mutex::new(rx));

        let mut workers = Vec::new();
        if cfg.batch >= 2 {
            let rx = rx.clone();
            let tables = tables.clone();
            let metrics = metrics.clone();
            let trace = trace.clone();
            let scfg = cfg.clone();
            let handle = std::thread::Builder::new()
                .name("ngrammys-engine-pool".to_string())
                .spawn(move || pool::run_pool(art, tables, metrics, trace, rx, scfg))
                .expect("spawning engine pool");
            workers.push(handle);
        } else {
            for wid in 0..cfg.workers.max(1) {
                let rx = rx.clone();
                let art = art.clone();
                let tables = tables.clone();
                let metrics = metrics.clone();
                let trace = trace.clone();
                let scfg = cfg.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("ngrammys-worker-{wid}"))
                    .spawn(move || {
                        let runtime = match ModelRuntime::load(&art) {
                            Ok(rt) => rt,
                            Err(e) => {
                                eprintln!("worker {wid}: runtime load failed: {e:#}");
                                return;
                            }
                        };
                        worker_loop(wid, runtime, tables, metrics, trace, rx, &scfg);
                    })
                    .expect("spawning worker");
                workers.push(handle);
            }
        }
        Ok(Scheduler { tx, metrics, trace, workers })
    }

    /// Non-blocking admission; `Err` = queue full (backpressure). A
    /// rejection is never silent: it bumps `requests_rejected` (rendered
    /// at `/metrics`) and logs the drop with the queue size so overload
    /// is visible on both the dashboard and the console.
    pub fn submit(&self, req: GenRequest) -> Result<Receiver<Result<GenResponse>>> {
        self.metrics.requests_total.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        match self.tx.try_send(Job { req, reply: reply_tx, t_submit: Instant::now() }) {
            Ok(()) => {
                self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
                Ok(reply_rx)
            }
            Err(TrySendError::Full(_)) => {
                let n = self.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed) + 1;
                eprintln!("scheduler: queue full, rejecting request ({n} rejected total)");
                Err(anyhow!("queue full"))
            }
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("scheduler stopped")),
        }
    }

    /// Submit and wait (convenience for tests/examples).
    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| anyhow!("worker dropped"))?
    }

    /// Graceful shutdown: close the queue and join workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Fold a finished [`GenResult`] into the serving metrics + trace hub and
/// build the reply. `queue_wait` is the submit → dequeue/admit dwell;
/// TTFT is that dwell plus the prefill call (the first token IS the
/// prefill's output), inter-token latency is the remaining decode spread
/// over the remaining tokens — both observed into their histograms and
/// logged as a [`RequestEvent`] by the hub.
fn finish_response(
    metrics: &Metrics,
    trace: &TraceHub,
    t_submit: Instant,
    queue_wait: Duration,
    r: GenResult,
) -> GenResponse {
    let accepted = r.tokens.len().saturating_sub(r.calls);
    let total = t_submit.elapsed();
    metrics.record_request(total, r.tokens.len(), r.calls, accepted);
    for tr in &r.traces {
        metrics.step_latency.observe(tr.exec_time);
        // a call where no draft token matched has no winning strategy —
        // the judge's row-0 default would otherwise credit whatever kind
        // fills row 0 (context-ngram under the mixed policy) with a "win"
        let kind = if tr.accepted > 0 { tr.kind } else { StrategyKind::Empty };
        metrics.record_strategy_step(kind, tr.accepted);
    }
    let ttft = queue_wait + r.prefill_time;
    trace.record_request(RequestEvent {
        t_us: 0, // stamped by the hub
        queue_us: queue_wait.as_micros() as u64,
        prefill_us: r.prefill_time.as_micros() as u64,
        ttft_us: ttft.as_micros() as u64,
        total_us: total.as_micros() as u64,
        tokens: r.tokens.len() as u32,
        calls: r.calls as u32,
    });
    GenResponse {
        tokens_per_call: r.tokens_per_call(),
        calls: r.calls,
        latency_ms: total.as_secs_f64() * 1e3,
        tokens: r.tokens,
    }
}

fn worker_loop(
    wid: usize,
    runtime: ModelRuntime,
    tables: Arc<NgramTables>,
    metrics: Arc<Metrics>,
    trace: Arc<TraceHub>,
    rx: Arc<Mutex<Receiver<Job>>>,
    scfg: &ServeConfig,
) {
    let recorder = trace.recorder_for_engine(wid as u64);
    loop {
        // hold the lock only while dequeuing
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => return, // scheduler dropped
        };
        metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
        let queue_wait = job.t_submit.elapsed();
        let strategy = make_strategy_with_cache(
            job.req.strategy, &tables, job.req.engine.q, &scfg.session_cache);
        let mut dec = SpecDecoder::new(&runtime, strategy, job.req.engine.clone());
        dec.controller = controller_for_request(
            job.req.strategy, &tables, job.req.engine.q, scfg, &runtime, &metrics);
        dec.collect_traces = true; // feeds the step-latency histogram
        dec.recorder = Some(recorder.clone());
        let result = dec
            .generate(&job.req.prompt)
            .map(|r| finish_response(&metrics, &trace, job.t_submit, queue_wait, r));
        let _ = job.reply.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_names_parse() {
        for (s, n) in [
            ("mixed", StrategyName::Mixed),
            ("context", StrategyName::Context),
            ("bigram", StrategyName::Bigram),
            ("unigram", StrategyName::Unigram),
            ("ext-bigram", StrategyName::ExtBigram),
            ("jacobi", StrategyName::Jacobi),
            ("session", StrategyName::Session),
            ("adaptive", StrategyName::Adaptive),
            ("greedy", StrategyName::None),
        ] {
            assert_eq!(StrategyName::parse(s).unwrap(), n);
        }
        // the error must enumerate every valid name, not just echo the input
        let err = StrategyName::parse("bogus").unwrap_err().to_string();
        assert!(err.contains("bogus"));
        for v in StrategyName::ALL {
            assert!(err.contains(v.label()), "error missing '{}': {err}", v.label());
        }
    }

    #[test]
    fn every_variant_round_trips_through_parse() {
        for v in StrategyName::ALL {
            assert_eq!(StrategyName::parse(v.label()).unwrap(), v);
        }
    }
}
