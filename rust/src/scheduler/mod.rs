//! Request scheduler: bounded admission queue + decode workers.
//!
//! Two execution modes, selected by `ServeConfig::batch`:
//!
//! - **Per-sequence workers** (`batch <= 1`, the paper's §3 setting): each
//!   worker owns a private `ModelRuntime` and decodes one request at a time
//!   with `SpecDecoder` — the model-call batch dimension is spent entirely
//!   on that request's speculation rows.
//! - **Engine pool** (`batch >= 2`): up to `ServeConfig::engines`
//!   continuous-batching worker threads, each driving its own
//!   [`crate::engine::BatchedEngine`] over its own `ModelRuntime` and
//!   resizable KV lane pool, fed through scored
//!   [`admission::AdmissionQueue`]s. Requests are routed depth-aware —
//!   greedy (w = 0) and speculative traffic land on different engines
//!   while capacity allows — admitted as lanes free up, and every engine
//!   verifies its active sequences' draft rows in packed calls per step;
//!   responses complete out of order. Two dispatch arrangements
//!   (`ServeConfig::dispatch`) drain the queues:
//!   [work-stealing](steal) (the default): each engine owns a queue,
//!   submissions route to the least-loaded compatible engine, and an idle
//!   engine steals from its most-loaded peer — no dispatcher thread on
//!   the submit→admit path; or [central](pool): one dispatcher thread
//!   owns a single shared queue and routes pops to engine channels, and
//!   additionally spawns/retires whole engines between 1 and the
//!   `engines` cap on sustained pressure/quiet
//!   ([`autoscale::EngineScaler`] — engine-count scaling is a
//!   central-mode feature; stealing mode runs the full fixed fleet).
//!   In both arrangements the pool is **elastic** by default
//!   (`ServeConfig::elastic`): each engine's lane pool scales between
//!   `autoscale.min_lanes` and the `batch` per-engine cap
//!   ([`autoscale::Autoscaler`]), the per-step row budget is derived
//!   online from the cost model (`--budget` caps it), and admissions are
//!   ordered by expected accepted-tokens-per-cost with per-strategy
//!   priors ([`admission::strategy_prior_tpc`]) rather than FIFO — the
//!   ordering is a property of the queue itself (see [`admission`]), so
//!   both dispatch modes inherit it unchanged.
//!
//! All modes share the same bounded-queue backpressure: `submit` fails
//! fast — counting and logging the rejection — when the queue is full.

pub mod admission;
pub mod autoscale;
pub mod pool;
pub mod steal;

pub use admission::{request_score, strategy_prior_tpc, AdmissionQueue};
pub use autoscale::{AutoscaleConfig, Autoscaler, Demand, EngineScaleConfig, EngineScaler};
pub use steal::WorkQueues;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::adaptive::{self, SeqController};
use crate::config::{
    Dispatch, EngineConfig, Manifest, ServeConfig, SessionCacheConfig, SharedDraft,
};
use crate::draft::{
    fingerprint, ContextNgram, DraftStrategy, ExtendedBigram, JacobiDraft, MixedStrategy,
    ModelBigram, ModelUnigram, NgramTables, SessionNgramCache, SharedDraftStore,
    SharedDraftStrategy, StrategyKind,
};
use crate::engine::{GenResult, NoDraft, SpecDecoder};
use crate::metrics::Metrics;
use crate::runtime::ModelRuntime;
use crate::tokenizer::TokenId;
use crate::trace::{RequestEvent, TraceHub, DEFAULT_RING_CAPACITY};

/// Strategy selector exposed through the API / CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyName {
    /// the paper's SS4.3 mixed policy (context n-gram + extended bigram)
    Mixed,
    /// context n-gram (SS4.2)
    Context,
    /// model bigram top-k (SS4.1)
    Bigram,
    /// model unigram (App. B.1)
    Unigram,
    /// extended bigram chains (SS4.1)
    ExtBigram,
    /// Jacobi decoding baseline
    Jacobi,
    /// online session n-gram cache (extension beyond the paper)
    Session,
    /// online (k, w) + strategy selection (`crate::adaptive`)
    Adaptive,
    /// no drafting (plain greedy decoding)
    None,
}

impl StrategyName {
    /// Every variant. `parse` and its error message derive from this plus
    /// `label()` (whose match the compiler keeps exhaustive), so the name
    /// set lives in exactly one place per direction.
    pub const ALL: [StrategyName; 9] = [
        Self::Mixed,
        Self::Context,
        Self::Bigram,
        Self::Unigram,
        Self::ExtBigram,
        Self::Jacobi,
        Self::Session,
        Self::Adaptive,
        Self::None,
    ];

    /// Parse a CLI/API strategy name (long-form aliases accepted).
    pub fn parse(s: &str) -> Result<Self> {
        // long-form aliases kept for back-compat with existing clients
        let canon = match s {
            "context-ngram" => "context",
            "model-bigram" => "bigram",
            "model-unigram" => "unigram",
            "extended-bigram" => "ext-bigram",
            "session-cache" => "session",
            "greedy" => "none",
            other => other,
        };
        Self::ALL
            .iter()
            .copied()
            .find(|v| v.label() == canon)
            .ok_or_else(|| {
                let valid: Vec<&str> = Self::ALL.iter().map(|v| v.label()).collect();
                anyhow!("unknown strategy '{s}' (valid: {})", valid.join(", "))
            })
    }

    /// Canonical short name (the CLI/API spelling).
    pub fn label(&self) -> &'static str {
        match self {
            Self::Mixed => "mixed",
            Self::Context => "context",
            Self::Bigram => "bigram",
            Self::Unigram => "unigram",
            Self::ExtBigram => "ext-bigram",
            Self::Jacobi => "jacobi",
            Self::Session => "session",
            Self::Adaptive => "adaptive",
            Self::None => "none",
        }
    }

    /// The draft-row [`StrategyKind`]s this strategy actually produces —
    /// the key set for the per-strategy admission prior
    /// ([`strategy_prior_tpc`]): a request's expected tokens/call should
    /// come from its own draft sources' acceptance record, not the
    /// fleet-wide average. `Adaptive` spans its default arm set
    /// ([`crate::adaptive::DEFAULT_ARMS`]); `None` drafts nothing.
    pub fn kinds(&self) -> &'static [StrategyKind] {
        match self {
            Self::Mixed => &[StrategyKind::ContextNgram, StrategyKind::ExtendedBigram],
            Self::Context => &[StrategyKind::ContextNgram],
            Self::Bigram => &[StrategyKind::ModelBigram],
            Self::Unigram => &[StrategyKind::ModelUnigram],
            Self::ExtBigram => &[StrategyKind::ExtendedBigram],
            Self::Jacobi => &[StrategyKind::Jacobi],
            Self::Session => &[StrategyKind::SessionCache],
            Self::Adaptive => &[
                StrategyKind::ContextNgram,
                StrategyKind::ExtendedBigram,
                StrategyKind::SessionCache,
            ],
            Self::None => &[],
        }
    }
}

/// Speculation-depth class of a request — the engine pool's routing
/// bucket. Greedy (w = 0) and speculative traffic are kept on different
/// engines while capacity allows, so a greedy request can only collapse
/// the packed depth of a group that is already greedy (the in-engine
/// per-class depth split covers the forced-mixing fallback).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DepthClass {
    /// speculation disabled: strategy `none` or a w = 0 shape
    Greedy,
    /// every other request (drafts ride verification calls)
    Speculative,
}

impl DepthClass {
    /// Classify a request the same way [`request_score`] prices it: it is
    /// greedy exactly when speculation cannot emit more than one token
    /// per call by construction.
    pub fn of(strategy: StrategyName, engine: &EngineConfig) -> Self {
        if strategy == StrategyName::None || engine.w == 0 {
            DepthClass::Greedy
        } else {
            DepthClass::Speculative
        }
    }
}

/// Build a boxed strategy (used by workers, benches and examples alike)
/// with default session-cache bounds.
pub fn make_strategy(
    name: StrategyName,
    tables: &Arc<NgramTables>,
    q: usize,
) -> Box<dyn DraftStrategy> {
    make_strategy_with_cache(name, tables, q, &SessionCacheConfig::default())
}

/// [`make_strategy`] with explicit session-cache bounds (`ServeConfig::
/// session_cache`). `Adaptive` is a control MODE, not a drafting source:
/// every real adaptive path attaches a [`SeqController`] to the engine
/// (which owns the drafting arms and ignores the engine's strategy slot),
/// so `Adaptive` maps to the no-op placeholder here rather than building
/// a strategy that would never be consulted.
pub fn make_strategy_with_cache(
    name: StrategyName,
    tables: &Arc<NgramTables>,
    q: usize,
    cache: &SessionCacheConfig,
) -> Box<dyn DraftStrategy> {
    match name {
        StrategyName::Mixed => Box::new(MixedStrategy::paper(tables.clone(), q)),
        StrategyName::Context => Box::new(ContextNgram::new(q)),
        StrategyName::Bigram => Box::new(ModelBigram::new(tables.clone())),
        StrategyName::Unigram => Box::new(ModelUnigram::new(tables.clone())),
        StrategyName::ExtBigram => Box::new(ExtendedBigram::new(tables.clone())),
        StrategyName::Jacobi => Box::new(JacobiDraft::new(0)),
        StrategyName::Session => {
            Box::new(SessionNgramCache::new(cache.per_query, cache.max_chain, cache.cap))
        }
        StrategyName::Adaptive | StrategyName::None => Box::new(NoDraft),
    }
}

/// The adaptive controller for one request, when the request asked for
/// adaptive mode — warm-started from the most specific acceptance record
/// available: the prompt's task-class priors in the fleet draft store
/// (`--shared-draft fleet`, [`adaptive::fingerprint_arm_priors`]) when
/// that class has history, else the fleet-wide per-strategy counters (the
/// serving half of the ROADMAP "cross-request bandit priors";
/// `strategy_prior_tpc` is the admission half).
fn controller_for_request(
    name: StrategyName,
    tables: &Arc<NgramTables>,
    q: usize,
    cfg: &ServeConfig,
    runtime: &ModelRuntime,
    metrics: &Metrics,
    shared: Option<&SharedDraftStore>,
    prompt: &[TokenId],
) -> Option<SeqController> {
    (name == StrategyName::Adaptive).then(|| {
        adaptive::controller_for_fingerprint(
            tables,
            q,
            &cfg.session_cache,
            &runtime.artifacts().dims.analog,
            metrics,
            shared,
            prompt,
        )
    })
}

/// The fleet draft store for this serving process, when
/// `--shared-draft fleet` asked for one (shared by every engine in every
/// dispatch mode; see [`crate::draft::shared`]).
pub(crate) fn shared_store_for(cfg: &ServeConfig) -> Option<Arc<SharedDraftStore>> {
    (cfg.shared_draft == SharedDraft::Fleet)
        .then(|| Arc::new(SharedDraftStore::new(cfg.shared_draft_shards)))
}

/// Give `strategy` a fleet memory when a shared store is attached: reads
/// fill spare draft rows from shared chains, accepted tokens publish
/// batched deltas, and `engine_hits` (when present) receives the engine's
/// proposed-shared-row count for the per-engine hit-through gauge. A
/// `None` store returns the strategy unchanged — the private behavior.
pub(crate) fn wrap_shared(
    strategy: Box<dyn DraftStrategy>,
    shared: Option<&Arc<SharedDraftStore>>,
    engine_hits: Option<Arc<AtomicU64>>,
) -> Box<dyn DraftStrategy> {
    match shared {
        Some(store) => Box::new(SharedDraftStrategy::new(strategy, store.clone(), engine_hits)),
        None => strategy,
    }
}

/// Record a finished request's per-step outcomes under its prompt
/// fingerprint (task class), so later same-class requests seed their
/// bandit from this history. Same no-winner demotion as the fleet-wide
/// counters in [`finish_response`]. No-op without a store or without
/// collected traces.
pub(crate) fn record_fingerprint(
    shared: Option<&SharedDraftStore>,
    prompt: &[TokenId],
    r: &crate::engine::GenResult,
) {
    record_fingerprint_fp(shared, fingerprint(prompt), r);
}

/// [`record_fingerprint`] with the fingerprint precomputed — the pool
/// paths hash at admission and carry the `u64` through the in-flight map
/// rather than keeping a prompt copy alive until retirement.
pub(crate) fn record_fingerprint_fp(
    shared: Option<&SharedDraftStore>,
    fp: u64,
    r: &crate::engine::GenResult,
) {
    let Some(store) = shared else { return };
    for tr in &r.traces {
        let kind = if tr.accepted > 0 { tr.kind } else { StrategyKind::Empty };
        store.record_step(fp, kind, tr.accepted);
    }
}

/// Copy the store's counters into the serving metrics gauges (the store
/// is the source of truth; `/metrics` mirrors it so the draft layer needs
/// no metrics dependency). Called from each mode's publish point.
pub(crate) fn mirror_shared_metrics(metrics: &Metrics, store: &SharedDraftStore) {
    metrics.shared_draft_hits.store(store.hits(), Ordering::Relaxed);
    metrics.shared_draft_misses.store(store.misses(), Ordering::Relaxed);
    metrics.shared_draft_publishes.store(store.publishes(), Ordering::Relaxed);
}

/// One generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// tokenized prompt
    pub prompt: Vec<TokenId>,
    /// per-request engine settings
    pub engine: EngineConfig,
    /// draft strategy for this request
    pub strategy: StrategyName,
}

/// Completed response.
#[derive(Debug)]
pub struct GenResponse {
    /// emitted tokens (the first comes from prefill)
    pub tokens: Vec<TokenId>,
    /// the paper's acceptance metric for this request
    pub tokens_per_call: f64,
    /// verification calls spent
    pub calls: usize,
    /// submit-to-reply latency in milliseconds
    pub latency_ms: f64,
}

/// Where a finished request's [`GenResponse`] is delivered. Blocking
/// callers ([`Scheduler::submit`]) use a channel and park on its
/// receiver; the event-driven reactor front-end
/// ([`crate::server::reactor`]) registers a callback that enqueues the
/// completion and wakes its event loop, so no thread blocks per request.
pub enum ReplySink {
    /// deliver by sending on an mpsc channel (a dropped receiver is fine
    /// — the caller went away and the result is discarded)
    Channel(Sender<Result<GenResponse>>),
    /// deliver by invoking a one-shot callback on the worker thread; the
    /// callback must be cheap and non-blocking (the reactor's pushes a
    /// completion record and writes one eventfd wakeup)
    Callback(Box<dyn FnOnce(Result<GenResponse>) + Send>),
}

impl ReplySink {
    /// Deliver the result, consuming the sink.
    pub fn send(self, r: Result<GenResponse>) {
        match self {
            ReplySink::Channel(tx) => {
                let _ = tx.send(r);
            }
            ReplySink::Callback(f) => f(r),
        }
    }
}

impl std::fmt::Debug for ReplySink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReplySink::Channel(_) => f.write_str("ReplySink::Channel"),
            ReplySink::Callback(_) => f.write_str("ReplySink::Callback"),
        }
    }
}

/// Cooperative cancellation flag for one in-flight request. The serving
/// front-end cancels it when the client disconnects; workers check it at
/// dequeue (per-sequence mode), at admission, and between engine steps
/// (pool modes), so a cancelled request frees its lane/pages within a
/// step instead of decoding to completion for nobody. Cancellation is
/// advisory — a request that wins the race and completes anyway is
/// delivered to its sink, which discards it.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// A fresh, un-cancelled token.
    pub fn new() -> Self {
        Self::default()
    }

    /// Flag the request as cancelled (idempotent, thread-safe).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    /// Whether the request has been cancelled.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

struct Job {
    req: GenRequest,
    reply: ReplySink,
    cancel: CancelToken,
    /// stamped in [`Scheduler::submit`]; queue-wait and TTFT spans are
    /// measured from here
    t_submit: Instant,
}

/// How submitted jobs reach the decode workers.
enum SubmitPath {
    /// bounded sync channel: per-sequence workers or the central
    /// dispatcher drain it
    Channel(SyncSender<Job>),
    /// per-engine work queues with idle-engine stealing (no dispatcher
    /// thread between submit and admit)
    Steal(Arc<steal::StealDispatch>),
}

/// The scheduler handle: cheap to clone, submits jobs to the pool.
pub struct Scheduler {
    path: SubmitPath,
    /// shared serving metrics (rendered at GET /metrics)
    pub metrics: Arc<Metrics>,
    /// flight-recorder hub: per-engine step rings + request spans
    /// (served at GET /trace and summarized at GET /stats)
    pub trace: Arc<TraceHub>,
    workers: Vec<JoinHandle<()>>,
}

impl Scheduler {
    /// Spin up workers for `model`: `cfg.workers` per-sequence workers, or
    /// (when `cfg.batch >= 2`) an engine-[`pool`] dispatcher thread
    /// driving up to `cfg.engines` batched engine workers — each with
    /// `cfg.batch` pooled KV lanes when `cfg.elastic` is off, or a
    /// demand-autoscaled lane pool capped at `cfg.batch` when it is on
    /// (the default, which also spawns/retires whole engines on sustained
    /// pressure/quiet). Each engine thread loads its own ModelRuntime.
    pub fn start(manifest: &Manifest, model: &str, cfg: &ServeConfig) -> Result<Scheduler> {
        let art = manifest.model(model)?.clone();
        let tables = Arc::new(NgramTables::load(&art)?);
        let metrics = Arc::new(Metrics::new());
        let trace = Arc::new(TraceHub::with_metrics(DEFAULT_RING_CAPACITY, metrics.clone()));
        let shared = shared_store_for(cfg);

        let mut workers = Vec::new();
        let path = if cfg.batch >= 2 && cfg.dispatch == Dispatch::Steal {
            let (dispatch, mut handles) = steal::start(
                art, tables, metrics.clone(), trace.clone(), cfg.clone(), shared);
            workers.append(&mut handles);
            SubmitPath::Steal(dispatch)
        } else {
            let (tx, rx) = sync_channel::<Job>(cfg.queue_cap);
            let rx = Arc::new(Mutex::new(rx));
            if cfg.batch >= 2 {
                let tables = tables.clone();
                let metrics = metrics.clone();
                let trace = trace.clone();
                let scfg = cfg.clone();
                let handle = std::thread::Builder::new()
                    .name("ngrammys-engine-pool".to_string())
                    .spawn(move || pool::run_pool(art, tables, metrics, trace, rx, scfg, shared))
                    .expect("spawning engine pool");
                workers.push(handle);
            } else {
                for wid in 0..cfg.workers.max(1) {
                    let rx = rx.clone();
                    let art = art.clone();
                    let tables = tables.clone();
                    let metrics = metrics.clone();
                    let trace = trace.clone();
                    let scfg = cfg.clone();
                    let shared = shared.clone();
                    let handle = std::thread::Builder::new()
                        .name(format!("ngrammys-worker-{wid}"))
                        .spawn(move || {
                            let runtime = match ModelRuntime::load(&art) {
                                Ok(rt) => rt,
                                Err(e) => {
                                    eprintln!("worker {wid}: runtime load failed: {e:#}");
                                    return;
                                }
                            };
                            worker_loop(wid, runtime, tables, metrics, trace, rx, &scfg, shared);
                        })
                        .expect("spawning worker");
                    workers.push(handle);
                }
            }
            SubmitPath::Channel(tx)
        };
        Ok(Scheduler { path, metrics, trace, workers })
    }

    /// Non-blocking admission; `Err` = queue full (backpressure). A
    /// rejection is never silent: it bumps `requests_rejected` (rendered
    /// at `/metrics`) and logs the drop with the queue size so overload
    /// is visible on both the dashboard and the console.
    pub fn submit(&self, req: GenRequest) -> Result<Receiver<Result<GenResponse>>> {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        self.submit_with(req, ReplySink::Channel(reply_tx), CancelToken::new())?;
        Ok(reply_rx)
    }

    /// [`Self::submit`] with an explicit delivery sink and cancellation
    /// token — the entry point for front-ends that neither park a thread
    /// per request (reactor callbacks) nor outlive their client (a
    /// disconnect cancels the token). Same backpressure contract as
    /// `submit`.
    pub fn submit_with(
        &self,
        req: GenRequest,
        reply: ReplySink,
        cancel: CancelToken,
    ) -> Result<()> {
        self.metrics.requests_total.fetch_add(1, Ordering::Relaxed);
        let job = Job { req, reply, cancel, t_submit: Instant::now() };
        match &self.path {
            SubmitPath::Channel(tx) => match tx.try_send(job) {
                Ok(()) => {
                    self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
                    Ok(())
                }
                Err(TrySendError::Full(_)) => {
                    let n = self.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed) + 1;
                    eprintln!("scheduler: queue full, rejecting request ({n} rejected total)");
                    Err(anyhow!("queue full"))
                }
                Err(TrySendError::Disconnected(_)) => Err(anyhow!("scheduler stopped")),
            },
            SubmitPath::Steal(d) => d.submit(job),
        }
    }

    /// Submit and wait (convenience for tests/examples).
    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| anyhow!("worker dropped"))?
    }

    /// Graceful shutdown: close the queue and join workers. Requests
    /// already queued or in flight drain to completion in every mode.
    pub fn shutdown(self) {
        match self.path {
            SubmitPath::Channel(tx) => drop(tx),
            SubmitPath::Steal(d) => d.close(),
        }
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Fold a finished [`GenResult`] into the serving metrics + trace hub and
/// build the reply. `queue_wait` is the submit → dequeue/admit dwell;
/// TTFT is that dwell plus the prefill call (the first token IS the
/// prefill's output), inter-token latency is the remaining decode spread
/// over the remaining tokens — both observed into their histograms and
/// logged as a [`RequestEvent`] by the hub.
fn finish_response(
    metrics: &Metrics,
    trace: &TraceHub,
    t_submit: Instant,
    queue_wait: Duration,
    r: GenResult,
) -> GenResponse {
    let accepted = r.tokens.len().saturating_sub(r.calls);
    let total = t_submit.elapsed();
    metrics.record_request(total, r.tokens.len(), r.calls, accepted);
    for tr in &r.traces {
        metrics.step_latency.observe(tr.exec_time);
        // a call where no draft token matched has no winning strategy —
        // the judge's row-0 default would otherwise credit whatever kind
        // fills row 0 (context-ngram under the mixed policy) with a "win"
        let kind = if tr.accepted > 0 { tr.kind } else { StrategyKind::Empty };
        metrics.record_strategy_step(kind, tr.accepted);
    }
    let ttft = queue_wait + r.prefill_time;
    trace.record_request(RequestEvent {
        t_us: 0, // stamped by the hub
        queue_us: queue_wait.as_micros() as u64,
        prefill_us: r.prefill_time.as_micros() as u64,
        ttft_us: ttft.as_micros() as u64,
        total_us: total.as_micros() as u64,
        tokens: r.tokens.len() as u32,
        calls: r.calls as u32,
    });
    GenResponse {
        tokens_per_call: r.tokens_per_call(),
        calls: r.calls,
        latency_ms: total.as_secs_f64() * 1e3,
        tokens: r.tokens,
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    wid: usize,
    runtime: ModelRuntime,
    tables: Arc<NgramTables>,
    metrics: Arc<Metrics>,
    trace: Arc<TraceHub>,
    rx: Arc<Mutex<Receiver<Job>>>,
    scfg: &ServeConfig,
    shared: Option<Arc<SharedDraftStore>>,
) {
    let recorder = trace.recorder_for_engine(wid as u64);
    loop {
        // hold the lock only while dequeuing
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => return, // scheduler dropped
        };
        metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
        if job.cancel.is_cancelled() {
            metrics.requests_cancelled.fetch_add(1, Ordering::Relaxed);
            job.reply.send(Err(anyhow!("request cancelled: client disconnected")));
            continue;
        }
        let queue_wait = job.t_submit.elapsed();
        let strategy = wrap_shared(
            make_strategy_with_cache(
                job.req.strategy, &tables, job.req.engine.q, &scfg.session_cache),
            shared.as_ref(),
            None, // per-sequence workers have no per-engine gauge row
        );
        let mut dec = SpecDecoder::new(&runtime, strategy, job.req.engine.clone());
        dec.controller = controller_for_request(
            job.req.strategy, &tables, job.req.engine.q, scfg, &runtime, &metrics,
            shared.as_deref(), &job.req.prompt);
        dec.collect_traces = true; // feeds the step-latency histogram
        dec.recorder = Some(recorder.clone());
        let result = dec.generate(&job.req.prompt).map(|r| {
            record_fingerprint(shared.as_deref(), &job.req.prompt, &r);
            finish_response(&metrics, &trace, job.t_submit, queue_wait, r)
        });
        drop(dec); // the shared wrapper's Drop publishes its buffered tail
        if let Some(store) = shared.as_deref() {
            mirror_shared_metrics(&metrics, store);
        }
        job.reply.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_names_parse() {
        for (s, n) in [
            ("mixed", StrategyName::Mixed),
            ("context", StrategyName::Context),
            ("bigram", StrategyName::Bigram),
            ("unigram", StrategyName::Unigram),
            ("ext-bigram", StrategyName::ExtBigram),
            ("jacobi", StrategyName::Jacobi),
            ("session", StrategyName::Session),
            ("adaptive", StrategyName::Adaptive),
            ("greedy", StrategyName::None),
        ] {
            assert_eq!(StrategyName::parse(s).unwrap(), n);
        }
        // the error must enumerate every valid name, not just echo the input
        let err = StrategyName::parse("bogus").unwrap_err().to_string();
        assert!(err.contains("bogus"));
        for v in StrategyName::ALL {
            assert!(err.contains(v.label()), "error missing '{}': {err}", v.label());
        }
    }

    #[test]
    fn every_variant_round_trips_through_parse() {
        for v in StrategyName::ALL {
            assert_eq!(StrategyName::parse(v.label()).unwrap(), v);
        }
    }
}
