//! Request scheduler: bounded admission queue + decode workers.
//!
//! Two execution modes, selected by `ServeConfig::batch`:
//!
//! - **Per-sequence workers** (`batch <= 1`, the paper's §3 setting): each
//!   worker owns a private `ModelRuntime` and decodes one request at a time
//!   with `SpecDecoder` — the model-call batch dimension is spent entirely
//!   on that request's speculation rows.
//! - **Batched engine** (`batch >= 2`): one engine thread drives a
//!   continuous-batching [`BatchedEngine`]. Requests are admitted as lanes
//!   free up, every active sequence's draft rows are verified in one
//!   packed call per step, and responses complete out of order — the batch
//!   dimension is spent on requests AND rows. By default the engine is
//!   **elastic** (`ServeConfig::elastic`): the lane pool scales between
//!   `autoscale.min_lanes` and `batch` from observed demand
//!   ([`autoscale::Autoscaler`]), the per-step row budget is derived
//!   online from the cost model (`--budget` caps it), and admissions are
//!   ordered by expected accepted-tokens-per-cost
//!   ([`admission::AdmissionQueue`]) rather than FIFO.
//!
//! Both modes share the same bounded-queue backpressure: `submit` fails
//! fast — counting and logging the rejection — when the queue is full.

pub mod admission;
pub mod autoscale;

pub use admission::{request_score, AdmissionQueue};
pub use autoscale::{AutoscaleConfig, Autoscaler, Demand};

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TryRecvError, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::adaptive::{self, SeqController};
use crate::config::{EngineConfig, Manifest, ServeConfig, SessionCacheConfig};
use crate::costmodel::CostModel;
use crate::draft::{
    ContextNgram, DraftStrategy, ExtendedBigram, JacobiDraft, MixedStrategy, ModelBigram,
    ModelUnigram, NgramTables, SessionNgramCache, StrategyKind,
};
use crate::engine::{AutoBudget, BatchedEngine, GenResult, NoDraft, SeqId, SpecDecoder};
use crate::metrics::Metrics;
use crate::runtime::ModelRuntime;
use crate::tokenizer::TokenId;

/// Strategy selector exposed through the API / CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyName {
    /// the paper's SS4.3 mixed policy (context n-gram + extended bigram)
    Mixed,
    /// context n-gram (SS4.2)
    Context,
    /// model bigram top-k (SS4.1)
    Bigram,
    /// model unigram (App. B.1)
    Unigram,
    /// extended bigram chains (SS4.1)
    ExtBigram,
    /// Jacobi decoding baseline
    Jacobi,
    /// online session n-gram cache (extension beyond the paper)
    Session,
    /// online (k, w) + strategy selection (`crate::adaptive`)
    Adaptive,
    /// no drafting (plain greedy decoding)
    None,
}

impl StrategyName {
    /// Every variant. `parse` and its error message derive from this plus
    /// `label()` (whose match the compiler keeps exhaustive), so the name
    /// set lives in exactly one place per direction.
    pub const ALL: [StrategyName; 9] = [
        Self::Mixed,
        Self::Context,
        Self::Bigram,
        Self::Unigram,
        Self::ExtBigram,
        Self::Jacobi,
        Self::Session,
        Self::Adaptive,
        Self::None,
    ];

    /// Parse a CLI/API strategy name (long-form aliases accepted).
    pub fn parse(s: &str) -> Result<Self> {
        // long-form aliases kept for back-compat with existing clients
        let canon = match s {
            "context-ngram" => "context",
            "model-bigram" => "bigram",
            "model-unigram" => "unigram",
            "extended-bigram" => "ext-bigram",
            "session-cache" => "session",
            "greedy" => "none",
            other => other,
        };
        Self::ALL
            .iter()
            .copied()
            .find(|v| v.label() == canon)
            .ok_or_else(|| {
                let valid: Vec<&str> = Self::ALL.iter().map(|v| v.label()).collect();
                anyhow!("unknown strategy '{s}' (valid: {})", valid.join(", "))
            })
    }

    /// Canonical short name (the CLI/API spelling).
    pub fn label(&self) -> &'static str {
        match self {
            Self::Mixed => "mixed",
            Self::Context => "context",
            Self::Bigram => "bigram",
            Self::Unigram => "unigram",
            Self::ExtBigram => "ext-bigram",
            Self::Jacobi => "jacobi",
            Self::Session => "session",
            Self::Adaptive => "adaptive",
            Self::None => "none",
        }
    }
}

/// Build a boxed strategy (used by workers, benches and examples alike)
/// with default session-cache bounds.
pub fn make_strategy(
    name: StrategyName,
    tables: &Arc<NgramTables>,
    q: usize,
) -> Box<dyn DraftStrategy> {
    make_strategy_with_cache(name, tables, q, &SessionCacheConfig::default())
}

/// [`make_strategy`] with explicit session-cache bounds (`ServeConfig::
/// session_cache`). `Adaptive` is a control MODE, not a drafting source:
/// every real adaptive path attaches a [`SeqController`] to the engine
/// (which owns the drafting arms and ignores the engine's strategy slot),
/// so `Adaptive` maps to the no-op placeholder here rather than building
/// a strategy that would never be consulted.
pub fn make_strategy_with_cache(
    name: StrategyName,
    tables: &Arc<NgramTables>,
    q: usize,
    cache: &SessionCacheConfig,
) -> Box<dyn DraftStrategy> {
    match name {
        StrategyName::Mixed => Box::new(MixedStrategy::paper(tables.clone(), q)),
        StrategyName::Context => Box::new(ContextNgram::new(q)),
        StrategyName::Bigram => Box::new(ModelBigram::new(tables.clone())),
        StrategyName::Unigram => Box::new(ModelUnigram::new(tables.clone())),
        StrategyName::ExtBigram => Box::new(ExtendedBigram::new(tables.clone())),
        StrategyName::Jacobi => Box::new(JacobiDraft::new(0)),
        StrategyName::Session => {
            Box::new(SessionNgramCache::new(cache.per_query, cache.max_chain, cache.cap))
        }
        StrategyName::Adaptive | StrategyName::None => Box::new(NoDraft),
    }
}

/// The adaptive controller for one request, when the request asked for
/// adaptive mode.
fn controller_for_request(
    name: StrategyName,
    tables: &Arc<NgramTables>,
    q: usize,
    cfg: &ServeConfig,
    runtime: &ModelRuntime,
) -> Option<SeqController> {
    (name == StrategyName::Adaptive).then(|| {
        adaptive::controller_for(tables, q, &cfg.session_cache,
                                 &runtime.artifacts().dims.analog)
    })
}

/// One generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// tokenized prompt
    pub prompt: Vec<TokenId>,
    /// per-request engine settings
    pub engine: EngineConfig,
    /// draft strategy for this request
    pub strategy: StrategyName,
}

/// Completed response.
#[derive(Debug)]
pub struct GenResponse {
    /// emitted tokens (the first comes from prefill)
    pub tokens: Vec<TokenId>,
    /// the paper's acceptance metric for this request
    pub tokens_per_call: f64,
    /// verification calls spent
    pub calls: usize,
    /// submit-to-reply latency in milliseconds
    pub latency_ms: f64,
}

struct Job {
    req: GenRequest,
    reply: Sender<Result<GenResponse>>,
}

/// The scheduler handle: cheap to clone, submits jobs to the pool.
pub struct Scheduler {
    tx: SyncSender<Job>,
    /// shared serving metrics (rendered at GET /metrics)
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
}

impl Scheduler {
    /// Spin up workers for `model`: `cfg.workers` per-sequence workers, or
    /// (when `cfg.batch >= 2`) one batched engine thread — with `cfg.batch`
    /// pooled KV lanes when `cfg.elastic` is off, or a demand-autoscaled
    /// lane pool capped at `cfg.batch` when it is on (the default). Each
    /// thread loads its own ModelRuntime.
    pub fn start(manifest: &Manifest, model: &str, cfg: &ServeConfig) -> Result<Scheduler> {
        let art = manifest.model(model)?.clone();
        let tables = Arc::new(NgramTables::load(&art)?);
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Job>(cfg.queue_cap);
        let rx = Arc::new(Mutex::new(rx));

        let mut workers = Vec::new();
        if cfg.batch >= 2 {
            let lanes = cfg.batch;
            let rx = rx.clone();
            let tables = tables.clone();
            let metrics = metrics.clone();
            let scfg = cfg.clone();
            let handle = std::thread::Builder::new()
                .name("ngrammys-batch-engine".to_string())
                .spawn(move || {
                    let runtime = match ModelRuntime::load(&art) {
                        Ok(rt) => rt,
                        Err(e) => {
                            eprintln!("batch engine: runtime load failed: {e:#}");
                            return;
                        }
                    };
                    batched_worker_loop(&runtime, lanes, tables, metrics, rx, &scfg);
                })
                .expect("spawning batch engine");
            workers.push(handle);
        } else {
            for wid in 0..cfg.workers.max(1) {
                let rx = rx.clone();
                let art = art.clone();
                let tables = tables.clone();
                let metrics = metrics.clone();
                let scfg = cfg.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("ngrammys-worker-{wid}"))
                    .spawn(move || {
                        let runtime = match ModelRuntime::load(&art) {
                            Ok(rt) => rt,
                            Err(e) => {
                                eprintln!("worker {wid}: runtime load failed: {e:#}");
                                return;
                            }
                        };
                        worker_loop(wid, runtime, tables, metrics, rx, &scfg);
                    })
                    .expect("spawning worker");
                workers.push(handle);
            }
        }
        Ok(Scheduler { tx, metrics, workers })
    }

    /// Non-blocking admission; `Err` = queue full (backpressure). A
    /// rejection is never silent: it bumps `requests_rejected` (rendered
    /// at `/metrics`) and logs the drop with the queue size so overload
    /// is visible on both the dashboard and the console.
    pub fn submit(&self, req: GenRequest) -> Result<Receiver<Result<GenResponse>>> {
        self.metrics.requests_total.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        match self.tx.try_send(Job { req, reply: reply_tx }) {
            Ok(()) => {
                self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
                Ok(reply_rx)
            }
            Err(TrySendError::Full(_)) => {
                let n = self.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed) + 1;
                eprintln!("scheduler: queue full, rejecting request ({n} rejected total)");
                Err(anyhow!("queue full"))
            }
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("scheduler stopped")),
        }
    }

    /// Submit and wait (convenience for tests/examples).
    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| anyhow!("worker dropped"))?
    }

    /// Graceful shutdown: close the queue and join workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn finish_response(metrics: &Metrics, t_submit: Instant, r: GenResult) -> GenResponse {
    let accepted = r.tokens.len().saturating_sub(r.calls);
    metrics.record_request(t_submit.elapsed(), r.tokens.len(), r.calls, accepted);
    for tr in &r.traces {
        metrics.step_latency.observe(tr.exec_time);
        // a call where no draft token matched has no winning strategy —
        // the judge's row-0 default would otherwise credit whatever kind
        // fills row 0 (context-ngram under the mixed policy) with a "win"
        let kind = if tr.accepted > 0 { tr.kind } else { StrategyKind::Empty };
        metrics.record_strategy_step(kind, tr.accepted);
    }
    GenResponse {
        tokens_per_call: r.tokens_per_call(),
        calls: r.calls,
        latency_ms: t_submit.elapsed().as_secs_f64() * 1e3,
        tokens: r.tokens,
    }
}

fn worker_loop(
    _wid: usize,
    runtime: ModelRuntime,
    tables: Arc<NgramTables>,
    metrics: Arc<Metrics>,
    rx: Arc<Mutex<Receiver<Job>>>,
    scfg: &ServeConfig,
) {
    loop {
        // hold the lock only while dequeuing
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => return, // scheduler dropped
        };
        metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
        let t = Instant::now();
        let strategy = make_strategy_with_cache(
            job.req.strategy, &tables, job.req.engine.q, &scfg.session_cache);
        let mut dec = SpecDecoder::new(&runtime, strategy, job.req.engine.clone());
        dec.controller =
            controller_for_request(job.req.strategy, &tables, job.req.engine.q, scfg, &runtime);
        dec.collect_traces = true; // feeds the step-latency histogram
        let result = dec
            .generate(&job.req.prompt)
            .map(|r| finish_response(&metrics, t, r));
        let _ = job.reply.send(result);
    }
}

/// A fresh batched engine for the worker loop: traces on (they feed the
/// step-latency histogram) and, in elastic mode, the online-derived row
/// budget installed with the operator `--budget` demoted to a cap.
fn fresh_engine<'rt>(
    runtime: &'rt ModelRuntime,
    lanes: usize,
    scfg: &ServeConfig,
    analog: &str,
) -> BatchedEngine<'rt> {
    let mut eng = BatchedEngine::with_budget(runtime, lanes, scfg.budget);
    eng.collect_traces = true;
    if scfg.elastic {
        eng.auto_budget = Some(AutoBudget {
            cm: CostModel::for_analog(analog),
            slack: scfg.budget_slack,
        });
    }
    eng
}

/// Score an arriving job and move it into the admission holding pen.
/// With elastic off, every job gets the same score, and the queue's
/// FIFO tie-break makes admission exactly the pre-elastic arrival order.
fn enqueue_job(
    adq: &mut AdmissionQueue<Job>,
    job: Job,
    cm: &CostModel,
    metrics: &Metrics,
    elastic: bool,
) {
    let score = if elastic {
        request_score(
            cm,
            metrics.tokens_per_call(),
            job.req.strategy,
            &job.req.engine,
            job.req.prompt.len(),
        )
    } else {
        0.0
    };
    adq.push(job, score);
}

/// The continuous-batching worker: one engine, many in-flight requests.
/// Blocks on the queue only when idle; while sequences are active it
/// drains the queue opportunistically between steps so arrivals join the
/// running batch without waiting for it to finish.
///
/// Elastic mode (`scfg.elastic`, the default) closes three loops per
/// iteration that the static mode leaves to the operator:
///
/// 1. **lanes** — the [`Autoscaler`] turns (queue depth, active count,
///    mean controller heat) into a lane target between
///    `autoscale.min_lanes` and `lane_cap`, applied via
///    `BatchedEngine::set_capacity` (shrinks reclaim only free lanes);
/// 2. **budget** — the engine re-derives its packed-row budget each step
///    from `CostModel::memory_bound_rows` at the current context lengths
///    (`--budget` caps it);
/// 3. **admission order** — lanes go to the highest
///    [`request_score`] first instead of FIFO.
///
/// None of this touches output bytes: every stream stays the base
/// model's greedy continuation (asserted in `rust/tests/elastic.rs`).
fn batched_worker_loop(
    runtime: &ModelRuntime,
    lane_cap: usize,
    tables: Arc<NgramTables>,
    metrics: Arc<Metrics>,
    rx: Arc<Mutex<Receiver<Job>>>,
    scfg: &ServeConfig,
) {
    let analog = runtime.artifacts().dims.analog.clone();
    let cm = CostModel::for_analog(&analog);
    let mut au_cfg = scfg.autoscale.clone();
    au_cfg.max_lanes = lane_cap;
    au_cfg.min_lanes = au_cfg.min_lanes.clamp(1, lane_cap);
    let boot_lanes = if scfg.elastic { au_cfg.min_lanes } else { lane_cap };
    let mut scaler = Autoscaler::new(au_cfg);

    let mut eng = fresh_engine(runtime, boot_lanes, scfg, &analog);
    let mut adq: AdmissionQueue<Job> = AdmissionQueue::new();
    let mut inflight: HashMap<SeqId, (Sender<Result<GenResponse>>, Instant)> = HashMap::new();
    loop {
        // block for work only when fully idle
        if eng.active() == 0 && adq.is_empty() {
            if scfg.elastic {
                // Fully idle: give the lane memory back NOW. The loop is
                // about to block, so the hysteretic scale-down path below
                // would never tick; with every lane free the shrink to
                // min_lanes succeeds in one call.
                let min = scaler.config().min_lanes;
                let lanes = eng.set_capacity(min);
                metrics.lanes_target.store(min as u64, Ordering::Relaxed);
                metrics.lanes.store(lanes as u64, Ordering::Relaxed);
            }
            match rx.lock().unwrap().recv() {
                Ok(job) => enqueue_job(&mut adq, job, &cm, &metrics, scfg.elastic),
                Err(_) => return, // scheduler dropped, everything drained
            }
        }
        // drain arrivals into the scored holding pen
        loop {
            match rx.lock().unwrap().try_recv() {
                Ok(job) => enqueue_job(&mut adq, job, &cm, &metrics, scfg.elastic),
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        // scale lanes to demand
        if scfg.elastic {
            let target = scaler.target_lanes(&Demand {
                queue_depth: adq.len(),
                active: eng.active(),
                lanes: eng.capacity(),
                mean_heat: eng.mean_heat(),
            });
            let achieved = eng.set_capacity(target);
            metrics.lanes_target.store(target as u64, Ordering::Relaxed);
            metrics.lanes.store(achieved as u64, Ordering::Relaxed);
        } else {
            metrics.lanes_target.store(lane_cap as u64, Ordering::Relaxed);
            metrics.lanes.store(eng.capacity() as u64, Ordering::Relaxed);
        }
        // admit best-scored first while lanes are free
        while eng.has_capacity() {
            let Some(job) = adq.pop_best() else { break };
            admit_job(&mut eng, job, &tables, &metrics, &mut inflight, scfg, runtime);
        }
        metrics.admission_reorders.store(adq.reorders(), Ordering::Relaxed);
        if eng.active() == 0 {
            continue; // every pending admission failed; wait for work
        }
        match eng.step() {
            Ok(done) => {
                if let Some(b) = eng.last_step_budget() {
                    metrics.derived_budget.store(b as u64, Ordering::Relaxed);
                }
                for (id, r) in done {
                    if let Some((reply, t)) = inflight.remove(&id) {
                        let _ = reply.send(Ok(finish_response(&metrics, t, r)));
                    }
                }
            }
            Err(e) => {
                // A step error poisons the whole batch (shared call): fail
                // every in-flight request and restart with a fresh engine
                // at the capacity the autoscaler had reached.
                eprintln!("batch engine: step failed: {e:#}");
                for (_, (reply, _)) in inflight.drain() {
                    let _ = reply.send(Err(anyhow!("batched engine step failed: {e:#}")));
                }
                let lanes = eng.capacity();
                eng = fresh_engine(runtime, lanes, scfg, &analog);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn admit_job(
    eng: &mut BatchedEngine,
    job: Job,
    tables: &Arc<NgramTables>,
    metrics: &Metrics,
    inflight: &mut HashMap<SeqId, (Sender<Result<GenResponse>>, Instant)>,
    scfg: &ServeConfig,
    runtime: &ModelRuntime,
) {
    metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
    let strategy =
        make_strategy_with_cache(job.req.strategy, tables, job.req.engine.q, &scfg.session_cache);
    let controller =
        controller_for_request(job.req.strategy, tables, job.req.engine.q, scfg, runtime);
    // start the latency clock BEFORE admit: admit runs the prefill, which
    // the per-sequence worker's clock also covers — keep the modes
    // comparable in latency_ms and /metrics
    let t = Instant::now();
    match eng.admit_with(&job.req.prompt, strategy, controller, job.req.engine.clone()) {
        Ok(id) => {
            inflight.insert(id, (job.reply, t));
        }
        Err(e) => {
            // count + log: an admission that dies here (no lane after all,
            // prefill failure) must not vanish into the reply channel only
            metrics.admissions_failed.fetch_add(1, Ordering::Relaxed);
            eprintln!("batch engine: admission failed: {e:#}");
            let _ = job.reply.send(Err(e));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_names_parse() {
        for (s, n) in [
            ("mixed", StrategyName::Mixed),
            ("context", StrategyName::Context),
            ("bigram", StrategyName::Bigram),
            ("unigram", StrategyName::Unigram),
            ("ext-bigram", StrategyName::ExtBigram),
            ("jacobi", StrategyName::Jacobi),
            ("session", StrategyName::Session),
            ("adaptive", StrategyName::Adaptive),
            ("greedy", StrategyName::None),
        ] {
            assert_eq!(StrategyName::parse(s).unwrap(), n);
        }
        // the error must enumerate every valid name, not just echo the input
        let err = StrategyName::parse("bogus").unwrap_err().to_string();
        assert!(err.contains("bogus"));
        for v in StrategyName::ALL {
            assert!(err.contains(v.label()), "error missing '{}': {err}", v.label());
        }
    }

    #[test]
    fn every_variant_round_trips_through_parse() {
        for v in StrategyName::ALL {
            assert_eq!(StrategyName::parse(v.label()).unwrap(), v);
        }
    }
}
