//! Request scheduler: bounded FIFO admission queue + worker pool.
//!
//! Parallelism structure mirrors the paper: the *batch dimension of a model
//! call is spent on speculation rows for one sequence* (§3 — the paper
//! serves at request-batch 1 and batches trajectories), so the scheduler
//! parallelizes across requests with workers (each worker owns a
//! ModelRuntime; PJRT executables are per-worker), and backpressure is a
//! bounded queue: `submit` fails fast when the queue is full.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::{EngineConfig, Manifest, ServeConfig};
use crate::draft::{
    ContextNgram, DraftStrategy, ExtendedBigram, JacobiDraft, MixedStrategy, ModelBigram,
    ModelUnigram, NgramTables, SessionNgramCache,
};
use crate::engine::{NoDraft, SpecDecoder};
use crate::metrics::Metrics;
use crate::runtime::ModelRuntime;
use crate::tokenizer::TokenId;

/// Strategy selector exposed through the API / CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyName {
    Mixed,
    Context,
    Bigram,
    Unigram,
    ExtBigram,
    Jacobi,
    /// online session n-gram cache (extension beyond the paper)
    Session,
    None,
}

impl StrategyName {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "mixed" => Self::Mixed,
            "context" | "context-ngram" => Self::Context,
            "bigram" | "model-bigram" => Self::Bigram,
            "unigram" | "model-unigram" => Self::Unigram,
            "ext-bigram" | "extended-bigram" => Self::ExtBigram,
            "jacobi" => Self::Jacobi,
            "session" | "session-cache" => Self::Session,
            "none" | "greedy" => Self::None,
            other => return Err(anyhow!("unknown strategy '{other}'")),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Mixed => "mixed",
            Self::Context => "context",
            Self::Bigram => "bigram",
            Self::Unigram => "unigram",
            Self::ExtBigram => "ext-bigram",
            Self::Jacobi => "jacobi",
            Self::Session => "session",
            Self::None => "none",
        }
    }
}

/// Build a boxed strategy (used by workers, benches and examples alike).
pub fn make_strategy(
    name: StrategyName,
    tables: &Arc<NgramTables>,
    q: usize,
) -> Box<dyn DraftStrategy> {
    match name {
        StrategyName::Mixed => Box::new(MixedStrategy::paper(tables.clone(), q)),
        StrategyName::Context => Box::new(ContextNgram::new(q)),
        StrategyName::Bigram => Box::new(ModelBigram::new(tables.clone())),
        StrategyName::Unigram => Box::new(ModelUnigram::new(tables.clone())),
        StrategyName::ExtBigram => Box::new(ExtendedBigram::new(tables.clone())),
        StrategyName::Jacobi => Box::new(JacobiDraft::new(0)),
        StrategyName::Session => Box::new(SessionNgramCache::new(8, 12, 100_000)),
        StrategyName::None => Box::new(NoDraft),
    }
}

/// One generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<TokenId>,
    pub engine: EngineConfig,
    pub strategy: StrategyName,
}

/// Completed response.
#[derive(Debug)]
pub struct GenResponse {
    pub tokens: Vec<TokenId>,
    pub tokens_per_call: f64,
    pub calls: usize,
    pub latency_ms: f64,
}

struct Job {
    req: GenRequest,
    reply: Sender<Result<GenResponse>>,
}

/// The scheduler handle: cheap to clone, submits jobs to the pool.
pub struct Scheduler {
    tx: SyncSender<Job>,
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
}

impl Scheduler {
    /// Spin up `cfg.workers` workers for `model`. Each worker loads its own
    /// ModelRuntime (PJRT executables are not shared across threads).
    pub fn start(manifest: &Manifest, model: &str, cfg: &ServeConfig) -> Result<Scheduler> {
        let art = manifest.model(model)?.clone();
        let tables = Arc::new(NgramTables::load(&art)?);
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Job>(cfg.queue_cap);
        let rx = Arc::new(Mutex::new(rx));

        let mut workers = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let rx = rx.clone();
            let art = art.clone();
            let tables = tables.clone();
            let metrics = metrics.clone();
            let handle = std::thread::Builder::new()
                .name(format!("ngrammys-worker-{wid}"))
                .spawn(move || {
                    let runtime = match ModelRuntime::load(&art) {
                        Ok(rt) => rt,
                        Err(e) => {
                            eprintln!("worker {wid}: runtime load failed: {e:#}");
                            return;
                        }
                    };
                    worker_loop(wid, runtime, tables, metrics, rx);
                })
                .expect("spawning worker");
            workers.push(handle);
        }
        Ok(Scheduler { tx, metrics, workers })
    }

    /// Non-blocking admission; `Err` = queue full (backpressure).
    pub fn submit(&self, req: GenRequest) -> Result<Receiver<Result<GenResponse>>> {
        self.metrics.requests_total.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        match self.tx.try_send(Job { req, reply: reply_tx }) {
            Ok(()) => {
                self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
                Ok(reply_rx)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
                Err(anyhow!("queue full"))
            }
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("scheduler stopped")),
        }
    }

    /// Submit and wait (convenience for tests/examples).
    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| anyhow!("worker dropped"))?
    }

    /// Graceful shutdown: close the queue and join workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    _wid: usize,
    runtime: ModelRuntime,
    tables: Arc<NgramTables>,
    metrics: Arc<Metrics>,
    rx: Arc<Mutex<Receiver<Job>>>,
) {
    loop {
        // hold the lock only while dequeuing
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => return, // scheduler dropped
        };
        metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
        let t = Instant::now();
        let strategy = make_strategy(job.req.strategy, &tables, job.req.engine.q);
        let mut dec = SpecDecoder::new(&runtime, strategy, job.req.engine.clone());
        dec.collect_traces = true; // feeds the step-latency histogram
        let result = dec.generate(&job.req.prompt).map(|r| {
            let accepted = r.tokens.len().saturating_sub(r.calls);
            metrics.record_request(t.elapsed(), r.tokens.len(), r.calls, accepted);
            for tr in &r.traces {
                metrics.step_latency.observe(tr.exec_time);
            }
            GenResponse {
                tokens_per_call: r.tokens_per_call(),
                calls: r.calls,
                latency_ms: t.elapsed().as_secs_f64() * 1e3,
                tokens: r.tokens,
            }
        });
        let _ = job.reply.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_names_parse() {
        for (s, n) in [
            ("mixed", StrategyName::Mixed),
            ("context", StrategyName::Context),
            ("bigram", StrategyName::Bigram),
            ("unigram", StrategyName::Unigram),
            ("ext-bigram", StrategyName::ExtBigram),
            ("jacobi", StrategyName::Jacobi),
            ("greedy", StrategyName::None),
        ] {
            assert_eq!(StrategyName::parse(s).unwrap(), n);
        }
        assert!(StrategyName::parse("bogus").is_err());
    }
}
