//! Cost-aware admission ordering for the elastic batched serving path.
//!
//! The plain scheduler admits FIFO: whichever request reached the queue
//! first gets the next free KV lane, regardless of what it is expected to
//! return for the verification rows it will consume. Under load that is
//! the wrong order — the paper's economics say a verification row should
//! go wherever it buys the most accepted tokens per unit of (simulated)
//! call time, and the same logic extends one level up to whole requests:
//! a cheap short-prompt speculative request that the fleet's history says
//! accepts 2.5 tokens/call should not wait behind an expensive greedy
//! long-prompt one that by construction returns 1.0.
//!
//! [`AdmissionQueue`] holds decoded-but-unadmitted requests and releases
//! them highest [`request_score`] first (ties FIFO by arrival, so equal
//! requests keep their order). The ordering is a property of the QUEUE,
//! not of whoever drains it: every pop path — [`AdmissionQueue::pop_best`],
//! the router's [`AdmissionQueue::pop_best_entry`], and the predicated
//! [`AdmissionQueue::pop_best_where`] that work-stealing workers use to
//! skip depth-class-incompatible entries — applies the same scored,
//! bounded policy. The central dispatcher (`scheduler::pool`) drains one
//! shared queue in a loop; the work-stealing dispatcher
//! (`scheduler::steal`) gives each engine its own queue and lets idle
//! engines pop from the most-loaded peer — in both arrangements a pop
//! yields the best-scored eligible entry, so dispatch topology never
//! changes admission order among the entries a worker can actually take.
//!
//! Ordering never becomes starvation: the oldest waiting request can be
//! overtaken at most [`AdmissionQueue::MAX_OVERTAKES`] times before it is
//! admitted regardless of score, so every request's delay is bounded even
//! under sustained higher-scoring load. Every pop that overtakes an older
//! request increments a reorder counter, exported as
//! `ngrammys_admission_reorders` so operators can see the policy
//! actually doing something.
//!
//! Bounded-queue backpressure is unchanged: the submit path (the
//! scheduler's sync channel in central mode, the shared queued-entry cap
//! in stealing mode) still rejects when full; this queue only re-orders
//! what was accepted.

use std::sync::atomic::Ordering;

use crate::config::EngineConfig;
use crate::costmodel::CostModel;
use crate::metrics::Metrics;
use crate::scheduler::StrategyName;

/// Expected accepted-tokens-per-simulated-verify-second of admitting a
/// request now — the admission priority.
///
/// Until any acceptance evidence exists (`prior_tokens_per_call <= 0`)
/// every request scores 0, so a COLD scheduler is exactly FIFO — with no
/// acceptance evidence there is no basis to prefer one request over an
/// earlier one. Warm, the numerator is a prior on tokens/call: exactly
/// 1.0 for greedy requests (speculation off, so every call emits one
/// token by construction) and the caller-supplied prior (floored at 1.0,
/// the greedy baseline) for speculative ones — normally the per-strategy
/// [`strategy_prior_tpc`], which keys on the request's own
/// `StrategyKind` counters instead of blaming/crediting every strategy
/// with the fleet-wide average. The denominator is the cost model's time
/// for one of this request's verification calls at its prompt's context
/// length, so long contexts and deep/wide shapes pay their real
/// (simulated) price. `max_new_tokens` cancels out of the ratio: a
/// request that wants more tokens needs proportionally more calls at the
/// same per-call rate.
pub fn request_score(
    cm: &CostModel,
    prior_tokens_per_call: f64,
    strategy: StrategyName,
    engine: &EngineConfig,
    prompt_len: usize,
) -> f64 {
    if prior_tokens_per_call <= 0.0 {
        return 0.0; // cold start: uniform score = FIFO
    }
    let prior_tpc = if strategy == StrategyName::None || engine.w == 0 {
        1.0
    } else {
        prior_tokens_per_call.max(1.0)
    };
    prior_tpc / cm.call_time(engine.k, engine.w + 1, prompt_len)
}

/// [`request_score`] for a paged KV pool: when the first `shared_len`
/// positions of the request's prompt are covered by SHARED resident pages
/// (prefix-index hit at admission probe time), the request's verification
/// calls are priced with [`CostModel::call_time_prefix`] — its per-call
/// memory traffic is the DISTINCT pages it adds, not its worst-case lane.
/// A request riding a hot system prompt therefore outscores an equally
/// accepting disjoint-prompt request, which is exactly the admission
/// order that maximizes accepted tokens per unit of KV bandwidth. At
/// `shared_len = 0` this is bitwise-identical to [`request_score`].
pub fn request_score_paged(
    cm: &CostModel,
    prior_tokens_per_call: f64,
    strategy: StrategyName,
    engine: &EngineConfig,
    prompt_len: usize,
    shared_len: usize,
) -> f64 {
    if prior_tokens_per_call <= 0.0 {
        return 0.0; // cold start: uniform score = FIFO
    }
    let prior_tpc = if strategy == StrategyName::None || engine.w == 0 {
        1.0
    } else {
        prior_tokens_per_call.max(1.0)
    };
    prior_tpc / cm.call_time_prefix(engine.k, engine.w + 1, prompt_len, shared_len)
}

/// Evidence (winning verification calls) at which the per-strategy prior
/// trusts half of its observed mean — below it the prior shrinks toward
/// the greedy baseline so a couple of lucky steps cannot dominate
/// admission order.
pub const PRIOR_SHRINK_CALLS: f64 = 4.0;

/// Per-strategy tokens/call prior for [`request_score`], keyed by the
/// request's draft-source [`crate::draft::StrategyKind`]s against the
/// fleet's per-strategy win/accepted counters ([`Metrics`]).
///
/// The old scorer fed the FLEET-WIDE tokens/call to every strategy, so a
/// consistently-losing strategy inherited the winners' acceptance record
/// (and vice versa) for as long as the process lived. This prior instead
/// sums wins and accepted tokens over the kinds the strategy actually
/// drafts with (`StrategyName::kinds`):
///
/// - kinds with winning calls: `1 + mean_accepted_per_win * shrink`,
///   where `shrink = wins / (wins + PRIOR_SHRINK_CALLS)` pulls thin
///   evidence toward the greedy baseline of 1.0 — a strategy whose rows
///   rarely survive verification scores barely above greedy;
/// - no per-strategy evidence at all: the fleet-wide tokens/call, the
///   documented FALLBACK (a brand-new strategy should inherit the fleet
///   prior rather than being scored as a known loser);
/// - fully cold fleet: 0.0, which [`request_score`] maps to pure FIFO.
pub fn strategy_prior_tpc(metrics: &Metrics, name: StrategyName) -> f64 {
    let mut wins = 0u64;
    let mut accepted = 0u64;
    for kind in name.kinds() {
        let i = kind.index();
        wins += metrics.strategy_wins[i].load(Ordering::Relaxed);
        accepted += metrics.strategy_accepted[i].load(Ordering::Relaxed);
    }
    if wins == 0 {
        return metrics.tokens_per_call(); // no per-strategy evidence
    }
    let mean = accepted as f64 / wins as f64;
    let shrink = wins as f64 / (wins as f64 + PRIOR_SHRINK_CALLS);
    1.0 + mean * shrink
}

struct Entry<T> {
    item: T,
    /// FIFO arrival stamp (tie-break + reorder accounting)
    seq: u64,
    score: f64,
    /// times a younger entry was popped past this one while it was the
    /// oldest waiter (drives the anti-starvation bound)
    overtaken: u64,
}

/// Score-ordered holding pen between the scheduler's bounded channel and
/// the engine's lanes. Pops are deterministic: highest score wins, ties
/// go to the earliest arrival.
pub struct AdmissionQueue<T> {
    entries: Vec<Entry<T>>,
    next_seq: u64,
    reorders: u64,
}

impl<T> Default for AdmissionQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> AdmissionQueue<T> {
    /// Times the oldest waiter may be overtaken before it is admitted
    /// regardless of score — the progress guarantee the plain FIFO queue
    /// had, retained at a bounded cost to the ordering policy.
    pub const MAX_OVERTAKES: u64 = 8;

    /// An empty queue.
    pub fn new() -> Self {
        AdmissionQueue { entries: Vec::new(), next_seq: 0, reorders: 0 }
    }

    /// Enqueue `item` with its admission `score`.
    pub fn push(&mut self, item: T, score: f64) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.entries.push(Entry { item, seq, score, overtaken: 0 });
    }

    /// Waiting requests.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether nothing is waiting.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Remove and return the best-scored entry (ties FIFO). Increments
    /// the reorder count when the winner overtook an older arrival.
    ///
    /// Anti-starvation: once the oldest waiter has been overtaken
    /// [`Self::MAX_OVERTAKES`] times, it is popped unconditionally.
    /// Every pop either removes the oldest entry or bumps its overtake
    /// count, so (inductively) every entry is admitted after a bounded
    /// number of pops.
    pub fn pop_best(&mut self) -> Option<T> {
        self.pop_best_entry().map(|(item, _, _)| item)
    }

    /// [`Self::pop_best`] returning the entry's score and arrival stamp
    /// alongside the item, so a caller that cannot place the item this
    /// round (the engine pool's depth-aware router) can hand both back to
    /// [`Self::reinsert`] without forging a fresh arrival.
    pub fn pop_best_entry(&mut self) -> Option<(T, f64, u64)> {
        self.pop_best_where(|_| true)
    }

    /// [`Self::pop_best_entry`] restricted to entries `eligible` accepts —
    /// the pop the work-stealing workers use, where eligibility is the
    /// engine's current depth-class compatibility (plus the deferral-count
    /// starvation override carried in the item itself). Ineligible entries
    /// are left untouched: they are neither returned nor charged an
    /// overtake, so the anti-starvation bound applies among the entries
    /// this caller could actually have taken. With an always-true
    /// predicate this is exactly [`Self::pop_best_entry`].
    pub fn pop_best_where(
        &mut self,
        mut eligible: impl FnMut(&T) -> bool,
    ) -> Option<(T, f64, u64)> {
        let oldest = self
            .entries
            .iter()
            .enumerate()
            .filter(|(_, e)| eligible(&e.item))
            .min_by_key(|&(_, e)| e.seq)
            .map(|(i, _)| i)?;
        if self.entries[oldest].overtaken >= Self::MAX_OVERTAKES {
            let e = self.entries.swap_remove(oldest);
            return Some((e.item, e.score, e.seq));
        }
        let best = self
            .entries
            .iter()
            .enumerate()
            .filter(|(_, e)| eligible(&e.item))
            .max_by(|(_, a), (_, b)| {
                a.score
                    .partial_cmp(&b.score)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(b.seq.cmp(&a.seq)) // lower seq wins score ties
            })
            .map(|(i, _)| i)?;
        if best != oldest {
            self.reorders += 1;
            self.entries[oldest].overtaken += 1;
        }
        let e = self.entries.swap_remove(best);
        Some((e.item, e.score, e.seq))
    }

    /// Visit every waiting item mutably (visit order is unspecified). The
    /// work-stealing dispatcher uses this to age entries a worker had to
    /// skip this round (depth-class incompatibility), driving the same
    /// deferral-count starvation fallback the central dispatcher applies.
    pub fn for_each_mut(&mut self, mut f: impl FnMut(&mut T)) {
        for e in &mut self.entries {
            f(&mut e.item);
        }
    }

    /// Re-insert an entry popped this round but not placeable yet,
    /// keeping its original arrival stamp so FIFO tie-breaks and the
    /// anti-starvation bound still see its true age. (The overtake count
    /// restarts; routing-level starvation is bounded separately by the
    /// pool's deferral threshold, which lives in the item itself.)
    pub fn reinsert(&mut self, item: T, score: f64, seq: u64) {
        self.entries.push(Entry { item, seq, score, overtaken: 0 });
    }

    /// Pops that overtook an older arrival so far.
    pub fn reorders(&self) -> u64 {
        self.reorders
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_by_score_then_fifo() {
        let mut q = AdmissionQueue::new();
        q.push("a", 1.0);
        q.push("b", 3.0);
        q.push("c", 3.0);
        q.push("d", 2.0);
        assert_eq!(q.len(), 4);
        // b and c tie at 3.0: FIFO says b first
        assert_eq!(q.pop_best(), Some("b"));
        assert_eq!(q.pop_best(), Some("c"));
        assert_eq!(q.pop_best(), Some("d"));
        assert_eq!(q.pop_best(), Some("a"));
        assert_eq!(q.pop_best(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn counts_reorders_only_when_overtaking() {
        let mut q = AdmissionQueue::new();
        q.push("a", 5.0);
        q.push("b", 1.0);
        q.push("c", 9.0);
        assert_eq!(q.pop_best(), Some("c")); // overtook a and b
        assert_eq!(q.reorders(), 1);
        assert_eq!(q.pop_best(), Some("a")); // oldest: not a reorder
        assert_eq!(q.pop_best(), Some("b"));
        assert_eq!(q.reorders(), 1);
    }

    #[test]
    fn oldest_entry_cannot_starve() {
        let mut q = AdmissionQueue::new();
        q.push(-1i64, 0.1); // low score, oldest
        let mut pops = 0u64;
        loop {
            // sustained stream of strictly better-scoring arrivals
            q.push(pops as i64, 10.0);
            let got = q.pop_best().unwrap();
            pops += 1;
            if got == -1 {
                break;
            }
            assert!(
                pops <= AdmissionQueue::<i64>::MAX_OVERTAKES + 1,
                "victim still waiting after {pops} pops"
            );
        }
        assert_eq!(pops, AdmissionQueue::<i64>::MAX_OVERTAKES + 1);
    }

    #[test]
    fn predicated_pop_skips_ineligible_without_charging_them() {
        let mut q = AdmissionQueue::new();
        q.push(("greedy", 1), 5.0);
        q.push(("spec", 2), 9.0);
        q.push(("greedy", 3), 2.0);
        // a worker that can only take greedy entries: best eligible wins,
        // the ineligible higher-scoring spec entry is left in place
        let (item, _, _) = q.pop_best_where(|(class, _)| *class == "greedy").unwrap();
        assert_eq!(item, ("greedy", 1));
        assert_eq!(q.len(), 2);
        // no eligible entry at all: None, queue untouched
        assert!(q.pop_best_where(|(class, _)| *class == "adaptive").is_none());
        assert_eq!(q.len(), 2);
        // the always-true predicate is exactly pop_best_entry
        let (item, _, _) = q.pop_best_where(|_| true).unwrap();
        assert_eq!(item, ("spec", 2));
    }

    #[test]
    fn oldest_eligible_entry_cannot_starve_under_predicated_pops() {
        let mut q = AdmissionQueue::new();
        q.push(("greedy", -1i64), 0.1); // low score, oldest eligible
        let mut pops = 0u64;
        loop {
            q.push(("greedy", pops as i64), 10.0);
            q.push(("spec", pops as i64), 99.0); // never eligible here
            let (got, _, _) = q.pop_best_where(|(class, _)| *class == "greedy").unwrap();
            pops += 1;
            if got.1 == -1 {
                break;
            }
            assert!(
                pops <= AdmissionQueue::<(&str, i64)>::MAX_OVERTAKES + 1,
                "victim still waiting after {pops} predicated pops"
            );
        }
        assert_eq!(pops, AdmissionQueue::<(&str, i64)>::MAX_OVERTAKES + 1);
    }

    #[test]
    fn for_each_mut_visits_every_waiter() {
        let mut q = AdmissionQueue::new();
        q.push(0u64, 1.0);
        q.push(10, 2.0);
        q.push(20, 3.0);
        q.for_each_mut(|v| *v += 1);
        let mut got = Vec::new();
        while let Some(v) = q.pop_best() {
            got.push(v);
        }
        got.sort_unstable();
        assert_eq!(got, vec![1, 11, 21]);
    }

    #[test]
    fn uniform_scores_are_pure_fifo() {
        let mut q = AdmissionQueue::new();
        for i in 0..5 {
            q.push(i, 1.0);
        }
        for i in 0..5 {
            assert_eq!(q.pop_best(), Some(i));
        }
        assert_eq!(q.reorders(), 0);
    }

    #[test]
    fn reinsert_keeps_the_original_arrival_stamp() {
        let mut q = AdmissionQueue::new();
        q.push("old", 1.0);
        q.push("new", 1.0);
        let (item, score, seq) = q.pop_best_entry().unwrap();
        assert_eq!(item, "old"); // uniform scores: FIFO
        q.reinsert(item, score, seq);
        // the reinserted entry still ties on score and still wins FIFO
        assert_eq!(q.pop_best(), Some("old"));
        assert_eq!(q.pop_best(), Some("new"));
    }

    #[test]
    fn losing_strategy_scores_below_winning_one() {
        use crate::draft::StrategyKind;

        let cm = CostModel::for_analog("mistral");
        let m = Metrics::new();
        // context n-gram (the Context strategy's kind) wins often and its
        // rows survive deep; ext-bigram wins as often but its rows die at
        // the first draft token — a consistently LOSING source
        for _ in 0..10 {
            m.record_strategy_step(StrategyKind::ContextNgram, 4);
            m.record_strategy_step(StrategyKind::ExtendedBigram, 0);
        }
        let winner = strategy_prior_tpc(&m, StrategyName::Context);
        let loser = strategy_prior_tpc(&m, StrategyName::ExtBigram);
        assert!(
            winner > loser,
            "winning prior {winner} must beat losing prior {loser}"
        );
        assert!((loser - 1.0).abs() < 1e-9, "a never-accepting strategy is greedy-equivalent");
        // the scores inherit the ordering at identical shapes/prompts
        let eng = EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: 64 };
        let s_win = request_score(&cm, winner, StrategyName::Context, &eng, 100);
        let s_lose = request_score(&cm, loser, StrategyName::ExtBigram, &eng, 100);
        assert!(s_win > s_lose, "winner score {s_win} <= loser score {s_lose}");
        // a strategy with NO per-kind evidence falls back to the
        // fleet-wide tokens/call instead of being scored as a loser
        let fallback = strategy_prior_tpc(&m, StrategyName::Session);
        assert!((fallback - m.tokens_per_call()).abs() < 1e-9);
        // fully cold fleet: prior 0 = FIFO
        let cold = Metrics::new();
        assert_eq!(strategy_prior_tpc(&cold, StrategyName::Context), 0.0);
    }

    #[test]
    fn paged_score_rewards_shared_prefixes() {
        let cm = CostModel::for_analog("mistral");
        let spec = EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: 64 };
        let observed = 2.5;
        // no shared pages: identical to the lane scorer (bitwise)
        let plain = request_score(&cm, observed, StrategyName::Mixed, &spec, 1000);
        let zero = request_score_paged(&cm, observed, StrategyName::Mixed, &spec, 1000, 0);
        assert_eq!(plain, zero);
        // a request whose long prompt mostly rides resident shared pages
        // outscores the same request with a fully distinct prompt
        let hot = request_score_paged(&cm, observed, StrategyName::Mixed, &spec, 1000, 896);
        assert!(hot > plain, "shared-prefix score {hot} <= distinct score {plain}");
        // cold fleet stays FIFO in paged mode too
        assert_eq!(request_score_paged(&cm, 0.0, StrategyName::Mixed, &spec, 1000, 896), 0.0);
    }

    #[test]
    fn score_prefers_cheap_speculative_requests() {
        let cm = CostModel::for_analog("mistral");
        let spec = EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: 64 };
        let greedy = EngineConfig { k: 1, w: 0, q: 1, max_new_tokens: 64 };
        let observed = 2.5;
        // an accepting speculative request beats greedy at the same prompt
        let s_spec = request_score(&cm, observed, StrategyName::Mixed, &spec, 100);
        let s_greedy = request_score(&cm, observed, StrategyName::None, &greedy, 100);
        assert!(s_spec > s_greedy, "spec {s_spec} <= greedy {s_greedy}");
        // longer prompts cost more, so they score lower at equal priors
        let s_long = request_score(&cm, observed, StrategyName::Mixed, &spec, 4000);
        assert!(s_long < s_spec);
        // a cold scheduler scores everything 0 — pure FIFO until any
        // request has completed
        let cold_spec = request_score(&cm, 0.0, StrategyName::Mixed, &spec, 100);
        let cold_greedy = request_score(&cm, 0.0, StrategyName::None, &greedy, 100);
        assert_eq!(cold_spec, 0.0);
        assert_eq!(cold_greedy, 0.0);
    }
}
