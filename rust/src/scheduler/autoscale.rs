//! Demand-driven TWO-LEVEL autoscaling for the elastic batched serving
//! path: lanes within an engine, whole engines within the pool.
//!
//! The fixed `--batch N` flag forced operators to pick one lane count for
//! the whole process lifetime: too low and the queue backs up under
//! bursts, too high and idle KV lanes pin memory (a lane is a full
//! `(layers, max_len, heads, head_dim)` cache). The [`Autoscaler`] closes
//! that loop: each engine iteration it converts the observed demand — the
//! admission-queue depth, the active sequence count, and the adaptive
//! controllers' mean heat ([`crate::adaptive::SeqController::heat`]) —
//! into a target lane count, which the scheduler applies through
//! [`crate::engine::BatchedEngine::set_capacity`]. `--batch` survives as
//! the CAP on the scale range, not the pinned value.
//!
//! One level up, the [`EngineScaler`] does the same for whole
//! [`crate::engine::BatchedEngine`] worker threads in the engine pool
//! ([`crate::scheduler::pool`]; `--engines N` is the engine cap):
//! sustained lane demand beyond what the live engines can hold
//! spawns another engine (each with its own `ModelRuntime` and KV pool),
//! sustained quiet retires one. Both directions are hysteretic — an
//! engine spawn loads a whole model runtime, so it must not happen on a
//! single-iteration blip, and a retire discards warm state, so it waits
//! for a long quiet streak.
//!
//! The policy is deliberately deterministic (no clocks, no RNG): scale-up
//! is immediate (a queued request is latency the moment it waits),
//! scale-down is hysteretic — one lane at a time, only after
//! `down_after_steps` consecutive low-demand decisions — so a bursty
//! arrival pattern cannot make the pool thrash. Determinism also keeps
//! the elastic property tests (`rust/tests/elastic.rs`) and `bench
//! elastic` reproducible.
//!
//! CORRECTNESS: scaling only changes how many sequences may ride a packed
//! call; each sequence's stream is still exactly the base model's greedy
//! continuation (the engine invariant), so any scaling trajectory —
//! however bad — can only cost speed or memory, never output bytes.

/// Tuning knobs for the [`Autoscaler`]. The defaults favor latency:
/// scale to demand instantly, give lanes back slowly.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    /// Lower bound of the lane range (also the boot capacity). At least 1.
    pub min_lanes: usize,
    /// Upper bound of the lane range (the old `--batch N` becomes this).
    pub max_lanes: usize,
    /// Consecutive low-demand decisions required before the pool gives up
    /// ONE lane. Higher = stickier capacity under bursty arrivals.
    pub down_after_steps: u32,
}

impl AutoscaleConfig {
    /// Defaults for a given lane cap: start at one lane, shed a lane
    /// after 8 consecutive idle decisions.
    pub fn for_cap(max_lanes: usize) -> Self {
        AutoscaleConfig { min_lanes: 1, max_lanes: max_lanes.max(1), down_after_steps: 8 }
    }
}

/// One iteration's demand snapshot, as the scheduler sees it.
#[derive(Debug, Clone, Copy)]
pub struct Demand {
    /// requests waiting in the admission queue (not yet on a lane)
    pub queue_depth: usize,
    /// sequences currently decoding
    pub active: usize,
    /// current lane-pool capacity
    pub lanes: usize,
    /// mean controller heat across active adaptive sequences
    /// ([`crate::engine::BatchedEngine::mean_heat`]); `None` when the
    /// population carries no controllers
    pub mean_heat: Option<f64>,
}

/// The scale-decision state machine. Pure and deterministic: the target
/// is a function of the demand snapshot plus the internal low-demand
/// streak counter.
#[derive(Debug)]
pub struct Autoscaler {
    cfg: AutoscaleConfig,
    /// consecutive decisions where demand sat below current capacity
    low_streak: u32,
    /// scale events observed (up, down) — exported as gauges
    ups: u64,
    downs: u64,
}

impl Autoscaler {
    /// A fresh autoscaler for `cfg` (no demand history).
    pub fn new(cfg: AutoscaleConfig) -> Self {
        Autoscaler { cfg, low_streak: 0, ups: 0, downs: 0 }
    }

    /// Decide the lane target for the next engine iteration.
    ///
    /// Demand is `active + ceil(queue / (1 + heat))`: every active
    /// sequence needs its lane, and queued requests are discounted by the
    /// observed heat because a lane that accepts `heat` extra tokens per
    /// step retires sequences proportionally faster — cold traffic
    /// (heat ~ 0) gets one lane per queued request, a population
    /// accepting 3 tokens/step gets a quarter of that. Scale-up jumps
    /// straight to the clamped demand; scale-down waits for
    /// `down_after_steps` consecutive low-demand calls and then releases
    /// a single lane, so capacity decays gently toward `min_lanes`.
    pub fn target_lanes(&mut self, d: &Demand) -> usize {
        let heat = d.mean_heat.unwrap_or(0.0).max(0.0);
        let queue_lanes = (d.queue_depth as f64 / (1.0 + heat)).ceil() as usize;
        let demand = (d.active + queue_lanes).clamp(self.cfg.min_lanes, self.cfg.max_lanes);
        if demand >= d.lanes {
            self.low_streak = 0;
            if demand > d.lanes {
                self.ups += 1;
            }
            demand
        } else {
            self.low_streak += 1;
            if self.low_streak >= self.cfg.down_after_steps {
                self.low_streak = 0;
                self.downs += 1;
                (d.lanes - 1).max(demand)
            } else {
                d.lanes
            }
        }
    }

    /// (scale-up events, scale-down events) decided so far.
    pub fn events(&self) -> (u64, u64) {
        (self.ups, self.downs)
    }

    /// The configured lane range.
    pub fn config(&self) -> &AutoscaleConfig {
        &self.cfg
    }
}

/// Tuning knobs for the ENGINE level of the two-level autoscaler: how many
/// [`crate::engine::BatchedEngine`] worker threads the pool may run, and
/// how sticky spawn/retire decisions are.
#[derive(Debug, Clone)]
pub struct EngineScaleConfig {
    /// Lower bound of the engine range (also the boot count). At least 1.
    pub min_engines: usize,
    /// Upper bound of the engine range (`--engines N` becomes this).
    pub max_engines: usize,
    /// Consecutive over-demand decisions required before the pool spawns
    /// ONE engine (a spawn loads a whole `ModelRuntime`, so a single
    /// burst iteration must not trigger it).
    pub up_after_steps: u32,
    /// Consecutive under-demand decisions required before the pool
    /// retires ONE engine. Much stickier than the lane-level knob: a
    /// retired engine's warm state (compiled shapes, session caches) is
    /// gone for good.
    pub down_after_steps: u32,
}

impl EngineScaleConfig {
    /// Defaults for a given engine cap: boot one engine, spawn after 2
    /// sustained-pressure decisions, retire after 32 quiet ones.
    pub fn for_cap(max_engines: usize) -> Self {
        EngineScaleConfig {
            min_engines: 1,
            max_engines: max_engines.max(1),
            up_after_steps: 2,
            down_after_steps: 32,
        }
    }
}

/// The engine-count decision state machine — the top level of the
/// two-level autoscaler. Pure and deterministic like [`Autoscaler`]: the
/// target is a function of the demand snapshot plus two streak counters.
#[derive(Debug)]
pub struct EngineScaler {
    cfg: EngineScaleConfig,
    /// consecutive decisions where demand exceeded live engine capacity
    high_streak: u32,
    /// consecutive decisions where demand fit in one fewer engine
    low_streak: u32,
    ups: u64,
    downs: u64,
}

impl EngineScaler {
    /// A fresh engine scaler for `cfg` (no demand history).
    pub fn new(cfg: EngineScaleConfig) -> Self {
        EngineScaler { cfg, high_streak: 0, low_streak: 0, ups: 0, downs: 0 }
    }

    /// Decide the engine-count target for the next pool iteration.
    ///
    /// `demand_lanes` is the pool-wide lane demand (active sequences +
    /// routed backlog + heat-discounted queue depth — the same quantity
    /// the lane-level [`Autoscaler`] works from), `lane_cap` the
    /// per-engine lane cap, and `engines` the current live engine count.
    /// The needed engine count is `ceil(demand / lane_cap)` clamped into
    /// the configured range; both scale directions move ONE engine at a
    /// time and only after their streak threshold, so neither a burst
    /// nor a lull can thrash whole model runtimes.
    pub fn target_engines(&mut self, demand_lanes: usize, lane_cap: usize, engines: usize)
                          -> usize {
        let needed = demand_lanes
            .div_ceil(lane_cap.max(1))
            .clamp(self.cfg.min_engines, self.cfg.max_engines);
        if needed > engines {
            self.low_streak = 0;
            self.high_streak += 1;
            if self.high_streak >= self.cfg.up_after_steps {
                self.high_streak = 0;
                self.ups += 1;
                return engines + 1;
            }
            engines
        } else if needed < engines {
            self.high_streak = 0;
            self.low_streak += 1;
            if self.low_streak >= self.cfg.down_after_steps {
                self.low_streak = 0;
                self.downs += 1;
                return (engines - 1).max(needed);
            }
            engines
        } else {
            self.high_streak = 0;
            self.low_streak = 0;
            engines
        }
    }

    /// (spawn events, retire events) decided so far.
    pub fn events(&self) -> (u64, u64) {
        (self.ups, self.downs)
    }

    /// The configured engine range.
    pub fn config(&self) -> &EngineScaleConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scaler(min: usize, max: usize, down_after: u32) -> Autoscaler {
        Autoscaler::new(AutoscaleConfig {
            min_lanes: min,
            max_lanes: max,
            down_after_steps: down_after,
        })
    }

    fn demand(queue: usize, active: usize, lanes: usize, heat: Option<f64>) -> Demand {
        Demand { queue_depth: queue, active, lanes, mean_heat: heat }
    }

    #[test]
    fn scales_up_immediately_to_demand() {
        let mut s = scaler(1, 8, 4);
        assert_eq!(s.target_lanes(&demand(5, 1, 1, None)), 6);
        assert_eq!(s.events(), (1, 0));
    }

    #[test]
    fn cap_bounds_the_target() {
        let mut s = scaler(1, 4, 4);
        assert_eq!(s.target_lanes(&demand(100, 4, 4, None)), 4);
        // at-cap demand is not a scale event
        assert_eq!(s.events(), (0, 0));
    }

    #[test]
    fn heat_discounts_queue_pressure() {
        // 9 queued cold requests want 9 lanes; at heat 2 (three tokens
        // emitted per step) they want ceil(9/3) = 3
        let mut cold = scaler(1, 16, 4);
        assert_eq!(cold.target_lanes(&demand(9, 0, 1, Some(0.0))), 9);
        let mut hot = scaler(1, 16, 4);
        assert_eq!(hot.target_lanes(&demand(9, 0, 1, Some(2.0))), 3);
    }

    #[test]
    fn scale_down_is_hysteretic_and_single_step() {
        let mut s = scaler(1, 8, 3);
        // demand 2 against 6 lanes: two quiet decisions keep capacity,
        // the third sheds exactly one lane
        assert_eq!(s.target_lanes(&demand(0, 2, 6, None)), 6);
        assert_eq!(s.target_lanes(&demand(0, 2, 6, None)), 6);
        assert_eq!(s.target_lanes(&demand(0, 2, 6, None)), 5);
        assert_eq!(s.events(), (0, 1));
        // the streak resets after a shed: two more quiet ticks, then -1
        assert_eq!(s.target_lanes(&demand(0, 2, 5, None)), 5);
        assert_eq!(s.target_lanes(&demand(0, 2, 5, None)), 5);
        assert_eq!(s.target_lanes(&demand(0, 2, 5, None)), 4);
    }

    #[test]
    fn burst_resets_the_down_streak() {
        let mut s = scaler(1, 8, 2);
        assert_eq!(s.target_lanes(&demand(0, 1, 4, None)), 4);
        // a burst arrives before the streak completes: jump up, streak 0
        assert_eq!(s.target_lanes(&demand(6, 1, 4, None)), 7);
        assert_eq!(s.target_lanes(&demand(0, 1, 7, None)), 7);
        assert_eq!(s.target_lanes(&demand(0, 1, 7, None)), 6);
    }

    #[test]
    fn never_goes_below_min_or_demand() {
        let mut s = scaler(2, 8, 1);
        // down_after 1: every low call sheds a lane, but never below
        // max(min_lanes, demand)
        assert_eq!(s.target_lanes(&demand(0, 3, 5, None)), 4);
        assert_eq!(s.target_lanes(&demand(0, 3, 4, None)), 3);
        assert_eq!(s.target_lanes(&demand(0, 3, 3, None)), 3);
        assert_eq!(s.target_lanes(&demand(0, 0, 3, None)), 2);
        assert_eq!(s.target_lanes(&demand(0, 0, 2, None)), 2);
    }

    fn escaler(min: usize, max: usize, up: u32, down: u32) -> EngineScaler {
        EngineScaler::new(EngineScaleConfig {
            min_engines: min,
            max_engines: max,
            up_after_steps: up,
            down_after_steps: down,
        })
    }

    #[test]
    fn engine_spawn_needs_sustained_pressure() {
        let mut s = escaler(1, 4, 3, 8);
        // demand for 2 engines (lane cap 4): two pressure ticks hold, the
        // third spawns exactly one engine
        assert_eq!(s.target_engines(7, 4, 1), 1);
        assert_eq!(s.target_engines(7, 4, 1), 1);
        assert_eq!(s.target_engines(7, 4, 1), 2);
        assert_eq!(s.events(), (1, 0));
        // the streak resets after a spawn: growth to 3 takes 3 more ticks
        assert_eq!(s.target_engines(12, 4, 2), 2);
        assert_eq!(s.target_engines(12, 4, 2), 2);
        assert_eq!(s.target_engines(12, 4, 2), 3);
    }

    #[test]
    fn engine_retire_is_stickier_and_single_step() {
        let mut s = escaler(1, 4, 1, 3);
        // quiet against 3 engines: two quiet ticks hold, the third
        // retires exactly one
        assert_eq!(s.target_engines(2, 4, 3), 3);
        assert_eq!(s.target_engines(2, 4, 3), 3);
        assert_eq!(s.target_engines(2, 4, 3), 2);
        assert_eq!(s.events(), (0, 1));
    }

    #[test]
    fn engine_cap_and_floor_bound_the_target() {
        let mut s = escaler(1, 2, 1, 1);
        // huge demand: one spawn per decision, never past the cap
        assert_eq!(s.target_engines(100, 4, 1), 2);
        assert_eq!(s.target_engines(100, 4, 2), 2);
        // zero demand: retire one at a time, never below min_engines
        assert_eq!(s.target_engines(0, 4, 2), 1);
        assert_eq!(s.target_engines(0, 4, 1), 1);
    }

    #[test]
    fn engine_burst_resets_the_retire_streak() {
        let mut s = escaler(1, 4, 1, 2);
        assert_eq!(s.target_engines(1, 4, 2), 2); // quiet tick 1
        assert_eq!(s.target_engines(9, 4, 2), 3); // burst: spawn, streak 0
        assert_eq!(s.target_engines(1, 4, 3), 3); // quiet tick 1 again
        assert_eq!(s.target_engines(1, 4, 3), 2); // quiet tick 2: retire
    }

    #[test]
    fn matched_demand_holds_and_clears_streaks() {
        let mut s = escaler(1, 4, 2, 2);
        assert_eq!(s.target_engines(9, 4, 2), 2); // pressure tick 1
        assert_eq!(s.target_engines(8, 4, 2), 2); // exact fit: streak cleared
        assert_eq!(s.target_engines(9, 4, 2), 2); // pressure tick 1 again
        assert_eq!(s.target_engines(9, 4, 2), 3); // tick 2: spawn
        assert_eq!(s.events(), (1, 0));
    }
}
