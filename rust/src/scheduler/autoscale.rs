//! Demand-driven lane autoscaling for the elastic batched serving path.
//!
//! The fixed `--batch N` flag forced operators to pick one lane count for
//! the whole process lifetime: too low and the queue backs up under
//! bursts, too high and idle KV lanes pin memory (a lane is a full
//! `(layers, max_len, heads, head_dim)` cache). The [`Autoscaler`] closes
//! that loop: each engine iteration it converts the observed demand — the
//! admission-queue depth, the active sequence count, and the adaptive
//! controllers' mean heat ([`crate::adaptive::SeqController::heat`]) —
//! into a target lane count, which the scheduler applies through
//! [`crate::engine::BatchedEngine::set_capacity`]. `--batch` survives as
//! the CAP on the scale range, not the pinned value.
//!
//! The policy is deliberately deterministic (no clocks, no RNG): scale-up
//! is immediate (a queued request is latency the moment it waits),
//! scale-down is hysteretic — one lane at a time, only after
//! `down_after_steps` consecutive low-demand decisions — so a bursty
//! arrival pattern cannot make the pool thrash. Determinism also keeps
//! the elastic property tests (`rust/tests/elastic.rs`) and `bench
//! elastic` reproducible.
//!
//! CORRECTNESS: scaling only changes how many sequences may ride a packed
//! call; each sequence's stream is still exactly the base model's greedy
//! continuation (the engine invariant), so any scaling trajectory —
//! however bad — can only cost speed or memory, never output bytes.

/// Tuning knobs for the [`Autoscaler`]. The defaults favor latency:
/// scale to demand instantly, give lanes back slowly.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    /// Lower bound of the lane range (also the boot capacity). At least 1.
    pub min_lanes: usize,
    /// Upper bound of the lane range (the old `--batch N` becomes this).
    pub max_lanes: usize,
    /// Consecutive low-demand decisions required before the pool gives up
    /// ONE lane. Higher = stickier capacity under bursty arrivals.
    pub down_after_steps: u32,
}

impl AutoscaleConfig {
    /// Defaults for a given lane cap: start at one lane, shed a lane
    /// after 8 consecutive idle decisions.
    pub fn for_cap(max_lanes: usize) -> Self {
        AutoscaleConfig { min_lanes: 1, max_lanes: max_lanes.max(1), down_after_steps: 8 }
    }
}

/// One iteration's demand snapshot, as the scheduler sees it.
#[derive(Debug, Clone, Copy)]
pub struct Demand {
    /// requests waiting in the admission queue (not yet on a lane)
    pub queue_depth: usize,
    /// sequences currently decoding
    pub active: usize,
    /// current lane-pool capacity
    pub lanes: usize,
    /// mean controller heat across active adaptive sequences
    /// ([`crate::engine::BatchedEngine::mean_heat`]); `None` when the
    /// population carries no controllers
    pub mean_heat: Option<f64>,
}

/// The scale-decision state machine. Pure and deterministic: the target
/// is a function of the demand snapshot plus the internal low-demand
/// streak counter.
#[derive(Debug)]
pub struct Autoscaler {
    cfg: AutoscaleConfig,
    /// consecutive decisions where demand sat below current capacity
    low_streak: u32,
    /// scale events observed (up, down) — exported as gauges
    ups: u64,
    downs: u64,
}

impl Autoscaler {
    /// A fresh autoscaler for `cfg` (no demand history).
    pub fn new(cfg: AutoscaleConfig) -> Self {
        Autoscaler { cfg, low_streak: 0, ups: 0, downs: 0 }
    }

    /// Decide the lane target for the next engine iteration.
    ///
    /// Demand is `active + ceil(queue / (1 + heat))`: every active
    /// sequence needs its lane, and queued requests are discounted by the
    /// observed heat because a lane that accepts `heat` extra tokens per
    /// step retires sequences proportionally faster — cold traffic
    /// (heat ~ 0) gets one lane per queued request, a population
    /// accepting 3 tokens/step gets a quarter of that. Scale-up jumps
    /// straight to the clamped demand; scale-down waits for
    /// `down_after_steps` consecutive low-demand calls and then releases
    /// a single lane, so capacity decays gently toward `min_lanes`.
    pub fn target_lanes(&mut self, d: &Demand) -> usize {
        let heat = d.mean_heat.unwrap_or(0.0).max(0.0);
        let queue_lanes = (d.queue_depth as f64 / (1.0 + heat)).ceil() as usize;
        let demand = (d.active + queue_lanes).clamp(self.cfg.min_lanes, self.cfg.max_lanes);
        if demand >= d.lanes {
            self.low_streak = 0;
            if demand > d.lanes {
                self.ups += 1;
            }
            demand
        } else {
            self.low_streak += 1;
            if self.low_streak >= self.cfg.down_after_steps {
                self.low_streak = 0;
                self.downs += 1;
                (d.lanes - 1).max(demand)
            } else {
                d.lanes
            }
        }
    }

    /// (scale-up events, scale-down events) decided so far.
    pub fn events(&self) -> (u64, u64) {
        (self.ups, self.downs)
    }

    /// The configured lane range.
    pub fn config(&self) -> &AutoscaleConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scaler(min: usize, max: usize, down_after: u32) -> Autoscaler {
        Autoscaler::new(AutoscaleConfig {
            min_lanes: min,
            max_lanes: max,
            down_after_steps: down_after,
        })
    }

    fn demand(queue: usize, active: usize, lanes: usize, heat: Option<f64>) -> Demand {
        Demand { queue_depth: queue, active, lanes, mean_heat: heat }
    }

    #[test]
    fn scales_up_immediately_to_demand() {
        let mut s = scaler(1, 8, 4);
        assert_eq!(s.target_lanes(&demand(5, 1, 1, None)), 6);
        assert_eq!(s.events(), (1, 0));
    }

    #[test]
    fn cap_bounds_the_target() {
        let mut s = scaler(1, 4, 4);
        assert_eq!(s.target_lanes(&demand(100, 4, 4, None)), 4);
        // at-cap demand is not a scale event
        assert_eq!(s.events(), (0, 0));
    }

    #[test]
    fn heat_discounts_queue_pressure() {
        // 9 queued cold requests want 9 lanes; at heat 2 (three tokens
        // emitted per step) they want ceil(9/3) = 3
        let mut cold = scaler(1, 16, 4);
        assert_eq!(cold.target_lanes(&demand(9, 0, 1, Some(0.0))), 9);
        let mut hot = scaler(1, 16, 4);
        assert_eq!(hot.target_lanes(&demand(9, 0, 1, Some(2.0))), 3);
    }

    #[test]
    fn scale_down_is_hysteretic_and_single_step() {
        let mut s = scaler(1, 8, 3);
        // demand 2 against 6 lanes: two quiet decisions keep capacity,
        // the third sheds exactly one lane
        assert_eq!(s.target_lanes(&demand(0, 2, 6, None)), 6);
        assert_eq!(s.target_lanes(&demand(0, 2, 6, None)), 6);
        assert_eq!(s.target_lanes(&demand(0, 2, 6, None)), 5);
        assert_eq!(s.events(), (0, 1));
        // the streak resets after a shed: two more quiet ticks, then -1
        assert_eq!(s.target_lanes(&demand(0, 2, 5, None)), 5);
        assert_eq!(s.target_lanes(&demand(0, 2, 5, None)), 5);
        assert_eq!(s.target_lanes(&demand(0, 2, 5, None)), 4);
    }

    #[test]
    fn burst_resets_the_down_streak() {
        let mut s = scaler(1, 8, 2);
        assert_eq!(s.target_lanes(&demand(0, 1, 4, None)), 4);
        // a burst arrives before the streak completes: jump up, streak 0
        assert_eq!(s.target_lanes(&demand(6, 1, 4, None)), 7);
        assert_eq!(s.target_lanes(&demand(0, 1, 7, None)), 7);
        assert_eq!(s.target_lanes(&demand(0, 1, 7, None)), 6);
    }

    #[test]
    fn never_goes_below_min_or_demand() {
        let mut s = scaler(2, 8, 1);
        // down_after 1: every low call sheds a lane, but never below
        // max(min_lanes, demand)
        assert_eq!(s.target_lanes(&demand(0, 3, 5, None)), 4);
        assert_eq!(s.target_lanes(&demand(0, 3, 4, None)), 3);
        assert_eq!(s.target_lanes(&demand(0, 3, 3, None)), 3);
        assert_eq!(s.target_lanes(&demand(0, 0, 3, None)), 2);
        assert_eq!(s.target_lanes(&demand(0, 0, 2, None)), 2);
    }
}
