//! Multi-engine scale-out: several [`BatchedEngine`] worker threads
//! behind ONE admission queue, with two-level autoscaling and depth-aware
//! routing.
//!
//! The elastic scheduler used to funnel every request through a single
//! engine thread, so one `ModelRuntime` was the throughput ceiling no
//! matter how high the lane cap went, and a single greedy (w = 0)
//! request could collapse the packed depth of its whole group. The
//! `EnginePool` removes both limits:
//!
//! - **Scale-out** — a dispatcher thread owns the scored
//!   [`AdmissionQueue`] and routes requests to up to
//!   `ServeConfig::engines` engine worker threads, each with its own
//!   `ModelRuntime` and resizable KV lane pool (`--batch` is the
//!   PER-ENGINE lane cap). The autoscaler is two-level: each worker
//!   scales its own lanes ([`Autoscaler`], level 1) while the dispatcher
//!   spawns/retires whole engines on sustained pressure/quiet
//!   ([`EngineScaler`], level 2). A spawn loads a full runtime; a retire
//!   only ever takes an idle engine, so in-flight requests never move.
//! - **Depth-aware routing** — requests are bucketed by
//!   [`DepthClass`] (greedy w = 0 vs speculative) and placed on the
//!   least-loaded engine whose resident population is depth-compatible,
//!   so greedy traffic cannot sit in speculative packed groups at all
//!   while capacity allows. A request that only incompatible engines
//!   could take is deferred at most [`STARVATION_DEFERRALS`] routing
//!   rounds, then placed anywhere with room (counted in
//!   `ngrammys_routing_fallbacks`); the engine-level per-class depth
//!   split (`engine/batched.rs`) keeps even that fallback from zeroing
//!   co-resident speculation depth.
//!
//! CORRECTNESS: routing, spawn/retire and both autoscale levels only
//! decide WHERE and alongside WHOM a sequence decodes — each stream is
//! still exactly the base model's greedy continuation of its prompt
//! (byte-identity across engine caps 1/2/4 and adversarial spawn/retire
//! trajectories is pinned in `rust/tests/pool.rs`).
//!
//! This module is the `--dispatch central` mode: one dispatcher thread
//! owns the scored queue and routes. The default `--dispatch steal` mode
//! ([`super::steal`]) replaces the dispatcher with per-engine work queues
//! plus idle-engine stealing and shares this module's engine worker
//! building blocks; engine-COUNT autoscaling (level 2) runs only here,
//! because only the central dispatcher owns spawn/retire.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError, TrySendError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::anyhow;

use crate::config::{ModelArtifacts, ServeConfig};
use crate::costmodel::CostModel;
use crate::draft::{fingerprint, NgramTables, SharedDraftStore};
use crate::engine::{AutoBudget, BatchedEngine, SeqId};
use crate::metrics::{EngineGauges, Metrics};
use crate::runtime::ModelRuntime;
use crate::trace::TraceHub;

use super::admission::{request_score, strategy_prior_tpc, AdmissionQueue};
use super::autoscale::{Autoscaler, Demand, EngineScaler};
use super::{
    controller_for_request, finish_response, make_strategy_with_cache, mirror_shared_metrics,
    record_fingerprint_fp, wrap_shared, DepthClass, Job, ReplySink,
};

/// Dispatcher pacing: how long one routing iteration waits on the arrival
/// channel while engines are busy. Bounds routing latency without
/// spinning; correctness never depends on it.
const DISPATCH_TICK: Duration = Duration::from_millis(1);

/// Routing rounds a request may be deferred because only
/// depth-INCOMPATIBLE engines had room, before it is placed on any engine
/// with a free slot. Keeps depth segregation a performance policy, never
/// a progress hazard.
pub const STARVATION_DEFERRALS: u32 = 4;

/// Consecutive engine-spawn failures (runtime load errors) after which
/// the pool stops respawning and fails queued work fast instead.
const MAX_SPAWN_FAILURES: u32 = 3;

/// A routed request: the scheduler job plus its depth bucket and how
/// often depth-aware placement has already passed it over. Shared with
/// [`super::steal`], whose per-engine queues hold the same item type so
/// scored ordering and the deferral fallback stay one mechanism.
pub(crate) struct PoolJob {
    pub(crate) job: Job,
    pub(crate) class: DepthClass,
    pub(crate) deferrals: u32,
}

/// Gauges one engine worker exports to whoever places work on it —
/// the central dispatcher or the work-stealing peers (lock-free; they
/// are snapshotted into [`Metrics`] every publish iteration).
pub(crate) struct EngineStatus {
    /// jobs routed to this worker but not yet admitted to a lane
    pub(crate) backlog: AtomicUsize,
    /// sequences currently decoding
    pub(crate) active: AtomicUsize,
    /// resident + routed greedy requests (depth bucket population)
    pub(crate) greedy: AtomicUsize,
    /// resident + routed speculative requests
    pub(crate) spec: AtomicUsize,
    /// current lane-pool capacity
    pub(crate) lanes: AtomicUsize,
    /// the lane target the worker's autoscaler last decided
    pub(crate) lanes_target: AtomicUsize,
    /// mean controller heat across the worker's lanes, milli-units
    pub(crate) heat_milli: AtomicU64,
    /// bytes this engine's KV lane pool currently pins
    pub(crate) kv_bytes: AtomicU64,
    /// distinct KV pages live in the engine's pool (lanes in lane mode)
    pub(crate) kv_pages: AtomicU64,
    /// unreserved KV pages still free in the engine's pool
    pub(crate) kv_pages_free: AtomicU64,
    /// KV pages shared by more than one resident sequence (paged mode)
    pub(crate) kv_pages_shared: AtomicU64,
    /// admissions that attached shared prefix pages (paged mode)
    pub(crate) kv_prefix_hits: AtomicU64,
    /// draft rows this engine filled from the fleet store
    /// (`--shared-draft fleet`); `Arc` so the strategy wrapper living
    /// inside the engine can bump it without holding the whole status
    pub(crate) shared_hits: Arc<AtomicU64>,
    /// worker is retiring (or failed to boot): route nothing more to it
    pub(crate) draining: AtomicBool,
    /// the worker never served: its `ModelRuntime` failed to load
    pub(crate) load_failed: AtomicBool,
}

impl EngineStatus {
    pub(crate) fn new() -> Self {
        EngineStatus {
            backlog: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            greedy: AtomicUsize::new(0),
            spec: AtomicUsize::new(0),
            lanes: AtomicUsize::new(0),
            lanes_target: AtomicUsize::new(0),
            heat_milli: AtomicU64::new(0),
            kv_bytes: AtomicU64::new(0),
            kv_pages: AtomicU64::new(0),
            kv_pages_free: AtomicU64::new(0),
            kv_pages_shared: AtomicU64::new(0),
            kv_prefix_hits: AtomicU64::new(0),
            shared_hits: Arc::new(AtomicU64::new(0)),
            draining: AtomicBool::new(false),
            load_failed: AtomicBool::new(false),
        }
    }

    /// Requests this engine currently owns (decoding + routed backlog).
    pub(crate) fn held(&self) -> usize {
        self.active.load(Ordering::Relaxed) + self.backlog.load(Ordering::Relaxed)
    }

    pub(crate) fn idle(&self) -> bool {
        self.held() == 0
    }

    /// Whether a `class` request can join this engine without mixing
    /// depth buckets (an empty engine is compatible with everything).
    pub(crate) fn compatible(&self, class: DepthClass) -> bool {
        match class {
            DepthClass::Greedy => self.spec.load(Ordering::Relaxed) == 0,
            DepthClass::Speculative => self.greedy.load(Ordering::Relaxed) == 0,
        }
    }

    pub(crate) fn class_counter(&self, class: DepthClass) -> &AtomicUsize {
        match class {
            DepthClass::Greedy => &self.greedy,
            DepthClass::Speculative => &self.spec,
        }
    }

    pub(crate) fn heat(&self) -> f64 {
        self.heat_milli.load(Ordering::Relaxed) as f64 / 1e3
    }
}

/// One engine worker as the dispatcher sees it.
struct EngineSlot {
    /// stable spawn ordinal — the `engine="<id>"` label on `/metrics`
    id: u64,
    /// `None` once the engine is retiring (closing the channel is the
    /// retire signal; the worker exits when its backlog drains)
    tx: Option<SyncSender<PoolJob>>,
    status: Arc<EngineStatus>,
    handle: JoinHandle<()>,
}

impl EngineSlot {
    fn live(&self) -> bool {
        self.tx.is_some() && !self.status.draining.load(Ordering::Relaxed)
    }

    /// Whether the dispatcher may route one more request here.
    fn can_take(&self, lane_cap: usize) -> bool {
        self.live() && self.status.held() < lane_cap
    }
}

/// The pool dispatcher: runs on the scheduler's `ngrammys-engine-pool`
/// thread until the scheduler shuts down and every routed request has
/// been answered.
pub(super) fn run_pool(
    art: ModelArtifacts,
    tables: Arc<NgramTables>,
    metrics: Arc<Metrics>,
    trace: Arc<TraceHub>,
    rx: Arc<Mutex<Receiver<Job>>>,
    scfg: ServeConfig,
    shared: Option<Arc<SharedDraftStore>>,
) {
    let cm = CostModel::for_analog(&art.dims.analog);
    let lane_cap = scfg.batch.max(2);
    let mut es_cfg = scfg.engine_scale.clone();
    es_cfg.max_engines = scfg.engines.max(1);
    es_cfg.min_engines = es_cfg.min_engines.clamp(1, es_cfg.max_engines);
    let boot = if scfg.elastic { es_cfg.min_engines } else { es_cfg.max_engines };
    let mut scaler = EngineScaler::new(es_cfg.clone());

    let mut next_id = 0u64;
    let mut engines: Vec<EngineSlot> = Vec::new();
    for _ in 0..boot {
        engines.push(spawn_engine(
            &mut next_id, &art, &tables, &metrics, &trace, &scfg, lane_cap, shared.clone(),
        ));
    }

    let mut adq: AdmissionQueue<PoolJob> = AdmissionQueue::new();
    let mut spawn_failures = 0u32;
    let mut open = true;
    loop {
        spawn_failures += reap(&mut engines);
        let busy = engines.iter().any(|e| !e.status.idle());
        if !open && adq.is_empty() && !busy {
            break; // scheduler gone, every request answered
        }

        // ---- arrivals
        if open && adq.is_empty() && !busy {
            // Fully idle and about to block: retire surplus engines NOW
            // (all are idle, so each retire completes as soon as the
            // worker notices) — the engine-level mirror of the lane
            // pool's idle shrink. The hysteretic path below never ticks
            // while the dispatcher is parked in recv().
            if scfg.elastic {
                while live_count(&engines) > es_cfg.min_engines && retire_one(&mut engines) {}
            }
            publish(&metrics, &engines);
            if let Some(store) = shared.as_deref() {
                mirror_shared_metrics(&metrics, store);
            }
            match rx.lock().unwrap().recv() {
                Ok(job) => enqueue(&mut adq, job, &cm, &metrics, scfg.elastic),
                Err(_) => open = false,
            }
        } else if open {
            // pace the loop on the arrival channel: picks up new work
            // and yields the CPU while the engine workers step
            match rx.lock().unwrap().recv_timeout(DISPATCH_TICK) {
                Ok(job) => enqueue(&mut adq, job, &cm, &metrics, scfg.elastic),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => open = false,
            }
        } else {
            std::thread::sleep(DISPATCH_TICK);
        }
        while open {
            let polled = rx.lock().unwrap().try_recv();
            match polled {
                Ok(job) => enqueue(&mut adq, job, &cm, &metrics, scfg.elastic),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }

        // ---- engine-level scaling (level 2 of the autoscaler)
        let live = live_count(&engines);
        if scfg.elastic {
            let target = scaler.target_engines(lane_demand(&engines, &adq), lane_cap, live);
            metrics.engines_target.store(target as u64, Ordering::Relaxed);
            if target > live && spawn_failures <= MAX_SPAWN_FAILURES {
                engines.push(spawn_engine(
                    &mut next_id,
                    &art,
                    &tables,
                    &metrics,
                    &trace,
                    &scfg,
                    lane_cap,
                    shared.clone(),
                ));
            } else if target < live {
                // only an IDLE engine retires; if none is idle the
                // scaler simply re-decides on a later iteration
                retire_one(&mut engines);
            }
        } else {
            metrics.engines_target.store(es_cfg.max_engines as u64, Ordering::Relaxed);
            // fixed pool: replace crashed engines (bounded by the spawn
            // failure cap so a broken artifact set cannot spawn forever)
            while live_count(&engines) < es_cfg.max_engines && spawn_failures <= MAX_SPAWN_FAILURES
            {
                engines.push(spawn_engine(
                    &mut next_id,
                    &art,
                    &tables,
                    &metrics,
                    &trace,
                    &scfg,
                    lane_cap,
                    shared.clone(),
                ));
            }
        }

        // every engine dead and no way to spawn more: fail queued work
        // fast rather than holding clients forever
        if live_count(&engines) == 0 && spawn_failures > MAX_SPAWN_FAILURES {
            while let Some((pj, _, _)) = adq.pop_best_entry() {
                metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                metrics.admissions_failed.fetch_add(1, Ordering::Relaxed);
                pj.job
                    .reply
                    .send(Err(anyhow!("engine pool: no engine available (runtime load failed)")));
            }
        }

        // ---- depth-aware routing
        route(&mut adq, &engines, &metrics, lane_cap);
        metrics.admission_reorders.store(adq.reorders(), Ordering::Relaxed);

        // ---- gauges
        publish(&metrics, &engines);
        if let Some(store) = shared.as_deref() {
            mirror_shared_metrics(&metrics, store);
        }
    }
    // shutdown: close every channel, then join the workers
    for e in &mut engines {
        e.tx = None;
    }
    publish(&metrics, &engines);
    for e in engines {
        let _ = e.handle.join();
    }
    // the workers' exit drops flushed their buffered tails: mirror the
    // final store counters so post-shutdown scrapes see every publish
    if let Some(store) = shared.as_deref() {
        mirror_shared_metrics(&metrics, store);
    }
}

/// Score an arriving job and move it into the admission holding pen.
/// With elastic off every job scores 0, so the queue's FIFO tie-break
/// reproduces plain arrival order.
fn enqueue(
    adq: &mut AdmissionQueue<PoolJob>,
    job: Job,
    cm: &CostModel,
    metrics: &Metrics,
    elastic: bool,
) {
    let class = DepthClass::of(job.req.strategy, &job.req.engine);
    let score = if elastic {
        request_score(
            cm,
            strategy_prior_tpc(metrics, job.req.strategy),
            job.req.strategy,
            &job.req.engine,
            job.req.prompt.len(),
        )
    } else {
        0.0
    };
    adq.push(PoolJob { job, class, deferrals: 0 }, score);
}

/// Pool-wide lane demand for the engine scaler: requests already held by
/// engines plus the queue, discounted by the fleet's mean heat exactly
/// like the lane-level scaler discounts its queue.
fn lane_demand(engines: &[EngineSlot], adq: &AdmissionQueue<PoolJob>) -> usize {
    let held: usize = engines.iter().filter(|e| e.live()).map(|e| e.status.held()).sum();
    let mut heat_sum = 0.0;
    let mut n = 0usize;
    for e in engines {
        let h = e.status.heat();
        if h > 0.0 {
            heat_sum += h;
            n += 1;
        }
    }
    let heat = if n > 0 { heat_sum / n as f64 } else { 0.0 };
    held + (adq.len() as f64 / (1.0 + heat)).ceil() as usize
}

fn live_count(engines: &[EngineSlot]) -> usize {
    engines.iter().filter(|e| e.live()).count()
}

/// Mark ONE idle live engine as retiring (newest first, mirroring the
/// lane pool's tail-shrink) and close its channel. Returns whether an
/// engine was retired; busy engines never are.
fn retire_one(engines: &mut [EngineSlot]) -> bool {
    let Some(slot) = engines
        .iter_mut()
        .filter(|e| e.live() && e.status.idle())
        .max_by_key(|e| e.id)
    else {
        return false;
    };
    slot.status.draining.store(true, Ordering::Relaxed);
    slot.tx = None; // the worker exits once its (empty) channel reports Disconnected
    true
}

/// Remove engine slots whose worker thread has exited, joining them.
/// Returns how many of the removed workers died on a runtime load
/// failure (the dispatcher's spawn-failure budget).
fn reap(engines: &mut Vec<EngineSlot>) -> u32 {
    let mut failures = 0u32;
    let mut i = 0;
    while i < engines.len() {
        // a worker that exits on its own (load failure) marks itself
        // draining; close its channel so anything still routed fails fast
        if engines[i].status.draining.load(Ordering::Relaxed) {
            engines[i].tx = None;
        }
        if engines[i].tx.is_none() && engines[i].handle.is_finished() {
            let e = engines.remove(i);
            if e.status.load_failed.load(Ordering::Relaxed) {
                failures += 1;
            }
            let _ = e.handle.join();
        } else {
            i += 1;
        }
    }
    failures
}

/// Least-loaded engine able to take a request now; `class` restricts the
/// choice to depth-compatible engines (`None` = any, the starvation
/// fallback).
fn best_slot(engines: &[EngineSlot], lane_cap: usize, class: Option<DepthClass>) -> Option<usize> {
    engines
        .iter()
        .enumerate()
        .filter(|(_, e)| e.can_take(lane_cap))
        .filter(|(_, e)| match class {
            Some(c) => e.status.compatible(c),
            None => true,
        })
        .min_by_key(|(_, e)| e.status.held())
        .map(|(i, _)| i)
}

/// One routing pass: place best-scored requests on depth-compatible
/// engines while any engine has room. Requests only an incompatible
/// engine could take are deferred (re-inserted with their original
/// arrival stamp) until [`STARVATION_DEFERRALS`] passes, then placed
/// anywhere free — counted in `ngrammys_routing_fallbacks`.
fn route(
    adq: &mut AdmissionQueue<PoolJob>,
    engines: &[EngineSlot],
    metrics: &Metrics,
    lane_cap: usize,
) {
    let mut held: Vec<(PoolJob, f64, u64)> = Vec::new();
    while engines.iter().any(|e| e.can_take(lane_cap)) {
        let Some((mut pj, score, seq)) = adq.pop_best_entry() else { break };
        let pick = match best_slot(engines, lane_cap, Some(pj.class)) {
            Some(i) => Some((i, false)),
            None if pj.deferrals >= STARVATION_DEFERRALS => {
                best_slot(engines, lane_cap, None).map(|i| (i, true))
            }
            None => None,
        };
        match pick {
            Some((i, fallback)) => {
                if fallback {
                    metrics.routing_fallbacks.fetch_add(1, Ordering::Relaxed);
                }
                let slot = &engines[i];
                slot.status.backlog.fetch_add(1, Ordering::Relaxed);
                slot.status.class_counter(pj.class).fetch_add(1, Ordering::Relaxed);
                let tx = slot.tx.as_ref().expect("live slot has a sender");
                if let Err(e) = tx.try_send(pj) {
                    // bounded channel full or worker just exited: undo
                    // the accounting and hold the job for the next pass
                    let pj = match e {
                        TrySendError::Full(pj) | TrySendError::Disconnected(pj) => pj,
                    };
                    slot.status.backlog.fetch_sub(1, Ordering::Relaxed);
                    slot.status.class_counter(pj.class).fetch_sub(1, Ordering::Relaxed);
                    held.push((pj, score, seq));
                }
            }
            None => {
                pj.deferrals += 1;
                held.push((pj, score, seq));
            }
        }
    }
    for (pj, score, seq) in held {
        adq.reinsert(pj, score, seq);
    }
}

/// Snapshot every engine's gauges into [`Metrics`]. The legacy
/// single-engine `lanes`/`lanes_target` gauges become pool aggregates so
/// existing dashboards keep a meaningful total.
fn publish(metrics: &Metrics, engines: &[EngineSlot]) {
    publish_statuses(
        metrics,
        live_count(engines),
        engines.iter().map(|e| (e.id, e.status.as_ref())),
    );
}

/// The gauge snapshot behind [`publish`], shared with the work-stealing
/// dispatcher (which has statuses but no [`EngineSlot`]s): aggregates
/// per-engine gauges into the pool-level families and exports the
/// per-engine rows for `/metrics`.
pub(crate) fn publish_statuses<'a>(
    metrics: &Metrics,
    live: usize,
    statuses: impl Iterator<Item = (u64, &'a EngineStatus)>,
) {
    metrics.engines.store(live as u64, Ordering::Relaxed);
    let mut lanes = 0u64;
    let mut lanes_target = 0u64;
    let mut kv_pages = 0u64;
    let mut kv_pages_free = 0u64;
    let mut kv_pages_shared = 0u64;
    let mut kv_prefix_hits = 0u64;
    let snaps: Vec<EngineGauges> = statuses
        .map(|(id, st)| {
            let g = EngineGauges {
                id,
                lanes: st.lanes.load(Ordering::Relaxed) as u64,
                lanes_target: st.lanes_target.load(Ordering::Relaxed) as u64,
                active: st.active.load(Ordering::Relaxed) as u64,
                greedy: st.greedy.load(Ordering::Relaxed) as u64,
                speculative: st.spec.load(Ordering::Relaxed) as u64,
                heat: st.heat(),
                kv_bytes: st.kv_bytes.load(Ordering::Relaxed),
                kv_pages: st.kv_pages.load(Ordering::Relaxed),
                kv_pages_free: st.kv_pages_free.load(Ordering::Relaxed),
                kv_pages_shared: st.kv_pages_shared.load(Ordering::Relaxed),
                kv_prefix_hits: st.kv_prefix_hits.load(Ordering::Relaxed),
                shared_draft_hits: st.shared_hits.load(Ordering::Relaxed),
            };
            lanes += g.lanes;
            lanes_target += g.lanes_target;
            kv_pages += g.kv_pages;
            kv_pages_free += g.kv_pages_free;
            kv_pages_shared += g.kv_pages_shared;
            kv_prefix_hits += g.kv_prefix_hits;
            g
        })
        .collect();
    metrics.lanes.store(lanes, Ordering::Relaxed);
    metrics.lanes_target.store(lanes_target, Ordering::Relaxed);
    metrics.kv_pages.store(kv_pages, Ordering::Relaxed);
    metrics.kv_pages_free.store(kv_pages_free, Ordering::Relaxed);
    metrics.kv_pages_shared.store(kv_pages_shared, Ordering::Relaxed);
    metrics.kv_prefix_hits.store(kv_prefix_hits, Ordering::Relaxed);
    metrics.set_per_engine(snaps);
}

/// Spawn one engine worker thread (its `ModelRuntime` loads on the new
/// thread, so the dispatcher never blocks on artifact IO).
#[allow(clippy::too_many_arguments)]
fn spawn_engine(
    next_id: &mut u64,
    art: &ModelArtifacts,
    tables: &Arc<NgramTables>,
    metrics: &Arc<Metrics>,
    trace: &Arc<TraceHub>,
    scfg: &ServeConfig,
    lane_cap: usize,
    shared: Option<Arc<SharedDraftStore>>,
) -> EngineSlot {
    let id = *next_id;
    *next_id += 1;
    let status = Arc::new(EngineStatus::new());
    let (tx, rx) = sync_channel::<PoolJob>(lane_cap);
    let art = art.clone();
    let tables = tables.clone();
    let metrics = metrics.clone();
    let trace = trace.clone();
    let scfg = scfg.clone();
    let st = status.clone();
    let handle = std::thread::Builder::new()
        .name(format!("ngrammys-engine-{id}"))
        .spawn(move || {
            let runtime = match ModelRuntime::load(&art) {
                Ok(rt) => rt,
                Err(e) => {
                    eprintln!("engine {id}: runtime load failed: {e:#}");
                    st.load_failed.store(true, Ordering::Relaxed);
                    st.draining.store(true, Ordering::Relaxed);
                    // fail whatever was routed here until the dispatcher
                    // notices the drain flag and closes the channel
                    while let Ok(pj) = rx.recv() {
                        metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                        st.backlog.fetch_sub(1, Ordering::Relaxed);
                        st.class_counter(pj.class).fetch_sub(1, Ordering::Relaxed);
                        metrics.admissions_failed.fetch_add(1, Ordering::Relaxed);
                        pj.job
                            .reply
                            .send(Err(anyhow!("engine {id}: runtime load failed: {e:#}")));
                    }
                    return;
                }
            };
            engine_worker_loop(
                id, &runtime, &tables, &metrics, &trace, rx, &scfg, &st, lane_cap, shared,
            );
        })
        .expect("spawning engine worker");
    EngineSlot { id, tx: Some(tx), status, handle }
}

/// A fresh batched engine for one worker: traces on (they feed the
/// step-latency histogram) and, in elastic mode, the online-derived row
/// budget installed with the operator `--budget` demoted to a cap.
/// `--kv-page-size > 0` swaps the contiguous lane pool for the paged
/// pool with prefix sharing (same output bytes, more admissions per KV
/// byte on shared-prefix traffic).
pub(crate) fn fresh_engine<'rt>(
    runtime: &'rt ModelRuntime,
    lanes: usize,
    scfg: &ServeConfig,
    analog: &str,
) -> BatchedEngine<'rt> {
    let mut eng = if scfg.kv_page_size > 0 {
        let mut e = BatchedEngine::new_paged(runtime, lanes, scfg.kv_page_size, scfg.kv_pages);
        e.budget = scfg.budget;
        e
    } else {
        BatchedEngine::with_budget(runtime, lanes, scfg.budget)
    };
    eng.collect_traces = true;
    eng.tree = scfg.tree;
    if scfg.elastic {
        eng.auto_budget =
            Some(AutoBudget { cm: CostModel::for_analog(analog), slack: scfg.budget_slack });
    }
    eng
}

/// Snapshot the engine's KV page accounting into its status gauges
/// (lane mode reports lanes as pages with no sharing, so the families
/// stay meaningful either way).
pub(crate) fn store_page_stats(status: &EngineStatus, eng: &BatchedEngine) {
    let ps = eng.page_stats();
    status.kv_pages.store(ps.live, Ordering::Relaxed);
    status.kv_pages_free.store(ps.free, Ordering::Relaxed);
    status.kv_pages_shared.store(ps.shared, Ordering::Relaxed);
    status.kv_prefix_hits.store(ps.prefix_hits, Ordering::Relaxed);
}

/// An admitted request's reply route plus the bookkeeping needed to give
/// its lane's class slot back on retirement.
pub(crate) struct Inflight {
    pub(crate) reply: ReplySink,
    /// aborts the sequence early when the client disconnects mid-stream
    pub(crate) cancel: super::CancelToken,
    /// when the request entered the scheduler (total-latency clock)
    pub(crate) t_submit: Instant,
    /// dwell between submit and lane admission (TTFT's queue component)
    pub(crate) queue_wait: Duration,
    pub(crate) class: DepthClass,
    /// prompt fingerprint (task class) for the shared store's priors;
    /// computed at admit so retirement needs no prompt copy
    pub(crate) fp: u64,
}

/// Abort every in-flight sequence whose client has gone away: the lane
/// (or its pages) is reclaimed immediately instead of decoding to EOS for
/// nobody. Packed verification batches rows independently, so an abort
/// never changes what any co-resident sequence emits. Counted in
/// `ngrammys_requests_cancelled`.
pub(crate) fn sweep_cancelled(
    eng: &mut BatchedEngine,
    inflight: &mut HashMap<SeqId, Inflight>,
    metrics: &Metrics,
    status: &EngineStatus,
) {
    let dead: Vec<SeqId> =
        inflight.iter().filter(|(_, inf)| inf.cancel.is_cancelled()).map(|(&sid, _)| sid).collect();
    for sid in dead {
        if let Some(inf) = inflight.remove(&sid) {
            eng.abort(sid);
            status.active.fetch_sub(1, Ordering::Relaxed);
            status.class_counter(inf.class).fetch_sub(1, Ordering::Relaxed);
            metrics.requests_cancelled.fetch_add(1, Ordering::Relaxed);
            inf.reply.send(Err(anyhow!("request cancelled: client disconnected")));
        }
    }
}

/// One engine worker: the continuous-batching loop over the requests the
/// dispatcher routed here. Blocks on its channel only when idle; while
/// sequences are active it drains arrivals opportunistically between
/// steps so routed requests join the running batch without waiting for
/// it to finish. Exits when the dispatcher closes the channel (retire or
/// shutdown) and the last resident sequence completes.
#[allow(clippy::too_many_arguments)]
fn engine_worker_loop(
    id: u64,
    runtime: &ModelRuntime,
    tables: &Arc<NgramTables>,
    metrics: &Arc<Metrics>,
    trace: &Arc<TraceHub>,
    rx: Receiver<PoolJob>,
    scfg: &ServeConfig,
    status: &EngineStatus,
    lane_cap: usize,
    shared: Option<Arc<SharedDraftStore>>,
) {
    let analog = runtime.artifacts().dims.analog.clone();
    let recorder = trace.recorder_for_engine(id);
    let mut au_cfg = scfg.autoscale.clone();
    au_cfg.max_lanes = lane_cap;
    au_cfg.min_lanes = au_cfg.min_lanes.clamp(1, lane_cap);
    let boot_lanes = if scfg.elastic { au_cfg.min_lanes } else { lane_cap };
    let mut scaler = Autoscaler::new(au_cfg);

    let mut eng = fresh_engine(runtime, boot_lanes, scfg, &analog);
    eng.recorder = Some(recorder.clone());
    status.lanes.store(eng.capacity(), Ordering::Relaxed);
    status.lanes_target.store(eng.capacity(), Ordering::Relaxed);
    status.kv_bytes.store(eng.kv_bytes() as u64, Ordering::Relaxed);
    store_page_stats(status, &eng);
    let mut inflight: HashMap<SeqId, Inflight> = HashMap::new();
    let mut open = true;
    loop {
        // block for work only when fully idle
        if open && eng.active() == 0 && status.backlog.load(Ordering::Relaxed) == 0 {
            if scfg.elastic {
                // idle: give the lane memory back NOW (the hysteretic
                // path below never ticks while recv() is parked)
                let min = scaler.config().min_lanes;
                let lanes = eng.set_capacity(min);
                status.lanes.store(lanes, Ordering::Relaxed);
                status.lanes_target.store(min, Ordering::Relaxed);
                status.heat_milli.store(0, Ordering::Relaxed);
                status.kv_bytes.store(eng.kv_bytes() as u64, Ordering::Relaxed);
                store_page_stats(status, &eng);
            }
            match rx.recv() {
                Ok(pj) => {
                    admit_pool_job(&mut eng, pj, tables, metrics, &mut inflight, scfg, runtime,
                                   status, lane_cap, shared.as_ref());
                }
                Err(_) => open = false,
            }
        }
        // drain routed arrivals while lanes are free (growing toward the
        // cap first: the dispatcher routes up to lane_cap, which may be
        // ahead of the current capacity)
        loop {
            if !eng.has_capacity() {
                let want = (eng.active() + status.backlog.load(Ordering::Relaxed)).min(lane_cap);
                if scfg.elastic && eng.capacity() < want {
                    let lanes = eng.set_capacity(want);
                    status.lanes.store(lanes, Ordering::Relaxed);
                }
                if !eng.has_capacity() {
                    break;
                }
            }
            match rx.try_recv() {
                Ok(pj) => {
                    admit_pool_job(&mut eng, pj, tables, metrics, &mut inflight, scfg, runtime,
                                   status, lane_cap, shared.as_ref());
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        // reclaim lanes whose client disconnected before stepping: the
        // freed lane is visible to the dispatcher this iteration
        sweep_cancelled(&mut eng, &mut inflight, metrics, status);
        if eng.active() == 0 {
            if !open {
                return; // retired: channel closed and fully drained
            }
            continue; // spurious wake / failed admission: wait for work
        }
        // lane-level autoscale (level 1): this engine's routed backlog is
        // its queue pressure
        if scfg.elastic {
            let target = scaler.target_lanes(&Demand {
                queue_depth: status.backlog.load(Ordering::Relaxed),
                active: eng.active(),
                lanes: eng.capacity(),
                mean_heat: eng.mean_heat(),
            });
            let achieved = eng.set_capacity(target);
            status.lanes_target.store(target, Ordering::Relaxed);
            status.lanes.store(achieved, Ordering::Relaxed);
        } else {
            status.lanes_target.store(lane_cap, Ordering::Relaxed);
            status.lanes.store(eng.capacity(), Ordering::Relaxed);
        }
        match eng.step() {
            Ok(done) => {
                if let Some(b) = eng.last_step_budget() {
                    metrics.derived_budget.store(b as u64, Ordering::Relaxed);
                }
                for (sid, r) in done {
                    if let Some(inf) = inflight.remove(&sid) {
                        status.active.fetch_sub(1, Ordering::Relaxed);
                        status.class_counter(inf.class).fetch_sub(1, Ordering::Relaxed);
                        record_fingerprint_fp(shared.as_deref(), inf.fp, &r);
                        let resp =
                            finish_response(metrics, trace, inf.t_submit, inf.queue_wait, r);
                        inf.reply.send(Ok(resp));
                    }
                }
            }
            Err(e) => {
                // a step error poisons the whole batch (shared call):
                // fail every in-flight request and restart with a fresh
                // engine at the capacity the autoscaler had reached
                eprintln!("engine pool: step failed: {e:#}");
                for (_, inf) in inflight.drain() {
                    status.active.fetch_sub(1, Ordering::Relaxed);
                    status.class_counter(inf.class).fetch_sub(1, Ordering::Relaxed);
                    inf.reply.send(Err(anyhow!("batched engine step failed: {e:#}")));
                }
                let lanes = eng.capacity();
                eng = fresh_engine(runtime, lanes, scfg, &analog);
                eng.recorder = Some(recorder.clone());
            }
        }
        status.heat_milli.store(
            (eng.mean_heat().unwrap_or(0.0).max(0.0) * 1e3) as u64,
            Ordering::Relaxed,
        );
        status.kv_bytes.store(eng.kv_bytes() as u64, Ordering::Relaxed);
        store_page_stats(status, &eng);
    }
}

/// Move one routed request onto a lane: claims (growing if the router
/// ran ahead of the autoscaler), prefills, and registers the reply
/// route. Admission failures are counted, logged and answered — never
/// silent.
#[allow(clippy::too_many_arguments)]
pub(crate) fn admit_pool_job(
    eng: &mut BatchedEngine,
    pj: PoolJob,
    tables: &Arc<NgramTables>,
    metrics: &Metrics,
    inflight: &mut HashMap<SeqId, Inflight>,
    scfg: &ServeConfig,
    runtime: &ModelRuntime,
    status: &EngineStatus,
    lane_cap: usize,
    shared: Option<&Arc<SharedDraftStore>>,
) {
    metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
    if pj.job.cancel.is_cancelled() {
        // the client went away while the request sat in the queue: skip
        // the prefill entirely and give the slot accounting back
        status.class_counter(pj.class).fetch_sub(1, Ordering::Relaxed);
        status.backlog.fetch_sub(1, Ordering::Relaxed);
        metrics.requests_cancelled.fetch_add(1, Ordering::Relaxed);
        pj.job.reply.send(Err(anyhow!("request cancelled: client disconnected")));
        return;
    }
    if !eng.has_capacity() && eng.capacity() < lane_cap {
        // the dispatcher routes ahead of the lane autoscaler: grow on
        // demand so a routed request never bounces off a stale capacity
        let lanes = eng.set_capacity(eng.capacity() + 1);
        status.lanes.store(lanes, Ordering::Relaxed);
    }
    let strategy = wrap_shared(
        make_strategy_with_cache(
            pj.job.req.strategy,
            tables,
            pj.job.req.engine.q,
            &scfg.session_cache,
        ),
        shared,
        Some(status.shared_hits.clone()),
    );
    let controller = controller_for_request(
        pj.job.req.strategy, tables, pj.job.req.engine.q, scfg, runtime, metrics,
        shared.map(|s| s.as_ref()), &pj.job.req.prompt);
    let fp = fingerprint(&pj.job.req.prompt);
    // the queue dwell ends HERE, before admit: admit runs the prefill,
    // which the flight recorder attributes separately from queue wait, and
    // the total-latency clock keeps running from t_submit so both serving
    // modes stay comparable in latency_ms and /metrics
    let queue_wait = pj.job.t_submit.elapsed();
    let admitted =
        eng.admit_with(&pj.job.req.prompt, strategy, controller, pj.job.req.engine.clone());
    // account active BEFORE giving the backlog slot back: held() must
    // never transiently dip to 0 mid-admit, or the dispatcher could
    // mistake a busy engine for an idle one and retire it
    match admitted {
        Ok(id) => {
            status.active.fetch_add(1, Ordering::Relaxed);
            status.backlog.fetch_sub(1, Ordering::Relaxed);
            let inf = Inflight {
                reply: pj.job.reply,
                cancel: pj.job.cancel,
                t_submit: pj.job.t_submit,
                queue_wait,
                class: pj.class,
                fp,
            };
            inflight.insert(id, inf);
        }
        Err(e) => {
            status.class_counter(pj.class).fetch_sub(1, Ordering::Relaxed);
            status.backlog.fetch_sub(1, Ordering::Relaxed);
            metrics.admissions_failed.fetch_add(1, Ordering::Relaxed);
            eprintln!("engine pool: admission failed: {e:#}");
            pj.job.reply.send(Err(e));
        }
    }
}
