//! Paged-KV prefix-sharing bench: how many concurrent lanes fit into a
//! FIXED KV byte budget, contiguous lane pool vs paged pool, on a
//! shared-system-prompt workload.
//!
//! The lane pool charges every admission a full `max_len` lane, so a
//! budget of N lanes admits exactly N sequences no matter what the
//! prompts look like. The paged pool charges admissions in DISTINCT
//! pages: reservations are right-sized to the request's worst-case
//! position, and full pages of a previously-seen prompt prefix are
//! attached by refcount instead of being rewritten. On the
//! shared-system-prompt workload (every request opens with the same
//! system prompt) that is the difference between N lanes and several
//! times N — which is the tentpole claim this bench GATES: it fails
//! unless the paged pool admits strictly more sequences than the lane
//! pool from the same bytes. A disjoint-prompt control shows how much of
//! the win is sharing vs reservation right-sizing alone.
//!
//! Byte-identity is re-checked here too: the same request set is decoded
//! to completion through both pools and the output streams must match
//! token for token.

use anyhow::{ensure, Result};

use crate::config::EngineConfig;
use crate::engine::{generate_all, BatchedEngine};
use crate::scheduler::{make_strategy, StrategyName};
use crate::tokenizer::TokenId;
use crate::trace::report::TraceSummary;
use crate::trace::{FlightRecorder, TraceEvent, DEFAULT_RING_CAPACITY};
use crate::util::json::Json;
use crate::workload::{disjoint_prompts, shared_prefix_prompts};

/// Lane count whose byte budget both pools get (the fixed KV budget).
const LANES: usize = 4;
/// Positions per KV page for the paged side.
const PAGE_SIZE: usize = 16;
/// Prompts generated per scenario (an upper bound on admissions).
const USERS: usize = 32;
/// Per-user suffix tokens after the shared system prompt.
const SUFFIX: usize = 8;

/// Run the prefix-sharing admission comparison; fails unless the paged
/// pool admits strictly more lanes than the lane pool at the same KV
/// byte budget on the shared-prompt workload.
pub fn run(ctx: &super::BenchCtx, smoke: bool) -> Result<()> {
    let d = &ctx.runtime.artifacts().dims;
    let vocab = ctx.manifest.vocab_size;
    let (max_new, ident_n) = if smoke { (12, 4) } else { (24, 8) };
    // system prompt = half the context, rounded to whole pages so the
    // shared region seals into shareable full pages
    let prefix_len = (d.max_len / 2 / PAGE_SIZE) * PAGE_SIZE;
    let cfg = EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: max_new };
    // the fixed budget: exactly the bytes the lane pool pins for LANES
    let n_pages = LANES * d.max_len.div_ceil(PAGE_SIZE);

    let shared = shared_prefix_prompts(0x9E37, USERS, prefix_len, SUFFIX, vocab);
    let disjoint = disjoint_prompts(0x79B9, USERS, prefix_len + SUFFIX, vocab);

    println!(
        "== paged KV prefix sharing (model '{}', budget = {LANES} lanes = {n_pages} \
         pages x {PAGE_SIZE}, system prompt {prefix_len} + {SUFFIX} tokens/user) ==\n",
        ctx.model
    );

    // ---- admissions until backpressure, per pool/workload
    let lane_admitted = {
        let mut eng = BatchedEngine::new(&ctx.runtime, LANES);
        count_admissions(&mut eng, ctx, &shared, &cfg)?
    };
    let (paged_admitted, hits) = {
        let mut eng = BatchedEngine::new_paged(&ctx.runtime, USERS, PAGE_SIZE, n_pages);
        let n = count_admissions(&mut eng, ctx, &shared, &cfg)?;
        (n, eng.page_stats().prefix_hits)
    };
    let control_admitted = {
        let mut eng = BatchedEngine::new_paged(&ctx.runtime, USERS, PAGE_SIZE, n_pages);
        count_admissions(&mut eng, ctx, &disjoint, &cfg)?
    };

    println!("{:<28} {:>10} {:>12}", "pool / workload", "admitted", "prefix hits");
    println!("{:<28} {:>10} {:>12}", "lane / shared-prompt", lane_admitted, "-");
    println!("{:<28} {:>10} {:>12}", "paged / shared-prompt", paged_admitted, hits);
    println!("{:<28} {:>10} {:>12}", "paged / disjoint", control_admitted, 0);
    let hit_rate = hits as f64 / paged_admitted.max(1) as f64;
    println!(
        "\npaged admits {:.2}x the lane pool on shared prompts \
         ({:.0}% of admissions attached shared pages); disjoint control {:.2}x",
        paged_admitted as f64 / lane_admitted.max(1) as f64,
        hit_rate * 100.0,
        control_admitted as f64 / lane_admitted.max(1) as f64,
    );
    ensure!(
        paged_admitted > lane_admitted,
        "paged pool admitted {paged_admitted} <= lane pool {lane_admitted} at the same \
         KV budget on the shared-prompt workload — prefix sharing is not paying"
    );

    // ---- byte-identity: same requests, both pools, identical streams
    let reqs = &shared[..ident_n.min(shared.len())];
    let mut lane_eng = BatchedEngine::new(&ctx.runtime, LANES);
    let lane_out = generate_all(&mut lane_eng, requests(ctx, reqs, &cfg))?;
    let mut paged_eng = BatchedEngine::new_paged(&ctx.runtime, USERS, PAGE_SIZE, n_pages);
    paged_eng.collect_traces = true;
    // recorder on the paged side only: identity vs the untraced lane run
    // doubles as a tracing-perturbation check
    let rec = FlightRecorder::standalone(0, DEFAULT_RING_CAPACITY);
    paged_eng.recorder = Some(rec.clone());
    let paged_out = generate_all(&mut paged_eng, requests(ctx, reqs, &cfg))?;
    for (i, (l, p)) in lane_out.iter().zip(&paged_out).enumerate() {
        ensure!(
            l.tokens == p.tokens,
            "BYTE-IDENTITY VIOLATION: request {i} differs between lane and paged pools"
        );
    }
    println!("byte-identity: {} streams identical across lane and paged pools", lane_out.len());

    // cost-model throughput of the paged run, for the CI regression gate
    let cm = ctx.cost_model();
    let sim_s: f64 = paged_eng
        .packed_traces
        .iter()
        .map(|t| cm.call_time(t.rows, t.w + 1, t.max_ctx))
        .sum();
    let tokens: usize = paged_out.iter().map(|r| r.tokens.len().saturating_sub(1)).sum();
    let calls: usize = paged_out.iter().map(|r| r.calls).sum();
    let sim_tps = tokens as f64 / sim_s.max(1e-12);

    super::write_json(
        &format!("prefix_{}", ctx.model),
        &Json::obj(vec![
            ("bench", Json::Str("kv-prefix-sharing".into())),
            ("model", Json::Str(ctx.model.clone())),
            ("page_size", Json::Num(PAGE_SIZE as f64)),
            ("budget_pages", Json::Num(n_pages as f64)),
            ("budget_lanes", Json::Num(LANES as f64)),
            ("system_prompt_tokens", Json::Num(prefix_len as f64)),
            ("lane_admitted", Json::Num(lane_admitted as f64)),
            ("paged_admitted_shared", Json::Num(paged_admitted as f64)),
            ("paged_admitted_disjoint", Json::Num(control_admitted as f64)),
            ("prefix_hits", Json::Num(hits as f64)),
            ("prefix_hit_rate", Json::Num(hit_rate)),
            ("sim_tokens_per_s", Json::Num(sim_tps)),
        ]),
    )?;
    // the CI bench-regression gate compares this summary against the
    // committed benches/baseline.json (`ngrammys ci-bench-check`);
    // phases + scenario_steps are ungated extras from the flight recorder
    let steps: Vec<TraceEvent> =
        rec.snapshot(DEFAULT_RING_CAPACITY).into_iter().map(TraceEvent::Step).collect();
    let scenario_steps = vec![
        ("lane-identity".to_string(), Json::Num(lane_eng.steps_done() as f64)),
        ("paged-identity".to_string(), Json::Num(paged_eng.steps_done() as f64)),
    ];
    super::write_bench_summary_with(
        "prefix",
        sim_tps,
        tokens as f64 / calls.max(1) as f64,
        super::accept_rate(tokens, calls),
        vec![
            ("phases", TraceSummary::from_events(&steps).phases_json()),
            ("scenario_steps", Json::Obj(scenario_steps)),
        ],
    )
}

/// Admit prompts one by one until the pool backpressures (or the prompt
/// set runs out); returns how many got in. Each admission really runs
/// its prefill, so the count reflects the live admission path, not just
/// the accounting.
fn count_admissions(
    eng: &mut BatchedEngine,
    ctx: &super::BenchCtx,
    prompts: &[Vec<TokenId>],
    cfg: &EngineConfig,
) -> Result<usize> {
    let mut n = 0usize;
    for p in prompts {
        if !eng.can_admit_prompt(p, cfg) {
            break;
        }
        let strat = make_strategy(StrategyName::Mixed, &ctx.tables, cfg.q);
        eng.admit(p, strat, cfg.clone())?;
        n += 1;
    }
    Ok(n)
}

/// Build the request tuples `generate_all` consumes (same strategy and
/// engine shape for every request, as the identity check requires).
fn requests(
    ctx: &super::BenchCtx,
    prompts: &[Vec<TokenId>],
    cfg: &EngineConfig,
) -> Vec<(Vec<TokenId>, Box<dyn crate::draft::DraftStrategy>, EngineConfig)> {
    prompts
        .iter()
        .map(|p| {
            (p.clone(), make_strategy(StrategyName::Mixed, &ctx.tables, cfg.q), cfg.clone())
        })
        .collect()
}
