//! Figures 3, 5 (base/7B-analog), 6, 7 (small/3B-analog), 8, 9
//! (large/13B-analog): wall-time-speedup and tokens-per-call grids over
//! the full mixed-strategy sweep k in {1,5,10,20,25} x w in {2,4,...,14}.

use anyhow::Result;

use crate::scheduler::StrategyName;
use crate::util::json::Json;
use crate::workload::TASKS;

/// The paper's grid sweep k values.
pub const GRID_KS: [usize; 5] = [1, 5, 10, 20, 25];
/// The paper's grid sweep w values.
pub const GRID_WS: [usize; 7] = [2, 4, 6, 8, 10, 12, 14];

/// Grid-sweep output: one (tokens/call, speedup) cell per (k, w).
pub struct GridResult {
    /// per task: map (k, w) -> (tokens_per_call, sim_speedup)
    pub cells: Vec<(String, Vec<((usize, usize), (f64, f64))>)>,
}

/// Run the full mixed-strategy (k, w) grid for one model.
pub fn run(ctx: &super::BenchCtx, n_prompts: usize, max_new: usize,
           ks: &[usize], ws: &[usize]) -> Result<GridResult> {
    println!(
        "== Speedup & tokens/call grids (model '{}' ~ {}) ==\n",
        ctx.model,
        ctx.runtime.artifacts().dims.analog
    );
    let mut all = Vec::new();
    let mut out_tasks = Vec::new();
    for task in TASKS {
        let prompts = ctx.prompts(task, n_prompts, 128)?;
        let mut cells = Vec::new();
        for &k in ks {
            for &w in ws {
                let c = super::run_cell(ctx, StrategyName::Mixed, &prompts, k, w, 1, max_new)?;
                cells.push(((k, w), (c.tokens_per_call, c.sim_speedup)));
            }
        }
        let lookup = |k: usize, w: usize, idx: usize| -> f64 {
            cells
                .iter()
                .find(|((ck, cw), _)| *ck == k && *cw == w)
                .map(|(_, v)| if idx == 0 { v.0 } else { v.1 })
                .unwrap_or(f64::NAN)
        };
        println!("{}", super::render_grid(
            &format!("-- {task}: simulated wall-time speedup (A100 cost model) --"),
            ks, ws, |k, w| lookup(k, w, 1)));
        println!("{}", super::render_grid(
            &format!("-- {task}: tokens per call --"),
            ks, ws, |k, w| lookup(k, w, 0)));

        let rows = |idx: usize| -> Json {
            Json::Arr(ks.iter().map(|&k| {
                Json::Arr(ws.iter().map(|&w| Json::Num(lookup(k, w, idx))).collect())
            }).collect())
        };
        out_tasks.push(Json::obj(vec![
            ("task", Json::Str(task.into())),
            ("ks", Json::Arr(ks.iter().map(|&k| Json::Num(k as f64)).collect())),
            ("ws", Json::Arr(ws.iter().map(|&w| Json::Num(w as f64)).collect())),
            ("tokens_per_call", rows(0)),
            ("sim_speedup", rows(1)),
        ]));
        all.push((task.to_string(), cells));
    }
    super::write_json(
        &format!("grid_{}", ctx.model),
        &Json::obj(vec![
            ("figure", Json::Str(format!("speedup+tok-call grids ({})", ctx.model))),
            ("model", Json::Str(ctx.model.clone())),
            ("tasks", Json::Arr(out_tasks)),
        ]),
    )?;
    Ok(GridResult { cells: all })
}
