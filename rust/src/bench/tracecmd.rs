//! `ngrammys trace` — flight-recorder tooling.
//!
//! Two modes:
//! - **replay** (`--input FILE.jsonl`): parse a captured trace (from
//!   `GET /trace` or a previous live run) and render the per-phase /
//!   per-strategy breakdown table, optionally exporting Chrome tracing
//!   format (`--chrome OUT.json`).
//! - **live** (no `--input`): decode a small mixed-task workload through
//!   one [`BatchedEngine`] with a recorder attached, then summarize what
//!   the ring captured and write the JSONL under `bench_out/`.
//!
//! With `--smoke`, live mode doubles as the CI trace-overhead gate: the
//! same workload runs twice — recorder attached vs detached — and the run
//! FAILS unless the output streams are byte-identical and the cost-model
//! throughput (priced from the packed call traces, which are
//! deterministic) is unchanged. Wall-clock overhead is printed for
//! information but not gated on: CI machines are too noisy to pin a
//! sub-percent timing delta, while byte identity + identical packed
//! traces pin everything tracing could have perturbed.

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::config::EngineConfig;
use crate::engine::{BatchedEngine, SeqId};
use crate::scheduler::{make_strategy, StrategyName};
use crate::tokenizer::TokenId;
use crate::trace::report::{chrome_trace, TraceSummary};
use crate::trace::{to_jsonl, FlightRecorder, TraceEvent, TraceHub, DEFAULT_RING_CAPACITY};
use crate::workload::{Prompt, TASKS};

/// Concurrency (pooled KV lanes) of the live capture workload.
const CONC: usize = 4;

/// Replay a captured JSONL trace: print the breakdown table and export
/// Chrome format when asked.
pub fn replay(input: &Path, chrome: Option<&Path>) -> Result<()> {
    let text = std::fs::read_to_string(input)?;
    let summary = TraceSummary::from_jsonl(&text)?;
    ensure!(summary.steps + summary.requests > 0, "no trace events in {}", input.display());
    println!("== trace replay: {} ==\n", input.display());
    print!("{}", summary.render_table());
    if let Some(out) = chrome {
        let events = crate::trace::report::parse_jsonl(&text)?;
        write_chrome(&events, out)?;
    }
    Ok(())
}

/// One pass of the live workload through a batched engine.
struct LiveRun {
    /// emitted token streams, in request order
    outputs: Vec<Vec<TokenId>>,
    /// cost-model seconds of every packed call (deterministic)
    sim_s: f64,
    /// wall-clock time of the decode loop on this host
    wall: Duration,
    /// engine steps driven
    steps: u64,
}

/// Decode `prompts` through one batched engine, optionally with a flight
/// recorder attached. Admission order, strategy and shapes are identical
/// across calls, so two passes differing only in `recorder` must produce
/// identical outputs and packed traces.
fn drive(
    ctx: &super::BenchCtx,
    prompts: &[Prompt],
    max_new: usize,
    recorder: Option<&std::sync::Arc<FlightRecorder>>,
) -> Result<LiveRun> {
    let cm = ctx.cost_model();
    let cfg = EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: max_new };
    let mut eng = BatchedEngine::with_budget(&ctx.runtime, CONC, None);
    eng.collect_traces = true;
    eng.recorder = recorder.cloned();
    let mut pending: Vec<(usize, &Prompt)> = prompts.iter().enumerate().collect();
    pending.reverse();
    let mut outputs: Vec<Vec<TokenId>> = vec![Vec::new(); prompts.len()];
    let mut idmap: HashMap<SeqId, usize> = HashMap::new();
    let t0 = Instant::now();
    loop {
        while eng.has_capacity() {
            let Some((i, p)) = pending.pop() else { break };
            let strat = make_strategy(StrategyName::Mixed, &ctx.tables, 1);
            let id = eng.admit_with(&p.tokens, strat, None, cfg.clone())?;
            idmap.insert(id, i);
        }
        if eng.active() == 0 && pending.is_empty() {
            break;
        }
        for (id, r) in eng.step()? {
            outputs[idmap[&id]] = r.tokens;
        }
    }
    let wall = t0.elapsed();
    let sim_s: f64 =
        eng.packed_traces.iter().map(|t| cm.call_time(t.rows, t.w + 1, t.max_ctx)).sum();
    Ok(LiveRun { outputs, sim_s, wall, steps: eng.steps_done() })
}

/// Live capture (and, with `smoke`, the traced-vs-untraced overhead
/// gate). Writes the captured events to `bench_out/trace_smoke.jsonl`
/// (smoke) or `bench_out/trace_live.jsonl`.
pub fn live(
    ctx: &super::BenchCtx,
    n_prompts: usize,
    max_new: usize,
    smoke: bool,
    chrome: Option<&Path>,
) -> Result<()> {
    let (n_prompts, max_new) = if smoke { (2, 16) } else { (n_prompts, max_new) };
    let mut prompts = Vec::new();
    for task in TASKS {
        prompts.extend(ctx.prompts(task, n_prompts.div_ceil(TASKS.len()).max(2), 96)?);
    }
    println!(
        "== live trace capture (model '{}', {} prompts x {} tokens, conc {CONC}) ==\n",
        ctx.model,
        prompts.len(),
        max_new
    );

    let hub = TraceHub::new(DEFAULT_RING_CAPACITY);
    let rec = hub.recorder_for_engine(0);
    let traced = drive(ctx, &prompts, max_new, Some(&rec))?;
    ensure!(rec.steps_recorded() > 0, "traced run recorded no step events");
    let events = hub.recent(DEFAULT_RING_CAPACITY);
    print!("{}", TraceSummary::from_events(&events).render_table());

    if smoke {
        let untraced = drive(ctx, &prompts, max_new, None)?;
        ensure!(
            traced.outputs == untraced.outputs,
            "INVARIANT VIOLATION: tracing perturbed the output streams"
        );
        let (a, b) = (traced.sim_s, untraced.sim_s);
        ensure!(
            (a - b).abs() <= 1e-9 * b.abs().max(1.0),
            "tracing changed the packed call schedule: {a} sim-s traced vs {b} untraced"
        );
        let overhead = traced.wall.as_secs_f64() / untraced.wall.as_secs_f64().max(1e-9) - 1.0;
        println!(
            "\noverhead gate: outputs byte-identical over {} streams, packed schedule \
             unchanged ({} steps); wall overhead {:+.1}% (informational)",
            traced.outputs.len(),
            traced.steps,
            overhead * 1e2
        );
    }

    let name = if smoke { "trace_smoke" } else { "trace_live" };
    std::fs::create_dir_all("bench_out")?;
    let path = format!("bench_out/{name}.jsonl");
    std::fs::write(&path, to_jsonl(&events))?;
    eprintln!("  -> wrote {path}");
    if let Some(out) = chrome {
        write_chrome(&events, out)?;
    }
    Ok(())
}

/// Write events in Chrome tracing format (load via `chrome://tracing` or
/// Perfetto).
fn write_chrome(events: &[TraceEvent], out: &Path) -> Result<()> {
    std::fs::write(out, chrome_trace(events).to_string_pretty())?;
    eprintln!("  -> wrote {} (chrome://tracing / Perfetto)", out.display());
    Ok(())
}
