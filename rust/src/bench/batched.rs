//! Cross-request batching bench: aggregate decode throughput of the
//! continuous-batching `BatchedEngine` vs the request-batch-1 baseline
//! (one `SpecDecoder` per request, run back to back) over the same
//! request set, at increasing concurrency.
//!
//! Headline metric is the cost-model-simulated aggregate tokens/sec at
//! paper scale (A100, the model's analog dims) — the same substitution the
//! rest of the bench suite uses (see bench/mod.rs): acceptance traces are
//! REAL, wall-times are simulated because CPU PJRT has no memory-bound
//! regime. A packed (sum k_i, w+1) call reads the weights ONCE for all
//! sequences, so its simulated cost is far below the sum of the per-
//! sequence calls it replaces — that gap is the §3 batch dimension spent
//! on requests. Measured CPU throughput is printed alongside for honesty.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config::EngineConfig;
use crate::engine::batched::generate_all;
use crate::engine::{BatchedEngine, GenResult, SpecDecoder};
use crate::scheduler::{make_strategy, StrategyName};
use crate::util::json::Json;
use crate::workload::TASKS;

/// Default lane counts swept by `bench batched`.
pub const CONCURRENCIES: [usize; 4] = [1, 2, 4, 8];

/// Run the batched-vs-sequential throughput comparison at each
/// concurrency in `concurrencies`.
pub fn run(
    ctx: &super::BenchCtx,
    n_prompts: usize,
    max_new: usize,
    concurrencies: &[usize],
) -> Result<()> {
    let (k, w) = (10usize, 10usize);
    let cfg = EngineConfig { k, w, q: 1, max_new_tokens: max_new };
    let cm = ctx.cost_model();

    // request mix: prompts from all three tasks, interleaved
    let mut prompts = Vec::new();
    for task in TASKS {
        prompts.extend(ctx.prompts(task, n_prompts.div_ceil(TASKS.len()).max(2), 96)?);
    }
    let n_requests = prompts.len().min(n_prompts.max(TASKS.len() * 2));
    let prompts = &prompts[..n_requests];

    println!(
        "== batched vs request-batch-1 throughput (model '{}', mixed ({k},{w}), \
         {n_requests} requests x {max_new} tokens) ==\n",
        ctx.model
    );
    println!(
        "{:<6} {:>14} {:>14} {:>9} | {:>12} {:>12}",
        "conc", "seq tok/s(sim)", "bat tok/s(sim)", "speedup", "seq tok/s", "bat tok/s"
    );

    // --- request-batch-1 baseline (independent of concurrency)
    let t0 = Instant::now();
    let mut seq_results: Vec<GenResult> = Vec::with_capacity(n_requests);
    for p in prompts {
        let strat = make_strategy(StrategyName::Mixed, &ctx.tables, 1);
        let mut dec = SpecDecoder::new(&ctx.runtime, strat, cfg.clone());
        dec.collect_traces = true;
        seq_results.push(dec.generate(&p.tokens)?);
    }
    let seq_cpu_s = t0.elapsed().as_secs_f64();
    let seq_tokens: usize = seq_results.iter().map(|r| r.tokens.len() - 1).sum();
    let seq_sim_s: f64 = seq_results
        .iter()
        .flat_map(|r| &r.traces)
        .map(|t| cm.call_time(t.k, t.w + 1, t.ctx_len))
        .sum();

    let mut rows = Vec::new();
    for &conc in concurrencies {
        // --- batched engine at this concurrency (caller-owned engine so
        // the packed-call traces stay accessible)
        let t1 = Instant::now();
        let mut eng = BatchedEngine::new(&ctx.runtime, conc);
        eng.collect_traces = true;
        let reqs: Vec<_> = prompts
            .iter()
            .map(|p| {
                let strat = make_strategy(StrategyName::Mixed, &ctx.tables, 1);
                (p.tokens.clone(), strat, cfg.clone())
            })
            .collect();
        let bat_results: Vec<GenResult> = generate_all(&mut eng, reqs)?;
        let bat_cpu_s = t1.elapsed().as_secs_f64();
        let bat_tokens: usize = bat_results.iter().map(|r| r.tokens.len() - 1).sum();
        ensure!(
            bat_tokens == seq_tokens,
            "batched engine emitted {bat_tokens} decode tokens vs {seq_tokens} sequential — \
             the greedy-stream invariant is broken"
        );
        let bat_sim_s: f64 = eng
            .packed_traces
            .iter()
            .map(|p| cm.call_time(p.rows, p.w + 1, p.max_ctx))
            .sum();

        let seq_sim_tps = seq_tokens as f64 / seq_sim_s;
        let bat_sim_tps = bat_tokens as f64 / bat_sim_s;
        println!(
            "{:<6} {:>14.1} {:>14.1} {:>8.2}x | {:>12.1} {:>12.1}",
            conc,
            seq_sim_tps,
            bat_sim_tps,
            bat_sim_tps / seq_sim_tps,
            seq_tokens as f64 / seq_cpu_s,
            bat_tokens as f64 / bat_cpu_s,
        );
        rows.push(Json::obj(vec![
            ("concurrency", Json::Num(conc as f64)),
            ("packed_calls", Json::Num(eng.packed_traces.len() as f64)),
            ("seq_sim_tokens_per_s", Json::Num(seq_sim_tps)),
            ("bat_sim_tokens_per_s", Json::Num(bat_sim_tps)),
            ("sim_speedup", Json::Num(bat_sim_tps / seq_sim_tps)),
            ("seq_cpu_tokens_per_s", Json::Num(seq_tokens as f64 / seq_cpu_s)),
            ("bat_cpu_tokens_per_s", Json::Num(bat_tokens as f64 / bat_cpu_s)),
        ]));
    }
    println!(
        "\nsim = A100 cost model at paper scale over the run's real call\n\
         traces; a packed call reads the weights once for every sequence\n\
         riding it, which is the cross-request half of the paper's free\n\
         batch dimension."
    );
    super::write_json(
        &format!("batched_{}", ctx.model),
        &Json::obj(vec![
            ("bench", Json::Str("batched-throughput".into())),
            ("model", Json::Str(ctx.model.clone())),
            ("k", Json::Num(k as f64)),
            ("w", Json::Num(w as f64)),
            ("max_new", Json::Num(max_new as f64)),
            ("n_requests", Json::Num(n_requests as f64)),
            ("rows", Json::Arr(rows)),
        ]),
    )
}
