//! CI bench-regression gate: compare the `BENCH_*.json` summaries the
//! smoke benches emit under `bench_out/` against the committed
//! `benches/baseline.json`, and FAIL on a cost-model throughput
//! regression beyond the tolerance.
//!
//! Baseline format — one object per gated bench, keyed by the summary
//! name ([`crate::bench::write_bench_summary`]):
//!
//! ```json
//! {
//!   "elastic": {"tokens_per_s": 1234.5},
//!   "adaptive": {"tokens_per_s": 987.6},
//!   "pool":    {"tokens_per_s": null}
//! }
//! ```
//!
//! A `null` (or missing) `tokens_per_s` means "not recorded yet": the
//! gate prints the observed value to copy into the baseline and passes —
//! that is how a fresh bench bootstraps into the gate without guessing a
//! number. Refresh the committed numbers with
//! `ngrammys ci-bench-check --update` after an intentional perf change
//! (the cost-model throughput is deterministic, so CI reproduces the
//! committed values exactly and the 10% tolerance only absorbs real
//! regressions, not noise).
//!
//! An entry may additionally carry `"wall_clock": true`, marking its
//! number as measured wall time (machine-dependent, so a committed value
//! would be wrong on every other machine). Wall-clock entries are
//! ADVISORY: a recorded number that regresses prints a warning but never
//! fails the gate — runner-to-runner variance would make it flaky.
//! `--strict-baseline` fails the gate for every still-null entry EXCEPT
//! wall-clock ones — the knob that keeps deterministic benches from
//! riding the bootstrap path forever (the rolling CI gate passes it).
//! `--update` preserves the marker.

use std::path::Path;

use anyhow::{anyhow, ensure, Result};

use crate::util::json::Json;

/// Default allowed throughput drop before the gate fails (10%).
pub const DEFAULT_TOLERANCE: f64 = 0.10;

/// Verdict for one gated bench.
#[derive(Debug, Clone, PartialEq)]
pub enum Verdict {
    /// current ≥ baseline × (1 − tolerance)
    Pass,
    /// baseline has no recorded number yet: print-and-pass
    Bootstrap,
    /// current < baseline × (1 − tolerance): the gate fails
    Regressed {
        /// committed baseline tokens/s
        baseline: f64,
        /// fractional drop below the baseline (0.25 = −25%)
        drop: f64,
    },
}

/// Compare one bench's current throughput against its baseline entry.
pub fn verdict(baseline_tps: Option<f64>, current_tps: f64, tolerance: f64) -> Verdict {
    match baseline_tps {
        None => Verdict::Bootstrap,
        Some(b) if b <= 0.0 => Verdict::Bootstrap,
        Some(b) => {
            if current_tps >= b * (1.0 - tolerance) {
                Verdict::Pass
            } else {
                Verdict::Regressed { baseline: b, drop: 1.0 - current_tps / b }
            }
        }
    }
}

/// Run the gate: read `baseline_path`, find each gated bench's
/// `BENCH_<name>.json` under `bench_dir`, compare, print a table, and
/// fail if any bench regressed past `tolerance` (or is missing its
/// summary entirely). With `update`, rewrite the baseline file with the
/// observed values instead of failing — the refresh procedure. With
/// `strict`, additionally fail when any entry NOT marked
/// `"wall_clock": true` is still null (never regression-gated).
pub fn run(
    baseline_path: &Path,
    bench_dir: &Path,
    tolerance: f64,
    update: bool,
    strict: bool,
) -> Result<()> {
    let baseline = Json::from_file(baseline_path)?;
    let entries = baseline
        .as_obj()
        .ok_or_else(|| anyhow!("{baseline_path:?}: baseline must be a JSON object"))?;
    ensure!(!entries.is_empty(), "{baseline_path:?}: baseline lists no benches");

    println!(
        "== ci-bench-check: {} benches vs {baseline_path:?} (tolerance {:.0}%) ==\n",
        entries.len(),
        tolerance * 100.0
    );
    println!("{:<12} {:>14} {:>14} {:>9}  verdict", "bench", "baseline", "current", "delta");

    let mut updated = Vec::new();
    let mut failures = Vec::new();
    let mut strict_nulls = Vec::new();
    for (name, entry) in entries {
        let wall_clock =
            entry.get("wall_clock").and_then(|v| v.as_bool()).unwrap_or(false);
        let summary_path = bench_dir.join(format!("BENCH_{name}.json"));
        let summary = Json::from_file(&summary_path).map_err(|e| {
            anyhow!("{e:#} — did the `bench {name} --smoke` step run before the gate?")
        })?;
        let current = summary
            .get("tokens_per_s")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("{summary_path:?}: missing tokens_per_s"))?;
        let base = entry.get("tokens_per_s").and_then(|v| v.as_f64());
        let v = verdict(base, current, tolerance);
        let delta = base
            .filter(|&b| b > 0.0)
            .map(|b| format!("{:+.1}%", (current / b - 1.0) * 100.0))
            .unwrap_or_else(|| "--".into());
        let verdict_str = match &v {
            Verdict::Pass => "ok".to_string(),
            Verdict::Bootstrap => "bootstrap (no baseline yet — run with --update)".to_string(),
            Verdict::Regressed { drop, .. } if wall_clock => {
                format!("regressed −{:.1}% (advisory: wall-clock entry)", drop * 100.0)
            }
            Verdict::Regressed { drop, .. } => format!("REGRESSED −{:.1}%", drop * 100.0),
        };
        println!(
            "{name:<12} {:>14} {current:>14.1} {delta:>9}  {verdict_str}",
            base.map(|b| format!("{b:.1}")).unwrap_or_else(|| "null".into()),
        );
        match v {
            // wall-clock numbers are machine-dependent: a regression is
            // worth a line in the log, never a red build
            Verdict::Regressed { .. } if wall_clock => {}
            Verdict::Regressed { .. } => failures.push(name.clone()),
            // still-null entries print as "bootstrap" in the table above;
            // `--strict-baseline` (the rolling CI gate) is what keeps
            // non-wall-clock ones from riding that path forever
            Verdict::Bootstrap if !wall_clock => strict_nulls.push(name.clone()),
            Verdict::Bootstrap | Verdict::Pass => {}
        }
        // --update must round-trip the wall_clock marker, or one refresh
        // would silently promote a machine-dependent number into the gate
        let mut fields = vec![("tokens_per_s", Json::Num(current))];
        if wall_clock {
            fields.push(("wall_clock", Json::Bool(true)));
        }
        updated.push((name.clone(), Json::obj(fields)));
    }

    // the gate must be symmetric: a summary the baseline does not know
    // about is as much a hole as a baseline entry with no summary —
    // otherwise a new gated bench silently escapes the gate forever
    let known: Vec<&str> = entries.iter().map(|(n, _)| n.as_str()).collect();
    let mut strays = Vec::new();
    if let Ok(dir) = std::fs::read_dir(bench_dir) {
        for f in dir.flatten() {
            let fname = f.file_name().to_string_lossy().into_owned();
            if let Some(name) = fname.strip_prefix("BENCH_").and_then(|s| s.strip_suffix(".json"))
            {
                if !known.contains(&name) {
                    strays.push(name.to_string());
                }
            }
        }
    }
    strays.sort();
    if update {
        for name in &strays {
            let summary = Json::from_file(&bench_dir.join(format!("BENCH_{name}.json")))?;
            let current = summary
                .get("tokens_per_s")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow!("BENCH_{name}.json: missing tokens_per_s"))?;
            println!("{name:<12} {:>14} {current:>14.1} {:>9}  added to baseline", "--", "--");
            updated.push((name.clone(), Json::obj(vec![("tokens_per_s", Json::Num(current))])));
        }
    } else {
        ensure!(
            strays.is_empty(),
            "bench summaries with no baseline entry: {} (add a null entry to {baseline_path:?} \
             or run `ngrammys ci-bench-check --update`)",
            strays.join(", ")
        );
    }

    if update {
        let j = Json::Obj(updated);
        std::fs::write(baseline_path, j.to_string_pretty())
            .map_err(|e| anyhow!("writing {baseline_path:?}: {e}"))?;
        println!("\nwrote observed values to {baseline_path:?}");
        return Ok(());
    }
    ensure!(
        !strict || strict_nulls.is_empty(),
        "--strict-baseline: {} non-wall-clock baseline entr{} still null (never \
         regression-gated): {} — commit real numbers via `ngrammys ci-bench-check --update`",
        strict_nulls.len(),
        if strict_nulls.len() == 1 { "y is" } else { "ies are" },
        strict_nulls.join(", ")
    );
    ensure!(
        failures.is_empty(),
        "cost-model throughput regressed >{:.0}% on: {} (refresh {baseline_path:?} with \
         `ngrammys ci-bench-check --update` ONLY if the change is intentional)",
        tolerance * 100.0,
        failures.join(", ")
    );
    println!("\nbench-regression gate: OK");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verdict_passes_within_tolerance() {
        assert_eq!(verdict(Some(100.0), 100.0, 0.10), Verdict::Pass);
        assert_eq!(verdict(Some(100.0), 95.0, 0.10), Verdict::Pass);
        assert_eq!(verdict(Some(100.0), 90.0, 0.10), Verdict::Pass); // exactly at the edge
        assert_eq!(verdict(Some(100.0), 140.0, 0.10), Verdict::Pass); // improvements always pass
    }

    #[test]
    fn verdict_fails_past_tolerance() {
        match verdict(Some(100.0), 80.0, 0.10) {
            Verdict::Regressed { baseline, drop } => {
                assert_eq!(baseline, 100.0);
                assert!((drop - 0.2).abs() < 1e-9);
            }
            v => panic!("expected Regressed, got {v:?}"),
        }
    }

    #[test]
    fn verdict_bootstraps_on_missing_baseline() {
        assert_eq!(verdict(None, 123.0, 0.10), Verdict::Bootstrap);
        assert_eq!(verdict(Some(0.0), 123.0, 0.10), Verdict::Bootstrap);
    }

    #[test]
    fn gate_end_to_end_against_temp_files() {
        let dir = std::env::temp_dir().join(format!("ngrammys-gate-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let baseline = dir.join("baseline.json");
        std::fs::write(
            &baseline,
            r#"{"alpha": {"tokens_per_s": 100.0}, "beta": {"tokens_per_s": null}}"#,
        )
        .unwrap();
        std::fs::write(
            dir.join("BENCH_alpha.json"),
            r#"{"bench": "alpha", "tokens_per_s": 96.0, "tokens_per_call": 2.0, "accept_rate": 0.5}"#,
        )
        .unwrap();
        std::fs::write(
            dir.join("BENCH_beta.json"),
            r#"{"bench": "beta", "tokens_per_s": 50.0, "tokens_per_call": 1.5, "accept_rate": 0.3}"#,
        )
        .unwrap();
        // alpha within tolerance, beta bootstraps: the gate passes
        run(&baseline, &dir, 0.10, false, false).unwrap();
        // a regression on alpha fails the gate and names the bench
        std::fs::write(
            dir.join("BENCH_alpha.json"),
            r#"{"bench": "alpha", "tokens_per_s": 50.0, "tokens_per_call": 2.0, "accept_rate": 0.5}"#,
        )
        .unwrap();
        let err = run(&baseline, &dir, 0.10, false, false).unwrap_err().to_string();
        assert!(err.contains("alpha"), "error must name the regressed bench: {err}");
        // --update rewrites the baseline with the observed values and a
        // re-check against the refreshed numbers passes
        run(&baseline, &dir, 0.10, true, false).unwrap();
        let refreshed = Json::from_file(&baseline).unwrap();
        assert_eq!(
            refreshed.get("alpha").unwrap().get("tokens_per_s").unwrap().as_f64(),
            Some(50.0)
        );
        assert_eq!(
            refreshed.get("beta").unwrap().get("tokens_per_s").unwrap().as_f64(),
            Some(50.0)
        );
        run(&baseline, &dir, 0.10, false, false).unwrap();
        // a summary with NO baseline entry fails the gate (no silent
        // exclusion of new benches) and --update adopts it
        std::fs::write(
            dir.join("BENCH_gamma.json"),
            r#"{"bench": "gamma", "tokens_per_s": 7.5, "tokens_per_call": 1.1, "accept_rate": 0.1}"#,
        )
        .unwrap();
        let err = run(&baseline, &dir, 0.10, false, false).unwrap_err().to_string();
        assert!(err.contains("gamma"), "error must name the stray summary: {err}");
        run(&baseline, &dir, 0.10, true, false).unwrap();
        let adopted = Json::from_file(&baseline).unwrap();
        assert_eq!(
            adopted.get("gamma").unwrap().get("tokens_per_s").unwrap().as_f64(),
            Some(7.5)
        );
        run(&baseline, &dir, 0.10, false, false).unwrap();
        // a missing summary is an error, not a silent pass
        std::fs::remove_file(dir.join("BENCH_beta.json")).unwrap();
        assert!(run(&baseline, &dir, 0.10, false, false).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wall_clock_regression_is_advisory_not_fatal() {
        let dir =
            std::env::temp_dir().join(format!("ngrammys-wallclock-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let baseline = dir.join("baseline.json");
        // both entries have recorded numbers and both regressed hard; only
        // the non-wall-clock one may fail the gate
        std::fs::write(
            &baseline,
            r#"{"fast": {"tokens_per_s": 100.0, "wall_clock": true},
                "det": {"tokens_per_s": 100.0}}"#,
        )
        .unwrap();
        for name in ["fast", "det"] {
            std::fs::write(
                dir.join(format!("BENCH_{name}.json")),
                r#"{"tokens_per_s": 10.0, "tokens_per_call": 2.0, "accept_rate": 0.5}"#,
            )
            .unwrap();
        }
        let err = run(&baseline, &dir, 0.10, false, false).unwrap_err().to_string();
        assert!(err.contains("det"), "deterministic entry must fail: {err}");
        assert!(!err.contains("fast"), "wall-clock entry must be advisory: {err}");
        // with only the wall-clock entry regressed, the gate passes
        std::fs::write(
            dir.join("BENCH_det.json"),
            r#"{"tokens_per_s": 100.0, "tokens_per_call": 2.0, "accept_rate": 0.5}"#,
        )
        .unwrap();
        run(&baseline, &dir, 0.10, false, false).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn strict_baseline_fails_on_null_non_wall_clock_entries() {
        let dir =
            std::env::temp_dir().join(format!("ngrammys-strict-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let baseline = dir.join("baseline.json");
        // alpha: gated; beta: null and NOT wall-clock; gamma: null but
        // wall-clock-exempt
        std::fs::write(
            &baseline,
            r#"{"alpha": {"tokens_per_s": 100.0},
                "beta": {"tokens_per_s": null},
                "gamma": {"tokens_per_s": null, "wall_clock": true}}"#,
        )
        .unwrap();
        for name in ["alpha", "beta", "gamma"] {
            std::fs::write(
                dir.join(format!("BENCH_{name}.json")),
                r#"{"tokens_per_s": 100.0, "tokens_per_call": 2.0, "accept_rate": 0.5}"#,
            )
            .unwrap();
        }
        // non-strict: beta + gamma bootstrap, gate passes
        run(&baseline, &dir, 0.10, false, false).unwrap();
        // strict: beta (null, not wall-clock) fails the gate by name;
        // gamma's wall_clock marker exempts it
        let err = run(&baseline, &dir, 0.10, false, true).unwrap_err().to_string();
        assert!(err.contains("strict-baseline"), "must name the flag: {err}");
        assert!(err.contains("beta"), "must name the null entry: {err}");
        assert!(!err.contains("gamma"), "wall-clock entries are exempt: {err}");
        // --update records beta's number AND keeps gamma's wall_clock
        // marker; strict then passes
        run(&baseline, &dir, 0.10, true, false).unwrap();
        let refreshed = Json::from_file(&baseline).unwrap();
        assert_eq!(
            refreshed.get("beta").unwrap().get("tokens_per_s").unwrap().as_f64(),
            Some(100.0)
        );
        assert_eq!(
            refreshed.get("gamma").unwrap().get("wall_clock").and_then(|v| v.as_bool()),
            Some(true)
        );
        run(&baseline, &dir, 0.10, false, true).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
