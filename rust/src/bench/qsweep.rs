//! §5 footnote 4: query length q in {1, 2, 3} for the context n-gram —
//! the paper observed q > 1 degrading both tokens/call and speedup across
//! all datasets/models. Plus the strategy-allocation ablation the paper's
//! §5.2 calls out as future work (`ablation-alloc`).

use std::sync::Arc;

use anyhow::Result;

use crate::config::EngineConfig;
use crate::draft::mixed::AllocationPolicy;
use crate::draft::MixedStrategy;
use crate::engine::SpecDecoder;
use crate::scheduler::StrategyName;
use crate::util::json::Json;
use crate::workload::TASKS;

/// Print the footnote-4 context query-length sweep.
pub fn run_qsweep(ctx: &super::BenchCtx, n_prompts: usize, max_new: usize) -> Result<()> {
    let (k, w) = (10usize, 10usize);
    println!("== q-sweep: context query length (mixed, k={k}, w={w}, model '{}') ==\n",
             ctx.model);
    println!("{:<8} {:>10} {:>10} {:>10}", "q", "chat", "code", "math");
    let mut rows = Vec::new();
    for q in [1usize, 2, 3] {
        let mut vals = Vec::new();
        for task in TASKS {
            let prompts = ctx.prompts(task, n_prompts, 128)?;
            let c = super::run_cell(ctx, StrategyName::Mixed, &prompts, k, w, q, max_new)?;
            vals.push(c.tokens_per_call);
        }
        println!("q={q:<6} {:>10.2} {:>10.2} {:>10.2}", vals[0], vals[1], vals[2]);
        rows.push(Json::obj(vec![
            ("q", Json::Num(q as f64)),
            ("tokens_per_call", Json::Arr(vals.into_iter().map(Json::Num).collect())),
        ]));
    }
    super::write_json(
        "qsweep",
        &Json::obj(vec![
            ("bench", Json::Str("qsweep".into())),
            ("model", Json::Str(ctx.model.clone())),
            ("rows", Json::Arr(rows)),
        ]),
    )
}

/// Ablation beyond the paper: allocation policy between context and bigram
/// rows (§5.2 suggests smarter allocation could win — quantify it).
pub fn run_alloc_ablation(ctx: &super::BenchCtx, n_prompts: usize, max_new: usize) -> Result<()> {
    let (k, w) = (10usize, 10usize);
    println!("== allocation-policy ablation (k={k}, w={w}, model '{}') ==\n", ctx.model);
    println!("{:<22} {:>10} {:>10} {:>10}", "policy", "chat", "code", "math");
    let policies: [(&str, AllocationPolicy); 4] = [
        ("context-first (paper)", AllocationPolicy::ContextFirst),
        ("bigram-first", AllocationPolicy::BigramFirst),
        ("fixed-split ctx=3", AllocationPolicy::FixedSplit { ctx: 3 }),
        ("fixed-split ctx=7", AllocationPolicy::FixedSplit { ctx: 7 }),
    ];
    let mut rows = Vec::new();
    for (label, policy) in policies {
        let mut vals = Vec::new();
        for task in TASKS {
            let prompts = ctx.prompts(task, n_prompts, 128)?;
            let mut tot_tokens = 0usize;
            let mut tot_calls = 0usize;
            for p in &prompts {
                let strat = Box::new(MixedStrategy::with_policy(
                    Arc::clone(&ctx.tables), 1, policy));
                let mut dec = SpecDecoder::new(
                    &ctx.runtime,
                    strat,
                    EngineConfig { k, w, q: 1, max_new_tokens: max_new },
                );
                let r = dec.generate(&p.tokens)?;
                tot_tokens += r.tokens.len();
                tot_calls += r.calls;
            }
            vals.push(tot_tokens as f64 / tot_calls.max(1) as f64);
        }
        println!("{label:<22} {:>10.2} {:>10.2} {:>10.2}", vals[0], vals[1], vals[2]);
        rows.push(Json::obj(vec![
            ("policy", Json::Str(label.into())),
            ("tokens_per_call", Json::Arr(vals.into_iter().map(Json::Num).collect())),
        ]));
    }
    super::write_json(
        "ablation_alloc",
        &Json::obj(vec![
            ("bench", Json::Str("ablation-alloc".into())),
            ("model", Json::Str(ctx.model.clone())),
            ("rows", Json::Arr(rows)),
        ]),
    )
}

/// Ablation (paper footnote 5): the same acceptance trace yields different
/// wall-time speedups on hardware with different OTB thresholds — the
/// paper's caution about comparing against Lookahead (higher-OTB GPU) and
/// REST (lower-OTB GPU) numbers, made quantitative.
pub fn run_hardware_ablation(ctx: &super::BenchCtx, n_prompts: usize,
                             max_new: usize) -> Result<()> {
    use crate::costmodel::{CostModel, Hardware, TxDims};
    let (k, w) = (10usize, 10usize);
    println!("== hardware-OTB ablation (mixed, k={k}, w={w}, model '{}') ==\n",
             ctx.model);
    let dims = TxDims::for_analog(&ctx.model).unwrap_or_else(TxDims::mistral_7b);
    let hws = [Hardware::low_otb(), Hardware::a100_40gb(), Hardware::high_otb()];
    println!("{:<22} {:>10} {:>10} {:>10} {:>10}", "hardware", "OTB thr",
             "chat", "code", "math");
    let mut rows = Vec::new();
    for hw in hws {
        let cm = CostModel::new(hw.clone(), dims.clone());
        let mut vals = Vec::new();
        for task in TASKS {
            let prompts = ctx.prompts(task, n_prompts, 128)?;
            let cell = super::run_cell(ctx, StrategyName::Mixed, &prompts, k, w, 1, max_new)?;
            let mut sims = Vec::new();
            for r in &cell.results {
                let calls: Vec<(usize, usize, usize)> =
                    r.traces.iter().map(|t| (t.k, t.w, t.ctx_len)).collect();
                if !calls.is_empty() {
                    sims.push(cm.simulate_speedup(&calls, r.tokens.len().saturating_sub(1)));
                }
            }
            vals.push(crate::util::stats::mean(&sims));
        }
        println!("{:<22} {:>10.0} {:>10.2} {:>10.2} {:>10.2}",
                 hw.name, hw.otb_threshold(), vals[0], vals[1], vals[2]);
        rows.push(Json::obj(vec![
            ("hardware", Json::Str(hw.name.into())),
            ("otb_threshold", Json::Num(hw.otb_threshold())),
            ("sim_speedup", Json::Arr(vals.into_iter().map(Json::Num).collect())),
        ]));
    }
    println!("\nhigher OTB threshold -> verification stays memory-bound longer");
    println!("-> bigger speedup from the same acceptance trace (footnote 5).");
    super::write_json(
        "ablation_hardware",
        &Json::obj(vec![
            ("bench", Json::Str("ablation-hardware".into())),
            ("model", Json::Str(ctx.model.clone())),
            ("rows", Json::Arr(rows)),
        ]),
    )
}
