//! Figure 4 ablations (paper §5.2): for the mixed strategy at (10, 10) on
//! the 7B-analog model, across the three tasks —
//!   top:    distribution of acceptance length per call
//!   middle: distribution of the winning row's rank within its strategy
//!   bottom: per-call allocation of batch rows to each strategy

use anyhow::Result;

use crate::draft::StrategyKind;
use crate::scheduler::StrategyName;
use crate::util::json::Json;
use crate::util::stats::Histogram;
use crate::workload::TASKS;

/// Print the SS5.2 ablation distributions for the mixed strategy.
pub fn run(ctx: &super::BenchCtx, n_prompts: usize, max_new: usize) -> Result<()> {
    let (k, w) = (10usize, 10usize);
    println!("== Figure 4 ablations: mixed strategy at (k, w) = ({k}, {w}), model '{}' ==\n",
             ctx.model);

    let mut out_tasks = Vec::new();
    for task in TASKS {
        let prompts = ctx.prompts(task, n_prompts, 128)?;
        let cell = super::run_cell(ctx, StrategyName::Mixed, &prompts, k, w, 1, max_new)?;

        let mut accept_ctx = Histogram::new(w + 1);
        let mut accept_big = Histogram::new(w + 1);
        let mut rank_ctx = Histogram::new(k);
        let mut rank_big = Histogram::new(k);
        let mut alloc_ctx = Histogram::new(k + 1);
        let mut alloc_big = Histogram::new(k + 1);
        for r in &cell.results {
            for t in &r.traces {
                match t.kind {
                    StrategyKind::ContextNgram => {
                        accept_ctx.record(t.accepted);
                        rank_ctx.record(t.rank);
                    }
                    StrategyKind::ExtendedBigram | StrategyKind::ModelBigram => {
                        accept_big.record(t.accepted);
                        rank_big.record(t.rank);
                    }
                    _ => {}
                }
                alloc_ctx.record(t.alloc_context);
                alloc_big.record(t.alloc_bigram);
            }
        }

        println!("-- {task} (tok/call {:.2}) --", cell.tokens_per_call);
        print_hist("accept-len | context-ngram", &accept_ctx);
        print_hist("accept-len | ext-bigram   ", &accept_big);
        print_hist("win-rank   | context-ngram", &rank_ctx);
        print_hist("win-rank   | ext-bigram   ", &rank_big);
        print_hist("rows/call  | context-ngram", &alloc_ctx);
        print_hist("rows/call  | ext-bigram   ", &alloc_big);
        println!();

        let h2j = |h: &Histogram| {
            Json::Arr(h.pmf().into_iter().map(Json::Num).collect())
        };
        out_tasks.push(Json::obj(vec![
            ("task", Json::Str(task.into())),
            ("tokens_per_call", Json::Num(cell.tokens_per_call)),
            ("accept_len_context", h2j(&accept_ctx)),
            ("accept_len_bigram", h2j(&accept_big)),
            ("win_rank_context", h2j(&rank_ctx)),
            ("win_rank_bigram", h2j(&rank_big)),
            ("alloc_context", h2j(&alloc_ctx)),
            ("alloc_bigram", h2j(&alloc_big)),
        ]));
    }
    super::write_json(
        "fig4",
        &Json::obj(vec![
            ("figure", Json::Str("fig4-ablations".into())),
            ("model", Json::Str(ctx.model.clone())),
            ("k", Json::Num(10.0)),
            ("w", Json::Num(10.0)),
            ("tasks", Json::Arr(out_tasks)),
        ]),
    )
}

fn print_hist(label: &str, h: &Histogram) {
    let pmf = h.pmf();
    let bars: String = pmf
        .iter()
        .map(|&p| {
            let lvl = (p * 8.0).round() as usize;
            char::from_u32(0x2581 + lvl.clamp(0, 7) as u32).unwrap()
        })
        .collect();
    println!("  {label}  n={:<5} mean={:<5.2} {bars}", h.count, h.mean());
}
