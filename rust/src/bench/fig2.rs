//! Figure 2: tokens per call as a function of top-k, for the model-derived
//! unigram and bigram (w=1) and the extended bigram at w in {2, 3}.
//! Paper setup: first 50 examples of MT-Bench and HumanEval, 7B model.
//! Here: chat + code tasks, `base` nano model (Mistral-7B analog).

use anyhow::Result;

use crate::scheduler::StrategyName;
use crate::util::json::Json;

/// Print tokens/call vs top-k for the model-derived strategies.
pub fn run(ctx: &super::BenchCtx, n_prompts: usize, max_new: usize) -> Result<()> {
    let ks = [1usize, 2, 5, 10, 15, 20, 25];
    println!("== Figure 2: tokens/call vs top-k (model '{}') ==\n", ctx.model);

    let mut out_tasks = Vec::new();
    for task in ["chat", "code"] {
        let prompts = ctx.prompts(task, n_prompts, 128)?;
        println!("-- {task} ({} prompts) --", prompts.len());
        println!("{:<18} {}", "strategy", ks.map(|k| format!("k={k:<5}")).join(""));

        let mut series = Vec::new();
        for (label, strategy, w) in [
            ("unigram (w=1)", StrategyName::Unigram, 1),
            ("bigram (w=1)", StrategyName::Bigram, 1),
            ("ext-bigram (w=2)", StrategyName::ExtBigram, 2),
            ("ext-bigram (w=3)", StrategyName::ExtBigram, 3),
        ] {
            let mut row = format!("{label:<18} ");
            let mut vals = Vec::new();
            for &k in &ks {
                let cell = super::run_cell(ctx, strategy, &prompts, k, w, 1, max_new)?;
                row.push_str(&format!("{:<7.2}", cell.tokens_per_call));
                vals.push(Json::Num(cell.tokens_per_call));
            }
            println!("{row}");
            series.push(Json::obj(vec![
                ("label", Json::Str(label.into())),
                ("w", Json::Num(w as f64)),
                ("tokens_per_call", Json::Arr(vals)),
            ]));
        }
        println!();
        out_tasks.push(Json::obj(vec![
            ("task", Json::Str(task.into())),
            ("ks", Json::Arr(ks.iter().map(|&k| Json::Num(k as f64)).collect())),
            ("series", Json::Arr(series)),
        ]));
    }
    super::write_json(
        "fig2",
        &Json::obj(vec![
            ("figure", Json::Str("fig2-topk-tokens-per-call".into())),
            ("model", Json::Str(ctx.model.clone())),
            ("tasks", Json::Arr(out_tasks)),
        ]),
    )
}
