//! Elastic-serving bench: demand-driven lane autoscaling + online derived
//! row budgets + cost-aware admission ordering, against every static
//! `--batch N` configuration an operator could have picked.
//!
//! All runs serve the SAME request set (bursty speculative traffic) with
//! the SAME draft policy through the same engine code; they differ ONLY
//! in the three knobs the elastic scheduler closes automatically:
//! lane count (static N vs autoscaled within a cap), row budget (none vs
//! cost-model knee), and admission order (FIFO vs expected
//! tokens-per-cost). The headline is cost-model-simulated aggregate
//! tokens/sec at paper scale — the same substitution the rest of the
//! bench suite uses (real acceptance traces, simulated wall-times) — and
//! the run FAILS if elastic does not at least match the best static
//! configuration, which is the PR's acceptance bar.

use std::collections::VecDeque;

use anyhow::{ensure, Result};

use crate::config::EngineConfig;
use crate::engine::{AutoBudget, BatchedEngine, SeqId};
use crate::scheduler::{
    make_strategy, request_score, AdmissionQueue, AutoscaleConfig, Autoscaler, Demand,
    StrategyName,
};
use crate::tokenizer::TokenId;
use crate::trace::report::TraceSummary;
use crate::trace::{FlightRecorder, TraceEvent, DEFAULT_RING_CAPACITY};
use crate::util::json::Json;
use crate::workload::TASKS;

/// Static lane counts the elastic run is compared against; the elastic
/// cap is the largest of these.
pub const STATIC_CAPS: [usize; 3] = [2, 4, 8];

/// One request of the bench workload.
struct Req {
    prompt: Vec<TokenId>,
    engine: EngineConfig,
    strategy: StrategyName,
    /// engine step at which this request becomes visible to the scheduler
    arrives_at: u64,
}

/// Aggregates of one serving run.
struct RunOut {
    /// decode tokens (excluding the prefill-emitted first token)
    tokens: usize,
    calls: usize,
    /// simulated packed-call seconds at paper scale
    sim_s: f64,
    max_lanes_seen: usize,
    scale_events: (u64, u64),
    reorders: u64,
    /// engine steps driven to serve the whole request set
    steps: u64,
    /// per-request output streams, in request order
    streams: Vec<Vec<TokenId>>,
}

impl RunOut {
    fn sim_tps(&self) -> f64 {
        self.tokens as f64 / self.sim_s.max(1e-12)
    }
}

/// Run the elastic-vs-static serving comparison; fails unless elastic
/// throughput matches or beats the best static lane count.
pub fn run(
    ctx: &super::BenchCtx,
    n_prompts: usize,
    max_new: usize,
    caps: &[usize],
    smoke: bool,
) -> Result<()> {
    let (n_prompts, max_new) = if smoke { (2, 16) } else { (n_prompts, max_new) };
    let cap = caps.iter().copied().max().unwrap_or(8).max(2);

    // Burst workload: speculative requests arriving in waves that let the
    // pool drain between them (scale-down events). All requests share the
    // paper-default (10, 10) shape — a w=0 request would drag every
    // packed group to the common depth 0 in BOTH modes — but admission
    // scores still differ (longer prompts cost more on the cost model),
    // so the ordering policy has real decisions to make.
    let mut prompts = Vec::new();
    for task in TASKS {
        prompts.extend(ctx.prompts(task, n_prompts.div_ceil(TASKS.len()).max(2), 96)?);
    }
    let burst = cap.div_ceil(2).max(2);
    let gap = (max_new as u64 / 2).max(4);
    let reqs: Vec<Req> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Req {
            prompt: p.tokens.clone(),
            engine: EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: max_new },
            strategy: StrategyName::Mixed,
            arrives_at: (i / burst) as u64 * gap,
        })
        .collect();

    println!(
        "== elastic serving vs static lane pools (model '{}', {} requests x {} tokens, \
         bursts of {burst} every {gap} steps) ==\n",
        ctx.model,
        reqs.len(),
        max_new
    );
    println!(
        "{:<16} {:>9} {:>7} {:>10} {:>12} {:>9}",
        "config", "tok/call", "calls", "max lanes", "sim tok/s", "reorders"
    );

    let mut rows = Vec::new();
    let mut best_static = f64::NEG_INFINITY;
    let mut best_static_cap = 0usize;
    let mut static_streams: Vec<Vec<Vec<TokenId>>> = Vec::new();
    let mut scenario_steps: Vec<(String, Json)> = Vec::new();
    for &n in caps {
        let out = drive(ctx, &reqs, n, false, None)?;
        scenario_steps.push((format!("static-{n}"), Json::Num(out.steps as f64)));
        println!(
            "{:<16} {:>9.2} {:>7} {:>10} {:>12.1} {:>9}",
            format!("static --batch {n}"),
            out.tokens as f64 / out.calls.max(1) as f64,
            out.calls,
            out.max_lanes_seen,
            out.sim_tps(),
            out.reorders,
        );
        if out.sim_tps() > best_static {
            best_static = out.sim_tps();
            best_static_cap = n;
        }
        rows.push(Json::obj(vec![
            ("config", Json::Str(format!("static-{n}"))),
            ("sim_tokens_per_s", Json::Num(out.sim_tps())),
            ("tokens_per_call", Json::Num(out.tokens as f64 / out.calls.max(1) as f64)),
            ("max_lanes", Json::Num(out.max_lanes_seen as f64)),
        ]));
        static_streams.push(out.streams);
    }

    // the elastic run carries a flight recorder so the CI summary can say
    // where its steps' wall-clock went (per-phase totals)
    let rec = FlightRecorder::standalone(0, DEFAULT_RING_CAPACITY);
    let elastic = drive(ctx, &reqs, cap, true, Some(&rec))?;
    scenario_steps.push((format!("elastic-cap-{cap}"), Json::Num(elastic.steps as f64)));
    println!(
        "{:<16} {:>9.2} {:>7} {:>10} {:>12.1} {:>9}",
        format!("elastic cap {cap}"),
        elastic.tokens as f64 / elastic.calls.max(1) as f64,
        elastic.calls,
        elastic.max_lanes_seen,
        elastic.sim_tps(),
        elastic.reorders,
    );
    let (ups, downs) = elastic.scale_events;
    println!(
        "\nelastic lane trajectory: {ups} scale-ups, {downs} scale-downs, \
         peak {} of cap {cap}",
        elastic.max_lanes_seen
    );

    // Losslessness across every configuration: identical streams.
    for (i, s) in static_streams.iter().enumerate() {
        ensure!(
            s == &elastic.streams,
            "static --batch {} and elastic produced different streams",
            caps[i]
        );
    }

    println!(
        "\nbest static: --batch {best_static_cap} at {best_static:.1} sim tok/s; \
         elastic {}: {:.1} sim tok/s",
        if elastic.sim_tps() >= best_static { "MATCHES/BEATS it" } else { "BELOW it" },
        elastic.sim_tps(),
    );
    ensure!(
        elastic.sim_tps() >= best_static,
        "elastic throughput {:.1} below best static {best_static:.1} (--batch \
         {best_static_cap}) — the autoscaler/budget is mis-tuned",
        elastic.sim_tps()
    );

    rows.push(Json::obj(vec![
        ("config", Json::Str(format!("elastic-cap-{cap}"))),
        ("sim_tokens_per_s", Json::Num(elastic.sim_tps())),
        ("tokens_per_call", Json::Num(elastic.tokens as f64 / elastic.calls.max(1) as f64)),
        ("max_lanes", Json::Num(elastic.max_lanes_seen as f64)),
        ("scale_ups", Json::Num(ups as f64)),
        ("scale_downs", Json::Num(downs as f64)),
        ("admission_reorders", Json::Num(elastic.reorders as f64)),
    ]));
    super::write_json(
        &format!("elastic_{}", ctx.model),
        &Json::obj(vec![
            ("bench", Json::Str("elastic-serving".into())),
            ("model", Json::Str(ctx.model.clone())),
            ("max_new", Json::Num(max_new as f64)),
            ("n_requests", Json::Num(reqs.len() as f64)),
            ("best_static_cap", Json::Num(best_static_cap as f64)),
            ("best_static_sim_tokens_per_s", Json::Num(best_static)),
            ("rows", Json::Arr(rows)),
        ]),
    )?;
    // the CI bench-regression gate compares this summary against the
    // committed benches/baseline.json (`ngrammys ci-bench-check`); the
    // phase totals and step counts ride along as ungated extra fields
    let steps: Vec<TraceEvent> =
        rec.snapshot(DEFAULT_RING_CAPACITY).into_iter().map(TraceEvent::Step).collect();
    super::write_bench_summary_with(
        "elastic",
        elastic.sim_tps(),
        elastic.tokens as f64 / elastic.calls.max(1) as f64,
        super::accept_rate(elastic.tokens, elastic.calls),
        vec![
            ("phases", TraceSummary::from_events(&steps).phases_json()),
            ("scenario_steps", Json::Obj(scenario_steps)),
        ],
    )
}

/// Serve `reqs` to completion through one engine: static mode pins
/// `lanes` lanes, FIFO admission and no budget (the pre-elastic
/// scheduler); elastic mode starts at one lane and lets the autoscaler,
/// the derived budget and the admission scorer run — the same loop the
/// serving scheduler uses, minus the channels.
fn drive(
    ctx: &super::BenchCtx,
    reqs: &[Req],
    lanes: usize,
    elastic: bool,
    recorder: Option<&std::sync::Arc<FlightRecorder>>,
) -> Result<RunOut> {
    let cm = ctx.cost_model();

    let mut eng = BatchedEngine::new(&ctx.runtime, if elastic { 1 } else { lanes });
    eng.collect_traces = true;
    eng.recorder = recorder.cloned();
    if elastic {
        eng.auto_budget = Some(AutoBudget::new(ctx.cost_model()));
    }
    let mut scaler = Autoscaler::new(AutoscaleConfig {
        min_lanes: 1,
        max_lanes: lanes,
        down_after_steps: 2,
    });

    let mut arrivals: VecDeque<usize> = (0..reqs.len()).collect();
    let mut pending: AdmissionQueue<usize> = AdmissionQueue::new();
    let mut by_id: Vec<(SeqId, usize)> = Vec::new();
    let mut streams: Vec<Vec<TokenId>> = vec![Vec::new(); reqs.len()];
    let mut out = RunOut {
        tokens: 0,
        calls: 0,
        sim_s: 0.0,
        max_lanes_seen: if elastic { 1 } else { lanes },
        scale_events: (0, 0),
        reorders: 0,
        steps: 0,
        streams: Vec::new(),
    };
    let mut done = 0usize;
    let mut step: u64 = 0;
    while done < reqs.len() {
        // requests whose arrival step has come enter the admission queue
        while let Some(&i) = arrivals.front() {
            if reqs[i].arrives_at > step {
                break;
            }
            arrivals.pop_front();
            let score = if elastic {
                request_score(&cm, 1.5, reqs[i].strategy, &reqs[i].engine, reqs[i].prompt.len())
            } else {
                0.0 // uniform score = FIFO
            };
            pending.push(i, score);
        }
        // idle with future arrivals: fast-forward to the next burst
        if eng.active() == 0 && pending.is_empty() {
            if let Some(&i) = arrivals.front() {
                step = reqs[i].arrives_at;
                continue;
            }
        }
        if elastic {
            let target = scaler.target_lanes(&Demand {
                queue_depth: pending.len(),
                active: eng.active(),
                lanes: eng.capacity(),
                mean_heat: eng.mean_heat(),
            });
            let achieved = eng.set_capacity(target);
            out.max_lanes_seen = out.max_lanes_seen.max(achieved);
        }
        while eng.has_capacity() {
            let Some(i) = pending.pop_best() else { break };
            let r = &reqs[i];
            // SAME draft policy in every mode (no adaptive controller):
            // the comparison must isolate the three elasticity knobs, not
            // confound them with a different drafting strategy. Without
            // controllers mean_heat is None and the autoscaler runs on
            // queue depth alone — its documented cold fallback.
            let strat = make_strategy(r.strategy, &ctx.tables, r.engine.q);
            let id = eng.admit(&r.prompt, strat, r.engine.clone())?;
            by_id.push((id, i));
        }
        for (id, r) in eng.step()? {
            let i = by_id
                .iter()
                .find(|(sid, _)| *sid == id)
                .map(|&(_, i)| i)
                .expect("engine returned unknown sequence");
            out.tokens += r.tokens.len().saturating_sub(1);
            out.calls += r.calls;
            streams[i] = r.tokens;
            done += 1;
        }
        step += 1;
    }
    out.sim_s = eng
        .packed_traces
        .iter()
        .map(|t| cm.call_time(t.rows, t.w + 1, t.max_ctx))
        .sum();
    out.scale_events = scaler.events();
    out.reorders = pending.reorders();
    out.steps = eng.steps_done();
    out.streams = streams;
    Ok(out)
}
