//! Bench harness: one target per table/figure of the paper's evaluation
//! (see DESIGN.md §Per-experiment-index). Invoked via `ngrammys bench
//! <target>`; every target prints the same rows/series the paper reports
//! and writes machine-readable JSON under `bench_out/`.
//!
//! Metrics:
//! - tokens/call — REAL measurement on the trained nano models.
//! - speedup(sim) — the paper's wall-time column, reproduced by combining
//!   each run's real call trace with the A100 cost model at the paper's
//!   model scale (CPU PJRT cannot show the §3 phase transition).
//! - speedup(cpu) — honest measured wall-time ratio on this host's CPU.

pub mod adaptive;
pub mod batched;
pub mod check;
pub mod draft;
pub mod elastic;
pub mod fig1;
pub mod fig2;
pub mod fig4;
pub mod grid;
pub mod pool;
pub mod prefix;
pub mod qsweep;
pub mod serve;
pub mod table1;
pub mod tracecmd;
pub mod tree;

use std::sync::Arc;

use anyhow::Result;

use crate::config::{EngineConfig, Manifest};
use crate::costmodel::CostModel;
use crate::draft::NgramTables;
use crate::engine::{GenResult, SpecDecoder};
use crate::runtime::ModelRuntime;
use crate::scheduler::{make_strategy, StrategyName};
use crate::tokenizer::BpeTokenizer;
use crate::util::stats;
use crate::workload::{build_prompts, load_examples, Prompt};

/// Everything a bench target needs for one model.
pub struct BenchCtx {
    /// loaded manifest
    pub manifest: Manifest,
    /// model name within the manifest
    pub model: String,
    /// loaded model runtime
    pub runtime: ModelRuntime,
    /// shared n-gram tables
    pub tables: Arc<NgramTables>,
    /// shared tokenizer
    pub tokenizer: Arc<BpeTokenizer>,
}

impl BenchCtx {
    /// Load everything a bench target needs for `model`.
    pub fn load(manifest: Manifest, model: &str) -> Result<BenchCtx> {
        let art = manifest.model(model)?.clone();
        let runtime = ModelRuntime::load(&art)?;
        let tables = Arc::new(NgramTables::load(&art)?);
        let tokenizer = Arc::new(BpeTokenizer::load(&manifest.tokenizer_path)?);
        Ok(BenchCtx { manifest, model: model.to_string(), runtime, tables, tokenizer })
    }

    /// Prompt prefixes from a task's eval corpus.
    pub fn prompts(&self, task: &str, n: usize, max_prompt: usize) -> Result<Vec<Prompt>> {
        let examples = load_examples(&self.manifest, task, n)?;
        Ok(build_prompts(&self.tokenizer, &examples, 0.4, max_prompt))
    }

    /// Cost model at the paper's scale for this nano model's analog.
    pub fn cost_model(&self) -> CostModel {
        CostModel::for_analog(&self.model)
    }
}

/// Aggregated measurements for one (strategy, k, w) cell over a prompt set.
#[derive(Debug, Clone)]
pub struct CellStats {
    /// the paper's acceptance metric over the cell
    pub tokens_per_call: f64,
    /// total generated tokens / total decode wall-time (CPU)
    pub cpu_tokens_per_s: f64,
    /// cost-model speedup vs greedy at paper scale (mean over prompts)
    pub sim_speedup: f64,
    /// std dev of the per-prompt simulated speedups
    pub sim_speedup_std: f64,
    /// tokens emitted across all prompts
    pub total_tokens: usize,
    /// verification calls across all prompts
    pub total_calls: usize,
    /// per-prompt raw results
    pub results: Vec<GenResult>,
}

/// Run one strategy/(k, w) over a prompt set, with traces for simulation.
pub fn run_cell(
    ctx: &BenchCtx,
    strategy: StrategyName,
    prompts: &[Prompt],
    k: usize,
    w: usize,
    q: usize,
    max_new: usize,
) -> Result<CellStats> {
    let cm = ctx.cost_model();
    let mut total_tokens = 0usize;
    let mut total_calls = 0usize;
    let mut decode_s = 0.0f64;
    let mut sims = Vec::new();
    let mut results = Vec::new();
    for p in prompts {
        let strat = make_strategy(strategy, &ctx.tables, q);
        let mut dec = SpecDecoder::new(
            &ctx.runtime,
            strat,
            EngineConfig { k, w, q, max_new_tokens: max_new },
        );
        dec.collect_traces = true;
        let r = dec.generate(&p.tokens)?;
        total_tokens += r.tokens.len();
        total_calls += r.calls;
        decode_s += r.decode_time.as_secs_f64();
        let calls: Vec<(usize, usize, usize)> =
            r.traces.iter().map(|t| (t.k, t.w, t.ctx_len)).collect();
        if !calls.is_empty() {
            // first token came from prefill on both sides of the ratio
            sims.push(cm.simulate_speedup(&calls, r.tokens.len().saturating_sub(1)));
        }
        results.push(r);
    }
    let decode_tokens = total_tokens.saturating_sub(prompts.len()); // minus prefill-emitted
    Ok(CellStats {
        tokens_per_call: if total_calls == 0 { 0.0 } else {
            decode_tokens as f64 / total_calls as f64
        },
        cpu_tokens_per_s: if decode_s == 0.0 { 0.0 } else {
            total_tokens as f64 / decode_s
        },
        sim_speedup: stats::mean(&sims),
        sim_speedup_std: stats::std_dev(&sims),
        total_tokens,
        total_calls,
        results,
    })
}

/// Write a bench artifact under bench_out/.
pub fn write_json(name: &str, json: &crate::util::json::Json) -> Result<()> {
    std::fs::create_dir_all("bench_out")?;
    let path = format!("bench_out/{name}.json");
    std::fs::write(&path, json.to_string_pretty())?;
    eprintln!("  -> wrote {path}");
    Ok(())
}

/// Write the machine-readable CI summary `bench_out/BENCH_<name>.json`
/// the bench-regression gate compares against `benches/baseline.json`
/// (`ngrammys ci-bench-check`). Every gated bench emits exactly these
/// three fields: cost-model throughput (the regression-gated headline),
/// tokens/call, and the accept rate (accepted draft tokens per decode
/// token — greedy decoding is exactly 0).
pub fn write_bench_summary(
    name: &str,
    tokens_per_s: f64,
    tokens_per_call: f64,
    accept_rate: f64,
) -> Result<()> {
    write_bench_summary_with(name, tokens_per_s, tokens_per_call, accept_rate, Vec::new())
}

/// [`write_bench_summary`] plus bench-specific fields appended after the
/// three gated ones — the smoke benches attach the flight recorder's
/// per-phase totals (`"phases"`) and per-scenario step counts
/// (`"scenario_steps"`) this way. `ci-bench-check` reads only
/// `tokens_per_s`, so extra fields can never move the regression gate.
pub fn write_bench_summary_with(
    name: &str,
    tokens_per_s: f64,
    tokens_per_call: f64,
    accept_rate: f64,
    extra: Vec<(&str, crate::util::json::Json)>,
) -> Result<()> {
    use crate::util::json::Json;
    let mut fields = vec![
        ("bench", Json::Str(name.into())),
        ("tokens_per_s", Json::Num(tokens_per_s)),
        ("tokens_per_call", Json::Num(tokens_per_call)),
        ("accept_rate", Json::Num(accept_rate)),
    ];
    fields.extend(extra);
    write_json(&format!("BENCH_{name}"), &Json::obj(fields))
}

/// Accept rate over a run: the share of decode tokens that came from
/// accepted draft rows (each verification call emits its accepted drafts
/// plus one bonus token, so greedy decoding is exactly 0).
pub fn accept_rate(decode_tokens: usize, calls: usize) -> f64 {
    if decode_tokens == 0 {
        0.0
    } else {
        decode_tokens.saturating_sub(calls) as f64 / decode_tokens as f64
    }
}

/// Render an ASCII heat-grid (rows = k values, cols = w values).
pub fn render_grid(
    title: &str,
    ks: &[usize],
    ws: &[usize],
    cell: impl Fn(usize, usize) -> f64,
) -> String {
    let mut s = format!("{title}\n      ");
    for w in ws {
        s.push_str(&format!("w={w:<5}"));
    }
    s.push('\n');
    for &k in ks {
        s.push_str(&format!("k={k:<4}"));
        for &w in ws {
            s.push_str(&format!("{:<7.2}", cell(k, w)));
        }
        s.push('\n');
    }
    s
}
