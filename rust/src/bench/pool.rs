//! Engine-pool bench: 1-engine vs N-engine cost-model throughput on a
//! bursty MIXED workload (greedy w = 0 alongside speculative requests).
//!
//! Both runs serve the SAME request trace through the same engine code
//! and the same depth-aware routing policy the serving pool uses; they
//! differ only in the engine cap. Each engine models its own device at
//! paper scale (engines run concurrently in production), so simulated
//! wall-clock is the BUSIEST engine's accumulated packed-call time, and
//! the headline is aggregate tokens/sec on the cost model. The run FAILS
//! unless the N-engine configuration at least matches 1-engine on the
//! bursty workload — the PR's acceptance bar — and byte-identity across
//! the two configurations is asserted on every stream.
//!
//! A second section compares private per-request draft caches against the
//! fleet-shared draft store (`--shared-draft fleet`) on SAME-task traffic
//! split across two engines, and FAILS unless shared mode strictly beats
//! private on speculative tokens/call at byte-identical streams.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::config::{EngineConfig, SessionCacheConfig};
use crate::costmodel::CostModel;
use crate::draft::{DraftStrategy, SharedDraftStore, SharedDraftStrategy};
use crate::engine::{AutoBudget, BatchedEngine, SeqId, SpecDecoder};
use crate::scheduler::pool::STARVATION_DEFERRALS;
use crate::scheduler::{
    make_strategy, make_strategy_with_cache, request_score, AdmissionQueue, DepthClass,
    EngineScaleConfig, EngineScaler, StrategyName,
};
use crate::tokenizer::TokenId;
use crate::trace::report::TraceSummary;
use crate::trace::{FlightRecorder, TraceEvent, DEFAULT_RING_CAPACITY};
use crate::util::json::Json;
use crate::workload::TASKS;

/// Engine cap of the N-engine run (vs the 1-engine baseline).
pub const ENGINE_CAP: usize = 4;

/// Per-engine lane cap of both runs.
const LANE_CAP: usize = 4;

/// One request of the bench workload.
struct Req {
    prompt: Vec<TokenId>,
    engine: EngineConfig,
    strategy: StrategyName,
    class: DepthClass,
    /// scheduler tick at which this request becomes visible
    arrives_at: u64,
}

/// One simulated engine: a real `BatchedEngine` whose packed calls are
/// priced on its OWN device clock.
struct SimEngine<'rt> {
    eng: BatchedEngine<'rt>,
    /// accumulated packed-call seconds on this engine's device
    busy_s: f64,
    /// packed traces already priced
    trace_mark: usize,
    /// resident (admitted, unfinished) request indexes
    resident: Vec<(SeqId, usize)>,
    greedy: usize,
    spec: usize,
}

impl<'rt> SimEngine<'rt> {
    fn can_take(&self) -> bool {
        self.resident.len() < LANE_CAP
    }

    fn compatible(&self, class: DepthClass) -> bool {
        match class {
            DepthClass::Greedy => self.spec == 0,
            DepthClass::Speculative => self.greedy == 0,
        }
    }
}

/// Aggregates of one pool run.
struct RunOut {
    tokens: usize,
    calls: usize,
    /// busiest engine's device time = simulated wall-clock
    wall_s: f64,
    peak_engines: usize,
    spawns: u64,
    retires: u64,
    fallbacks: u64,
    /// decode tokens / calls over SPECULATIVE requests only
    spec_tpc: f64,
    streams: Vec<Vec<TokenId>>,
    /// engine steps driven, summed over every engine (incl. retired ones)
    steps: u64,
}

impl RunOut {
    fn sim_tps(&self) -> f64 {
        self.tokens as f64 / self.wall_s.max(1e-12)
    }
}

/// Run the 1-engine vs N-engine comparison; fails unless N engines match
/// or beat one on cost-model throughput.
pub fn run(
    ctx: &super::BenchCtx,
    n_prompts: usize,
    max_new: usize,
    engine_cap: usize,
    smoke: bool,
) -> Result<()> {
    let (n_prompts, max_new) = if smoke { (2, 16) } else { (n_prompts, max_new) };
    let engine_cap = engine_cap.max(2);

    // Bursty mixed traffic: every third request is greedy (w = 0), the
    // rest speculate at the paper default (10, 10) — the regime where a
    // single shared engine used to collapse packed depth and where
    // depth-aware routing has real placements to choose.
    let mut prompts = Vec::new();
    for task in TASKS {
        prompts.extend(ctx.prompts(task, n_prompts.div_ceil(TASKS.len()).max(2), 96)?);
    }
    let burst = (engine_cap * LANE_CAP / 2).max(2);
    let gap = (max_new as u64 / 2).max(4);
    let reqs: Vec<Req> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let greedy = i % 3 == 2;
            let engine = if greedy {
                EngineConfig { k: 1, w: 0, q: 1, max_new_tokens: max_new }
            } else {
                EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: max_new }
            };
            let strategy = if greedy { StrategyName::None } else { StrategyName::Mixed };
            Req {
                prompt: p.tokens.clone(),
                class: DepthClass::of(strategy, &engine),
                engine,
                strategy,
                arrives_at: (i / burst) as u64 * gap,
            }
        })
        .collect();

    println!(
        "== engine pool: 1 vs {engine_cap} engines (model '{}', {} requests x {} tokens, \
         {} greedy / {} spec, bursts of {burst} every {gap} ticks, lane cap {LANE_CAP}) ==\n",
        ctx.model,
        reqs.len(),
        max_new,
        reqs.iter().filter(|r| r.class == DepthClass::Greedy).count(),
        reqs.iter().filter(|r| r.class == DepthClass::Speculative).count(),
    );
    println!(
        "{:<14} {:>9} {:>13} {:>7} {:>12} {:>13} {:>9}",
        "config", "tok/call", "spec tok/call", "calls", "sim tok/s", "spawn/retire", "fallbacks"
    );

    let one = drive(ctx, &reqs, 1, None)?;
    // the pooled run carries a flight recorder (shared across its
    // engines); byte-identity vs the untraced 1-engine run below doubles
    // as a tracing-perturbation check on this workload
    let rec = FlightRecorder::standalone(0, DEFAULT_RING_CAPACITY);
    let many = drive(ctx, &reqs, engine_cap, Some(&rec))?;
    let mut rows = Vec::new();
    for (label, out) in [("1 engine", &one), ("pool", &many)] {
        println!(
            "{:<14} {:>9.2} {:>13.2} {:>7} {:>12.1} {:>13} {:>9}",
            format!("{label} (peak {})", out.peak_engines),
            out.tokens as f64 / out.calls.max(1) as f64,
            out.spec_tpc,
            out.calls,
            out.sim_tps(),
            format!("{}/{}", out.spawns, out.retires),
            out.fallbacks,
        );
        rows.push(Json::obj(vec![
            ("config", Json::Str(label.to_string())),
            ("sim_tokens_per_s", Json::Num(out.sim_tps())),
            ("tokens_per_call", Json::Num(out.tokens as f64 / out.calls.max(1) as f64)),
            ("spec_tokens_per_call", Json::Num(out.spec_tpc)),
            ("peak_engines", Json::Num(out.peak_engines as f64)),
            ("spawns", Json::Num(out.spawns as f64)),
            ("retires", Json::Num(out.retires as f64)),
            ("routing_fallbacks", Json::Num(out.fallbacks as f64)),
        ]));
    }

    // Losslessness across engine counts: identical streams.
    ensure!(
        one.streams == many.streams,
        "1-engine and {engine_cap}-engine runs produced different streams"
    );
    println!(
        "\n{engine_cap}-engine pool {}: {:.1} vs {:.1} sim tok/s (1 engine)",
        if many.sim_tps() >= one.sim_tps() { "MATCHES/BEATS 1 engine" } else { "BELOW 1 engine" },
        many.sim_tps(),
        one.sim_tps(),
    );
    ensure!(
        many.sim_tps() >= one.sim_tps(),
        "pool throughput {:.1} below single-engine {:.1} — scale-out or routing is mis-tuned",
        many.sim_tps(),
        one.sim_tps()
    );

    // ---- cross-engine fleet sharing (`--shared-draft fleet`)
    let shared_json = shared_draft_section(ctx, max_new)?;

    super::write_json(
        &format!("pool_{}", ctx.model),
        &Json::obj(vec![
            ("bench", Json::Str("engine-pool".into())),
            ("model", Json::Str(ctx.model.clone())),
            ("max_new", Json::Num(max_new as f64)),
            ("n_requests", Json::Num(reqs.len() as f64)),
            ("engine_cap", Json::Num(engine_cap as f64)),
            ("rows", Json::Arr(rows)),
            ("shared_draft", shared_json),
        ]),
    )?;
    let steps: Vec<TraceEvent> =
        rec.snapshot(DEFAULT_RING_CAPACITY).into_iter().map(TraceEvent::Step).collect();
    let scenario_steps = vec![
        ("one-engine".to_string(), Json::Num(one.steps as f64)),
        (format!("pool-{engine_cap}"), Json::Num(many.steps as f64)),
    ];
    super::write_bench_summary_with(
        "pool",
        many.sim_tps(),
        many.tokens as f64 / many.calls.max(1) as f64,
        super::accept_rate(many.tokens, many.calls),
        vec![
            ("phases", TraceSummary::from_events(&steps).phases_json()),
            ("scenario_steps", Json::Obj(scenario_steps)),
        ],
    )
}

/// Serve `reqs` to completion on up to `engine_cap` simulated engines,
/// spawn/retire decided by the real [`EngineScaler`] and placement by the
/// pool's depth-aware routing policy (compatible engine first, any
/// engine after [`STARVATION_DEFERRALS`] deferred rounds).
fn drive(
    ctx: &super::BenchCtx,
    reqs: &[Req],
    engine_cap: usize,
    recorder: Option<&std::sync::Arc<FlightRecorder>>,
) -> Result<RunOut> {
    let cm = ctx.cost_model();
    let mk_engine = || {
        let mut eng = BatchedEngine::new(&ctx.runtime, 1);
        eng.collect_traces = true;
        eng.recorder = recorder.cloned();
        eng.auto_budget = Some(AutoBudget::new(ctx.cost_model()));
        SimEngine { eng, busy_s: 0.0, trace_mark: 0, resident: Vec::new(), greedy: 0, spec: 0 }
    };
    let mut engines: Vec<SimEngine> = vec![mk_engine()];
    let mut scaler = EngineScaler::new(EngineScaleConfig {
        min_engines: 1,
        max_engines: engine_cap,
        up_after_steps: 1,
        down_after_steps: 4,
    });

    let mut arrivals: VecDeque<usize> = (0..reqs.len()).collect();
    let mut pending: AdmissionQueue<(usize, u32)> = AdmissionQueue::new();
    let mut streams: Vec<Vec<TokenId>> = vec![Vec::new(); reqs.len()];
    let mut out = RunOut {
        tokens: 0,
        calls: 0,
        wall_s: 0.0,
        peak_engines: 1,
        spawns: 0,
        retires: 0,
        fallbacks: 0,
        spec_tpc: 0.0,
        streams: Vec::new(),
        steps: 0,
    };
    let mut spec_tokens = 0usize;
    let mut spec_calls = 0usize;
    // device clocks freed by retired engines: a respawn REUSES a freed
    // device (inherits its accumulated busy time), so wall-clock counts
    // at most `engine_cap` device slots — a retire/respawn cycle cannot
    // reset the busiest clock and flatter the pool
    let mut freed_clocks: Vec<f64> = Vec::new();
    let mut done = 0usize;
    let mut tick: u64 = 0;
    while done < reqs.len() {
        // requests whose arrival tick has come enter the admission queue
        while let Some(&i) = arrivals.front() {
            if reqs[i].arrives_at > tick {
                break;
            }
            arrivals.pop_front();
            let score = request_score(
                &cm,
                1.5,
                reqs[i].strategy,
                &reqs[i].engine,
                reqs[i].prompt.len(),
            );
            pending.push((i, 0), score);
        }
        // idle with future arrivals: fast-forward to the next burst
        let all_idle = engines.iter().all(|e| e.resident.is_empty());
        if all_idle && pending.is_empty() {
            if let Some(&i) = arrivals.front() {
                tick = reqs[i].arrives_at;
                continue;
            }
        }
        // engine-level scaling: spawn on pressure, retire an idle engine
        // on sustained quiet
        let held: usize = engines.iter().map(|e| e.resident.len()).sum();
        let target = scaler.target_engines(held + pending.len(), LANE_CAP, engines.len());
        if target > engines.len() {
            let mut se = mk_engine();
            se.busy_s = freed_clocks.pop().unwrap_or(0.0); // reuse a freed device
            engines.push(se);
            out.spawns += 1;
            out.peak_engines = out.peak_engines.max(engines.len());
        } else if target < engines.len() {
            if let Some(idx) = engines.iter().position(|e| e.resident.is_empty()) {
                let se = engines.remove(idx);
                freed_clocks.push(se.busy_s);
                out.steps += se.eng.steps_done();
                out.retires += 1;
            }
        }
        // depth-aware routing + admission (the sim admits directly: no
        // cross-thread backlog to model)
        let mut held_back: Vec<((usize, u32), f64, u64)> = Vec::new();
        while engines.iter().any(|e| e.can_take()) {
            let Some(((i, deferrals), score, seq)) = pending.pop_best_entry() else { break };
            let r = &reqs[i];
            let pick = engines
                .iter()
                .enumerate()
                .filter(|(_, e)| e.can_take() && e.compatible(r.class))
                .min_by_key(|(_, e)| e.resident.len())
                .map(|(j, _)| (j, false))
                .or_else(|| {
                    (deferrals >= STARVATION_DEFERRALS)
                        .then(|| {
                            engines
                                .iter()
                                .enumerate()
                                .filter(|(_, e)| e.can_take())
                                .min_by_key(|(_, e)| e.resident.len())
                                .map(|(j, _)| (j, true))
                        })
                        .flatten()
                });
            match pick {
                Some((j, fallback)) => {
                    if fallback {
                        out.fallbacks += 1;
                    }
                    let se = &mut engines[j];
                    if !se.eng.has_capacity() {
                        se.eng.set_capacity(se.eng.capacity() + 1);
                    }
                    let strat = make_strategy(r.strategy, &ctx.tables, r.engine.q);
                    let id = se.eng.admit(&r.prompt, strat, r.engine.clone())?;
                    se.resident.push((id, i));
                    match r.class {
                        DepthClass::Greedy => se.greedy += 1,
                        DepthClass::Speculative => se.spec += 1,
                    }
                }
                None => held_back.push(((i, deferrals + 1), score, seq)),
            }
        }
        for (item, score, seq) in held_back {
            pending.reinsert(item, score, seq);
        }
        // step every engine that has work, on its own device clock
        for se in engines.iter_mut() {
            if se.eng.active() == 0 {
                continue;
            }
            for (id, r) in se.eng.step()? {
                let pos = se
                    .resident
                    .iter()
                    .position(|&(sid, _)| sid == id)
                    .expect("engine returned unknown sequence");
                let (_, i) = se.resident.swap_remove(pos);
                match reqs[i].class {
                    DepthClass::Greedy => se.greedy -= 1,
                    DepthClass::Speculative => {
                        se.spec -= 1;
                        spec_tokens += r.tokens.len().saturating_sub(1);
                        spec_calls += r.calls;
                    }
                }
                out.tokens += r.tokens.len().saturating_sub(1);
                out.calls += r.calls;
                streams[i] = r.tokens;
                done += 1;
            }
            let new_busy: f64 = se.eng.packed_traces[se.trace_mark..]
                .iter()
                .map(|t| cm.call_time(t.rows, t.w + 1, t.max_ctx))
                .sum();
            se.trace_mark = se.eng.packed_traces.len();
            se.busy_s += new_busy;
        }
        tick += 1;
    }
    let freed_max = freed_clocks.iter().copied().fold(0.0f64, f64::max);
    out.wall_s = engines.iter().map(|e| e.busy_s).fold(freed_max, f64::max);
    out.spec_tpc = spec_tokens as f64 / spec_calls.max(1) as f64;
    out.streams = streams;
    out.steps += engines.iter().map(|e| e.eng.steps_done()).sum::<u64>();
    Ok(out)
}

/// Engines in the cross-engine sharing comparison. Requests alternate
/// between them, so a fleet-store hit on one engine almost always reads a
/// chain the OTHER engine's traffic published.
const SHARED_ENGINES: usize = 2;

/// How many times the same-task prompt set is replayed. Repetition is the
/// regime the store exists for: the first pass seeds the chains, later
/// passes harvest them.
const SHARED_REPS: usize = 3;

/// Serve `reqs` sequentially, round-robined across [`SHARED_ENGINES`]
/// per-engine hit sinks. Every request drafts with a FRESH session cache
/// (no private cross-request memory); with a store attached the strategy
/// is additionally wrapped over the fleet store, which is then the ONLY
/// cross-request channel. Returns (streams, decode tokens, verify calls).
fn shared_draft_run(
    ctx: &super::BenchCtx,
    reqs: &[(Vec<TokenId>, EngineConfig)],
    store: Option<&Arc<SharedDraftStore>>,
    sinks: &[Arc<AtomicU64>],
) -> Result<(Vec<Vec<TokenId>>, usize, usize)> {
    let cache = SessionCacheConfig::default();
    let mut streams = Vec::new();
    let (mut tokens, mut calls) = (0usize, 0usize);
    for (i, (prompt, engine)) in reqs.iter().enumerate() {
        let inner =
            make_strategy_with_cache(StrategyName::Session, &ctx.tables, engine.q, &cache);
        let strategy: Box<dyn DraftStrategy> = match store {
            Some(store) => Box::new(SharedDraftStrategy::new(
                inner,
                store.clone(),
                Some(sinks[i % sinks.len()].clone()),
            )),
            None => inner,
        };
        let mut dec = SpecDecoder::new(&ctx.runtime, strategy, engine.clone());
        let r = dec.generate(prompt)?;
        tokens += r.tokens.len().saturating_sub(1);
        calls += r.calls;
        streams.push(r.tokens);
        // dec drops here: the shared wrapper's Drop publishes its tail,
        // so the NEXT request sees this one's accepted tokens
    }
    Ok((streams, tokens, calls))
}

/// The cross-engine acceptance gate for `--shared-draft fleet`: same-task
/// traffic split across [`SHARED_ENGINES`] engines, served once with
/// private per-request caches and once over one fleet
/// [`SharedDraftStore`]. FAILS unless fleet mode strictly beats private
/// on tokens/call, every engine proposed shared rows, and the streams are
/// byte-identical (shared chains may only change which candidates are
/// proposed, never the accepted greedy stream).
fn shared_draft_section(ctx: &super::BenchCtx, max_new: usize) -> Result<Json> {
    let task = TASKS[0];
    let base = ctx.prompts(task, 3, 96)?;
    let mut reqs: Vec<(Vec<TokenId>, EngineConfig)> = Vec::new();
    for _ in 0..SHARED_REPS {
        for p in &base {
            reqs.push((
                p.tokens.clone(),
                EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: max_new },
            ));
        }
    }
    println!(
        "\n== cross-engine shared draft: private vs fleet (task '{task}', {} prompts x \
         {SHARED_REPS} reps over {SHARED_ENGINES} engines) ==",
        base.len(),
    );
    let sinks: Vec<Arc<AtomicU64>> =
        (0..SHARED_ENGINES).map(|_| Arc::new(AtomicU64::new(0))).collect();
    let (priv_streams, priv_tokens, priv_calls) = shared_draft_run(ctx, &reqs, None, &sinks)?;
    let store = Arc::new(SharedDraftStore::new(SHARED_ENGINES));
    let (fleet_streams, fleet_tokens, fleet_calls) =
        shared_draft_run(ctx, &reqs, Some(&store), &sinks)?;
    ensure!(
        priv_streams == fleet_streams,
        "fleet-shared draft store changed an output stream — shared chains may only change \
         which candidates are proposed, never the accepted greedy stream"
    );
    let priv_tpc = priv_tokens as f64 / priv_calls.max(1) as f64;
    let fleet_tpc = fleet_tokens as f64 / fleet_calls.max(1) as f64;
    let hits: Vec<u64> = sinks.iter().map(|s| s.load(Ordering::Relaxed)).collect();
    println!("{:<10} {:>7} {:>13}", "config", "calls", "spec tok/call");
    println!("{:<10} {:>7} {:>13.2}", "private", priv_calls, priv_tpc);
    println!("{:<10} {:>7} {:>13.2}", "fleet", fleet_calls, fleet_tpc);
    println!(
        "fleet store: hits {} (per-engine {}), misses {}, publishes {}",
        store.hits(),
        hits.iter().map(|h| h.to_string()).collect::<Vec<_>>().join("/"),
        store.misses(),
        store.publishes(),
    );
    ensure!(
        fleet_tpc > priv_tpc,
        "fleet-shared draft store must STRICTLY beat private caches on same-task multi-engine \
         traffic: fleet {fleet_tpc:.3} tokens/call vs private {priv_tpc:.3}"
    );
    ensure!(
        hits.iter().all(|&h| h > 0),
        "every engine must propose rows from the fleet store (per-engine hit-through {hits:?})"
    );
    ensure!(store.publishes() > 0, "fleet store saw no delta publishes");
    Ok(Json::obj(vec![
        ("task", Json::Str(task.to_string())),
        ("private_tokens_per_call", Json::Num(priv_tpc)),
        ("fleet_tokens_per_call", Json::Num(fleet_tpc)),
        ("private_calls", Json::Num(priv_calls as f64)),
        ("fleet_calls", Json::Num(fleet_calls as f64)),
        ("store_hits", Json::Num(store.hits() as f64)),
        ("store_misses", Json::Num(store.misses() as f64)),
        ("store_publishes", Json::Num(store.publishes() as f64)),
        ("engine_hits", Json::Arr(hits.iter().map(|&h| Json::Num(h as f64)).collect())),
    ]))
}
