//! `bench serve` — serving front-end shootout over real sockets.
//!
//! Drives every front-end × dispatch combination ({reactor, threaded} ×
//! {steal, central}) through a full server: bind, POST `/generate` from
//! N concurrent client threads at each concurrency level, read the
//! latency digests back from `/stats`, and shut down gracefully.
//!
//! Two invariants are enforced (and fail the bench, `--smoke` or not):
//!
//! 1. **Byte-identity** — every request's decoded `text` is identical
//!    across all four combinations at every concurrency level. The
//!    front-end and the dispatch arrangement may only move bytes, never
//!    change them.
//! 2. **No reactor regression** — the reactor's p50/p99 TTFT and
//!    inter-token latency must stay within [`SMOKE_TOLERANCE`]× of the
//!    threaded baseline (plus [`SMOKE_SLACK_US`] absolute slack for CI
//!    scheduler hiccups a tiny smoke workload cannot average away),
//!    compared under the same dispatch mode at the same total load.
//!
//! The headline `tokens_per_s` written to `BENCH_serve.json` is measured
//! wall time (machine-dependent), so the baseline entry carries
//! `"wall_clock": true` and is advisory in the regression gate.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::config::{Dispatch, EngineConfig, FrontEnd, Manifest, ServeConfig};
use crate::scheduler::Scheduler;
use crate::server::{client, Server};
use crate::tokenizer::BpeTokenizer;
use crate::util::json::Json;

/// Concurrency levels every front-end/dispatch combination is driven at.
pub const CONCURRENCIES: [usize; 3] = [1, 4, 8];

/// The reactor may not be worse than threaded by more than this factor on
/// any gated latency quantile...
const SMOKE_TOLERANCE: f64 = 3.0;
/// ...plus this absolute slack (µs). Synthetic-model latencies are small
/// enough that a single preemption would otherwise blow past any ratio.
const SMOKE_SLACK_US: f64 = 25_000.0;

/// Client-side latency digest for one concurrency level.
struct ConcStats {
    conc: usize,
    requests: usize,
    p50_us: u64,
    p99_us: u64,
}

/// Everything measured for one front-end × dispatch combination.
struct RunStats {
    front_end: FrontEnd,
    dispatch: Dispatch,
    /// server-side digests from `/stats`, cumulative over all levels
    ttft_p50_us: f64,
    ttft_p99_us: f64,
    inter_p50_us: f64,
    inter_p99_us: f64,
    per_conc: Vec<ConcStats>,
    /// request key → decoded text, for cross-config byte-identity
    texts: BTreeMap<String, String>,
    total_tokens: u64,
    total_calls: u64,
    wall_s: f64,
}

fn pct(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

/// Pull one `/stats` digest's (p50_us, p99_us) out of the response JSON.
fn digest(j: &Json, name: &str) -> Result<(f64, f64)> {
    let d = j.get(name).ok_or_else(|| anyhow!("/stats is missing the {name} digest"))?;
    let q = |key: &str| d.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
    Ok((q("p50_us"), q("p99_us")))
}

/// One (key, text, tokens, calls, latency_us) row per completed request.
type ClientRows = Vec<(String, String, u64, u64, u64)>;

fn run_config(
    manifest: &Manifest,
    model: &str,
    tok: &Arc<BpeTokenizer>,
    front_end: FrontEnd,
    dispatch: Dispatch,
    per_level: usize,
    max_new: usize,
) -> Result<RunStats> {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        front_end,
        dispatch,
        // batch >= 2 so the dispatch arrangement actually runs
        batch: 4,
        queue_cap: 64,
        default_engine: EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: max_new },
        ..ServeConfig::default()
    };
    let sched = Arc::new(Scheduler::start(manifest, model, &cfg)?);
    let handle =
        Server { scheduler: sched.clone(), tokenizer: tok.clone(), cfg }.spawn_handle()?;
    let addr = handle.addr.to_string();
    let fe_label = front_end.label();

    let t0 = Instant::now();
    let mut texts = BTreeMap::new();
    let mut per_conc = Vec::new();
    let (mut total_tokens, mut total_calls) = (0u64, 0u64);
    for &conc in &CONCURRENCIES {
        let per_thread = per_level.div_ceil(conc);
        let mut joins = Vec::new();
        for t in 0..conc {
            let addr = addr.clone();
            joins.push(std::thread::spawn(move || -> Result<ClientRows> {
                let mut rows = Vec::new();
                for r in 0..per_thread {
                    // unique deterministic prompt per request key, so the
                    // same key must decode to the same text in every
                    // front-end/dispatch combination
                    let key = format!("c{conc}-t{t}-r{r}");
                    let body = format!(
                        "{{\"prompt\": \"Question: Tom has {t} apples and {r} pens at level {conc}.\"}}",
                    );
                    let t_req = Instant::now();
                    let (code, resp) = client::post(&addr, "/generate", &body)?;
                    let lat_us = t_req.elapsed().as_micros() as u64;
                    ensure!(code == 200, "{fe_label} request {key}: HTTP {code}: {resp}");
                    let j = Json::parse(&resp)
                        .map_err(|e| anyhow!("bad /generate response for {key}: {e}"))?;
                    let text = j
                        .req("text")?
                        .as_str()
                        .ok_or_else(|| anyhow!("'text' is not a string"))?
                        .to_string();
                    let tokens = j.req("tokens")?.as_f64().unwrap_or(0.0) as u64;
                    let calls = j.req("calls")?.as_f64().unwrap_or(0.0) as u64;
                    rows.push((key, text, tokens, calls, lat_us));
                }
                Ok(rows)
            }));
        }
        let mut lats = Vec::new();
        for join in joins {
            let rows = join.join().map_err(|_| anyhow!("client thread panicked"))??;
            for (key, text, tokens, calls, lat_us) in rows {
                texts.insert(key, text);
                total_tokens += tokens;
                total_calls += calls;
                lats.push(lat_us);
            }
        }
        lats.sort_unstable();
        per_conc.push(ConcStats {
            conc,
            requests: lats.len(),
            p50_us: pct(&lats, 0.5),
            p99_us: pct(&lats, 0.99),
        });
    }

    let (code, stats) = client::get(&addr, "/stats")?;
    ensure!(code == 200, "/stats failed: HTTP {code}");
    let j = Json::parse(&stats).map_err(|e| anyhow!("bad /stats response: {e}"))?;
    let (ttft_p50_us, ttft_p99_us) = digest(&j, "ttft_us")?;
    let (inter_p50_us, inter_p99_us) = digest(&j, "inter_token_us")?;
    let wall_s = t0.elapsed().as_secs_f64();

    // graceful shutdown: stop accepting, drain in-flight connections,
    // then close the scheduler queue and join its workers
    handle.shutdown();
    if let Ok(s) = Arc::try_unwrap(sched) {
        s.shutdown();
    }
    Ok(RunStats {
        front_end,
        dispatch,
        ttft_p50_us,
        ttft_p99_us,
        inter_p50_us,
        inter_p99_us,
        per_conc,
        texts,
        total_tokens,
        total_calls,
        wall_s,
    })
}

/// Run the shootout. `--smoke` shrinks the workload for CI; both modes
/// enforce byte-identity and the reactor-vs-threaded latency gate.
pub fn run(manifest: &Manifest, model: &str, smoke: bool) -> Result<()> {
    let (per_level, max_new) = if smoke { (8, 8) } else { (24, 16) };
    let tok = Arc::new(BpeTokenizer::load(&manifest.tokenizer_path)?);
    let combos = [
        (FrontEnd::Threaded, Dispatch::Central),
        (FrontEnd::Threaded, Dispatch::Steal),
        (FrontEnd::Reactor, Dispatch::Central),
        (FrontEnd::Reactor, Dispatch::Steal),
    ];
    println!(
        "== bench serve: {{reactor,threaded}} x {{steal,central}}, \
         {per_level} requests at each concurrency {CONCURRENCIES:?} =="
    );
    let mut runs = Vec::new();
    for (fe, disp) in combos {
        eprintln!("  running {}/{} ...", fe.label(), disp.label());
        runs.push(run_config(manifest, model, &tok, fe, disp, per_level, max_new)?);
    }

    println!(
        "\n{:<10} {:<8} {:>10} {:>10} {:>10} {:>10}  client p99 by concurrency",
        "front-end", "dispatch", "ttft_p50", "ttft_p99", "inter_p50", "inter_p99"
    );
    for r in &runs {
        let by_conc: Vec<String> =
            r.per_conc.iter().map(|c| format!("c{}:{}us", c.conc, c.p99_us)).collect();
        println!(
            "{:<10} {:<8} {:>10.0} {:>10.0} {:>10.0} {:>10.0}  {}",
            r.front_end.label(),
            r.dispatch.label(),
            r.ttft_p50_us,
            r.ttft_p99_us,
            r.inter_p50_us,
            r.inter_p99_us,
            by_conc.join(" ")
        );
    }

    // invariant 1: byte-identity across every combination
    let reference = &runs[0];
    for r in &runs[1..] {
        ensure!(
            r.texts.len() == reference.texts.len(),
            "{}/{} answered {} requests, {}/{} answered {}",
            r.front_end.label(),
            r.dispatch.label(),
            r.texts.len(),
            reference.front_end.label(),
            reference.dispatch.label(),
            reference.texts.len()
        );
        for (key, want) in &reference.texts {
            let got = r.texts.get(key).ok_or_else(|| anyhow!("missing request {key}"))?;
            ensure!(
                got == want,
                "BYTE-IDENTITY VIOLATION: request {key} decoded differently under {}/{} \
                 than {}/{}",
                r.front_end.label(),
                r.dispatch.label(),
                reference.front_end.label(),
                reference.dispatch.label()
            );
        }
    }
    println!(
        "\nbyte-identity: OK ({} requests identical across {} front-end/dispatch combos)",
        reference.texts.len(),
        runs.len()
    );

    // invariant 2: the reactor holds the latency quantiles vs threaded
    // under the same dispatch mode at the same total load
    let find = |fe: FrontEnd, d: Dispatch| {
        runs.iter()
            .find(|r| r.front_end == fe && r.dispatch == d)
            .expect("every combo was run above")
    };
    for d in [Dispatch::Central, Dispatch::Steal] {
        let th = find(FrontEnd::Threaded, d);
        let re = find(FrontEnd::Reactor, d);
        for (name, t, r) in [
            ("ttft p50", th.ttft_p50_us, re.ttft_p50_us),
            ("ttft p99", th.ttft_p99_us, re.ttft_p99_us),
            ("inter-token p50", th.inter_p50_us, re.inter_p50_us),
            ("inter-token p99", th.inter_p99_us, re.inter_p99_us),
        ] {
            ensure!(
                r <= t * SMOKE_TOLERANCE + SMOKE_SLACK_US,
                "reactor {name} ({r:.0}us) regressed past threaded ({t:.0}us) \
                 x{SMOKE_TOLERANCE} + {SMOKE_SLACK_US:.0}us slack under {} dispatch",
                d.label()
            );
        }
    }
    println!(
        "latency gate: OK (reactor within x{SMOKE_TOLERANCE} + {SMOKE_SLACK_US:.0}us of \
         threaded on every gated quantile)"
    );

    let detail = Json::Arr(
        runs.iter()
            .map(|r| {
                Json::obj(vec![
                    ("front_end", Json::Str(r.front_end.label().into())),
                    ("dispatch", Json::Str(r.dispatch.label().into())),
                    ("ttft_p50_us", Json::Num(r.ttft_p50_us)),
                    ("ttft_p99_us", Json::Num(r.ttft_p99_us)),
                    ("inter_token_p50_us", Json::Num(r.inter_p50_us)),
                    ("inter_token_p99_us", Json::Num(r.inter_p99_us)),
                    ("tokens", Json::Num(r.total_tokens as f64)),
                    ("calls", Json::Num(r.total_calls as f64)),
                    ("wall_s", Json::Num(r.wall_s)),
                    (
                        "client_latency",
                        Json::Arr(
                            r.per_conc
                                .iter()
                                .map(|c| {
                                    Json::obj(vec![
                                        ("concurrency", Json::Num(c.conc as f64)),
                                        ("requests", Json::Num(c.requests as f64)),
                                        ("p50_us", Json::Num(c.p50_us as f64)),
                                        ("p99_us", Json::Num(c.p99_us as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    );
    super::write_json("serve", &detail)?;

    // the headline is the default serving configuration (reactor + steal);
    // wall-clock, so its baseline entry is marked "wall_clock": true
    let headline = find(FrontEnd::Reactor, Dispatch::Steal);
    let tokens_per_s = headline.total_tokens as f64 / headline.wall_s.max(1e-9);
    let tokens_per_call = if headline.total_calls == 0 {
        0.0
    } else {
        headline.total_tokens as f64 / headline.total_calls as f64
    };
    let ar = super::accept_rate(headline.total_tokens as usize, headline.total_calls as usize);
    super::write_bench_summary_with(
        "serve",
        tokens_per_s,
        tokens_per_call,
        ar,
        vec![("front_ends", detail)],
    )
}
