//! Tree-speculation bench: accepted tokens per verify call, flat rows vs
//! shared-prefix token tree at the SAME row budget, on a branchy
//! high-repetition workload — the tentpole claim this bench GATES.
//!
//! The workload is adversarially branchy in exactly the way the paper's
//! §4.2 context source is vulnerable to: a short greedy warmup run finds
//! the model's recurring tokens (its bigram-attractor cycle), and every
//! request prompt then plants `K` equally-frequent decoy continuations
//! after each such anchor. When decoding revisits an anchor, the
//! context-first mixed policy ranks those high-count decoys above the
//! (initially unseen) true continuation, so flat mode's k rows are
//! entirely decoys and accept nothing. Tree mode proposes with the same
//! strategy at the overdraft quota and trie-packs the rows into the same
//! k*(w+1)-node budget — the k decoy rows cost k*w nodes, and the
//! leftover slack holds the true attractor chain, which keeps accepting.
//! Same verify-call positions, strictly more accepted tokens: the gate
//! fails unless tree mode's aggregate tokens/call strictly beats linear.
//!
//! Byte-identity is re-checked in-bench: every request is decoded through
//! linear rows, the token tree, and plain greedy, and all three streams
//! must match token for token.

use anyhow::{ensure, Result};

use crate::config::EngineConfig;
use crate::draft::DraftStrategy;
use crate::engine::{generate_all, greedy_config, BatchedEngine, SpecDecoder};
use crate::scheduler::{make_strategy, StrategyName};
use crate::tokenizer::TokenId;
use crate::trace::report::TraceSummary;
use crate::trace::{FlightRecorder, TraceEvent, DEFAULT_RING_CAPACITY};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Verify rows per call (the shared row budget for both modes).
const K: usize = 5;
/// Draft depth per row.
const W: usize = 4;
/// Concurrent decode lanes (exercises grouped packed tree calls).
const LANES: usize = 4;
/// Decoy continuations planted after each ambush anchor — exactly K, so
/// they fill flat mode's context-first quota and nothing else gets in.
const DECOYS: usize = K;
/// Copies of each decoy pattern. The decoys outrank the true continuation
/// until the anchor has been revisited about this many times, so every
/// request gets several ambushed calls per anchor.
const REPS: usize = 4;
/// Recurring tokens ambushed per prompt.
const ANCHORS: usize = 4;
/// Greedy warmup length used to discover the recurring anchors.
const WARMUP_NEW: usize = 96;

/// Run the tree-vs-linear acceptance comparison; fails unless tree mode
/// achieves strictly more accepted tokens per verify call than linear
/// rows at the same row budget, or if any stream diverges from greedy.
pub fn run(ctx: &super::BenchCtx, smoke: bool) -> Result<()> {
    let d = &ctx.runtime.artifacts().dims;
    let vocab = ctx.manifest.vocab_size;
    let n_req = if smoke { 6 } else { 16 };
    let prompt_len = ANCHORS * DECOYS * REPS * 2 + 1;
    let max_new = (d.max_len - prompt_len - (W + 1)).min(104);
    ensure!(max_new >= 32, "model context too short for the tree bench");
    let cfg = EngineConfig { k: K, w: W, q: 1, max_new_tokens: max_new };

    println!(
        "== tree speculation (model '{}', k={K} w={W}, {ANCHORS} anchors x {DECOYS} decoys, \
         {n_req} requests x {max_new} new tokens) ==\n",
        ctx.model
    );

    // ---- warmup: find the model's recurring tokens (the ambush anchors)
    let mut rng = Rng::new(0xB1A5_ED);
    let seed_prompt: Vec<TokenId> = (0..8).map(|_| rng.below(vocab) as TokenId).collect();
    let mut dec = SpecDecoder::new(
        &ctx.runtime,
        make_strategy(StrategyName::None, &ctx.tables, 1),
        greedy_config(WARMUP_NEW),
    );
    let warm = dec.generate(&seed_prompt)?;
    let mut freq = vec![0u32; vocab];
    for &t in &warm.tokens {
        freq[t as usize] += 1;
    }
    let mut order: Vec<usize> = (0..vocab).collect();
    order.sort_by_key(|&t| std::cmp::Reverse(freq[t]));
    let anchors: Vec<TokenId> = order[..ANCHORS].iter().map(|&t| t as TokenId).collect();
    println!(
        "anchors (token: warmup count): {}",
        anchors.iter().map(|&a| format!("{a}: {}", freq[a as usize])).collect::<Vec<_>>().join(", ")
    );

    let prompts: Vec<Vec<TokenId>> = (0..n_req)
        .map(|i| ambush_prompt(&anchors, vocab, &mut Rng::new(0x7EE5 ^ i as u64)))
        .collect();

    // ---- the comparison: same requests, same (k, w), flat rows vs tree
    let mut lin_eng = BatchedEngine::new(&ctx.runtime, LANES);
    let lin = generate_all(&mut lin_eng, requests(ctx, &prompts, &cfg))?;

    let mut tree_eng = BatchedEngine::new(&ctx.runtime, LANES);
    tree_eng.tree = true;
    tree_eng.collect_traces = true;
    let rec = FlightRecorder::standalone(0, DEFAULT_RING_CAPACITY);
    tree_eng.recorder = Some(rec.clone());
    let tree = generate_all(&mut tree_eng, requests(ctx, &prompts, &cfg))?;

    // ---- byte-identity: linear == tree == plain greedy, per request
    for (i, (l, t)) in lin.iter().zip(&tree).enumerate() {
        ensure!(
            l.tokens == t.tokens,
            "BYTE-IDENTITY VIOLATION: request {i} differs between linear and tree modes"
        );
        let mut g = SpecDecoder::new(
            &ctx.runtime,
            make_strategy(StrategyName::None, &ctx.tables, 1),
            greedy_config(max_new),
        );
        let greedy = g.generate(&prompts[i])?;
        ensure!(
            t.tokens == greedy.tokens,
            "BYTE-IDENTITY VIOLATION: request {i} tree stream differs from plain greedy"
        );
    }
    println!("byte-identity: {} streams identical across linear, tree and greedy", lin.len());

    // decode tokens exclude the prefill-emitted first token, as everywhere
    let tokens: usize = tree.iter().map(|r| r.tokens.len().saturating_sub(1)).sum();
    let lin_calls: usize = lin.iter().map(|r| r.calls).sum();
    let tree_calls: usize = tree.iter().map(|r| r.calls).sum();
    let lin_tpc = tokens as f64 / lin_calls.max(1) as f64;
    let tree_tpc = tokens as f64 / tree_calls.max(1) as f64;
    let mean_nodes = tree_eng.packed_traces.iter().map(|t| t.rows).sum::<usize>() as f64
        / tree_eng.packed_traces.len().max(1) as f64;

    println!("\n{:<10} {:>8} {:>14} {:>10}", "mode", "calls", "tokens/call", "accept");
    println!("{:<10} {:>8} {:>14.3} {:>10.3}", "greedy", tokens, 1.0, 0.0);
    println!(
        "{:<10} {:>8} {:>14.3} {:>10.3}",
        "linear", lin_calls, lin_tpc,
        super::accept_rate(tokens, lin_calls)
    );
    println!(
        "{:<10} {:>8} {:>14.3} {:>10.3}",
        "tree", tree_calls, tree_tpc,
        super::accept_rate(tokens, tree_calls)
    );
    println!(
        "\ntree packs a mean {mean_nodes:.1} nodes/call into the {}-position budget; \
         tokens/call {tree_tpc:.3} vs linear {lin_tpc:.3} ({:+.1}%)",
        K * (W + 1),
        (tree_tpc / lin_tpc.max(1e-12) - 1.0) * 100.0,
    );
    ensure!(
        tree_tpc > lin_tpc,
        "tree mode accepted {tree_tpc:.3} tokens/call <= linear {lin_tpc:.3} at the same \
         row budget on the branchy workload — tree packing is not paying"
    );

    // tree shape/acceptance provenance must have reached the recorder
    let steps = rec.snapshot(DEFAULT_RING_CAPACITY);
    ensure!(
        steps.iter().any(|e| e.tree_nodes > 0),
        "no StepEvent carried tree provenance (tree_nodes == 0 everywhere)"
    );

    // cost-model throughput of the tree run, for the CI regression gate
    let cm = ctx.cost_model();
    let sim_s: f64 = tree_eng
        .packed_traces
        .iter()
        .map(|t| cm.call_time(t.rows, t.w + 1, t.max_ctx))
        .sum();
    let sim_tps = tokens as f64 / sim_s.max(1e-12);

    super::write_json(
        &format!("tree_{}", ctx.model),
        &Json::obj(vec![
            ("bench", Json::Str("tree-speculation".into())),
            ("model", Json::Str(ctx.model.clone())),
            ("k", Json::Num(K as f64)),
            ("w", Json::Num(W as f64)),
            ("requests", Json::Num(n_req as f64)),
            ("max_new", Json::Num(max_new as f64)),
            ("anchors", Json::Arr(anchors.iter().map(|&a| Json::Num(a as f64)).collect())),
            ("decode_tokens", Json::Num(tokens as f64)),
            ("linear_calls", Json::Num(lin_calls as f64)),
            ("tree_calls", Json::Num(tree_calls as f64)),
            ("linear_tokens_per_call", Json::Num(lin_tpc)),
            ("tree_tokens_per_call", Json::Num(tree_tpc)),
            ("mean_nodes_per_call", Json::Num(mean_nodes)),
            ("sim_tokens_per_s", Json::Num(sim_tps)),
        ]),
    )?;
    let events: Vec<TraceEvent> = steps.into_iter().map(TraceEvent::Step).collect();
    super::write_bench_summary_with(
        "tree",
        sim_tps,
        tree_tpc,
        super::accept_rate(tokens, tree_calls),
        vec![
            ("linear_tokens_per_call", Json::Num(lin_tpc)),
            ("mean_nodes_per_call", Json::Num(mean_nodes)),
            ("phases", TraceSummary::from_events(&events).phases_json()),
        ],
    )
}

/// One request prompt: for each anchor, `DECOYS` distinct decoy
/// continuations repeated `REPS` times (`a j1 a j2 ... | a j1 ...`), so
/// every decoy q=1 continuation group carries count ~REPS. Ends on an
/// anchor so decoding opens ambushed.
fn ambush_prompt(anchors: &[TokenId], vocab: usize, rng: &mut Rng) -> Vec<TokenId> {
    let mut p = Vec::with_capacity(anchors.len() * DECOYS * REPS * 2 + 1);
    for &a in anchors {
        let mut decoys: Vec<TokenId> = Vec::with_capacity(DECOYS);
        while decoys.len() < DECOYS {
            let t = rng.below(vocab) as TokenId;
            // decoys must not collide with any anchor: an anchor-valued
            // decoy would plant foreign continuations under that anchor
            if !decoys.contains(&t) && !anchors.contains(&t) {
                decoys.push(t);
            }
        }
        for _ in 0..REPS {
            for &j in &decoys {
                p.push(a);
                p.push(j);
            }
        }
    }
    p.push(anchors[0]);
    p
}

/// Build the request tuples `generate_all` consumes (same mixed strategy
/// and engine shape for every request, as the identity check requires).
fn requests(
    ctx: &super::BenchCtx,
    prompts: &[Vec<TokenId>],
    cfg: &EngineConfig,
) -> Vec<(Vec<TokenId>, Box<dyn DraftStrategy>, EngineConfig)> {
    prompts
        .iter()
        .map(|p| (p.clone(), make_strategy(StrategyName::Mixed, &ctx.tables, cfg.q), cfg.clone()))
        .collect()
}
