//! `bench draft [--smoke]` — the draft hot-path regression bench.
//!
//! Measures proposals/sec of the incremental suffix-index `ContextNgram`
//! against the seed's O(context) rescan
//! ([`crate::draft::context_ngram::reference_candidates`], preserved as
//! the specification oracle) across context lengths and query lengths,
//! plus the arena-backed mixed proposal path. Each incremental iteration
//! does the full decode-step work — append one token, sync the index,
//! propose, roll the token back — so index maintenance and rollback are
//! inside the measurement, not amortised away.
//!
//! THE GATE: the bench FAILS (non-zero exit, red CI) unless the
//! incremental path achieves at least [`MIN_SPEEDUP`]x the rescan's
//! proposals/sec at every context >= 256 — a hardware-independent ratio,
//! which is why it is asserted here rather than compared against a
//! committed wall-clock number. `BENCH_draft.json` also feeds the
//! `ci-bench-check` gate; its `tokens_per_s` (incremental proposals/sec
//! at the headline config) is machine-dependent wall-clock, so the
//! committed baseline entry deliberately stays `null` (bootstrap) — the
//! ratio assertion above is the regression tooth.
//!
//! For scale, every config also prints the drafting cost as a share of
//! one paper-scale A100 verification call ([`crate::costmodel`]): the
//! paper's premise is that this share is ~0.

use anyhow::{ensure, Result};

use crate::costmodel::CostModel;
use crate::draft::context_ngram::reference_candidates;
use crate::draft::tables::Table;
use crate::draft::{ContextNgram, DraftBatch, DraftStrategy, MixedStrategy, NgramTables};
use crate::util::bench::{black_box, fmt_ns, Bencher};
use crate::util::json::Json;
use crate::util::prop;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Required incremental-over-rescan proposals/sec ratio at context >= 256
/// (the acceptance bar for the suffix-index rewrite).
pub const MIN_SPEEDUP: f64 = 2.0;

/// Block shape every config proposes at (the paper's headline (k, w)).
const K: usize = 10;
const W: usize = 10;

fn synthetic_tables(vocab: usize, topk: usize, depth: usize) -> Arc<NgramTables> {
    let bigram = Table::from_data(
        vocab,
        topk,
        1,
        (0..vocab as u32)
            .flat_map(|x| (1..=topk as u32).map(move |j| (x + j) % vocab as u32))
            .collect(),
    );
    let unigram = Table::from_data(1, topk, 1, (0..topk as u32).collect());
    let ext = Table::from_data(
        vocab,
        topk,
        depth,
        (0..vocab as u32)
            .flat_map(|x| {
                (1..=topk as u32)
                    .flat_map(move |j| (0..depth as u32).map(move |d| (x + j + d) % vocab as u32))
            })
            .collect(),
    );
    Arc::new(NgramTables { bigram, unigram, ext_bigram: ext })
}

/// A repetitive decode-like sequence of `len` tokens (heavy n-gram reuse,
/// like the paper's code/markdown workloads where context drafting pays).
fn synthetic_seq(rng: &mut Rng, len: usize, vocab: u32) -> Vec<u32> {
    let mut seq = prop::vec_u32(rng, (len / 4).max(24), 0..vocab);
    while seq.len() < len {
        let start = rng.below(seq.len().saturating_sub(20).max(1));
        let n = rng.range(4, 16).min(seq.len() - start);
        let repeat: Vec<u32> = seq[start..start + n].to_vec();
        seq.extend(repeat);
    }
    seq.truncate(len);
    seq
}

/// One measured configuration's results.
struct Cell {
    ctx: usize,
    q: usize,
    rescan_ns: f64,
    incremental_ns: f64,
    /// measured incremental iterations (the bench's "steps" for the
    /// per-scenario step counts in BENCH_draft.json)
    iters: u64,
}

impl Cell {
    fn speedup(&self) -> f64 {
        self.rescan_ns / self.incremental_ns.max(1e-9)
    }
}

/// Run the draft bench; see the module docs for what is measured and
/// what fails the gate.
pub fn run(smoke: bool) -> Result<()> {
    let mut bench = if smoke { Bencher::quick() } else { Bencher::default() };
    let contexts: &[usize] = if smoke { &[64, 256] } else { &[64, 256, 512] };
    let qs: &[usize] = if smoke { &[1] } else { &[1, 2] };
    let vocab = 512u32;
    let cm = CostModel::for_analog("mistral");

    println!("== bench draft: incremental suffix index vs seed rescan ==");
    println!("   shape (k={K}, w={W}); every incremental iteration appends one");
    println!("   token, syncs the index, proposes, then rolls the token back\n");

    let mut rng = Rng::new(0x6472616674); // "draft"
    let mut cells: Vec<Cell> = Vec::new();
    for &q in qs {
        for &ctx_len in contexts {
            let seq = synthetic_seq(&mut rng, ctx_len, vocab);

            // --- seed rescan: rebuild the window map every proposal
            let r = bench.bench(
                &format!("rescan    propose (q={q}, ctx={ctx_len})"),
                || {
                    black_box(reference_candidates(q, black_box(&seq), W).len());
                },
            );
            let rescan_ns = r.mean_ns;

            // --- incremental: persistent index, decode-style step
            let mut ctx = ContextNgram::new(q);
            let mut batch = DraftBatch::new(W);
            let mut live = seq.clone();
            ctx.propose(&live, K, &mut batch); // warm the index once
            let mut step = 0u32;
            let r = bench.bench(
                &format!("suffix-ix propose (q={q}, ctx={ctx_len})"),
                || {
                    live.push(step % vocab);
                    step = step.wrapping_add(1);
                    batch.reset(W);
                    ctx.propose(black_box(&live), K, &mut batch);
                    black_box(batch.k());
                    live.pop();
                },
            );
            let (incremental_ns, iters) = (r.mean_ns, r.iters);
            cells.push(Cell { ctx: ctx_len, q, rescan_ns, incremental_ns, iters });
        }
    }

    // arena-backed mixed proposal at the headline config, for the
    // negligible-cost table
    let tables = synthetic_tables(vocab as usize, 32, 16);
    let seq = synthetic_seq(&mut rng, 256, vocab);
    let mut mixed = MixedStrategy::paper(tables, 1);
    let mut batch = DraftBatch::new(W);
    let r = bench.bench("mixed     propose (q=1, ctx=256, arena)", || {
        batch.reset(W);
        mixed.propose(black_box(&seq), K, &mut batch);
        black_box(batch.k());
    });
    let (mixed_ns, mixed_iters) = (r.mean_ns, r.iters);

    // --- report + gate
    println!("\n{:<6} {:>3} {:>14} {:>14} {:>9} {:>16}", "ctx", "q", "rescan", "suffix-ix",
             "speedup", "% of verify call");
    let mut worst_gated: Option<f64> = None;
    for c in &cells {
        let verify_ns = cm.call_time(K, W + 1, c.ctx) * 1e9;
        println!(
            "{:<6} {:>3} {:>14} {:>14} {:>8.1}x {:>15.4}%",
            c.ctx,
            c.q,
            fmt_ns(c.rescan_ns),
            fmt_ns(c.incremental_ns),
            c.speedup(),
            c.incremental_ns / verify_ns * 100.0,
        );
        if c.ctx >= 256 {
            let s = c.speedup();
            worst_gated = Some(worst_gated.map_or(s, |w: f64| w.min(s)));
        }
    }
    println!("mixed arena propose (ctx=256): {}", fmt_ns(mixed_ns));

    // headline summary for ci-bench-check: incremental proposals/sec at
    // (q=1, ctx=256); wall-clock, so the committed baseline stays null
    // and regressions are caught by the ratio gate below instead
    let headline = cells
        .iter()
        .find(|c| c.q == 1 && c.ctx == 256)
        .expect("ctx=256 q=1 cell always measured");
    let proposals_per_s = 1e9 / headline.incremental_ns.max(1e-9);
    // per-scenario measured iteration counts (this bench's step counts)
    let mut scenario_steps: Vec<(String, Json)> = cells
        .iter()
        .map(|c| (format!("suffix-ix-q{}-ctx{}", c.q, c.ctx), Json::Num(c.iters as f64)))
        .collect();
    scenario_steps.push(("mixed-arena-ctx256".to_string(), Json::Num(mixed_iters as f64)));
    super::write_json(
        "BENCH_draft",
        &Json::obj(vec![
            ("bench", Json::Str("draft".into())),
            ("tokens_per_s", Json::Num(proposals_per_s)),
            ("rescan_ns", Json::Num(headline.rescan_ns)),
            ("incremental_ns", Json::Num(headline.incremental_ns)),
            ("speedup", Json::Num(headline.speedup())),
            ("min_gated_speedup", Json::Num(worst_gated.unwrap_or(0.0))),
            ("mixed_arena_ns", Json::Num(mixed_ns)),
            ("scenario_steps", Json::Obj(scenario_steps)),
        ]),
    )?;

    let worst = worst_gated.expect("at least one ctx >= 256 config is always measured");
    ensure!(
        worst >= MIN_SPEEDUP,
        "incremental context-ngram path lost its edge: {worst:.2}x < {MIN_SPEEDUP}x \
         over the seed rescan at ctx >= 256"
    );
    println!(
        "\ndraft gate: OK (worst ctx>=256 speedup {worst:.1}x >= {MIN_SPEEDUP}x)"
    );
    Ok(())
}
