//! Figure 1: memory-bound -> compute-bound phase transition heatmaps.
//!
//! Paper: slowdown of a (k, w) model call relative to (1, 0) for Mistral-7B
//! on an A100-40GB, at context lengths l in {25, 100, 500}, k in 1..32,
//! w in 0..15. Reproduced with the analytical cost model (the mechanism —
//! OTB threshold + wave quantization — is deterministic); a measured-CPU
//! series for the nano model is printed alongside to show the contrast
//! (CPU is compute-bound from the start, so its "transition" is immediate).

use anyhow::Result;

use crate::costmodel::{CostModel, Hardware, TxDims};
use crate::util::json::Json;

/// The paper's Fig. 1 context lengths.
pub const CTX_LENS: [usize; 3] = [25, 100, 500];

/// Print the phase-transition heatmaps (plus a measured-CPU series
/// when a context is provided).
pub fn run(measured: Option<&super::BenchCtx>) -> Result<()> {
    let cm = CostModel::new(Hardware::a100_40gb(), TxDims::mistral_7b());
    let ks: Vec<usize> = (0..=5).map(|i| 1usize << i).collect(); // 1..32
    let ws: Vec<usize> = vec![0, 1, 2, 3, 4, 6, 8, 10, 12, 15];

    println!("== Figure 1: slowdown of a (k, w) call vs (1, 0) — {} / {} ==",
             cm.hw.name, cm.dims.name);
    println!("(paper: transition stays ~1.0 while memory-bound, then wave-");
    println!(" quantized jumps; larger l moves the boundary to smaller k*w)\n");

    let mut series = Vec::new();
    for &l in &CTX_LENS {
        let grid = super::render_grid(
            &format!("-- context length l = {l} --"),
            &ks,
            &ws,
            |k, w| cm.slowdown(k, w, l),
        );
        println!("{grid}");
        let mut rows = Vec::new();
        for &k in &ks {
            let r: Vec<Json> = ws.iter().map(|&w| Json::Num(cm.slowdown(k, w, l))).collect();
            rows.push(Json::Arr(r));
        }
        series.push(Json::obj(vec![
            ("ctx_len", Json::Num(l as f64)),
            ("ks", Json::Arr(ks.iter().map(|&k| Json::Num(k as f64)).collect())),
            ("ws", Json::Arr(ws.iter().map(|&w| Json::Num(w as f64)).collect())),
            ("slowdown", Json::Arr(rows)),
        ]));
    }

    // contrast series: measured CPU slowdowns for the nano model
    let mut measured_json = Json::Null;
    if let Some(ctx) = measured {
        println!("-- measured CPU PJRT (nano '{}' model), l = 100 --", ctx.model);
        println!("   (CPU has no memory-bound regime: slowdown grows immediately)");
        let shapes = ctx.runtime.artifacts().step_shapes();
        let mut cache = crate::kvcache::SharedKvCache::new(
            ctx.runtime.artifacts().dims.n_layers,
            ctx.runtime.artifacts().dims.max_len,
            ctx.runtime.artifacts().dims.n_heads,
            ctx.runtime.artifacts().dims.head_dim,
        );
        cache.len = 100;
        let mut rows = Vec::new();
        let t_base = time_step(ctx, 1, 0, &cache)?;
        for &(k, w) in shapes.iter().filter(|&&(k, w)| k <= 25 && w <= 14) {
            let t = time_step(ctx, k, w, &cache)?;
            let slow = t / t_base;
            println!("   (k={k:>2}, w={w:>2})  {:>8.2} ms   slowdown {slow:>5.2}x",
                     t * 1e3);
            rows.push(Json::obj(vec![
                ("k", Json::Num(k as f64)),
                ("w", Json::Num(w as f64)),
                ("ms", Json::Num(t * 1e3)),
                ("slowdown", Json::Num(slow)),
            ]));
        }
        measured_json = Json::Arr(rows);
    }

    super::write_json(
        "fig1",
        &Json::obj(vec![
            ("figure", Json::Str("fig1-phase-transition".into())),
            ("hardware", Json::Str(cm.hw.name.into())),
            ("model", Json::Str(cm.dims.name.into())),
            ("series", Json::Arr(series)),
            ("measured_cpu", measured_json),
        ]),
    )
}

/// Median-of-3 wall time of one verification call at shape (k, w).
fn time_step(ctx: &super::BenchCtx, k: usize, w: usize,
             cache: &crate::kvcache::SharedKvCache) -> Result<f64> {
    let tokens = vec![1u32; k * (w + 1)];
    ctx.runtime.warm_step(k, w)?;
    let mut ts = Vec::new();
    for _ in 0..3 {
        let out = ctx.runtime.spec_step(k, w, &tokens, cache)?;
        ts.push(out.exec_time.as_secs_f64());
    }
    ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(ts[1])
}
