//! Table 1: tokens/call and wall-time speedup for the mixed strategy at
//! (10, 10) and at the sweep-optimal (k*, w*), for all three models and
//! tasks, next to the paper's quoted Lookahead/REST rows and our in-repo
//! learning-free baseline (Jacobi decoding).

use anyhow::Result;

use crate::config::Manifest;
use crate::scheduler::StrategyName;
use crate::util::json::Json;
use crate::workload::{task_analog, TASKS};

/// The paper's quoted comparison rows (Table 1, reproduced verbatim —
/// the paper itself quotes these from Fu et al. / He et al.).
pub const PAPER_QUOTED: [(&str, &str, [Option<f64>; 3]); 6] = [
    ("3b", "Lookahead", [Some(1.65), Some(2.25), Some(1.89)]),
    ("3b", "REST", [Some(1.69), Some(2.12), None]),
    ("7b", "Lookahead", [Some(1.51), Some(2.26), Some(1.72)]),
    ("7b", "REST", [Some(1.77), Some(2.17), None]),
    ("13b", "Lookahead", [None, None, None]),
    ("13b", "REST", [None, None, None]),
];

/// Paper's own Table-1 numbers for shape comparison in EXPERIMENTS.md.
pub const PAPER_OURS_1010: [(&str, [(f64, f64); 3]); 3] = [
    ("3b", [(2.17, 2.01), (2.28, 2.11), (2.38, 2.30)]),
    ("7b", [(2.13, 1.91), (2.22, 2.04), (2.16, 2.03)]),
    ("13b", [(2.78, 2.31), (2.89, 2.50), (2.56, 2.21)]),
];

/// Print the headline table across models, tasks and baselines.
pub fn run(
    manifest: &Manifest,
    models: &[&str],
    n_prompts: usize,
    max_new: usize,
    sweep_ks: &[usize],
    sweep_ws: &[usize],
) -> Result<()> {
    println!("== Table 1: mixed strategies across models and tasks ==");
    println!("   speedup = simulated wall-time at paper scale (A100 cost");
    println!("   model driven by REAL measured acceptance traces); cpu tok/s");
    println!("   = measured on this host\n");
    println!(
        "{:<7} {:<22} | {:>9} {:>9} | {:>9} {:>9} | {:>9} {:>9}",
        "model", "strategy", "tok/call", "speedup", "tok/call", "speedup", "tok/call", "speedup"
    );
    println!(
        "{:<7} {:<22} | {:^19} | {:^19} | {:^19}",
        "", "", task_analog("chat"), task_analog("code"), task_analog("math")
    );
    println!("{}", "-".repeat(100));

    let mut out_models = Vec::new();
    for model in models {
        let ctx = super::BenchCtx::load(manifest.clone(), model)?;
        let analog = paper_size_label(model);
        let mut prompts_by_task = Vec::new();
        for task in TASKS {
            prompts_by_task.push(ctx.prompts(task, n_prompts, 128)?);
        }

        // --- (10, 10) default
        let mut row_1010 = Vec::new();
        for prompts in &prompts_by_task {
            row_1010.push(super::run_cell(
                &ctx, StrategyName::Mixed, prompts, 10, 10, 1, max_new)?);
        }
        print_row(analog, "Ours (10,10)", &row_1010);

        // --- sweep for (k*, w*): maximize simulated speedup per task
        let mut best_cells = Vec::new();
        for prompts in &prompts_by_task {
            let mut best: Option<((usize, usize), super::CellStats)> = None;
            for &k in sweep_ks {
                for &w in sweep_ws {
                    let c = super::run_cell(
                        &ctx, StrategyName::Mixed, prompts, k, w, 1, max_new)?;
                    if best.as_ref().map_or(true, |(_, b)| c.sim_speedup > b.sim_speedup) {
                        best = Some(((k, w), c));
                    }
                }
            }
            best_cells.push(best.unwrap());
        }
        let label = format!(
            "Ours (k*,w*) {}",
            best_cells
                .iter()
                .map(|((k, w), _)| format!("({k},{w})"))
                .collect::<Vec<_>>()
                .join("")
        );
        let best_stats: Vec<_> = best_cells.iter().map(|(_, c)| c.clone()).collect();
        print_row(analog, &label, &best_stats);

        // --- Jacobi baseline (learning-free ancestor, in-repo)
        let mut jac = Vec::new();
        for prompts in &prompts_by_task {
            jac.push(super::run_cell(
                &ctx, StrategyName::Jacobi, prompts, 1, 10, 1, max_new)?);
        }
        print_row(analog, "Jacobi (1,10)", &jac);

        // --- the paper's quoted external rows for context
        for (sz, name, vals) in PAPER_QUOTED {
            if sz == analog {
                let cells: Vec<String> = vals
                    .iter()
                    .map(|v| match v {
                        Some(x) => format!("{:>9} {:>9.2}", "-", x),
                        None => format!("{:>9} {:>9}", "-", "-"),
                    })
                    .collect();
                println!("{:<7} {:<22} | {} | {} | {}  [paper-quoted]",
                         analog, name, cells[0], cells[1], cells[2]);
            }
        }
        println!("{}", "-".repeat(100));

        let task_json = |cells: &[super::CellStats]| -> Json {
            Json::Arr(
                TASKS
                    .iter()
                    .zip(cells)
                    .map(|(t, c)| {
                        Json::obj(vec![
                            ("task", Json::Str((*t).into())),
                            ("tokens_per_call", Json::Num(c.tokens_per_call)),
                            ("sim_speedup", Json::Num(c.sim_speedup)),
                            ("sim_speedup_std", Json::Num(c.sim_speedup_std)),
                            ("cpu_tokens_per_s", Json::Num(c.cpu_tokens_per_s)),
                        ])
                    })
                    .collect(),
            )
        };
        out_models.push(Json::obj(vec![
            ("model", Json::Str(model.to_string())),
            ("paper_size", Json::Str(analog.into())),
            ("ours_10_10", task_json(&row_1010)),
            (
                "ours_best",
                Json::obj(vec![
                    (
                        "shapes",
                        Json::Arr(
                            best_cells
                                .iter()
                                .map(|((k, w), _)| {
                                    Json::Arr(vec![Json::Num(*k as f64), Json::Num(*w as f64)])
                                })
                                .collect(),
                        ),
                    ),
                    ("cells", task_json(&best_stats)),
                ]),
            ),
            ("jacobi", task_json(&jac)),
        ]));
    }
    super::write_json(
        "table1",
        &Json::obj(vec![
            ("table", Json::Str("table1".into())),
            ("models", Json::Arr(out_models)),
        ]),
    )
}

fn print_row(analog: &str, label: &str, cells: &[super::CellStats]) {
    let mut s = format!("{analog:<7} {label:<22} |");
    for c in cells {
        s.push_str(&format!(" {:>9.2} {:>9.2} |", c.tokens_per_call, c.sim_speedup));
    }
    println!("{s}");
}

/// Paper-size label ("7b", ...) for a repo model name.
pub fn paper_size_label(model: &str) -> &'static str {
    match model {
        "small" => "3b",
        "base" => "7b",
        "large" => "13b",
        _ => "?",
    }
}
