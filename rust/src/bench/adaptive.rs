//! Adaptive-controller bench: online (k, w) + strategy selection vs every
//! static single-strategy configuration on the repetitive testkit
//! workload, plus the budgeted batched engine.
//!
//! Headline: adaptive tokens/call should meet or beat the BEST static
//! arm at the paper-default (10, 10) — the controller gets the same row
//! cap but may plan deeper speculation when its acceptance estimates say
//! the stream is hot, and routes drafting to whichever arm is paying.
//! Per-arm pull counts and per-kind acceptance estimates are printed so
//! the bandit's behavior is inspectable, not just its score.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::adaptive::{self, DEFAULT_ARMS};
use crate::config::{EngineConfig, SessionCacheConfig};
use crate::costmodel::CostModel;
use crate::engine::{BatchedEngine, SpecDecoder};
use crate::scheduler::{make_strategy, StrategyName};
use crate::trace::report::TraceSummary;
use crate::trace::{FlightRecorder, TraceEvent, DEFAULT_RING_CAPACITY};
use crate::util::json::Json;
use crate::workload::{Prompt, TASKS};

/// Concurrency (pooled KV lanes) of the budgeted-batched section. The
/// default row budget is derived from it as 60% of the unbudgeted
/// `BATCH_CONC * k` rows, so the allocator has real decisions to make.
const BATCH_CONC: usize = 4;

/// Run the adaptive-vs-static comparison plus the budgeted-batch
/// section (`--smoke` shrinks the workload for CI).
pub fn run(
    ctx: &super::BenchCtx,
    n_prompts: usize,
    max_new: usize,
    budget: Option<usize>,
    smoke: bool,
) -> Result<()> {
    let (n_prompts, max_new) = if smoke { (2, 16) } else { (n_prompts, max_new) };
    let (k, w) = (10usize, 10usize);
    let cm = ctx.cost_model();
    let cache_cfg = SessionCacheConfig::default();
    let analog = ctx.runtime.artifacts().dims.analog.clone();
    // adaptive gets the same row cap but the full artifact depth range
    let w_cap = ctx
        .runtime
        .artifacts()
        .step_shapes()
        .iter()
        .map(|&(_, sw)| sw)
        .max()
        .unwrap_or(w);

    let mut prompts = Vec::new();
    for task in TASKS {
        prompts.extend(ctx.prompts(task, n_prompts.div_ceil(TASKS.len()).max(2), 96)?);
    }

    println!(
        "== adaptive controller vs static strategies (model '{}', {} prompts x {} tokens) ==\n",
        ctx.model,
        prompts.len(),
        max_new
    );
    println!("{:<22} {:>9} {:>7} {:>12}", "config", "tok/call", "calls", "sim tok/s");

    // --- static single-strategy baselines at the paper default (10, 10).
    // One decoder per config, reused across prompts, so the session cache
    // keeps its cross-request table — same semantics the controller's
    // session arm gets.
    let mut best_static = f64::NEG_INFINITY;
    let mut best_static_name = "";
    let mut rows = Vec::new();
    let mut static_calls = 0usize;
    for name in DEFAULT_ARMS {
        let strat = make_strategy(name, &ctx.tables, 1);
        let mut dec = SpecDecoder::new(
            &ctx.runtime,
            strat,
            EngineConfig { k, w, q: 1, max_new_tokens: max_new },
        );
        dec.collect_traces = true;
        let (tokens, calls, sim_s) = decode_all(&mut dec, &prompts, &cm)?;
        static_calls += calls;
        let tpc = tokens as f64 / calls.max(1) as f64;
        let sim_tps = tokens as f64 / sim_s;
        if tpc > best_static {
            best_static = tpc;
            best_static_name = name.label();
        }
        let label = format!("static {} ({k},{w})", name.label());
        println!("{label:<22} {tpc:>9.2} {calls:>7} {sim_tps:>12.1}");
        rows.push(Json::obj(vec![
            ("config", Json::Str(format!("static-{}", name.label()))),
            ("tokens_per_call", Json::Num(tpc)),
            ("calls", Json::Num(calls as f64)),
            ("sim_tokens_per_s", Json::Num(sim_tps)),
        ]));
    }

    // --- adaptive: same row cap, full depth range, bandit over the arms
    let ctrl = adaptive::controller_for(&ctx.tables, 1, &cache_cfg, &analog);
    let mut dec = SpecDecoder::with_controller(
        &ctx.runtime,
        ctrl,
        EngineConfig { k, w: w_cap, q: 1, max_new_tokens: max_new },
    );
    dec.collect_traces = true;
    // flight recorder on the adaptive run: the CI summary carries its
    // per-phase wall-clock totals as ungated extra fields
    let rec = FlightRecorder::standalone(0, DEFAULT_RING_CAPACITY);
    dec.recorder = Some(rec.clone());
    let mut arm_pulls = vec![0u64; DEFAULT_ARMS.len()];
    let mut arm_emitted = vec![0u64; DEFAULT_ARMS.len()];
    let mut kinds: BTreeMap<&'static str, (u64, u64, f64)> = BTreeMap::new();
    let mut tokens = 0usize;
    let mut calls = 0usize;
    let mut sim_s = 0.0f64;
    for p in &prompts {
        let r = dec.generate(&p.tokens)?;
        tokens += r.tokens.len().saturating_sub(1);
        calls += r.calls;
        sim_s += r
            .traces
            .iter()
            .map(|t| cm.call_time(t.k, t.w + 1, t.ctx_len))
            .sum::<f64>();
        // harvest per-arm / per-kind stats before the next generate resets
        let c = dec.controller.as_ref().expect("adaptive decoder");
        for (i, rep) in c.arm_reports().iter().enumerate() {
            arm_pulls[i] += rep.pulls;
            arm_emitted[i] += rep.emitted_total;
        }
        for (kind, s) in c.kind_reports() {
            let e = kinds.entry(kind.label()).or_insert((0, 0, 0.0));
            e.0 += s.wins;
            e.1 += s.accepted_total;
            e.2 = e.2.max(s.ewma_hit);
        }
    }
    let adaptive_tpc = tokens as f64 / calls.max(1) as f64;
    let adaptive_tps = tokens as f64 / sim_s;
    let label = format!("adaptive (<={k},<={w_cap})");
    println!("{label:<22} {adaptive_tpc:>9.2} {calls:>7} {adaptive_tps:>12.1}");
    println!(
        "\nbest static: {best_static_name} at {best_static:.2} tok/call; adaptive {}: \
         {adaptive_tpc:.2} tok/call",
        if adaptive_tpc >= best_static { "MATCHES/BEATS it" } else { "BELOW it" },
    );

    println!("\n-- adaptive arm statistics (summed over {} prompts) --", prompts.len());
    println!("{:<12} {:>7} {:>14}", "arm", "pulls", "mean emitted");
    let mut arm_json = Vec::new();
    for (i, name) in DEFAULT_ARMS.iter().enumerate() {
        let mean = arm_emitted[i] as f64 / (arm_pulls[i].max(1)) as f64;
        println!("{:<12} {:>7} {:>14.2}", name.label(), arm_pulls[i], mean);
        arm_json.push(Json::obj(vec![
            ("arm", Json::Str(name.label().into())),
            ("pulls", Json::Num(arm_pulls[i] as f64)),
            ("mean_emitted", Json::Num(mean)),
        ]));
    }
    println!("\n-- per-kind acceptance (wins / accepted tokens / peak hit-rate EWMA) --");
    for (label, (wins, accepted, hit)) in &kinds {
        println!("{:<14} {:>6} {:>8} {:>8.2}", label, wins, accepted, hit);
    }

    rows.push(Json::obj(vec![
        ("config", Json::Str("adaptive".into())),
        ("tokens_per_call", Json::Num(adaptive_tpc)),
        ("calls", Json::Num(calls as f64)),
        ("sim_tokens_per_s", Json::Num(adaptive_tps)),
    ]));

    // --- budgeted batched engine: adaptive sequences under a shared row
    // budget, vs the same engine unbudgeted
    let b = budget.unwrap_or(BATCH_CONC * k * 3 / 5); // 60% of the unbudgeted rows
    println!(
        "\n== budgeted batched engine (conc {BATCH_CONC}, row budget {b}, adaptive mode) =="
    );
    let budgeted =
        run_batched(ctx, &prompts, max_new, k, w_cap, Some(b), &cache_cfg, &analog, &cm)?;
    let unbudgeted =
        run_batched(ctx, &prompts, max_new, k, w_cap, None, &cache_cfg, &analog, &cm)?;
    println!(
        "{:<12} {:>9} {:>11} {:>11} {:>12}",
        "mode", "tok/call", "rows/step", "max rows", "sim tok/s"
    );
    for (label, r) in [("budget", &budgeted), ("unbudgeted", &unbudgeted)] {
        println!(
            "{:<12} {:>9.2} {:>11.1} {:>11} {:>12.1}",
            label, r.tokens_per_call, r.mean_rows, r.max_rows, r.sim_tps
        );
    }
    // the effective budget floors at one row per active sequence
    let limit = b.max(BATCH_CONC);
    anyhow::ensure!(
        budgeted.max_rows <= limit,
        "budget violated: packed {} rows in a step with budget {limit}",
        budgeted.max_rows
    );

    super::write_json(
        &format!("adaptive_{}", ctx.model),
        &Json::obj(vec![
            ("bench", Json::Str("adaptive".into())),
            ("model", Json::Str(ctx.model.clone())),
            ("max_new", Json::Num(max_new as f64)),
            ("n_prompts", Json::Num(prompts.len() as f64)),
            ("best_static", Json::Str(best_static_name.into())),
            ("best_static_tokens_per_call", Json::Num(best_static)),
            ("rows", Json::Arr(rows)),
            ("arms", Json::Arr(arm_json)),
            ("batch_budget", Json::Num(b as f64)),
            ("batch_budget_max_rows", Json::Num(budgeted.max_rows as f64)),
            ("batch_budget_tokens_per_call", Json::Num(budgeted.tokens_per_call)),
            ("batch_unbudgeted_tokens_per_call", Json::Num(unbudgeted.tokens_per_call)),
        ]),
    )?;
    // the CI bench-regression gate compares this summary against the
    // committed benches/baseline.json (`ngrammys ci-bench-check`);
    // phases + scenario_steps are ungated extras from the flight recorder
    let steps: Vec<TraceEvent> =
        rec.snapshot(DEFAULT_RING_CAPACITY).into_iter().map(TraceEvent::Step).collect();
    let scenario_steps = vec![
        ("static-total-calls".to_string(), Json::Num(static_calls as f64)),
        ("adaptive-steps".to_string(), Json::Num(rec.steps_recorded() as f64)),
        ("batch-budget-steps".to_string(), Json::Num(budgeted.steps as f64)),
        ("batch-unbudgeted-steps".to_string(), Json::Num(unbudgeted.steps as f64)),
    ];
    super::write_bench_summary_with(
        "adaptive",
        adaptive_tps,
        adaptive_tpc,
        super::accept_rate(tokens, calls),
        vec![
            ("phases", TraceSummary::from_events(&steps).phases_json()),
            ("scenario_steps", Json::Obj(scenario_steps)),
        ],
    )
}

/// Decode every prompt with one (reused) decoder; returns (decode tokens,
/// calls, simulated seconds at paper scale).
fn decode_all(
    dec: &mut SpecDecoder,
    prompts: &[Prompt],
    cm: &CostModel,
) -> Result<(usize, usize, f64)> {
    let mut tokens = 0usize;
    let mut calls = 0usize;
    let mut sim_s = 0.0f64;
    for p in prompts {
        let r = dec.generate(&p.tokens)?;
        tokens += r.tokens.len().saturating_sub(1);
        calls += r.calls;
        sim_s += r
            .traces
            .iter()
            .map(|t| cm.call_time(t.k, t.w + 1, t.ctx_len))
            .sum::<f64>();
    }
    Ok((tokens, calls, sim_s))
}

struct BatchedRun {
    tokens_per_call: f64,
    mean_rows: f64,
    max_rows: usize,
    sim_tps: f64,
    steps: usize,
}

#[allow(clippy::too_many_arguments)]
fn run_batched(
    ctx: &super::BenchCtx,
    prompts: &[Prompt],
    max_new: usize,
    k: usize,
    w_cap: usize,
    budget: Option<usize>,
    cache_cfg: &SessionCacheConfig,
    analog: &str,
    cm: &CostModel,
) -> Result<BatchedRun> {
    let cfg = EngineConfig { k, w: w_cap, q: 1, max_new_tokens: max_new };
    let mut eng = BatchedEngine::with_budget(&ctx.runtime, BATCH_CONC, budget);
    eng.collect_traces = true;
    let mut pending: Vec<&Prompt> = prompts.iter().collect();
    pending.reverse();
    let mut tokens = 0usize;
    let mut calls = 0usize;
    loop {
        while eng.has_capacity() {
            let Some(p) = pending.pop() else { break };
            let strat = make_strategy(StrategyName::Mixed, &ctx.tables, 1);
            let ctrl = adaptive::controller_for(&ctx.tables, 1, cache_cfg, analog);
            eng.admit_with(&p.tokens, strat, Some(ctrl), cfg.clone())?;
        }
        if eng.active() == 0 && pending.is_empty() {
            break;
        }
        for (_, r) in eng.step()? {
            tokens += r.tokens.len().saturating_sub(1);
            calls += r.calls;
        }
    }
    // per-step packed rows (a ragged step issues several packed calls)
    let mut per_step: BTreeMap<u64, usize> = BTreeMap::new();
    for t in &eng.packed_traces {
        *per_step.entry(t.step).or_insert(0) += t.rows;
    }
    let sim_s: f64 = eng
        .packed_traces
        .iter()
        .map(|t| cm.call_time(t.rows, t.w + 1, t.max_ctx))
        .sum();
    let n_steps = per_step.len().max(1);
    Ok(BatchedRun {
        tokens_per_call: tokens as f64 / calls.max(1) as f64,
        mean_rows: per_step.values().sum::<usize>() as f64 / n_steps as f64,
        max_rows: per_step.values().copied().max().unwrap_or(0),
        sim_tps: tokens as f64 / sim_s.max(1e-12),
        steps: per_step.len(),
    })
}
