//! Event-driven serving front-end: one reactor thread drives every
//! connection through non-blocking accept/read/write state machines.
//!
//! # Why a reactor
//!
//! The threaded front-end parks one OS thread per connection for the
//! whole request lifetime — including the decode, which can take
//! hundreds of milliseconds. A slow reader additionally pins its thread
//! inside `write_all`. The reactor inverts this: the only per-request
//! thread cost is the engine lane the scheduler already owns. The
//! reactor thread itself blocks in `epoll_wait` and wakes for exactly
//! three reasons: the listener is readable (accept), a connection is
//! readable/writable (advance its state machine), or an engine finished
//! a generation (eventfd wakeup from the completion callback).
//!
//! # The `Reactor` trait
//!
//! The event loop is generic over [`Reactor`], a minimal
//! registration + readiness interface shaped so a completion-based
//! backend (io_uring: registrations become SQEs, readiness becomes
//! CQEs) can slot in later without touching the connection state
//! machines. The only implementation today is [`EpollReactor`]
//! (level-triggered epoll via raw syscalls — the crate stays
//! dependency-free).
//!
//! ```
//! use std::net::{TcpListener, TcpStream};
//! use std::os::fd::AsRawFd;
//! use std::time::Duration;
//! use ngrammys::server::reactor::{EpollReactor, Event, Interest, Reactor};
//!
//! let listener = TcpListener::bind("127.0.0.1:0").unwrap();
//! let stream = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
//! let mut r = EpollReactor::new().unwrap();
//! r.register(stream.as_raw_fd(), 7, Interest::WRITABLE).unwrap();
//! let mut events: Vec<Event> = Vec::new();
//! r.wait(&mut events, Some(Duration::from_secs(1))).unwrap();
//! assert!(events.iter().any(|e| e.token == 7 && e.writable));
//! ```
//!
//! # Connection state machine
//!
//! ```text
//! accept ── over conn-cap? ──► best-effort 503, close
//!    │
//!    ▼
//! Reading ──[request complete or EOF]──► parse (shared parser, byte-
//!    │                                   identical 4xx) ──► route
//!    │                                        │
//!    │                     sync route (metrics/stats/...) or error
//!    │                                        │            │
//!    ▼                                        │            ▼
//! Dispatched ◄──[POST /generate submitted]────┘         Writing
//!    │   (scheduler runs it; reactor holds only a CancelToken)
//!    ▼
//! completion callback → eventfd → Writing ──[flushed]──► close
//! ```
//!
//! Request bytes are buffered per connection and handed to the *same*
//! [`super::parse_request_from`] the threaded front-end uses, over a
//! `Cursor`, once a completeness pre-check ([`request_ready`]) says the
//! request — or its framing violation — is fully present. The pre-check
//! mirrors the parser's caps, which also bounds the buffer: a
//! connection can never buffer more than the body cap plus the header
//! caps before the parser is invoked and settles the request.
//!
//! # Disconnects and cancellation
//!
//! EOF (or hangup) while **Reading** is not an error: the buffered
//! bytes are parsed as-is, so half-closing clients that send a request
//! and `shutdown(Write)` still get their response. EOF while
//! **Dispatched** means the client is gone: the request's
//! [`CancelToken`] is cancelled — the engine aborts the sequence and
//! frees its lane and KV pages within a step — and `disconnects` is
//! bumped. A write failure while **Writing** counts the same way.
//!
//! # Graceful shutdown
//!
//! When the stop flag is set the listener is deregistered, idle
//! (Reading) connections are dropped, and the loop keeps running until
//! every Dispatched/Writing connection has received and flushed its
//! response — in-flight requests always drain.

use std::collections::HashMap;
use std::fs::File;
use std::io::{self, Cursor, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::{AsRawFd, FromRawFd, OwnedFd, RawFd};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::{
    error_body, http_response, parse_request_from, Routed, Server, MAX_BODY_BYTES,
    MAX_HEADERS, MAX_HEADER_LINE_BYTES,
};
use crate::scheduler::{CancelToken, GenResponse, ReplySink};
use crate::trace::ConnEvent;

/// I/O readiness a file descriptor is registered for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    /// wake when the fd is readable
    pub readable: bool,
    /// wake when the fd is writable
    pub writable: bool,
}

impl Interest {
    /// readable only
    pub const READABLE: Interest = Interest { readable: true, writable: false };
    /// writable only
    pub const WRITABLE: Interest = Interest { readable: false, writable: true };
    /// no readiness at all — keep the registration but stay quiet
    /// (fatal conditions are still delivered)
    pub const NONE: Interest = Interest { readable: false, writable: false };
}

/// One readiness event delivered by [`Reactor::wait`].
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// the token the fd was registered with
    pub token: u64,
    /// fd is readable
    pub readable: bool,
    /// fd is writable
    pub writable: bool,
    /// peer hung up (full close or write-half shutdown)
    pub hangup: bool,
    /// fd is in an error state
    pub error: bool,
}

/// Minimal readiness-notification interface the serving event loop runs
/// on. Registrations carry a caller-chosen `token` echoed back in each
/// [`Event`]. The shape — register/modify/deregister plus a blocking
/// wait that fills a completion batch — is deliberately io_uring-like
/// so a submission/completion-ring backend can implement it without the
/// event loop changing.
pub trait Reactor {
    /// Start watching `fd` with `interest`, tagging its events `token`.
    fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()>;
    /// Change the interest set (and token) of an already-watched fd.
    fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()>;
    /// Stop watching `fd`.
    fn deregister(&mut self, fd: RawFd) -> io::Result<()>;
    /// Block until at least one event or the timeout elapses (`None` =
    /// forever); `out` is cleared and refilled. Returns the event count.
    fn wait(&mut self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<usize>;
}

// ---------------------------------------------------------------------
// epoll backend (raw syscalls; the crate has no libc dependency)
// ---------------------------------------------------------------------

const EPOLL_CLOEXEC: i32 = 0x80000;
const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;
const EPOLLIN: u32 = 0x1;
const EPOLLOUT: u32 = 0x4;
const EPOLLERR: u32 = 0x8;
const EPOLLHUP: u32 = 0x10;
const EPOLLRDHUP: u32 = 0x2000;
const EFD_NONBLOCK: i32 = 0x800;
const EFD_CLOEXEC: i32 = 0x80000;

/// Matches the kernel's `struct epoll_event`, which is packed on x86-64.
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
}

fn events_bits(i: Interest) -> u32 {
    let mut bits = 0;
    if i.readable {
        bits |= EPOLLIN | EPOLLRDHUP;
    }
    if i.writable {
        bits |= EPOLLOUT;
    }
    bits
}

/// Level-triggered epoll [`Reactor`] — the production backend.
pub struct EpollReactor {
    epfd: OwnedFd,
}

impl EpollReactor {
    /// A fresh epoll instance (close-on-exec).
    pub fn new() -> io::Result<Self> {
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(EpollReactor { epfd: unsafe { OwnedFd::from_raw_fd(fd) } })
    }

    fn ctl(&self, op: i32, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        let mut ev = EpollEvent { events: events_bits(interest), data: token };
        if unsafe { epoll_ctl(self.epfd.as_raw_fd(), op, fd, &mut ev) } < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }
}

impl Reactor for EpollReactor {
    fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, token, interest)
    }

    fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, token, interest)
    }

    fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, Interest::NONE)
    }

    fn wait(&mut self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<usize> {
        out.clear();
        let mut buf = [EpollEvent { events: 0, data: 0 }; 64];
        let tmo = timeout.map_or(-1, |d| d.as_millis().min(i32::MAX as u128) as i32);
        let n = loop {
            let n = unsafe { epoll_wait(self.epfd.as_raw_fd(), buf.as_mut_ptr(), 64, tmo) };
            if n >= 0 {
                break n as usize;
            }
            let e = io::Error::last_os_error();
            if e.kind() != io::ErrorKind::Interrupted {
                return Err(e);
            }
        };
        for ev in buf.iter().take(n) {
            let bits = ev.events;
            out.push(Event {
                token: ev.data,
                readable: bits & EPOLLIN != 0,
                writable: bits & EPOLLOUT != 0,
                hangup: bits & (EPOLLHUP | EPOLLRDHUP) != 0,
                error: bits & EPOLLERR != 0,
            });
        }
        Ok(out.len())
    }
}

// ---------------------------------------------------------------------
// completion plumbing: engine worker -> reactor thread
// ---------------------------------------------------------------------

type Completion = (u64, Result<GenResponse>);

/// Finished generations en route from engine workers to the reactor.
/// `push` runs on the worker thread: it appends the completion record
/// and writes one eventfd wakeup (both non-blocking), which is the
/// entire cross-thread cost per request.
struct Completions {
    q: Mutex<Vec<Completion>>,
    wake_fd: File,
}

impl Completions {
    fn new() -> io::Result<Self> {
        let fd = unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Completions { q: Mutex::new(Vec::new()), wake_fd: unsafe { File::from_raw_fd(fd) } })
    }

    fn push(&self, token: u64, r: Result<GenResponse>) {
        self.q.lock().unwrap().push((token, r));
        let _ = (&self.wake_fd).write_all(&1u64.to_ne_bytes());
    }

    fn drain(&self) -> Vec<Completion> {
        std::mem::take(&mut *self.q.lock().unwrap())
    }

    /// Reset the eventfd counter (reading it zeroes it).
    fn drain_wake(&self) {
        let mut b = [0u8; 8];
        let _ = (&self.wake_fd).read(&mut b);
    }
}

// ---------------------------------------------------------------------
// request completeness pre-check
// ---------------------------------------------------------------------

/// Byte offset just past the header-terminating blank line, if present.
/// Accepts `\r\n\r\n`, `\n\n`, and the mixed `\n\r\n` the line parser
/// also treats as a terminator.
fn header_end(buf: &[u8]) -> Option<usize> {
    for i in 0..buf.len() {
        if buf[i] == b'\n' {
            match buf.get(i + 1) {
                Some(b'\n') => return Some(i + 2),
                Some(b'\r') if buf.get(i + 2) == Some(&b'\n') => return Some(i + 3),
                _ => {}
            }
        }
    }
    None
}

/// True when some line already exceeds the header-line cap — terminated
/// or not, the parser is guaranteed to settle it with a 431.
fn line_overflow(buf: &[u8]) -> bool {
    let mut start = 0;
    for (i, &b) in buf.iter().enumerate() {
        if b == b'\n' {
            if i + 1 - start > MAX_HEADER_LINE_BYTES {
                return true;
            }
            start = i + 1;
        }
    }
    buf.len() - start > MAX_HEADER_LINE_BYTES
}

/// What the Content-Length prescan concluded about a complete header
/// block.
enum Prescan {
    /// a valid Content-Length: the body is `n` bytes
    Body(usize),
    /// no Content-Length header present
    Absent,
    /// the parser will reject the framing (invalid or over-cap value) —
    /// no point waiting for a body that cannot be accepted
    Settles,
}

/// Scan the raw header block for Content-Length the same way the parser
/// does: every occurrence is validated in order (the first invalid one
/// is where the parser errors), the last valid one wins.
fn content_length_prescan(head: &[u8]) -> Prescan {
    let text = String::from_utf8_lossy(head);
    let mut found = Prescan::Absent;
    // skip the request line; stop at the blank terminator
    for line in text.split('\n').skip(1) {
        let line = line.trim_end_matches('\r');
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                match v.trim().parse::<usize>() {
                    Ok(n) if n <= MAX_BODY_BYTES => found = Prescan::Body(n),
                    _ => return Prescan::Settles,
                }
            }
        }
    }
    found
}

/// Whether the buffered bytes are ready to hand to the parser: either
/// the request is fully present, or the parser is guaranteed to settle
/// it conclusively (framing violation, EOF). Until this returns true
/// the connection just keeps reading — and the same caps the parser
/// enforces bound how much it can ever buffer.
fn request_ready(buf: &[u8], eof: bool) -> bool {
    if eof {
        return true;
    }
    match header_end(buf) {
        Some(end) => match content_length_prescan(&buf[..end]) {
            Prescan::Body(n) => buf.len() >= end + n,
            // parser answers immediately: 411 for bodied methods, or an
            // empty body — either way no more bytes are needed
            Prescan::Absent => true,
            Prescan::Settles => true,
        },
        None => {
            // headers still streaming in; parse early only when a cap
            // is already blown (the parser will 431 without the rest)
            line_overflow(buf)
                || buf.iter().filter(|&&b| b == b'\n').count() > MAX_HEADERS + 1
        }
    }
}

// ---------------------------------------------------------------------
// the event loop
// ---------------------------------------------------------------------

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// Stop-flag poll cadence; everything else wakes the loop immediately.
const TICK: Duration = Duration::from_millis(25);

enum ConnState {
    /// buffering request bytes until [`request_ready`]
    Reading,
    /// a `/generate` is running in the scheduler; on disconnect the
    /// token is cancelled so the engine frees the lane within a step
    Dispatched { cancel: CancelToken },
    /// flushing the response; `off` tracks partial writes
    Writing { resp: Vec<u8>, off: usize, t_write: Instant },
}

struct Conn {
    stream: TcpStream,
    buf: Vec<u8>,
    state: ConnState,
    t_accept: Instant,
    /// when the request was fully read and parsed (ConnRead phase end)
    read_done: Option<Instant>,
    bytes_in: u64,
    /// the client half-closed; suppress readiness so level-triggered
    /// EOF does not busy-loop while the response is produced
    saw_eof: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Self {
        Conn {
            stream,
            buf: Vec::new(),
            state: ConnState::Reading,
            t_accept: Instant::now(),
            read_done: None,
            bytes_in: 0,
            saw_eof: false,
        }
    }
}

/// What parsing a complete request decided (split out so the borrow of
/// the connection's buffer ends before the state transition).
enum Parsed {
    Respond(&'static str, String, &'static str),
    InFlight(CancelToken),
}

struct EventLoop<R: Reactor> {
    r: R,
    me: Arc<Server>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    completions: Arc<Completions>,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    accepting: bool,
}

/// Run the reactor front-end on `listener` until `stop` is set and the
/// in-flight connections have drained.
pub(crate) fn serve(me: Arc<Server>, listener: TcpListener, stop: Arc<AtomicBool>) -> Result<()> {
    let el = EventLoop {
        r: EpollReactor::new()?,
        me,
        listener,
        stop,
        completions: Arc::new(Completions::new()?),
        conns: HashMap::new(),
        next_token: FIRST_CONN_TOKEN,
        accepting: true,
    };
    el.run()
}

impl<R: Reactor> EventLoop<R> {
    fn run(mut self) -> Result<()> {
        self.listener.set_nonblocking(true)?;
        self.r.register(self.listener.as_raw_fd(), TOKEN_LISTENER, Interest::READABLE)?;
        self.r
            .register(self.completions.wake_fd.as_raw_fd(), TOKEN_WAKER, Interest::READABLE)?;
        let mut events = Vec::new();
        loop {
            if self.stop.load(Ordering::SeqCst) {
                if self.accepting {
                    self.begin_drain();
                }
                if self.conns.is_empty() {
                    return Ok(());
                }
            }
            self.r.wait(&mut events, Some(TICK))?;
            for ev in events.drain(..) {
                match ev.token {
                    TOKEN_LISTENER => {
                        if self.accepting {
                            self.on_accept();
                        }
                    }
                    TOKEN_WAKER => self.on_wake(),
                    _ => self.on_conn_event(ev),
                }
            }
        }
    }

    /// Stop accepting and drop idle connections; Dispatched/Writing
    /// ones keep running until their responses are flushed.
    fn begin_drain(&mut self) {
        let _ = self.r.deregister(self.listener.as_raw_fd());
        self.accepting = false;
        let r = &mut self.r;
        self.conns.retain(|_, c| {
            if matches!(c.state, ConnState::Reading) {
                let _ = r.deregister(c.stream.as_raw_fd());
                false
            } else {
                true
            }
        });
    }

    fn on_accept(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let metrics = &self.me.scheduler.metrics;
                    metrics.connections_total.fetch_add(1, Ordering::Relaxed);
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    if self.conns.len() >= self.me.cfg.conn_cap.max(1) {
                        // over capacity: answer 503 best-effort and close
                        // (the body almost always fits the socket buffer)
                        let body = error_body(format!(
                            "server at connection capacity ({} open connections)",
                            self.conns.len()
                        ));
                        let resp = http_response("503 Service Unavailable", "application/json", &body);
                        let _ = (&stream).write_all(resp.as_bytes());
                        continue;
                    }
                    let token = self.next_token;
                    self.next_token += 1;
                    if self.r.register(stream.as_raw_fd(), token, Interest::READABLE).is_err() {
                        continue;
                    }
                    self.conns.insert(token, Conn::new(stream));
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
    }

    /// Deliver finished generations: look the connection up by token
    /// (it may be gone — the client disconnected and the engine's abort
    /// raced its last step) and start writing the response the threaded
    /// front-end would have written byte-for-byte.
    fn on_wake(&mut self) {
        self.completions.drain_wake();
        for (token, result) in self.completions.drain() {
            let Some(mut conn) = self.conns.remove(&token) else { continue };
            let (status, body, ctype) = match result {
                Ok(resp) => {
                    ("200 OK", self.me.render_generate(&resp).to_string(), "application/json")
                }
                Err(e) => ("400 Bad Request", error_body(format!("{e:#}")), "application/json"),
            };
            if self.respond(token, &mut conn, status, ctype, &body) {
                self.conns.insert(token, conn);
            }
        }
    }

    fn on_conn_event(&mut self, ev: Event) {
        let Some(mut conn) = self.conns.remove(&ev.token) else { return };
        let alive = if matches!(conn.state, ConnState::Reading) {
            self.conn_read(ev, &mut conn)
        } else if matches!(conn.state, ConnState::Dispatched { .. }) {
            self.conn_dispatched(ev, &mut conn)
        } else if ev.error {
            self.drop_conn(&mut conn, true);
            false
        } else {
            self.flush(ev.token, &mut conn)
        };
        if alive {
            self.conns.insert(ev.token, conn);
        }
    }

    /// Reading state: pull bytes until the socket would block (or EOF),
    /// then hand off to the parser once the request is ready.
    fn conn_read(&mut self, ev: Event, conn: &mut Conn) -> bool {
        let mut eof = false;
        if ev.readable || ev.hangup || ev.error {
            let mut tmp = [0u8; 16 * 1024];
            loop {
                match conn.stream.read(&mut tmp) {
                    Ok(0) => {
                        eof = true;
                        break;
                    }
                    Ok(n) => {
                        conn.bytes_in += n as u64;
                        conn.buf.extend_from_slice(&tmp[..n]);
                    }
                    Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        eof = true;
                        break;
                    }
                }
            }
        }
        conn.saw_eof |= eof;
        if !request_ready(&conn.buf, eof) {
            return true;
        }
        if conn.buf.is_empty() && eof {
            // opened and closed without sending a byte
            self.drop_conn(conn, true);
            return false;
        }
        self.try_dispatch(ev.token, conn)
    }

    /// Parse the buffered request with the shared parser and act on the
    /// routing decision.
    fn try_dispatch(&mut self, token: u64, conn: &mut Conn) -> bool {
        conn.read_done = Some(Instant::now());
        let parsed = {
            let mut cur = Cursor::new(conn.buf.as_slice());
            match parse_request_from(&mut cur) {
                Err(e) => Parsed::Respond(e.status, error_body(e.msg), "application/json"),
                Ok(req) => match self.me.route_pre(&req) {
                    Routed::Ready(s, b, c) => Parsed::Respond(s, b, c),
                    Routed::Generate(body) => match self.dispatch_generate(token, &body) {
                        Ok(cancel) => Parsed::InFlight(cancel),
                        Err(e) => Parsed::Respond(
                            "400 Bad Request",
                            error_body(format!("{e:#}")),
                            "application/json",
                        ),
                    },
                },
            }
        };
        match parsed {
            Parsed::Respond(status, body, ctype) => self.respond(token, conn, status, ctype, &body),
            Parsed::InFlight(cancel) => {
                conn.state = ConnState::Dispatched { cancel };
                // a half-closed client can't disconnect any further:
                // watch nothing, or level-triggered EOF would spin
                let interest =
                    if conn.saw_eof { Interest::NONE } else { Interest::READABLE };
                let _ = self.r.modify(conn.stream.as_raw_fd(), token, interest);
                true
            }
        }
    }

    /// Parse the generate body and submit it with a callback sink; the
    /// error strings (bad json / empty prompt / queue full / ...) reach
    /// the client exactly as the threaded front-end reports them.
    fn dispatch_generate(&self, token: u64, body: &str) -> Result<CancelToken> {
        let req = self.me.parse_generate(body)?;
        let cancel = CancelToken::new();
        let comp = self.completions.clone();
        let sink = ReplySink::Callback(Box::new(move |r| comp.push(token, r)));
        self.me.scheduler.submit_with(req, sink, cancel.clone())?;
        Ok(cancel)
    }

    /// Dispatched state: the only readiness we expect is the client
    /// vanishing — drain (discarding stray bytes) and cancel on EOF.
    fn conn_dispatched(&mut self, ev: Event, conn: &mut Conn) -> bool {
        let mut gone = ev.error;
        if ev.readable || ev.hangup {
            let mut tmp = [0u8; 4096];
            loop {
                match conn.stream.read(&mut tmp) {
                    Ok(0) => {
                        gone = true;
                        break;
                    }
                    Ok(_) => continue,
                    Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        gone = true;
                        break;
                    }
                }
            }
        }
        if gone {
            if let ConnState::Dispatched { cancel } = &conn.state {
                cancel.cancel();
            }
            self.drop_conn(conn, true);
            return false;
        }
        true
    }

    /// Transition to Writing and flush as much as the socket takes.
    fn respond(
        &mut self,
        token: u64,
        conn: &mut Conn,
        status: &'static str,
        ctype: &'static str,
        body: &str,
    ) -> bool {
        conn.state = ConnState::Writing {
            resp: http_response(status, ctype, body).into_bytes(),
            off: 0,
            t_write: Instant::now(),
        };
        self.flush(token, conn)
    }

    /// Writing state: write until done (close), the socket would block
    /// (wait for EPOLLOUT), or the client is gone.
    fn flush(&mut self, token: u64, conn: &mut Conn) -> bool {
        let ConnState::Writing { resp, off, t_write } = &mut conn.state else {
            return true;
        };
        loop {
            if *off >= resp.len() {
                // response fully flushed: record the connection span and
                // close (every response is Connection: close)
                let read_us = conn
                    .read_done
                    .map_or(0, |t| t.duration_since(conn.t_accept).as_micros() as u64);
                let ev = ConnEvent {
                    t_us: 0, // stamped by the hub
                    read_us,
                    write_us: t_write.elapsed().as_micros() as u64,
                    bytes_in: conn.bytes_in,
                    bytes_out: resp.len() as u64,
                };
                self.me.scheduler.trace.record_conn(ev);
                let _ = self.r.deregister(conn.stream.as_raw_fd());
                return false;
            }
            match conn.stream.write(&resp[*off..]) {
                Ok(0) => {
                    self.me.scheduler.metrics.disconnects.fetch_add(1, Ordering::Relaxed);
                    let _ = self.r.deregister(conn.stream.as_raw_fd());
                    return false;
                }
                Ok(n) => *off += n,
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                    let _ = self.r.modify(conn.stream.as_raw_fd(), token, Interest::WRITABLE);
                    return true;
                }
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.me.scheduler.metrics.disconnects.fetch_add(1, Ordering::Relaxed);
                    let _ = self.r.deregister(conn.stream.as_raw_fd());
                    return false;
                }
            }
        }
    }

    /// Deregister and count the drop; the socket closes when the
    /// connection is not re-inserted into the map.
    fn drop_conn(&mut self, conn: &mut Conn, disconnected: bool) {
        if disconnected {
            self.me.scheduler.metrics.disconnects.fetch_add(1, Ordering::Relaxed);
        }
        let _ = self.r.deregister(conn.stream.as_raw_fd());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_end_accepts_every_terminator_spelling() {
        assert_eq!(header_end(b"GET / HTTP/1.1\r\nHost: x\r\n\r\nbody"), Some(27));
        assert_eq!(header_end(b"GET / HTTP/1.1\nHost: x\n\nbody"), Some(24));
        assert_eq!(header_end(b"GET / HTTP/1.1\nHost: x\n\r\n"), Some(25));
        assert_eq!(header_end(b"\r\n\r\n"), Some(4));
        assert_eq!(header_end(b"GET / HTTP/1.1\r\nHost: x\r\n"), None);
        assert_eq!(header_end(b""), None);
    }

    #[test]
    fn request_ready_tracks_the_parser_caps() {
        // incomplete headers: wait
        assert!(!request_ready(b"POST /generate HTTP/1.1\r\n", false));
        // complete headers + full body: ready
        let full = b"POST /g HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcd";
        assert!(request_ready(full, false));
        // declared body still streaming: wait
        let partial = b"POST /g HTTP/1.1\r\nContent-Length: 9\r\n\r\nabcd";
        assert!(!request_ready(partial, false));
        // EOF settles anything
        assert!(request_ready(partial, true));
        assert!(request_ready(b"", true));
        // no Content-Length: the parser answers (411 or empty body)
        assert!(request_ready(b"POST /g HTTP/1.1\r\nHost: x\r\n\r\n", false));
        assert!(request_ready(b"GET /healthz HTTP/1.1\r\n\r\n", false));
        // invalid / over-cap Content-Length: no point waiting for a body
        assert!(request_ready(b"POST /g HTTP/1.1\r\nContent-Length: banana\r\n\r\n", false));
        let huge = format!("POST /g HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(request_ready(huge.as_bytes(), false));
        // a header line over the cap settles as 431 without its newline
        let mut long = b"GET / HTTP/1.1\r\nX-Big: ".to_vec();
        long.extend(std::iter::repeat(b'a').take(MAX_HEADER_LINE_BYTES + 1));
        assert!(request_ready(&long, false));
        assert!(line_overflow(&long));
    }

    #[test]
    fn prescan_matches_parser_semantics_on_repeated_content_length() {
        // last valid value wins, like the parser's overwrite loop
        let head = b"POST /g HTTP/1.1\r\nContent-Length: 3\r\nContent-Length: 5\r\n\r\n";
        match content_length_prescan(head) {
            Prescan::Body(5) => {}
            _ => panic!("expected Body(5)"),
        }
        // an invalid occurrence settles immediately, like the parser's error
        let head = b"POST /g HTTP/1.1\r\nContent-Length: x\r\nContent-Length: 5\r\n\r\n";
        assert!(matches!(content_length_prescan(head), Prescan::Settles));
    }

    /// The reactor's parse path (shared parser over a Cursor on the
    /// buffered bytes) must produce the same pinned statuses the
    /// threaded front-end produces on the hardened-request corpus.
    #[test]
    fn buffered_parse_reproduces_pinned_hardening_statuses() {
        let parse = |raw: &str| {
            let mut cur = Cursor::new(raw.as_bytes());
            parse_request_from(&mut cur)
        };
        let status = |raw: &str| parse(raw).unwrap_err().status;
        assert_eq!(
            status("POST /generate HTTP/1.1\r\nHost: x\r\n\r\n{\"prompt\": \"hi\"}"),
            "411 Length Required"
        );
        assert_eq!(
            status("POST /generate HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n"),
            "413 Payload Too Large"
        );
        assert_eq!(
            status("POST /generate HTTP/1.1\r\nContent-Length: banana\r\n\r\n"),
            "400 Bad Request"
        );
        assert_eq!(
            status("POST /generate HTTP/1.1\r\nContent-Length: 50\r\n\r\n{\"a\":1}"),
            "400 Bad Request"
        );
        assert_eq!(status("\r\n\r\n"), "400 Bad Request");
        let ok = parse("POST /generate HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi").unwrap();
        assert_eq!((ok.method.as_str(), ok.path.as_str(), ok.body.as_str()), ("POST", "/generate", "hi"));
    }

    #[test]
    fn epoll_reactor_delivers_readiness_and_modify_works() {
        use std::net::{TcpListener, TcpStream};
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (served, _) = listener.accept().unwrap();

        let mut r = EpollReactor::new().unwrap();
        let mut events = Vec::new();
        // a fresh socket is writable but not readable
        r.register(client.as_raw_fd(), 9, Interest::WRITABLE).unwrap();
        r.wait(&mut events, Some(Duration::from_secs(2))).unwrap();
        assert!(events.iter().any(|e| e.token == 9 && e.writable));

        // switch interest to readable; it fires once the peer writes
        r.modify(client.as_raw_fd(), 9, Interest::READABLE).unwrap();
        r.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
        assert!(!events.iter().any(|e| e.readable), "nothing to read yet");
        (&served).write_all(b"ping").unwrap();
        r.wait(&mut events, Some(Duration::from_secs(2))).unwrap();
        assert!(events.iter().any(|e| e.token == 9 && e.readable));

        // deregister silences the fd entirely
        r.deregister(client.as_raw_fd()).unwrap();
        r.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
        assert!(events.is_empty(), "{events:?}");
    }

    #[test]
    fn eventfd_completions_wake_and_drain() {
        let comp = Completions::new().unwrap();
        let mut r = EpollReactor::new().unwrap();
        r.register(comp.wake_fd.as_raw_fd(), TOKEN_WAKER, Interest::READABLE).unwrap();
        let mut events = Vec::new();
        r.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
        assert!(events.is_empty(), "no completion pushed yet");
        comp.push(42, Err(anyhow::anyhow!("x")));
        r.wait(&mut events, Some(Duration::from_secs(2))).unwrap();
        assert!(events.iter().any(|e| e.token == TOKEN_WAKER && e.readable));
        comp.drain_wake();
        let drained = comp.drain();
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].0, 42);
        // counter reset: no stale wakeups
        r.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
        assert!(events.is_empty(), "{events:?}");
    }
}
