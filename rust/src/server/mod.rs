//! Minimal HTTP/1.1 serving front-end on std::net (no tokio/hyper offline).
//!
//! Endpoints:
//!   POST /generate   {"prompt": str, "max_tokens"?: int, "k"?: int,
//!                     "w"?: int, "strategy"?: str}
//!                 -> {"text": str, "tokens": int, "tokens_per_call": f,
//!                     "calls": int, "latency_ms": f}
//!   GET  /metrics    prometheus-style text, including the per-strategy
//!                    win/accepted-token counters (which draft source is
//!                    actually paying for its rows) and the ttft /
//!                    inter-token / per-phase latency quantiles
//!   GET  /stats      JSON latency summary: request counts plus
//!                    ttft/inter-token/request-latency digests and
//!                    per-phase quantiles from the same histograms
//!   GET  /trace?n=K  the last K flight-recorder events (decode steps +
//!                    request spans, merged across engines) as JSONL —
//!                    replayable by `ngrammys trace --input`
//!   GET  /healthz    "ok"
//!
//! Requests that don't name a strategy get `ServeConfig::default_strategy`
//! (`ngrammys serve --strategy adaptive` makes online (k, w) + strategy
//! selection the server default; per-request `"strategy"` still wins).
//!
//! One thread per connection (bounded by the scheduler's queue for actual
//! work); keep-alive is not supported — every response closes the socket,
//! which keeps the parser tiny and is plenty for the benchmark driver.
//!
//! Request hardening: the parser enforces a body-size cap (1 MiB), header
//! count/size caps, and a valid Content-Length on POST. Violations get a
//! proper 4xx JSON error response ({"error": ...}) instead of a dropped
//! connection. Routing errors are JSON too: an unknown path is a 404 and
//! a known path hit with the wrong method is a 405 naming the method it
//! supports.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::{EngineConfig, ServeConfig};
use crate::scheduler::{GenRequest, Scheduler, StrategyName};
use crate::tokenizer::BpeTokenizer;
use crate::trace::to_jsonl;
use crate::util::json::Json;

/// How many flight-recorder events `GET /trace` returns when the request
/// doesn't pass `?n=K`.
pub const DEFAULT_TRACE_EVENTS: usize = 256;

/// HTTP front-end: the scheduler handle, tokenizer and settings one
/// accept loop serves.
pub struct Server {
    /// request scheduler handle
    pub scheduler: Arc<Scheduler>,
    /// shared tokenizer
    pub tokenizer: Arc<BpeTokenizer>,
    /// serving settings (defaults for /generate)
    pub cfg: ServeConfig,
}

impl Server {
    /// Blocking accept loop. Binds `cfg.addr`; call from main.
    pub fn run(self) -> Result<()> {
        let listener = TcpListener::bind(&self.cfg.addr)?;
        eprintln!("ngrammys serving on http://{}", self.cfg.addr);
        let me = Arc::new(self);
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let me = me.clone();
            std::thread::spawn(move || {
                if let Err(e) = me.handle(stream) {
                    eprintln!("connection error: {e:#}");
                }
            });
        }
        Ok(())
    }

    /// Bind and serve in a background thread; returns the bound address
    /// (useful with port 0 in tests).
    pub fn spawn(self) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<()>)> {
        let listener = TcpListener::bind(&self.cfg.addr)?;
        let addr = listener.local_addr()?;
        let me = Arc::new(self);
        let handle = std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { continue };
                let me = me.clone();
                std::thread::spawn(move || {
                    let _ = me.handle(stream);
                });
            }
        });
        Ok((addr, handle))
    }

    fn handle(&self, mut stream: TcpStream) -> Result<()> {
        let (status, body, ctype) = match parse_request(&mut stream) {
            Ok(req) => self.route(&req),
            Err(e) => (
                e.status,
                Json::obj(vec![("error", Json::Str(e.msg))]).to_string(),
                "application/json",
            ),
        };
        let resp = format!(
            "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        stream.write_all(resp.as_bytes())?;
        Ok(())
    }

    fn route(&self, req: &HttpRequest) -> (&'static str, String, &'static str) {
        // the request target may carry a query string; route on the bare
        // path so `/trace?n=64` still hits `/trace`
        let (path, query) = match req.path.split_once('?') {
            Some((p, q)) => (p, q),
            None => (req.path.as_str(), ""),
        };
        let err = |msg: String| Json::obj(vec![("error", Json::Str(msg))]).to_string();
        // every known path serves exactly one method: anything else on it
        // is a 405 naming the method it supports, an unknown path is a 404
        let allowed = match path {
            "/healthz" | "/metrics" | "/stats" | "/trace" => "GET",
            "/generate" => "POST",
            _ => {
                return ("404 Not Found", err(format!("no such path: {path}")), "application/json")
            }
        };
        if req.method != allowed {
            let msg = format!("{path} only supports {allowed}, got {}", req.method);
            return ("405 Method Not Allowed", err(msg), "application/json");
        }
        match path {
            "/healthz" => ("200 OK", "ok\n".into(), "text/plain"),
            "/metrics" => ("200 OK", self.scheduler.metrics.render(), "text/plain"),
            "/stats" => {
                ("200 OK", self.scheduler.metrics.stats_json().to_string(), "application/json")
            }
            "/trace" => {
                let n = query_param(query, "n")
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(DEFAULT_TRACE_EVENTS);
                let events = self.scheduler.trace.recent(n);
                ("200 OK", to_jsonl(&events), "application/x-ndjson")
            }
            "/generate" => match self.generate(&req.body) {
                Ok(j) => ("200 OK", j.to_string(), "application/json"),
                Err(e) => ("400 Bad Request", err(format!("{e:#}")), "application/json"),
            },
            _ => unreachable!("every path in the allow table is matched above"),
        }
    }

    fn generate(&self, body: &str) -> Result<Json> {
        let j = Json::parse(body).map_err(|e| anyhow!("bad json: {e}"))?;
        let prompt_text = j
            .req("prompt")?
            .as_str()
            .ok_or_else(|| anyhow!("'prompt' must be a string"))?;
        let d = &self.cfg.default_engine;
        let engine = EngineConfig {
            k: j.get("k").and_then(|v| v.as_usize()).unwrap_or(d.k),
            w: j.get("w").and_then(|v| v.as_usize()).unwrap_or(d.w),
            q: j.get("q").and_then(|v| v.as_usize()).unwrap_or(d.q),
            max_new_tokens: j
                .get("max_tokens")
                .and_then(|v| v.as_usize())
                .unwrap_or(d.max_new_tokens),
        };
        let strategy = match j.get("strategy").and_then(|v| v.as_str()) {
            Some(s) => StrategyName::parse(s)?,
            None => self.cfg.default_strategy,
        };
        let prompt = self.tokenizer.encode(prompt_text);
        if prompt.is_empty() {
            return Err(anyhow!("empty prompt"));
        }
        let resp = self.scheduler.generate(GenRequest { prompt, engine, strategy })?;
        Ok(Json::obj(vec![
            ("text", Json::Str(self.tokenizer.decode(&resp.tokens))),
            ("tokens", Json::Num(resp.tokens.len() as f64)),
            ("calls", Json::Num(resp.calls as f64)),
            ("tokens_per_call", Json::Num(resp.tokens_per_call)),
            ("latency_ms", Json::Num(resp.latency_ms)),
        ]))
    }
}

/// First value of `key` in a URL query string (`"a=1&b=2"`), `None` when
/// absent. Values are taken verbatim — no percent-decoding, which is fine
/// for the numeric parameters the server defines.
fn query_param<'a>(query: &'a str, key: &str) -> Option<&'a str> {
    query.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=')?;
        (k == key).then_some(v)
    })
}

/// One parsed HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    /// request method (GET, POST, ...)
    pub method: String,
    /// request path
    pub path: String,
    /// request body (empty when no Content-Length)
    pub body: String,
}

/// Largest request body the server accepts (absurd Content-Lengths are
/// rejected with 413 instead of attempting the allocation).
pub const MAX_BODY_BYTES: usize = 1 << 20;
const MAX_HEADER_LINE_BYTES: usize = 8 * 1024;
const MAX_HEADERS: usize = 100;

/// A request-parse failure with the HTTP status it should be reported as.
#[derive(Debug)]
pub struct HttpError {
    /// HTTP status line to report (e.g. "400 Bad Request")
    pub status: &'static str,
    /// human-readable error detail (returned as JSON)
    pub msg: String,
}

impl HttpError {
    fn new(status: &'static str, msg: impl Into<String>) -> Self {
        HttpError { status, msg: msg.into() }
    }
}

/// Read one CRLF-terminated line without ever buffering more than `cap`
/// bytes — a client streaming an endless unterminated line is cut off at
/// the cap instead of growing the allocation until OOM.
fn read_line_capped<R: BufRead>(
    reader: &mut R,
    cap: usize,
) -> std::result::Result<String, HttpError> {
    let mut buf = Vec::new();
    let mut limited = Read::take(reader.by_ref(), cap as u64 + 1);
    limited
        .read_until(b'\n', &mut buf)
        .map_err(|e| HttpError::new("400 Bad Request", format!("malformed request: {e}")))?;
    if buf.len() > cap {
        return Err(HttpError::new(
            "431 Request Header Fields Too Large",
            format!("header line exceeds the {cap}-byte limit"),
        ));
    }
    Ok(String::from_utf8_lossy(&buf).into_owned())
}

/// Parse one HTTP/1.1 request from `stream`, enforcing the header and
/// body caps; violations carry the 4xx status they should produce.
pub fn parse_request(stream: &mut TcpStream) -> std::result::Result<HttpRequest, HttpError> {
    let bad = |msg: String| HttpError::new("400 Bad Request", msg);
    let mut reader = BufReader::new(stream);
    let line = read_line_capped(&mut reader, MAX_HEADER_LINE_BYTES)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        return Err(bad("malformed request line".to_string()));
    }

    let mut content_length: Option<usize> = None;
    let mut n_headers = 0usize;
    loop {
        let h = read_line_capped(&mut reader, MAX_HEADER_LINE_BYTES)?;
        if h.is_empty() {
            // EOF before the blank line terminating the header block
            return Err(bad("truncated request: headers not terminated".to_string()));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        n_headers += 1;
        if n_headers > MAX_HEADERS {
            return Err(HttpError::new(
                "431 Request Header Fields Too Large",
                format!("more than {MAX_HEADERS} headers"),
            ));
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                let n: usize = v
                    .trim()
                    .parse()
                    .map_err(|_| bad(format!("invalid Content-Length '{}'", v.trim())))?;
                if n > MAX_BODY_BYTES {
                    return Err(HttpError::new(
                        "413 Payload Too Large",
                        format!("Content-Length {n} exceeds the {MAX_BODY_BYTES}-byte limit"),
                    ));
                }
                content_length = Some(n);
            }
        }
    }

    let content_length = match content_length {
        Some(n) => n,
        // a bodied method without Content-Length cannot be framed
        None if method == "POST" || method == "PUT" => {
            return Err(HttpError::new(
                "411 Length Required",
                "POST requires a Content-Length header",
            ));
        }
        None => 0,
    };
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader
            .read_exact(&mut body)
            .map_err(|_| bad("body shorter than Content-Length".to_string()))?;
    }
    Ok(HttpRequest {
        method,
        path,
        body: String::from_utf8_lossy(&body).into_owned(),
    })
}

/// Tiny blocking HTTP client for the examples / integration tests.
pub mod client {
    use super::*;

    /// POST `body` to `path`; returns (status, response body).
    pub fn post(addr: &str, path: &str, body: &str) -> Result<(u16, String)> {
        request(addr, "POST", path, body)
    }

    /// GET `path`; returns (status, response body).
    pub fn get(addr: &str, path: &str) -> Result<(u16, String)> {
        request(addr, "GET", path, "")
    }

    fn request(addr: &str, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
        let mut stream = TcpStream::connect(addr)?;
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        stream.write_all(req.as_bytes())?;
        let mut buf = String::new();
        BufReader::new(stream).read_to_string(&mut buf)?;
        let status: u16 = buf
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("bad response"))?;
        let body = buf
            .split_once("\r\n\r\n")
            .map(|(_, b)| b.to_string())
            .unwrap_or_default();
        Ok((status, body))
    }
}
