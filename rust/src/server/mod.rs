//! Minimal HTTP/1.1 serving front-end on std::net (no tokio/hyper offline).
//!
//! Endpoints:
//!   POST /generate   {"prompt": str, "max_tokens"?: int, "k"?: int,
//!                     "w"?: int, "strategy"?: str}
//!                 -> {"text": str, "tokens": int, "tokens_per_call": f,
//!                     "calls": int, "latency_ms": f}
//!   GET  /metrics    prometheus-style text, including the per-strategy
//!                    win/accepted-token counters (which draft source is
//!                    actually paying for its rows) and the ttft /
//!                    inter-token / per-phase latency quantiles
//!   GET  /stats      JSON latency summary: request counts plus
//!                    ttft/inter-token/request-latency digests and
//!                    per-phase quantiles from the same histograms
//!   GET  /trace?n=K  the last K flight-recorder events (decode steps +
//!                    request spans, merged across engines) as JSONL —
//!                    replayable by `ngrammys trace --input`
//!   GET  /healthz    "ok"
//!
//! Requests that don't name a strategy get `ServeConfig::default_strategy`
//! (`ngrammys serve --strategy adaptive` makes online (k, w) + strategy
//! selection the server default; per-request `"strategy"` still wins).
//!
//! Two front-ends serve the same routes with byte-identical responses
//! (`ngrammys serve --front-end {reactor,threaded}`):
//!
//! * **reactor** (default on Linux) — a single event-loop thread drives
//!   every connection through non-blocking accept/read/write state
//!   machines over epoll (see [`reactor`]). `/generate` is submitted to
//!   the scheduler asynchronously, so a slow or vanished client never
//!   pins an OS thread; client disconnects cancel the in-flight request
//!   and release its lane and KV pages.
//! * **threaded** — one blocking thread per connection, the original
//!   front-end. Kept as the fallback for non-Linux builds and as the
//!   comparison baseline for `ngrammys bench serve`.
//!
//! Keep-alive is not supported in either front-end — every response
//! closes the socket, which keeps the parser tiny and is plenty for the
//! benchmark driver. [`Server::spawn_handle`] returns a [`ServerHandle`]
//! whose `shutdown()` stops accepting and drains in-flight connections
//! before returning.
//!
//! Request hardening: the parser enforces a body-size cap (1 MiB), header
//! count/size caps, and a valid Content-Length on POST. Violations get a
//! proper 4xx JSON error response ({"error": ...}) instead of a dropped
//! connection. Routing errors are JSON too: an unknown path is a 404 and
//! a known path hit with the wrong method is a 405 naming the method it
//! supports.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::config::{EngineConfig, FrontEnd, ServeConfig};
use crate::scheduler::{GenRequest, GenResponse, Scheduler, StrategyName};
use crate::tokenizer::BpeTokenizer;
use crate::trace::to_jsonl;
use crate::util::json::Json;

#[cfg(target_os = "linux")]
pub mod reactor;

/// How many flight-recorder events `GET /trace` returns when the request
/// doesn't pass `?n=K`.
pub const DEFAULT_TRACE_EVENTS: usize = 256;

/// HTTP front-end: the scheduler handle, tokenizer and settings one
/// accept loop serves.
pub struct Server {
    /// request scheduler handle
    pub scheduler: Arc<Scheduler>,
    /// shared tokenizer
    pub tokenizer: Arc<BpeTokenizer>,
    /// serving settings (defaults for /generate)
    pub cfg: ServeConfig,
}

/// A running server: its bound address plus the stop flag and thread
/// handle needed for a graceful shutdown.
pub struct ServerHandle {
    /// the address the listener actually bound (resolves port 0)
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<()>,
}

impl ServerHandle {
    /// Stop accepting new connections, drain the ones in flight, and
    /// join the serving thread. In-flight `/generate` requests finish
    /// and their responses are delivered before this returns.
    pub fn shutdown(self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.handle.join();
    }
}

impl Server {
    /// Blocking accept loop. Binds `cfg.addr`; call from main. Runs the
    /// front-end `cfg.front_end` selects (reactor by default on Linux).
    pub fn run(self) -> Result<()> {
        let listener = TcpListener::bind(&self.cfg.addr)?;
        let fe = effective_front_end(&self.cfg);
        eprintln!("ngrammys serving on http://{} ({} front-end)", self.cfg.addr, fe.label());
        serve_on(Arc::new(self), listener, Arc::new(AtomicBool::new(false)), fe)
    }

    /// Bind and serve in a background thread; returns the bound address
    /// (useful with port 0 in tests). The server runs until the process
    /// exits — use [`Server::spawn_handle`] when you need to stop it.
    pub fn spawn(self) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<()>)> {
        let h = self.spawn_handle()?;
        Ok((h.addr, h.handle))
    }

    /// Bind and serve in a background thread, returning a handle whose
    /// `shutdown()` stops the accept loop and drains in-flight
    /// connections before returning.
    pub fn spawn_handle(self) -> Result<ServerHandle> {
        let listener = TcpListener::bind(&self.cfg.addr)?;
        let addr = listener.local_addr()?;
        let fe = effective_front_end(&self.cfg);
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let me = Arc::new(self);
        let handle = std::thread::spawn(move || {
            if let Err(e) = serve_on(me, listener, stop2, fe) {
                eprintln!("server: front-end failed: {e:#}");
            }
        });
        Ok(ServerHandle { addr, stop, handle })
    }

    fn handle(&self, mut stream: TcpStream) -> Result<()> {
        let (status, body, ctype) = match parse_request(&mut stream) {
            Ok(req) => self.route(&req),
            Err(e) => (e.status, error_body(e.msg), "application/json"),
        };
        if stream.write_all(http_response(status, ctype, &body).as_bytes()).is_err() {
            self.scheduler.metrics.disconnects.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    fn route(&self, req: &HttpRequest) -> (&'static str, String, &'static str) {
        match self.route_pre(req) {
            Routed::Ready(status, body, ctype) => (status, body, ctype),
            Routed::Generate(body) => match self.generate(&body) {
                Ok(j) => ("200 OK", j.to_string(), "application/json"),
                Err(e) => ("400 Bad Request", error_body(format!("{e:#}")), "application/json"),
            },
        }
    }

    /// Route everything except the actual generation work: synchronous
    /// routes come back [`Routed::Ready`], a well-formed `POST /generate`
    /// comes back [`Routed::Generate`] so the caller can run it blocking
    /// (threaded front-end) or submit it asynchronously (reactor).
    pub(crate) fn route_pre(&self, req: &HttpRequest) -> Routed {
        // the request target may carry a query string; route on the bare
        // path so `/trace?n=64` still hits `/trace`
        let (path, query) = match req.path.split_once('?') {
            Some((p, q)) => (p, q),
            None => (req.path.as_str(), ""),
        };
        let fail = |status: &'static str, msg: String| {
            Routed::Ready(status, error_body(msg), "application/json")
        };
        // every known path serves exactly one method: anything else on it
        // is a 405 naming the method it supports, an unknown path is a 404
        let allowed = match path {
            "/healthz" | "/metrics" | "/stats" | "/trace" => "GET",
            "/generate" => "POST",
            _ => return fail("404 Not Found", format!("no such path: {path}")),
        };
        if req.method != allowed {
            let msg = format!("{path} only supports {allowed}, got {}", req.method);
            return fail("405 Method Not Allowed", msg);
        }
        match path {
            "/healthz" => Routed::Ready("200 OK", "ok\n".into(), "text/plain"),
            "/metrics" => Routed::Ready("200 OK", self.scheduler.metrics.render(), "text/plain"),
            "/stats" => Routed::Ready(
                "200 OK",
                self.scheduler.metrics.stats_json().to_string(),
                "application/json",
            ),
            "/trace" => {
                let n = query_param(query, "n")
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(DEFAULT_TRACE_EVENTS);
                let events = self.scheduler.trace.recent(n);
                Routed::Ready("200 OK", to_jsonl(&events), "application/x-ndjson")
            }
            "/generate" => Routed::Generate(req.body.clone()),
            _ => unreachable!("every path in the allow table is matched above"),
        }
    }

    /// Parse a `/generate` request body into the scheduler request it
    /// describes. Error strings here are pinned by the integration tests
    /// — both front-ends report them byte-identically.
    pub(crate) fn parse_generate(&self, body: &str) -> Result<GenRequest> {
        let j = Json::parse(body).map_err(|e| anyhow!("bad json: {e}"))?;
        let prompt_text = j
            .req("prompt")?
            .as_str()
            .ok_or_else(|| anyhow!("'prompt' must be a string"))?;
        let d = &self.cfg.default_engine;
        let engine = EngineConfig {
            k: j.get("k").and_then(|v| v.as_usize()).unwrap_or(d.k),
            w: j.get("w").and_then(|v| v.as_usize()).unwrap_or(d.w),
            q: j.get("q").and_then(|v| v.as_usize()).unwrap_or(d.q),
            max_new_tokens: j
                .get("max_tokens")
                .and_then(|v| v.as_usize())
                .unwrap_or(d.max_new_tokens),
        };
        let strategy = match j.get("strategy").and_then(|v| v.as_str()) {
            Some(s) => StrategyName::parse(s)?,
            None => self.cfg.default_strategy,
        };
        let prompt = self.tokenizer.encode(prompt_text);
        if prompt.is_empty() {
            return Err(anyhow!("empty prompt"));
        }
        Ok(GenRequest { prompt, engine, strategy })
    }

    /// Render a finished generation as the `/generate` response JSON.
    pub(crate) fn render_generate(&self, resp: &GenResponse) -> Json {
        Json::obj(vec![
            ("text", Json::Str(self.tokenizer.decode(&resp.tokens))),
            ("tokens", Json::Num(resp.tokens.len() as f64)),
            ("calls", Json::Num(resp.calls as f64)),
            ("tokens_per_call", Json::Num(resp.tokens_per_call)),
            ("latency_ms", Json::Num(resp.latency_ms)),
        ])
    }

    fn generate(&self, body: &str) -> Result<Json> {
        let req = self.parse_generate(body)?;
        let resp = self.scheduler.generate(req)?;
        Ok(self.render_generate(&resp))
    }
}

/// What [`Server::route_pre`] decided about a request.
pub(crate) enum Routed {
    /// a complete response: (status line, body, content type)
    Ready(&'static str, String, &'static str),
    /// a well-formed `POST /generate` whose body still needs running
    Generate(String),
}

/// The front-end actually used: the configured one, except that the
/// epoll reactor only exists on Linux — elsewhere it falls back to the
/// threaded front-end with a warning.
fn effective_front_end(cfg: &ServeConfig) -> FrontEnd {
    if cfg.front_end == FrontEnd::Reactor && !cfg!(target_os = "linux") {
        eprintln!("server: reactor front-end requires Linux epoll; falling back to threaded");
        return FrontEnd::Threaded;
    }
    cfg.front_end
}

/// Run the selected front-end on `listener` until `stop` is set, then
/// drain in-flight connections and return.
fn serve_on(
    me: Arc<Server>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    fe: FrontEnd,
) -> Result<()> {
    match fe {
        #[cfg(target_os = "linux")]
        FrontEnd::Reactor => reactor::serve(me, listener, stop),
        #[cfg(not(target_os = "linux"))]
        FrontEnd::Reactor => serve_threaded(me, listener, stop),
        FrontEnd::Threaded => serve_threaded(me, listener, stop),
    }
}

/// The original front-end: one blocking thread per connection. The
/// accept loop polls so the stop flag is honoured; on stop it joins the
/// per-connection threads, draining whatever is in flight.
fn serve_threaded(me: Arc<Server>, listener: TcpListener, stop: Arc<AtomicBool>) -> Result<()> {
    listener.set_nonblocking(true)?;
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                // accepted sockets do not inherit the listener's
                // non-blocking flag on every platform — force blocking
                let _ = stream.set_nonblocking(false);
                me.scheduler.metrics.connections_total.fetch_add(1, Ordering::Relaxed);
                let me = me.clone();
                conns.push(std::thread::spawn(move || {
                    if let Err(e) = me.handle(stream) {
                        eprintln!("connection error: {e:#}");
                    }
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) => {
                eprintln!("server: accept failed: {e}");
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        if conns.len() >= 32 {
            conns.retain(|h| !h.is_finished());
        }
    }
    for h in conns {
        let _ = h.join();
    }
    Ok(())
}

/// First value of `key` in a URL query string (`"a=1&b=2"`), `None` when
/// absent. Values are taken verbatim — no percent-decoding, which is fine
/// for the numeric parameters the server defines.
fn query_param<'a>(query: &'a str, key: &str) -> Option<&'a str> {
    query.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=')?;
        (k == key).then_some(v)
    })
}

/// One parsed HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    /// request method (GET, POST, ...)
    pub method: String,
    /// request path
    pub path: String,
    /// request body (empty when no Content-Length)
    pub body: String,
}

/// Largest request body the server accepts (absurd Content-Lengths are
/// rejected with 413 instead of attempting the allocation).
pub const MAX_BODY_BYTES: usize = 1 << 20;
const MAX_HEADER_LINE_BYTES: usize = 8 * 1024;
const MAX_HEADERS: usize = 100;

/// A request-parse failure with the HTTP status it should be reported as.
#[derive(Debug)]
pub struct HttpError {
    /// HTTP status line to report (e.g. "400 Bad Request")
    pub status: &'static str,
    /// human-readable error detail (returned as JSON)
    pub msg: String,
}

impl HttpError {
    fn new(status: &'static str, msg: impl Into<String>) -> Self {
        HttpError { status, msg: msg.into() }
    }
}

/// Read one CRLF-terminated line without ever buffering more than `cap`
/// bytes — a client streaming an endless unterminated line is cut off at
/// the cap instead of growing the allocation until OOM.
fn read_line_capped<R: BufRead>(
    reader: &mut R,
    cap: usize,
) -> std::result::Result<String, HttpError> {
    let mut buf = Vec::new();
    let mut limited = Read::take(reader.by_ref(), cap as u64 + 1);
    limited
        .read_until(b'\n', &mut buf)
        .map_err(|e| HttpError::new("400 Bad Request", format!("malformed request: {e}")))?;
    if buf.len() > cap {
        return Err(HttpError::new(
            "431 Request Header Fields Too Large",
            format!("header line exceeds the {cap}-byte limit"),
        ));
    }
    Ok(String::from_utf8_lossy(&buf).into_owned())
}

/// Format one complete HTTP/1.1 response. Both front-ends emit their
/// bytes through this single formatter, which is what makes the
/// byte-identity guarantee between them checkable.
pub(crate) fn http_response(status: &str, ctype: &str, body: &str) -> String {
    format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

/// Render an error message as the JSON error body both front-ends use.
pub(crate) fn error_body(msg: impl Into<String>) -> String {
    Json::obj(vec![("error", Json::Str(msg.into()))]).to_string()
}

/// Parse one HTTP/1.1 request from `stream`, enforcing the header and
/// body caps; violations carry the 4xx status they should produce.
pub fn parse_request(stream: &mut TcpStream) -> std::result::Result<HttpRequest, HttpError> {
    parse_request_from(&mut BufReader::new(stream))
}

/// [`parse_request`] over any buffered byte source — the reactor runs it
/// on a `Cursor` over a connection's already-buffered bytes once its
/// completeness pre-check says the request (or its framing violation) is
/// fully present, so both front-ends produce identical parses and
/// identical pinned 4xx errors.
pub(crate) fn parse_request_from<R: BufRead>(
    reader: &mut R,
) -> std::result::Result<HttpRequest, HttpError> {
    let bad = |msg: String| HttpError::new("400 Bad Request", msg);
    let line = read_line_capped(reader, MAX_HEADER_LINE_BYTES)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        return Err(bad("malformed request line".to_string()));
    }

    let mut content_length: Option<usize> = None;
    let mut n_headers = 0usize;
    loop {
        let h = read_line_capped(reader, MAX_HEADER_LINE_BYTES)?;
        if h.is_empty() {
            // EOF before the blank line terminating the header block
            return Err(bad("truncated request: headers not terminated".to_string()));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        n_headers += 1;
        if n_headers > MAX_HEADERS {
            return Err(HttpError::new(
                "431 Request Header Fields Too Large",
                format!("more than {MAX_HEADERS} headers"),
            ));
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                let n: usize = v
                    .trim()
                    .parse()
                    .map_err(|_| bad(format!("invalid Content-Length '{}'", v.trim())))?;
                if n > MAX_BODY_BYTES {
                    return Err(HttpError::new(
                        "413 Payload Too Large",
                        format!("Content-Length {n} exceeds the {MAX_BODY_BYTES}-byte limit"),
                    ));
                }
                content_length = Some(n);
            }
        }
    }

    let content_length = match content_length {
        Some(n) => n,
        // a bodied method without Content-Length cannot be framed
        None if method == "POST" || method == "PUT" => {
            return Err(HttpError::new(
                "411 Length Required",
                "POST requires a Content-Length header",
            ));
        }
        None => 0,
    };
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader
            .read_exact(&mut body)
            .map_err(|_| bad("body shorter than Content-Length".to_string()))?;
    }
    Ok(HttpRequest {
        method,
        path,
        body: String::from_utf8_lossy(&body).into_owned(),
    })
}

/// Tiny blocking HTTP client for the examples / integration tests.
pub mod client {
    use super::*;

    /// POST `body` to `path`; returns (status, response body).
    pub fn post(addr: &str, path: &str, body: &str) -> Result<(u16, String)> {
        request(addr, "POST", path, body)
    }

    /// GET `path`; returns (status, response body).
    pub fn get(addr: &str, path: &str) -> Result<(u16, String)> {
        request(addr, "GET", path, "")
    }

    fn request(addr: &str, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
        let mut stream = TcpStream::connect(addr)?;
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        stream.write_all(req.as_bytes())?;
        let mut buf = String::new();
        BufReader::new(stream).read_to_string(&mut buf)?;
        let status: u16 = buf
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("bad response"))?;
        let body = buf
            .split_once("\r\n\r\n")
            .map(|(_, b)| b.to_string())
            .unwrap_or_default();
        Ok((status, body))
    }
}
