//! Per-sequence, per-`StrategyKind` acceptance estimators.
//!
//! Fed from step row provenance (which kinds had rows allocated, which row
//! won, how long its accepted prefix was), these EWMAs are the raw signal
//! behind both the controller's arm scores and the operator-facing arm
//! statistics printed by `bench adaptive`.

use crate::draft::{DraftBatch, StrategyKind};

/// EWMA acceptance statistics for one `StrategyKind` within one sequence.
#[derive(Debug, Clone, Copy, Default)]
pub struct KindStats {
    /// EWMA of the accepted-prefix length on steps a row of this kind won
    pub ewma_accepted: f64,
    /// EWMA of the hit rate: 1 when this kind won a step it had rows in,
    /// 0 when it had rows allocated but lost
    pub ewma_hit: f64,
    /// steps in which this kind had at least one allocated row
    pub steps_allocated: u64,
    /// steps a row of this kind won
    pub wins: u64,
    /// total accepted draft tokens across winning steps
    pub accepted_total: u64,
}

/// Fixed-array estimator over every `StrategyKind`.
#[derive(Debug, Clone)]
pub struct AcceptanceEstimator {
    alpha: f64,
    stats: [KindStats; StrategyKind::COUNT],
}

/// One EWMA update; the first sample initializes the average directly.
pub(crate) fn ewma(old: f64, x: f64, alpha: f64, samples: u64) -> f64 {
    if samples == 0 {
        x
    } else {
        alpha * x + (1.0 - alpha) * old
    }
}

impl AcceptanceEstimator {
    /// An empty estimator with EWMA decay `alpha` (clamped to [0.01, 1]).
    pub fn new(alpha: f64) -> Self {
        AcceptanceEstimator {
            alpha: alpha.clamp(0.01, 1.0),
            stats: [KindStats::default(); StrategyKind::COUNT],
        }
    }

    /// Digest one judged step: for every kind with allocated rows, update
    /// its hit rate (did it win?) and, for the winner, its accepted-length
    /// EWMA. A step with NO accepted draft tokens has no winner — the
    /// judge defaults to row 0 there, and crediting row 0's kind would
    /// systematically inflate whatever strategy fills the top row.
    pub fn observe(&mut self, batch: &DraftBatch, win_row: usize, accepted: usize) {
        if batch.k() == 0 {
            return;
        }
        let winner = (accepted > 0).then(|| batch.rows()[win_row].kind);
        for kind in StrategyKind::ALL {
            if kind == StrategyKind::Empty {
                continue; // padding rows carry no signal
            }
            let allocated = batch.rows().iter().any(|r| r.kind == kind);
            if !allocated {
                continue;
            }
            let i = kind.index();
            let hit = winner == Some(kind);
            let s = &mut self.stats[i];
            s.ewma_hit = ewma(s.ewma_hit, if hit { 1.0 } else { 0.0 }, self.alpha,
                              s.steps_allocated);
            if hit {
                s.ewma_accepted = ewma(s.ewma_accepted, accepted as f64, self.alpha, s.wins);
                s.wins += 1;
                s.accepted_total += accepted as u64;
            }
            s.steps_allocated += 1;
        }
    }

    /// Statistics for one kind.
    pub fn stats(&self, kind: StrategyKind) -> &KindStats {
        &self.stats[kind.index()]
    }

    /// (kind, stats) for every kind that ever had rows allocated.
    pub fn active_kinds(&self) -> Vec<(StrategyKind, KindStats)> {
        StrategyKind::ALL
            .iter()
            .filter_map(|&k| {
                let s = self.stats[k.index()];
                (s.steps_allocated > 0).then_some((k, s))
            })
            .collect()
    }

    /// Clear all per-kind statistics (between requests).
    pub fn reset(&mut self) {
        self.stats = [KindStats::default(); StrategyKind::COUNT];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(kinds: &[StrategyKind]) -> DraftBatch {
        let mut b = DraftBatch::new(4);
        for (i, &k) in kinds.iter().enumerate() {
            b.push(vec![1, 2], k, i);
        }
        b
    }

    #[test]
    fn winner_and_losers_update_separately() {
        let mut e = AcceptanceEstimator::new(0.5);
        let b = batch(&[StrategyKind::ContextNgram, StrategyKind::ExtendedBigram]);
        e.observe(&b, 0, 2);
        let ctx = e.stats(StrategyKind::ContextNgram);
        let big = e.stats(StrategyKind::ExtendedBigram);
        assert_eq!(ctx.wins, 1);
        assert_eq!(big.wins, 0);
        assert!((ctx.ewma_hit - 1.0).abs() < 1e-12);
        assert!((big.ewma_hit - 0.0).abs() < 1e-12);
        assert!((ctx.ewma_accepted - 2.0).abs() < 1e-12);
        assert_eq!(ctx.steps_allocated, 1);
        assert_eq!(big.steps_allocated, 1);
    }

    #[test]
    fn ewma_tracks_recent_behavior() {
        let mut e = AcceptanceEstimator::new(0.5);
        let b = batch(&[StrategyKind::ContextNgram]);
        e.observe(&b, 0, 4);
        e.observe(&b, 0, 2);
        let s = e.stats(StrategyKind::ContextNgram);
        assert!((s.ewma_accepted - 3.0).abs() < 1e-12); // 0.5*2 + 0.5*4
        assert_eq!(s.accepted_total, 6);
        assert_eq!(s.wins, 2);
    }

    #[test]
    fn zero_acceptance_steps_have_no_winner() {
        let mut e = AcceptanceEstimator::new(0.5);
        let b = batch(&[StrategyKind::ContextNgram, StrategyKind::ExtendedBigram]);
        // judge defaults to row 0 when nothing matched; that is a MISS for
        // every allocated kind, not a win for row 0's kind
        e.observe(&b, 0, 0);
        let ctx = e.stats(StrategyKind::ContextNgram);
        assert_eq!(ctx.wins, 0);
        assert!((ctx.ewma_hit - 0.0).abs() < 1e-12);
        assert_eq!(ctx.steps_allocated, 1);
    }

    #[test]
    fn unallocated_kinds_untouched_and_empty_ignored() {
        let mut e = AcceptanceEstimator::new(0.3);
        let mut b = batch(&[StrategyKind::ContextNgram]);
        b.push(Vec::<u32>::new(), StrategyKind::Empty, 1);
        e.observe(&b, 0, 1);
        assert_eq!(e.stats(StrategyKind::ModelBigram).steps_allocated, 0);
        assert_eq!(e.stats(StrategyKind::Empty).steps_allocated, 0);
        assert_eq!(e.active_kinds().len(), 1);
    }

    #[test]
    fn reset_clears_everything() {
        let mut e = AcceptanceEstimator::new(0.3);
        e.observe(&batch(&[StrategyKind::ContextNgram]), 0, 3);
        e.reset();
        assert!(e.active_kinds().is_empty());
    }
}
