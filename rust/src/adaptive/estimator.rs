//! Per-sequence, per-`StrategyKind` acceptance estimators.
//!
//! Fed from step row provenance (which kinds had rows allocated, which row
//! won, how long its accepted prefix was), these EWMAs are the raw signal
//! behind both the controller's arm scores and the operator-facing arm
//! statistics printed by `bench adaptive`.

use crate::draft::{DraftBatch, StrategyKind};

/// EWMA acceptance statistics for one `StrategyKind` within one sequence.
#[derive(Debug, Clone, Copy, Default)]
pub struct KindStats {
    /// EWMA of the accepted-prefix length on steps a row of this kind won
    pub ewma_accepted: f64,
    /// EWMA of the hit rate: 1 when this kind won a step it had rows in,
    /// 0 when it had rows allocated but lost
    pub ewma_hit: f64,
    /// steps in which this kind had at least one allocated row
    pub steps_allocated: u64,
    /// steps a row of this kind won
    pub wins: u64,
    /// total accepted draft tokens across winning steps
    pub accepted_total: u64,
}

/// Fixed-array estimator over every `StrategyKind`.
#[derive(Debug, Clone)]
pub struct AcceptanceEstimator {
    alpha: f64,
    stats: [KindStats; StrategyKind::COUNT],
}

/// One EWMA update; the first sample initializes the average directly.
pub(crate) fn ewma(old: f64, x: f64, alpha: f64, samples: u64) -> f64 {
    if samples == 0 {
        x
    } else {
        alpha * x + (1.0 - alpha) * old
    }
}

impl AcceptanceEstimator {
    /// An empty estimator with EWMA decay `alpha` (clamped to [0.01, 1]).
    pub fn new(alpha: f64) -> Self {
        AcceptanceEstimator {
            alpha: alpha.clamp(0.01, 1.0),
            stats: [KindStats::default(); StrategyKind::COUNT],
        }
    }

    /// Digest one judged step: for every kind with allocated rows, update
    /// its hit rate (did it win?) and, for the winner, its accepted-length
    /// EWMA. A step with NO accepted draft tokens has no winner — the
    /// judge defaults to row 0 there, and crediting row 0's kind would
    /// systematically inflate whatever strategy fills the top row.
    pub fn observe(&mut self, batch: &DraftBatch, win_row: usize, accepted: usize) {
        if batch.k() == 0 {
            return;
        }
        let winner = (accepted > 0).then(|| batch.rows()[win_row].kind);
        for kind in StrategyKind::ALL {
            if kind == StrategyKind::Empty {
                continue; // padding rows carry no signal
            }
            let allocated = batch.rows().iter().any(|r| r.kind == kind);
            if !allocated {
                continue;
            }
            let i = kind.index();
            let hit = winner == Some(kind);
            let s = &mut self.stats[i];
            s.ewma_hit = ewma(s.ewma_hit, if hit { 1.0 } else { 0.0 }, self.alpha,
                              s.steps_allocated);
            if hit {
                s.ewma_accepted = ewma(s.ewma_accepted, accepted as f64, self.alpha, s.wins);
                s.wins += 1;
                s.accepted_total += accepted as u64;
            }
            s.steps_allocated += 1;
        }
    }

    /// Statistics for one kind.
    pub fn stats(&self, kind: StrategyKind) -> &KindStats {
        &self.stats[kind.index()]
    }

    /// (kind, stats) for every kind that ever had rows allocated.
    pub fn active_kinds(&self) -> Vec<(StrategyKind, KindStats)> {
        StrategyKind::ALL
            .iter()
            .filter_map(|&k| {
                let s = self.stats[k.index()];
                (s.steps_allocated > 0).then_some((k, s))
            })
            .collect()
    }

    /// Clear all per-kind statistics (between requests).
    pub fn reset(&mut self) {
        self.stats = [KindStats::default(); StrategyKind::COUNT];
    }
}

/// Windowed, change-point-aware acceptance tracker — the regime-shift
/// companion to the EWMAs above. An EWMA with small `alpha` converges but
/// then takes dozens of steps to notice that a stream's character flipped
/// (a chat request that switches to pasting code mid-session); this keeps
/// the last [`Self::WINDOW`] per-step acceptance rates verbatim and flags
/// a change-point when the window's two halves disagree by at least the
/// threshold. The controller reacts by re-opening exploration
/// ([`super::SeqController`] caps every arm's pull count so the UCB
/// bonuses dominate again), which is lossless — re-exploring can only
/// cost speed, never correctness.
#[derive(Debug, Clone)]
pub struct WindowedAcceptance {
    window: Vec<f64>,
    threshold: f64,
    shifts: u64,
}

impl WindowedAcceptance {
    /// Samples held (and compared, half against half) per change-point
    /// test. Small enough to re-trigger exploration within ~one warmup's
    /// worth of steps after a hard flip.
    pub const WINDOW: usize = 16;

    /// A tracker that flags when the mean acceptance rate of the newer
    /// half of the window departs from the older half by at least
    /// `threshold` (clamped to [0.05, 1]; acceptance rates live in
    /// [0, 1], so 0.5 means "half the speculation value appeared or
    /// vanished").
    pub fn new(threshold: f64) -> Self {
        WindowedAcceptance {
            window: Vec::with_capacity(Self::WINDOW),
            threshold: threshold.clamp(0.05, 1.0),
            shifts: 0,
        }
    }

    /// Record one step's acceptance rate (accepted / planned depth, any
    /// [0, 1] signal). Returns true when a change-point is detected; the
    /// window is cleared so one regime shift fires exactly once.
    pub fn observe(&mut self, rate: f64) -> bool {
        if self.window.len() == Self::WINDOW {
            self.window.remove(0);
        }
        self.window.push(rate.clamp(0.0, 1.0));
        if self.window.len() < Self::WINDOW {
            return false;
        }
        let half = Self::WINDOW / 2;
        let old: f64 = self.window[..half].iter().sum::<f64>() / half as f64;
        let new: f64 = self.window[half..].iter().sum::<f64>() / half as f64;
        if (new - old).abs() >= self.threshold {
            self.window.clear();
            self.shifts += 1;
            return true;
        }
        false
    }

    /// Change-points detected over this tracker's lifetime.
    pub fn regime_shifts(&self) -> u64 {
        self.shifts
    }

    /// Clear the window (between requests); the lifetime shift count is
    /// kept for reporting.
    pub fn reset(&mut self) {
        self.window.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(kinds: &[StrategyKind]) -> DraftBatch {
        let mut b = DraftBatch::new(4);
        for (i, &k) in kinds.iter().enumerate() {
            b.push(vec![1, 2], k, i);
        }
        b
    }

    #[test]
    fn winner_and_losers_update_separately() {
        let mut e = AcceptanceEstimator::new(0.5);
        let b = batch(&[StrategyKind::ContextNgram, StrategyKind::ExtendedBigram]);
        e.observe(&b, 0, 2);
        let ctx = e.stats(StrategyKind::ContextNgram);
        let big = e.stats(StrategyKind::ExtendedBigram);
        assert_eq!(ctx.wins, 1);
        assert_eq!(big.wins, 0);
        assert!((ctx.ewma_hit - 1.0).abs() < 1e-12);
        assert!((big.ewma_hit - 0.0).abs() < 1e-12);
        assert!((ctx.ewma_accepted - 2.0).abs() < 1e-12);
        assert_eq!(ctx.steps_allocated, 1);
        assert_eq!(big.steps_allocated, 1);
    }

    #[test]
    fn ewma_tracks_recent_behavior() {
        let mut e = AcceptanceEstimator::new(0.5);
        let b = batch(&[StrategyKind::ContextNgram]);
        e.observe(&b, 0, 4);
        e.observe(&b, 0, 2);
        let s = e.stats(StrategyKind::ContextNgram);
        assert!((s.ewma_accepted - 3.0).abs() < 1e-12); // 0.5*2 + 0.5*4
        assert_eq!(s.accepted_total, 6);
        assert_eq!(s.wins, 2);
    }

    #[test]
    fn zero_acceptance_steps_have_no_winner() {
        let mut e = AcceptanceEstimator::new(0.5);
        let b = batch(&[StrategyKind::ContextNgram, StrategyKind::ExtendedBigram]);
        // judge defaults to row 0 when nothing matched; that is a MISS for
        // every allocated kind, not a win for row 0's kind
        e.observe(&b, 0, 0);
        let ctx = e.stats(StrategyKind::ContextNgram);
        assert_eq!(ctx.wins, 0);
        assert!((ctx.ewma_hit - 0.0).abs() < 1e-12);
        assert_eq!(ctx.steps_allocated, 1);
    }

    #[test]
    fn unallocated_kinds_untouched_and_empty_ignored() {
        let mut e = AcceptanceEstimator::new(0.3);
        let mut b = batch(&[StrategyKind::ContextNgram]);
        b.push(Vec::<u32>::new(), StrategyKind::Empty, 1);
        e.observe(&b, 0, 1);
        assert_eq!(e.stats(StrategyKind::ModelBigram).steps_allocated, 0);
        assert_eq!(e.stats(StrategyKind::Empty).steps_allocated, 0);
        assert_eq!(e.active_kinds().len(), 1);
    }

    #[test]
    fn reset_clears_everything() {
        let mut e = AcceptanceEstimator::new(0.3);
        e.observe(&batch(&[StrategyKind::ContextNgram]), 0, 3);
        e.reset();
        assert!(e.active_kinds().is_empty());
    }

    #[test]
    fn steady_acceptance_never_flags_a_change_point() {
        let mut w = WindowedAcceptance::new(0.4);
        for i in 0..100 {
            // mild noise around 0.7 — well under the threshold
            let rate = 0.7 + if i % 2 == 0 { 0.05 } else { -0.05 };
            assert!(!w.observe(rate), "steady regime flagged at step {i}");
        }
        assert_eq!(w.regime_shifts(), 0);
    }

    #[test]
    fn hard_flip_flags_within_one_window() {
        let mut w = WindowedAcceptance::new(0.4);
        for _ in 0..WindowedAcceptance::WINDOW {
            assert!(!w.observe(0.9));
        }
        // regime flips hard: 0.9 -> 0.0 acceptance
        let mut fired_at = None;
        for i in 0..WindowedAcceptance::WINDOW {
            if w.observe(0.0) {
                fired_at = Some(i);
                break;
            }
        }
        let at = fired_at.expect("hard flip must be detected");
        assert!(
            at < WindowedAcceptance::WINDOW,
            "detection must land within one window, got {at}"
        );
        assert_eq!(w.regime_shifts(), 1);
        // the window was cleared: the new regime is now the baseline and
        // does not re-fire
        for _ in 0..WindowedAcceptance::WINDOW * 2 {
            assert!(!w.observe(0.0));
        }
        assert_eq!(w.regime_shifts(), 1);
    }

    #[test]
    fn windowed_reset_keeps_lifetime_shift_count() {
        let mut w = WindowedAcceptance::new(0.4);
        for _ in 0..WindowedAcceptance::WINDOW {
            w.observe(1.0);
        }
        for _ in 0..WindowedAcceptance::WINDOW {
            if w.observe(0.0) {
                break;
            }
        }
        assert_eq!(w.regime_shifts(), 1);
        w.reset();
        assert_eq!(w.regime_shifts(), 1);
        // a fresh window must fill completely before testing again
        assert!(!w.observe(0.9));
    }
}
