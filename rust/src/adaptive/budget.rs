//! Packed-batch row-budget allocation.
//!
//! The batched engine's per-step verification cost is driven by the packed
//! batch size `sum k_i` (paper §3: the batch dimension is only ~free while
//! the call stays memory-bound). Under a global row budget `B`, rows
//! should go where they buy the most expected acceptance — hot sequences
//! get deep speculation, cold ones degrade toward their anchor row.

/// Allocate a global row budget across sequences by marginal expected
/// acceptance. Returns per-sequence row counts `a_i` with:
///
/// - `1 <= a_i <= caps[i]` (every active sequence keeps at least its
///   anchor row — a sequence cannot sit a step out);
/// - `sum a_i <= max(budget, n)` (the budget floors at one row per active
///   sequence; callers keep `B >= lanes` for a strict `sum <= B`).
///
/// Greedy water-filling: rows go one at a time to the sequence whose NEXT
/// row has the highest marginal gain `gain(seq, row_idx)`; ties break to
/// the lower sequence index, so the result is deterministic. For gains
/// that are non-increasing in `row_idx` (true of every estimator here)
/// this greedy is exactly optimal.
pub fn allocate_rows(
    budget: usize,
    caps: &[usize],
    gain: impl Fn(usize, usize) -> f64,
) -> Vec<usize> {
    let n = caps.len();
    if n == 0 {
        return Vec::new();
    }
    let mut alloc: Vec<usize> = caps.iter().map(|&c| c.min(1)).collect();
    let mut used: usize = alloc.iter().sum();
    let budget = budget.max(used);
    while used < budget {
        let mut best: Option<(usize, f64)> = None;
        for (i, &cap) in caps.iter().enumerate() {
            if alloc[i] >= cap {
                continue;
            }
            let g = gain(i, alloc[i]);
            match best {
                Some((_, bg)) if g <= bg => {}
                _ => best = Some((i, g)),
            }
        }
        let Some((i, _)) = best else { break }; // everyone at cap
        alloc[i] += 1;
        used += 1;
    }
    alloc
}

/// Marginal-gain prior for sequences without an adaptive controller:
/// plain diminishing returns in row depth (rank-0 rows win most often —
/// the paper's Fig. 4 middle panel).
pub fn static_gain(row_idx: usize) -> f64 {
    1.0 / (1.0 + row_idx as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn respects_budget_and_caps() {
        let caps = [10, 10, 10];
        let a = allocate_rows(12, &caps, |_, j| static_gain(j));
        assert_eq!(a.iter().sum::<usize>(), 12);
        assert!(a.iter().zip(&caps).all(|(&x, &c)| x >= 1 && x <= c));
        // uniform gains spread evenly
        assert_eq!(a, vec![4, 4, 4]);
    }

    #[test]
    fn hot_sequences_get_more_rows() {
        // sequence 1 is "hot": its marginal gains dominate at every depth
        let a = allocate_rows(8, &[10, 10], |i, j| {
            if i == 1 { 10.0 * static_gain(j) } else { static_gain(j) }
        });
        assert_eq!(a.iter().sum::<usize>(), 8);
        assert!(a[1] > a[0], "hot sequence got {a:?}");
        assert!(a[0] >= 1, "cold sequence must keep its anchor row");
    }

    #[test]
    fn budget_floors_at_one_row_per_sequence() {
        let a = allocate_rows(2, &[5, 5, 5, 5], |_, j| static_gain(j));
        assert_eq!(a, vec![1, 1, 1, 1]); // effective budget = max(B, n)
    }

    #[test]
    fn caps_bound_total_below_budget() {
        let a = allocate_rows(100, &[2, 3], |_, j| static_gain(j));
        assert_eq!(a, vec![2, 3]);
    }

    #[test]
    fn empty_input() {
        assert!(allocate_rows(10, &[], |_, _| 1.0).is_empty());
    }

    #[test]
    fn prop_allocation_invariants() {
        prop::check(200, |rng: &mut Rng| {
            let n = rng.range(1, 8);
            let caps: Vec<usize> = (0..n).map(|_| rng.range(1, 12)).collect();
            let budget = rng.range(0, 40);
            let heats: Vec<f64> = (0..n).map(|_| rng.f64() * 5.0).collect();
            let a = allocate_rows(budget, &caps, |i, j| heats[i] * static_gain(j));
            let total: usize = a.iter().sum();
            let cap_total: usize = caps.iter().sum();
            total <= budget.max(n).min(cap_total)
                && a.iter().zip(&caps).all(|(&x, &c)| x >= 1 && x <= c)
        });
    }
}
