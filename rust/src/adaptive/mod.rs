//! Adaptive speculation control: online (k, w) + strategy selection under
//! a packed-batch row budget.
//!
//! The paper (Fig. 1/4) shows the best learning-free strategy mix and the
//! useful speculation depth vary sharply by task and by position in the
//! stream, yet a static engine pins one strategy and one (k, w) per
//! request for its whole lifetime. This subsystem closes the loop:
//!
//! - [`estimator`] — per-sequence, per-[`StrategyKind`] acceptance
//!   estimators (EWMA of accepted-prefix length and hit rate), fed from
//!   the step's row provenance (`DraftBatch` kinds + the winning row).
//! - [`controller`] — [`SeqController`]: a deterministic UCB bandit over
//!   `StrategyName` arms scored by expected accepted-tokens-per-verify-
//!   cost (the [`crate::costmodel`] call time), plus per-step (k, w)
//!   planning over the model's available artifact shapes.
//! - [`budget`] — the packed-batch row allocator for
//!   [`crate::engine::BatchedEngine`]: distributes a global row budget
//!   `sum k_i <= B` across active sequences by marginal expected
//!   acceptance, so hot sequences get deep speculation and cold ones
//!   degrade toward anchor-only rows.
//!
//! CORRECTNESS: adaptation is lossless by construction. The controller
//! only ever changes *which drafts are proposed* and *how many rows/how
//! deep* the verifier checks — acceptance itself (`engine::acceptance`)
//! still emits exactly the base model's greedy stream, so any adaptation
//! trajectory, however bad, can only cost speed (property-tested in
//! `rust/tests/adaptive.rs`).

pub mod budget;
pub mod controller;
pub mod estimator;

pub use controller::{ArmReport, SeqController};
pub use estimator::AcceptanceEstimator;

use std::sync::Arc;

use crate::config::SessionCacheConfig;
use crate::costmodel::CostModel;
use crate::draft::{DraftBatch, NgramTables};
use crate::scheduler::{make_strategy_with_cache, StrategyName};
use crate::tokenizer::TokenId;

/// Tuning knobs for the per-sequence controller. Every field has a sane
/// default; the losslessness property tests randomize all of them.
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    /// EWMA decay for acceptance statistics (weight of the newest sample).
    pub alpha: f64,
    /// UCB exploration coefficient for arm selection (0 = pure greedy).
    pub explore: f64,
    /// Round-robin passes through the arms before the bandit exploits.
    pub warmup: usize,
    /// Optimism factor on the estimated acceptance length when planning
    /// speculation depth: plan for `ewma * depth_optimism + 1` tokens so a
    /// hot sequence keeps probing deeper than its average run.
    pub depth_optimism: f64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig { alpha: 0.25, explore: 0.15, warmup: 2, depth_optimism: 1.5 }
    }
}

/// Everything the controller learns from about one verification step.
/// Built by the engines right after `judge_and_commit`.
pub struct StepFeedback<'a> {
    /// the judged draft batch (row provenance: kind / rank / confidence)
    pub batch: &'a DraftBatch,
    /// winning row index within the batch
    pub row: usize,
    /// accepted draft-prefix length of the winning row
    pub accepted: usize,
    /// tokens emitted this step (accepted drafts + bonus token)
    pub emitted: &'a [TokenId],
    /// verifier output for the winning row (forwarded to the arm strategy)
    pub model_out: &'a [TokenId],
    /// block shape actually verified
    pub k: usize,
    /// speculation depth actually verified
    pub w: usize,
    /// context length at call time
    pub ctx_len: usize,
}

/// The default arm set: the paper's mixed policy plus its two strongest
/// single sources and the online session cache (which only pays off late
/// in repetitive streams — exactly what the bandit is for).
pub const DEFAULT_ARMS: [StrategyName; 4] = [
    StrategyName::Mixed,
    StrategyName::Context,
    StrategyName::ExtBigram,
    StrategyName::Session,
];

/// Build a per-sequence controller with the default arm set for a model:
/// `analog` picks the cost-model dims (`TxDims::for_analog`, falling back
/// to the 7B analog) so verify costs are scored at paper scale.
pub fn controller_for(
    tables: &Arc<NgramTables>,
    q: usize,
    cache: &SessionCacheConfig,
    analog: &str,
) -> SeqController {
    let arms = DEFAULT_ARMS
        .iter()
        .map(|&name| (name, make_strategy_with_cache(name, tables, q, cache)))
        .collect();
    SeqController::new(arms, AdaptiveConfig::default(), CostModel::for_analog(analog))
}
