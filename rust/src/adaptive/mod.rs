//! Adaptive speculation control: online (k, w) + strategy selection under
//! a packed-batch row budget.
//!
//! The paper (Fig. 1/4) shows the best learning-free strategy mix and the
//! useful speculation depth vary sharply by task and by position in the
//! stream, yet a static engine pins one strategy and one (k, w) per
//! request for its whole lifetime. This subsystem closes the loop:
//!
//! - [`estimator`] — per-sequence, per-[`StrategyKind`] acceptance
//!   estimators (EWMA of accepted-prefix length and hit rate), fed from
//!   the step's row provenance (`DraftBatch` kinds + the winning row).
//! - [`controller`] — [`SeqController`]: a deterministic UCB bandit over
//!   `StrategyName` arms scored by expected accepted-tokens-per-verify-
//!   cost (the [`crate::costmodel`] call time), plus per-step (k, w)
//!   planning over the model's available artifact shapes.
//! - [`budget`] — the packed-batch row allocator for
//!   [`crate::engine::BatchedEngine`]: distributes a global row budget
//!   `sum k_i <= B` across active sequences by marginal expected
//!   acceptance, so hot sequences get deep speculation and cold ones
//!   degrade toward anchor-only rows.
//!
//! CORRECTNESS: adaptation is lossless by construction. The controller
//! only ever changes *which drafts are proposed* and *how many rows/how
//! deep* the verifier checks — acceptance itself (`engine::acceptance`)
//! still emits exactly the base model's greedy stream, so any adaptation
//! trajectory, however bad, can only cost speed (property-tested in
//! `rust/tests/adaptive.rs`).

pub mod budget;
pub mod controller;
pub mod estimator;

pub use controller::{ArmPrior, ArmReport, SeqController};
pub use estimator::{AcceptanceEstimator, WindowedAcceptance};

use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::config::SessionCacheConfig;
use crate::costmodel::CostModel;
use crate::draft::{DraftBatch, NgramTables, SharedDraftStore};
use crate::metrics::Metrics;
use crate::scheduler::{make_strategy_with_cache, strategy_prior_tpc, StrategyName};
use crate::tokenizer::TokenId;

/// Tuning knobs for the per-sequence controller. Every field has a sane
/// default; the losslessness property tests randomize all of them.
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    /// EWMA decay for acceptance statistics (weight of the newest sample).
    pub alpha: f64,
    /// UCB exploration coefficient for arm selection (0 = pure greedy).
    pub explore: f64,
    /// Round-robin passes through the arms before the bandit exploits.
    pub warmup: usize,
    /// Optimism factor on the estimated acceptance length when planning
    /// speculation depth: plan for `ewma * depth_optimism + 1` tokens so a
    /// hot sequence keeps probing deeper than its average run.
    pub depth_optimism: f64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig { alpha: 0.25, explore: 0.15, warmup: 2, depth_optimism: 1.5 }
    }
}

/// Everything the controller learns from about one verification step.
/// Built by the engines right after `judge_and_commit`.
pub struct StepFeedback<'a> {
    /// the judged draft batch (row provenance: kind / rank / confidence)
    pub batch: &'a DraftBatch,
    /// winning row index within the batch
    pub row: usize,
    /// accepted draft-prefix length of the winning row
    pub accepted: usize,
    /// tokens emitted this step (accepted drafts + bonus token)
    pub emitted: &'a [TokenId],
    /// verifier output for the winning row (forwarded to the arm strategy)
    pub model_out: &'a [TokenId],
    /// block shape actually verified
    pub k: usize,
    /// speculation depth actually verified
    pub w: usize,
    /// context length at call time
    pub ctx_len: usize,
}

/// The default arm set: the paper's mixed policy plus its two strongest
/// single sources and the online session cache (which only pays off late
/// in repetitive streams — exactly what the bandit is for).
pub const DEFAULT_ARMS: [StrategyName; 4] = [
    StrategyName::Mixed,
    StrategyName::Context,
    StrategyName::ExtBigram,
    StrategyName::Session,
];

/// Build a per-sequence controller with the default arm set for a model:
/// `analog` picks the cost-model dims (`TxDims::for_analog`, falling back
/// to the 7B analog) so verify costs are scored at paper scale.
pub fn controller_for(
    tables: &Arc<NgramTables>,
    q: usize,
    cache: &SessionCacheConfig,
    analog: &str,
) -> SeqController {
    let arms = DEFAULT_ARMS
        .iter()
        .map(|&name| (name, make_strategy_with_cache(name, tables, q, cache)))
        .collect();
    SeqController::new(arms, AdaptiveConfig::default(), CostModel::for_analog(analog))
}

/// Pseudo-pull cap on fleet-derived arm priors: enough weight that the
/// bandit exploits the fleet's best arm immediately, small enough that a
/// few live steps of contrary evidence overturn a stale prior.
pub const MAX_SEED_PULLS: u64 = 8;

/// Fleet-wide arm priors from the serving metrics' per-strategy counters
/// (ROADMAP "cross-request bandit priors"): each default arm whose draft
/// kinds have recorded wins gets its [`crate::scheduler::strategy_prior_tpc`]
/// tokens/call at a pseudo-pull weight of `wins` capped at
/// [`MAX_SEED_PULLS`]. Arms with no fleet evidence are omitted so the
/// controller still explores them first. A cold fleet returns an empty
/// list — seeding with it is a no-op and the controller boots exactly
/// like the unseeded seed behavior.
pub fn fleet_arm_priors(metrics: &Metrics) -> Vec<ArmPrior> {
    DEFAULT_ARMS
        .iter()
        .filter_map(|&name| {
            let wins: u64 = name
                .kinds()
                .iter()
                .map(|k| metrics.strategy_wins[k.index()].load(Ordering::Relaxed))
                .sum();
            if wins == 0 {
                return None;
            }
            Some(ArmPrior {
                name,
                tokens_per_call: strategy_prior_tpc(metrics, name),
                pulls: wins.min(MAX_SEED_PULLS),
            })
        })
        .collect()
}

/// [`controller_for`] warm-started from the fleet's per-strategy
/// acceptance record: new sequences no longer boot with uniform arm
/// values (see [`SeqController::seed_arms`]).
pub fn controller_for_seeded(
    tables: &Arc<NgramTables>,
    q: usize,
    cache: &SessionCacheConfig,
    analog: &str,
    metrics: &Metrics,
) -> SeqController {
    let mut c = controller_for(tables, q, cache, analog);
    c.seed_arms(&fleet_arm_priors(metrics));
    c
}

/// Task-class arm priors from the shared store's fingerprint record: like
/// [`fleet_arm_priors`] but scoped to one prompt fingerprint, so a
/// chat-shaped request seeds from chat history instead of whatever the
/// rest of the fleet is serving. Same shrink discipline as the admission
/// prior ([`crate::scheduler::strategy_prior_tpc`]): thin evidence is
/// pulled toward the greedy baseline of 1.0. Empty when the store has no
/// record for this fingerprint.
pub fn fingerprint_arm_priors(store: &SharedDraftStore, fp: u64) -> Vec<ArmPrior> {
    let Some(stats) = store.fingerprint_stats(fp) else {
        return Vec::new();
    };
    DEFAULT_ARMS
        .iter()
        .filter_map(|&name| {
            let mut wins = 0u64;
            let mut accepted = 0u64;
            for k in name.kinds() {
                let (w, a) = stats[k.index()];
                wins += w;
                accepted += a;
            }
            if wins == 0 {
                return None;
            }
            let mean = accepted as f64 / wins as f64;
            let shrink = wins as f64
                / (wins as f64 + crate::scheduler::admission::PRIOR_SHRINK_CALLS);
            Some(ArmPrior {
                name,
                tokens_per_call: 1.0 + mean * shrink,
                pulls: wins.min(MAX_SEED_PULLS),
            })
        })
        .collect()
}

/// [`controller_for`] seeded from the most specific history available:
/// the prompt's task-class record in the shared store when it has one,
/// else the fleet-wide counters ([`controller_for_seeded`]'s behavior).
/// With no store attached this IS `controller_for_seeded`.
pub fn controller_for_fingerprint(
    tables: &Arc<NgramTables>,
    q: usize,
    cache: &SessionCacheConfig,
    analog: &str,
    metrics: &Metrics,
    store: Option<&SharedDraftStore>,
    prompt: &[TokenId],
) -> SeqController {
    if let Some(store) = store {
        let priors = fingerprint_arm_priors(store, crate::draft::fingerprint(prompt));
        if !priors.is_empty() {
            let mut c = controller_for(tables, q, cache, analog);
            c.seed_arms(&priors);
            return c;
        }
    }
    controller_for_seeded(tables, q, cache, analog, metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::draft::StrategyKind;

    #[test]
    fn fleet_priors_map_counters_to_arms() {
        let m = Metrics::new();
        assert!(fleet_arm_priors(&m).is_empty(), "cold fleet seeds nothing");
        // context-ngram wins a lot and deep; session cache wins a little
        for _ in 0..20 {
            m.record_strategy_step(StrategyKind::ContextNgram, 4);
        }
        m.record_strategy_step(StrategyKind::SessionCache, 1);
        let priors = fleet_arm_priors(&m);
        let ctx = priors
            .iter()
            .find(|p| p.name == StrategyName::Context)
            .expect("context arm must be seeded");
        assert_eq!(ctx.pulls, MAX_SEED_PULLS, "pulls cap at MAX_SEED_PULLS");
        assert!(ctx.tokens_per_call > 1.0);
        let session = priors
            .iter()
            .find(|p| p.name == StrategyName::Session)
            .expect("session arm must be seeded");
        assert_eq!(session.pulls, 1);
        assert!(
            ctx.tokens_per_call > session.tokens_per_call,
            "deep-accepting strategy must carry the larger prior"
        );
        // ext-bigram never won: it must stay unseeded (so UCB explores it)
        assert!(priors.iter().all(|p| p.name != StrategyName::ExtBigram));
        // Mixed spans context-ngram kinds, so it inherits that evidence
        assert!(priors.iter().any(|p| p.name == StrategyName::Mixed));
    }

    #[test]
    fn fingerprint_priors_scope_to_task_class() {
        let store = SharedDraftStore::new(1);
        let chat = crate::draft::fingerprint(&[1, 2, 3, 4]);
        let code = crate::draft::fingerprint(&[9, 9, 9, 9]);
        // chat traffic accepts deep session-cache chains; code traffic
        // wins shallow context-ngram rows
        for _ in 0..10 {
            store.record_step(chat, StrategyKind::SessionCache, 5);
            store.record_step(code, StrategyKind::ContextNgram, 1);
        }
        let chat_priors = fingerprint_arm_priors(&store, chat);
        let session = chat_priors
            .iter()
            .find(|p| p.name == StrategyName::Session)
            .expect("chat class seeds the session arm");
        assert_eq!(session.pulls, MAX_SEED_PULLS);
        assert!(session.tokens_per_call > 4.0);
        assert!(
            chat_priors.iter().all(|p| p.name != StrategyName::Context),
            "chat class must not inherit code-class evidence"
        );
        let code_priors = fingerprint_arm_priors(&store, code);
        assert!(code_priors.iter().any(|p| p.name == StrategyName::Context));
        assert!(code_priors.iter().all(|p| p.name != StrategyName::Session));
        // unknown class: no priors at all (caller falls back to fleet)
        assert!(fingerprint_arm_priors(&store, 0xDEAD).is_empty());
    }
}
