//! The per-sequence adaptive controller: a deterministic UCB bandit over
//! `StrategyName` arms plus cost-model-driven (k, w) planning.
//!
//! Determinism: arm choice and shape choice are pure functions of the
//! observed history (no RNG), so a decode replays bit-identically — the
//! losslessness property tests rely on nothing more than the acceptance
//! invariant, but deterministic control keeps benches reproducible.

use crate::costmodel::CostModel;
use crate::draft::{DraftBatch, DraftStrategy, StrategyKind};
use crate::scheduler::StrategyName;
use crate::tokenizer::TokenId;

use super::estimator::{ewma, AcceptanceEstimator, KindStats, WindowedAcceptance};
use super::{AdaptiveConfig, StepFeedback};

/// One bandit arm: a strategy plus its running value estimate.
struct Arm {
    name: StrategyName,
    strategy: Box<dyn DraftStrategy>,
    pulls: u64,
    /// EWMA of emitted tokens per step while this arm drove the draft
    ewma_emitted: f64,
    /// EWMA of the simulated verify cost per step (seconds, cost model)
    ewma_cost: f64,
    /// total tokens emitted across this arm's pulls (exact, for reporting)
    emitted_total: u64,
}

impl Arm {
    /// Expected accepted-tokens-per-verify-cost (the bandit's raw value;
    /// 0 until the arm has been pulled).
    fn value(&self) -> f64 {
        if self.pulls == 0 {
            0.0
        } else {
            self.ewma_emitted / self.ewma_cost.max(1e-12)
        }
    }
}

/// A fleet-derived warm-start prior for one arm (see
/// [`SeqController::seed_arms`]): the strategy's observed tokens/call
/// plus a pseudo-pull weight saying how much evidence backs it.
#[derive(Debug, Clone, Copy)]
pub struct ArmPrior {
    /// the arm this prior applies to
    pub name: StrategyName,
    /// expected emitted tokens per verification call (floored at the
    /// greedy baseline of 1.0 when applied)
    pub tokens_per_call: f64,
    /// pseudo-pull count the prior is worth (0 disables the prior; keep
    /// it small so live per-sequence feedback can overturn a stale fleet
    /// picture within a few EWMA updates)
    pub pulls: u64,
}

/// Operator-facing snapshot of one arm (bench / metrics output).
#[derive(Debug, Clone)]
pub struct ArmReport {
    /// the arm's strategy
    pub name: StrategyName,
    /// steps this arm drove the draft
    pub pulls: u64,
    /// EWMA of tokens emitted per pulled step
    pub ewma_emitted: f64,
    /// total tokens emitted across this arm's pulls (exact)
    pub emitted_total: u64,
    /// expected emitted tokens per second of simulated verify cost
    pub value: f64,
}

/// Online (k, w) + strategy selection for ONE sequence.
pub struct SeqController {
    /// tuning knobs (public so tests/benches can randomize them)
    pub cfg: AdaptiveConfig,
    cm: CostModel,
    arms: Vec<Arm>,
    /// arm driving the CURRENT step (chosen by `plan`, charged by `observe`)
    cur: usize,
    /// completed (observed) steps
    steps: u64,
    est: AcceptanceEstimator,
    /// EWMA of the accepted-prefix length per step (arm-agnostic)
    ewma_accept: f64,
    /// EWMA of "some draft token accepted" per step
    ewma_hit: f64,
    /// EWMA of winning row index + 1 (useful batch depth)
    ewma_depth: f64,
    /// confidence profile of the latest proposed batch, by row index
    /// (feeds the packed-batch allocator's marginal gains)
    last_conf: Vec<f64>,
    /// fleet-derived arm priors, re-applied on every [`Self::reset`] so a
    /// fresh request still boots from fleet-wide knowledge (empty =
    /// unseeded, the seed behavior)
    seeds: Vec<ArmPrior>,
    /// change-point detector over per-step acceptance rates: a hard
    /// regime shift (EWMAs too slow to notice) restarts the bandit's pull
    /// counts so exploration re-opens (see [`Self::observe`])
    window: WindowedAcceptance,
}

impl SeqController {
    /// `arms` must be non-empty and must not contain `Adaptive` itself.
    pub fn new(
        arms: Vec<(StrategyName, Box<dyn DraftStrategy>)>,
        cfg: AdaptiveConfig,
        cm: CostModel,
    ) -> Self {
        assert!(!arms.is_empty(), "adaptive controller needs at least one arm");
        assert!(
            arms.iter().all(|(n, _)| *n != StrategyName::Adaptive),
            "adaptive cannot be its own arm"
        );
        let alpha = cfg.alpha;
        SeqController {
            cfg,
            cm,
            arms: arms
                .into_iter()
                .map(|(name, strategy)| Arm {
                    name,
                    strategy,
                    pulls: 0,
                    ewma_emitted: 0.0,
                    ewma_cost: 0.0,
                    emitted_total: 0,
                })
                .collect(),
            cur: 0,
            steps: 0,
            est: AcceptanceEstimator::new(alpha),
            ewma_accept: 0.0,
            ewma_hit: 0.0,
            ewma_depth: 1.0,
            last_conf: Vec::new(),
            seeds: Vec::new(),
            window: WindowedAcceptance::new(Self::REGIME_SHIFT_THRESHOLD),
        }
    }

    /// Acceptance-rate swing (over [`WindowedAcceptance`]'s half-window
    /// means) that counts as a regime shift: half the speculation value
    /// appearing or vanishing.
    pub const REGIME_SHIFT_THRESHOLD: f64 = 0.5;

    /// Reference call shape the seeded arm values are priced at: every
    /// prior divides the same simulated verify cost, so seeding fixes the
    /// arms' RELATIVE order (what the bandit consumes) while staying on
    /// the same scale as live accepted-tokens-per-cost observations.
    const SEED_SHAPE: (usize, usize, usize) = (10, 10, 256);

    /// Warm-start arm values from fleet-wide priors (ROADMAP
    /// "cross-request bandit priors"; the admission-scorer half is
    /// [`crate::scheduler::strategy_prior_tpc`]). A seeded arm starts
    /// with `pulls` pseudo-pulls at `tokens_per_call` emitted per
    /// reference-shape verify cost, so a NEW sequence's bandit exploits
    /// the fleet's best-known strategy immediately instead of booting
    /// through the uniform round-robin warmup — while arms with no fleet
    /// evidence keep their infinite UCB bonus and still get explored
    /// first. Shape planning is deliberately NOT seeded: (k, w) depends
    /// on per-sequence acceptance EWMAs that only real feedback fills.
    /// Priors are stored and re-applied by [`Self::reset`], and live
    /// feedback folds into the seeded EWMAs like any later sample.
    pub fn seed_arms(&mut self, priors: &[ArmPrior]) {
        self.seeds = priors.to_vec();
        self.apply_seeds();
    }

    fn apply_seeds(&mut self) {
        let (k, w, ctx) = Self::SEED_SHAPE;
        let cost = self.cm.call_time(k, w + 1, ctx);
        for si in 0..self.seeds.len() {
            let p = self.seeds[si];
            if p.pulls == 0 || !p.tokens_per_call.is_finite() || p.tokens_per_call <= 0.0 {
                continue;
            }
            if let Some(arm) = self.arms.iter_mut().find(|a| a.name == p.name) {
                arm.pulls = p.pulls;
                arm.ewma_emitted = p.tokens_per_call.max(1.0);
                arm.ewma_cost = cost;
                // emitted_total stays 0: it is an exact observed counter
            }
        }
    }

    /// Whether any arm carries fleet-seeded evidence (skips the uniform
    /// warmup round-robin in [`Self::plan`]).
    fn seeded(&self) -> bool {
        self.seeds.iter().any(|p| p.pulls > 0 && p.tokens_per_call > 0.0)
    }

    /// Choose the arm and the desired (k, w) for the next step.
    ///
    /// `shapes` is the model's available artifact (k, w) grid; the result
    /// is one of those shapes (capped by `k_cap`/`w_cap`/`room`), so the
    /// engine's `best_fitting_shape` on it is exact. Idempotent: calling
    /// twice without an intervening `observe` re-derives the same answer.
    pub fn plan(
        &mut self,
        ctx_len: usize,
        room: usize,
        shapes: &[(usize, usize)],
        k_cap: usize,
        w_cap: usize,
    ) -> (usize, usize) {
        // --- arm: round-robin during warmup, then UCB. The exploration
        // bonus is ADDITIVE on max-normalized values (standard UCB1 form):
        // a weak arm's bonus grows with ln(total)/pulls until it gets
        // re-pulled, so regime shifts can re-trigger exploration — a
        // multiplicative bonus would be scaled away by the weak arm's own
        // low value and never fire.
        let n = self.arms.len();
        let warmup_steps = (self.cfg.warmup * n) as u64;
        // Fleet-seeded controllers skip the uniform round-robin warmup:
        // the seeded values already rank the arms, and any UNSEEDED arm
        // still gets pulled first through UCB's infinite bonus below.
        self.cur = if self.steps < warmup_steps && !self.seeded() {
            (self.steps as usize) % n
        } else {
            let total = self.steps as f64;
            let vmax = self.arms.iter().map(Arm::value).fold(1e-12, f64::max);
            let mut best = 0usize;
            let mut best_s = f64::NEG_INFINITY;
            for (i, a) in self.arms.iter().enumerate() {
                let s = if a.pulls == 0 {
                    f64::INFINITY
                } else {
                    a.value() / vmax
                        + self.cfg.explore * (total.ln_1p() / a.pulls as f64).sqrt()
                };
                if s > best_s {
                    best_s = s;
                    best = i;
                }
            }
            best
        };

        // --- shape: before any feedback, behave like the static config
        if self.steps == 0 {
            return (k_cap, w_cap);
        }

        // Expected emitted tokens for shape (k, w): the bonus token plus
        // the expected accepted prefix, which saturates at the optimistic
        // depth estimate and needs enough rows to cover the useful rank
        // depth. The hit rate is floored so a cold streak can never
        // collapse the plan to w = 0 forever (w = 0 proposes nothing, so
        // acceptance could never be re-observed); probing stays ~free
        // while the verify call is memory-bound, which is the paper's
        // whole premise.
        let opt_len = self.ewma_accept * self.cfg.depth_optimism + 1.0;
        let depth_need = self.ewma_depth * self.cfg.depth_optimism + 1.0;
        let hit = self.ewma_hit.max(0.05);
        let expect = |k: usize, w: usize| -> f64 {
            let coverage = (k as f64 / depth_need).min(1.0);
            1.0 + hit * coverage * opt_len.min(w as f64)
        };
        let mut best: Option<((usize, usize), f64)> = None;
        for &(k, w) in shapes {
            if k > k_cap || w > w_cap || w + 1 > room {
                continue;
            }
            let v = expect(k, w) / self.cm.call_time(k, w + 1, ctx_len);
            match best {
                Some((_, bv)) if v <= bv => {}
                _ => best = Some(((k, w), v)),
            }
        }
        best.map(|(s, _)| s).unwrap_or((k_cap, w_cap))
    }

    /// Draft via the arm chosen by the latest `plan`; records the batch's
    /// confidence profile for the budget allocator.
    pub fn propose(&mut self, seq: &[TokenId], k: usize, batch: &mut DraftBatch) {
        self.arms[self.cur].strategy.propose(seq, k, batch);
        self.last_conf.clear();
        self.last_conf.extend(batch.rows().iter().map(|r| r.confidence));
    }

    /// Digest one judged step: arm value, per-kind estimators, shape
    /// statistics, and the arm strategy's own `observe`.
    pub fn observe(&mut self, fb: &StepFeedback) {
        let a = self.cfg.alpha;
        let cost = self.cm.call_time(fb.k, fb.w + 1, fb.ctx_len);
        let emitted = (fb.accepted + 1) as f64;

        let arm = &mut self.arms[self.cur];
        arm.ewma_emitted = ewma(arm.ewma_emitted, emitted, a, arm.pulls);
        arm.ewma_cost = ewma(arm.ewma_cost, cost, a, arm.pulls);
        arm.pulls += 1;
        arm.emitted_total += (fb.accepted + 1) as u64;
        // Stream feedback is arm-agnostic (the emitted tokens and verifier
        // output do not depend on who drafted), so EVERY arm gets to learn
        // from it — otherwise a late-blooming learning arm (session cache)
        // could never warm up while unpulled and the bandit would starve it
        // forever. Only the pulled arm's VALUE estimate is charged above.
        for other in &mut self.arms {
            other.strategy.observe(fb.emitted, fb.model_out);
        }

        self.est.observe(fb.batch, fb.row, fb.accepted);
        self.ewma_accept = ewma(self.ewma_accept, fb.accepted as f64, a, self.steps);
        let hit = if fb.accepted > 0 { 1.0 } else { 0.0 };
        self.ewma_hit = ewma(self.ewma_hit, hit, a, self.steps);
        if fb.accepted > 0 {
            // row 0 is the judge's default on barren steps — only genuine
            // wins say anything about the useful rank depth
            self.ewma_depth = ewma(self.ewma_depth, (fb.row + 1) as f64, a, self.steps);
        }
        self.steps += 1;

        // Regime shift: the windowed detector saw the per-step acceptance
        // rate flip hard (the EWMAs above only drift there). Restart the
        // bandit — zero every arm's pull count so the UCB bonus is
        // infinite again and each arm gets re-pulled under the NEW regime
        // (its first fresh sample re-initializes the value EWMAs, see
        // `estimator::ewma`). Lossless: re-exploring only costs speed.
        let rate = (fb.accepted as f64 / fb.w.max(1) as f64).min(1.0);
        if self.window.observe(rate) {
            for arm in &mut self.arms {
                arm.pulls = 0;
            }
        }
    }

    /// Acceptance-regime change-points detected so far (operator-facing;
    /// each one restarted the bandit's exploration).
    pub fn regime_shifts(&self) -> u64 {
        self.window.regime_shifts()
    }

    /// Tree-mode width planning: how many candidate rows to PROPOSE for a
    /// `k`-row tree block. The trie's prefix sharing frees node budget
    /// (`k*(w+1)` minus the shared nodes), and this decides how hard to
    /// fill that slack with extra sibling candidates. Deterministic, in
    /// `[k, 3k]`: a stream whose TOP-ranked row keeps winning needs no
    /// breadth (depth is where its budget pays), while frequent misses or
    /// rank-deep wins say the true token hides below the cut — widen.
    pub fn tree_overdraft(&self, k: usize) -> usize {
        let miss = 1.0 - self.ewma_hit;
        let rank_spread = (self.ewma_depth - 1.0).max(0.0) / k.max(1) as f64;
        let breadth = (miss + rank_spread).clamp(0.0, 1.0);
        k + (k as f64 * 2.0 * breadth).round() as usize
    }

    /// This sequence's "heat": expected accepted tokens per verification
    /// step, from the arm-agnostic acceptance EWMAs (hit rate times one
    /// plus the mean accepted-prefix length). Cold or cold-started
    /// sequences sit near 0; a stream accepting long drafts every step
    /// approaches `1 + w`. This is the demand signal the elastic
    /// scheduler's autoscaler aggregates across lanes: hot lanes retire
    /// sequences quickly, so the same queue needs fewer of them.
    pub fn heat(&self) -> f64 {
        self.ewma_hit * (1.0 + self.ewma_accept)
    }

    /// Expected accepted-tokens-per-second-of-verify-cost of the best arm
    /// so far (0 until any arm has been pulled) — the cost-aware aggregate
    /// the admission scorer compares against a cold request's prior.
    pub fn expected_rate(&self) -> f64 {
        self.arms.iter().map(Arm::value).fold(0.0, f64::max)
    }

    /// Marginal expected acceptance of this sequence's `row_idx`-th packed
    /// row next step (for [`super::budget::allocate_rows`]). Scaled by the
    /// sequence's [`Self::heat`] so hot sequences outbid cold ones; within
    /// a sequence it decays with the latest draft's confidence profile.
    pub fn marginal_gain(&self, row_idx: usize) -> f64 {
        let decay = self
            .last_conf
            .get(row_idx)
            .copied()
            .unwrap_or_else(|| super::budget::static_gain(row_idx));
        self.heat().max(1e-3) * decay
    }

    /// Per-arm statistics (pulls, EWMA emitted, tokens-per-cost value).
    pub fn arm_reports(&self) -> Vec<ArmReport> {
        self.arms
            .iter()
            .map(|a| ArmReport {
                name: a.name,
                pulls: a.pulls,
                ewma_emitted: a.ewma_emitted,
                emitted_total: a.emitted_total,
                value: a.value(),
            })
            .collect()
    }

    /// Per-kind acceptance estimates observed so far.
    pub fn kind_reports(&self) -> Vec<(StrategyKind, KindStats)> {
        self.est.active_kinds()
    }

    /// Completed (observed) steps so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Reset per-sequence state between requests. Arm strategies keep
    /// their own cross-request semantics (`SessionNgramCache` persists its
    /// table through reset by design), and fleet-seeded arm priors are
    /// re-applied so the next request boots warm too.
    pub fn reset(&mut self) {
        for arm in &mut self.arms {
            arm.strategy.reset();
            arm.pulls = 0;
            arm.ewma_emitted = 0.0;
            arm.ewma_cost = 0.0;
            arm.emitted_total = 0;
        }
        self.cur = 0;
        self.steps = 0;
        self.est.reset();
        self.ewma_accept = 0.0;
        self.ewma_hit = 0.0;
        self.ewma_depth = 1.0;
        self.last_conf.clear();
        self.window.reset();
        self.apply_seeds();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NoDraft;

    fn ctl(n_arms: usize) -> SeqController {
        let names = [
            StrategyName::Mixed,
            StrategyName::Context,
            StrategyName::ExtBigram,
        ];
        let arms: Vec<(StrategyName, Box<dyn DraftStrategy>)> = names[..n_arms]
            .iter()
            .map(|&n| (n, Box::new(NoDraft) as Box<dyn DraftStrategy>))
            .collect();
        SeqController::new(arms, AdaptiveConfig::default(), CostModel::for_analog("mistral"))
    }

    fn feed(c: &mut SeqController, accepted: usize, k: usize, w: usize) {
        let mut b = DraftBatch::new(w);
        b.push(vec![0; w.min(3)], StrategyKind::ContextNgram, 0);
        let emitted = vec![0u32; accepted + 1];
        let model_out = vec![0u32; w + 1];
        c.observe(&StepFeedback {
            batch: &b,
            row: 0,
            accepted,
            emitted: &emitted,
            model_out: &model_out,
            k,
            w,
            ctx_len: 100,
        });
    }

    const SHAPES: [(usize, usize); 8] = [
        (1, 0), (1, 4), (2, 4), (5, 4), (5, 10), (10, 10), (10, 14), (25, 14),
    ];

    #[test]
    fn cold_plan_matches_static_config() {
        let mut c = ctl(2);
        assert_eq!(c.plan(10, 100, &SHAPES, 10, 10), (10, 10));
    }

    #[test]
    fn warmup_round_robins_arms() {
        let mut c = ctl(3);
        for expect_arm in [0usize, 1, 2, 0, 1, 2] {
            c.plan(10, 100, &SHAPES, 10, 10);
            assert_eq!(c.cur, expect_arm);
            feed(&mut c, 1, 10, 10);
        }
    }

    #[test]
    fn hot_sequence_plans_deep_cold_plans_shallow() {
        let mut hot = ctl(1);
        for _ in 0..12 {
            hot.plan(10, 100, &SHAPES, 25, 14);
            feed(&mut hot, 9, 10, 10);
        }
        let (_, w_hot) = hot.plan(10, 100, &SHAPES, 25, 14);
        assert!(w_hot >= 10, "hot sequence chose w={w_hot}");

        let mut cold = ctl(1);
        for _ in 0..12 {
            cold.plan(10, 100, &SHAPES, 25, 14);
            feed(&mut cold, 0, 10, 10);
        }
        let (k_cold, w_cold) = cold.plan(10, 100, &SHAPES, 25, 14);
        assert!(
            w_cold <= w_hot && k_cold <= 25,
            "cold sequence chose ({k_cold}, {w_cold}) vs hot w={w_hot}"
        );
    }

    #[test]
    fn plan_respects_room_and_caps() {
        let mut c = ctl(1);
        feed(&mut c, 3, 5, 4);
        let (k, w) = c.plan(10, 3, &SHAPES, 10, 14); // room 3 -> w + 1 <= 3
        assert!(w + 1 <= 3 && k <= 10);
    }

    #[test]
    fn plan_is_idempotent_between_observes() {
        let mut c = ctl(3);
        for _ in 0..8 {
            c.plan(10, 100, &SHAPES, 10, 10);
            feed(&mut c, 2, 10, 10);
        }
        let a = c.plan(50, 100, &SHAPES, 10, 10);
        let arm_a = c.cur;
        let b = c.plan(50, 100, &SHAPES, 10, 10);
        assert_eq!(a, b);
        assert_eq!(arm_a, c.cur);
    }

    #[test]
    fn bandit_prefers_the_paying_arm() {
        let mut c = ctl(2);
        // warmup: arm 0 gets big acceptance, arm 1 gets none
        for _ in 0..20 {
            c.plan(10, 100, &SHAPES, 10, 10);
            let acc = if c.cur == 0 { 8 } else { 0 };
            feed(&mut c, acc, 10, 10);
        }
        c.plan(10, 100, &SHAPES, 10, 10);
        assert_eq!(c.cur, 0, "bandit should exploit the accepting arm");
    }

    #[test]
    fn marginal_gain_decays_with_depth_and_scales_with_heat() {
        let mut hot = ctl(1);
        for _ in 0..6 {
            hot.plan(10, 100, &SHAPES, 10, 10);
            feed(&mut hot, 8, 10, 10);
        }
        let cold = ctl(1);
        assert!(hot.marginal_gain(0) > cold.marginal_gain(0));
        assert!(hot.marginal_gain(0) >= hot.marginal_gain(5));
    }

    #[test]
    fn tree_overdraft_widens_on_misses_and_stays_bounded() {
        // cold start: full miss rate -> maximum breadth
        let cold = ctl(1);
        assert_eq!(cold.tree_overdraft(5), 15);
        // a stream whose top row always wins deep needs no extra width
        let mut hot = ctl(1);
        for _ in 0..12 {
            hot.plan(10, 100, &SHAPES, 10, 10);
            feed(&mut hot, 8, 10, 10); // row 0 wins every step
        }
        let od = hot.tree_overdraft(5);
        assert!((5..15).contains(&od), "hot overdraft {od} should shed breadth");
        assert!(od < cold.tree_overdraft(5));
        // bounds hold for any k
        for k in [1usize, 2, 5, 25] {
            let o = cold.tree_overdraft(k);
            assert!((k..=3 * k).contains(&o));
        }
    }

    #[test]
    fn heat_and_expected_rate_track_acceptance() {
        let mut hot = ctl(1);
        let cold = ctl(1);
        for _ in 0..6 {
            hot.plan(10, 100, &SHAPES, 10, 10);
            feed(&mut hot, 8, 10, 10);
        }
        assert!(hot.heat() > cold.heat(), "hot {} vs cold {}", hot.heat(), cold.heat());
        assert!(hot.expected_rate() > 0.0);
        assert_eq!(cold.expected_rate(), 0.0, "unpulled arms must report rate 0");
    }

    #[test]
    fn reset_restores_cold_start() {
        let mut c = ctl(2);
        for _ in 0..5 {
            c.plan(10, 100, &SHAPES, 10, 10);
            feed(&mut c, 4, 10, 10);
        }
        c.reset();
        assert_eq!(c.steps(), 0);
        assert_eq!(c.plan(10, 100, &SHAPES, 10, 10), (10, 10));
        assert!(c.arm_reports().iter().all(|r| r.pulls == 0));
    }

    #[test]
    fn seeded_controller_skips_warmup_and_exploits_the_prior() {
        let mut c = ctl(3);
        // fleet says Context pays 3.2 tokens/call, Mixed only 1.1; the
        // third arm (ExtBigram) has no fleet evidence
        c.seed_arms(&[
            ArmPrior { name: StrategyName::Context, tokens_per_call: 3.2, pulls: 8 },
            ArmPrior { name: StrategyName::Mixed, tokens_per_call: 1.1, pulls: 8 },
        ]);
        // every arm now has a value except the unseeded one, which keeps
        // the infinite UCB bonus: it gets explored first...
        c.plan(10, 100, &SHAPES, 10, 10);
        assert_eq!(c.cur, 2, "unseeded arm must be explored first");
        feed(&mut c, 0, 10, 10);
        // ...then the bandit exploits the best SEEDED arm instead of
        // round-robining through warmup (arms are Mixed=0, Context=1)
        c.plan(10, 100, &SHAPES, 10, 10);
        assert_eq!(c.cur, 1, "bandit must exploit the fleet's best arm");
    }

    #[test]
    fn seeds_survive_reset_and_live_feedback_can_overturn_them() {
        let mut c = ctl(2);
        c.seed_arms(&[
            ArmPrior { name: StrategyName::Mixed, tokens_per_call: 1.05, pulls: 4 },
            ArmPrior { name: StrategyName::Context, tokens_per_call: 4.0, pulls: 4 },
        ]);
        c.reset();
        assert!(
            c.arm_reports().iter().any(|r| r.pulls > 0),
            "seeded pulls must survive reset"
        );
        // the seeded favourite (Context, arm 1) gets pulled but never
        // accepts; the seeded underdog eventually wins the bandit back
        for _ in 0..40 {
            c.plan(10, 100, &SHAPES, 10, 10);
            let acc = if c.cur == 0 { 6 } else { 0 };
            feed(&mut c, acc, 10, 10);
        }
        c.plan(10, 100, &SHAPES, 10, 10);
        assert_eq!(c.cur, 0, "live feedback must overturn a stale prior");
    }

    #[test]
    fn hard_regime_flip_reopens_the_bandit_within_the_window() {
        let mut c = ctl(2);
        // arm 0 pays richly: the bandit converges on it and stops
        // exploring arm 1 (its UCB bonus alone cannot catch up)
        for _ in 0..30 {
            c.plan(10, 100, &SHAPES, 10, 10);
            let acc = if c.cur == 0 { 8 } else { 0 };
            feed(&mut c, acc, 10, 10);
        }
        c.plan(10, 100, &SHAPES, 10, 10);
        assert_eq!(c.cur, 0, "bandit must have converged before the flip");
        assert_eq!(c.regime_shifts(), 0, "steady regime must not false-fire");
        // hard flip: acceptance collapses to zero for everything
        for _ in 0..WindowedAcceptance::WINDOW {
            c.plan(10, 100, &SHAPES, 10, 10);
            feed(&mut c, 0, 10, 10);
        }
        assert_eq!(
            c.regime_shifts(),
            1,
            "a hard acceptance flip must be detected within one window"
        );
        // and the bandit actually re-opened: the abandoned arm is
        // re-pulled within a couple of steps (infinite UCB bonus again)
        let mut repulled = false;
        for _ in 0..4 {
            c.plan(10, 100, &SHAPES, 10, 10);
            repulled |= c.cur == 1;
            feed(&mut c, 0, 10, 10);
        }
        assert!(repulled, "regime shift must re-open the abandoned arm");
    }

    #[test]
    fn unseeded_behavior_is_unchanged() {
        let mut a = ctl(2);
        let mut b = ctl(2);
        b.seed_arms(&[]); // empty priors = unseeded
        for _ in 0..6 {
            let pa = a.plan(10, 100, &SHAPES, 10, 10);
            let pb = b.plan(10, 100, &SHAPES, 10, 10);
            assert_eq!(pa, pb);
            assert_eq!(a.cur, b.cur);
            feed(&mut a, 2, 10, 10);
            feed(&mut b, 2, 10, 10);
        }
    }
}
