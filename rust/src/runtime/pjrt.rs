//! PJRT/XLA execution backend (feature `pjrt`): loads the AOT HLO-text
//! artifacts written by `python -m compile.aot` and executes them on the
//! CPU PJRT client (`xla` crate).
//!
//! This module is OFF by default so the crate builds without the offline
//! accelerator toolchain; enable with `--features pjrt` after adding the
//! `xla` crate from the toolchain image to [dependencies].
//!
//! Design notes:
//! - Interchange is HLO **text** (jax >= 0.5 serialized protos use 64-bit
//!   instruction ids that xla_extension 0.5.1 rejects; the text parser
//!   reassigns ids — see /opt/xla-example/README.md).
//! - Model weights are uploaded ONCE as device buffers; per-call arguments
//!   (tokens, KV cache, cache_len) are marshalled per step via
//!   `buffer_from_host_buffer` and everything runs through `execute_b`.
//! - Executables for each (k, w) shape are compiled lazily on first use
//!   and cached for the life of the process.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::config::ModelArtifacts;
use crate::kvcache::{KvRead, KvWrite};
use crate::tokenizer::TokenId;

use super::{PrefillOutput, StepOutput};

pub struct PjrtBackend {
    client: PjRtClient,
    params: Vec<PjRtBuffer>,
    steps: RefCell<HashMap<(usize, usize), PjRtLoadedExecutable>>,
    prefills: RefCell<HashMap<usize, PjRtLoadedExecutable>>,
}

impl PjrtBackend {
    pub fn load(art: &ModelArtifacts) -> Result<Self> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let params = upload_params(&client, art)?;
        Ok(PjrtBackend {
            client,
            params,
            steps: RefCell::new(HashMap::new()),
            prefills: RefCell::new(HashMap::new()),
        })
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    fn compile(&self, path: &Path) -> Result<PjRtLoadedExecutable> {
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))
    }

    pub fn warm_step(&self, path: &Path, k: usize, w: usize) -> Result<()> {
        let mut steps = self.steps.borrow_mut();
        if !steps.contains_key(&(k, w)) {
            let exe = self.compile(path)?;
            steps.insert((k, w), exe);
        }
        Ok(())
    }

    pub fn warm_prefill(&self, path: &Path, bucket: usize) -> Result<()> {
        let mut pf = self.prefills.borrow_mut();
        if !pf.contains_key(&bucket) {
            let exe = self.compile(path)?;
            pf.insert(bucket, exe);
        }
        Ok(())
    }

    /// Run prefill for `prompt` (bucket pre-warmed by the caller), filling
    /// `cache` and returning the first greedy next-token.
    pub fn prefill(
        &self,
        art: &ModelArtifacts,
        bucket: usize,
        prompt: &[TokenId],
        cache: &mut dyn KvWrite,
    ) -> Result<PrefillOutput> {
        let pf = self.prefills.borrow();
        let exe = pf
            .get(&bucket)
            .ok_or_else(|| anyhow!("prefill bucket {bucket} not warmed"))?;
        let _ = art;

        let mut toks: Vec<i32> = prompt.iter().map(|&t| t as i32).collect();
        toks.resize(bucket, 0);
        let tok_buf = self
            .client
            .buffer_from_host_buffer(&toks, &[1, bucket], None)?;
        let len_buf = self
            .client
            .buffer_from_host_buffer(&[prompt.len() as i32], &[], None)?;

        let mut args: Vec<&PjRtBuffer> = self.params.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);

        let t = Instant::now();
        let result = exe.execute_b(&args)?;
        let lit = result[0][0].to_literal_sync()?;
        let exec_time = t.elapsed();

        let outs = tuple_elements(lit)?;
        if outs.len() != 3 {
            return Err(anyhow!("prefill returned {} outputs, want 3", outs.len()));
        }
        let next_id = outs[0].to_vec::<i32>()?[0] as TokenId;
        let kc = outs[1].to_vec::<f32>()?;
        let vc = outs[2].to_vec::<f32>()?;
        cache.install(kc, vc, prompt.len())?;
        Ok(PrefillOutput { next_id, exec_time })
    }

    /// One verification call on a (k, w+1) block (shape pre-warmed and
    /// pre-validated by the caller).
    pub fn spec_step(
        &self,
        art: &ModelArtifacts,
        k: usize,
        w: usize,
        tokens: &[TokenId],
        cache: &dyn KvRead,
    ) -> Result<StepOutput> {
        let w1 = w + 1;
        let steps = self.steps.borrow();
        let exe = steps
            .get(&(k, w))
            .ok_or_else(|| anyhow!("step ({k}, {w}) not warmed"))?;

        let toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let d = &art.dims;
        let cache_dims = [d.n_layers, d.max_len, d.n_heads, d.head_dim];
        // A contiguous lane uploads its buffers directly; a paged view is
        // gathered into the same dense (layers, max_len, heads, head_dim)
        // geometry the AOT executable was compiled for.
        let gathered;
        let (kd, vd): (&[f32], &[f32]) = match cache.as_contiguous() {
            Some(s) => s,
            None => {
                gathered = cache.gather();
                (&gathered.0, &gathered.1)
            }
        };
        let tok_buf = self.client.buffer_from_host_buffer(&toks, &[k, w1], None)?;
        let kc_buf = self.client.buffer_from_host_buffer(kd, &cache_dims, None)?;
        let vc_buf = self.client.buffer_from_host_buffer(vd, &cache_dims, None)?;
        let len_buf = self
            .client
            .buffer_from_host_buffer(&[cache.ctx_len() as i32], &[], None)?;

        let mut args: Vec<&PjRtBuffer> = self.params.iter().collect();
        args.push(&tok_buf);
        args.push(&kc_buf);
        args.push(&vc_buf);
        args.push(&len_buf);

        let t = Instant::now();
        let result = exe.execute_b(&args)?;
        let lit = result[0][0].to_literal_sync()?;
        let exec_time = t.elapsed();

        let outs = tuple_elements(lit)?;
        if outs.len() != 3 {
            return Err(anyhow!("step returned {} outputs, want 3", outs.len()));
        }
        let next_ids: Vec<TokenId> = outs[0]
            .to_vec::<i32>()?
            .into_iter()
            .map(|t| t as TokenId)
            .collect();
        let k_tail = outs[1].to_vec::<f32>()?;
        let v_tail = outs[2].to_vec::<f32>()?;
        Ok(StepOutput { next_ids, k, w1, k_tail, v_tail, exec_time })
    }
}

fn upload_params(client: &PjRtClient, art: &ModelArtifacts) -> Result<Vec<PjRtBuffer>> {
    let bytes = std::fs::read(&art.params_bin)
        .with_context(|| format!("reading params {:?}", art.params_bin))?;
    let total: usize = art.param_spec.iter().map(|p| p.numel()).sum();
    if bytes.len() != total * 4 {
        return Err(anyhow!(
            "params.bin is {} bytes, manifest expects {}",
            bytes.len(),
            total * 4
        ));
    }
    let mut floats = vec![0f32; total];
    for (i, c) in bytes.chunks_exact(4).enumerate() {
        floats[i] = f32::from_le_bytes(c.try_into().unwrap());
    }
    let mut bufs = Vec::with_capacity(art.param_spec.len());
    let mut off = 0;
    for spec in &art.param_spec {
        let n = spec.numel();
        let buf = client
            .buffer_from_host_buffer(&floats[off..off + n], &spec.shape, None)
            .with_context(|| format!("uploading param {}", spec.name))?;
        bufs.push(buf);
        off += n;
    }
    Ok(bufs)
}

fn tuple_elements(lit: Literal) -> Result<Vec<Literal>> {
    Ok(lit.to_tuple()?)
}
