//! Deterministic reference backend: a pure-Rust stand-in for the PJRT/XLA
//! execution path that needs no accelerator toolchain, no python artifacts
//! and no network. It is the DEFAULT backend (the `pjrt` feature selects
//! the real one) so `cargo build && cargo test` work on a bare machine.
//!
//! The "model" is a deterministic function of the full token context:
//!
//! - a rolling FNV-style hash `h` is folded over every consumed token;
//! - with probability 1/SURPRISE (decided by `mix(h)`, i.e. by the WHOLE
//!   context) the next token is a pseudo-random "surprise" draw;
//! - otherwise it is `bigram_next(last)` — a fixed per-model bigram
//!   attractor.
//!
//! Two properties make this a faithful verification stand-in:
//!
//! 1. **Cache honesty.** `spec_step` recovers the context ONLY from the KV
//!    cache (each committed position encodes its token id in the K values,
//!    and the negated id in the V values). Any commit bug — wrong row,
//!    wrong layer offset, k/v swap, cross-lane contamination — corrupts
//!    the recovered context and immediately breaks the greedy-equivalence
//!    tests, exactly like a real KV bug would.
//! 2. **Speculatable dynamics.** ~3/4 of positions follow the bigram
//!    attractor, so the synthetic N-gram tables built from the same
//!    `bigram_next` function (see `testkit`) get realistic, non-trivial
//!    acceptance rates, while surprise positions keep acceptance < 100%.

use std::cell::RefCell;
use std::collections::HashSet;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::config::ModelArtifacts;
use crate::kvcache::{KvRead, KvWrite};
use crate::tokenizer::TokenId;

use super::{PackedBlock, PackedTreeBlock, PrefillOutput, StepOutput};

/// First token of a valid reference step artifact file.
pub const STEP_MAGIC: &str = "REFSTEP";
/// First token of a valid reference prefill artifact file.
pub const PREFILL_MAGIC: &str = "REFPREFILL";

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
/// 1 in SURPRISE positions deviate from the bigram attractor.
const SURPRISE: u64 = 4;

/// SplitMix64 finalizer — the scrambler behind every pseudo-random draw.
pub fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Per-model seed: FNV-1a over the params.bin bytes, so corrupting the
/// weights changes the model and truncating them fails the load check.
pub fn seed_from_params(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Rolling-hash initial state for an empty context.
pub fn hash_init(seed: u64) -> u64 {
    FNV_OFFSET ^ mix(seed)
}

/// Fold one consumed token into the rolling context hash.
pub fn hash_push(h: u64, t: TokenId) -> u64 {
    (h ^ (t as u64).wrapping_add(0x9E37_79B9)).wrapping_mul(FNV_PRIME)
}

/// The model's bigram attractor: the "likely" next token after `x`.
/// The synthetic tables in `testkit` are built from this same function,
/// which is what gives the draft strategies real acceptance.
pub fn bigram_next(seed: u64, x: TokenId, vocab: usize) -> TokenId {
    (mix(seed ^ 0x00B1_6000 ^ ((x as u64) << 17).wrapping_add(x as u64)) % vocab as u64) as TokenId
}

/// Greedy next token given the rolling hash `h` of the full consumed
/// context and the last consumed token.
pub fn next_token(seed: u64, h: u64, last: TokenId, vocab: usize) -> TokenId {
    let g = mix(h);
    if g % SURPRISE == 0 {
        (mix(g ^ 0x51AB_0001) % vocab as u64) as TokenId
    } else {
        bigram_next(seed, last, vocab)
    }
}

/// Test oracle: the model's greedy continuation of `prefix`, computed
/// directly on tokens (no KV cache involved).
pub fn greedy_continuation(seed: u64, prefix: &[TokenId], vocab: usize, n: usize) -> Vec<TokenId> {
    let mut h = hash_init(seed);
    for &t in prefix {
        h = hash_push(h, t);
    }
    let mut last = prefix.last().copied().unwrap_or(0);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let t = next_token(seed, h, last, vocab);
        out.push(t);
        h = hash_push(h, t);
        last = t;
    }
    out
}

/// The reference execution backend for one loaded model.
pub struct RefBackend {
    seed: u64,
    vocab: usize,
    steps_ok: RefCell<HashSet<(usize, usize)>>,
    prefills_ok: RefCell<HashSet<usize>>,
}

impl RefBackend {
    /// Validate `params.bin` against the manifest and derive the model seed.
    pub fn load(art: &ModelArtifacts) -> Result<Self> {
        let bytes = std::fs::read(&art.params_bin)
            .with_context(|| format!("reading params {:?}", art.params_bin))?;
        let total: usize = art.param_spec.iter().map(|p| p.numel()).sum();
        if bytes.len() != total * 4 {
            return Err(anyhow!(
                "params.bin is {} bytes, manifest expects {}",
                bytes.len(),
                total * 4
            ));
        }
        Ok(RefBackend {
            seed: seed_from_params(&bytes),
            vocab: art.dims.vocab_size,
            steps_ok: RefCell::new(HashSet::new()),
            prefills_ok: RefCell::new(HashSet::new()),
        })
    }

    /// The parameter-derived model seed (tests predict outputs from it).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// "Compile" a step artifact: validate the file's header. Garbage (e.g.
    /// real HLO text fed to the wrong backend) fails here, not at execute.
    pub fn warm_step(&self, path: &Path, k: usize, w: usize) -> Result<()> {
        if self.steps_ok.borrow().contains(&(k, w)) {
            return Ok(());
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading step artifact {path:?}"))?;
        let want = format!("{STEP_MAGIC} k={k} w={w}");
        let first = text.lines().next().unwrap_or("");
        if first.trim() != want {
            return Err(anyhow!(
                "bad step artifact {path:?}: expected header '{want}', got '{first}'"
            ));
        }
        self.steps_ok.borrow_mut().insert((k, w));
        Ok(())
    }

    /// Validate a prefill artifact's header (see [`Self::warm_step`]).
    pub fn warm_prefill(&self, path: &Path, bucket: usize) -> Result<()> {
        if self.prefills_ok.borrow().contains(&bucket) {
            return Ok(());
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading prefill artifact {path:?}"))?;
        let want = format!("{PREFILL_MAGIC} p={bucket}");
        let first = text.lines().next().unwrap_or("");
        if first.trim() != want {
            return Err(anyhow!(
                "bad prefill artifact {path:?}: expected header '{want}', got '{first}'"
            ));
        }
        self.prefills_ok.borrow_mut().insert(bucket);
        Ok(())
    }

    /// Reference prefill: fill `cache` deterministically from the prompt
    /// and return the first greedy token.
    pub fn prefill(
        &self,
        art: &ModelArtifacts,
        prompt: &[TokenId],
        cache: &mut dyn KvWrite,
    ) -> Result<PrefillOutput> {
        let t0 = Instant::now();
        let n = cache.numel();
        let mut k_data = vec![0.0f32; n];
        let mut v_data = vec![0.0f32; n];
        let ps = cache.pos_stride();
        let ls = cache.layer_stride();
        for (pos, &tok) in prompt.iter().enumerate() {
            for layer in 0..cache.layers() {
                let base = layer * ls + pos * ps;
                for e in 0..ps {
                    k_data[base + e] = tok as f32;
                    v_data[base + e] = -(tok as f32) - 1.0;
                }
            }
        }
        cache.install(k_data, v_data, prompt.len())?;

        let mut h = hash_init(self.seed);
        for &t in prompt {
            h = hash_push(h, t);
        }
        let _ = art;
        let last = *prompt.last().expect("non-empty prompt checked by caller");
        let next_id = next_token(self.seed, h, last, self.vocab);
        Ok(PrefillOutput { next_id, exec_time: t0.elapsed() })
    }

    /// Recover the committed context tokens from the K half of the cache.
    /// Reads go through [`KvRead::k_at`], so a paged page-table walk and a
    /// contiguous lane are decoded identically.
    fn decode_context(&self, cache: &dyn KvRead) -> Vec<TokenId> {
        (0..cache.ctx_len())
            .map(|pos| {
                let v = cache.k_at(0, pos)[0];
                if v.is_finite() && v >= 0.0 {
                    v.round() as TokenId
                } else {
                    // corrupted slot (e.g. a k/v swap wrote negatives here):
                    // decode to an arbitrary token so the divergence surfaces
                    0
                }
            })
            .collect()
    }

    /// Model outputs + KV tails for one (k, w+1) block against one cache.
    fn block_outputs(
        &self,
        layers: usize,
        ps: usize,
        k: usize,
        w1: usize,
        tokens: &[TokenId],
        cache: &dyn KvRead,
    ) -> (Vec<TokenId>, Vec<f32>, Vec<f32>) {
        let ctx = self.decode_context(cache);
        let mut h_ctx = hash_init(self.seed);
        for &t in &ctx {
            h_ctx = hash_push(h_ctx, t);
        }

        let mut next_ids = vec![0 as TokenId; k * w1];
        let n_tail = layers * k * w1 * ps;
        let mut k_tail = vec![0.0f32; n_tail];
        let mut v_tail = vec![0.0f32; n_tail];
        for r in 0..k {
            let mut h = h_ctx;
            for i in 0..w1 {
                let t = tokens[r * w1 + i];
                h = hash_push(h, t);
                next_ids[r * w1 + i] = next_token(self.seed, h, t, self.vocab);
                for layer in 0..layers {
                    let base = ((layer * k + r) * w1 + i) * ps;
                    for e in 0..ps {
                        k_tail[base + e] = t as f32;
                        v_tail[base + e] = -(t as f32) - 1.0;
                    }
                }
            }
        }
        (next_ids, k_tail, v_tail)
    }

    /// Model outputs + KV tails for one speculation TREE against one
    /// cache. Each node's context is the committed cache context plus the
    /// mask-selected root-to-node token path: masks are self-inclusive and
    /// parents precede children, so folding the masked tokens in ascending
    /// node-index order replays exactly that node's path. Outputs are
    /// shaped (n, 1): one prediction and one KV tail position per node.
    fn tree_outputs(
        &self,
        layers: usize,
        ps: usize,
        tree: &crate::draft::DraftTree,
        cache: &dyn KvRead,
    ) -> (Vec<TokenId>, Vec<f32>, Vec<f32>) {
        let ctx = self.decode_context(cache);
        let mut h_ctx = hash_init(self.seed);
        for &t in &ctx {
            h_ctx = hash_push(h_ctx, t);
        }

        let n = tree.len();
        let toks = tree.tokens();
        let words = tree.words();
        let masks = tree.masks();
        let mut next_ids = vec![0 as TokenId; n];
        let mut k_tail = vec![0.0f32; layers * n * ps];
        let mut v_tail = vec![0.0f32; layers * n * ps];
        for j in 0..n {
            let mask = &masks[j * words..(j + 1) * words];
            let mut h = h_ctx;
            for (i, &t) in toks.iter().enumerate().take(j + 1) {
                if mask[i / 64] & (1u64 << (i % 64)) != 0 {
                    h = hash_push(h, t);
                }
            }
            let t = toks[j];
            next_ids[j] = next_token(self.seed, h, t, self.vocab);
            for layer in 0..layers {
                let base = (layer * n + j) * ps;
                for e in 0..ps {
                    k_tail[base + e] = t as f32;
                    v_tail[base + e] = -(t as f32) - 1.0;
                }
            }
        }
        (next_ids, k_tail, v_tail)
    }

    /// One PACKED verification call over speculation trees (the tree-mode
    /// hot path). As with [`Self::spec_step_packed`], every returned
    /// output carries the whole packed call's latency.
    pub fn spec_step_tree_packed(
        &self,
        art: &ModelArtifacts,
        blocks: &[PackedTreeBlock],
    ) -> Result<Vec<StepOutput>> {
        let t0 = Instant::now();
        let d = &art.dims;
        let ps = d.n_heads * d.head_dim;
        let raw: Vec<(Vec<TokenId>, Vec<f32>, Vec<f32>, usize)> = blocks
            .iter()
            .map(|b| {
                let (ids, kt, vt) = self.tree_outputs(d.n_layers, ps, b.tree, b.cache);
                (ids, kt, vt, b.tree.len())
            })
            .collect();
        let exec_time = t0.elapsed();
        Ok(raw
            .into_iter()
            .map(|(next_ids, k_tail, v_tail, n)| StepOutput {
                next_ids,
                k: n,
                w1: 1,
                k_tail,
                v_tail,
                exec_time,
            })
            .collect())
    }

    /// Reference verification call on one (k, w) block against `cache`.
    pub fn spec_step(
        &self,
        art: &ModelArtifacts,
        k: usize,
        w: usize,
        tokens: &[TokenId],
        cache: &dyn KvRead,
    ) -> Result<StepOutput> {
        let t0 = Instant::now();
        let w1 = w + 1;
        let d = &art.dims;
        let (next_ids, k_tail, v_tail) =
            self.block_outputs(d.n_layers, d.n_heads * d.head_dim, k, w1, tokens, cache);
        Ok(StepOutput { next_ids, k, w1, k_tail, v_tail, exec_time: t0.elapsed() })
    }

    /// One PACKED verification call: all blocks are judged in a single
    /// device call (this is the batched-engine hot path). Every returned
    /// `StepOutput` carries the full packed-call latency, because that is
    /// the wall time each participating sequence actually waited.
    pub fn spec_step_packed(
        &self,
        art: &ModelArtifacts,
        w: usize,
        blocks: &[PackedBlock],
    ) -> Result<Vec<StepOutput>> {
        let t0 = Instant::now();
        let w1 = w + 1;
        let d = &art.dims;
        let ps = d.n_heads * d.head_dim;
        let raw: Vec<(Vec<TokenId>, Vec<f32>, Vec<f32>, usize)> = blocks
            .iter()
            .map(|b| {
                let (ids, kt, vt) = self.block_outputs(d.n_layers, ps, b.k, w1, b.tokens, b.cache);
                (ids, kt, vt, b.k)
            })
            .collect();
        let exec_time = t0.elapsed();
        Ok(raw
            .into_iter()
            .map(|(next_ids, k_tail, v_tail, k)| StepOutput {
                next_ids,
                k,
                w1,
                k_tail,
                v_tail,
                exec_time,
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_is_deterministic() {
        let a = greedy_continuation(7, &[1, 2, 3], 100, 16);
        let b = greedy_continuation(7, &[1, 2, 3], 100, 16);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (t as usize) < 100));
    }

    #[test]
    fn different_seeds_differ() {
        let a = greedy_continuation(1, &[5, 6], 512, 24);
        let b = greedy_continuation(2, &[5, 6], 512, 24);
        assert_ne!(a, b);
    }

    #[test]
    fn mostly_follows_bigram_attractor() {
        // ~3/4 of transitions must equal bigram_next(last) — that is what
        // makes the synthetic tables accept.
        let seed = 42u64;
        let vocab = 300;
        let toks = greedy_continuation(seed, &[9], vocab, 400);
        let mut follow = 0usize;
        let mut last = 9 as TokenId;
        for &t in &toks {
            if t == bigram_next(seed, last, vocab) {
                follow += 1;
            }
            last = t;
        }
        let frac = follow as f64 / toks.len() as f64;
        assert!(frac > 0.55 && frac < 0.95, "attractor fraction {frac}");
    }

    #[test]
    fn surprise_depends_on_full_context() {
        // changing an EARLY token must (almost surely) change the stream,
        // proving outputs depend on the whole context, not just `last`.
        let a = greedy_continuation(3, &[1, 2, 3, 4, 5, 6, 7, 8], 512, 64);
        let b = greedy_continuation(3, &[9, 2, 3, 4, 5, 6, 7, 8], 512, 64);
        assert_ne!(a, b);
    }
}
