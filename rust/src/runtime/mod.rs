//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! CPU PJRT client (`xla` crate). This is the ONLY place python output
//! crosses into the serving process, and it happens at load time.
//!
//! Design notes:
//! - Interchange is HLO **text** (jax >= 0.5 serialized protos use 64-bit
//!   instruction ids that xla_extension 0.5.1 rejects; the text parser
//!   reassigns ids — see /opt/xla-example/README.md).
//! - Model weights are uploaded ONCE as device buffers; per-call arguments
//!   (tokens, KV cache, cache_len) are marshalled per step via
//!   `buffer_from_host_buffer` and everything runs through `execute_b`.
//! - Executables for each (k, w) shape are compiled lazily on first use
//!   and cached for the life of the process.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::config::ModelArtifacts;
use crate::kvcache::SharedKvCache;
use crate::tokenizer::TokenId;

/// Output of one verification step.
#[derive(Debug)]
pub struct StepOutput {
    /// greedy next-token ids, row-major (k, w+1)
    pub next_ids: Vec<TokenId>,
    pub k: usize,
    pub w1: usize,
    /// KV tails, (layers, k, w1, heads, head_dim) flattened
    pub k_tail: Vec<f32>,
    pub v_tail: Vec<f32>,
    /// wall time of the device call (execute + output fetch)
    pub exec_time: Duration,
}

impl StepOutput {
    /// Model outputs for row r: out[i] = prediction after consuming block
    /// position i.
    pub fn row(&self, r: usize) -> &[TokenId] {
        &self.next_ids[r * self.w1..(r + 1) * self.w1]
    }
}

/// Output of a prefill call.
#[derive(Debug)]
pub struct PrefillOutput {
    pub next_id: TokenId,
    pub exec_time: Duration,
}

/// A loaded model: weights on device + lazily compiled executables.
pub struct ModelRuntime {
    client: PjRtClient,
    art: ModelArtifacts,
    params: Vec<PjRtBuffer>,
    steps: RefCell<HashMap<(usize, usize), PjRtLoadedExecutable>>,
    prefills: RefCell<HashMap<usize, PjRtLoadedExecutable>>,
    /// cumulative compile time (reported by the bench harnesses)
    pub compile_time: RefCell<Duration>,
}

// SAFETY: the PJRT CPU client is thread-safe for compilation and execution
// (PJRT C API contract); the RefCell caches are never shared across threads
// without external synchronization — the serving layer wraps ModelRuntime
// in a Mutex.
unsafe impl Send for ModelRuntime {}

impl ModelRuntime {
    pub fn load(art: &ModelArtifacts) -> Result<Self> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Self::load_with_client(client, art)
    }

    pub fn load_with_client(client: PjRtClient, art: &ModelArtifacts) -> Result<Self> {
        let params = upload_params(&client, art)?;
        Ok(ModelRuntime {
            client,
            art: art.clone(),
            params,
            steps: RefCell::new(HashMap::new()),
            prefills: RefCell::new(HashMap::new()),
            compile_time: RefCell::new(Duration::ZERO),
        })
    }

    pub fn artifacts(&self) -> &ModelArtifacts {
        &self.art
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    fn compile(&self, path: &Path) -> Result<PjRtLoadedExecutable> {
        let t = Instant::now();
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        *self.compile_time.borrow_mut() += t.elapsed();
        Ok(exe)
    }

    /// Ensure the (k, w) step executable is compiled (startup warming).
    pub fn warm_step(&self, k: usize, w: usize) -> Result<()> {
        let mut steps = self.steps.borrow_mut();
        if !steps.contains_key(&(k, w)) {
            let path = self
                .art
                .steps
                .get(&(k, w))
                .ok_or_else(|| anyhow!("no step artifact for (k={k}, w={w})"))?;
            let exe = self.compile(path)?;
            steps.insert((k, w), exe);
        }
        Ok(())
    }

    pub fn warm_prefill(&self, bucket: usize) -> Result<()> {
        let mut pf = self.prefills.borrow_mut();
        if !pf.contains_key(&bucket) {
            let path = self
                .art
                .prefills
                .get(&bucket)
                .ok_or_else(|| anyhow!("no prefill bucket {bucket}"))?;
            let exe = self.compile(path)?;
            pf.insert(bucket, exe);
        }
        Ok(())
    }

    /// Run prefill for `prompt`, filling `cache` and returning the first
    /// greedy next-token. The prompt must fit the largest prefill bucket.
    pub fn prefill(&self, prompt: &[TokenId], cache: &mut SharedKvCache) -> Result<PrefillOutput> {
        if prompt.is_empty() {
            return Err(anyhow!("empty prompt"));
        }
        let bucket = self
            .art
            .prefill_bucket(prompt.len())
            .ok_or_else(|| anyhow!("prompt of {} tokens exceeds prefill buckets", prompt.len()))?;
        self.warm_prefill(bucket)?;
        let pf = self.prefills.borrow();
        let exe = pf.get(&bucket).unwrap();

        let mut toks: Vec<i32> = prompt.iter().map(|&t| t as i32).collect();
        toks.resize(bucket, 0);
        let tok_buf = self
            .client
            .buffer_from_host_buffer(&toks, &[1, bucket], None)?;
        let len_buf = self
            .client
            .buffer_from_host_buffer(&[prompt.len() as i32], &[], None)?;

        let mut args: Vec<&PjRtBuffer> = self.params.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);

        let t = Instant::now();
        let result = exe.execute_b(&args)?;
        let lit = result[0][0].to_literal_sync()?;
        let exec_time = t.elapsed();

        let outs = tuple_elements(lit)?;
        if outs.len() != 3 {
            return Err(anyhow!("prefill returned {} outputs, want 3", outs.len()));
        }
        let next_id = outs[0].to_vec::<i32>()?[0] as TokenId;
        let kc = outs[1].to_vec::<f32>()?;
        let vc = outs[2].to_vec::<f32>()?;
        cache.install(kc, vc, prompt.len())?;
        Ok(PrefillOutput { next_id, exec_time })
    }

    /// One verification call on a (k, w+1) block. `tokens` is row-major
    /// (k, w+1): column 0 = last accepted token, columns 1.. = drafts.
    pub fn spec_step(
        &self,
        k: usize,
        w: usize,
        tokens: &[TokenId],
        cache: &SharedKvCache,
    ) -> Result<StepOutput> {
        let w1 = w + 1;
        if tokens.len() != k * w1 {
            return Err(anyhow!("tokens len {} != k*w1 {}", tokens.len(), k * w1));
        }
        if cache.len + w1 > cache.max_len {
            return Err(anyhow!(
                "cache too full for step: len {} + w1 {} > {}",
                cache.len,
                w1,
                cache.max_len
            ));
        }
        self.warm_step(k, w)?;
        let steps = self.steps.borrow();
        let exe = steps.get(&(k, w)).unwrap();

        let toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let d = &self.art.dims;
        let cache_dims = [d.n_layers, d.max_len, d.n_heads, d.head_dim];
        let tok_buf = self.client.buffer_from_host_buffer(&toks, &[k, w1], None)?;
        let kc_buf = self
            .client
            .buffer_from_host_buffer(&cache.k_data, &cache_dims, None)?;
        let vc_buf = self
            .client
            .buffer_from_host_buffer(&cache.v_data, &cache_dims, None)?;
        let len_buf = self
            .client
            .buffer_from_host_buffer(&[cache.len as i32], &[], None)?;

        let mut args: Vec<&PjRtBuffer> = self.params.iter().collect();
        args.push(&tok_buf);
        args.push(&kc_buf);
        args.push(&vc_buf);
        args.push(&len_buf);

        let t = Instant::now();
        let result = exe.execute_b(&args)?;
        let lit = result[0][0].to_literal_sync()?;
        let exec_time = t.elapsed();

        let outs = tuple_elements(lit)?;
        if outs.len() != 3 {
            return Err(anyhow!("step returned {} outputs, want 3", outs.len()));
        }
        let next_ids: Vec<TokenId> = outs[0]
            .to_vec::<i32>()?
            .into_iter()
            .map(|t| t as TokenId)
            .collect();
        let k_tail = outs[1].to_vec::<f32>()?;
        let v_tail = outs[2].to_vec::<f32>()?;
        Ok(StepOutput { next_ids, k, w1, k_tail, v_tail, exec_time })
    }

    /// Largest available (k', w') shape with k' <= k, w' <= w and w'+1 <=
    /// room (used when the cache is nearly full and the block must shrink).
    pub fn best_fitting_shape(&self, k: usize, w: usize, room: usize) -> Option<(usize, usize)> {
        self.art
            .steps
            .keys()
            .copied()
            .filter(|&(sk, sw)| sk <= k && sw <= w && sw + 1 <= room)
            .max_by_key(|&(sk, sw)| (sw, sk))
    }
}

fn upload_params(client: &PjRtClient, art: &ModelArtifacts) -> Result<Vec<PjRtBuffer>> {
    let bytes = std::fs::read(&art.params_bin)
        .with_context(|| format!("reading params {:?}", art.params_bin))?;
    let total: usize = art.param_spec.iter().map(|p| p.numel()).sum();
    if bytes.len() != total * 4 {
        return Err(anyhow!(
            "params.bin is {} bytes, manifest expects {}",
            bytes.len(),
            total * 4
        ));
    }
    let mut floats = vec![0f32; total];
    for (i, c) in bytes.chunks_exact(4).enumerate() {
        floats[i] = f32::from_le_bytes(c.try_into().unwrap());
    }
    let mut bufs = Vec::with_capacity(art.param_spec.len());
    let mut off = 0;
    for spec in &art.param_spec {
        let n = spec.numel();
        let buf = client
            .buffer_from_host_buffer(&floats[off..off + n], &spec.shape, None)
            .with_context(|| format!("uploading param {}", spec.name))?;
        bufs.push(buf);
        off += n;
    }
    Ok(bufs)
}

fn tuple_elements(lit: Literal) -> Result<Vec<Literal>> {
    Ok(lit.to_tuple()?)
}

#[cfg(test)]
mod tests {
    // ModelRuntime integration tests live in rust/tests/ (they need the
    // built artifacts); unit coverage here is limited to pure helpers.
    use super::*;

    #[test]
    fn step_output_row_indexing() {
        let out = StepOutput {
            next_ids: vec![1, 2, 3, 4, 5, 6],
            k: 2,
            w1: 3,
            k_tail: vec![],
            v_tail: vec![],
            exec_time: Duration::ZERO,
        };
        assert_eq!(out.row(0), &[1, 2, 3]);
        assert_eq!(out.row(1), &[4, 5, 6]);
    }
}
