//! Model runtime: loads a model's artifacts and executes prefill and
//! batched verification calls against it, behind a backend-neutral API.
//!
//! Two backends implement the same contract:
//!
//! - [`reference`] (default) — a deterministic pure-Rust model that derives
//!   its outputs from the KV cache contents, so every cache-management bug
//!   is observable. Runs anywhere, needs no artifacts beyond the synthetic
//!   tree (`testkit`), and is what CI exercises.
//! - [`pjrt`] (feature `pjrt`) — the real path: AOT HLO-text artifacts from
//!   the python build, compiled and executed on the CPU PJRT client
//!   (`xla` crate). Python never runs on the request path.
//!
//! New in the batched-engine refactor: [`ModelRuntime::spec_step_packed`]
//! verifies draft blocks from MANY sequences in one call — the paper's
//! batch dimension spent across requests as well as speculation rows. The
//! reference backend executes the packed call as a single unit; the PJRT
//! backend currently lowers it to per-sequence executions (per-sequence
//! caches live in separate device buffers) and is the documented gap.

pub mod reference;

#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::cell::RefCell;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::ModelArtifacts;
use crate::draft::DraftTree;
use crate::kvcache::{KvRead, KvWrite};
use crate::tokenizer::TokenId;

/// Output of one verification step (one sequence's block).
#[derive(Debug)]
pub struct StepOutput {
    /// greedy next-token ids, row-major (k, w+1)
    pub next_ids: Vec<TokenId>,
    /// rows in the verified block
    pub k: usize,
    /// block width (w + 1)
    pub w1: usize,
    /// KV tails, (layers, k, w1, heads, head_dim) flattened
    pub k_tail: Vec<f32>,
    /// value-cache tail, same shape as `k_tail`
    pub v_tail: Vec<f32>,
    /// wall time of the device call (execute + output fetch); for packed
    /// calls this is the whole packed call's latency — the time every
    /// participating sequence actually waited
    pub exec_time: Duration,
}

impl StepOutput {
    /// Model outputs for row r: out[i] = prediction after consuming block
    /// position i.
    pub fn row(&self, r: usize) -> &[TokenId] {
        &self.next_ids[r * self.w1..(r + 1) * self.w1]
    }
}

/// Output of a prefill call.
#[derive(Debug)]
pub struct PrefillOutput {
    /// first greedy token after the prompt
    pub next_id: TokenId,
    /// wall time of the prefill call
    pub exec_time: Duration,
}

/// One sequence's slice of a packed multi-sequence verification call:
/// `k` draft rows of `w+1` tokens (row-major) against that sequence's own
/// KV context. All blocks in one packed call share the same `w`.
///
/// The cache is behind the [`KvRead`] trait: a contiguous lane and a paged
/// page-table view are both valid sources — backends read positions
/// through `k_at`/`v_at` (or `as_contiguous`/`gather` for bulk transfer)
/// and never see the storage organization.
pub struct PackedBlock<'a> {
    /// draft rows in this block
    pub k: usize,
    /// row-major (k, w+1) token block
    pub tokens: &'a [TokenId],
    /// this sequence's own KV context
    pub cache: &'a dyn KvRead,
}

/// One sequence's slice of a packed TREE verification call: a speculation
/// trie whose per-node ancestor masks replace the row structure of
/// [`PackedBlock`]. The tree's source `(k, w)` shape names the artifact the
/// call warms; its node budget `k * (w + 1)` bounds the position count, so
/// a tree call never attends over more positions than the flat block it
/// replaces. Outputs come back as a [`StepOutput`] with `k = node count`
/// and `w1 = 1` — one prediction and one KV tail position per node.
pub struct PackedTreeBlock<'a> {
    /// the speculation trie (node 0 = anchor)
    pub tree: &'a DraftTree,
    /// this sequence's own KV context
    pub cache: &'a dyn KvRead,
}

enum Backend {
    Reference(reference::RefBackend),
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtBackend),
}

/// A loaded model: artifacts + execution backend.
pub struct ModelRuntime {
    art: ModelArtifacts,
    backend: Backend,
    /// cumulative artifact compile/validate time (reported by benches)
    pub compile_time: RefCell<Duration>,
}

// SAFETY (pjrt only): the PJRT CPU client is thread-safe for compilation
// and execution (PJRT C API contract); the RefCell caches are never shared
// across threads without external synchronization — the serving layer gives
// each worker its own ModelRuntime. The reference backend is Send already.
#[cfg(feature = "pjrt")]
unsafe impl Send for ModelRuntime {}

impl ModelRuntime {
    /// Load `art` and pick an execution backend for it.
    pub fn load(art: &ModelArtifacts) -> Result<Self> {
        let backend = pick_backend(art)?;
        Ok(ModelRuntime {
            art: art.clone(),
            backend,
            compile_time: RefCell::new(Duration::ZERO),
        })
    }

    /// The loaded artifact set.
    pub fn artifacts(&self) -> &ModelArtifacts {
        &self.art
    }

    /// Which execution backend this runtime is using.
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            Backend::Reference(_) => "reference",
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// Ensure the (k, w) step executable is compiled/validated.
    pub fn warm_step(&self, k: usize, w: usize) -> Result<()> {
        let path = self
            .art
            .steps
            .get(&(k, w))
            .ok_or_else(|| anyhow!("no step artifact for (k={k}, w={w})"))?;
        let t = Instant::now();
        let r = match &self.backend {
            Backend::Reference(b) => b.warm_step(path, k, w),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.warm_step(path, k, w),
        };
        *self.compile_time.borrow_mut() += t.elapsed();
        r
    }

    /// Ensure the prefill executable for `bucket` is compiled/validated.
    pub fn warm_prefill(&self, bucket: usize) -> Result<()> {
        let path = self
            .art
            .prefills
            .get(&bucket)
            .ok_or_else(|| anyhow!("no prefill bucket {bucket}"))?;
        let t = Instant::now();
        let r = match &self.backend {
            Backend::Reference(b) => b.warm_prefill(path, bucket),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.warm_prefill(path, bucket),
        };
        *self.compile_time.borrow_mut() += t.elapsed();
        r
    }

    /// Run prefill for `prompt`, filling `cache` and returning the first
    /// greedy next-token. The prompt must fit the largest prefill bucket.
    pub fn prefill(&self, prompt: &[TokenId], cache: &mut dyn KvWrite) -> Result<PrefillOutput> {
        if prompt.is_empty() {
            return Err(anyhow!("empty prompt"));
        }
        let bucket = self
            .art
            .prefill_bucket(prompt.len())
            .ok_or_else(|| anyhow!("prompt of {} tokens exceeds prefill buckets", prompt.len()))?;
        self.warm_prefill(bucket)?;
        match &self.backend {
            Backend::Reference(b) => b.prefill(&self.art, prompt, cache),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.prefill(&self.art, bucket, prompt, cache),
        }
    }

    /// One verification call on a (k, w+1) block. `tokens` is row-major
    /// (k, w+1): column 0 = last accepted token, columns 1.. = drafts.
    pub fn spec_step(
        &self,
        k: usize,
        w: usize,
        tokens: &[TokenId],
        cache: &dyn KvRead,
    ) -> Result<StepOutput> {
        validate_block(k, w, tokens.len(), cache)?;
        self.warm_step(k, w)?;
        match &self.backend {
            Backend::Reference(b) => b.spec_step(&self.art, k, w, tokens, cache),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.spec_step(&self.art, k, w, tokens, cache),
        }
    }

    /// One PACKED verification call over blocks from many sequences: the
    /// (sum of k_i, w+1) batch the batched engine builds per step. All
    /// blocks share `w`; each keeps its own KV lane. Returns one
    /// `StepOutput` per block, in order.
    pub fn spec_step_packed(&self, w: usize, blocks: &[PackedBlock]) -> Result<Vec<StepOutput>> {
        if blocks.is_empty() {
            return Ok(Vec::new());
        }
        for b in blocks {
            validate_block(b.k, w, b.tokens.len(), b.cache)?;
            self.warm_step(b.k, w)?;
        }
        match &self.backend {
            Backend::Reference(r) => r.spec_step_packed(&self.art, w, blocks),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => blocks
                .iter()
                .map(|b| p.spec_step(&self.art, b.k, w, b.tokens, b.cache))
                .collect(),
        }
    }

    /// One PACKED verification call over speculation TREES from many
    /// sequences. Each tree's nodes are verified in one call using its
    /// per-node ancestor masks; the returned [`StepOutput`]s carry one
    /// prediction + one KV tail position per node (`k = nodes, w1 = 1`).
    /// The reference backend consumes the masks natively; the PJRT backend
    /// lowers each tree to root-to-leaf linear paths over the tree's
    /// source `(k, w)` artifact and scatters the outputs back to nodes
    /// (shared-prefix nodes are recomputed per path — the documented gap,
    /// mirroring the per-sequence lowering of [`Self::spec_step_packed`]).
    pub fn spec_step_tree_packed(&self, blocks: &[PackedTreeBlock]) -> Result<Vec<StepOutput>> {
        if blocks.is_empty() {
            return Ok(Vec::new());
        }
        for b in blocks {
            let (k, w) = b.tree.shape();
            validate_tree_block(b.tree, b.cache)?;
            self.warm_step(k, w)?;
        }
        match &self.backend {
            Backend::Reference(r) => r.spec_step_tree_packed(&self.art, blocks),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => blocks.iter().map(|b| pjrt_tree_step(p, &self.art, b)).collect(),
        }
    }

    /// Largest available (k', w') shape with k' <= k, w' <= w and w'+1 <=
    /// room (used when the cache is nearly full and the block must shrink).
    pub fn best_fitting_shape(&self, k: usize, w: usize, room: usize) -> Option<(usize, usize)> {
        self.art
            .steps
            .keys()
            .copied()
            .filter(|&(sk, sw)| sk <= k && sw <= w && sw + 1 <= room)
            .max_by_key(|&(sk, sw)| (sw, sk))
    }

    /// The FEWEST-rows shape with w' <= w and w'+1 <= room (deepest such
    /// shape on a row tie). Fallback for the batched engine's row-budget
    /// refit on ragged artifact grids where no shape small enough for a
    /// sequence's allocation exists — it minimizes how far a step can
    /// overshoot the budget.
    pub fn smallest_row_shape(&self, w: usize, room: usize) -> Option<(usize, usize)> {
        self.art
            .steps
            .keys()
            .copied()
            .filter(|&(_, sw)| sw <= w && sw + 1 <= room)
            .min_by_key(|&(sk, sw)| (sk, std::cmp::Reverse(sw)))
    }
}

fn validate_block(k: usize, w: usize, tok_len: usize, cache: &dyn KvRead) -> Result<()> {
    let w1 = w + 1;
    if tok_len != k * w1 {
        return Err(anyhow!("tokens len {} != k*w1 {}", tok_len, k * w1));
    }
    if cache.ctx_len() + w1 > cache.max_ctx() {
        return Err(anyhow!(
            "cache too full for step: len {} + w1 {} > {}",
            cache.ctx_len(),
            w1,
            cache.max_ctx()
        ));
    }
    Ok(())
}

fn validate_tree_block(tree: &DraftTree, cache: &dyn KvRead) -> Result<()> {
    let (k, w) = tree.shape();
    let w1 = w + 1;
    if tree.is_empty() {
        return Err(anyhow!("tree block has no nodes (reset not called)"));
    }
    if tree.len() > k * w1 {
        return Err(anyhow!("tree of {} nodes exceeds its budget {}", tree.len(), k * w1));
    }
    // same room rule as the flat block of the source shape: the deepest
    // path is at most w1 positions, and the engine's shape fitting
    // already guarantees w1 <= remaining room
    if cache.ctx_len() + w1 > cache.max_ctx() {
        return Err(anyhow!(
            "cache too full for tree step: len {} + w1 {} > {}",
            cache.ctx_len(),
            w1,
            cache.max_ctx()
        ));
    }
    Ok(())
}

/// PJRT lowering of one tree block: chunk the tree's root-to-leaf paths
/// into (k, w+1) linear blocks of the source artifact shape, execute them
/// as flat `spec_step` calls, and scatter per-path outputs back onto
/// nodes. A node shared by several paths is recomputed identically each
/// time (its context is the same root-to-node prefix), so the scatter is
/// write-idempotent.
#[cfg(feature = "pjrt")]
fn pjrt_tree_step(
    p: &pjrt::PjrtBackend,
    art: &ModelArtifacts,
    b: &PackedTreeBlock,
) -> Result<StepOutput> {
    let tree = b.tree;
    let (k, w) = tree.shape();
    let w1 = w + 1;
    let n = tree.len();
    let parents = tree.parents();
    // enumerate root-to-leaf node chains
    let mut paths: Vec<Vec<usize>> = Vec::new();
    for leaf in 0..n {
        if (leaf + 1..n).any(|j| parents[j] as usize == leaf) {
            continue; // not a leaf
        }
        let mut path = vec![leaf];
        let mut cur = leaf;
        while parents[cur] != crate::draft::tree::NO_PARENT {
            cur = parents[cur] as usize;
            path.push(cur);
        }
        path.reverse();
        paths.push(path);
    }
    let d = &art.dims;
    let ps = d.n_heads * d.head_dim;
    let mut next_ids = vec![0 as TokenId; n];
    let mut k_tail = vec![0.0f32; d.n_layers * n * ps];
    let mut v_tail = vec![0.0f32; d.n_layers * n * ps];
    let mut exec_time = Duration::ZERO;
    for chunk in paths.chunks(k) {
        let mut tokens = Vec::with_capacity(k * w1);
        for r in 0..k {
            // missing rows in the last chunk repeat the first path
            let path = chunk.get(r).unwrap_or(&chunk[0]);
            for i in 0..w1 {
                let node = path.get(i).copied().unwrap_or(*path.last().unwrap());
                tokens.push(tree.token(node));
            }
        }
        let out = p.spec_step(art, k, w, &tokens, b.cache)?;
        exec_time += out.exec_time;
        for (r, path) in chunk.iter().enumerate() {
            for (i, &node) in path.iter().enumerate() {
                next_ids[node] = out.next_ids[r * w1 + i];
                for layer in 0..d.n_layers {
                    let src = ((layer * k + r) * w1 + i) * ps;
                    let dst = (layer * n + node) * ps;
                    k_tail[dst..dst + ps].copy_from_slice(&out.k_tail[src..src + ps]);
                    v_tail[dst..dst + ps].copy_from_slice(&out.v_tail[src..src + ps]);
                }
            }
        }
    }
    Ok(StepOutput { next_ids, k: n, w1: 1, k_tail, v_tail, exec_time })
}

#[cfg(not(feature = "pjrt"))]
fn pick_backend(art: &ModelArtifacts) -> Result<Backend> {
    Ok(Backend::Reference(reference::RefBackend::load(art)?))
}

/// With the pjrt feature on, artifacts pick their backend by content: the
/// synthetic testkit tree carries REFSTEP headers, real AOT builds carry
/// HLO text.
#[cfg(feature = "pjrt")]
fn pick_backend(art: &ModelArtifacts) -> Result<Backend> {
    let looks_reference = art
        .steps
        .values()
        .next()
        .and_then(|p| std::fs::read_to_string(p).ok())
        .map(|t| t.starts_with(reference::STEP_MAGIC))
        .unwrap_or(false);
    if looks_reference {
        Ok(Backend::Reference(reference::RefBackend::load(art)?))
    } else {
        Ok(Backend::Pjrt(pjrt::PjrtBackend::load(art)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::SharedKvCache;

    #[test]
    fn step_output_row_indexing() {
        let out = StepOutput {
            next_ids: vec![1, 2, 3, 4, 5, 6],
            k: 2,
            w1: 3,
            k_tail: vec![],
            v_tail: vec![],
            exec_time: Duration::ZERO,
        };
        assert_eq!(out.row(0), &[1, 2, 3]);
        assert_eq!(out.row(1), &[4, 5, 6]);
    }

    #[test]
    fn validate_block_checks_shape_and_room() {
        let cache = SharedKvCache::new(1, 8, 1, 2);
        assert!(validate_block(2, 1, 4, &cache).is_ok());
        assert!(validate_block(2, 1, 5, &cache).is_err()); // len mismatch
        let mut full = SharedKvCache::new(1, 8, 1, 2);
        full.len = 7;
        assert!(validate_block(1, 1, 2, &full).is_err()); // no room for w1=2
        assert!(validate_block(1, 0, 1, &full).is_ok());
    }
}
