//! ngrammys — CLI for the N-Grammys serving stack.
//!
//! Subcommands:
//!   serve      start the HTTP serving front-end
//!   generate   one-shot generation from a prompt
//!   bench      reproduce the paper's tables/figures
//!   trace      flight-recorder tooling: replay captured JSONL or run a
//!              live traced workload (with a `--smoke` overhead gate)
//!   info       print manifest / artifact summary

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use ngrammys::bench::{self, BenchCtx};
use ngrammys::config::{
    default_artifacts_dir, Dispatch, EngineConfig, FrontEnd, Manifest, ServeConfig,
    SessionCacheConfig, SharedDraft,
};
use ngrammys::scheduler::{Scheduler, StrategyName};
use ngrammys::server::Server;
use ngrammys::tokenizer::BpeTokenizer;
use ngrammys::util::cli::Args;

const USAGE: &str = "\
ngrammys — learning-free batched speculative decoding (N-Grammys)

USAGE:
  ngrammys <command> [--artifacts DIR] [options]

COMMANDS:
  info                        artifact & model summary
  generate --prompt TEXT      one-shot generation
      [--model base] [--k 10] [--w 10] [--q 1] [--strategy mixed]
      [--max-tokens 64] [--compare] [--tree]
      strategy 'adaptive' = online (k, w) + strategy selection (k/w as caps)
      --tree verifies drafts as a shared-prefix trie (one masked call per
      step, extra candidate rows in the freed node budget); output bytes
      are identical to flat-row mode
  serve                       HTTP server (POST /generate, GET /metrics)
      [--model base] [--addr 127.0.0.1:8077] [--workers 1]
      [--front-end reactor|threaded]
                              connection front-end: 'reactor' (default,
                              Linux) = one epoll event-loop thread with
                              non-blocking accept/read/write state
                              machines and async scheduler dispatch;
                              'threaded' = one blocking thread per
                              connection (the non-Linux fallback).
                              Responses are byte-identical either way
      [--dispatch steal|central]
                              batched-mode dispatch (batch >= 2):
                              'steal' (default) = per-engine scored work
                              queues with idle-engine stealing;
                              'central' = one dispatcher thread owns the
                              scored queue (the only mode that
                              autoscales the ENGINE count)
      [--conn-cap 1024]       max connections the reactor holds open at
                              once; accepts past the cap are answered
                              with a 503 JSON error and closed
      [--batch N]             continuous batching (N >= 2). Elastic by
                              default: N is the PER-ENGINE CAP of a
                              demand-driven lane range, the per-step row
                              budget is derived from the cost model, and
                              admissions are ordered by expected
                              tokens-per-cost with per-strategy priors
      [--engines E]           engine-pool cap (default 1): up to E
                              batched engine threads, each with its own
                              runtime + KV pool, behind one queue; whole
                              engines spawn/retire on sustained
                              pressure/quiet (elastic) or run pinned at E
                              (--no-elastic); requests are routed
                              depth-aware (greedy vs speculative)
      [--budget B]            packed-row budget CAP over the derived
                              value (0 = derived value used as-is; with
                              --no-elastic: the fixed budget, 0 = off)
      [--no-elastic]          pin --batch lanes x --engines E + static
                              --budget (the pre-elastic fixed behavior)
      [--min-lanes 1]         lower bound of the elastic lane range
      [--scale-down-after 8]  idle decisions before shedding one lane
      [--budget-slack 1.15]   slowdown tolerance of the derived budget
      [--strategy mixed]      default strategy for requests that name none
      [--cache-per-query 8] [--cache-chain 12] [--cache-cap 100000]
                              session n-gram cache bounds
      [--kv-page-size 0]      0 = contiguous KV lanes; N > 0 = paged KV
                              pool (N positions per page) with refcounted
                              copy-on-write prefix sharing — admission is
                              charged in distinct pages, so shared-prefix
                              requests pack more lanes into the same KV
                              bytes (output streams are byte-identical)
      [--kv-pages 0]          paged-pool page budget (0 = derive the
                              lane-equivalent budget from --batch)
      [--tree]                tree speculation in every batched engine
                              (trie-packed drafts, masked verification;
                              byte-identical output streams)
      [--shared-draft off|fleet]
                              'fleet' = all pool engines share one
                              sharded, seqlock-snapshotted n-gram chain
                              store: accepted tokens publish fleet-wide,
                              propose paths fill spare rows from shared
                              chains, and adaptive requests seed from
                              prompt-fingerprint (task-class) priors.
                              Output streams are byte-identical to 'off'
      [--shared-draft-shards 8]
                              shard count for the fleet store (writer
                              serialization granularity; reads are
                              lock-free at any count)
  bench <target>              reproduce a paper table/figure:
      fig1                    phase-transition heatmaps (cost model)
      fig2                    tokens/call vs top-k  [--model base]
      table1                  the headline table    [--models small,base,large]
      grid                    figs 3/5/6/7/8/9      [--model base]
      fig4                    s5.2 ablations        [--model base]
      qsweep                  footnote-4 q sweep    [--model base]
      ablation-alloc          allocation-policy ablation
      ablation-hardware       OTB-threshold sensitivity (footnote 5)
      batched                 cross-request batching throughput
                              [--model base] [--conc 1,2,4,8]
      adaptive                adaptive controller vs static strategies
                              [--model base] [--budget B] [--smoke]
      elastic                 elastic autoscaling vs every static --batch
                              [--model base] [--caps 2,4,8] [--smoke]
      pool                    1-engine vs N-engine pool throughput on a
                              mixed greedy+speculative burst workload,
                              plus a cross-engine shared-draft section
                              (fails unless the fleet store strictly
                              beats private caches on same-task traffic
                              split across 2 engines, at byte-identical
                              outputs) [--model base] [--engines 4]
                              [--smoke]
      draft                   draft hot path: incremental suffix index
                              vs the seed rescan (fails unless the
                              incremental path keeps a >=2x edge at
                              context >= 256) [--smoke]
      prefix                  paged KV prefix sharing: admitted lanes per
                              fixed KV budget, paged vs lane pool, on a
                              shared-system-prompt workload (fails unless
                              paged admits strictly more; also re-checks
                              byte-identity) [--model base] [--smoke]
      tree                    tree vs flat-row speculation at the same row
                              budget on a high-repetition workload (fails
                              unless tree accepts strictly more tokens per
                              verify call; also re-checks tree/linear/
                              greedy byte-identity) [--model base] [--smoke]
      serve                   serving front-end shootout over real
                              sockets: {reactor,threaded} x {steal,
                              central} at concurrency 1/4/8 — fails
                              unless all four combos return
                              byte-identical responses and the reactor
                              holds p50/p99 TTFT + inter-token latency
                              within tolerance of the threaded baseline
                              [--model base] [--smoke]
      all                     everything above
      common: [--prompts N] [--max-new N] [--ks 1,5,10] [--ws 2,6,10]
  trace                       flight-recorder tooling:
      --input FILE.jsonl      replay a captured trace (saved from
                              GET /trace or a previous live run):
                              per-phase + per-strategy breakdown table
      [--chrome OUT.json]     also export Chrome tracing format
                              (chrome://tracing / Perfetto)
      (without --input)       decode a live workload through one traced
                              batched engine, summarize, and write the
                              JSONL under bench_out/
      [--model base] [--prompts N] [--max-new N]
      [--smoke]               CI overhead gate: run the workload traced
                              AND untraced; fail unless outputs are
                              byte-identical and the packed call schedule
                              (cost-model throughput) is unchanged
  ci-bench-check              bench-regression gate: compare the
                              bench_out/BENCH_*.json summaries emitted by
                              the smoke benches against a committed
                              baseline; fails on >tolerance throughput
                              regression
      [--baseline benches/baseline.json] [--bench-dir bench_out]
      [--tolerance 0.10] [--update]  (--update rewrites the baseline
                              with the observed values)
      [--strict-baseline]     also fail when any non-wall-clock baseline
                              entry is still null (i.e. a bench gate has
                              never seeded its baseline value)
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env(&[
        "compare",
        "help",
        "traces",
        "smoke",
        "no-elastic",
        "update",
        "tree",
        "strict-baseline",
    ])
    .map_err(|e| anyhow!(e))?;
    if args.has_flag("help") || args.positional.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let artifacts = PathBuf::from(
        args.get_or("artifacts", default_artifacts_dir().to_str().unwrap()));

    match args.positional[0].as_str() {
        "info" => info(&artifacts),
        "generate" => generate(&artifacts, &args),
        "serve" => serve(&artifacts, &args),
        "bench" => bench_cmd(&artifacts, &args),
        "trace" => trace_cmd(&artifacts, &args),
        "ci-bench-check" => check_cmd(&args),
        other => Err(anyhow!("unknown command '{other}'\n{USAGE}")),
    }
}

fn info(artifacts: &PathBuf) -> Result<()> {
    let m = Manifest::load(artifacts)?;
    println!("artifacts: {:?}", m.root);
    println!("vocab: {}", m.vocab_size);
    println!("tasks: {:?}", m.data.keys().collect::<Vec<_>>());
    let mut names: Vec<_> = m.models.keys().collect();
    names.sort();
    for name in names {
        let a = &m.models[name];
        let mut buckets: Vec<_> = a.prefills.keys().collect();
        buckets.sort();
        println!(
            "model '{}' (~{}): {} params, d={}, layers={}, heads={}, \
             {} step shapes, prefill {:?}, train loss {:.3}",
            name,
            a.dims.analog,
            a.dims.n_params,
            a.dims.d_model,
            a.dims.n_layers,
            a.dims.n_heads,
            a.steps.len(),
            buckets,
            a.train_final_loss,
        );
    }
    Ok(())
}

fn generate(artifacts: &PathBuf, args: &Args) -> Result<()> {
    let manifest = Manifest::load(artifacts)?;
    let model = args.get_or("model", "base");
    let prompt_text = args
        .get("prompt")
        .ok_or_else(|| anyhow!("--prompt required"))?;
    let engine = EngineConfig {
        k: args.get_usize("k", 10).map_err(|e| anyhow!(e))?,
        w: args.get_usize("w", 10).map_err(|e| anyhow!(e))?,
        q: args.get_usize("q", 1).map_err(|e| anyhow!(e))?,
        max_new_tokens: args.get_usize("max-tokens", 64).map_err(|e| anyhow!(e))?,
    };
    let strategy = StrategyName::parse(args.get_or("strategy", "mixed"))?;

    let ctx = BenchCtx::load(manifest, model)?;
    let prompt = ctx.tokenizer.encode(prompt_text);
    let run = |strat: StrategyName, eng: EngineConfig, tree: bool| -> Result<_> {
        let s = ngrammys::scheduler::make_strategy(strat, &ctx.tables, eng.q);
        let mut dec = ngrammys::engine::SpecDecoder::new(&ctx.runtime, s, eng);
        dec.tree = tree;
        if strat == StrategyName::Adaptive {
            dec.controller = Some(ngrammys::adaptive::controller_for(
                &ctx.tables,
                dec.cfg.q,
                &SessionCacheConfig::default(),
                &ctx.runtime.artifacts().dims.analog,
            ));
        }
        let t = std::time::Instant::now();
        let r = dec.generate(&prompt)?;
        Ok((r, t.elapsed()))
    };

    let (r, dt) = run(strategy, engine.clone(), args.has_flag("tree"))?;
    println!("{}", ctx.tokenizer.decode(&r.tokens));
    eprintln!(
        "\n[{} tokens, {} calls, {:.2} tok/call, {:.0} ms total ({:.1} tok/s)]",
        r.tokens.len(),
        r.calls,
        r.tokens_per_call(),
        dt.as_secs_f64() * 1e3,
        r.tokens.len() as f64 / r.decode_time.as_secs_f64().max(1e-9),
    );
    if args.has_flag("compare") {
        let (g, gdt) = run(StrategyName::None, ngrammys::engine::greedy_config(
            engine.max_new_tokens), false)?;
        assert_eq!(g.tokens, r.tokens,
                   "INVARIANT VIOLATION: speculative != greedy stream");
        eprintln!(
            "[greedy: {} calls, {:.0} ms — identical output verified; cpu speedup {:.2}x]",
            g.calls,
            gdt.as_secs_f64() * 1e3,
            g.decode_time.as_secs_f64() / r.decode_time.as_secs_f64(),
        );
    }
    Ok(())
}

fn serve(artifacts: &PathBuf, args: &Args) -> Result<()> {
    let manifest = Manifest::load(artifacts)?;
    let model = args.get_or("model", "base");
    let default_strategy = StrategyName::parse(args.get_or("strategy", "mixed"))?;
    let cache_defaults = SessionCacheConfig::default();
    let defaults = ServeConfig::default();
    let cfg = ServeConfig {
        addr: args.get_or("addr", "127.0.0.1:8077").to_string(),
        front_end: FrontEnd::parse(args.get_or("front-end", defaults.front_end.label()))?,
        dispatch: Dispatch::parse(args.get_or("dispatch", defaults.dispatch.label()))?,
        conn_cap: args.get_usize("conn-cap", defaults.conn_cap).map_err(|e| anyhow!(e))?,
        workers: args.get_usize("workers", 1).map_err(|e| anyhow!(e))?,
        queue_cap: args.get_usize("queue-cap", 256).map_err(|e| anyhow!(e))?,
        batch: args.get_usize("batch", 0).map_err(|e| anyhow!(e))?,
        engines: args.get_usize("engines", 1).map_err(|e| anyhow!(e))?,
        // max_engines is overridden by `engines` at scheduler start
        engine_scale: defaults.engine_scale.clone(),
        budget: parse_budget(args)?,
        elastic: !args.has_flag("no-elastic"),
        autoscale: ngrammys::scheduler::AutoscaleConfig {
            min_lanes: args
                .get_usize("min-lanes", defaults.autoscale.min_lanes)
                .map_err(|e| anyhow!(e))?,
            // overridden by `batch` at scheduler start
            max_lanes: defaults.autoscale.max_lanes,
            down_after_steps: args
                .get_usize("scale-down-after", defaults.autoscale.down_after_steps as usize)
                .map_err(|e| anyhow!(e))? as u32,
        },
        budget_slack: args
            .get_f64("budget-slack", defaults.budget_slack)
            .map_err(|e| anyhow!(e))?,
        default_strategy,
        session_cache: SessionCacheConfig {
            per_query: args
                .get_usize("cache-per-query", cache_defaults.per_query)
                .map_err(|e| anyhow!(e))?,
            max_chain: args
                .get_usize("cache-chain", cache_defaults.max_chain)
                .map_err(|e| anyhow!(e))?,
            cap: args.get_usize("cache-cap", cache_defaults.cap).map_err(|e| anyhow!(e))?,
        },
        default_engine: EngineConfig {
            k: args.get_usize("k", 10).map_err(|e| anyhow!(e))?,
            w: args.get_usize("w", 10).map_err(|e| anyhow!(e))?,
            q: args.get_usize("q", 1).map_err(|e| anyhow!(e))?,
            max_new_tokens: args.get_usize("max-tokens", 64).map_err(|e| anyhow!(e))?,
        },
        kv_page_size: args.get_usize("kv-page-size", 0).map_err(|e| anyhow!(e))?,
        kv_pages: args.get_usize("kv-pages", 0).map_err(|e| anyhow!(e))?,
        tree: args.has_flag("tree"),
        shared_draft: SharedDraft::parse(
            args.get_or("shared-draft", defaults.shared_draft.label()))?,
        shared_draft_shards: args
            .get_usize("shared-draft-shards", defaults.shared_draft_shards)
            .map_err(|e| anyhow!(e))?,
    };
    let scheduler = Arc::new(Scheduler::start(&manifest, model, &cfg)?);
    let tokenizer = Arc::new(BpeTokenizer::load(&manifest.tokenizer_path)?);
    Server { scheduler, tokenizer, cfg }.run()
}

/// `--budget B` with 0 (the default) meaning "no row budget".
fn parse_budget(args: &Args) -> Result<Option<usize>> {
    Ok(match args.get_usize("budget", 0).map_err(|e| anyhow!(e))? {
        0 => None,
        b => Some(b),
    })
}

/// `ngrammys trace`: replay a captured JSONL trace (`--input`), or run a
/// live traced workload through one batched engine — `--smoke` makes the
/// live run the CI trace-overhead gate (byte-identity + unchanged packed
/// schedule between traced and untraced passes).
fn trace_cmd(artifacts: &PathBuf, args: &Args) -> Result<()> {
    let chrome = args.get("chrome").map(PathBuf::from);
    if let Some(input) = args.get("input") {
        return bench::tracecmd::replay(std::path::Path::new(input), chrome.as_deref());
    }
    let manifest = Manifest::load(artifacts)?;
    let model = args.get_or("model", "base");
    let n_prompts = args.get_usize("prompts", 6).map_err(|e| anyhow!(e))?;
    let max_new = args.get_usize("max-new", 32).map_err(|e| anyhow!(e))?;
    let ctx = BenchCtx::load(manifest, model)?;
    bench::tracecmd::live(&ctx, n_prompts, max_new, args.has_flag("smoke"), chrome.as_deref())
}

/// The CI bench-regression gate (`ngrammys ci-bench-check`): compares
/// the smoke benches' `BENCH_*.json` output against the committed
/// baseline and fails on a >tolerance cost-model throughput regression.
fn check_cmd(args: &Args) -> Result<()> {
    let baseline = PathBuf::from(args.get_or("baseline", "benches/baseline.json"));
    let dir = PathBuf::from(args.get_or("bench-dir", "bench_out"));
    let tolerance = args
        .get_f64("tolerance", ngrammys::bench::check::DEFAULT_TOLERANCE)
        .map_err(|e| anyhow!(e))?;
    ngrammys::bench::check::run(
        &baseline,
        &dir,
        tolerance,
        args.has_flag("update"),
        args.has_flag("strict-baseline"),
    )
}

fn bench_cmd(artifacts: &PathBuf, args: &Args) -> Result<()> {
    let target = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow!("bench target required\n{USAGE}"))?;
    let manifest = Manifest::load(artifacts)?;
    let n_prompts = args.get_usize("prompts", 10).map_err(|e| anyhow!(e))?;
    let max_new = args.get_usize("max-new", 48).map_err(|e| anyhow!(e))?;
    let model = args.get_or("model", "base");
    let ks = args
        .get_usize_list("ks", &bench::grid::GRID_KS)
        .map_err(|e| anyhow!(e))?;
    let ws = args
        .get_usize_list("ws", &bench::grid::GRID_WS)
        .map_err(|e| anyhow!(e))?;

    let load = || BenchCtx::load(manifest.clone(), model);
    match target {
        "fig1" => bench::fig1::run(Some(&load()?)),
        "fig2" => bench::fig2::run(&load()?, n_prompts, max_new),
        "fig4" => bench::fig4::run(&load()?, n_prompts, max_new),
        "grid" => bench::grid::run(&load()?, n_prompts, max_new, &ks, &ws).map(|_| ()),
        "qsweep" => bench::qsweep::run_qsweep(&load()?, n_prompts, max_new),
        "ablation-alloc" => bench::qsweep::run_alloc_ablation(&load()?, n_prompts, max_new),
        "ablation-hardware" => bench::qsweep::run_hardware_ablation(&load()?, n_prompts, max_new),
        "batched" => {
            let conc = args
                .get_usize_list("conc", &bench::batched::CONCURRENCIES)
                .map_err(|e| anyhow!(e))?;
            bench::batched::run(&load()?, n_prompts, max_new, &conc)
        }
        "adaptive" => {
            let budget = parse_budget(args)?;
            bench::adaptive::run(&load()?, n_prompts, max_new, budget, args.has_flag("smoke"))
        }
        "elastic" => {
            let caps = args
                .get_usize_list("caps", &bench::elastic::STATIC_CAPS)
                .map_err(|e| anyhow!(e))?;
            bench::elastic::run(&load()?, n_prompts, max_new, &caps, args.has_flag("smoke"))
        }
        "pool" => {
            let engines = args
                .get_usize("engines", bench::pool::ENGINE_CAP)
                .map_err(|e| anyhow!(e))?;
            bench::pool::run(&load()?, n_prompts, max_new, engines, args.has_flag("smoke"))
        }
        // draft needs no model artifacts: it measures the drafting layer
        // itself on synthetic sequences/tables
        "draft" => bench::draft::run(args.has_flag("smoke")),
        "prefix" => bench::prefix::run(&load()?, args.has_flag("smoke")),
        "tree" => bench::tree::run(&load()?, args.has_flag("smoke")),
        // serve spins up its own schedulers (one per front-end/dispatch
        // combo), so it takes the manifest directly instead of a BenchCtx
        "serve" => bench::serve::run(&manifest, model, args.has_flag("smoke")),
        "table1" => {
            let models: Vec<String> = args
                .get_or("models", "small,base,large")
                .split(',')
                .map(|s| s.to_string())
                .collect();
            let mrefs: Vec<&str> = models.iter().map(|s| s.as_str()).collect();
            bench::table1::run(&manifest, &mrefs, n_prompts, max_new, &ks, &ws)
        }
        "all" => {
            let ctx = load()?;
            bench::draft::run(false)?;
            bench::fig1::run(Some(&ctx))?;
            bench::fig2::run(&ctx, n_prompts, max_new)?;
            bench::fig4::run(&ctx, n_prompts, max_new)?;
            bench::qsweep::run_qsweep(&ctx, n_prompts, max_new)?;
            bench::qsweep::run_alloc_ablation(&ctx, n_prompts, max_new)?;
            bench::qsweep::run_hardware_ablation(&ctx, n_prompts, max_new)?;
            bench::batched::run(&ctx, n_prompts, max_new, &bench::batched::CONCURRENCIES)?;
            bench::adaptive::run(&ctx, n_prompts, max_new, None, false)?;
            bench::elastic::run(&ctx, n_prompts, max_new, &bench::elastic::STATIC_CAPS, false)?;
            bench::pool::run(&ctx, n_prompts, max_new, bench::pool::ENGINE_CAP, false)?;
            bench::prefix::run(&ctx, false)?;
            bench::tree::run(&ctx, false)?;
            drop(ctx);
            bench::serve::run(&manifest, model, false)?;
            for m in ["small", "base", "large"] {
                let c = BenchCtx::load(manifest.clone(), m)?;
                bench::grid::run(&c, n_prompts, max_new, &ks, &ws)?;
            }
            bench::table1::run(&manifest, &["small", "base", "large"],
                               n_prompts, max_new, &ks, &ws)
        }
        other => Err(anyhow!("unknown bench target '{other}'\n{USAGE}")),
    }
}
