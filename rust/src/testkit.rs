//! Synthetic artifact tree for the reference backend.
//!
//! The real artifact tree is produced by `make artifacts` (python + JAX +
//! Pallas, AOT-lowered to HLO text). That toolchain is not available in a
//! bare build environment, and the seed repo shipped with NO way to build
//! or test without it. This module restores a zero-dependency path: it
//! materializes a complete, deterministic artifact tree — manifest,
//! tokenizer (BPE actually trained on the synthetic corpora), corpora,
//! N-gram tables, params.bin and step/prefill artifacts — that the
//! [`crate::runtime::reference`] backend executes.
//!
//! Fidelity notes:
//! - The N-gram tables are built from the SAME `bigram_next` attractor the
//!   reference model follows ~3/4 of the time, so draft acceptance is
//!   realistic (tokens/call well above 1), not degenerate.
//! - The tokenizer is a real byte-BPE trained here with the same greedy
//!   most-frequent-pair rule the python side uses, so the parity tests
//!   exercise the actual merge machinery.
//! - Layout matches the python build exactly (models/<name>/..., data/...),
//!   so failure-injection tests can corrupt copies of it.
//!
//! The tree is built once per machine under `$TMPDIR/ngrammys-synth-v<N>`
//! (build into a staging dir, atomic rename), and
//! [`crate::config::default_artifacts_dir`] falls back to it when no real
//! artifact tree is present — which is what lets `cargo test` run green on
//! a machine that has never seen the python toolchain.

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use anyhow::{Context, Result};

use crate::draft::tables;
use crate::runtime::reference;
use crate::tokenizer::{split_pieces, BpeTokenizer};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Bump when the synthetic format changes so stale trees are not reused.
const FORMAT_VERSION: u32 = 1;
const N_MERGES: usize = 200;
const TABLE_TOPK: usize = 32;
const UNIGRAM_TOPK: usize = 32;
const EXT_BIGRAM_W: usize = 8;
const STEP_KS: [usize; 6] = [1, 2, 5, 10, 20, 25];
const STEP_WS: [usize; 9] = [0, 1, 2, 4, 6, 8, 10, 12, 14];
const PREFILL_BUCKETS: [usize; 4] = [32, 64, 128, 256];

struct ModelSpec {
    name: &'static str,
    analog: &'static str,
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    mlp_hidden: usize,
    max_len: usize,
    params_seed: u64,
    train_final_loss: f64,
}

const MODELS: [ModelSpec; 3] = [
    ModelSpec {
        name: "small",
        analog: "phi3",
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        head_dim: 8,
        mlp_hidden: 64,
        max_len: 288,
        params_seed: 0xA11CE,
        train_final_loss: 1.61,
    },
    ModelSpec {
        name: "base",
        analog: "mistral",
        d_model: 48,
        n_layers: 3,
        n_heads: 3,
        head_dim: 8,
        mlp_hidden: 96,
        max_len: 320,
        params_seed: 0xB0B,
        train_final_loss: 1.42,
    },
    ModelSpec {
        name: "large",
        analog: "vicuna",
        d_model: 64,
        n_layers: 4,
        n_heads: 4,
        head_dim: 8,
        mlp_hidden: 128,
        max_len: 352,
        params_seed: 0xCAFE,
        train_final_loss: 1.27,
    },
];

/// Path of the shared synthetic artifact tree, building it on first use.
pub fn artifacts_dir() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let root = std::env::temp_dir().join(format!("ngrammys-synth-v{FORMAT_VERSION}"));
        if root.join("manifest.json").exists() {
            return root;
        }
        let staging = std::env::temp_dir().join(format!(
            "ngrammys-synth-v{FORMAT_VERSION}-build-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&staging);
        build_tree(&staging).expect("building synthetic artifacts");
        if fs::rename(&staging, &root).is_err() {
            // a concurrent builder won the rename, or rename is unsupported:
            // fall back to building in place if the tree is still missing
            let _ = fs::remove_dir_all(&staging);
            if !root.join("manifest.json").exists() {
                build_tree(&root).expect("building synthetic artifacts in place");
            }
        }
        root
    })
    .clone()
}

/// Convenience for tests: the parsed manifest of the synthetic tree.
pub fn manifest() -> crate::config::Manifest {
    crate::config::Manifest::load(&artifacts_dir()).expect("loading synthetic manifest")
}

/// Build the whole tree under `root` (which must not yet exist).
pub fn build_tree(root: &Path) -> Result<()> {
    fs::create_dir_all(root.join("data"))?;

    // --- corpora
    let corpora: Vec<(&str, String, String)> = vec![
        ("chat", gen_chat(80), gen_chat_seeded(30, 0x17)),
        ("code", gen_code(80), gen_code_seeded(30, 0x23)),
        ("math", gen_math(80), gen_math_seeded(30, 0x31)),
    ];
    let mut all_text = Vec::new();
    for (task, train, eval) in &corpora {
        fs::write(root.join("data").join(format!("{task}_train.txt")), train)?;
        fs::write(root.join("data").join(format!("{task}_eval.txt")), eval)?;
        all_text.push(train.clone());
        all_text.push(eval.clone());
    }

    // --- tokenizer: real BPE trained on the corpora
    let merges = train_bpe(&all_text, N_MERGES);
    let vocab = 256 + merges.len();
    write_tokenizer(&root.join("tokenizer.json"), &merges)?;
    let tok = BpeTokenizer::from_merges(merges.clone());
    write_fixtures(&root.join("tokenizer_fixtures.json"), &tok, &corpora)?;

    // --- models
    let mut model_jsons = Vec::new();
    for spec in &MODELS {
        let dir = root.join("models").join(spec.name);
        fs::create_dir_all(&dir)?;
        let j = build_model(&dir, spec, vocab)?;
        model_jsons.push((spec.name, j));
    }

    // --- manifest
    let data_json = Json::Obj(
        corpora
            .iter()
            .map(|(task, _, _)| {
                (
                    task.to_string(),
                    Json::obj(vec![
                        ("train", Json::Str(format!("data/{task}_train.txt"))),
                        ("eval", Json::Str(format!("data/{task}_eval.txt"))),
                    ]),
                )
            })
            .collect(),
    );
    let manifest = Json::obj(vec![
        ("version", Json::Num(FORMAT_VERSION as f64)),
        ("builder", Json::Str("rust-testkit-synthetic".into())),
        ("vocab_size", Json::Num(vocab as f64)),
        ("tokenizer", Json::Str("tokenizer.json".into())),
        ("data", data_json),
        (
            "table_topk",
            Json::obj(vec![
                ("bigram", Json::Num(TABLE_TOPK as f64)),
                ("unigram", Json::Num(UNIGRAM_TOPK as f64)),
                ("ext_bigram_w", Json::Num(EXT_BIGRAM_W as f64)),
            ]),
        ),
        (
            "models",
            Json::Obj(
                model_jsons
                    .into_iter()
                    .map(|(n, j)| (n.to_string(), j))
                    .collect(),
            ),
        ),
    ]);
    fs::write(root.join("manifest.json"), manifest.to_string_pretty())
        .context("writing manifest.json")?;
    Ok(())
}

fn build_model(dir: &Path, spec: &ModelSpec, vocab: usize) -> Result<Json> {
    // params.bin: deterministic pseudo-random bytes; the reference model's
    // seed is derived from these bytes, so each model behaves differently.
    let param_spec: Vec<(&str, Vec<usize>)> = vec![
        ("embedding", vec![vocab, spec.d_model]),
        ("blocks", vec![spec.n_layers, spec.d_model, 4]),
        ("lm_head", vec![spec.d_model, vocab]),
    ];
    let total: usize = param_spec
        .iter()
        .map(|(_, s)| s.iter().product::<usize>())
        .sum();
    let mut rng = Rng::new(spec.params_seed);
    let mut bytes = Vec::with_capacity(total * 4);
    while bytes.len() < total * 4 {
        bytes.extend_from_slice(&rng.next_u64().to_le_bytes());
    }
    bytes.truncate(total * 4);
    fs::write(dir.join("params.bin"), &bytes)?;
    let seed = reference::seed_from_params(&bytes);

    // tables derived from the model's own bigram attractor
    write_bigram_tables(dir, seed, vocab)?;

    // step + prefill artifacts for the reference backend
    let mut steps = Vec::new();
    for &k in &STEP_KS {
        for &w in &STEP_WS {
            let f = format!("step_k{k}_w{w}.txt");
            fs::write(
                dir.join(&f),
                format!(
                    "{} k={k} w={w}\nsynthetic reference-backend verification artifact\n",
                    reference::STEP_MAGIC
                ),
            )?;
            steps.push((k, w, f));
        }
    }
    let mut prefills = Vec::new();
    for &p in &PREFILL_BUCKETS {
        let f = format!("prefill_{p}.txt");
        fs::write(
            dir.join(&f),
            format!(
                "{} p={p}\nsynthetic reference-backend prefill artifact\n",
                reference::PREFILL_MAGIC
            ),
        )?;
        prefills.push((p, f));
    }

    Ok(Json::obj(vec![
        (
            "dir",
            Json::Str(format!("models/{}", spec.name)),
        ),
        ("analog", Json::Str(spec.analog.into())),
        ("vocab_size", Json::Num(vocab as f64)),
        ("d_model", Json::Num(spec.d_model as f64)),
        ("n_layers", Json::Num(spec.n_layers as f64)),
        ("n_heads", Json::Num(spec.n_heads as f64)),
        ("head_dim", Json::Num(spec.head_dim as f64)),
        ("mlp_hidden", Json::Num(spec.mlp_hidden as f64)),
        ("max_len", Json::Num(spec.max_len as f64)),
        ("n_params", Json::Num(total as f64)),
        (
            "param_spec",
            Json::Arr(
                param_spec
                    .iter()
                    .map(|(n, s)| {
                        Json::obj(vec![
                            ("name", Json::Str((*n).into())),
                            (
                                "shape",
                                Json::Arr(s.iter().map(|&d| Json::Num(d as f64)).collect()),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("params_bin", Json::Str("params.bin".into())),
        (
            "steps",
            Json::Obj(
                steps
                    .into_iter()
                    .map(|(k, w, f)| (format!("{k},{w}"), Json::Str(f)))
                    .collect(),
            ),
        ),
        (
            "prefills",
            Json::Obj(
                prefills
                    .into_iter()
                    .map(|(p, f)| (format!("{p}"), Json::Str(f)))
                    .collect(),
            ),
        ),
        (
            "tables",
            Json::obj(vec![
                ("bigram", Json::Str("bigram.bin".into())),
                ("unigram", Json::Str("unigram.bin".into())),
                ("ext_bigram", Json::Str("ext_bigram.bin".into())),
            ]),
        ),
        ("train_final_loss", Json::Num(spec.train_final_loss)),
    ]))
}

/// Bigram/unigram/ext-bigram tables consistent with the reference model:
/// rank 0 of the bigram table IS the model's attractor, and ext-bigram
/// chains are greedy closures of it, so speculation genuinely accepts.
fn write_bigram_tables(dir: &Path, seed: u64, vocab: usize) -> Result<()> {
    let mut bigram = Vec::with_capacity(vocab * TABLE_TOPK);
    for x in 0..vocab as u32 {
        let top = reference::bigram_next(seed, x, vocab);
        for j in 0..TABLE_TOPK as u32 {
            bigram.push((top + j) % vocab as u32);
        }
    }
    write_table(&dir.join("bigram.bin"), vocab, TABLE_TOPK, 1, &bigram)?;

    let unigram: Vec<u32> = (0..UNIGRAM_TOPK as u64)
        .map(|j| (reference::mix(seed ^ 0x0001_0000 ^ j) % vocab as u64) as u32)
        .collect();
    write_table(&dir.join("unigram.bin"), 1, UNIGRAM_TOPK, 1, &unigram)?;

    let mut ext = Vec::with_capacity(vocab * TABLE_TOPK * EXT_BIGRAM_W);
    for x in 0..vocab as u32 {
        let top = reference::bigram_next(seed, x, vocab);
        for j in 0..TABLE_TOPK as u32 {
            let mut cur = (top + j) % vocab as u32;
            for _ in 0..EXT_BIGRAM_W {
                ext.push(cur);
                cur = reference::bigram_next(seed, cur, vocab);
            }
        }
    }
    write_table(
        &dir.join("ext_bigram.bin"),
        vocab,
        TABLE_TOPK,
        EXT_BIGRAM_W,
        &ext,
    )?;
    Ok(())
}

fn write_table(path: &Path, rows: usize, cols: usize, depth: usize, data: &[u32]) -> Result<()> {
    assert_eq!(data.len(), rows * cols * depth);
    let mut bytes = Vec::with_capacity(16 + data.len() * 4);
    for v in [tables::MAGIC, rows as u32, cols as u32, depth as u32] {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    fs::write(path, bytes).with_context(|| format!("writing table {path:?}"))?;
    Ok(())
}

fn write_tokenizer(path: &Path, merges: &[(u32, u32)]) -> Result<()> {
    let j = Json::obj(vec![
        ("type", Json::Str("byte_bpe".into())),
        ("vocab_size", Json::Num((256 + merges.len()) as f64)),
        (
            "merges",
            Json::Arr(
                merges
                    .iter()
                    .map(|&(a, b)| Json::Arr(vec![Json::Num(a as f64), Json::Num(b as f64)]))
                    .collect(),
            ),
        ),
    ]);
    fs::write(path, j.to_string_pretty()).context("writing tokenizer.json")?;
    Ok(())
}

fn write_fixtures(
    path: &Path,
    tok: &BpeTokenizer,
    corpora: &[(&str, String, String)],
) -> Result<()> {
    let mut texts: Vec<String> = vec![
        "def scale(x, y):\n    return x".into(),
        "User: What is the capital of France?".into(),
        "Question: Tom has 5 apples.".into(),
        "hello world".into(),
        "  leading and trailing  ".into(),
        "tabs\tand\nnewlines".into(),
        "Answer: Tom has 5 plus 3 which makes 8 apples.".into(),
        "Assistant: That is a good question.".into(),
    ];
    for (_, train, _) in corpora {
        let cut = train
            .char_indices()
            .take_while(|(i, _)| *i < 120)
            .last()
            .map(|(i, c)| i + c.len_utf8())
            .unwrap_or(0);
        texts.push(train[..cut].to_string());
    }
    let cases: Vec<Json> = texts
        .iter()
        .map(|t| {
            Json::obj(vec![
                ("text", Json::Str(t.clone())),
                (
                    "ids",
                    Json::Arr(
                        tok.encode(t)
                            .into_iter()
                            .map(|i| Json::Num(i as f64))
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    let j = Json::obj(vec![("cases", Json::Arr(cases))]);
    fs::write(path, j.to_string_pretty()).context("writing tokenizer_fixtures.json")?;
    Ok(())
}

// --- BPE training -----------------------------------------------------------

/// Greedy most-frequent-pair BPE over the piece-split corpora (the same
/// rule `python/compile/tokenizer.py` trains with). Deterministic: ties
/// break toward the lexicographically smallest pair.
fn train_bpe(texts: &[String], n_merges: usize) -> Vec<(u32, u32)> {
    let mut pieces: Vec<Vec<u32>> = texts
        .iter()
        .flat_map(|t| {
            split_pieces(t.as_bytes())
                .into_iter()
                .map(|p| p.iter().map(|&b| b as u32).collect::<Vec<u32>>())
        })
        .collect();
    let mut merges = Vec::with_capacity(n_merges);
    for i in 0..n_merges {
        let mut counts: HashMap<(u32, u32), u64> = HashMap::new();
        for p in &pieces {
            for w in p.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
        }
        let best = counts
            .iter()
            .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(a.0)))
            .map(|(&pair, &c)| (pair, c));
        let Some((pair, count)) = best else { break };
        if count < 2 {
            break;
        }
        let new_id = 256 + i as u32;
        merges.push(pair);
        for p in pieces.iter_mut() {
            apply_merge(p, pair, new_id);
        }
    }
    merges
}

fn apply_merge(p: &mut Vec<u32>, pair: (u32, u32), new_id: u32) {
    let mut out = Vec::with_capacity(p.len());
    let mut i = 0;
    while i < p.len() {
        if i + 1 < p.len() && p[i] == pair.0 && p[i + 1] == pair.1 {
            out.push(new_id);
            i += 2;
        } else {
            out.push(p[i]);
            i += 1;
        }
    }
    *p = out;
}

// --- corpora ----------------------------------------------------------------

const NAMES: [&str; 4] = ["Tom", "Mia", "Sam", "Ava"];
const ITEMS: [&str; 4] = ["apples", "coins", "pens", "cards"];
const TOPICS: [&str; 6] = [
    "the capital of France",
    "the speed of light",
    "ancient rivers",
    "the water cycle",
    "simple machines",
    "the rules of chess",
];

fn gen_chat(n: usize) -> String {
    gen_chat_seeded(n, 0x11)
}

fn gen_chat_seeded(n: usize, seed: u64) -> String {
    let mut rng = Rng::new(seed);
    let mut s = String::new();
    for _ in 0..n {
        let t = *rng.choose(&TOPICS);
        let q = match rng.below(3) {
            0 => format!("What is {t}?"),
            1 => format!("Tell me about {t}."),
            _ => format!("Why does {t} matter?"),
        };
        let a = match rng.below(3) {
            0 => format!(
                "That is a good question. The short answer is that {t} is a \
                 classic topic and people study it every day."
            ),
            1 => format!(
                "Many people ask about {t}. The simple story is that {t} \
                 shapes the way we think about the world."
            ),
            _ => format!(
                "Let me explain. The key idea behind {t} is that small parts \
                 work together, and that is why {t} matters."
            ),
        };
        s.push_str(&format!("User: {q}\nAssistant: {a}\n\n"));
    }
    s
}

fn gen_code(n: usize) -> String {
    gen_code_seeded(n, 0x21)
}

fn gen_code_seeded(n: usize, seed: u64) -> String {
    let mut rng = Rng::new(seed);
    const FNS: [&str; 6] = ["scale", "clamp", "blend", "total", "ratio", "shift"];
    const VARS: [&str; 4] = ["x", "y", "value", "count"];
    const OPS: [&str; 3] = ["+", "-", "*"];
    let mut s = String::new();
    for i in 0..n {
        let f = *rng.choose(&FNS);
        let a = *rng.choose(&VARS);
        let mut b = *rng.choose(&VARS);
        if b == a {
            b = "other";
        }
        let op = *rng.choose(&OPS);
        let c = rng.range(2, 9);
        s.push_str(&format!(
            "def {f}_{i}({a}, {b}):\n    result = {a} {op} {b}\n    \
             result = result {op} {c}\n    return result\n\n"
        ));
    }
    s
}

fn gen_math(n: usize) -> String {
    gen_math_seeded(n, 0x41)
}

fn gen_math_seeded(n: usize, seed: u64) -> String {
    let mut rng = Rng::new(seed);
    let mut s = String::new();
    for _ in 0..n {
        let name = *rng.choose(&NAMES);
        let item = *rng.choose(&ITEMS);
        let x = rng.range(2, 9);
        let y = rng.range(2, 9);
        s.push_str(&format!(
            "Question: {name} has {x} {item}. {name} buys {y} more {item}. \
             How many {item} does {name} have now?\nAnswer: {name} has {x} \
             plus {y} which makes {z} {item}.\n\n",
            z = x + y
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bpe_trainer_matches_encoder() {
        // training then encoding the training text must reproduce the
        // trained segmentation (merges apply in rank order on both sides)
        let texts = vec![gen_chat_seeded(10, 1)];
        let merges = train_bpe(&texts, 50);
        assert!(!merges.is_empty());
        let tok = BpeTokenizer::from_merges(merges);
        let ids = tok.encode(&texts[0]);
        assert_eq!(tok.decode(&ids), texts[0]);
        // trained BPE must compress its own training corpus well
        assert!(
            ids.len() * 2 < texts[0].len(),
            "{} ids for {} bytes",
            ids.len(),
            texts[0].len()
        );
    }

    #[test]
    fn corpora_are_deterministic() {
        assert_eq!(gen_chat(5), gen_chat(5));
        assert_eq!(gen_code(5), gen_code(5));
        assert_eq!(gen_math(5), gen_math(5));
    }

    #[test]
    fn synthetic_tree_loads_as_manifest() {
        let m = manifest();
        assert_eq!(m.models.len(), 3);
        assert!(m.vocab_size > 256);
        for task in ["chat", "code", "math"] {
            assert!(m.data.contains_key(task));
        }
        let art = m.model("small").unwrap();
        assert!(art.steps.contains_key(&(10, 10)));
        assert!(art.prefills.contains_key(&256));
    }
}
