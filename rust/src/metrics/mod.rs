//! Serving metrics: thread-safe counters + latency histograms, rendered in
//! a Prometheus-ish text format at GET /metrics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::draft::StrategyKind;
use crate::trace::Phase;
use crate::util::json::Json;

/// Exponential-bucket latency histogram (microseconds).
#[derive(Debug)]
pub struct LatencyHist {
    /// bucket i counts observations <= 1µs * 2^i (last bucket = overflow)
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl LatencyHist {
    /// An empty histogram.
    pub fn new() -> Self {
        LatencyHist {
            buckets: (0..32).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    /// Record one latency observation.
    pub fn observe(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile from the exponential buckets, linearly
    /// interpolated within the winning bucket. Returns 0 on an empty
    /// histogram; `q` is clamped into [0, 1].
    pub fn quantile_us(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 1.0) };
        let target = ((q * total as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n > 0 && acc + n >= target {
                // interpolate between the bucket's bounds by the target's
                // rank within the bucket
                let lo = if i == 0 { 0u64 } else { 1u64 << (i - 1) };
                let hi = 1u64 << i;
                let frac = (target - acc) as f64 / n as f64;
                return lo as f64 + (hi - lo) as f64 * frac;
            }
            acc += n;
        }
        (1u64 << (self.buckets.len() - 1)) as f64
    }
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

/// All serving-level metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    /// requests ever submitted (accepted + rejected)
    pub requests_total: AtomicU64,
    /// requests rejected at the bounded queue (backpressure)
    pub requests_rejected: AtomicU64,
    /// requests aborted because their client disconnected before the
    /// stream completed (the lane/pages were reclaimed early)
    pub requests_cancelled: AtomicU64,
    /// requests fully decoded and replied
    pub requests_completed: AtomicU64,
    /// total tokens emitted across completed requests
    pub tokens_generated: AtomicU64,
    /// total verification calls across completed requests
    pub verify_calls: AtomicU64,
    /// total accepted draft tokens
    pub drafts_accepted: AtomicU64,
    /// submit-to-reply latency histogram
    pub request_latency: LatencyHistDefault,
    /// per-verification-call latency histogram
    pub step_latency: LatencyHistDefault,
    /// submit → first emitted token latency histogram (fed by the trace
    /// hub when tracing is enabled — the serving default)
    pub ttft: LatencyHistDefault,
    /// per-request mean inter-token latency histogram ((total - ttft) /
    /// (tokens - 1)), fed by the trace hub
    pub inter_token: LatencyHistDefault,
    /// per-phase wall-clock histograms (µs), indexed by
    /// [`Phase::index`]; step phases are fed by engine flight recorders,
    /// queue-wait/prefill by the trace hub on request completion
    pub phase_latency: [LatencyHistDefault; Phase::COUNT],
    /// requests admitted to the queue but not yet on a worker/lane
    pub queue_depth: AtomicU64,
    /// pooled-lane capacity summed across all live engines (elastic mode
    /// scales each engine between `--min-lanes` and the `--batch`
    /// per-engine cap)
    pub lanes: AtomicU64,
    /// lane target summed across engines; `lanes` sits ABOVE this
    /// transiently while a shrink waits for busy lanes to retire (growth
    /// is applied immediately, so `lanes` never lags a larger target)
    pub lanes_target: AtomicU64,
    /// engine worker threads currently live in the pool
    pub engines: AtomicU64,
    /// engine count the two-level autoscaler last decided; `engines`
    /// converges toward it as spawns boot and idle engines retire
    pub engines_target: AtomicU64,
    /// depth-aware routing decisions that fell back to a
    /// depth-incompatible engine after the starvation threshold
    pub routing_fallbacks: AtomicU64,
    /// work-stealing pickups: an idle engine took a request from another
    /// engine's queue (`--dispatch steal` only; stays 0 under central)
    pub steals: AtomicU64,
    /// connections the front-end ever accepted
    pub connections_total: AtomicU64,
    /// connections that ended before their response finished (client
    /// closed or errored mid-stream)
    pub disconnects: AtomicU64,
    /// per-engine gauge snapshots (labelled `engine="<id>"` in render),
    /// overwritten wholesale by the pool dispatcher each iteration
    pub per_engine: Mutex<Vec<EngineGauges>>,
    /// packed-row budget the batched engine enforced on its latest step
    /// (derived online from the cost model in elastic mode)
    pub derived_budget: AtomicU64,
    /// admissions that overtook an older queued request under the
    /// expected-tokens-per-cost admission ordering
    pub admission_reorders: AtomicU64,
    /// requests that reached the engine but failed admission (no free
    /// lane after all, or a prefill error)
    pub admissions_failed: AtomicU64,
    /// distinct KV pages currently live summed across paged engines
    /// (stays 0 when engines run the contiguous lane pool)
    pub kv_pages: AtomicU64,
    /// unreserved KV pages still available summed across paged engines
    pub kv_pages_free: AtomicU64,
    /// KV pages referenced by more than one sequence (prefix sharing)
    /// summed across paged engines
    pub kv_pages_shared: AtomicU64,
    /// admissions that attached at least one shared prefix page instead
    /// of writing fresh KV for it
    pub kv_prefix_hits: AtomicU64,
    /// draft rows filled from the fleet-shared draft store across all
    /// engines (`--shared-draft fleet`; mirrored from the store each
    /// gauge publish, 0 when the store is off)
    pub shared_draft_hits: AtomicU64,
    /// propose calls that consulted the shared store but found no chain
    /// for their context
    pub shared_draft_misses: AtomicU64,
    /// batched accepted-token deltas writers published into the store
    pub shared_draft_publishes: AtomicU64,
    /// per-`StrategyKind` step wins (indexed by `StrategyKind::index()`):
    /// which draft source actually won each verification call
    pub strategy_wins: [AtomicU64; StrategyKind::COUNT],
    /// per-`StrategyKind` accepted draft tokens across winning steps
    pub strategy_accepted: [AtomicU64; StrategyKind::COUNT],
    /// last N per-request summaries for debugging (bounded)
    pub recent: Mutex<Vec<String>>,
}

/// One engine worker's gauge snapshot, as the pool dispatcher last saw
/// it. Rendered as the `ngrammys_engine_*{engine="<id>"}` families; the
/// label is the engine's stable spawn ordinal, so a retired engine's
/// series simply stops instead of being renumbered.
#[derive(Debug, Clone, Default)]
pub struct EngineGauges {
    /// stable spawn ordinal (the `engine` label value)
    pub id: u64,
    /// current lane-pool capacity
    pub lanes: u64,
    /// lane target this engine's autoscaler last decided
    pub lanes_target: u64,
    /// sequences currently decoding on this engine
    pub active: u64,
    /// resident + routed greedy (w = 0) requests
    pub greedy: u64,
    /// resident + routed speculative requests
    pub speculative: u64,
    /// mean adaptive-controller heat across this engine's lanes
    pub heat: f64,
    /// bytes this engine's KV lane pool currently pins
    pub kv_bytes: u64,
    /// distinct KV pages live in this engine's paged pool (lane mode
    /// reports in-use lanes here, so the family reads one shape either way)
    pub kv_pages: u64,
    /// unreserved KV pages left in this engine's paged pool
    pub kv_pages_free: u64,
    /// KV pages on this engine shared by more than one sequence
    pub kv_pages_shared: u64,
    /// admissions on this engine that reused shared prefix pages
    pub kv_prefix_hits: u64,
    /// draft rows this engine filled from the fleet-shared draft store
    /// (per-engine hit-through; 0 with `--shared-draft off`)
    pub shared_draft_hits: u64,
}

/// Default-able newtype around [`LatencyHist`] so [`Metrics`] can derive
/// `Default`; derefs to the inner histogram.
#[derive(Debug, Default)]
pub struct LatencyHistDefault(pub LatencyHist);

impl std::ops::Deref for LatencyHistDefault {
    type Target = LatencyHist;
    fn deref(&self) -> &LatencyHist {
        &self.0
    }
}

impl Metrics {
    /// Fresh zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one completed request's aggregates.
    pub fn record_request(&self, latency: Duration, tokens: usize, calls: usize, accepted: usize) {
        self.requests_completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated.fetch_add(tokens as u64, Ordering::Relaxed);
        self.verify_calls.fetch_add(calls as u64, Ordering::Relaxed);
        self.drafts_accepted.fetch_add(accepted as u64, Ordering::Relaxed);
        self.request_latency.observe(latency);
    }

    /// Record one verification call's winner: which strategy kind won and
    /// how many draft tokens it got accepted (operators watch these to see
    /// which strategies are actually paying for their rows).
    pub fn record_strategy_step(&self, kind: StrategyKind, accepted: usize) {
        let i = kind.index();
        self.strategy_wins[i].fetch_add(1, Ordering::Relaxed);
        self.strategy_accepted[i].fetch_add(accepted as u64, Ordering::Relaxed);
    }

    /// Replace the per-engine gauge snapshots (the pool dispatcher calls
    /// this once per routing iteration with every engine's live gauges).
    pub fn set_per_engine(&self, snaps: Vec<EngineGauges>) {
        *self.per_engine.lock().unwrap() = snaps;
    }

    /// Observed tokens-per-call across all requests (the paper's metric,
    /// aggregated).
    pub fn tokens_per_call(&self) -> f64 {
        let calls = self.verify_calls.load(Ordering::Relaxed);
        if calls == 0 {
            0.0
        } else {
            self.tokens_generated.load(Ordering::Relaxed) as f64 / calls as f64
        }
    }

    /// Render every metric in the Prometheus-ish text format served at
    /// GET /metrics (field names are pinned by the render tests below).
    pub fn render(&self) -> String {
        let mut s = String::new();
        let c = |n: &AtomicU64| n.load(Ordering::Relaxed);
        s.push_str(&format!("ngrammys_requests_total {}\n", c(&self.requests_total)));
        s.push_str(&format!("ngrammys_requests_rejected {}\n", c(&self.requests_rejected)));
        s.push_str(&format!("ngrammys_requests_cancelled {}\n", c(&self.requests_cancelled)));
        s.push_str(&format!("ngrammys_requests_completed {}\n", c(&self.requests_completed)));
        s.push_str(&format!("ngrammys_tokens_generated {}\n", c(&self.tokens_generated)));
        s.push_str(&format!("ngrammys_verify_calls {}\n", c(&self.verify_calls)));
        s.push_str(&format!("ngrammys_tokens_per_call {:.4}\n", self.tokens_per_call()));
        s.push_str(&format!("ngrammys_queue_depth {}\n", c(&self.queue_depth)));
        s.push_str(&format!("ngrammys_lanes {}\n", c(&self.lanes)));
        s.push_str(&format!("ngrammys_lanes_target {}\n", c(&self.lanes_target)));
        s.push_str(&format!("ngrammys_engines {}\n", c(&self.engines)));
        s.push_str(&format!("ngrammys_engines_target {}\n", c(&self.engines_target)));
        s.push_str(&format!("ngrammys_routing_fallbacks {}\n", c(&self.routing_fallbacks)));
        s.push_str(&format!("ngrammys_steals {}\n", c(&self.steals)));
        s.push_str(&format!("ngrammys_connections_total {}\n", c(&self.connections_total)));
        s.push_str(&format!("ngrammys_disconnects {}\n", c(&self.disconnects)));
        for g in self.per_engine.lock().unwrap().iter() {
            let e = g.id;
            s.push_str(&format!("ngrammys_engine_lanes{{engine=\"{e}\"}} {}\n", g.lanes));
            s.push_str(&format!(
                "ngrammys_engine_lanes_target{{engine=\"{e}\"}} {}\n",
                g.lanes_target
            ));
            s.push_str(&format!("ngrammys_engine_active{{engine=\"{e}\"}} {}\n", g.active));
            s.push_str(&format!("ngrammys_engine_greedy{{engine=\"{e}\"}} {}\n", g.greedy));
            s.push_str(&format!(
                "ngrammys_engine_speculative{{engine=\"{e}\"}} {}\n",
                g.speculative
            ));
            s.push_str(&format!("ngrammys_engine_heat{{engine=\"{e}\"}} {:.3}\n", g.heat));
            s.push_str(&format!("ngrammys_engine_kv_bytes{{engine=\"{e}\"}} {}\n", g.kv_bytes));
            s.push_str(&format!("ngrammys_engine_kv_pages{{engine=\"{e}\"}} {}\n", g.kv_pages));
            s.push_str(&format!(
                "ngrammys_engine_kv_pages_free{{engine=\"{e}\"}} {}\n",
                g.kv_pages_free
            ));
            s.push_str(&format!(
                "ngrammys_engine_kv_pages_shared{{engine=\"{e}\"}} {}\n",
                g.kv_pages_shared
            ));
            s.push_str(&format!(
                "ngrammys_engine_kv_prefix_hits{{engine=\"{e}\"}} {}\n",
                g.kv_prefix_hits
            ));
            s.push_str(&format!(
                "ngrammys_engine_shared_draft_hits{{engine=\"{e}\"}} {}\n",
                g.shared_draft_hits
            ));
        }
        s.push_str(&format!("ngrammys_derived_budget {}\n", c(&self.derived_budget)));
        s.push_str(&format!("ngrammys_admission_reorders {}\n", c(&self.admission_reorders)));
        s.push_str(&format!("ngrammys_admissions_failed {}\n", c(&self.admissions_failed)));
        s.push_str(&format!("ngrammys_kv_pages {}\n", c(&self.kv_pages)));
        s.push_str(&format!("ngrammys_kv_pages_free {}\n", c(&self.kv_pages_free)));
        s.push_str(&format!("ngrammys_kv_pages_shared {}\n", c(&self.kv_pages_shared)));
        s.push_str(&format!("ngrammys_kv_prefix_hits {}\n", c(&self.kv_prefix_hits)));
        s.push_str(&format!("ngrammys_shared_draft_hits {}\n", c(&self.shared_draft_hits)));
        s.push_str(&format!("ngrammys_shared_draft_misses {}\n", c(&self.shared_draft_misses)));
        s.push_str(&format!(
            "ngrammys_shared_draft_publishes {}\n",
            c(&self.shared_draft_publishes)
        ));
        s.push_str(&format!(
            "ngrammys_request_latency_ms_mean {:.3}\n",
            self.request_latency.mean_us() / 1e3
        ));
        s.push_str(&format!(
            "ngrammys_request_latency_ms_p50 {:.3}\n",
            self.request_latency.quantile_us(0.5) / 1e3
        ));
        s.push_str(&format!(
            "ngrammys_request_latency_ms_p99 {:.3}\n",
            self.request_latency.quantile_us(0.99) / 1e3
        ));
        s.push_str(&format!(
            "ngrammys_step_latency_ms_mean {:.3}\n",
            self.step_latency.mean_us() / 1e3
        ));
        for kind in StrategyKind::ALL {
            let i = kind.index();
            s.push_str(&format!(
                "ngrammys_strategy_wins{{strategy=\"{}\"}} {}\n",
                kind.label(),
                c(&self.strategy_wins[i])
            ));
            s.push_str(&format!(
                "ngrammys_strategy_accepted_tokens{{strategy=\"{}\"}} {}\n",
                kind.label(),
                c(&self.strategy_accepted[i])
            ));
        }
        const QUANTILES: [(f64, &str); 3] = [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99")];
        for (q, label) in QUANTILES {
            s.push_str(&format!(
                "ngrammys_ttft_us{{quantile=\"{label}\"}} {:.1}\n",
                self.ttft.quantile_us(q)
            ));
        }
        for (q, label) in QUANTILES {
            s.push_str(&format!(
                "ngrammys_inter_token_us{{quantile=\"{label}\"}} {:.1}\n",
                self.inter_token.quantile_us(q)
            ));
        }
        for p in Phase::ALL {
            for (q, label) in QUANTILES {
                s.push_str(&format!(
                    "ngrammys_phase_us{{phase=\"{}\",quantile=\"{label}\"}} {:.1}\n",
                    p.label(),
                    self.phase_latency[p.index()].quantile_us(q)
                ));
            }
        }
        s
    }

    /// JSON latency summary served at `GET /stats`: request counters plus
    /// ttft / inter-token / per-phase histogram digests.
    pub fn stats_json(&self) -> Json {
        let c = |n: &AtomicU64| Json::Num(n.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("requests_completed", c(&self.requests_completed)),
            ("tokens_generated", c(&self.tokens_generated)),
            ("verify_calls", c(&self.verify_calls)),
            ("tokens_per_call", Json::Num(self.tokens_per_call())),
            ("ttft_us", hist_json(&self.ttft)),
            ("inter_token_us", hist_json(&self.inter_token)),
            ("request_latency_us", hist_json(&self.request_latency)),
            (
                "phases",
                Json::Obj(
                    Phase::ALL
                        .iter()
                        .map(|p| {
                            (p.label().to_string(), hist_json(&self.phase_latency[p.index()]))
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// One histogram's JSON digest (count, mean, p50/p90/p99 in µs).
fn hist_json(h: &LatencyHist) -> Json {
    Json::obj(vec![
        ("count", Json::Num(h.count() as f64)),
        ("mean_us", Json::Num(h.mean_us())),
        ("p50_us", Json::Num(h.quantile_us(0.5))),
        ("p90_us", Json::Num(h.quantile_us(0.9))),
        ("p99_us", Json::Num(h.quantile_us(0.99))),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = LatencyHist::new();
        for ms in [1u64, 1, 1, 1, 1, 1, 1, 1, 1, 100] {
            h.observe(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 10);
        assert!(h.quantile_us(0.5) <= 2048.0);
        assert!(h.quantile_us(0.99) >= 65536.0);
        assert!((h.mean_us() - (9.0 * 1000.0 + 100_000.0) / 10.0).abs() < 1.0);
    }

    #[test]
    fn quantile_empty_histogram_returns_zero() {
        let h = LatencyHist::new();
        assert_eq!(h.quantile_us(0.5), 0.0);
        assert_eq!(h.quantile_us(0.99), 0.0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn quantile_clamps_q_outside_unit_interval() {
        let h = LatencyHist::new();
        h.observe(Duration::from_micros(100));
        let lo = h.quantile_us(-3.0);
        let hi = h.quantile_us(7.5);
        assert!(lo.is_finite() && hi.is_finite());
        assert_eq!(lo, h.quantile_us(0.0));
        assert_eq!(hi, h.quantile_us(1.0));
        assert_eq!(h.quantile_us(f64::NAN), h.quantile_us(0.0));
    }

    #[test]
    fn quantile_single_sample_lands_in_its_bucket() {
        let h = LatencyHist::new();
        h.observe(Duration::from_micros(100)); // bucket (64, 128]
        for q in [0.0, 0.5, 1.0] {
            let v = h.quantile_us(q);
            assert!(v > 64.0 && v <= 128.0, "q={q} gave {v}");
        }
    }

    #[test]
    fn quantile_interpolates_within_bucket() {
        let h = LatencyHist::new();
        // nine observations in the (512, 1024] bucket
        for _ in 0..9 {
            h.observe(Duration::from_micros(1000));
        }
        let p50 = h.quantile_us(0.5);
        // rank 5 of 9 → lo + (hi-lo) * 5/9
        let expect = 512.0 + 512.0 * 5.0 / 9.0;
        assert!((p50 - expect).abs() < 1e-9, "p50={p50} expect={expect}");
        assert_eq!(h.quantile_us(1.0), 1024.0);
    }

    #[test]
    fn tokens_per_call_aggregates() {
        let m = Metrics::new();
        m.record_request(Duration::from_millis(5), 30, 10, 20);
        m.record_request(Duration::from_millis(5), 10, 10, 0);
        assert!((m.tokens_per_call() - 2.0).abs() < 1e-9);
        let r = m.render();
        assert!(r.contains("ngrammys_tokens_per_call 2.0000"));
    }

    /// The `/metrics` contract: every field documented in the
    /// rust/README.md reference table must appear in `render` output
    /// under exactly this name. Renaming or adding a field means
    /// updating the README table AND this list — the doc can no longer
    /// drift silently.
    #[test]
    fn render_exports_every_documented_field() {
        let m = Metrics::new();
        let r = m.render();
        const FIELDS: [&str; 30] = [
            "ngrammys_requests_total",
            "ngrammys_requests_rejected",
            "ngrammys_requests_cancelled",
            "ngrammys_requests_completed",
            "ngrammys_tokens_generated",
            "ngrammys_verify_calls",
            "ngrammys_tokens_per_call",
            "ngrammys_queue_depth",
            "ngrammys_lanes",
            "ngrammys_lanes_target",
            "ngrammys_engines",
            "ngrammys_engines_target",
            "ngrammys_routing_fallbacks",
            "ngrammys_steals",
            "ngrammys_connections_total",
            "ngrammys_disconnects",
            "ngrammys_derived_budget",
            "ngrammys_admission_reorders",
            "ngrammys_admissions_failed",
            "ngrammys_kv_pages",
            "ngrammys_kv_pages_free",
            "ngrammys_kv_pages_shared",
            "ngrammys_kv_prefix_hits",
            "ngrammys_shared_draft_hits",
            "ngrammys_shared_draft_misses",
            "ngrammys_shared_draft_publishes",
            "ngrammys_request_latency_ms_mean",
            "ngrammys_request_latency_ms_p50",
            "ngrammys_request_latency_ms_p99",
            "ngrammys_step_latency_ms_mean",
        ];
        for f in FIELDS {
            let line_start = format!("{f} ");
            assert!(
                r.starts_with(&line_start) || r.contains(&format!("\n{line_start}")),
                "missing /metrics field '{f}' in:\n{r}"
            );
        }
        for kind in StrategyKind::ALL {
            for family in ["ngrammys_strategy_wins", "ngrammys_strategy_accepted_tokens"] {
                let field = format!("{family}{{strategy=\"{}\"}} ", kind.label());
                assert!(r.contains(&field), "missing {field}");
            }
        }
        // latency-quantile families added with the flight recorder: every
        // documented quantile label must render for ttft / inter-token and
        // for every phase
        for q in ["0.5", "0.9", "0.99"] {
            for family in ["ngrammys_ttft_us", "ngrammys_inter_token_us"] {
                let field = format!("{family}{{quantile=\"{q}\"}} ");
                assert!(r.contains(&field), "missing {field}");
            }
            for p in Phase::ALL {
                let field =
                    format!("ngrammys_phase_us{{phase=\"{}\",quantile=\"{q}\"}} ", p.label());
                assert!(r.contains(&field), "missing {field}");
            }
        }
    }

    #[test]
    fn stats_json_digests_latency_histograms() {
        let m = Metrics::new();
        m.record_request(Duration::from_millis(5), 30, 10, 20);
        m.ttft.observe(Duration::from_micros(800));
        m.inter_token.observe(Duration::from_micros(90));
        m.phase_latency[Phase::Verify.index()].observe(Duration::from_micros(400));
        let j = m.stats_json();
        assert_eq!(j.get("requests_completed").and_then(|v| v.as_f64()), Some(1.0));
        let ttft = j.get("ttft_us").expect("ttft digest");
        assert_eq!(ttft.get("count").and_then(|v| v.as_f64()), Some(1.0));
        assert!(ttft.get("p50_us").and_then(|v| v.as_f64()).unwrap() > 0.0);
        assert!(ttft.get("p99_us").and_then(|v| v.as_f64()).unwrap() > 0.0);
        let phases = j.get("phases").expect("phase digests");
        let verify = phases.get("verify").expect("verify digest");
        assert!(verify.get("mean_us").and_then(|v| v.as_f64()).unwrap() > 0.0);
        assert_eq!(
            phases.get("draft").and_then(|p| p.get("count")).and_then(|v| v.as_f64()),
            Some(0.0)
        );
        // the summary must parse back through the in-tree JSON parser
        let text = j.to_string();
        assert!(crate::util::json::Json::parse(&text).is_ok());
    }

    #[test]
    fn elastic_gauges_render_stored_values() {
        let m = Metrics::new();
        m.lanes.store(3, Ordering::Relaxed);
        m.lanes_target.store(5, Ordering::Relaxed);
        m.derived_budget.store(17, Ordering::Relaxed);
        m.admission_reorders.store(2, Ordering::Relaxed);
        m.admissions_failed.store(1, Ordering::Relaxed);
        let r = m.render();
        assert!(r.contains("ngrammys_lanes 3\n"));
        assert!(r.contains("ngrammys_lanes_target 5\n"));
        assert!(r.contains("ngrammys_derived_budget 17\n"));
        assert!(r.contains("ngrammys_admission_reorders 2\n"));
        assert!(r.contains("ngrammys_admissions_failed 1\n"));
    }

    /// The per-engine gauge families: one labelled series per snapshot,
    /// keyed by the engine's stable spawn ordinal — every family the
    /// README table documents must render under exactly these names.
    #[test]
    fn per_engine_gauges_render_labelled_families() {
        let m = Metrics::new();
        m.engines.store(2, Ordering::Relaxed);
        m.engines_target.store(3, Ordering::Relaxed);
        m.routing_fallbacks.store(4, Ordering::Relaxed);
        m.set_per_engine(vec![
            EngineGauges {
                id: 0,
                lanes: 2,
                lanes_target: 2,
                active: 1,
                greedy: 0,
                speculative: 1,
                heat: 1.5,
                kv_bytes: 4096,
                kv_pages: 6,
                kv_pages_free: 2,
                kv_pages_shared: 3,
                kv_prefix_hits: 1,
                shared_draft_hits: 9,
            },
            EngineGauges {
                id: 3,
                lanes: 4,
                lanes_target: 3,
                active: 4,
                greedy: 4,
                speculative: 0,
                heat: 0.0,
                kv_bytes: 8192,
                kv_pages: 0,
                kv_pages_free: 0,
                kv_pages_shared: 0,
                kv_prefix_hits: 0,
                shared_draft_hits: 0,
            },
        ]);
        let r = m.render();
        assert!(r.contains("ngrammys_engines 2\n"));
        assert!(r.contains("ngrammys_engines_target 3\n"));
        assert!(r.contains("ngrammys_routing_fallbacks 4\n"));
        // labels are spawn ordinals, NOT vector positions: engine 3 kept
        // its id even though it renders second
        assert!(r.contains("ngrammys_engine_lanes{engine=\"0\"} 2\n"));
        assert!(r.contains("ngrammys_engine_lanes_target{engine=\"0\"} 2\n"));
        assert!(r.contains("ngrammys_engine_active{engine=\"0\"} 1\n"));
        assert!(r.contains("ngrammys_engine_greedy{engine=\"0\"} 0\n"));
        assert!(r.contains("ngrammys_engine_speculative{engine=\"0\"} 1\n"));
        assert!(r.contains("ngrammys_engine_heat{engine=\"0\"} 1.500\n"));
        assert!(r.contains("ngrammys_engine_kv_bytes{engine=\"0\"} 4096\n"));
        assert!(r.contains("ngrammys_engine_kv_pages{engine=\"0\"} 6\n"));
        assert!(r.contains("ngrammys_engine_kv_pages_free{engine=\"0\"} 2\n"));
        assert!(r.contains("ngrammys_engine_kv_pages_shared{engine=\"0\"} 3\n"));
        assert!(r.contains("ngrammys_engine_kv_prefix_hits{engine=\"0\"} 1\n"));
        assert!(r.contains("ngrammys_engine_shared_draft_hits{engine=\"0\"} 9\n"));
        assert!(r.contains("ngrammys_engine_shared_draft_hits{engine=\"3\"} 0\n"));
        assert!(r.contains("ngrammys_engine_kv_bytes{engine=\"3\"} 8192\n"));
        assert!(r.contains("ngrammys_engine_kv_pages{engine=\"3\"} 0\n"));
        assert!(r.contains("ngrammys_engine_lanes{engine=\"3\"} 4\n"));
        assert!(r.contains("ngrammys_engine_lanes_target{engine=\"3\"} 3\n"));
        assert!(r.contains("ngrammys_engine_active{engine=\"3\"} 4\n"));
        assert!(r.contains("ngrammys_engine_greedy{engine=\"3\"} 4\n"));
        assert!(r.contains("ngrammys_engine_speculative{engine=\"3\"} 0\n"));
        assert!(r.contains("ngrammys_engine_heat{engine=\"3\"} 0.000\n"));
        // a later snapshot REPLACES the families (retired engines stop)
        m.set_per_engine(vec![EngineGauges { id: 3, lanes: 1, ..EngineGauges::default() }]);
        let r = m.render();
        assert!(!r.contains("engine=\"0\""));
        assert!(r.contains("ngrammys_engine_lanes{engine=\"3\"} 1\n"));
    }

    #[test]
    fn per_strategy_counters_render() {
        let m = Metrics::new();
        m.record_strategy_step(StrategyKind::ContextNgram, 4);
        m.record_strategy_step(StrategyKind::ContextNgram, 2);
        m.record_strategy_step(StrategyKind::SessionCache, 7);
        let r = m.render();
        assert!(r.contains("ngrammys_strategy_wins{strategy=\"context-ngram\"} 2"));
        assert!(r.contains("ngrammys_strategy_accepted_tokens{strategy=\"context-ngram\"} 6"));
        assert!(r.contains("ngrammys_strategy_wins{strategy=\"session-cache\"} 1"));
        assert!(r.contains("ngrammys_strategy_wins{strategy=\"ext-bigram\"} 0"));
    }
}
