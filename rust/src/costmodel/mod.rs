//! Analytical accelerator cost model (paper §3 + Fig. 1).
//!
//! The paper's phase-transition argument: a matmul is *memory-bound* when
//! its operations-to-bytes (OTB) ratio is below the hardware threshold
//! (peak_flops / memory_bandwidth); batched verification is ~free exactly
//! while every matmul in the forward pass stays memory-bound. Above the
//! threshold the op is compute-bound and, because tiles are quantized onto
//! a finite number of multiprocessors, time grows in discrete *waves*
//! ("wave quantization") — the blocky jumps in Fig. 1.
//!
//! This module reproduces that mechanism for an A100-40GB-like device and
//! the paper's model sizes. CPU PJRT cannot exhibit the transition (it is
//! compute-bound almost immediately), so Fig. 1 and the simulated wall-time
//! columns come from here while tokens/call comes from real runs — see
//! DESIGN.md §Substitutions.

/// Hardware description (defaults = NVIDIA A100 40GB SXM, bf16).
#[derive(Debug, Clone)]
pub struct Hardware {
    /// human-readable device name (bench reports)
    pub name: &'static str,
    /// peak dense bf16 throughput, FLOP/s
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s
    pub mem_bw: f64,
    /// number of multiprocessors (SMs) for wave quantization
    pub sms: usize,
    /// matmul tile size the kernel library targets (M and N)
    pub tile: usize,
    /// fixed per-kernel launch overhead, seconds
    pub launch_overhead: f64,
    /// fixed per-forward-pass overhead (framework, sampling), seconds
    pub step_overhead: f64,
}

impl Hardware {
    /// The paper's reference device.
    pub fn a100_40gb() -> Self {
        Hardware {
            name: "A100-40GB (bf16)",
            peak_flops: 312e12,
            mem_bw: 1.555e12,
            sms: 108,
            tile: 128,
            launch_overhead: 4e-6,
            step_overhead: 60e-6,
        }
    }

    /// A device with a *lower* OTB threshold (compute-poor, like the GPU
    /// REST used) — for the hardware-sensitivity ablation.
    pub fn low_otb() -> Self {
        Hardware {
            name: "low-OTB device",
            peak_flops: 120e12,
            mem_bw: 2.0e12,
            sms: 80,
            ..Hardware::a100_40gb()
        }
    }

    /// A device with a *higher* OTB threshold (like Lookahead's testbed).
    pub fn high_otb() -> Self {
        Hardware {
            name: "high-OTB device",
            peak_flops: 600e12,
            mem_bw: 1.6e12,
            sms: 132,
            ..Hardware::a100_40gb()
        }
    }

    /// Ops-to-bytes threshold (FLOP per byte at the roofline ridge).
    pub fn otb_threshold(&self) -> f64 {
        self.peak_flops / self.mem_bw
    }
}

/// Transformer dimensions for the cost model (the *paper's* models — the
/// nano models' measured tokens/call are combined with THESE dims to
/// produce simulated wall-times at the paper's scale).
#[derive(Debug, Clone)]
pub struct TxDims {
    /// size label used in bench output ("7b", "3b", "13b")
    pub name: &'static str,
    /// residual-stream width
    pub d_model: usize,
    /// transformer layer count
    pub n_layers: usize,
    /// attention head count
    pub n_heads: usize,
    /// per-head dimension
    pub head_dim: usize,
    /// MLP hidden width
    pub mlp_hidden: usize,
    /// vocabulary size (lm-head width)
    pub vocab: usize,
    /// bytes per parameter/activation element (bf16 = 2)
    pub dtype_bytes: usize,
}

impl TxDims {
    /// Mistral-7B-Instruct (GQA folded into an effective kv width).
    pub fn mistral_7b() -> Self {
        TxDims {
            name: "7b",
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            head_dim: 128,
            mlp_hidden: 14336,
            vocab: 32000,
            dtype_bytes: 2,
        }
    }

    /// Phi-3-mini (3.8B).
    pub fn phi3_mini() -> Self {
        TxDims {
            name: "3b",
            d_model: 3072,
            n_layers: 32,
            n_heads: 32,
            head_dim: 96,
            mlp_hidden: 8192,
            vocab: 32064,
            dtype_bytes: 2,
        }
    }

    /// Vicuna-13B.
    pub fn vicuna_13b() -> Self {
        TxDims {
            name: "13b",
            d_model: 5120,
            n_layers: 40,
            n_heads: 40,
            head_dim: 128,
            mlp_hidden: 13824,
            vocab: 32000,
            dtype_bytes: 2,
        }
    }

    /// Dimensions for a paper-model analog name (`None` if unknown).
    pub fn for_analog(name: &str) -> Option<Self> {
        match name {
            "small" | "3b" | "phi3" => Some(Self::phi3_mini()),
            "base" | "7b" | "mistral" => Some(Self::mistral_7b()),
            "large" | "13b" | "vicuna" => Some(Self::vicuna_13b()),
            _ => None,
        }
    }
}

/// One GEMM in the forward pass: (batch, m, n, k_dim) with operand reuse
/// semantics — `weight_bytes` counts B once (weights are read once per
/// kernel regardless of batch).
#[derive(Debug, Clone, Copy)]
struct Gemm {
    batch: usize,
    m: usize,
    n: usize,
    k: usize,
    /// whether B is a weight matrix shared across the batch
    shared_b: bool,
}

/// Analytical call-time model: per-GEMM max(memory roofline,
/// wave-quantized compute) summed over one forward pass.
pub struct CostModel {
    /// device description
    pub hw: Hardware,
    /// transformer dimensions at paper scale
    pub dims: TxDims,
}

impl CostModel {
    /// A cost model for `dims` running on `hw`.
    pub fn new(hw: Hardware, dims: TxDims) -> Self {
        CostModel { hw, dims }
    }

    /// Paper-scale cost model for a nano model's analog name (manifest
    /// `dims.analog`), defaulting to the 7B analog on the reference A100.
    /// This is what the adaptive controller scores verify calls with.
    pub fn for_analog(analog: &str) -> Self {
        let dims = TxDims::for_analog(analog).unwrap_or_else(TxDims::mistral_7b);
        CostModel::new(Hardware::a100_40gb(), dims)
    }

    /// Time for one GEMM: max(memory roofline, wave-quantized compute) +
    /// launch overhead.
    fn gemm_time(&self, g: Gemm) -> f64 {
        let eb = self.dims.dtype_bytes as f64;
        let flops = 2.0 * (g.batch * g.m * g.n * g.k) as f64;
        let a_bytes = (g.batch * g.m * g.k) as f64 * eb;
        let b_bytes = if g.shared_b {
            (g.n * g.k) as f64 * eb
        } else {
            (g.batch * g.n * g.k) as f64 * eb
        };
        let c_bytes = (g.batch * g.m * g.n) as f64 * eb;
        let mem_t = (a_bytes + b_bytes + c_bytes) / self.hw.mem_bw;

        // wave quantization: tiles rounded up to whole waves of SMs
        let tiles = g.batch
            * g.m.div_ceil(self.hw.tile)
            * g.n.div_ceil(self.hw.tile);
        let waves = tiles.div_ceil(self.hw.sms) as f64;
        let per_wave_flops = flops / tiles as f64 * self.hw.sms as f64;
        let compute_t = waves * per_wave_flops / self.hw.peak_flops;

        mem_t.max(compute_t) + self.hw.launch_overhead
    }

    /// Forward-pass time for an input block of (k_rows, w1) tokens with
    /// `ctx_len` KV-cached context positions.
    ///
    /// Matmul inventory per layer (paper §3's O(k·w·(w+ℓ)) attention):
    ///   qkv proj, attention scores, attention values, out proj,
    ///   mlp gate/up/down; plus the lm head once.
    pub fn call_time(&self, k_rows: usize, w1: usize, ctx_len: usize) -> f64 {
        let d = &self.dims;
        let rows = k_rows * w1; // total query tokens
        let att_cols = ctx_len + w1; // keys each query can see
        let mut t = 0.0;
        let per_layer = [
            // fused qkv projection: (rows, 3d) = (rows, d) x (d, 3d)
            Gemm { batch: 1, m: rows, n: 3 * d.d_model, k: d.d_model, shared_b: true },
            // scores: per (row-batch, head): (w1, att_cols) — batched GEMM
            Gemm { batch: k_rows * d.n_heads, m: w1, n: att_cols, k: d.head_dim,
                   shared_b: false },
            // attn out: (w1, head_dim) = (w1, att_cols) x (att_cols, head_dim)
            Gemm { batch: k_rows * d.n_heads, m: w1, n: d.head_dim, k: att_cols,
                   shared_b: false },
            // output projection
            Gemm { batch: 1, m: rows, n: d.d_model, k: d.d_model, shared_b: true },
            // mlp gate+up fused, then down
            Gemm { batch: 1, m: rows, n: 2 * d.mlp_hidden, k: d.d_model, shared_b: true },
            Gemm { batch: 1, m: rows, n: d.d_model, k: d.mlp_hidden, shared_b: true },
        ];
        for g in per_layer {
            t += self.gemm_time(g);
        }
        t *= d.n_layers as f64;
        // lm head
        t += self.gemm_time(Gemm {
            batch: 1, m: rows, n: d.vocab, k: d.d_model, shared_b: true,
        });
        t + self.hw.step_overhead
    }

    /// [`Self::call_time`] when the first `shared_len` context positions
    /// live in SHARED KV pages (paged pool prefix sharing): the shared
    /// columns' K/V bytes are read from HBM once per kernel instead of
    /// once per batched row, so the attention GEMMs' memory rooflines
    /// split into a weight-like shared part and a per-row private part.
    /// FLOPs are unchanged — sharing moves bytes, not math — and at
    /// `shared_len = 0` the GEMM inventory is identical to
    /// [`Self::call_time`] (bitwise-equal result), which the tests pin.
    pub fn call_time_prefix(
        &self,
        k_rows: usize,
        w1: usize,
        ctx_len: usize,
        shared_len: usize,
    ) -> f64 {
        let d = &self.dims;
        let rows = k_rows * w1;
        let att_cols = ctx_len + w1;
        let shared = shared_len.min(ctx_len);
        let priv_cols = att_cols - shared;
        let heads = k_rows * d.n_heads;
        let mut t = 0.0;
        let mut per_layer: Vec<Gemm> = Vec::with_capacity(8);
        // fused qkv projection: (rows, 3d) = (rows, d) x (d, 3d)
        per_layer.push(Gemm {
            batch: 1, m: rows, n: 3 * d.d_model, k: d.d_model, shared_b: true,
        });
        // scores, split on the key columns: shared-prefix keys behave like
        // weights (read once), private keys are read per row-batch element
        if shared > 0 {
            per_layer.push(Gemm {
                batch: heads, m: w1, n: shared, k: d.head_dim, shared_b: true,
            });
        }
        per_layer.push(Gemm {
            batch: heads, m: w1, n: priv_cols, k: d.head_dim, shared_b: false,
        });
        // attn out, split on the contraction (value rows) the same way
        if shared > 0 {
            per_layer.push(Gemm {
                batch: heads, m: w1, n: d.head_dim, k: shared, shared_b: true,
            });
        }
        per_layer.push(Gemm {
            batch: heads, m: w1, n: d.head_dim, k: priv_cols, shared_b: false,
        });
        // output projection
        per_layer.push(Gemm {
            batch: 1, m: rows, n: d.d_model, k: d.d_model, shared_b: true,
        });
        // mlp gate+up fused, then down
        per_layer.push(Gemm {
            batch: 1, m: rows, n: 2 * d.mlp_hidden, k: d.d_model, shared_b: true,
        });
        per_layer.push(Gemm {
            batch: 1, m: rows, n: d.d_model, k: d.mlp_hidden, shared_b: true,
        });
        for g in per_layer {
            t += self.gemm_time(g);
        }
        t *= d.n_layers as f64;
        // lm head
        t += self.gemm_time(Gemm {
            batch: 1, m: rows, n: d.vocab, k: d.d_model, shared_b: true,
        });
        t + self.hw.step_overhead
    }

    /// [`Self::memory_bound_rows`] re-derived in units of DISTINCT pages:
    /// with the first `shared_len` context positions in shared pages, the
    /// per-row memory cost is lower, so the phase-transition knee sits at
    /// more rows. Never below the plain derivation at `shared_len = 0`.
    pub fn memory_bound_rows_shared(
        &self,
        w: usize,
        ctx_len: usize,
        shared_len: usize,
        slack: f64,
    ) -> usize {
        let base = self.call_time_prefix(1, w + 1, ctx_len, shared_len);
        let mut rows = 1;
        while rows < Self::MAX_BUDGET_ROWS {
            let t = self.call_time_prefix(rows + 1, w + 1, ctx_len, shared_len);
            if t > base * slack.max(1.0) {
                break;
            }
            rows += 1;
        }
        rows
    }

    /// Fig. 1 quantity: slowdown of a (k, w) call relative to (1, 0).
    pub fn slowdown(&self, k_rows: usize, w: usize, ctx_len: usize) -> f64 {
        self.call_time(k_rows, w + 1, ctx_len) / self.call_time(1, 1, ctx_len)
    }

    /// Largest packed row count that stays (approximately) memory-bound at
    /// depth `w` and context `ctx_len`: the biggest `rows` whose
    /// [`Self::slowdown`] relative to a single row of the same depth is at
    /// most `slack` (e.g. 1.15 = "rows may cost at most 15% extra"). This
    /// is the online replacement for the operator's static `--budget` flag:
    /// while the verification call is memory-bound, extra rows are ~free
    /// (paper §3), so the budget should sit exactly at the phase-transition
    /// knee for the CURRENT context lengths — which shifts as sequences
    /// grow — rather than at a number picked at boot.
    ///
    /// The search is a linear scan capped at [`Self::MAX_BUDGET_ROWS`];
    /// wave quantization makes the slowdown curve only coarsely monotone,
    /// so the scan returns the last row count before the FIRST crossing,
    /// which is the conservative (never compute-bound) choice. Always
    /// returns at least 1.
    pub fn memory_bound_rows(&self, w: usize, ctx_len: usize, slack: f64) -> usize {
        let base = self.call_time(1, w + 1, ctx_len);
        let mut rows = 1;
        while rows < Self::MAX_BUDGET_ROWS {
            let t = self.call_time(rows + 1, w + 1, ctx_len);
            if t > base * slack.max(1.0) {
                break;
            }
            rows += 1;
        }
        rows
    }

    /// Upper bound of the [`Self::memory_bound_rows`] scan — far above any
    /// packed batch a real lane pool can produce, so the cap only guards
    /// against a pathological cost-model configuration.
    pub const MAX_BUDGET_ROWS: usize = 256;

    /// Simulated wall-time of a decode trace: per call, the (k, w) shape
    /// and context length; baseline = one (1, 0) call per emitted token.
    pub fn simulate_speedup(&self, calls: &[(usize, usize, usize)], tokens: usize) -> f64 {
        let spec: f64 = calls
            .iter()
            .map(|&(k, w, l)| self.call_time(k, w + 1, l))
            .sum();
        // greedy emits the same tokens one at a time with growing context
        let start_ctx = calls.first().map(|&(_, _, l)| l).unwrap_or(0);
        let greedy: f64 = (0..tokens)
            .map(|i| self.call_time(1, 1, start_ctx + i))
            .sum();
        greedy / spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm() -> CostModel {
        CostModel::new(Hardware::a100_40gb(), TxDims::mistral_7b())
    }

    #[test]
    fn single_token_call_is_memory_bound() {
        let m = cm();
        // (1,1) decode step ~ weights / bandwidth: 7B params * 2 bytes /
        // 1.555 TB/s ~ 9.3 ms; allow overheads
        let t = m.call_time(1, 1, 100);
        assert!(t > 5e-3 && t < 20e-3, "t = {t}");
    }

    #[test]
    fn small_blocks_are_nearly_free() {
        let m = cm();
        // paper Fig. 1 (l=100): modest (k, w) stays close to 1x
        let s = m.slowdown(5, 4, 100);
        assert!(s < 1.3, "slowdown {s}");
    }

    #[test]
    fn large_blocks_are_compute_bound() {
        let m = cm();
        let s = m.slowdown(32, 15, 500);
        assert!(s > 1.5, "slowdown {s}");
    }

    #[test]
    fn slowdown_monotone_in_k_coarsely() {
        let m = cm();
        let s1 = m.slowdown(1, 4, 100);
        let s32 = m.slowdown(32, 4, 100);
        assert!(s32 >= s1);
    }

    #[test]
    fn speedup_simulation_sane() {
        let m = cm();
        // 3 calls at (10, 10) each accepting ~3.3 tokens -> 10 tokens
        let calls = vec![(10, 10, 100), (10, 10, 104), (10, 10, 108)];
        let s = m.simulate_speedup(&calls, 10);
        assert!(s > 1.5 && s < 4.0, "speedup {s}");
    }

    #[test]
    fn memory_bound_rows_sits_at_the_knee() {
        let m = cm();
        let b = m.memory_bound_rows(10, 100, 1.15);
        // the derived budget must be a real batch (the whole point is that
        // rows are ~free while memory-bound) but must stop before the
        // compute-bound regime the large-block test pins down
        assert!(b >= 4, "budget {b} too small to be useful");
        assert!(b < CostModel::MAX_BUDGET_ROWS, "budget scan never found the knee");
        // one past the budget really does cross the slack threshold
        let base = m.call_time(1, 11, 100);
        assert!(m.call_time(b + 1, 11, 100) > base * 1.15);
        assert!(m.call_time(b, 11, 100) <= base * 1.15);
    }

    #[test]
    fn memory_bound_rows_shrinks_with_depth_and_context() {
        let m = cm();
        let shallow = m.memory_bound_rows(2, 100, 1.15);
        let deep = m.memory_bound_rows(14, 100, 1.15);
        assert!(deep <= shallow, "deep {deep} > shallow {shallow}");
        let short = m.memory_bound_rows(10, 50, 1.15);
        let long = m.memory_bound_rows(10, 2000, 1.15);
        assert!(long <= short, "long-ctx {long} > short-ctx {short}");
        assert!(long >= 1, "budget must floor at one row");
    }

    #[test]
    fn memory_bound_rows_monotone_in_slack() {
        let m = cm();
        let tight = m.memory_bound_rows(10, 100, 1.0);
        let loose = m.memory_bound_rows(10, 100, 1.5);
        assert!(tight <= loose);
        assert!(tight >= 1);
    }

    #[test]
    fn prefix_call_time_equals_plain_at_zero_shared() {
        let m = cm();
        // the zero-shared path must run the IDENTICAL GEMM inventory, so
        // the results are bitwise equal, not merely close
        for (k, w1, l) in [(1, 1, 50), (5, 5, 100), (32, 11, 1000)] {
            assert_eq!(m.call_time_prefix(k, w1, l, 0), m.call_time(k, w1, l));
        }
        assert_eq!(
            m.memory_bound_rows_shared(10, 100, 0, 1.15),
            m.memory_bound_rows(10, 100, 1.15)
        );
    }

    #[test]
    fn shared_prefix_lowers_call_time() {
        let m = cm();
        let plain = m.call_time(32, 11, 1000);
        let shared = m.call_time_prefix(32, 11, 1000, 896);
        assert!(shared < plain, "shared {shared} !< plain {plain}");
        // sharing MORE of the context never costs more
        let half = m.call_time_prefix(32, 11, 1000, 448);
        assert!(shared <= half, "shared {shared} > half {half}");
    }

    #[test]
    fn shared_prefix_raises_the_row_knee() {
        let m = cm();
        let plain = m.memory_bound_rows(10, 2000, 1.15);
        let shared = m.memory_bound_rows_shared(10, 2000, 1900, 1.15);
        assert!(shared >= plain, "shared knee {shared} < plain knee {plain}");
        assert!(shared >= 1);
    }

    #[test]
    fn otb_threshold_a100() {
        let t = Hardware::a100_40gb().otb_threshold();
        assert!((t - 200.6).abs() < 1.0, "threshold {t}");
    }
}
