//! # N-Grammys: learning-free batched speculative decoding
//!
//! Production-style reproduction of *"The N-Grammys: Accelerating
//! Autoregressive Inference with Learning-Free Batched Speculation"*
//! (Stewart et al., 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — serving coordinator: draft strategies
//!   ([`draft`]), batched guess-and-verify engine ([`engine`]), KV-cache
//!   management ([`kvcache`]), request scheduling ([`scheduler`]), HTTP
//!   serving ([`server`]), the accelerator cost model ([`costmodel`]) and
//!   the paper's bench harness ([`bench`]).
//! - **L2/L1 (python, build-time only)** — JAX transformer + Pallas
//!   kernels, AOT-lowered to HLO text and executed through [`runtime`]
//!   (PJRT CPU client). Python never runs on the request path.
//!
//! Start with [`engine::SpecDecoder`] or `examples/quickstart.rs`.

pub mod bench;
pub mod config;
pub mod costmodel;
pub mod draft;
pub mod engine;
pub mod kvcache;
pub mod metrics;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod tokenizer;
pub mod util;
pub mod workload;
