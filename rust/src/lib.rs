//! # N-Grammys: learning-free batched speculative decoding
//!
//! Production-style reproduction of *"The N-Grammys: Accelerating
//! Autoregressive Inference with Learning-Free Batched Speculation"*
//! (Stewart et al., 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — serving coordinator: draft strategies
//!   ([`draft`]), guess-and-verify engines ([`engine`]) — the per-sequence
//!   [`engine::SpecDecoder`] and the continuous-batching
//!   [`engine::BatchedEngine`] that verifies ALL active sequences in one
//!   packed call per step over a pooled KV cache — contiguous lanes
//!   ([`kvcache::KvPool`]) or refcounted pages with copy-on-write prefix
//!   sharing ([`kvcache::paged::PagedKvPool`]), byte-identical either
//!   way — KV-cache management ([`kvcache`]), request
//!   scheduling ([`scheduler`]), HTTP serving ([`server`]), the
//!   accelerator cost model ([`costmodel`]) and the paper's bench harness
//!   ([`bench`]).
//! - **L2/L1 (python, build-time only)** — JAX transformer + Pallas
//!   kernels, AOT-lowered to HLO text and executed through [`runtime`]
//!   behind the `pjrt` feature. Python never runs on the request path.
//!
//! Without the `pjrt` toolchain the crate runs on the deterministic
//! [`runtime::reference`] backend against the synthetic artifact tree
//! built by [`testkit`] — which is what makes a bare checkout build, test
//! and serve with zero external dependencies beyond `anyhow`.
//!
//! Start with [`engine::SpecDecoder`] or `examples/quickstart.rs`; for
//! cross-request batching see [`engine::batched::generate_all`] or
//! `ngrammys serve --batch N` (elastic by default: lane autoscaling +
//! cost-model-derived row budgets + scored admission — see
//! `rust/docs/ARCHITECTURE.md` for the full module map and data flow).

// Every public item carries rustdoc; CI runs `cargo doc --no-deps` with
// RUSTDOCFLAGS="-D warnings", so a missing doc or broken intra-doc link
// fails the build rather than rotting silently.
#![warn(missing_docs)]

pub mod adaptive;
pub mod bench;
pub mod config;
pub mod costmodel;
pub mod draft;
pub mod engine;
pub mod kvcache;
pub mod metrics;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod testkit;
pub mod tokenizer;
pub mod trace;
pub mod util;
pub mod workload;
