//! Extension beyond the paper (§6 future work / Lookahead-style): an
//! **online session n-gram cache**. Every verification call produces w+1
//! model-output tokens per row — most discarded by acceptance. Lookahead
//! decoding's insight is that those outputs are free training data for an
//! n-gram cache. This strategy accumulates (query -> continuation)
//! statistics from *all* accepted text across the session (not just the
//! current context window like `ContextNgram`), so acceptance keeps
//! improving over a serving session on repetitive workloads.
//!
//! Learning-free in the paper's sense: no gradient updates, no external
//! data — only counting what the base model already emitted (P1, P2, P3).

use std::collections::HashMap;

use super::{count_share, DraftBatch, DraftStrategy, StrategyKind};
use crate::tokenizer::TokenId;

/// (query token, continuation) statistics with LRU-ish bounding.
#[derive(Debug)]
pub struct SessionNgramCache {
    /// query token -> ranked continuations (token chain, count)
    table: HashMap<TokenId, Vec<(Vec<TokenId>, u32)>>,
    /// max continuations kept per query
    per_query: usize,
    /// max chain length stored
    max_chain: usize,
    /// total stored chains (for the size bound)
    stored: usize,
    cap: usize,
    /// rolling tail of the accepted stream awaiting ingestion
    tail: Vec<TokenId>,
}

impl SessionNgramCache {
    /// A cache bounded to `per_query` continuations per query token,
    /// `max_chain` tokens per chain and `cap` chains total.
    pub fn new(per_query: usize, max_chain: usize, cap: usize) -> Self {
        SessionNgramCache {
            table: HashMap::new(),
            per_query,
            max_chain,
            stored: 0,
            cap,
            tail: Vec::new(),
        }
    }

    /// Stored continuation chains.
    pub fn len(&self) -> usize {
        self.stored
    }

    /// Whether nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.stored == 0
    }

    /// Ingest a span of accepted text: for each position, record the
    /// following `max_chain` tokens under the query token.
    pub fn ingest(&mut self, span: &[TokenId]) {
        for i in 0..span.len().saturating_sub(1) {
            let q = span[i];
            let chain: Vec<TokenId> = span[i + 1..].iter().copied()
                .take(self.max_chain).collect();
            if chain.is_empty() {
                continue;
            }
            let entry = self.table.entry(q).or_default();
            if let Some(e) = entry.iter_mut().find(|(c, _)| {
                c.starts_with(&chain) || chain.starts_with(c)
            }) {
                // extend to the longer chain, bump the count
                if chain.len() > e.0.len() {
                    e.0 = chain;
                }
                e.1 += 1;
            } else if entry.len() < self.per_query && self.stored < self.cap {
                entry.push((chain, 1));
                self.stored += 1;
            }
            entry.sort_by(|a, b| b.1.cmp(&a.1));
        }
    }
}

impl DraftStrategy for SessionNgramCache {
    fn name(&self) -> &'static str {
        "session-ngram-cache"
    }

    fn propose(&mut self, seq: &[TokenId], k: usize, batch: &mut DraftBatch) {
        let Some(&cur) = seq.last() else { return };
        let w = batch.w;
        if let Some(conts) = self.table.get(&cur) {
            let total: u32 = conts.iter().map(|(_, c)| *c).sum();
            for (rank, (chain, count)) in conts.iter().enumerate() {
                if batch.is_full(k) {
                    break;
                }
                batch.push_conf(
                    chain.iter().copied().take(w).collect(),
                    StrategyKind::SessionCache,
                    rank,
                    count_share(*count, total),
                );
            }
        }
    }

    fn observe(&mut self, accepted: &[TokenId], _model_out: &[TokenId]) {
        // ingest with one token of overlap so cross-step bigrams are seen
        self.tail.extend_from_slice(accepted);
        if self.tail.len() > self.max_chain + 1 {
            let span: Vec<TokenId> = self.tail.clone();
            self.ingest(&span);
            let keep = self.max_chain.min(self.tail.len());
            self.tail.drain(..self.tail.len() - keep);
        }
    }

    fn reset(&mut self) {
        // deliberately KEEP the table across sequences — that is the point
        // of a session cache; only the rolling tail is per-sequence.
        self.tail.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learns_and_proposes_continuations() {
        let mut c = SessionNgramCache::new(4, 4, 1000);
        c.ingest(&[1, 2, 3, 4, 1, 2, 3, 9]);
        let mut b = DraftBatch::new(3);
        c.propose(&[7, 1], 2, &mut b);
        assert!(b.k() >= 1);
        assert_eq!(&b.rows[0].tokens[..2], &[2, 3]);
    }

    #[test]
    fn counts_rank_frequent_continuations_first() {
        let mut c = SessionNgramCache::new(4, 2, 1000);
        c.ingest(&[5, 7, 0, 5, 7, 0, 5, 8]);
        let mut b = DraftBatch::new(2);
        c.propose(&[5], 2, &mut b);
        assert_eq!(b.rows[0].tokens[0], 7); // seen twice
    }

    #[test]
    fn survives_reset_but_clears_tail() {
        let mut c = SessionNgramCache::new(4, 2, 1000);
        c.observe(&[1, 2, 3, 4, 5], &[]);
        let before = c.len();
        assert!(before > 0);
        c.reset();
        assert_eq!(c.len(), before, "table must persist across sequences");
    }

    #[test]
    fn respects_capacity() {
        let mut c = SessionNgramCache::new(64, 2, 10);
        let span: Vec<u32> = (0..200).collect();
        c.ingest(&span);
        assert!(c.len() <= 10);
    }

    #[test]
    fn empty_cache_proposes_nothing() {
        let mut c = SessionNgramCache::new(4, 4, 100);
        let mut b = DraftBatch::new(3);
        c.propose(&[1, 2], 4, &mut b);
        assert_eq!(b.k(), 0);
    }
}
