//! Extension beyond the paper (§6 future work / Lookahead-style): an
//! **online session n-gram cache**. Every verification call produces w+1
//! model-output tokens per row — most discarded by acceptance. Lookahead
//! decoding's insight is that those outputs are free training data for an
//! n-gram cache. This strategy accumulates (query -> continuation)
//! statistics from *all* accepted text across the session (not just the
//! current context window like `ContextNgram`), so acceptance keeps
//! improving over a serving session on repetitive workloads.
//!
//! Learning-free in the paper's sense: no gradient updates, no external
//! data — only counting what the base model already emitted (P1, P2, P3).
//!
//! Hot-path discipline: ingestion bumps counts **in place** and restores
//! the count-descending order by bubbling the bumped entry up to its
//! ranked position (byte-identical to the seed's full re-sort, at O(moved
//! entries) instead of O(list log list) per observation), chains are
//! matched against the span by slice comparison (no per-position clone),
//! and `observe` ingests the rolling tail without copying it — so a
//! saturated cache learns and proposes with zero heap allocations.

use std::collections::HashMap;

use super::{count_share, DraftBatch, DraftStrategy, StrategyKind};
use crate::tokenizer::TokenId;

/// (query token, continuation) statistics with LRU-ish bounding.
#[derive(Debug)]
pub struct SessionNgramCache {
    /// query token -> continuations (token chain, count), kept sorted by
    /// count descending (stable w.r.t. insertion order on ties)
    table: HashMap<TokenId, Vec<(Vec<TokenId>, u32)>>,
    /// max continuations kept per query
    per_query: usize,
    /// max chain length stored
    max_chain: usize,
    /// total stored chains (for the size bound)
    stored: usize,
    cap: usize,
    /// rolling tail of the accepted stream awaiting ingestion
    tail: Vec<TokenId>,
}

impl SessionNgramCache {
    /// A cache bounded to `per_query` continuations per query token,
    /// `max_chain` tokens per chain and `cap` chains total.
    pub fn new(per_query: usize, max_chain: usize, cap: usize) -> Self {
        SessionNgramCache {
            table: HashMap::new(),
            per_query,
            max_chain,
            stored: 0,
            cap,
            tail: Vec::new(),
        }
    }

    /// Stored continuation chains.
    pub fn len(&self) -> usize {
        self.stored
    }

    /// Whether nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.stored == 0
    }

    /// Ingest a span of accepted text: for each position, record the
    /// following `max_chain` tokens under the query token. Existing
    /// chains are updated in place (count bump + ranked re-insertion);
    /// only genuinely new chains allocate.
    pub fn ingest(&mut self, span: &[TokenId]) {
        for i in 0..span.len().saturating_sub(1) {
            let q = span[i];
            let end = span.len().min(i + 1 + self.max_chain);
            let chain = &span[i + 1..end];
            if chain.is_empty() {
                continue;
            }
            let entry = self.table.entry(q).or_default();
            if let Some(idx) = entry
                .iter()
                .position(|(c, _)| c.starts_with(chain) || chain.starts_with(c))
            {
                // extend to the longer chain (in place), bump the count
                if chain.len() > entry[idx].0.len() {
                    entry[idx].0.clear();
                    entry[idx].0.extend_from_slice(chain);
                }
                entry[idx].1 += 1;
                // restore count-descending order: bubble the bumped entry
                // up past every entry its new count now beats — exactly
                // where the seed's stable re-sort would put it
                let mut j = idx;
                while j > 0 && entry[j - 1].1 < entry[j].1 {
                    entry.swap(j - 1, j);
                    j -= 1;
                }
            } else if entry.len() < self.per_query && self.stored < self.cap {
                // count 1 ranks at the tail: sorted order is preserved
                entry.push((chain.to_vec(), 1));
                self.stored += 1;
            }
        }
    }
}

impl DraftStrategy for SessionNgramCache {
    fn name(&self) -> &'static str {
        "session-ngram-cache"
    }

    fn propose(&mut self, seq: &[TokenId], k: usize, batch: &mut DraftBatch) {
        let Some(&cur) = seq.last() else { return };
        let w = batch.w;
        if let Some(conts) = self.table.get(&cur) {
            let total: u32 = conts.iter().map(|(_, c)| *c).sum();
            for (rank, (chain, count)) in conts.iter().enumerate() {
                if batch.is_full(k) {
                    break;
                }
                batch.push_conf(
                    &chain[..chain.len().min(w)],
                    StrategyKind::SessionCache,
                    rank,
                    count_share(*count, total),
                );
            }
        }
    }

    fn observe(&mut self, accepted: &[TokenId], _model_out: &[TokenId]) {
        // ingest with one token of overlap so cross-step bigrams are seen
        self.tail.extend_from_slice(accepted);
        if self.tail.len() > self.max_chain + 1 {
            // ingest the tail in place: move it out (Vec::new allocates
            // nothing), ingest, put it back — no clone of the rolling tail
            let tail = std::mem::take(&mut self.tail);
            self.ingest(&tail);
            self.tail = tail;
            let keep = self.max_chain.min(self.tail.len());
            let cut = self.tail.len() - keep;
            self.tail.drain(..cut);
        }
    }

    fn reset(&mut self) {
        // deliberately KEEP the table across sequences — that is the point
        // of a session cache; only the rolling tail is per-sequence.
        self.tail.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learns_and_proposes_continuations() {
        let mut c = SessionNgramCache::new(4, 4, 1000);
        c.ingest(&[1, 2, 3, 4, 1, 2, 3, 9]);
        let mut b = DraftBatch::new(3);
        c.propose(&[7, 1], 2, &mut b);
        assert!(b.k() >= 1);
        assert_eq!(&b.row_tokens(0)[..2], &[2, 3]);
    }

    #[test]
    fn counts_rank_frequent_continuations_first() {
        let mut c = SessionNgramCache::new(4, 2, 1000);
        c.ingest(&[5, 7, 0, 5, 7, 0, 5, 8]);
        let mut b = DraftBatch::new(2);
        c.propose(&[5], 2, &mut b);
        assert_eq!(b.row_tokens(0)[0], 7); // seen twice
    }

    #[test]
    fn bumped_entries_keep_stable_ranked_order() {
        // three distinct continuations of 5, then one gets re-observed:
        // it must move ahead of the count-1 entries but keep the original
        // order among the entries it ties with
        let mut c = SessionNgramCache::new(8, 2, 1000);
        c.ingest(&[5, 1, 5, 2, 5, 3]); // query 5: chains [1,5], [2,5], [3], each count 1
        let mut b = DraftBatch::new(2);
        c.propose(&[5], 8, &mut b);
        let first_before = b.row_tokens(0).to_vec();
        // re-observe the LAST-ranked continuation twice so it outranks all
        c.ingest(&[5, 3, 0, 5, 3, 0]);
        let mut b2 = DraftBatch::new(2);
        c.propose(&[5], 8, &mut b2);
        assert_eq!(b2.row_tokens(0)[0], 3, "bumped entry must rise to the top");
        assert_ne!(first_before[0], 3, "top entry actually changed");
    }

    #[test]
    fn survives_reset_but_clears_tail() {
        let mut c = SessionNgramCache::new(4, 2, 1000);
        c.observe(&[1, 2, 3, 4, 5], &[]);
        let before = c.len();
        assert!(before > 0);
        c.reset();
        assert_eq!(c.len(), before, "table must persist across sequences");
    }

    #[test]
    fn respects_capacity() {
        let mut c = SessionNgramCache::new(64, 2, 10);
        let span: Vec<u32> = (0..200).collect();
        c.ingest(&span);
        assert!(c.len() <= 10);
    }

    #[test]
    fn empty_cache_proposes_nothing() {
        let mut c = SessionNgramCache::new(4, 4, 100);
        let mut b = DraftBatch::new(3);
        c.propose(&[1, 2], 4, &mut b);
        assert_eq!(b.k(), 0);
    }
}
