//! Persistent per-sequence suffix index for context-derived N-grams.
//!
//! The seed `ContextNgram` re-scanned the whole sequence and rebuilt a
//! `HashMap` of windows on EVERY proposal — O(context) hashing and heap
//! allocation per decode step per lane, which is exactly the cost the
//! paper's "negligible-cost drafting" premise forbids. [`SuffixIndex`]
//! replaces the rescan with posting lists maintained *online*:
//!
//! - **Key**: the `q`-token window `seq[i..i + q]` (the paper's query
//!   length; q = 1 in the headline configuration).
//! - **Posting list**: every start position `i` at which that window
//!   occurs, in ascending order.
//! - **Append**: pushing one accepted token adds exactly one new window
//!   (the one ending at the new token) — O(1) amortised, allocation-free
//!   once the key has been seen before.
//! - **Rollback**: [`SuffixIndex::truncate`] removes the windows that
//!   overlap the rolled-back suffix by popping each affected posting
//!   list's tail (positions are appended in ascending order, so the
//!   victim is always the last element) — O(rolled-back tokens).
//! - **Sync**: [`SuffixIndex::sync`] reconciles the index with an
//!   arbitrary caller-supplied sequence: a prefix equality check (one
//!   vectorised word-compare over the common prefix — no hashing, no
//!   allocation) confirms the common case of pure extension; divergence
//!   rolls back to the longest common prefix and re-appends. This is
//!   what keeps the stateless `DraftStrategy::propose(&seq, ..)`
//!   contract safe even for callers that hand the strategy a completely
//!   different sequence.
//!
//! A proposal then costs one O(context) prefix memcmp (the sync guard —
//! a straight-line word-compare, deliberately kept so byte-identity
//! never rests on trusting the caller; ~2 KB at this repo's 512-token
//! max context, orders of magnitude cheaper than the seed's per-window
//! hashing and allocation over the same span) plus O(#matches) to
//! gather candidate positions and O(m log m) to rank the m distinct
//! continuations — while reproducing the seed rescan's
//! count-desc/recency-desc/lexicographic ranking byte-identically
//! (property-tested in `rust/tests/draft_equiv.rs`). Contexts far
//! beyond this repo's artifact limits would want a bounded guard
//! (length/generation stamp) instead of the full memcmp.

use std::collections::HashMap;

use crate::tokenizer::TokenId;

/// Incrementally maintained posting lists over one token sequence's
/// `q`-token windows (see the module docs for the cost model).
#[derive(Debug)]
pub struct SuffixIndex {
    /// window length (the paper's q)
    q: usize,
    /// the ingested sequence (the index's own copy; `sync` diffs the
    /// caller's sequence against it)
    tokens: Vec<TokenId>,
    /// window content -> ascending start positions. Emptied lists are
    /// kept so their allocations (key and list) are reused when the same
    /// window reappears after a rollback.
    postings: HashMap<Vec<TokenId>, Vec<u32>>,
}

impl SuffixIndex {
    /// An empty index over `q`-token windows (`q >= 1`).
    pub fn new(q: usize) -> Self {
        assert!(q >= 1, "window length must be at least 1");
        SuffixIndex { q, tokens: Vec::new(), postings: HashMap::new() }
    }

    /// Window length this index was built with.
    pub fn q(&self) -> usize {
        self.q
    }

    /// Tokens ingested so far.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    /// Whether nothing has been ingested.
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// The ingested sequence (what `sync` last reconciled against).
    pub fn tokens(&self) -> &[TokenId] {
        &self.tokens
    }

    /// Drop all state (between requests). Allocation capacity is NOT
    /// kept: a new request shares no windows with the old one, so stale
    /// keys would only pin memory.
    pub fn clear(&mut self) {
        self.tokens.clear();
        self.postings.clear();
    }

    /// Ingest one appended token: registers the single new window that
    /// ends at it. O(1) amortised; allocates only when the window content
    /// has never been seen before.
    pub fn append(&mut self, t: TokenId) {
        self.tokens.push(t);
        let n = self.tokens.len();
        if n < self.q {
            return;
        }
        let i = n - self.q;
        if let Some(list) = self.postings.get_mut(&self.tokens[i..n]) {
            list.push(i as u32);
            return;
        }
        self.postings.insert(self.tokens[i..n].to_vec(), vec![i as u32]);
    }

    /// Roll the index back to its first `new_len` tokens (rejected
    /// speculation, or a caller switching to a diverging sequence):
    /// every window overlapping the removed suffix is unregistered by
    /// popping its posting list's tail. O(removed tokens).
    pub fn truncate(&mut self, new_len: usize) {
        let n = self.tokens.len();
        if new_len >= n {
            return;
        }
        if n >= self.q {
            // valid window starts are 0..=n-q; a window [i, i+q) survives
            // the truncation iff i + q <= new_len
            let last = n - self.q;
            let first = (new_len + 1).saturating_sub(self.q);
            // remove in descending start order so each affected posting
            // list's LAST element is always the position being removed
            for i in (first..=last).rev() {
                let key = &self.tokens[i..i + self.q];
                if let Some(list) = self.postings.get_mut(key) {
                    debug_assert_eq!(list.last().copied(), Some(i as u32));
                    list.pop();
                }
            }
        }
        self.tokens.truncate(new_len);
    }

    /// Reconcile the index with `seq`: extend in place when `seq` extends
    /// the ingested sequence (the decode-loop common case — one cheap
    /// prefix word-compare, then O(new tokens) appends), otherwise roll
    /// back to the longest common prefix and re-ingest the rest.
    pub fn sync(&mut self, seq: &[TokenId]) {
        let n = self.tokens.len().min(seq.len());
        if self.tokens[..n] == seq[..n] {
            if self.tokens.len() > seq.len() {
                self.truncate(seq.len());
            }
        } else {
            let mut common = 0;
            while common < n && self.tokens[common] == seq[common] {
                common += 1;
            }
            self.truncate(common);
        }
        let start = self.tokens.len();
        for &t in &seq[start..] {
            self.append(t);
        }
    }

    /// Ascending start positions whose window equals `window` (empty when
    /// the window was never ingested). `window.len()` must be `q`.
    pub fn positions(&self, window: &[TokenId]) -> &[u32] {
        debug_assert_eq!(window.len(), self.q);
        self.postings.get(window).map(|v| v.as_slice()).unwrap_or(&[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn positions_of(ix: &SuffixIndex, window: &[TokenId]) -> Vec<u32> {
        ix.positions(window).to_vec()
    }

    #[test]
    fn append_registers_every_window() {
        let mut ix = SuffixIndex::new(2);
        for t in [1, 2, 1, 2, 3] {
            ix.append(t);
        }
        assert_eq!(positions_of(&ix, &[1, 2]), vec![0, 2]);
        assert_eq!(positions_of(&ix, &[2, 1]), vec![1]);
        assert_eq!(positions_of(&ix, &[2, 3]), vec![3]);
        assert_eq!(positions_of(&ix, &[3, 1]), Vec::<u32>::new());
        assert_eq!(ix.len(), 5);
    }

    #[test]
    fn truncate_unregisters_overlapping_windows() {
        let mut ix = SuffixIndex::new(2);
        for t in [1, 2, 1, 2, 3] {
            ix.append(t);
        }
        ix.truncate(3); // keep [1, 2, 1]
        assert_eq!(positions_of(&ix, &[1, 2]), vec![0]);
        assert_eq!(positions_of(&ix, &[2, 1]), vec![1]);
        assert_eq!(positions_of(&ix, &[2, 3]), Vec::<u32>::new());
        assert_eq!(ix.len(), 3);
        // re-appending after a rollback re-registers cleanly
        ix.append(9);
        assert_eq!(positions_of(&ix, &[1, 9]), vec![2]);
    }

    #[test]
    fn truncate_below_q_empties_everything() {
        let mut ix = SuffixIndex::new(3);
        for t in [4, 5, 6, 7] {
            ix.append(t);
        }
        ix.truncate(2);
        assert_eq!(positions_of(&ix, &[4, 5, 6]), Vec::<u32>::new());
        assert_eq!(positions_of(&ix, &[5, 6, 7]), Vec::<u32>::new());
        assert_eq!(ix.len(), 2);
    }

    #[test]
    fn sync_extends_rolls_back_and_rebuilds() {
        let mut ix = SuffixIndex::new(1);
        ix.sync(&[1, 2, 3]);
        assert_eq!(ix.tokens(), &[1, 2, 3]);
        // pure extension
        ix.sync(&[1, 2, 3, 4]);
        assert_eq!(positions_of(&ix, &[4]), vec![3]);
        // pure rollback
        ix.sync(&[1, 2]);
        assert_eq!(positions_of(&ix, &[3]), Vec::<u32>::new());
        assert_eq!(positions_of(&ix, &[4]), Vec::<u32>::new());
        // divergence: rollback to the common prefix, then re-ingest
        ix.sync(&[1, 9, 9]);
        assert_eq!(ix.tokens(), &[1, 9, 9]);
        assert_eq!(positions_of(&ix, &[9]), vec![1, 2]);
        assert_eq!(positions_of(&ix, &[2]), Vec::<u32>::new());
    }

    #[test]
    fn sync_against_empty_and_short_sequences() {
        let mut ix = SuffixIndex::new(2);
        ix.sync(&[7]);
        assert_eq!(ix.len(), 1);
        assert!(ix.positions(&[7, 7]).is_empty());
        ix.sync(&[]);
        assert!(ix.is_empty());
    }

    #[test]
    fn random_trajectories_match_a_fresh_rebuild() {
        use crate::util::{prop, rng::Rng};
        prop::check(200, |rng: &mut Rng| {
            let q = rng.range(1, 3);
            let vocab = rng.range(2, 6);
            let mut ix = SuffixIndex::new(q);
            let mut shadow: Vec<TokenId> = Vec::new();
            for _ in 0..rng.range(3, 20) {
                if rng.f64() < 0.65 || shadow.is_empty() {
                    for _ in 0..rng.range(1, 6) {
                        let t = rng.below(vocab) as TokenId;
                        shadow.push(t);
                    }
                } else {
                    let keep = rng.below(shadow.len());
                    shadow.truncate(keep);
                }
                ix.sync(&shadow);
                // compare every window's postings against a rebuild
                let mut fresh = SuffixIndex::new(q);
                fresh.sync(&shadow);
                if ix.tokens() != shadow.as_slice() {
                    return false;
                }
                if shadow.len() >= q {
                    for i in 0..=shadow.len() - q {
                        let win = &shadow[i..i + q];
                        if ix.positions(win) != fresh.positions(win) {
                            return false;
                        }
                    }
                }
            }
            true
        });
    }
}
