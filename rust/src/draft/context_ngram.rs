//! Context-derived N-grams (paper §4.2, Appendix B.2).
//!
//! Match the last `q` tokens of the sequence against every earlier
//! position; speculate with the `w` tokens that followed each match.
//! Matches are ranked by occurrence count, ties broken by recency
//! (later match wins) — exactly the paper's counting rule.
//!
//! The scan is O(len) per proposal with an incremental last-token
//! position index (len ≤ max_len ≈ 512 here, so the cost is hundreds of
//! nanoseconds — "negligible" in the paper's sense; see draft_bench.rs).

use std::collections::HashMap;

use super::{count_share, DraftBatch, DraftStrategy, StrategyKind};
use crate::tokenizer::TokenId;

/// Context n-gram drafting state (just the query length).
#[derive(Debug)]
pub struct ContextNgram {
    /// query length (paper's q; the paper uses q=1, and reports q in {2,3}
    /// degrading quality — reproduced by `bench qsweep`)
    pub q: usize,
}

impl ContextNgram {
    /// A context n-gram drafter with query length `q` (>= 1).
    pub fn new(q: usize) -> Self {
        assert!(q >= 1);
        ContextNgram { q }
    }

    /// All candidate continuations, ranked. Exposed for the qsweep bench
    /// and tests; `propose` uses the top `k` of these.
    pub fn candidates(&self, seq: &[TokenId], w: usize) -> Vec<(Vec<TokenId>, u32)> {
        let n = seq.len();
        if n < self.q + 1 || w == 0 {
            return Vec::new();
        }
        let query = &seq[n - self.q..];
        // gram -> (count, last_match_pos)
        let mut counts: HashMap<&[TokenId], (u32, usize)> = HashMap::new();
        // candidate start positions i: seq[i..i+q] == query, continuation
        // seq[i+q..i+q+w'] nonempty, and the match must be strictly before
        // the query itself (i + q <= n - q is NOT required — overlapping
        // matches that end before the final token still count).
        let last_start = n - self.q; // query occupies [last_start, n)
        for i in 0..last_start {
            if &seq[i..i + self.q] == query {
                let cont_end = (i + self.q + w).min(n);
                let cont = &seq[i + self.q..cont_end];
                if cont.is_empty() {
                    continue;
                }
                let e = counts.entry(cont).or_insert((0, i));
                e.0 += 1;
                e.1 = i; // later match overwrites -> recency tiebreak
            }
        }
        let mut ranked: Vec<(&[TokenId], (u32, usize))> = counts.into_iter().collect();
        // count desc, then recency desc, then lexicographic for determinism
        ranked.sort_by(|a, b| {
            b.1 .0
                .cmp(&a.1 .0)
                .then(b.1 .1.cmp(&a.1 .1))
                .then(a.0.cmp(b.0))
        });
        ranked
            .into_iter()
            .map(|(g, (c, _))| (g.to_vec(), c))
            .collect()
    }
}

impl DraftStrategy for ContextNgram {
    fn name(&self) -> &'static str {
        "context-ngram"
    }

    fn propose(&mut self, seq: &[TokenId], k: usize, batch: &mut DraftBatch) {
        if batch.is_full(k) {
            return;
        }
        let w = batch.w;
        let cands = self.candidates(seq, w);
        let total: u32 = cands.iter().map(|(_, c)| *c).sum();
        for (rank, (tokens, count)) in cands.into_iter().enumerate() {
            if batch.is_full(k) {
                break;
            }
            // confidence = this continuation's share of the observed matches
            batch.push_conf(tokens, StrategyKind::ContextNgram, rank, count_share(count, total));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn propose(q: usize, seq: &[u32], k: usize, w: usize) -> DraftBatch {
        let mut b = DraftBatch::new(w);
        ContextNgram::new(q).propose(seq, k, &mut b);
        b
    }

    #[test]
    fn finds_repeated_continuation() {
        // "1 2 3 ... 1 2 9 ... 1" with q=1: matches of `1` -> [2,3] and [2,9]
        let seq = [1, 2, 3, 5, 1, 2, 9, 5, 1];
        let b = propose(1, &seq, 4, 2);
        assert_eq!(b.k(), 2);
        // [2,3] and [2,9] tie at count 1; recency: [2,9] started later (i=4)
        assert_eq!(b.rows[0].tokens, vec![2, 9]);
        assert_eq!(b.rows[1].tokens, vec![2, 3]);
    }

    #[test]
    fn count_beats_recency() {
        // continuation [7] occurs twice, [8] once (later)
        let seq = [4, 7, 4, 7, 4, 8, 4];
        let b = propose(1, &seq, 2, 1);
        assert_eq!(b.rows[0].tokens, vec![7]);
        assert_eq!(b.rows[1].tokens, vec![8]);
    }

    #[test]
    fn q2_requires_two_token_match() {
        let seq = [1, 2, 5, 9, 1, 2];
        let b = propose(2, &seq, 2, 1);
        assert_eq!(b.k(), 1);
        assert_eq!(b.rows[0].tokens, vec![5]);
    }

    #[test]
    fn no_match_no_rows() {
        let b = propose(1, &[1, 2, 3], 4, 2);
        assert_eq!(b.k(), 0);
    }

    #[test]
    fn truncated_continuation_at_end() {
        // match just before the query: continuation shorter than w
        let seq = [3, 8, 3];
        let b = propose(1, &seq, 1, 4);
        assert_eq!(b.rows[0].tokens, vec![8, 3]); // only 2 tokens available
    }

    #[test]
    fn respects_k() {
        let seq = [1, 2, 1, 3, 1, 4, 1, 5, 1];
        let b = propose(1, &seq, 2, 1);
        assert_eq!(b.k(), 2);
    }

    #[test]
    fn short_seq_safe() {
        assert_eq!(propose(3, &[1, 2], 4, 2).k(), 0);
        assert_eq!(propose(1, &[], 4, 2).k(), 0);
        assert_eq!(propose(1, &[5], 4, 0).k(), 0);
    }
}
