//! Context-derived N-grams (paper §4.2, Appendix B.2).
//!
//! Match the last `q` tokens of the sequence against every earlier
//! position; speculate with the `w` tokens that followed each match.
//! Matches are ranked by occurrence count, ties broken by recency
//! (later match wins) — exactly the paper's counting rule.
//!
//! Proposals run off a persistent [`SuffixIndex`]: posting lists keyed by
//! the `q`-token window, maintained incrementally as accepted tokens are
//! appended (O(1) amortised per token, with truncate-on-rollback), so one
//! proposal costs a single flat prefix memcmp (the index's sync guard;
//! see `index.rs` for why it is kept) plus O(#matches) to gather
//! candidates and O(m log m) to rank the m distinct continuations —
//! instead of the seed's rescan that re-hashed every context window into
//! a fresh `HashMap` with per-candidate heap allocations on every decode
//! step. The ranking is byte-identical to the seed rescan (kept below as
//! [`reference_candidates`], the property-test oracle and the
//! `bench draft` comparison baseline).

use std::collections::HashMap;

use super::index::SuffixIndex;
use super::{count_share, DraftBatch, DraftStrategy, StrategyKind};
use crate::tokenizer::TokenId;

/// One ranked candidate group: a distinct continuation and its evidence.
#[derive(Debug, Clone, Copy)]
pub(crate) struct CtxGroup {
    /// occurrences of this continuation after the query
    pub count: u32,
    /// latest match start position (the recency tie-break)
    pub last: u32,
    /// one representative match start (the continuation's tokens are
    /// `seq[rep + q .. min(rep + q + w, len)]`)
    pub rep: u32,
}

/// Context n-gram drafting state: the query length plus the persistent
/// suffix index and reusable ranking scratch.
#[derive(Debug)]
pub struct ContextNgram {
    /// query length (the paper's q)
    q: usize,
    index: SuffixIndex,
    /// candidate match positions for the current proposal (reused)
    pos_scratch: Vec<u32>,
    /// ranked candidate groups for the current proposal (reused)
    groups: Vec<CtxGroup>,
}

impl ContextNgram {
    /// A context n-gram drafter with query length `q` (>= 1; the paper
    /// uses q=1 and reports q in {2,3} degrading quality — reproduced by
    /// `bench qsweep`).
    pub fn new(q: usize) -> Self {
        assert!(q >= 1);
        ContextNgram {
            q,
            index: SuffixIndex::new(q),
            pos_scratch: Vec::new(),
            groups: Vec::new(),
        }
    }

    /// Query length (the paper's q).
    pub fn q(&self) -> usize {
        self.q
    }

    /// Sync the index with `seq` and rebuild the ranked candidate groups
    /// for depth `w` into the reusable scratch. Returns the total number
    /// of matches (the confidence normalizer); 0 means no candidates.
    /// Allocation-free once the scratch and posting lists are warm.
    pub(crate) fn refresh(&mut self, seq: &[TokenId], w: usize) -> u32 {
        self.pos_scratch.clear();
        self.groups.clear();
        let n = seq.len();
        if n < self.q + 1 || w == 0 {
            // keep the index in step even on degenerate calls so the next
            // real proposal still extends incrementally
            self.index.sync(seq);
            return 0;
        }
        self.index.sync(seq);
        let q = self.q;
        let last_start = (n - q) as u32;
        let query = &seq[n - q..];
        self.pos_scratch.extend(
            self.index
                .positions(query)
                .iter()
                .copied()
                .filter(|&i| i < last_start),
        );
        if self.pos_scratch.is_empty() {
            return 0;
        }
        // continuation of a match starting at i (possibly truncated at the
        // end of the sequence, exactly like the seed rescan)
        let cont = |i: u32| {
            let s = i as usize + q;
            &seq[s..(s + w).min(n)]
        };
        // group equal continuations: sort positions by continuation
        // content, then walk runs
        self.pos_scratch.sort_unstable_by(|&a, &b| cont(a).cmp(cont(b)));
        let total = self.pos_scratch.len() as u32;
        let mut i = 0;
        while i < self.pos_scratch.len() {
            let rep = self.pos_scratch[i];
            let mut last = rep;
            let mut j = i + 1;
            while j < self.pos_scratch.len() && cont(self.pos_scratch[j]) == cont(rep) {
                last = last.max(self.pos_scratch[j]);
                j += 1;
            }
            self.groups.push(CtxGroup { count: (j - i) as u32, last, rep });
            i = j;
        }
        // count desc, then recency desc, then lexicographic for
        // determinism — the seed rescan's exact ordering (total: distinct
        // groups can never tie on content)
        self.groups.sort_unstable_by(|a, b| {
            b.count
                .cmp(&a.count)
                .then(b.last.cmp(&a.last))
                .then(cont(a.rep).cmp(cont(b.rep)))
        });
        total
    }

    /// The ranked candidate groups produced by the latest
    /// [`Self::refresh`] (consumed by [`super::MixedStrategy`]).
    pub(crate) fn ranked(&self) -> &[CtxGroup] {
        &self.groups
    }

    /// All candidate continuations, ranked. Exposed for benches and
    /// tests; `propose` streams the same groups into the batch without
    /// materializing them.
    pub fn candidates(&mut self, seq: &[TokenId], w: usize) -> Vec<(Vec<TokenId>, u32)> {
        self.refresh(seq, w);
        let n = seq.len();
        let q = self.q;
        self.groups
            .iter()
            .map(|g| {
                let s = g.rep as usize + q;
                (seq[s..(s + w).min(n)].to_vec(), g.count)
            })
            .collect()
    }
}

impl DraftStrategy for ContextNgram {
    fn name(&self) -> &'static str {
        "context-ngram"
    }

    fn propose(&mut self, seq: &[TokenId], k: usize, batch: &mut DraftBatch) {
        if batch.is_full(k) {
            return;
        }
        let w = batch.w;
        let total = self.refresh(seq, w);
        if total == 0 {
            return;
        }
        let n = seq.len();
        let q = self.q;
        for (rank, g) in self.groups.iter().enumerate() {
            if batch.is_full(k) {
                break;
            }
            let s = g.rep as usize + q;
            // confidence = this continuation's share of the observed matches
            batch.push_conf(
                &seq[s..(s + w).min(n)],
                StrategyKind::ContextNgram,
                rank,
                count_share(g.count, total),
            );
        }
    }

    fn reset(&mut self) {
        self.index.clear();
        self.pos_scratch.clear();
        self.groups.clear();
    }
}

/// The seed implementation, preserved verbatim as the specification
/// oracle: a full O(context) rescan that rebuilds a window `HashMap` per
/// call. `ContextNgram` must reproduce its output byte-identically
/// (`rust/tests/draft_equiv.rs`); `bench draft` measures the incremental
/// path against it.
pub fn reference_candidates(q: usize, seq: &[TokenId], w: usize) -> Vec<(Vec<TokenId>, u32)> {
    let n = seq.len();
    if n < q + 1 || w == 0 {
        return Vec::new();
    }
    let query = &seq[n - q..];
    // gram -> (count, last_match_pos)
    let mut counts: HashMap<&[TokenId], (u32, usize)> = HashMap::new();
    // candidate start positions i: seq[i..i+q] == query, continuation
    // seq[i+q..i+q+w'] nonempty, and the match must be strictly before
    // the query itself (i + q <= n - q is NOT required — overlapping
    // matches that end before the final token still count).
    let last_start = n - q; // query occupies [last_start, n)
    for i in 0..last_start {
        if &seq[i..i + q] == query {
            let cont_end = (i + q + w).min(n);
            let cont = &seq[i + q..cont_end];
            if cont.is_empty() {
                continue;
            }
            let e = counts.entry(cont).or_insert((0, i));
            e.0 += 1;
            e.1 = i; // later match overwrites -> recency tiebreak
        }
    }
    let mut ranked: Vec<(&[TokenId], (u32, usize))> = counts.into_iter().collect();
    // count desc, then recency desc, then lexicographic for determinism
    ranked.sort_by(|a, b| {
        b.1 .0
            .cmp(&a.1 .0)
            .then(b.1 .1.cmp(&a.1 .1))
            .then(a.0.cmp(b.0))
    });
    ranked
        .into_iter()
        .map(|(g, (c, _))| (g.to_vec(), c))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn propose(q: usize, seq: &[u32], k: usize, w: usize) -> DraftBatch {
        let mut b = DraftBatch::new(w);
        ContextNgram::new(q).propose(seq, k, &mut b);
        b
    }

    #[test]
    fn finds_repeated_continuation() {
        // "1 2 3 ... 1 2 9 ... 1" with q=1: matches of `1` -> [2,3] and [2,9]
        let seq = [1, 2, 3, 5, 1, 2, 9, 5, 1];
        let b = propose(1, &seq, 4, 2);
        assert_eq!(b.k(), 2);
        // [2,3] and [2,9] tie at count 1; recency: [2,9] started later (i=4)
        assert_eq!(b.row_tokens(0), vec![2, 9]);
        assert_eq!(b.row_tokens(1), vec![2, 3]);
    }

    #[test]
    fn count_beats_recency() {
        // continuation [7] occurs twice, [8] once (later)
        let seq = [4, 7, 4, 7, 4, 8, 4];
        let b = propose(1, &seq, 2, 1);
        assert_eq!(b.row_tokens(0), vec![7]);
        assert_eq!(b.row_tokens(1), vec![8]);
    }

    #[test]
    fn q2_requires_two_token_match() {
        let seq = [1, 2, 5, 9, 1, 2];
        let b = propose(2, &seq, 2, 1);
        assert_eq!(b.k(), 1);
        assert_eq!(b.row_tokens(0), vec![5]);
    }

    #[test]
    fn no_match_no_rows() {
        let b = propose(1, &[1, 2, 3], 4, 2);
        assert_eq!(b.k(), 0);
    }

    #[test]
    fn truncated_continuation_at_end() {
        // match just before the query: continuation shorter than w
        let seq = [3, 8, 3];
        let b = propose(1, &seq, 1, 4);
        assert_eq!(b.row_tokens(0), vec![8, 3]); // only 2 tokens available
    }

    #[test]
    fn respects_k() {
        let seq = [1, 2, 1, 3, 1, 4, 1, 5, 1];
        let b = propose(1, &seq, 2, 1);
        assert_eq!(b.k(), 2);
    }

    #[test]
    fn short_seq_safe() {
        assert_eq!(propose(3, &[1, 2], 4, 2).k(), 0);
        assert_eq!(propose(1, &[], 4, 2).k(), 0);
        assert_eq!(propose(1, &[5], 4, 0).k(), 0);
    }

    #[test]
    fn incremental_proposals_survive_append_and_rollback() {
        // the same persistent instance must match the reference oracle as
        // its sequence grows and rolls back
        let mut ctx = ContextNgram::new(1);
        let mut seq: Vec<u32> = vec![1, 2, 3, 1, 2, 9, 1];
        assert_eq!(ctx.candidates(&seq, 2), reference_candidates(1, &seq, 2));
        seq.extend([2, 3, 1]); // append accepted tokens
        assert_eq!(ctx.candidates(&seq, 2), reference_candidates(1, &seq, 2));
        seq.truncate(8); // rollback
        assert_eq!(ctx.candidates(&seq, 2), reference_candidates(1, &seq, 2));
        seq.extend([7, 7, 1]); // diverge after the rollback
        assert_eq!(ctx.candidates(&seq, 2), reference_candidates(1, &seq, 2));
    }
}
