//! Binary N-gram table loader (written by python/compile/ngram_tables.py).
//!
//! Format: little-endian u32 header [magic "NGRM", rows, cols, depth]
//! followed by row-major u32 data.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::config::ModelArtifacts;
use crate::tokenizer::TokenId;

/// Table-file magic: "NGRM" as a little-endian u32.
pub const MAGIC: u32 = 0x4E47524D;

/// A dense u32 lookup table of rank 2 (rows, cols) or 3 (rows, cols, depth).
#[derive(Debug, Clone)]
pub struct Table {
    /// first dimension
    pub rows: usize,
    /// second dimension
    pub cols: usize,
    /// third dimension (1 for rank-2 tables)
    pub depth: usize,
    data: Vec<u32>,
}

impl Table {
    /// Read and parse one table file.
    pub fn load(path: &Path) -> Result<Table> {
        let bytes = std::fs::read(path).with_context(|| format!("reading table {path:?}"))?;
        Table::from_bytes(&bytes).with_context(|| format!("parsing table {path:?}"))
    }

    /// Parse a table from raw bytes (header + row-major u32 data).
    pub fn from_bytes(bytes: &[u8]) -> Result<Table> {
        if bytes.len() < 16 {
            return Err(anyhow!("table too short"));
        }
        let rd = |i: usize| u32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap());
        if rd(0) != MAGIC {
            return Err(anyhow!("bad magic {:#x}", rd(0)));
        }
        let (rows, cols, depth) = (rd(1) as usize, rd(2) as usize, rd(3) as usize);
        let n = rows * cols * depth;
        if bytes.len() != 16 + n * 4 {
            return Err(anyhow!(
                "table size mismatch: {} bytes for {rows}x{cols}x{depth}",
                bytes.len()
            ));
        }
        let data = bytes[16..]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Table { rows, cols, depth, data })
    }

    /// 2-D access (depth must be 1).
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> u32 {
        debug_assert_eq!(self.depth, 1);
        self.data[r * self.cols + c]
    }

    /// 3-D access: chain element `d` of entry (r, c).
    #[inline]
    pub fn at3(&self, r: usize, c: usize, d: usize) -> u32 {
        self.data[(r * self.cols + c) * self.depth + d]
    }

    /// Row slice for depth-1 tables.
    #[inline]
    pub fn row(&self, r: usize) -> &[u32] {
        &self.data[r * self.cols * self.depth..(r + 1) * self.cols * self.depth]
    }

    /// Build a table from raw data (tests, benches, synthetic strategies).
    pub fn from_data(rows: usize, cols: usize, depth: usize, data: Vec<u32>) -> Table {
        assert_eq!(data.len(), rows * cols * depth);
        Table { rows, cols, depth, data }
    }
}

/// The three model-derived tables for one model.
#[derive(Debug, Clone)]
pub struct NgramTables {
    /// (V, topk): top-k of p_M(. | x)
    pub bigram: Table,
    /// (1, topk): static unigram ranking from the embedding geometry
    pub unigram: Table,
    /// (V, topk, w): greedy bigram chains per (token, rank)
    pub ext_bigram: Table,
}

impl NgramTables {
    /// Load the three tables referenced by a model's artifacts.
    pub fn load(art: &ModelArtifacts) -> Result<NgramTables> {
        let t = NgramTables {
            bigram: Table::load(&art.bigram_table)?,
            unigram: Table::load(&art.unigram_table)?,
            ext_bigram: Table::load(&art.ext_bigram_table)?,
        };
        if t.bigram.rows != art.dims.vocab_size {
            return Err(anyhow!(
                "bigram rows {} != vocab {}",
                t.bigram.rows,
                art.dims.vocab_size
            ));
        }
        if t.ext_bigram.rows != t.bigram.rows || t.ext_bigram.cols > t.bigram.cols {
            return Err(anyhow!("ext_bigram shape inconsistent with bigram"));
        }
        Ok(t)
    }

    /// j-th ranked continuation chain of token x, `w` tokens long.
    /// Falls back to repeating bigram top-1 beyond the stored depth.
    pub fn ext_chain(&self, x: TokenId, j: usize, w: usize, out: &mut Vec<TokenId>) {
        out.clear();
        let r = (x as usize).min(self.ext_bigram.rows - 1);
        let j = j.min(self.ext_bigram.cols - 1);
        let depth = self.ext_bigram.depth;
        for d in 0..w.min(depth) {
            out.push(self.ext_bigram.at3(r, j, d));
        }
        // beyond stored depth: continue with bigram top-1 of the last token
        while out.len() < w {
            let last = *out.last().unwrap_or(&x) as usize;
            out.push(self.bigram.at(last.min(self.bigram.rows - 1), 0));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table_bytes(rows: u32, cols: u32, depth: u32, data: &[u32]) -> Vec<u8> {
        let mut b = Vec::new();
        for v in [MAGIC, rows, cols, depth] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        for v in data {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b
    }

    #[test]
    fn parse_2d() {
        let b = table_bytes(2, 3, 1, &[1, 2, 3, 4, 5, 6]);
        let t = Table::from_bytes(&b).unwrap();
        assert_eq!(t.at(0, 2), 3);
        assert_eq!(t.at(1, 0), 4);
        assert_eq!(t.row(1), &[4, 5, 6]);
    }

    #[test]
    fn parse_3d() {
        let b = table_bytes(2, 2, 2, &[0, 1, 2, 3, 4, 5, 6, 7]);
        let t = Table::from_bytes(&b).unwrap();
        assert_eq!(t.at3(1, 0, 1), 5);
    }

    #[test]
    fn rejects_bad_magic_and_size() {
        let mut b = table_bytes(1, 1, 1, &[9]);
        b[0] ^= 0xff;
        assert!(Table::from_bytes(&b).is_err());
        let b = table_bytes(2, 2, 1, &[1, 2, 3]); // size mismatch
        assert!(Table::from_bytes(&b).is_err());
    }

    #[test]
    fn ext_chain_extends_past_depth() {
        let tables = NgramTables {
            bigram: Table::from_data(4, 2, 1, vec![1, 2, 2, 3, 3, 0, 0, 1]),
            unigram: Table::from_data(1, 2, 1, vec![0, 1]),
            // depth-2 chains: token x rank j -> [x+1, x+2] (mod 4)
            ext_bigram: Table::from_data(
                4, 2, 2,
                (0..4u32).flat_map(|x| vec![(x + 1) % 4, (x + 2) % 4, (x + 2) % 4, (x + 3) % 4])
                    .collect(),
            ),
        };
        let mut out = Vec::new();
        tables.ext_chain(1, 0, 4, &mut out);
        // stored: [2, 3]; then bigram top-1 of 3 is 0, of 0 is 1
        assert_eq!(out, vec![2, 3, 0, 1]);
    }
}
