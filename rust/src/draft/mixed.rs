//! Mixed strategy (paper §4.3): fill the k rows with as many context-n-gram
//! drafts as the context yields, then fill the remainder with the extended
//! model bigram. The per-step allocation is therefore variable — exactly
//! what the paper ablates in §5.2 (our Fig. 4 bench records it via row
//! provenance).
//!
//! `AllocationPolicy` generalizes the paper's ordering for the ablation
//! benches (`bench ablation-alloc`). Both sources stream straight into the
//! `DraftBatch` arena — context rows as slices of the live sequence,
//! bigram chains through the open-row writer — with duplicate rows
//! (identical drafts waste verification rows) rejected by comparing
//! against the arena in place, so the whole mixed proposal is
//! allocation-free once warm.

use std::sync::Arc;

use super::{
    count_share, ContextNgram, DraftBatch, DraftStrategy, ExtendedBigram, NgramTables,
    StrategyKind,
};
use crate::tokenizer::TokenId;

/// How the k rows are split between the two sources.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocationPolicy {
    /// paper §4.3: context matches first, bigram fills the rest
    ContextFirst,
    /// inverse ordering (ablation)
    BigramFirst,
    /// fixed split: at most `ctx` rows from the context (ablation)
    FixedSplit {
        /// context-row quota
        ctx: usize,
    },
}

/// The paper's mixed drafting policy: context n-gram rows plus
/// extended-bigram fill.
pub struct MixedStrategy {
    /// the context n-gram source
    pub context: ContextNgram,
    /// the extended-bigram source
    pub bigram: ExtendedBigram,
    /// how the k rows are split between the two sources
    pub policy: AllocationPolicy,
}

impl MixedStrategy {
    /// The paper's §4.3 configuration: q=1 context n-gram + extended bigram.
    pub fn paper(tables: Arc<NgramTables>, q: usize) -> Self {
        MixedStrategy {
            context: ContextNgram::new(q),
            bigram: ExtendedBigram::new(tables),
            policy: AllocationPolicy::ContextFirst,
        }
    }

    /// A mixed strategy with an explicit allocation policy (ablations).
    pub fn with_policy(tables: Arc<NgramTables>, q: usize, policy: AllocationPolicy) -> Self {
        MixedStrategy {
            context: ContextNgram::new(q),
            bigram: ExtendedBigram::new(tables),
            policy,
        }
    }

    /// Push the context source's ranked candidates (rank order, skipping
    /// rows already present) until `quota` rows stand.
    fn push_context(&self, seq: &[TokenId], ctx_total: u32, quota: usize, batch: &mut DraftBatch) {
        let w = batch.w;
        let n = seq.len();
        let q = self.context.q();
        for (rank, g) in self.context.ranked().iter().enumerate() {
            if batch.is_full(quota) {
                break;
            }
            let s = g.rep as usize + q;
            let row = &seq[s..(s + w).min(n)];
            let dup = (0..batch.k()).any(|i| batch.row_tokens(i) == row);
            if !dup {
                batch.push_conf(
                    row,
                    StrategyKind::ContextNgram,
                    rank,
                    count_share(g.count, ctx_total),
                );
            }
        }
    }

    /// Push extended-bigram chains (rank order, skipping rows already
    /// present) until `quota` rows stand. Chains are written through the
    /// arena writer and aborted in place when they duplicate an earlier row.
    fn push_bigram(&self, cur: Option<TokenId>, quota: usize, batch: &mut DraftBatch) {
        let Some(cur) = cur else { return };
        let t = self.bigram.tables();
        let w = batch.w;
        for j in 0..t.ext_bigram.cols {
            if batch.is_full(quota) {
                break;
            }
            batch.begin_row();
            let r = (cur as usize).min(t.ext_bigram.rows - 1);
            for d in 0..w.min(t.ext_bigram.depth) {
                batch.push_token(t.ext_bigram.at3(r, j, d));
            }
            while batch.open_row().len() < w {
                let last = batch.open_row().last().copied().unwrap_or(cur);
                batch.push_token(t.bigram.at((last as usize).min(t.bigram.rows - 1), 0));
            }
            let dup = (0..batch.k()).any(|i| batch.row_tokens(i) == batch.open_row());
            if dup {
                batch.abort_row();
            } else {
                batch.commit_row_conf(StrategyKind::ExtendedBigram, j, 1.0 / (1.0 + j as f64));
            }
        }
    }
}

impl DraftStrategy for MixedStrategy {
    fn name(&self) -> &'static str {
        "mixed(context+ext-bigram)"
    }

    fn propose(&mut self, seq: &[TokenId], k: usize, batch: &mut DraftBatch) {
        // Rank the context source once (refreshing its suffix index), then
        // fill the batch with DISTINCT rows in policy order.
        let w = batch.w;
        let ctx_total = self.context.refresh(seq, w);
        let cur = seq.last().copied();

        match self.policy {
            AllocationPolicy::ContextFirst => {
                self.push_context(seq, ctx_total, k, batch);
                self.push_bigram(cur, k, batch);
            }
            AllocationPolicy::BigramFirst => {
                self.push_bigram(cur, k, batch);
                self.push_context(seq, ctx_total, k, batch);
            }
            AllocationPolicy::FixedSplit { ctx } => {
                self.push_context(seq, ctx_total, ctx.min(k), batch);
                self.push_bigram(cur, k, batch);
                self.push_context(seq, ctx_total, k, batch);
            }
        }
    }

    fn reset(&mut self) {
        self.context.reset();
        self.bigram.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::draft::tables::Table;
    use crate::draft::StrategyKind;

    fn tables() -> Arc<NgramTables> {
        let bigram = Table::from_data(
            8, 4, 1,
            (0..8u32).flat_map(|x| (1..5).map(move |j| (x + j) % 8)).collect(),
        );
        let unigram = Table::from_data(1, 4, 1, vec![0, 1, 2, 3]);
        let ext = Table::from_data(
            8, 4, 2,
            (0..8u32)
                .flat_map(|x| (1..5u32).flat_map(move |j| vec![(x + j) % 8, (x + j + 1) % 8]))
                .collect(),
        );
        Arc::new(NgramTables { bigram, unigram, ext_bigram: ext })
    }

    #[test]
    fn context_rows_come_first_then_bigram_fills() {
        let mut m = MixedStrategy::paper(tables(), 1);
        // context has one match for token 5 -> continuation [6]
        let seq = [5, 6, 1, 5];
        let mut b = DraftBatch::new(1);
        m.propose(&seq, 4, &mut b);
        assert_eq!(b.k(), 4);
        assert_eq!(b.rows()[0].kind, StrategyKind::ContextNgram);
        assert_eq!(b.row_tokens(0), vec![6]);
        assert!(b.rows()[1..].iter().all(|r| r.kind == StrategyKind::ExtendedBigram));
    }

    #[test]
    fn dedup_removes_identical_rows() {
        let mut m = MixedStrategy::paper(tables(), 1);
        // context match for 2 yields [3] == ext-bigram rank 0 chain start;
        // with w=1 both propose [3] -> dedup keeps one, bigram refills
        let seq = [2, 3, 2];
        let mut b = DraftBatch::new(1);
        m.propose(&seq, 3, &mut b);
        let toks: Vec<u32> = (0..b.k()).map(|r| b.row_tokens(r)[0]).collect();
        let mut uniq = toks.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), toks.len(), "rows must be distinct: {toks:?}");
        assert_eq!(b.k(), 3);
    }

    #[test]
    fn bigram_first_policy_orders_rows() {
        let mut m = MixedStrategy::with_policy(tables(), 1, AllocationPolicy::BigramFirst);
        let seq = [5, 6, 1, 5];
        let mut b = DraftBatch::new(1);
        m.propose(&seq, 2, &mut b);
        assert_eq!(b.rows()[0].kind, StrategyKind::ExtendedBigram);
    }

    #[test]
    fn fixed_split_caps_context() {
        let mut m = MixedStrategy::with_policy(tables(), 1, AllocationPolicy::FixedSplit { ctx: 1 });
        // context would match twice for token 1: continuations [2] and [4]
        let seq = [1, 2, 0, 1, 4, 0, 1];
        let mut b = DraftBatch::new(1);
        m.propose(&seq, 4, &mut b);
        let n_ctx = b.rows().iter().filter(|r| r.kind == StrategyKind::ContextNgram).count();
        assert!(n_ctx <= 2); // 1 from quota (+1 possible from final refill)
        assert_eq!(b.k(), 4);
    }
}
