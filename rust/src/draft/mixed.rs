//! Mixed strategy (paper §4.3): fill the k rows with as many context-n-gram
//! drafts as the context yields, then fill the remainder with the extended
//! model bigram. The per-step allocation is therefore variable — exactly
//! what the paper ablates in §5.2 (our Fig. 4 bench records it via row
//! provenance).
//!
//! `AllocationPolicy` generalizes the paper's ordering for the ablation
//! benches (`bench ablation-alloc`).

use std::sync::Arc;

use super::{
    count_share, ContextNgram, DraftBatch, DraftStrategy, ExtendedBigram, NgramTables,
    StrategyKind,
};
use crate::tokenizer::TokenId;

/// How the k rows are split between the two sources.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocationPolicy {
    /// paper §4.3: context matches first, bigram fills the rest
    ContextFirst,
    /// inverse ordering (ablation)
    BigramFirst,
    /// fixed split: at most `ctx` rows from the context (ablation)
    FixedSplit { ctx: usize },
}

/// The paper's mixed drafting policy: context n-gram rows plus
/// extended-bigram fill.
pub struct MixedStrategy {
    /// the context n-gram source
    pub context: ContextNgram,
    /// the extended-bigram source
    pub bigram: ExtendedBigram,
    /// how the k rows are split between the two sources
    pub policy: AllocationPolicy,
}

impl MixedStrategy {
    /// The paper's §4.3 configuration: q=1 context n-gram + extended bigram.
    pub fn paper(tables: Arc<NgramTables>, q: usize) -> Self {
        MixedStrategy {
            context: ContextNgram::new(q),
            bigram: ExtendedBigram::new(tables),
            policy: AllocationPolicy::ContextFirst,
        }
    }

    /// A mixed strategy with an explicit allocation policy (ablations).
    pub fn with_policy(tables: Arc<NgramTables>, q: usize, policy: AllocationPolicy) -> Self {
        MixedStrategy {
            context: ContextNgram::new(q),
            bigram: ExtendedBigram::new(tables),
            policy,
        }
    }
}

impl DraftStrategy for MixedStrategy {
    fn name(&self) -> &'static str {
        "mixed(context+ext-bigram)"
    }

    fn propose(&mut self, seq: &[TokenId], k: usize, batch: &mut DraftBatch) {
        // Gather both sources' ranked candidates (with confidences), then
        // fill the batch with DISTINCT rows in policy order (duplicates
        // waste verification rows).
        let w = batch.w;
        let ctx_cands = self.context.candidates(seq, w);
        let ctx_total: u32 = ctx_cands.iter().map(|(_, c)| *c).sum();
        let ctx_rows: Vec<(Vec<TokenId>, f64)> = ctx_cands
            .into_iter()
            .map(|(g, c)| (g, count_share(c, ctx_total)))
            .collect();
        let tables = self.bigram_tables();
        let mut big_rows: Vec<(Vec<TokenId>, f64)> = Vec::new();
        if let Some(&cur) = seq.last() {
            let mut chain = Vec::new();
            for j in 0..tables.ext_bigram.cols {
                tables.ext_chain(cur, j, w, &mut chain);
                big_rows.push((chain.clone(), 1.0 / (1.0 + j as f64)));
            }
        }

        let push = |batch: &mut DraftBatch, rows: &[(Vec<TokenId>, f64)],
                    kind: StrategyKind, quota: usize| {
            for (rank, (row, conf)) in rows.iter().enumerate() {
                if batch.is_full(quota) {
                    break;
                }
                let exists = batch.rows.iter().any(|r| {
                    r.tokens.len() == row.len().min(w) && r.tokens == row[..row.len().min(w)]
                });
                if !exists {
                    batch.push_conf(row.clone(), kind, rank, *conf);
                }
            }
        };

        match self.policy {
            AllocationPolicy::ContextFirst => {
                push(batch, &ctx_rows, StrategyKind::ContextNgram, k);
                push(batch, &big_rows, StrategyKind::ExtendedBigram, k);
            }
            AllocationPolicy::BigramFirst => {
                push(batch, &big_rows, StrategyKind::ExtendedBigram, k);
                push(batch, &ctx_rows, StrategyKind::ContextNgram, k);
            }
            AllocationPolicy::FixedSplit { ctx } => {
                push(batch, &ctx_rows, StrategyKind::ContextNgram, ctx.min(k));
                push(batch, &big_rows, StrategyKind::ExtendedBigram, k);
                push(batch, &ctx_rows, StrategyKind::ContextNgram, k);
            }
        }
    }

    fn reset(&mut self) {
        self.context.reset();
        self.bigram.reset();
    }
}

impl MixedStrategy {
    fn bigram_tables(&self) -> &NgramTables {
        self.bigram.tables()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::draft::tables::Table;
    use crate::draft::StrategyKind;

    fn tables() -> Arc<NgramTables> {
        let bigram = Table::from_data(
            8, 4, 1,
            (0..8u32).flat_map(|x| (1..5).map(move |j| (x + j) % 8)).collect(),
        );
        let unigram = Table::from_data(1, 4, 1, vec![0, 1, 2, 3]);
        let ext = Table::from_data(
            8, 4, 2,
            (0..8u32)
                .flat_map(|x| (1..5u32).flat_map(move |j| vec![(x + j) % 8, (x + j + 1) % 8]))
                .collect(),
        );
        Arc::new(NgramTables { bigram, unigram, ext_bigram: ext })
    }

    #[test]
    fn context_rows_come_first_then_bigram_fills() {
        let mut m = MixedStrategy::paper(tables(), 1);
        // context has one match for token 5 -> continuation [6]
        let seq = [5, 6, 1, 5];
        let mut b = DraftBatch::new(1);
        m.propose(&seq, 4, &mut b);
        assert_eq!(b.k(), 4);
        assert_eq!(b.rows[0].kind, StrategyKind::ContextNgram);
        assert_eq!(b.rows[0].tokens, vec![6]);
        assert!(b.rows[1..].iter().all(|r| r.kind == StrategyKind::ExtendedBigram));
    }

    #[test]
    fn dedup_removes_identical_rows() {
        let mut m = MixedStrategy::paper(tables(), 1);
        // context match for 2 yields [3] == ext-bigram rank 0 chain start;
        // with w=1 both propose [3] -> dedup keeps one, bigram refills
        let seq = [2, 3, 2];
        let mut b = DraftBatch::new(1);
        m.propose(&seq, 3, &mut b);
        let toks: Vec<_> = b.rows.iter().map(|r| r.tokens[0]).collect();
        let mut uniq = toks.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), toks.len(), "rows must be distinct: {toks:?}");
        assert_eq!(b.k(), 3);
    }

    #[test]
    fn bigram_first_policy_orders_rows() {
        let mut m = MixedStrategy::with_policy(tables(), 1, AllocationPolicy::BigramFirst);
        let seq = [5, 6, 1, 5];
        let mut b = DraftBatch::new(1);
        m.propose(&seq, 2, &mut b);
        assert_eq!(b.rows[0].kind, StrategyKind::ExtendedBigram);
    }

    #[test]
    fn fixed_split_caps_context() {
        let mut m = MixedStrategy::with_policy(tables(), 1, AllocationPolicy::FixedSplit { ctx: 1 });
        // context would match twice for token 1: continuations [2] and [4]
        let seq = [1, 2, 0, 1, 4, 0, 1];
        let mut b = DraftBatch::new(1);
        m.propose(&seq, 4, &mut b);
        let n_ctx = b.rows.iter().filter(|r| r.kind == StrategyKind::ContextNgram).count();
        assert!(n_ctx <= 2); // 1 from quota (+1 possible from final refill)
        assert_eq!(b.k(), 4);
    }
}
