//! Fleet-shared draft store: one sharded, read-mostly n-gram chain store
//! shared by every engine in the serving pool, so hot continuations are
//! learned ONCE fleet-wide instead of once per engine (ANPD-style shared
//! online draft state; ROADMAP "fleet-shared draft state + per-task
//! priors").
//!
//! Layout: `shards` independent shards, each a fixed-capacity
//! open-addressed table of [`Entry`] slots. Every slot is a single-writer
//! **seqlock** (the `trace/` ring discipline): the slot's version counter
//! is odd while a writer is mutating the entry and even once published, so
//! a reader copies optimistically, re-checks the counter, and discards any
//! torn copy. Engine read paths ([`SharedDraftStore::find`], called from
//! [`SharedDraftStrategy::propose`]) therefore take **no lock and perform
//! no heap allocation** — an entry is a fixed-size `Copy` value read onto
//! the stack. Writers are serialized per shard by a mutex that readers
//! never touch, and arrive only in **batched deltas**: the wrapper
//! strategy buffers accepted tokens and publishes a span at a time
//! ([`SharedDraftStore::publish`]), off the per-step propose path.
//!
//! Two key spaces share the table, mirroring the private strategies they
//! generalize: a **unigram chain layer** (last token → ranked
//! continuation chains, the fleet analog of
//! [`super::SessionNgramCache`]) and a **bigram posting layer** (last two
//! tokens → chains, the suffix-index-flavored higher-precision probe,
//! tried first on lookup). Bigram keys set the top key bit so the two
//! spaces can never collide.
//!
//! The store also keys **adaptive priors by prompt fingerprint**
//! ([`fingerprint`]): per-(fingerprint, [`StrategyKind`]) win/accepted
//! counters recorded at request completion, so a chat-shaped request's
//! bandit seeds from chat history instead of fleet-wide traffic
//! (`crate::adaptive::controller_for_fingerprint` builds `ArmPrior`s from
//! these).
//!
//! CORRECTNESS: shared chains only change *which* candidate rows are
//! proposed, never what the verifier accepts — every emitted token is
//! still the base model's greedy continuation, so output streams are
//! byte-identical with the store on or off (pinned by
//! `rust/tests/shared_draft.rs` and the `bench pool` cross-engine gate).

use std::cell::UnsafeCell;
use std::collections::HashMap;
use std::sync::atomic::{fence, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::{count_share, DraftBatch, DraftStrategy, StrategyKind};
use crate::tokenizer::TokenId;

/// Tokens stored per continuation chain.
pub const CHAIN_LEN: usize = 8;

/// Ranked continuation chains kept per key (per entry).
const CHAINS_PER_ENTRY: usize = 4;

/// Linear-probe window: how many consecutive slots a key may land in.
const PROBE: usize = 8;

/// Seqlock slots per shard (fixed at construction; eviction replaces the
/// coldest entry in a full probe window instead of growing).
const SLOTS_PER_SHARD: usize = 2048;

/// Accepted tokens the wrapper buffers before publishing one batched
/// delta to the store (plus a final flush when the strategy is dropped).
const FLUSH_THRESHOLD: usize = 24;

/// Prompt tokens hashed into the task-class fingerprint. Task corpora
/// share their leading format tokens ("Question:", "def ", chat role
/// markers), so a short prefix hash separates task classes while mapping
/// identical prompts to identical fingerprints deterministically.
pub const FP_WINDOW: usize = 4;

/// Distinct fingerprints the prior map retains (bounds memory; a fleet
/// serves few task classes, so collisions with this cap are theoretical).
const FP_CAP: usize = 1024;

/// Task-class fingerprint of a prompt: FNV-1a over the first
/// [`FP_WINDOW`] tokens. Deterministic, so identical prompts always land
/// in the same prior bucket.
pub fn fingerprint(prompt: &[TokenId]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &t in prompt.iter().take(FP_WINDOW) {
        h ^= t as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// One key's ranked continuation chains — the fixed-size `Copy` payload a
/// seqlock slot protects. `key == 0` marks an empty slot; unigram keys
/// are `token + 1` (never 0) and bigram keys set the top bit.
#[derive(Debug, Clone, Copy)]
struct Entry {
    key: u64,
    /// chain lengths (0 = free chain slot)
    lens: [u8; CHAINS_PER_ENTRY],
    /// observation counts (the within-entry ranking signal)
    counts: [u32; CHAINS_PER_ENTRY],
    chains: [[TokenId; CHAIN_LEN]; CHAINS_PER_ENTRY],
}

impl Entry {
    const EMPTY: Entry = Entry {
        key: 0,
        lens: [0; CHAINS_PER_ENTRY],
        counts: [0; CHAINS_PER_ENTRY],
        chains: [[0; CHAIN_LEN]; CHAINS_PER_ENTRY],
    };

    /// Total observations across the entry's chains (the eviction
    /// coldness signal).
    fn total(&self) -> u32 {
        self.counts.iter().sum()
    }

    /// Fold one observed continuation in: a prefix-compatible stored
    /// chain is bumped (and extended to the longer spelling, like the
    /// session cache); otherwise the chain takes a free slot or replaces
    /// the coldest one.
    fn ingest(&mut self, chain: &[TokenId]) {
        let len = chain.len().min(CHAIN_LEN);
        if len == 0 {
            return;
        }
        let chain = &chain[..len];
        for j in 0..CHAINS_PER_ENTRY {
            let stored_len = self.lens[j] as usize;
            if stored_len == 0 {
                continue;
            }
            let n = stored_len.min(len);
            if self.chains[j][..n] == chain[..n] {
                if len > stored_len {
                    self.chains[j][..len].copy_from_slice(chain);
                    self.lens[j] = len as u8;
                }
                self.counts[j] = self.counts[j].saturating_add(1);
                return;
            }
        }
        let j = (0..CHAINS_PER_ENTRY)
            .find(|&j| self.lens[j] == 0)
            .unwrap_or_else(|| {
                (0..CHAINS_PER_ENTRY).min_by_key(|&j| self.counts[j]).unwrap_or(0)
            });
        self.chains[j] = [0; CHAIN_LEN];
        self.chains[j][..len].copy_from_slice(chain);
        self.lens[j] = len as u8;
        self.counts[j] = 1;
    }
}

/// One seqlock slot: even version = published, odd = write in flight.
struct Slot {
    seq: AtomicU64,
    data: UnsafeCell<Entry>,
}

/// One shard: a fixed slot table plus the writer-side mutex. Readers
/// never take the mutex — the seqlock protocol makes torn copies
/// detectable instead of preventable.
struct Shard {
    slots: Box<[Slot]>,
    /// serializes WRITERS only (publish batches); the single-writer
    /// precondition of each slot's seqlock
    write: Mutex<()>,
}

// SAFETY: each slot's `data` is only mutated while the shard's `write`
// mutex is held AND between the odd/even stores of that slot's `seq`;
// readers access it exclusively through `read_volatile` and discard any
// copy whose seq re-check fails, so a torn read is detected, never
// interpreted. This is the `trace::StepRing` discipline applied per slot.
unsafe impl Sync for Shard {}

impl Shard {
    fn new(slots: usize) -> Self {
        Shard {
            slots: (0..slots.max(PROBE))
                .map(|_| Slot { seq: AtomicU64::new(0), data: UnsafeCell::new(Entry::EMPTY) })
                .collect::<Vec<_>>()
                .into_boxed_slice(),
            write: Mutex::new(()),
        }
    }

    /// Lock-free snapshot of slot `idx` into `out`. Returns false if the
    /// writer kept tearing the copy (bounded retries; callers treat that
    /// as "slot unknown" and keep probing).
    fn read(&self, idx: usize, out: &mut Entry) -> bool {
        let slot = &self.slots[idx];
        for _attempt in 0..4 {
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 & 1 == 1 {
                continue; // write in flight: retry
            }
            // SAFETY: volatile copy of Copy data; validity is established
            // by the seq re-check below, a torn copy is discarded.
            let e = unsafe { std::ptr::read_volatile(slot.data.get()) };
            fence(Ordering::Acquire);
            let s2 = slot.seq.load(Ordering::Relaxed);
            if s1 == s2 {
                *out = e;
                return true;
            }
        }
        false
    }

    /// Mutate slot `idx` under the seqlock protocol. The caller must hold
    /// this shard's `write` mutex (single-writer precondition).
    fn update(&self, idx: usize, f: impl FnOnce(&mut Entry)) {
        let slot = &self.slots[idx];
        let s = slot.seq.load(Ordering::Relaxed);
        slot.seq.store(s + 1, Ordering::Relaxed); // odd: write in flight
        fence(Ordering::Release);
        // SAFETY: single writer (the shard mutex is held); readers detect
        // this in-flight write via the odd seq and discard their copy.
        unsafe { f(&mut *slot.data.get()) };
        slot.seq.store(s + 2, Ordering::Release); // even: published
    }
}

/// Per-(fingerprint, kind) acceptance record: (step wins, accepted draft
/// tokens across winning steps) — the raw signal behind fingerprint-keyed
/// `ArmPrior`s.
pub type FpStats = [(u64, u64); StrategyKind::COUNT];

/// The fleet-shared draft store. Cheap to share (`Arc`); see the module
/// docs for the shard/seqlock layout.
pub struct SharedDraftStore {
    shards: Vec<Shard>,
    /// propose-side consults that yielded at least one shared chain
    hits: AtomicU64,
    /// propose-side consults that found nothing for the current context
    misses: AtomicU64,
    /// batched deltas writers have published
    publishes: AtomicU64,
    /// prompt fingerprint → per-kind acceptance record (NOT on the
    /// propose hot path: written once per completed request, read once
    /// per adaptive admission)
    priors: Mutex<HashMap<u64, FpStats>>,
}

impl std::fmt::Debug for SharedDraftStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SharedDraftStore")
            .field("shards", &self.shards.len())
            .field("hits", &self.hits())
            .field("misses", &self.misses())
            .field("publishes", &self.publishes())
            .finish()
    }
}

/// Splitmix-style key scrambler: the high half picks the shard, the low
/// half the slot, so the two choices stay independent.
fn mix(key: u64) -> u64 {
    let mut h = key.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    h ^= h >> 32;
    h.wrapping_mul(0xbf58_476d_1ce4_e5b9)
}

/// Unigram-layer key for `t` (offset by 1 so key 0 stays "empty").
fn uni_key(t: TokenId) -> u64 {
    t as u64 + 1
}

/// Bigram-posting-layer key for the context `(a, b)`: FNV-1a over both
/// tokens with the top bit forced, so bigram keys never collide with
/// unigram keys (whose realistic values never reach bit 63) and never
/// equal 0.
fn bi_key(a: TokenId, b: TokenId) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for t in [a, b] {
        h ^= t as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h | (1 << 63)
}

impl SharedDraftStore {
    /// A store with `shards` shards (floored at 1) of the default slot
    /// capacity.
    pub fn new(shards: usize) -> Self {
        Self::with_capacity(shards, SLOTS_PER_SHARD)
    }

    /// [`Self::new`] with an explicit per-shard slot count (tests use
    /// tiny tables to exercise eviction).
    pub fn with_capacity(shards: usize, slots_per_shard: usize) -> Self {
        SharedDraftStore {
            shards: (0..shards.max(1)).map(|_| Shard::new(slots_per_shard)).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            publishes: AtomicU64::new(0),
            priors: Mutex::new(HashMap::new()),
        }
    }

    /// Shard count (the `--shared-draft-shards` knob, echoed in docs).
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Propose-side consults that yielded at least one shared chain.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Propose-side consults that found nothing.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Batched deltas published by writers.
    pub fn publishes(&self) -> u64 {
        self.publishes.load(Ordering::Relaxed)
    }

    fn shard_and_base(&self, key: u64) -> (&Shard, usize) {
        let h = mix(key);
        let shard = &self.shards[(h >> 32) as usize % self.shards.len()];
        let base = h as usize % shard.slots.len();
        (shard, base)
    }

    /// Lock-free, allocation-free lookup: copy `key`'s entry into `out`
    /// if present. Probes at most [`PROBE`] slots; an empty slot ends the
    /// probe (eviction replaces entries in place, never re-empties a
    /// slot, so probe chains stay intact).
    fn find(&self, key: u64, out: &mut Entry) -> bool {
        let (shard, base) = self.shard_and_base(key);
        let n = shard.slots.len();
        for p in 0..PROBE {
            let idx = (base + p) % n;
            if shard.read(idx, out) {
                if out.key == key {
                    return true;
                }
                if out.key == 0 {
                    return false;
                }
            }
            // torn after retries: treat the slot as occupied-by-other and
            // keep probing
        }
        false
    }

    /// Writer-side upsert of one observed continuation under `key`
    /// (serialized per shard; readers stay lock-free throughout).
    fn upsert(&self, key: u64, chain: &[TokenId]) {
        if chain.is_empty() {
            return;
        }
        let (shard, base) = self.shard_and_base(key);
        let guard = shard.write.lock().unwrap();
        let n = shard.slots.len();
        let mut victim = base;
        let mut victim_total = u32::MAX;
        let mut target = None;
        for p in 0..PROBE {
            let idx = (base + p) % n;
            // SAFETY: the shard write mutex is held, so no concurrent
            // writer exists; concurrent readers only read, so a plain
            // shared reference is sound here.
            let e = unsafe { &*shard.slots[idx].data.get() };
            if e.key == key || e.key == 0 {
                target = Some(idx);
                break;
            }
            let t = e.total();
            if t < victim_total {
                victim_total = t;
                victim = idx;
            }
        }
        // full window with no match: evict the coldest entry in place
        let idx = target.unwrap_or(victim);
        shard.update(idx, |e| {
            if e.key != key {
                *e = Entry::EMPTY;
                e.key = key;
            }
            e.ingest(chain);
        });
        drop(guard);
    }

    /// Publish one batched delta of accepted text: every position's
    /// following tokens feed the unigram layer, every adjacent pair's the
    /// bigram posting layer. Called off the propose path (the wrapper
    /// buffers [`FLUSH_THRESHOLD`] tokens per flush).
    pub fn publish(&self, span: &[TokenId]) {
        if span.len() < 2 {
            return;
        }
        for i in 0..span.len() - 1 {
            let end = span.len().min(i + 1 + CHAIN_LEN);
            self.upsert(uni_key(span[i]), &span[i + 1..end]);
            if i + 2 < span.len() {
                let bend = span.len().min(i + 2 + CHAIN_LEN);
                self.upsert(bi_key(span[i], span[i + 1]), &span[i + 2..bend]);
            }
        }
        self.publishes.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one judged verification step for `fp`'s task class (the
    /// caller demotes no-acceptance steps to [`StrategyKind::Empty`],
    /// matching the fleet-wide counters).
    pub fn record_step(&self, fp: u64, kind: StrategyKind, accepted: usize) {
        let mut map = self.priors.lock().unwrap();
        if map.len() >= FP_CAP && !map.contains_key(&fp) {
            return; // bounded: new classes past the cap are dropped
        }
        let stats = map.entry(fp).or_insert([(0, 0); StrategyKind::COUNT]);
        let s = &mut stats[kind.index()];
        s.0 += 1;
        s.1 += accepted as u64;
    }

    /// The per-kind acceptance record for `fp`, if its task class has
    /// history.
    pub fn fingerprint_stats(&self, fp: u64) -> Option<FpStats> {
        self.priors.lock().unwrap().get(&fp).copied()
    }
}

/// Decorator that gives any private strategy a fleet memory: proposes the
/// inner strategy's rows first, then fills remaining row budget from the
/// shared store (bigram posting layer first, then unigram chains),
/// deduplicated against rows already in the batch. Observed accepted
/// tokens are forwarded to the inner strategy AND buffered into batched
/// deltas for the store.
pub struct SharedDraftStrategy {
    inner: Box<dyn DraftStrategy>,
    store: Arc<SharedDraftStore>,
    /// per-engine hit-through sink (`ngrammys_engine_shared_draft_hits`):
    /// counts shared rows this engine actually proposed
    engine_hits: Option<Arc<AtomicU64>>,
    /// accepted tokens awaiting publication
    tail: Vec<TokenId>,
}

impl SharedDraftStrategy {
    /// Wrap `inner` over `store`; `engine_hits` receives this engine's
    /// proposed-shared-row count when attached.
    pub fn new(
        inner: Box<dyn DraftStrategy>,
        store: Arc<SharedDraftStore>,
        engine_hits: Option<Arc<AtomicU64>>,
    ) -> Self {
        SharedDraftStrategy { inner, store, engine_hits, tail: Vec::new() }
    }

    /// Publish everything buffered (keeping a [`CHAIN_LEN`]-token overlap
    /// so chains spanning flush boundaries are still observed, like the
    /// session cache's rolling tail).
    fn flush(&mut self) {
        if self.tail.len() < 2 {
            return;
        }
        self.store.publish(&self.tail);
        let keep = (CHAIN_LEN + 1).min(self.tail.len());
        let cut = self.tail.len() - keep;
        self.tail.drain(..cut);
    }
}

impl Drop for SharedDraftStrategy {
    /// A retiring sequence publishes its remaining buffered tokens, so
    /// short requests still contribute deltas.
    fn drop(&mut self) {
        if self.tail.len() >= 2 {
            self.store.publish(&self.tail);
        }
    }
}

impl DraftStrategy for SharedDraftStrategy {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn propose(&mut self, seq: &[TokenId], k: usize, batch: &mut DraftBatch) {
        self.inner.propose(seq, k, batch);
        if batch.w == 0 || batch.is_full(k) {
            return; // greedy shape or no spare rows: nothing to add
        }
        let Some(&cur) = seq.last() else { return };
        let prev = seq.len().checked_sub(2).map(|i| seq[i]);
        let mut added = 0u64;
        let mut entry = Entry::EMPTY;
        // bigram posting layer first (higher precision), then unigram
        let keys = [prev.map(|p| bi_key(p, cur)), Some(uni_key(cur))];
        for key in keys.into_iter().flatten() {
            if batch.is_full(k) {
                break;
            }
            if !self.store.find(key, &mut entry) {
                continue;
            }
            let total = entry.total();
            // rank the (at most 4) chains by count, descending — a fixed
            // index array, no allocation
            let mut order = [0usize, 1, 2, 3];
            order.sort_unstable_by_key(|&j| std::cmp::Reverse(entry.counts[j]));
            for (rank, &j) in order.iter().enumerate() {
                if batch.is_full(k) {
                    break;
                }
                let len = (entry.lens[j] as usize).min(batch.w);
                if len == 0 || entry.counts[j] == 0 {
                    continue;
                }
                let chain = &entry.chains[j][..len];
                // dedup: a row opening with the same token verifies the
                // same first position — skip the redundant candidate
                let dup = (0..batch.k())
                    .any(|r| batch.row_tokens(r).first() == chain.first());
                if dup {
                    continue;
                }
                batch.push_conf(
                    chain,
                    StrategyKind::SharedFleet,
                    rank,
                    count_share(entry.counts[j], total),
                );
                added += 1;
            }
        }
        if added > 0 {
            self.store.hits.fetch_add(1, Ordering::Relaxed);
            if let Some(h) = &self.engine_hits {
                h.fetch_add(added, Ordering::Relaxed);
            }
        } else {
            self.store.misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn observe(&mut self, accepted: &[TokenId], model_out: &[TokenId]) {
        self.inner.observe(accepted, model_out);
        self.tail.extend_from_slice(accepted);
        if self.tail.len() >= FLUSH_THRESHOLD {
            self.flush();
        }
    }

    fn reset(&mut self) {
        // publish what this sequence learned, then clear per-sequence
        // state; the STORE persists — that is the point
        self.flush();
        self.tail.clear();
        self.inner.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NoDraft;

    fn wrapped(store: &Arc<SharedDraftStore>) -> SharedDraftStrategy {
        SharedDraftStrategy::new(Box::new(NoDraft), store.clone(), None)
    }

    #[test]
    fn published_chains_are_proposed_with_shared_kind() {
        let store = Arc::new(SharedDraftStore::new(2));
        store.publish(&[5, 6, 7, 8, 9]);
        let mut s = wrapped(&store);
        let mut b = DraftBatch::new(4);
        s.propose(&[1, 5], 4, &mut b);
        assert!(b.k() >= 1, "store-backed rows expected");
        assert_eq!(b.rows()[0].kind, StrategyKind::SharedFleet);
        assert_eq!(&b.row_tokens(0)[..3], &[6, 7, 8]);
        assert_eq!(store.hits(), 1);
    }

    #[test]
    fn bigram_layer_outranks_unigram_on_two_token_context() {
        let store = Arc::new(SharedDraftStore::new(1));
        // unigram layer for 6 learns "...7 8"; the bigram (5, 6) context
        // learns the more specific "...9 9"
        store.publish(&[6, 7, 8, 6, 7, 8]);
        store.publish(&[5, 6, 9, 9, 5, 6, 9, 9]);
        let mut s = wrapped(&store);
        let mut b = DraftBatch::new(4);
        s.propose(&[5, 6], 8, &mut b);
        assert!(b.k() >= 1);
        assert_eq!(b.row_tokens(0)[0], 9, "bigram posting layer is consulted first");
    }

    #[test]
    fn counts_rank_chains_and_misses_are_counted() {
        let store = Arc::new(SharedDraftStore::new(1));
        store.publish(&[5, 7]);
        store.publish(&[5, 7]);
        store.publish(&[5, 8]);
        let mut s = wrapped(&store);
        let mut b = DraftBatch::new(2);
        s.propose(&[5], 8, &mut b);
        assert_eq!(b.row_tokens(0)[0], 7, "seen-twice chain ranks first");
        let mut b2 = DraftBatch::new(2);
        s.propose(&[4242], 8, &mut b2);
        assert_eq!(b2.k(), 0);
        assert_eq!(store.misses(), 1);
    }

    #[test]
    fn greedy_shape_and_full_batch_add_nothing() {
        let store = Arc::new(SharedDraftStore::new(1));
        store.publish(&[5, 7, 8, 9]);
        let mut s = wrapped(&store);
        let mut b = DraftBatch::new(0); // w = 0: greedy
        s.propose(&[5], 4, &mut b);
        assert_eq!(b.k(), 0);
        let mut b = DraftBatch::new(4);
        b.push(vec![7, 1], StrategyKind::ContextNgram, 0);
        s.propose(&[5], 1, &mut b); // k already reached
        assert_eq!(b.k(), 1);
    }

    #[test]
    fn duplicate_first_tokens_are_deduped_against_inner_rows() {
        let store = Arc::new(SharedDraftStore::new(1));
        store.publish(&[5, 7, 8, 9, 5, 7, 8, 9]);
        let mut s = wrapped(&store);
        let mut b = DraftBatch::new(4);
        b.push(vec![7, 0], StrategyKind::ContextNgram, 0); // inner row opens with 7
        s.propose(&[5], 8, &mut b);
        for r in 1..b.k() {
            assert_ne!(b.row_tokens(r).first(), Some(&7), "row {r} duplicates the inner row");
        }
    }

    #[test]
    fn observe_buffers_and_drop_flushes_short_sequences() {
        let store = Arc::new(SharedDraftStore::new(2));
        {
            let mut s = wrapped(&store);
            s.observe(&[3, 4, 5], &[]);
            assert_eq!(store.publishes(), 0, "below the flush threshold: buffered");
        }
        assert!(store.publishes() >= 1, "drop publishes the remaining tail");
        let mut s2 = wrapped(&store);
        let mut b = DraftBatch::new(2);
        s2.propose(&[9, 3], 4, &mut b);
        assert!(b.k() >= 1);
        assert_eq!(b.row_tokens(0)[0], 4);
    }

    #[test]
    fn reset_flushes_but_the_store_persists() {
        let store = Arc::new(SharedDraftStore::new(1));
        let mut s = wrapped(&store);
        s.observe(&[1, 2, 3], &[]);
        s.reset();
        assert!(store.publishes() >= 1);
        let mut b = DraftBatch::new(2);
        s.propose(&[0, 1], 4, &mut b);
        assert!(b.k() >= 1, "chains survive reset — fleet memory");
    }

    #[test]
    fn eviction_keeps_tiny_tables_functional() {
        let store = SharedDraftStore::with_capacity(1, PROBE); // one probe window total
        for t in 0..200u32 {
            store.publish(&[t, t + 1, t + 2]);
        }
        // re-heat one key with single-upsert publishes (a 2-token span
        // touches only uni_key(500)), so nothing can evict it after
        for _ in 0..5 {
            store.publish(&[500, 501]);
        }
        let mut e = Entry::EMPTY;
        assert!(store.find(uni_key(500), &mut e));
        assert_eq!(e.chains[0][0], 501);
    }

    #[test]
    fn fingerprint_separates_leading_tokens_and_is_deterministic() {
        assert_eq!(fingerprint(&[1, 2, 3, 4, 99]), fingerprint(&[1, 2, 3, 4, 7]));
        assert_ne!(fingerprint(&[1, 2, 3, 4]), fingerprint(&[2, 2, 3, 4]));
        assert_eq!(fingerprint(&[]), fingerprint(&[]));
    }

    #[test]
    fn fingerprint_stats_accumulate_per_kind() {
        let store = SharedDraftStore::new(1);
        let fp = fingerprint(&[10, 11, 12, 13]);
        store.record_step(fp, StrategyKind::SessionCache, 4);
        store.record_step(fp, StrategyKind::SessionCache, 2);
        store.record_step(fp, StrategyKind::Empty, 0);
        let stats = store.fingerprint_stats(fp).expect("recorded class");
        assert_eq!(stats[StrategyKind::SessionCache.index()], (2, 6));
        assert_eq!(stats[StrategyKind::Empty.index()], (1, 0));
        assert!(store.fingerprint_stats(fp ^ 1).is_none());
    }

    /// The seqlock contract under real contention: a writer hammers
    /// publishes whose chains are all-same-token by construction, so ANY
    /// chain a concurrent reader extracts must be internally uniform — a
    /// torn (half-old, half-new) chain would mix token values.
    #[test]
    fn concurrent_readers_never_see_torn_chains() {
        let store = Arc::new(SharedDraftStore::with_capacity(1, PROBE));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let writer = {
            let store = store.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut v = 1u32;
                while !stop.load(Ordering::Relaxed) {
                    // span = key token 7 followed by an all-v tail: every
                    // chain written under ANY key is all-equal tokens
                    let span = [7, v, v, v, v, v, v, v, v, v];
                    store.publish(&span);
                    v = v % 97 + 1;
                }
            })
        };
        let mut checked = 0u64;
        let mut e = Entry::EMPTY;
        for i in 0..20_000u32 {
            let key = if i % 2 == 0 { uni_key(7) } else { uni_key(i % 97 + 1) };
            if !store.find(key, &mut e) {
                continue;
            }
            for j in 0..CHAINS_PER_ENTRY {
                let len = e.lens[j] as usize;
                if len == 0 {
                    continue;
                }
                let first = e.chains[j][0];
                assert!(
                    e.chains[j][..len].iter().all(|&t| t == first),
                    "torn chain under key {key}: {:?}",
                    &e.chains[j][..len]
                );
                checked += 1;
            }
        }
        stop.store(true, Ordering::Relaxed);
        writer.join().unwrap();
        assert!(checked > 0, "reader never observed a published entry");
    }
}
