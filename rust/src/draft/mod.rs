//! Learning-free draft strategies (paper §4) — the system's contribution.
//!
//! A strategy fills rows of a `DraftBatch` with `w` speculative tokens each;
//! the engine verifies all rows in one model call. Strategies are
//! negligible-cost by construction: table lookups (model-derived N-grams)
//! or context scans (context-derived N-grams), never a model call.

pub mod context_ngram;
pub mod jacobi;
pub mod mixed;
pub mod model_ngram;
pub mod session_cache;
pub mod tables;

pub use context_ngram::ContextNgram;
pub use jacobi::JacobiDraft;
pub use mixed::MixedStrategy;
pub use model_ngram::{ExtendedBigram, ModelBigram, ModelUnigram};
pub use session_cache::SessionNgramCache;
pub use tables::NgramTables;

use crate::tokenizer::TokenId;

/// Which strategy produced a draft row (for the paper's Fig. 4 ablations,
/// the adaptive controller's per-kind acceptance estimators, and the
/// per-strategy serving counters).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StrategyKind {
    /// context n-gram rows (SS4.2)
    ContextNgram,
    /// model bigram rows (SS4.1)
    ModelBigram,
    /// model unigram rows (App. B.1)
    ModelUnigram,
    /// extended bigram chain rows (SS4.1)
    ExtendedBigram,
    /// Jacobi decoding rows
    Jacobi,
    /// online session n-gram cache rows (extension beyond the paper)
    SessionCache,
    /// row k=0 baseline: greedy continuation column only (no draft)
    Empty,
}

impl StrategyKind {
    /// Every variant, in `index()` order — the adaptive estimators and the
    /// metrics counters are fixed arrays over this.
    pub const ALL: [StrategyKind; Self::COUNT] = [
        StrategyKind::ContextNgram,
        StrategyKind::ModelBigram,
        StrategyKind::ModelUnigram,
        StrategyKind::ExtendedBigram,
        StrategyKind::Jacobi,
        StrategyKind::SessionCache,
        StrategyKind::Empty,
    ];
    /// Number of variants (sizes the array-backed statistics).
    pub const COUNT: usize = 7;

    /// Dense index into `ALL` (used for array-backed per-kind statistics).
    /// `ALL` lists the variants in declaration order, so the discriminant
    /// IS the index — no hand-maintained mapping to drift.
    pub fn index(&self) -> usize {
        *self as usize
    }

    /// Stable label used in metrics and bench output.
    pub fn label(&self) -> &'static str {
        match self {
            StrategyKind::ContextNgram => "context-ngram",
            StrategyKind::ModelBigram => "model-bigram",
            StrategyKind::ModelUnigram => "model-unigram",
            StrategyKind::ExtendedBigram => "ext-bigram",
            StrategyKind::Jacobi => "jacobi",
            StrategyKind::SessionCache => "session-cache",
            StrategyKind::Empty => "empty",
        }
    }
}

/// One proposed row: `w` draft tokens plus provenance.
#[derive(Debug, Clone)]
pub struct DraftRow {
    /// the row's draft tokens (at most `w`)
    pub tokens: Vec<TokenId>,
    /// producing strategy
    pub kind: StrategyKind,
    /// rank of this row within its strategy's own ordering (0 = top)
    pub rank: usize,
    /// strategy-reported confidence in (0, 1]: count-based strategies
    /// (context n-gram, session cache) report normalized occurrence mass,
    /// table strategies fall back to the rank prior 1/(1+rank). Feeds the
    /// adaptive budget allocator's marginal-gain estimates.
    pub confidence: f64,
}

/// The (k, w) speculation batch handed to the verifier.
#[derive(Debug, Clone, Default)]
pub struct DraftBatch {
    /// proposed rows, in policy order
    pub rows: Vec<DraftRow>,
    /// speculation depth every row is truncated to
    pub w: usize,
}

impl DraftBatch {
    /// An empty batch of depth `w`.
    pub fn new(w: usize) -> Self {
        DraftBatch { rows: Vec::new(), w }
    }

    /// Append a row with the rank-prior confidence `1 / (1 + rank)`.
    pub fn push(&mut self, tokens: Vec<TokenId>, kind: StrategyKind, rank: usize) {
        let confidence = 1.0 / (1.0 + rank as f64);
        self.push_conf(tokens, kind, rank, confidence);
    }

    /// `push` with an explicit strategy-reported confidence (clamped to
    /// (0, 1]); strategies with real frequency counts use this.
    pub fn push_conf(
        &mut self,
        mut tokens: Vec<TokenId>,
        kind: StrategyKind,
        rank: usize,
        confidence: f64,
    ) {
        // over-length rows are truncated (the documented contract; see
        // `batch_truncates_to_w`)
        tokens.truncate(self.w);
        let confidence = confidence.clamp(f64::MIN_POSITIVE, 1.0);
        self.rows.push(DraftRow { tokens, kind, rank, confidence });
    }

    /// Current row count.
    pub fn k(&self) -> usize {
        self.rows.len()
    }

    /// Whether the batch already holds `k` rows.
    pub fn is_full(&self, k: usize) -> bool {
        self.rows.len() >= k
    }
}

/// Normalized count share for strategy confidence reporting: `count`'s
/// fraction of `total` observed occurrences (safe on an empty total).
/// Shared by every count-based strategy so their confidences stay
/// comparable inputs to the adaptive budget allocator.
pub fn count_share(count: u32, total: u32) -> f64 {
    count as f64 / total.max(1) as f64
}

/// A draft proposal source. `seq` is the whole token history *including*
/// the current last accepted token (`seq.last()` is the token whose KV is
/// not yet cached — the anchor of the speculation block).
pub trait DraftStrategy: Send {
    /// Stable human-readable strategy name.
    fn name(&self) -> &'static str;

    /// Append up to `k - batch.k()` rows of `batch.w` tokens each.
    /// Rows may be shorter than `w` (the engine pads by chaining or
    /// repeats); rows beyond `k` are ignored.
    fn propose(&mut self, seq: &[TokenId], k: usize, batch: &mut DraftBatch);

    /// Observe the verification outcome so stateful strategies (Jacobi)
    /// can update. `accepted` are the tokens emitted this step (including
    /// the bonus token); `model_out` is the verifier's full output for the
    /// chosen row.
    fn observe(&mut self, _accepted: &[TokenId], _model_out: &[TokenId]) {}

    /// Reset per-sequence state (called between requests).
    fn reset(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_truncates_to_w() {
        let mut b = DraftBatch::new(3);
        b.push(vec![1, 2, 3, 4, 5], StrategyKind::ModelBigram, 0);
        assert_eq!(b.rows[0].tokens, vec![1, 2, 3]);
        assert_eq!(b.k(), 1);
    }
}
