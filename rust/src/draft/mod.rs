//! Learning-free draft strategies (paper §4) — the system's contribution.
//!
//! A strategy fills rows of a `DraftBatch` with `w` speculative tokens each;
//! the engine verifies all rows in one model call. Strategies are
//! negligible-cost by construction: table lookups (model-derived N-grams)
//! or posting-list probes (context-derived N-grams, [`index::SuffixIndex`]),
//! never a model call.
//!
//! The batch itself is **arena-backed**: one contiguous token buffer plus
//! per-row descriptors (offset/len/kind/rank/confidence), reused across
//! steps via [`DraftBatch::reset`], so a steady-state decode step performs
//! zero draft-side heap allocations (pinned by `rust/tests/draft_alloc.rs`).

pub mod context_ngram;
pub mod index;
pub mod jacobi;
pub mod mixed;
pub mod model_ngram;
pub mod session_cache;
pub mod shared;
pub mod tables;
pub mod tree;

pub use context_ngram::ContextNgram;
pub use index::SuffixIndex;
pub use jacobi::JacobiDraft;
pub use mixed::MixedStrategy;
pub use model_ngram::{ExtendedBigram, ModelBigram, ModelUnigram};
pub use session_cache::SessionNgramCache;
pub use shared::{fingerprint, SharedDraftStore, SharedDraftStrategy};
pub use tables::NgramTables;
pub use tree::DraftTree;

use crate::tokenizer::TokenId;

/// Which strategy produced a draft row (for the paper's Fig. 4 ablations,
/// the adaptive controller's per-kind acceptance estimators, and the
/// per-strategy serving counters).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StrategyKind {
    /// context n-gram rows (SS4.2)
    ContextNgram,
    /// model bigram rows (SS4.1)
    ModelBigram,
    /// model unigram rows (App. B.1)
    ModelUnigram,
    /// extended bigram chain rows (SS4.1)
    ExtendedBigram,
    /// Jacobi decoding rows
    Jacobi,
    /// online session n-gram cache rows (extension beyond the paper)
    SessionCache,
    /// fleet-shared draft store rows ([`shared::SharedDraftStore`])
    SharedFleet,
    /// row k=0 baseline: greedy continuation column only (no draft)
    Empty,
}

impl StrategyKind {
    /// Every variant, in `index()` order — the adaptive estimators and the
    /// metrics counters are fixed arrays over this.
    pub const ALL: [StrategyKind; Self::COUNT] = [
        StrategyKind::ContextNgram,
        StrategyKind::ModelBigram,
        StrategyKind::ModelUnigram,
        StrategyKind::ExtendedBigram,
        StrategyKind::Jacobi,
        StrategyKind::SessionCache,
        StrategyKind::SharedFleet,
        StrategyKind::Empty,
    ];
    /// Number of variants (sizes the array-backed statistics).
    pub const COUNT: usize = 8;

    /// Dense index into `ALL` (used for array-backed per-kind statistics).
    /// `ALL` lists the variants in declaration order, so the discriminant
    /// IS the index — no hand-maintained mapping to drift.
    pub fn index(&self) -> usize {
        *self as usize
    }

    /// Stable label used in metrics and bench output.
    pub fn label(&self) -> &'static str {
        match self {
            StrategyKind::ContextNgram => "context-ngram",
            StrategyKind::ModelBigram => "model-bigram",
            StrategyKind::ModelUnigram => "model-unigram",
            StrategyKind::ExtendedBigram => "ext-bigram",
            StrategyKind::Jacobi => "jacobi",
            StrategyKind::SessionCache => "session-cache",
            StrategyKind::SharedFleet => "shared-fleet",
            StrategyKind::Empty => "empty",
        }
    }
}

/// One proposed row's descriptor: provenance plus the row's span within
/// the batch's shared token arena (read the tokens back with
/// [`DraftBatch::row_tokens`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DraftRow {
    /// start of the row's tokens in the batch arena
    off: usize,
    /// number of draft tokens in the row (at most the batch's `w`)
    len: usize,
    /// producing strategy
    pub kind: StrategyKind,
    /// rank of this row within its strategy's own ordering (0 = top)
    pub rank: usize,
    /// strategy-reported confidence in (0, 1]: count-based strategies
    /// (context n-gram, session cache) report normalized occurrence mass,
    /// table strategies fall back to the rank prior 1/(1+rank). Feeds the
    /// adaptive budget allocator's marginal-gain estimates.
    pub confidence: f64,
}

impl DraftRow {
    /// Number of draft tokens in the row.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the row carries no draft tokens (anchor-only padding).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// The (k, w) speculation batch handed to the verifier.
///
/// Arena layout: all rows' tokens live back-to-back in one contiguous
/// buffer; [`DraftRow`] descriptors carry each row's span. Strategies
/// append either whole slices ([`DraftBatch::push_conf`]) or token by
/// token through the open-row writer ([`DraftBatch::begin_row`] /
/// [`DraftBatch::push_token`] / [`DraftBatch::commit_row`]), so chain
/// construction needs no intermediate `Vec`. [`DraftBatch::reset`] clears
/// rows and arena while keeping both allocations, which is what makes the
/// per-step draft path allocation-free once warm.
#[derive(Debug, Clone, Default)]
pub struct DraftBatch {
    /// speculation depth every row is truncated to
    pub w: usize,
    /// contiguous token storage for all rows
    arena: Vec<TokenId>,
    /// per-row descriptors, in policy order
    rows: Vec<DraftRow>,
    /// arena offset of the currently open (uncommitted) row, if any
    open: Option<usize>,
}

impl DraftBatch {
    /// An empty batch of depth `w`.
    pub fn new(w: usize) -> Self {
        DraftBatch { w, arena: Vec::new(), rows: Vec::new(), open: None }
    }

    /// Clear all rows and re-target depth `w`, KEEPING the arena and
    /// descriptor allocations — the per-step reuse hook.
    pub fn reset(&mut self, w: usize) {
        self.w = w;
        self.arena.clear();
        self.rows.clear();
        self.open = None;
    }

    /// Append a row with the rank-prior confidence `1 / (1 + rank)`.
    pub fn push(&mut self, tokens: impl AsRef<[TokenId]>, kind: StrategyKind, rank: usize) {
        let confidence = 1.0 / (1.0 + rank as f64);
        self.push_conf(tokens, kind, rank, confidence);
    }

    /// `push` with an explicit strategy-reported confidence (clamped to
    /// (0, 1]); strategies with real frequency counts use this. The row is
    /// truncated to the batch depth `w` (the documented contract; see
    /// `batch_truncates_to_w`).
    pub fn push_conf(
        &mut self,
        tokens: impl AsRef<[TokenId]>,
        kind: StrategyKind,
        rank: usize,
        confidence: f64,
    ) {
        debug_assert!(self.open.is_none(), "push while a writer row is open");
        let s = tokens.as_ref();
        let len = s.len().min(self.w);
        let off = self.arena.len();
        self.arena.extend_from_slice(&s[..len]);
        let confidence = confidence.clamp(f64::MIN_POSITIVE, 1.0);
        self.rows.push(DraftRow { off, len, kind, rank, confidence });
    }

    /// Open a new row for token-by-token writing (chain strategies write
    /// straight into the arena; finish with [`Self::commit_row`] /
    /// [`Self::commit_row_conf`] or discard with [`Self::abort_row`]).
    pub fn begin_row(&mut self) {
        debug_assert!(self.open.is_none(), "begin_row while a row is open");
        self.open = Some(self.arena.len());
    }

    /// Append one token to the open row; silently ignored once the row
    /// has reached the batch depth `w` (same truncation contract as
    /// [`Self::push_conf`]).
    pub fn push_token(&mut self, t: TokenId) {
        let off = self.open.expect("push_token without begin_row");
        if self.arena.len() - off < self.w {
            self.arena.push(t);
        }
    }

    /// The open row's tokens so far (empty when no row is open).
    pub fn open_row(&self) -> &[TokenId] {
        match self.open {
            Some(off) => &self.arena[off..],
            None => &[],
        }
    }

    /// Commit the open row with the rank-prior confidence `1/(1+rank)`.
    pub fn commit_row(&mut self, kind: StrategyKind, rank: usize) {
        let confidence = 1.0 / (1.0 + rank as f64);
        self.commit_row_conf(kind, rank, confidence);
    }

    /// Commit the open row with an explicit confidence (clamped to (0, 1]).
    pub fn commit_row_conf(&mut self, kind: StrategyKind, rank: usize, confidence: f64) {
        let off = self.open.take().expect("commit_row without begin_row");
        let len = self.arena.len() - off;
        let confidence = confidence.clamp(f64::MIN_POSITIVE, 1.0);
        self.rows.push(DraftRow { off, len, kind, rank, confidence });
    }

    /// Discard the open row, returning its arena span for reuse.
    pub fn abort_row(&mut self) {
        if let Some(off) = self.open.take() {
            self.arena.truncate(off);
        }
    }

    /// The committed row descriptors, in policy order.
    pub fn rows(&self) -> &[DraftRow] {
        &self.rows
    }

    /// Row `r`'s draft tokens (a view into the arena).
    pub fn row_tokens(&self, r: usize) -> &[TokenId] {
        let d = &self.rows[r];
        &self.arena[d.off..d.off + d.len]
    }

    /// Drop row `r`'s descriptor (its arena span becomes dead space until
    /// the next [`Self::reset`] — cheap, and a batch lives one step).
    pub(crate) fn remove_row(&mut self, r: usize) {
        self.rows.remove(r);
    }

    /// Keep only the first `k` rows (descriptor truncation only).
    pub(crate) fn truncate_rows(&mut self, k: usize) {
        self.rows.truncate(k);
    }

    /// Current row count.
    pub fn k(&self) -> usize {
        self.rows.len()
    }

    /// Whether the batch already holds `k` rows.
    pub fn is_full(&self, k: usize) -> bool {
        self.rows.len() >= k
    }
}

/// Normalized count share for strategy confidence reporting: `count`'s
/// fraction of `total` observed occurrences (safe on an empty total).
/// Shared by every count-based strategy so their confidences stay
/// comparable inputs to the adaptive budget allocator.
pub fn count_share(count: u32, total: u32) -> f64 {
    count as f64 / total.max(1) as f64
}

/// A draft proposal source. `seq` is the whole token history *including*
/// the current last accepted token (`seq.last()` is the token whose KV is
/// not yet cached — the anchor of the speculation block).
pub trait DraftStrategy: Send {
    /// Stable human-readable strategy name.
    fn name(&self) -> &'static str;

    /// Append up to `k - batch.k()` rows of `batch.w` tokens each.
    /// Rows may be shorter than `w` (the engine pads by chaining or
    /// repeats); rows beyond `k` are ignored.
    fn propose(&mut self, seq: &[TokenId], k: usize, batch: &mut DraftBatch);

    /// Observe the verification outcome so stateful strategies (Jacobi)
    /// can update. `accepted` are the tokens emitted this step (including
    /// the bonus token); `model_out` is the verifier's full output for the
    /// chosen row.
    fn observe(&mut self, _accepted: &[TokenId], _model_out: &[TokenId]) {}

    /// Reset per-sequence state (called between requests).
    fn reset(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_truncates_to_w() {
        let mut b = DraftBatch::new(3);
        b.push(vec![1, 2, 3, 4, 5], StrategyKind::ModelBigram, 0);
        assert_eq!(b.row_tokens(0), vec![1, 2, 3]);
        assert_eq!(b.k(), 1);
    }

    #[test]
    fn writer_rows_truncate_commit_and_abort() {
        let mut b = DraftBatch::new(2);
        b.begin_row();
        b.push_token(7);
        b.push_token(8);
        b.push_token(9); // beyond w: ignored
        assert_eq!(b.open_row(), vec![7, 8]);
        b.commit_row(StrategyKind::ExtendedBigram, 1);
        assert_eq!(b.k(), 1);
        assert_eq!(b.row_tokens(0), vec![7, 8]);
        assert_eq!(b.rows()[0].rank, 1);
        assert!((b.rows()[0].confidence - 0.5).abs() < 1e-12);

        b.begin_row();
        b.push_token(5);
        b.abort_row();
        assert_eq!(b.k(), 1, "aborted rows leave no descriptor");
        b.push(vec![3], StrategyKind::ContextNgram, 0);
        assert_eq!(b.row_tokens(1), vec![3], "arena reuses the aborted span");
    }

    #[test]
    fn reset_keeps_capacity_and_clears_rows() {
        let mut b = DraftBatch::new(4);
        b.push(vec![1, 2, 3, 4], StrategyKind::ContextNgram, 0);
        b.reset(2);
        assert_eq!(b.k(), 0);
        assert_eq!(b.w, 2);
        b.push(vec![9, 9, 9], StrategyKind::ContextNgram, 0);
        assert_eq!(b.row_tokens(0), vec![9, 9]);
    }
}
