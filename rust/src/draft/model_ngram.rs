//! Model-derived N-gram strategies (paper §4.1).
//!
//! All three consume the tables extracted at build time from the trained
//! model weights (draft/tables.rs) — zero model calls at decode time.
//! Chains are written token-by-token straight into the `DraftBatch`
//! arena (the open-row writer), so proposing is allocation-free once the
//! batch is warm — no per-row scratch `Vec`, no per-row clone.

use std::sync::Arc;

use super::{DraftBatch, DraftStrategy, NgramTables, StrategyKind};
use crate::tokenizer::TokenId;

/// Extend the open row of `batch` with bigram top-1 chaining until it
/// reaches the batch depth: each next token is the bigram table's rank-0
/// continuation of the previous one (`anchor` seeds an empty row). The
/// shared tail rule of all three table strategies.
fn chain_to_depth(batch: &mut DraftBatch, tables: &NgramTables, anchor: TokenId) {
    while batch.open_row().len() < batch.w {
        let last = batch.open_row().last().copied().unwrap_or(anchor);
        let r = (last as usize).min(tables.bigram.rows - 1);
        batch.push_token(tables.bigram.at(r, 0));
    }
}

/// Top-k of p_M(. | last token), one row per rank; rows extended past the
/// first token with greedy bigram chains ("extended bigram", §4.1).
#[derive(Clone)]
pub struct ExtendedBigram {
    tables: Arc<NgramTables>,
}

impl ExtendedBigram {
    /// An extended-bigram drafter over `tables`.
    pub fn new(tables: Arc<NgramTables>) -> Self {
        ExtendedBigram { tables }
    }

    /// The backing tables (bench introspection).
    pub fn tables(&self) -> &NgramTables {
        &self.tables
    }
}

impl DraftStrategy for ExtendedBigram {
    fn name(&self) -> &'static str {
        "ext-bigram"
    }

    fn propose(&mut self, seq: &[TokenId], k: usize, batch: &mut DraftBatch) {
        let Some(&cur) = seq.last() else { return };
        let w = batch.w;
        let t = &self.tables;
        let mut rank = 0;
        while !batch.is_full(k) && rank < t.ext_bigram.cols {
            // the stored chain for (cur, rank), then bigram top-1 beyond
            // its depth — ext_chain's rule, written straight into the arena
            batch.begin_row();
            let r = (cur as usize).min(t.ext_bigram.rows - 1);
            for d in 0..w.min(t.ext_bigram.depth) {
                batch.push_token(t.ext_bigram.at3(r, rank, d));
            }
            chain_to_depth(batch, t, cur);
            batch.commit_row(StrategyKind::ExtendedBigram, rank);
            rank += 1;
        }
    }
}

/// Pure bigram: top-k single-token speculations (w effectively 1); rows are
/// padded to `w` with the bigram top-1 chain so they stay verifiable, but
/// rank/kind reflect the plain-bigram strategy for the Fig. 2 sweeps.
#[derive(Clone)]
pub struct ModelBigram {
    tables: Arc<NgramTables>,
}

impl ModelBigram {
    /// A plain-bigram drafter over `tables`.
    pub fn new(tables: Arc<NgramTables>) -> Self {
        ModelBigram { tables }
    }
}

impl DraftStrategy for ModelBigram {
    fn name(&self) -> &'static str {
        "model-bigram"
    }

    fn propose(&mut self, seq: &[TokenId], k: usize, batch: &mut DraftBatch) {
        let Some(&cur) = seq.last() else { return };
        let t = &self.tables;
        let row = (cur as usize).min(t.bigram.rows - 1);
        let mut rank = 0;
        while !batch.is_full(k) && rank < t.bigram.cols {
            batch.begin_row();
            batch.push_token(t.bigram.at(row, rank));
            chain_to_depth(batch, t, cur);
            batch.commit_row(StrategyKind::ModelBigram, rank);
            rank += 1;
        }
    }
}

/// Unigram from the embedding geometry (paper App. B.1): a static top-k
/// token list, independent of context. Each rank becomes a row; the row is
/// extended with bigram top-1 chains for w > 1.
#[derive(Clone)]
pub struct ModelUnigram {
    tables: Arc<NgramTables>,
}

impl ModelUnigram {
    /// A unigram drafter over `tables`.
    pub fn new(tables: Arc<NgramTables>) -> Self {
        ModelUnigram { tables }
    }
}

impl DraftStrategy for ModelUnigram {
    fn name(&self) -> &'static str {
        "model-unigram"
    }

    fn propose(&mut self, _seq: &[TokenId], k: usize, batch: &mut DraftBatch) {
        let t = &self.tables;
        let mut rank = 0;
        while !batch.is_full(k) && rank < t.unigram.cols {
            let first = t.unigram.at(0, rank);
            batch.begin_row();
            batch.push_token(first);
            chain_to_depth(batch, t, first);
            batch.commit_row(StrategyKind::ModelUnigram, rank);
            rank += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::draft::tables::Table;

    fn tables() -> Arc<NgramTables> {
        // vocab 4; bigram top-2 of x: [(x+1)%4, (x+2)%4]
        let bigram = Table::from_data(
            4, 2, 1,
            (0..4u32).flat_map(|x| vec![(x + 1) % 4, (x + 2) % 4]).collect(),
        );
        let unigram = Table::from_data(1, 3, 1, vec![2, 0, 1]);
        // ext chains depth 3: rank j of x -> [x+1+j, x+2+j, x+3+j] mod 4
        let ext = Table::from_data(
            4, 2, 3,
            (0..4u32)
                .flat_map(|x| (0..2u32).flat_map(move |j| {
                    vec![(x + 1 + j) % 4, (x + 2 + j) % 4, (x + 3 + j) % 4]
                }))
                .collect(),
        );
        Arc::new(NgramTables { bigram, unigram, ext_bigram: ext })
    }

    #[test]
    fn ext_bigram_rows_by_rank() {
        let mut s = ExtendedBigram::new(tables());
        let mut b = DraftBatch::new(3);
        s.propose(&[0, 1], 2, &mut b);
        assert_eq!(b.k(), 2);
        assert_eq!(b.row_tokens(0), vec![2, 3, 0]); // rank 0 chain of token 1
        assert_eq!(b.row_tokens(1), vec![3, 0, 1]); // rank 1 chain
        assert_eq!(b.rows()[0].kind, StrategyKind::ExtendedBigram);
    }

    #[test]
    fn bigram_pads_with_top1_chain() {
        let mut s = ModelBigram::new(tables());
        let mut b = DraftBatch::new(3);
        s.propose(&[1], 1, &mut b);
        // first = bigram(1, rank0) = 2; chain: top1(2)=3, top1(3)=0
        assert_eq!(b.row_tokens(0), vec![2, 3, 0]);
    }

    #[test]
    fn unigram_is_context_free() {
        let mut s = ModelUnigram::new(tables());
        let mut b1 = DraftBatch::new(1);
        let mut b2 = DraftBatch::new(1);
        s.propose(&[0], 3, &mut b1);
        s.propose(&[3, 2, 1], 3, &mut b2);
        let t1: Vec<Vec<u32>> = (0..b1.k()).map(|r| b1.row_tokens(r).to_vec()).collect();
        let t2: Vec<Vec<u32>> = (0..b2.k()).map(|r| b2.row_tokens(r).to_vec()).collect();
        assert_eq!(t1, t2);
        assert_eq!(b1.row_tokens(0), vec![2]); // unigram top-1
    }

    #[test]
    fn respects_existing_rows() {
        let mut s = ExtendedBigram::new(tables());
        let mut b = DraftBatch::new(2);
        b.push(vec![9, 9], StrategyKind::ContextNgram, 0);
        s.propose(&[1], 2, &mut b);
        assert_eq!(b.k(), 2);
        assert_eq!(b.rows()[0].kind, StrategyKind::ContextNgram);
        assert_eq!(b.rows()[1].kind, StrategyKind::ExtendedBigram);
    }

    #[test]
    fn empty_seq_no_rows() {
        let mut s = ModelBigram::new(tables());
        let mut b = DraftBatch::new(2);
        s.propose(&[], 2, &mut b);
        assert_eq!(b.k(), 0);
    }
}
