//! Jacobi decoding draft (Santilli et al. 2023) — the learning-free
//! baseline the paper builds on. The speculation for step t+1 is the
//! model's own (shifted) output from step t; the initial guess is a fixed
//! token. Implemented as a stateful strategy so it drops into the same
//! engine as the N-gram strategies.

use super::{DraftBatch, DraftStrategy, StrategyKind};
use crate::tokenizer::TokenId;

/// Jacobi-decoding draft state (previous step's model outputs).
#[derive(Debug)]
pub struct JacobiDraft {
    /// model outputs for the chosen row from the previous verification call
    prev_out: Vec<TokenId>,
    /// how many of prev_out were consumed as accepted tokens
    consumed: usize,
    init_token: TokenId,
}

impl JacobiDraft {
    /// A Jacobi drafter whose cold-start guess is `init_token`.
    pub fn new(init_token: TokenId) -> Self {
        JacobiDraft { prev_out: Vec::new(), consumed: 0, init_token }
    }
}

impl DraftStrategy for JacobiDraft {
    fn name(&self) -> &'static str {
        "jacobi"
    }

    fn propose(&mut self, _seq: &[TokenId], k: usize, batch: &mut DraftBatch) {
        if batch.is_full(k) {
            return;
        }
        let w = batch.w;
        // unconsumed leftover model predictions from last step; they were
        // produced past the accepted prefix so they are a (stale but often
        // good) guess at the upcoming tokens — the Jacobi fixed point.
        // Written straight into the batch arena (no per-step row Vec).
        batch.begin_row();
        for &t in self.prev_out.iter().skip(self.consumed).take(w) {
            batch.push_token(t);
        }
        while batch.open_row().len() < w {
            batch.push_token(self.init_token);
        }
        batch.commit_row(StrategyKind::Jacobi, 0);
    }

    fn observe(&mut self, accepted: &[TokenId], model_out: &[TokenId]) {
        // reuse the buffer (steady state: no allocation once warm)
        self.prev_out.clear();
        self.prev_out.extend_from_slice(model_out);
        self.consumed = accepted.len();
    }

    fn reset(&mut self) {
        self.prev_out.clear();
        self.consumed = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_uses_init_token() {
        let mut j = JacobiDraft::new(7);
        let mut b = DraftBatch::new(3);
        j.propose(&[1], 1, &mut b);
        assert_eq!(b.row_tokens(0), vec![7, 7, 7]);
    }

    #[test]
    fn reuses_unconsumed_model_output() {
        let mut j = JacobiDraft::new(0);
        // model emitted [5,6,7,8] for the chosen row; 2 tokens accepted
        j.observe(&[5, 6], &[5, 6, 7, 8]);
        let mut b = DraftBatch::new(3);
        j.propose(&[1], 1, &mut b);
        assert_eq!(b.row_tokens(0), vec![7, 8, 0]);
    }

    #[test]
    fn reset_clears_state() {
        let mut j = JacobiDraft::new(1);
        j.observe(&[2], &[2, 3]);
        j.reset();
        let mut b = DraftBatch::new(2);
        j.propose(&[9], 1, &mut b);
        assert_eq!(b.row_tokens(0), vec![1, 1]);
    }
}
