//! Arena-backed draft **tree**: the generalization of [`DraftBatch`]'s
//! flat rows to a token trie, so sibling continuations share their common
//! prefix instead of re-verifying it row by row (ROADMAP open item 1,
//! Medusa-style tree verification).
//!
//! Layout is struct-of-arrays over node index, all buffers reused across
//! steps via [`DraftTree::reset`] (zero steady-state heap allocations once
//! warm, pinned by `rust/tests/draft_alloc.rs`):
//!
//! - `tokens[i]`  — the token this node speculates,
//! - `parents[i]` — parent node index ([`NO_PARENT`] for the root),
//! - `depths[i]`  — root = 0,
//! - `rows/kinds/ranks[i]` — provenance of the batch row that first
//!   created the node (trace + adaptive feedback),
//! - `masks[i*words..]` — the node's **ancestor bitmask** over node
//!   indices, self-inclusive: bit `j` is set iff node `j` lies on the
//!   root-to-`i` path. This is the per-node attention mask the packed
//!   verifier consumes.
//!
//! Two structural invariants make the masks and the judge O(path):
//! `parents[i] < i` for every non-root node (ascending index order IS
//! root-to-leaf order), and siblings carry distinct tokens (trie insertion
//! never duplicates a child). Node 0 is always the anchor — the last
//! accepted token, whose KV is not yet cached — so a tree built from `k`
//! rows of `w` tokens holds at most `1 + k*w <= k*(w+1)` nodes and always
//! fits the source block's node budget; the slack is what overdraft rows
//! (extra width beyond `k`) spend.

use crate::tokenizer::TokenId;

use super::{DraftBatch, StrategyKind};

/// `parents[]` sentinel for the root node.
pub const NO_PARENT: u32 = u32::MAX;

/// A speculation trie built from draft rows, verified in one packed call.
///
/// Linear chains are the degenerate width-1 case: inserting one row yields
/// a path, and the judge's root-to-leaf walk reduces to the flat-row
/// longest-prefix rule.
#[derive(Debug, Clone, Default)]
pub struct DraftTree {
    tokens: Vec<TokenId>,
    parents: Vec<u32>,
    depths: Vec<u32>,
    rows: Vec<u32>,
    kinds: Vec<StrategyKind>,
    ranks: Vec<u32>,
    masks: Vec<u64>,
    words: usize,
    budget: usize,
    k: usize,
    w: usize,
}

impl DraftTree {
    /// An empty tree (call [`Self::reset`] before inserting).
    pub fn new() -> Self {
        Self::default()
    }

    /// Clear the tree and re-root it at `anchor`, KEEPING all buffer
    /// allocations. `(k, w)` is the source block shape: it fixes the node
    /// budget at `k * (w + 1)` (the packed verifier's position count) and
    /// is the artifact shape the runtime warms for this tree.
    pub fn reset(&mut self, anchor: TokenId, k: usize, w: usize) {
        self.k = k;
        self.w = w;
        self.budget = k * (w + 1);
        self.words = self.budget.div_ceil(64).max(1);
        self.tokens.clear();
        self.parents.clear();
        self.depths.clear();
        self.rows.clear();
        self.kinds.clear();
        self.ranks.clear();
        self.masks.clear();
        self.push_node(anchor, NO_PARENT, 0, StrategyKind::Empty, 0);
    }

    fn push_node(
        &mut self,
        token: TokenId,
        parent: u32,
        row: u32,
        kind: StrategyKind,
        rank: u32,
    ) -> u32 {
        let i = self.tokens.len();
        debug_assert!(i < self.budget, "push beyond node budget");
        self.tokens.push(token);
        self.parents.push(parent);
        self.rows.push(row);
        self.kinds.push(kind);
        self.ranks.push(rank);
        let depth =
            if parent == NO_PARENT { 0 } else { self.depths[parent as usize] + 1 };
        self.depths.push(depth);
        // mask = parent's mask | own bit (root: just own bit)
        let off = i * self.words;
        self.masks.resize(off + self.words, 0);
        if parent != NO_PARENT {
            let poff = parent as usize * self.words;
            for wd in 0..self.words {
                self.masks[off + wd] = self.masks[poff + wd];
            }
        }
        self.masks[off + i / 64] |= 1u64 << (i % 64);
        i as u32
    }

    /// The child of `parent` speculating `token`, if present. Linear scan:
    /// node counts are small (<= `k * (w + 1)`), and parents always have
    /// lower indices so the scan starts past `parent`.
    pub fn child_matching(&self, parent: u32, token: TokenId) -> Option<u32> {
        (parent as usize + 1..self.tokens.len())
            .find(|&i| self.parents[i] == parent && self.tokens[i] == token)
            .map(|i| i as u32)
    }

    /// Insert one draft row as a root-to-leaf path, sharing every prefix
    /// token already present. Tokens beyond the tree depth `w` are
    /// truncated (same contract as [`DraftBatch::push_conf`]); insertion
    /// stops early — keeping the partial prefix — once the node budget is
    /// exhausted. Returns the number of NEW nodes created (0 means the row
    /// was a duplicate or the budget is spent).
    pub fn insert_row(
        &mut self,
        tokens: &[TokenId],
        kind: StrategyKind,
        rank: usize,
        row: usize,
    ) -> usize {
        let mut cur = 0u32;
        let mut created = 0usize;
        for &t in tokens.iter().take(self.w) {
            if let Some(c) = self.child_matching(cur, t) {
                cur = c;
                continue;
            }
            if self.tokens.len() >= self.budget {
                break;
            }
            cur = self.push_node(t, cur, row as u32, kind, rank as u32);
            created += 1;
        }
        created
    }

    /// Insert every committed row of `batch` (in policy order — earlier
    /// rows claim shared-prefix provenance first, matching the flat
    /// judge's lowest-row tie-break).
    pub fn insert_batch(&mut self, batch: &DraftBatch) {
        for (r, d) in batch.rows().iter().enumerate() {
            self.insert_row(batch.row_tokens(r), d.kind, d.rank, r);
        }
    }

    /// Drop every node with index `>= n` (rollback hook). Because parents
    /// always precede children, any prefix of the node arrays is itself a
    /// well-formed tree; `n` is clamped to at least the root.
    pub fn truncate(&mut self, n: usize) {
        let n = n.clamp(1, self.tokens.len());
        self.tokens.truncate(n);
        self.parents.truncate(n);
        self.depths.truncate(n);
        self.rows.truncate(n);
        self.kinds.truncate(n);
        self.ranks.truncate(n);
        self.masks.truncate(n * self.words);
    }

    /// Node count (root included); 0 only before the first `reset`.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    /// Whether the tree holds no nodes (only before the first `reset`).
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Whether the node budget is spent.
    pub fn is_full(&self) -> bool {
        self.tokens.len() >= self.budget
    }

    /// The node budget `k * (w + 1)` fixed by the last `reset`.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// The source block shape `(k, w)` — the artifact the verifier warms.
    pub fn shape(&self) -> (usize, usize) {
        (self.k, self.w)
    }

    /// `u64` words per ancestor mask.
    pub fn words(&self) -> usize {
        self.words
    }

    /// All node tokens, by node index (node 0 = anchor).
    pub fn tokens(&self) -> &[TokenId] {
        &self.tokens
    }

    /// All parent pointers, by node index ([`NO_PARENT`] at the root).
    pub fn parents(&self) -> &[u32] {
        &self.parents
    }

    /// Concatenated self-inclusive ancestor masks, `words()` u64s per node.
    pub fn masks(&self) -> &[u64] {
        &self.masks
    }

    /// Node `i`'s self-inclusive ancestor mask.
    pub fn mask(&self, i: usize) -> &[u64] {
        &self.masks[i * self.words..(i + 1) * self.words]
    }

    /// Node `i`'s token.
    pub fn token(&self, i: usize) -> TokenId {
        self.tokens[i]
    }

    /// Node `i`'s depth (root = 0).
    pub fn depth(&self, i: usize) -> usize {
        self.depths[i] as usize
    }

    /// Deepest node's depth (0 for a root-only tree).
    pub fn max_depth(&self) -> usize {
        self.depths.iter().copied().max().unwrap_or(0) as usize
    }

    /// Batch row that first created node `i` (0 for the root).
    pub fn node_row(&self, i: usize) -> usize {
        self.rows[i] as usize
    }

    /// Strategy that first created node `i` (`Empty` for the root).
    pub fn node_kind(&self, i: usize) -> StrategyKind {
        self.kinds[i]
    }

    /// Strategy-local rank of the row that first created node `i`.
    pub fn node_rank(&self, i: usize) -> usize {
        self.ranks[i] as usize
    }

    /// Number of leaves (nodes with no children); 1 for a root-only tree.
    /// Allocation-free (children always have higher indices, so a node is
    /// a leaf iff no later node points back at it).
    pub fn leaf_count(&self) -> usize {
        let n = self.tokens.len();
        (0..n)
            .filter(|&i| !(i + 1..n).any(|j| self.parents[j] == i as u32))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mask_bits(tree: &DraftTree, i: usize) -> Vec<usize> {
        let mut out = Vec::new();
        for (wd, &m) in tree.mask(i).iter().enumerate() {
            for b in 0..64 {
                if m & (1u64 << b) != 0 {
                    out.push(wd * 64 + b);
                }
            }
        }
        out
    }

    #[test]
    fn linear_chain_is_degenerate_width_one() {
        let mut t = DraftTree::new();
        t.reset(7, 1, 3);
        assert_eq!(t.insert_row(&[1, 2, 3], StrategyKind::ContextNgram, 0, 0), 3);
        assert_eq!(t.len(), 4);
        assert_eq!(t.max_depth(), 3);
        assert_eq!(t.leaf_count(), 1);
        assert_eq!(t.parents(), &[NO_PARENT, 0, 1, 2]);
        assert_eq!(mask_bits(&t, 3), vec![0, 1, 2, 3]);
    }

    #[test]
    fn siblings_share_their_common_prefix() {
        let mut t = DraftTree::new();
        t.reset(9, 3, 3);
        t.insert_row(&[1, 2, 3], StrategyKind::ContextNgram, 0, 0);
        // shares [1, 2], branches at the last token
        assert_eq!(t.insert_row(&[1, 2, 4], StrategyKind::ModelBigram, 0, 1), 1);
        // duplicate row adds nothing
        assert_eq!(t.insert_row(&[1, 2, 3], StrategyKind::ModelBigram, 1, 2), 0);
        assert_eq!(t.len(), 5);
        assert_eq!(t.leaf_count(), 2);
        // branch node's mask covers root + shared prefix + itself only
        let j = t.child_matching(2, 4).unwrap() as usize;
        assert_eq!(mask_bits(&t, j), vec![0, 1, 2, j]);
        // provenance of the shared prefix belongs to the FIRST row
        assert_eq!(t.node_kind(1), StrategyKind::ContextNgram);
        assert_eq!(t.node_kind(j), StrategyKind::ModelBigram);
    }

    #[test]
    fn budget_caps_insertion_keeping_partial_prefix() {
        let mut t = DraftTree::new();
        t.reset(0, 1, 2); // budget = 3 nodes
        t.insert_row(&[1, 2], StrategyKind::ContextNgram, 0, 0);
        assert!(t.is_full());
        // disjoint row: no room, partial prefix shares nothing
        assert_eq!(t.insert_row(&[5, 6], StrategyKind::Jacobi, 0, 1), 0);
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn rows_truncate_to_w() {
        let mut t = DraftTree::new();
        t.reset(0, 2, 2);
        assert_eq!(t.insert_row(&[1, 2, 3, 4], StrategyKind::ContextNgram, 0, 0), 2);
        assert_eq!(t.max_depth(), 2);
    }

    #[test]
    fn truncate_drops_suffix_nodes_and_masks() {
        let mut t = DraftTree::new();
        t.reset(9, 2, 2);
        t.insert_row(&[1, 2], StrategyKind::ContextNgram, 0, 0);
        t.insert_row(&[3, 4], StrategyKind::ModelBigram, 0, 1);
        let n = t.len();
        t.truncate(3);
        assert_eq!(t.len(), 3);
        assert!(n > 3);
        assert_eq!(t.masks().len(), 3 * t.words());
        // re-inserting reuses the surviving prefix, no stale children
        assert_eq!(t.child_matching(0, 3), None);
        t.insert_row(&[3, 4], StrategyKind::ModelBigram, 0, 1);
        assert_eq!(t.len(), 5);
    }

    #[test]
    fn reset_keeps_capacity_and_reroots() {
        let mut t = DraftTree::new();
        t.reset(1, 2, 4);
        t.insert_row(&[1, 2, 3, 4], StrategyKind::ContextNgram, 0, 0);
        t.reset(5, 2, 2);
        assert_eq!(t.len(), 1);
        assert_eq!(t.token(0), 5);
        assert_eq!(t.budget(), 6);
        assert_eq!(t.max_depth(), 0);
        assert_eq!(t.leaf_count(), 1);
    }
}
