//! Greedy acceptance logic for batched speculation (pure — heavily unit-
//! and property-tested).
//!
//! Block layout for row r (anchored at absolute position n = cache_len):
//!   input  tokens:  [t_cur, d1, d2, ..., dw]        (t_cur already decided)
//!   model outputs:  [o0,    o1, ..., ow]            oi = greedy prediction
//!                                                   after consuming input i
//! Draft di is accepted iff the model, having consumed the accepted prefix,
//! predicts it: d1 == o0, d2 == o1, ... The emitted tokens for a row with
//! accepted length a are d1..da plus the bonus token o_a — so every call
//! emits >= 1 token and the output stream is EXACTLY the base model's
//! greedy stream (the correctness invariant tested in prop tests).

use crate::draft::{DraftBatch, DraftTree};
use crate::tokenizer::TokenId;

/// Result of judging one verification call.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Acceptance {
    /// winning row index
    pub row: usize,
    /// number of accepted draft tokens (0..=w)
    pub accepted: usize,
    /// tokens to emit: accepted drafts + bonus (len = accepted + 1)
    pub emitted: Vec<TokenId>,
}

/// Accepted length of a single row.
pub fn row_accept_len(drafts: &[TokenId], outputs: &[TokenId]) -> usize {
    let mut a = 0;
    while a < drafts.len() && a < outputs.len() && drafts[a] == outputs[a] {
        a += 1;
    }
    a
}

/// Judge all rows of a verification call and pick the winner.
///
/// `next_ids` is row-major (k, w1) model output; `batch.row_tokens(r)`
/// holds row r's drafts (possibly shorter than w — missing positions never
/// match). Ties prefer the lowest row index, which (with the paper's
/// context-first allocation) prefers context-n-gram rows.
pub fn judge(batch: &DraftBatch, next_ids: &[TokenId], w1: usize) -> Acceptance {
    let k = batch.k();
    debug_assert_eq!(next_ids.len(), k * w1);
    let mut best_row = 0;
    let mut best_a = 0;
    for r in 0..k {
        let out = &next_ids[r * w1..(r + 1) * w1];
        let a = row_accept_len(batch.row_tokens(r), out);
        if a > best_a {
            best_a = a;
            best_row = r;
        }
    }
    let out = &next_ids[best_row * w1..(best_row + 1) * w1];
    let mut emitted = Vec::with_capacity(best_a + 1);
    emitted.extend_from_slice(&batch.row_tokens(best_row)[..best_a]);
    emitted.push(out[best_a]); // bonus token
    Acceptance { row: best_row, accepted: best_a, emitted }
}

/// Result of judging one TREE verification call.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TreeAcceptance {
    /// accepted node indices in root-to-leaf order (root excluded)
    pub path: Vec<u32>,
    /// deepest accepted node (the root, node 0, when nothing accepted)
    pub node: usize,
    /// number of accepted draft tokens (= `path.len()`)
    pub accepted: usize,
    /// tokens to emit: accepted path tokens + bonus (len = accepted + 1)
    pub emitted: Vec<TokenId>,
}

/// Judge a tree verification call: walk from the root, at each node
/// following the child whose token equals the model's prediction AT that
/// node. Siblings carry distinct tokens (trie construction), so at most
/// one child can match — the walk accepts the UNIQUE root-to-leaf path the
/// model's argmax traces, and the bonus token is the prediction at the
/// deepest accepted node. By induction each emitted token is exactly the
/// greedy prediction given everything emitted before it, so the output
/// stream stays byte-identical to plain greedy decoding (and to flat-row
/// judging of the same drafts — the flat judge is the width-1 case).
///
/// `next_ids[j]` is the model's prediction after consuming node `j`'s
/// root-to-node path (a (n, 1) [`crate::runtime::StepOutput`]).
pub fn judge_tree(tree: &DraftTree, next_ids: &[TokenId]) -> TreeAcceptance {
    debug_assert_eq!(next_ids.len(), tree.len());
    let mut cur = 0u32;
    let mut path = Vec::new();
    let mut emitted = Vec::new();
    while let Some(c) = tree.child_matching(cur, next_ids[cur as usize]) {
        path.push(c);
        emitted.push(tree.token(c as usize));
        cur = c;
    }
    emitted.push(next_ids[cur as usize]); // bonus token
    TreeAcceptance { accepted: path.len(), node: cur as usize, path, emitted }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::draft::StrategyKind;

    fn batch(rows: Vec<Vec<TokenId>>, w: usize) -> DraftBatch {
        let mut b = DraftBatch::new(w);
        for r in rows {
            b.push(r, StrategyKind::ContextNgram, 0);
        }
        b
    }

    #[test]
    fn accepts_longest_prefix() {
        // w = 3, k = 2. outputs row0: [9, 8, 7, 6]; row1: [5, 6, 9, 9]
        let b = batch(vec![vec![9, 8, 0], vec![5, 6, 7]], 3);
        let out = vec![9, 8, 7, 6, 5, 6, 9, 9];
        let a = judge(&b, &out, 4);
        // row0 accepts 2 ([9,8]), bonus 7; row1 accepts 2 ([5,6]), bonus 9.
        // tie at 2 -> row 0 wins
        assert_eq!(a.row, 0);
        assert_eq!(a.accepted, 2);
        assert_eq!(a.emitted, vec![9, 8, 7]);
    }

    #[test]
    fn zero_accept_still_emits_bonus() {
        let b = batch(vec![vec![1, 2]], 2);
        let out = vec![7, 8, 9];
        let a = judge(&b, &out, 3);
        assert_eq!(a.accepted, 0);
        assert_eq!(a.emitted, vec![7]); // the model's own next token
    }

    #[test]
    fn full_accept_emits_w_plus_one() {
        let b = batch(vec![vec![4, 5, 6]], 3);
        let out = vec![4, 5, 6, 7];
        let a = judge(&b, &out, 4);
        assert_eq!(a.accepted, 3);
        assert_eq!(a.emitted, vec![4, 5, 6, 7]);
    }

    #[test]
    fn longer_accept_beats_earlier_row() {
        let b = batch(vec![vec![1, 0], vec![1, 2]], 2);
        let out = vec![1, 2, 3, 1, 2, 3];
        let a = judge(&b, &out, 3);
        assert_eq!(a.row, 1);
        assert_eq!(a.accepted, 2);
    }

    #[test]
    fn short_row_never_matches_missing_positions() {
        let b = batch(vec![vec![1]], 3); // row shorter than w
        let out = vec![1, 2, 3, 4];
        let a = judge(&b, &out, 4);
        assert_eq!(a.accepted, 1);
        assert_eq!(a.emitted, vec![1, 2]);
    }

    #[test]
    fn greedy_stream_invariant_property() {
        // For ANY drafts, the emitted tokens must equal what sequential
        // greedy decoding would produce, given the model outputs are a
        // function of the accepted prefix. We simulate a deterministic
        // "model" out(prefix) = hash of prefix, and check equality.
        use crate::util::{prop, rng::Rng};
        fn model_next(prefix: &[TokenId]) -> TokenId {
            let mut h = 1469598103934665603u64;
            for &t in prefix {
                h = (h ^ t as u64).wrapping_mul(1099511628211);
            }
            (h % 64) as TokenId
        }
        prop::check(300, |rng: &mut Rng| {
            let w = rng.range(1, 6);
            let k = rng.range(1, 5);
            let plen = rng.range(1, 8);
            let prefix: Vec<TokenId> = prop::vec_u32(rng, plen, 0..64);
            // build drafts: random, sometimes copying the true continuation
            let mut b = DraftBatch::new(w);
            for _ in 0..k {
                let mut row = Vec::with_capacity(w);
                let mut p = prefix.clone();
                for _ in 0..w {
                    let t = if rng.f64() < 0.6 {
                        model_next(&p)
                    } else {
                        rng.below(64) as TokenId
                    };
                    row.push(t);
                    p.push(t);
                }
                b.push(row, StrategyKind::ContextNgram, 0);
            }
            // simulate the verifier: out[r][i] = model_next(prefix ++ row[..i])
            let w1 = w + 1;
            let mut out = vec![0; k * w1];
            for r in 0..b.k() {
                let row = b.row_tokens(r);
                let mut p = prefix.clone();
                for i in 0..w1 {
                    out[r * w1 + i] = model_next(&p);
                    if i < row.len() {
                        p.push(row[i]);
                    }
                }
            }
            let acc = judge(&b, &out, w1);
            // sequential greedy reference for the emitted span
            let mut p = prefix.clone();
            for &e in &acc.emitted {
                if e != model_next(&p) {
                    return false;
                }
                p.push(e);
            }
            acc.emitted.len() == acc.accepted + 1
        });
    }

    #[test]
    fn tree_judge_follows_the_argmax_branch() {
        // root=9 with two children: 1 (row 0) and 2 (row 1); 2 extends to 5
        let mut t = DraftTree::new();
        t.reset(9, 2, 2);
        t.insert_row(&[1, 7], StrategyKind::ContextNgram, 0, 0);
        t.insert_row(&[2, 5], StrategyKind::ModelBigram, 0, 1);
        // nodes: 0=root(9), 1=1, 2=7, 3=2, 4=5
        // model: after root predict 2 -> node 3; after [2] predict 5 ->
        // node 4; after [2,5] predict 8 (bonus)
        let out = vec![2, 0, 0, 5, 8];
        let a = judge_tree(&t, &out);
        assert_eq!(a.path, vec![3, 4]);
        assert_eq!(a.node, 4);
        assert_eq!(a.accepted, 2);
        assert_eq!(a.emitted, vec![2, 5, 8]);
    }

    #[test]
    fn tree_zero_accept_emits_root_bonus() {
        let mut t = DraftTree::new();
        t.reset(9, 1, 2);
        t.insert_row(&[1, 2], StrategyKind::ContextNgram, 0, 0);
        let out = vec![4, 4, 4];
        let a = judge_tree(&t, &out);
        assert_eq!(a.accepted, 0);
        assert_eq!(a.node, 0);
        assert_eq!(a.emitted, vec![4]);
    }

    #[test]
    fn tree_judge_equals_flat_judge_on_a_single_row() {
        // width-1 degenerate case: one row, tree walk == longest prefix
        use crate::util::{prop, rng::Rng};
        prop::check(200, |rng: &mut Rng| {
            let w = rng.range(1, 6);
            let row: Vec<TokenId> = prop::vec_u32(rng, w, 0..8);
            let mut b = DraftBatch::new(w);
            b.push(row.clone(), StrategyKind::ContextNgram, 0);
            let mut t = DraftTree::new();
            t.reset(99, 1, w);
            t.insert_row(&row, StrategyKind::ContextNgram, 0, 0);
            // random model outputs, often matching the drafts
            let w1 = w + 1;
            let flat_out: Vec<TokenId> = (0..w1)
                .map(|i| {
                    if i < w && rng.f64() < 0.7 {
                        row[i]
                    } else {
                        rng.below(8) as TokenId
                    }
                })
                .collect();
            // tree outputs: node j (depth d = j) predicts flat_out[d]
            // (node 0 = root = depth 0, node j = row[j-1])
            let tree_out: Vec<TokenId> = (0..t.len()).map(|j| flat_out[j]).collect();
            let fa = judge(&b, &flat_out, w1);
            let ta = judge_tree(&t, &tree_out);
            ta.accepted == fa.accepted && ta.emitted == fa.emitted
        });
    }
}
