//! Continuous-batching engine: cross-request batched verification.
//!
//! The paper's premise is that the batch dimension of a verification call
//! is nearly free while the call stays memory-bound (§3) — and the seed
//! engine only ever spent that dimension on speculation rows *of one
//! sequence*. `BatchedEngine` spends it on both axes at once: per step it
//! gathers draft rows from ALL active sequences into one packed
//! (sum of k_i, w+1) verification call, judges and commits each sequence's
//! lanes independently against its own pooled KV lane, and admits/retires
//! sequences between steps (continuous batching, vLLM-style).
//!
//! Correctness invariant — unchanged from [`super::SpecDecoder`] and
//! enforced by the equivalence tests in `rust/tests/batched_engine.rs`:
//! every sequence's output stream is exactly the base model's greedy
//! continuation of its prompt, regardless of what else rides in the batch.
//!
//! Shape selection across sequences: all blocks in one packed call must
//! share the speculation depth `w`. Sequences are first split by DEPTH
//! CLASS — greedy (w = 0) vs speculative — so a greedy request can never
//! drag a speculative group's common depth to 0 (the mixed-traffic
//! regression `rust/tests/pool.rs` pins down); each step then picks the
//! largest common `w` every *speculative* sequence can still afford
//! (config + remaining lane room) and refits each sequence's row count
//! `k_i` to its class depth. Sequences that cannot meet their class's
//! common depth (odd artifact sets) fall back to their own shape; every
//! distinct depth runs as its own packed group within the same step.

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::adaptive::{budget, SeqController, StepFeedback};
use crate::config::EngineConfig;
use crate::costmodel::CostModel;
use crate::draft::{DraftBatch, DraftStrategy, DraftTree, StrategyKind};
use crate::kvcache::{KvSeq, KvSlot, KvStore, PageStats};
use crate::runtime::{ModelRuntime, PackedBlock, PackedTreeBlock};
use crate::tokenizer::TokenId;
use crate::trace::{FlightRecorder, Phase, PhaseTimer, StepEvent};

use super::{
    assemble_block_into, judge_and_commit, judge_and_commit_tree, make_trace, make_tree_trace,
    pad_batch, GenResult,
};

/// Identifier of one admitted sequence, unique within an engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SeqId(pub u64);

/// Online row-budget derivation for the elastic serving path.
///
/// When installed on a [`BatchedEngine`], every step recomputes its
/// packed-row budget as the largest batch that still stays memory-bound
/// for the step's speculation depth and the CURRENT context lengths
/// ([`CostModel::memory_bound_rows`]) — the phase-transition knee moves
/// as sequences grow, so a boot-time `--budget` number is wrong for most
/// of a long decode. The engine's static [`BatchedEngine::budget`], when
/// also set, acts as an operator CAP on the derived value, never as the
/// value itself.
pub struct AutoBudget {
    /// paper-scale cost model the budget is derived on (normally the
    /// served model's analog, [`CostModel::for_analog`])
    pub cm: CostModel,
    /// slowdown tolerance handed to [`CostModel::memory_bound_rows`]:
    /// rows may cost at most this factor over a one-row call of the same
    /// depth before the budget cuts them off
    pub slack: f64,
}

impl AutoBudget {
    /// Default slowdown tolerance: rows are admitted while they cost at
    /// most 15% over the memory-bound floor — inside the flat region of
    /// the paper's Fig. 1 curves for every analog.
    pub const DEFAULT_SLACK: f64 = 1.15;

    /// An auto-budget with the default slack for `cm`.
    pub fn new(cm: CostModel) -> Self {
        AutoBudget { cm, slack: Self::DEFAULT_SLACK }
    }
}

/// One packed verification call, as the engine saw it (feeds the batched
/// bench's cost-model throughput accounting). Consumers price a call at
/// `rows * (w + 1)` positions; a TREE-mode group therefore reports
/// `rows = total nodes, w = 0` — one position per node, exactly what the
/// masked call runs.
#[derive(Debug, Clone)]
pub struct PackedTrace {
    /// common speculation depth of the call (0 for tree-mode groups)
    pub w: usize,
    /// total rows across all sequences (the packed batch size, sum of k_i;
    /// total NODES for a tree-mode group)
    pub rows: usize,
    /// largest context length among participating lanes
    pub max_ctx: usize,
    /// number of sequences that rode this call
    pub seqs: usize,
    /// engine step index this call belonged to (a step with a ragged
    /// depth set issues several packed calls; the row budget bounds their
    /// SUM per step — asserted in `rust/tests/adaptive.rs`)
    pub step: u64,
}

/// Reusable per-lane draft scratch: the arena-backed batch plus the
/// assembled (k, w+1) block buffer. One slot per co-drafted sequence,
/// pooled on the engine and reused every step, so the steady-state draft
/// path performs no heap allocation (the slots only grow when a step
/// drafts more sequences at once than any step before it).
#[derive(Default)]
struct DraftSlot {
    batch: DraftBatch,
    block: Vec<TokenId>,
    tree: DraftTree,
}

struct SeqState {
    id: SeqId,
    cfg: EngineConfig,
    /// prompt ++ generated; last element is the anchor (KV not yet cached)
    seq: Vec<TokenId>,
    strategy: Box<dyn DraftStrategy>,
    /// adaptive mode: plans this sequence's (k, w), drafts via its bandit
    /// arm and bids for budget rows; `strategy` is ignored when set
    controller: Option<SeqController>,
    kv: KvSeq,
    res: GenResult,
    /// set when the sequence can no longer step (cache exhausted)
    done: bool,
    t_decode: Instant,
}

impl SeqState {
    fn finished(&self) -> bool {
        self.done || self.res.tokens.len() >= self.cfg.max_new_tokens
    }
}

/// Multi-sequence speculative decoding over a pooled KV cache.
///
/// # Example
///
/// Serve two sequences through one engine (each step verifies both in a
/// single packed call); [`generate_all`] drives admit/step to completion:
///
/// ```
/// use ngrammys::config::EngineConfig;
/// use ngrammys::draft::DraftStrategy;
/// use ngrammys::engine::batched::generate_all;
/// use ngrammys::engine::{BatchedEngine, NoDraft};
/// use ngrammys::runtime::ModelRuntime;
///
/// let manifest = ngrammys::testkit::manifest();
/// let runtime = ModelRuntime::load(manifest.model("small")?)?;
/// let mut eng = BatchedEngine::new(&runtime, 2); // two pooled KV lanes
/// let cfg = EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 6 };
/// let reqs: Vec<(Vec<u32>, Box<dyn DraftStrategy>, EngineConfig)> = vec![
///     (vec![1, 2, 3], Box::new(NoDraft), cfg.clone()),
///     (vec![7, 8, 9], Box::new(NoDraft), cfg),
/// ];
/// let results = generate_all(&mut eng, reqs)?;
/// assert_eq!(results.len(), 2);
/// assert!(results.iter().all(|r| r.tokens.len() == 6));
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct BatchedEngine<'rt> {
    /// the loaded model every lane executes against
    pub runtime: &'rt ModelRuntime,
    /// collect per-step traces on each sequence's GenResult + packed traces
    pub collect_traces: bool,
    /// one record per packed verification call (when collect_traces)
    pub packed_traces: Vec<PackedTrace>,
    /// Global row budget per step: the packed batch size `sum k_i` across
    /// ALL of a step's calls is capped at `max(B, active)` (every active
    /// sequence keeps at least its anchor row; keep `B >= lanes` for a
    /// strict `sum <= B`). Rows are distributed by marginal expected
    /// acceptance — adaptive sequences bid with their controller's
    /// estimates, static ones with the rank-decay prior. When
    /// [`Self::auto_budget`] is also set, this value is demoted to an
    /// operator CAP on the per-step derived budget.
    pub budget: Option<usize>,
    /// Elastic mode: derive each step's row budget online from the cost
    /// model instead of using [`Self::budget`] directly (see
    /// [`AutoBudget`]).
    pub auto_budget: Option<AutoBudget>,
    /// The budget the most recent [`Self::step`] actually enforced
    /// (derived or static) — exported as the `ngrammys_derived_budget`
    /// gauge by the elastic scheduler.
    last_budget: Option<usize>,
    /// Flight recorder for per-step phase timings + strategy provenance
    /// (one [`StepEvent`] per packed group). `None` (the default) skips
    /// all timing; a disabled recorder costs one branch per group. Never
    /// affects emitted tokens — pinned by `rust/tests/trace.rs`.
    pub recorder: Option<std::sync::Arc<FlightRecorder>>,
    /// Tree speculation (`--tree`): every same-depth group trie-packs its
    /// sequences' overdrafted rows and verifies all nodes in one masked
    /// call ([`PackedTreeBlock`]). Output streams stay byte-identical to
    /// flat-row mode and to plain greedy — pinned by
    /// `rust/tests/tree_equiv.rs`.
    pub tree: bool,
    pool: KvStore,
    active: Vec<SeqState>,
    next_id: u64,
    /// completed engine steps (stamps `PackedTrace::step`)
    steps_done: u64,
    /// the model's sorted (k, w) artifact grid, hoisted out of the
    /// per-step hot loop (adaptive planning scans it every step)
    shape_grid: Vec<(usize, usize)>,
    /// pooled per-sequence draft scratch (arena batches + block buffers),
    /// reused across steps so drafting allocates nothing in steady state
    draft_scratch: Vec<DraftSlot>,
}

impl<'rt> BatchedEngine<'rt> {
    /// An engine with `max_concurrency` pooled KV lanes for `runtime`'s
    /// model.
    pub fn new(runtime: &'rt ModelRuntime, max_concurrency: usize) -> Self {
        let d = &runtime.artifacts().dims;
        let pool = KvStore::lanes(d.n_layers, d.max_len, d.n_heads, d.head_dim,
                                  max_concurrency.max(1));
        Self::with_store(runtime, pool)
    }

    /// An engine on a paged KV pool with prefix sharing: up to
    /// `max_concurrency` sequences over `n_pages` pages of `page_size`
    /// positions each. `n_pages = 0` derives the lane-equivalent budget
    /// (`max_concurrency * pages_for(max_len)`), which pins the same bytes
    /// as the lane pool — admissions beyond `max_concurrency` lanes then
    /// come purely from prefix sharing and right-sized reservations.
    /// Output streams are byte-identical to lane mode (the paged pool
    /// writes/reads the same dense geometry through page indirection).
    pub fn new_paged(
        runtime: &'rt ModelRuntime,
        max_concurrency: usize,
        page_size: usize,
        n_pages: usize,
    ) -> Self {
        let d = &runtime.artifacts().dims;
        let seq_cap = max_concurrency.max(1);
        let page_size = page_size.max(1).min(d.max_len);
        let n_pages = if n_pages == 0 {
            seq_cap * d.max_len.div_ceil(page_size)
        } else {
            n_pages
        };
        let pool = KvStore::paged(
            d.n_layers, d.max_len, d.n_heads, d.head_dim, page_size, n_pages, seq_cap,
        );
        Self::with_store(runtime, pool)
    }

    fn with_store(runtime: &'rt ModelRuntime, pool: KvStore) -> Self {
        BatchedEngine {
            runtime,
            collect_traces: false,
            packed_traces: Vec::new(),
            budget: None,
            auto_budget: None,
            last_budget: None,
            recorder: None,
            tree: false,
            pool,
            active: Vec::new(),
            next_id: 0,
            steps_done: 0,
            shape_grid: runtime.artifacts().step_shapes(),
            draft_scratch: Vec::new(),
        }
    }

    /// An engine with a per-step packed-row budget (see [`Self::budget`]).
    pub fn with_budget(
        runtime: &'rt ModelRuntime,
        max_concurrency: usize,
        budget: Option<usize>,
    ) -> Self {
        let mut e = Self::new(runtime, max_concurrency);
        e.budget = budget;
        e
    }

    /// Max concurrent sequences (the lane-pool size).
    pub fn capacity(&self) -> usize {
        self.pool.capacity()
    }

    /// Grow or shrink the pool toward `target` concurrent sequences and
    /// return the achieved capacity — the elastic scheduler's scale knob.
    /// Growth is immediate; lane-mode shrinking reclaims only free lanes
    /// (see [`crate::kvcache::KvPool::resize`]) and paged-mode shrinking
    /// just lowers the admission cap, so in-flight sequences are never
    /// evicted and a downscale decision converges over the next few steps
    /// as sequences retire. Output streams are unaffected either way:
    /// scale events only change how many sequences may ride future packed
    /// calls, never what any existing sequence emits.
    pub fn set_capacity(&mut self, target: usize) -> usize {
        self.pool.set_capacity(target)
    }

    /// Number of currently active (admitted, unfinished) sequences.
    pub fn active(&self) -> usize {
        self.active.len()
    }

    /// Whether another sequence can be admitted right now (concurrency
    /// cap only — the paged pool may still refuse a SPECIFIC prompt on
    /// page pressure; see [`Self::can_admit_prompt`]).
    pub fn has_capacity(&self) -> bool {
        self.active.len() < self.pool.capacity()
    }

    /// Whether THIS prompt can be admitted right now. In lane mode this
    /// is exactly [`Self::has_capacity`]; in paged mode it additionally
    /// checks the page budget for the prompt's distinct (non-shared)
    /// pages at its worst-case reservation, so a prompt sharing a
    /// resident prefix may be admissible when a disjoint one is not.
    pub fn can_admit_prompt(&self, prompt: &[TokenId], cfg: &EngineConfig) -> bool {
        self.has_capacity() && self.pool.can_admit(prompt, self.max_pos_for(prompt.len(), cfg))
    }

    /// Worst-case KV position a sequence can reach: prompt + generation
    /// limit + one uncommitted block of slack on both sides of the last
    /// step. Purely an admission-reservation bound — the per-step room
    /// fed to shape planning stays `max_len - len` in both pool modes.
    fn max_pos_for(&self, prompt_len: usize, cfg: &EngineConfig) -> usize {
        let max_len = self.runtime.artifacts().dims.max_len;
        (prompt_len + cfg.max_new_tokens + 2 * cfg.w + 2).min(max_len)
    }

    /// KV lanes currently claimed by active sequences.
    pub fn lanes_in_use(&self) -> usize {
        self.pool.in_use()
    }

    /// Bytes the engine's KV pool currently pins (all capacity lanes in
    /// lane mode, materialized pages in paged mode) — the memory a lane
    /// shrink or an engine retire actually returns.
    pub fn kv_bytes(&self) -> usize {
        self.pool.memory_bytes()
    }

    /// Page accounting snapshot: live/free/shared pages + prefix hits.
    /// Lane mode reports lanes as pages with no sharing, so dashboards
    /// read one shape either way.
    pub fn page_stats(&self) -> PageStats {
        self.pool.page_stats()
    }

    /// Mean controller heat (expected accepted tokens per step, see
    /// [`SeqController::heat`]) across active adaptive sequences; `None`
    /// when no active sequence carries a controller. The autoscaler uses
    /// this to discount queue pressure — hot lanes drain the queue faster,
    /// so the same backlog needs fewer of them.
    pub fn mean_heat(&self) -> Option<f64> {
        let mut sum = 0.0;
        let mut n = 0usize;
        for s in &self.active {
            if let Some(c) = s.controller.as_ref() {
                sum += c.heat();
                n += 1;
            }
        }
        if n > 0 {
            Some(sum / n as f64)
        } else {
            None
        }
    }

    /// The packed-row budget the most recent [`Self::step`] enforced:
    /// the online-derived value in auto-budget mode, the static
    /// [`Self::budget`] otherwise, `None` before any step or when
    /// unbudgeted.
    pub fn last_step_budget(&self) -> Option<usize> {
        self.last_budget
    }

    /// Admit one sequence: claim a lane, prefill it, emit the first greedy
    /// token. Fails (releasing the lane) on prefill errors; fails fast when
    /// no lane is free — callers treat that as backpressure.
    pub fn admit(
        &mut self,
        prompt: &[TokenId],
        strategy: Box<dyn DraftStrategy>,
        cfg: EngineConfig,
    ) -> Result<SeqId> {
        self.admit_with(prompt, strategy, None, cfg)
    }

    /// [`Self::admit`] with an optional adaptive controller; when present
    /// the controller drives this sequence's drafting and shape planning
    /// and `strategy` is ignored.
    pub fn admit_with(
        &mut self,
        prompt: &[TokenId],
        mut strategy: Box<dyn DraftStrategy>,
        mut controller: Option<SeqController>,
        cfg: EngineConfig,
    ) -> Result<SeqId> {
        let max_pos = self.max_pos_for(prompt.len(), &cfg);
        let kv = self
            .pool
            .acquire(prompt, max_pos)
            .ok_or_else(|| anyhow!("no free KV lanes ({} in use)", self.pool.in_use()))?;
        strategy.reset();
        if let Some(c) = controller.as_mut() {
            c.reset();
        }
        let t0 = Instant::now();
        // Prefill ALWAYS runs (identical compute in both pool modes); a
        // paged writer with an attached shared prefix installs only the
        // positions past it — the sharing saves memory, not this call.
        let pf = {
            let mut slot = self.pool.slot_mut(kv);
            self.runtime.prefill(prompt, slot.as_write())
        };
        let pf = match pf {
            Ok(pf) => pf,
            Err(e) => {
                self.pool.release(kv);
                return Err(e);
            }
        };
        let mut res = GenResult::default();
        res.prefill_time = t0.elapsed();
        res.tokens.push(pf.next_id);
        let mut seq = prompt.to_vec();
        seq.push(pf.next_id);

        let id = SeqId(self.next_id);
        self.next_id += 1;
        self.active.push(SeqState {
            id,
            cfg,
            seq,
            strategy,
            controller,
            kv,
            res,
            done: false,
            t_decode: Instant::now(),
        });
        Ok(id)
    }

    /// One engine step: draft every active sequence, verify all drafts in
    /// packed calls, commit each sequence's winning lane, and retire
    /// whatever finished. Returns the finished sequences (id + result);
    /// their lanes are already reclaimed.
    pub fn step(&mut self) -> Result<Vec<(SeqId, GenResult)>> {
        let mut finished = Vec::new();

        // Shape selection across sequences. Sequences whose lane cannot fit
        // any block anymore are retired here (cache exhausted — same end
        // condition as SpecDecoder's `break`). Adaptive sequences plan
        // their own (k, w) caps each step; static ones use their config.
        let shapes = loop {
            self.sweep_finished(&mut finished);
            if self.active.is_empty() {
                return Ok(finished);
            }
            let mut caps: Vec<(usize, usize)> = Vec::with_capacity(self.active.len());
            let mut fits: Vec<Option<(usize, usize)>> = Vec::with_capacity(self.active.len());
            for s in self.active.iter_mut() {
                let room = self.pool.seq_remaining(s.kv);
                let ctx = self.pool.ctx_len(s.kv);
                let (ck, cw) = (s.cfg.k, s.cfg.w);
                let cap = match s.controller.as_mut() {
                    Some(c) => c.plan(ctx, room, &self.shape_grid, ck, cw),
                    None => (ck, cw),
                };
                caps.push(cap);
                fits.push(self.runtime.best_fitting_shape(cap.0, cap.1, room));
            }
            if fits.iter().all(|f| f.is_some()) {
                let fits: Vec<(usize, usize)> = fits.into_iter().map(|f| f.unwrap()).collect();
                // Common-depth selection PER DEPTH CLASS: greedy (w = 0)
                // sequences form their own packed group and no longer drag
                // every speculative co-resident to depth 0 — a step with
                // both classes issues (at least) two packed calls, one per
                // class. Within the speculative class the depth is still
                // the largest COMMON one every member affords.
                let w_common_spec = fits.iter().map(|&(_, w)| w).filter(|&w| w > 0).min();
                let shaped: Vec<(usize, usize)> = self
                    .active
                    .iter()
                    .zip(fits.iter().zip(&caps))
                    .map(|(s, (&own, &(k_cap, _)))| {
                        let (_, w_fit) = own;
                        if w_fit == 0 {
                            return own; // greedy class keeps its anchor-only shape
                        }
                        let room = self.pool.seq_remaining(s.kv);
                        self.runtime
                            .best_fitting_shape(k_cap, w_common_spec.unwrap(), room)
                            .unwrap_or(own)
                    })
                    .collect();
                break shaped;
            }
            for (s, f) in self.active.iter_mut().zip(&fits) {
                if f.is_none() {
                    s.done = true;
                }
            }
        };

        // Effective budget for THIS step: in auto mode it is re-derived
        // from the cost model at the step's deepest planned w and the
        // largest current context (the conservative corner of the packed
        // call), with the static budget demoted to an operator cap.
        let step_budget = match &self.auto_budget {
            Some(ab) => {
                let w_max = shapes.iter().map(|&(_, w)| w).max().unwrap_or(0);
                let ctx = self
                    .active
                    .iter()
                    .map(|s| self.pool.ctx_len(s.kv))
                    .max()
                    .unwrap_or(0);
                let derived = ab.cm.memory_bound_rows(w_max, ctx, ab.slack);
                Some(self.budget.map_or(derived, |cap| derived.min(cap)))
            }
            None => self.budget,
        };
        self.last_budget = step_budget;

        // Packed-row budget: refit each sequence's k_i so the step packs
        // at most max(B, active) rows, distributed by marginal expected
        // acceptance (hot sequences outbid cold ones, which degrade toward
        // their anchor row). A ragged artifact grid may have no shape
        // small enough for a sequence's allocation; it then takes the
        // grid's fewest-rows shape instead, which minimizes (but on such
        // grids cannot always eliminate) budget overshoot — on a full
        // k x w grid, which always has k = 1 shapes, the bound is exact.
        let shapes = match step_budget {
            Some(b) => {
                let caps_k: Vec<usize> = shapes.iter().map(|&(k, _)| k).collect();
                let alloc = budget::allocate_rows(b, &caps_k, |i, j| {
                    match &self.active[i].controller {
                        Some(c) => c.marginal_gain(j),
                        None => budget::static_gain(j),
                    }
                });
                shapes
                    .iter()
                    .enumerate()
                    .map(|(i, &(k, w))| {
                        let room = self.pool.seq_remaining(self.active[i].kv);
                        self.runtime
                            .best_fitting_shape(alloc[i].min(k), w, room)
                            .or_else(|| self.runtime.smallest_row_shape(w, room))
                            .unwrap_or((k, w))
                    })
                    .collect()
            }
            None => shapes,
        };

        // Group sequences by depth (one group — and one packed call — in
        // the common case; ragged artifact sets produce more).
        let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
        for (i, &(_, w)) in shapes.iter().enumerate() {
            match groups.iter_mut().find(|(gw, _)| *gw == w) {
                Some((_, idxs)) => idxs.push(i),
                None => groups.push((w, vec![i])),
            }
        }
        for (w, idxs) in groups {
            if self.tree {
                self.run_group_tree(w, &idxs, &shapes)?;
            } else {
                self.run_group(w, &idxs, &shapes)?;
            }
        }
        self.steps_done += 1;

        self.sweep_finished(&mut finished);
        Ok(finished)
    }

    /// Completed engine steps so far.
    pub fn steps_done(&self) -> u64 {
        self.steps_done
    }

    /// Draft, pack, verify and commit one same-depth group of sequences.
    fn run_group(&mut self, w: usize, idxs: &[usize], shapes: &[(usize, usize)]) -> Result<()> {
        // phase stopwatch: inert (never reads the clock) unless a live
        // recorder is attached — the zero-cost-when-idle contract
        let mut timer = PhaseTimer::new(self.recorder.as_ref().is_some_and(|r| r.enabled()));

        // --- draft every sequence's (k_i, w) block into the pooled
        // scratch slots (taken out of self for the duration so the
        // borrow checker sees the disjoint accesses; put back at the end)
        let mut slots = std::mem::take(&mut self.draft_scratch);
        while slots.len() < idxs.len() {
            slots.push(DraftSlot::default());
        }
        for (slot, &i) in slots.iter_mut().zip(idxs) {
            let k = shapes[i].0;
            let s = &mut self.active[i];
            slot.batch.reset(w);
            if w > 0 {
                match s.controller.as_mut() {
                    Some(c) => c.propose(&s.seq, k, &mut slot.batch),
                    None => s.strategy.propose(&s.seq, k, &mut slot.batch),
                }
            }
            pad_batch(&mut slot.batch, k);
            timer.lap(Phase::Draft);
            assemble_block_into(&slot.batch, *s.seq.last().unwrap(), w, &mut slot.block);
            timer.lap(Phase::Pack);
        }

        // --- one packed verification call for the whole group, straight
        // off the arena-assembled block buffers (no intermediate copies).
        // Per-sequence cache views (lane refs or paged page-table views)
        // are materialized first so the blocks can borrow them uniformly.
        let views: Vec<KvSlot> = idxs
            .iter()
            .map(|&i| self.pool.slot(self.active[i].kv))
            .collect();
        let blocks: Vec<PackedBlock> = idxs
            .iter()
            .zip(&slots)
            .zip(&views)
            .map(|((_, slot), view)| PackedBlock {
                k: slot.batch.k(),
                tokens: &slot.block,
                cache: view.as_read(),
            })
            .collect();
        let packed_rows: usize = blocks.iter().map(|b| b.k).sum();
        if self.collect_traces {
            self.packed_traces.push(PackedTrace {
                w,
                rows: packed_rows,
                max_ctx: blocks.iter().map(|b| b.cache.ctx_len()).max().unwrap_or(0),
                seqs: blocks.len(),
                step: self.steps_done,
            });
        }
        timer.lap(Phase::Pack);
        let outs = self.runtime.spec_step_packed(w, &blocks);
        timer.lap(Phase::Verify);
        drop(blocks);
        drop(views);
        let outs = match outs {
            Ok(o) => o,
            Err(e) => {
                self.draft_scratch = slots;
                return Err(e);
            }
        };

        // --- judge + commit each sequence independently. (An early `?`
        // return here drops the scratch instead of restoring it — a
        // failed step ends the engine's life anyway, the pool replaces
        // it wholesale.)
        let mut wins = [0u32; StrategyKind::COUNT];
        let mut accepted_by = [0u32; StrategyKind::COUNT];
        let mut accepted_total = 0u32;
        let mut emitted_total = 0u32;
        for ((&i, slot), out) in idxs.iter().zip(&slots).zip(&outs) {
            let batch = &slot.batch;
            let k = batch.k();
            let kv = self.active[i].kv;
            let (acc, ctx_len) = {
                let mut wslot = self.pool.slot_mut(kv);
                judge_and_commit(batch, out, wslot.as_write(), &mut timer)?
            };
            if timer.enabled() {
                // same Empty demotion the serving counters apply: a win
                // with zero accepted tokens is provenance-free
                let kind = if acc.accepted == 0 {
                    StrategyKind::Empty
                } else {
                    batch.rows()[acc.row].kind
                };
                wins[kind.index()] += 1;
                accepted_by[kind.index()] += acc.accepted as u32;
                accepted_total += acc.accepted as u32;
                emitted_total += acc.emitted.len() as u32;
            }
            let s = &mut self.active[i];
            s.res.exec_time += out.exec_time;
            if self.collect_traces {
                s.res
                    .traces
                    .push(make_trace(batch, &acc, k, w, ctx_len, out.exec_time));
            }
            match s.controller.as_mut() {
                Some(c) => c.observe(&StepFeedback {
                    batch,
                    row: acc.row,
                    accepted: acc.accepted,
                    emitted: &acc.emitted,
                    model_out: out.row(acc.row),
                    k,
                    w,
                    ctx_len,
                }),
                None => s.strategy.observe(&acc.emitted, out.row(acc.row)),
            }
            s.res.calls += 1;
            for &t in &acc.emitted {
                s.seq.push(t);
                s.res.tokens.push(t);
                if s.res.tokens.len() >= s.cfg.max_new_tokens {
                    break;
                }
            }
            // keep the pool's token mirror current so newly-full pages
            // get sealed into the prefix index (no-op in lane mode)
            self.pool.sync_tokens(kv, &self.active[i].seq);
        }
        if timer.enabled() {
            if let Some(rec) = &self.recorder {
                rec.record_step(StepEvent {
                    step: self.steps_done,
                    w: w as u32,
                    rows: packed_rows as u32,
                    seqs: idxs.len() as u32,
                    phase_us: timer.us,
                    accepted: accepted_total,
                    emitted: emitted_total,
                    wins,
                    accepted_by,
                    ..StepEvent::default()
                });
            }
        }
        self.draft_scratch = slots;
        Ok(())
    }

    /// Tree-mode twin of [`Self::run_group`]: each sequence overdrafts
    /// extra candidate rows, trie-packs them into its slot's
    /// [`DraftTree`] (shared prefixes collapse, so the node count stays
    /// within the same `k * (w + 1)` budget the flat block would spend),
    /// and the whole group is verified in one packed masked call.
    fn run_group_tree(&mut self, w: usize, idxs: &[usize], shapes: &[(usize, usize)]) -> Result<()> {
        // phase stopwatch: inert (never reads the clock) unless a live
        // recorder is attached — the zero-cost-when-idle contract
        let mut timer = PhaseTimer::new(self.recorder.as_ref().is_some_and(|r| r.enabled()));

        // --- draft + trie-pack every sequence into its pooled slot
        let mut slots = std::mem::take(&mut self.draft_scratch);
        while slots.len() < idxs.len() {
            slots.push(DraftSlot::default());
        }
        for (slot, &i) in slots.iter_mut().zip(idxs) {
            let k = shapes[i].0;
            let s = &mut self.active[i];
            let k_extra = match s.controller.as_ref() {
                Some(c) => c.tree_overdraft(k),
                None => k * 2,
            };
            slot.batch.reset(w);
            if w > 0 {
                match s.controller.as_mut() {
                    Some(c) => c.propose(&s.seq, k_extra, &mut slot.batch),
                    None => s.strategy.propose(&s.seq, k_extra, &mut slot.batch),
                }
            }
            timer.lap(Phase::Draft);
            // trie insertion dedups shared prefixes and enforces the node
            // budget; no pad/assemble — the tree IS the packed block
            slot.tree.reset(*s.seq.last().unwrap(), k, w);
            slot.tree.insert_batch(&slot.batch);
            timer.lap(Phase::Pack);
        }

        // --- one packed tree call for the whole group
        let views: Vec<KvSlot> = idxs
            .iter()
            .map(|&i| self.pool.slot(self.active[i].kv))
            .collect();
        let blocks: Vec<PackedTreeBlock> = slots
            .iter()
            .zip(&views)
            .map(|(slot, view)| PackedTreeBlock { tree: &slot.tree, cache: view.as_read() })
            .collect();
        let packed_nodes: usize = blocks.iter().map(|b| b.tree.len()).sum();
        if self.collect_traces {
            self.packed_traces.push(PackedTrace {
                w: 0, // one position per node (see PackedTrace docs)
                rows: packed_nodes,
                max_ctx: blocks.iter().map(|b| b.cache.ctx_len()).max().unwrap_or(0),
                seqs: blocks.len(),
                step: self.steps_done,
            });
        }
        timer.lap(Phase::Pack);
        let outs = self.runtime.spec_step_tree_packed(&blocks);
        timer.lap(Phase::Verify);
        drop(blocks);
        drop(views);
        let outs = match outs {
            Ok(o) => o,
            Err(e) => {
                self.draft_scratch = slots;
                return Err(e);
            }
        };

        // --- judge + commit each sequence independently (see run_group
        // on the early-`?` scratch-drop tradeoff)
        let mut wins = [0u32; StrategyKind::COUNT];
        let mut accepted_by = [0u32; StrategyKind::COUNT];
        let mut accepted_total = 0u32;
        let mut emitted_total = 0u32;
        for ((&i, slot), out) in idxs.iter().zip(&slots).zip(&outs) {
            let tree = &slot.tree;
            let k = shapes[i].0;
            let kv = self.active[i].kv;
            let (acc, ctx_len) = {
                let mut wslot = self.pool.slot_mut(kv);
                judge_and_commit_tree(tree, out, wslot.as_write(), &mut timer)?
            };
            if timer.enabled() {
                // same Empty demotion as flat mode: a win with zero
                // accepted tokens is provenance-free (the root is Empty)
                let kind = if acc.accepted == 0 {
                    StrategyKind::Empty
                } else {
                    tree.node_kind(acc.node)
                };
                wins[kind.index()] += 1;
                accepted_by[kind.index()] += acc.accepted as u32;
                accepted_total += acc.accepted as u32;
                emitted_total += acc.emitted.len() as u32;
            }
            let s = &mut self.active[i];
            s.res.exec_time += out.exec_time;
            if self.collect_traces {
                s.res
                    .traces
                    .push(make_tree_trace(&slot.batch, tree, &acc, k, w, ctx_len, out.exec_time));
            }
            // outputs along the accepted path ARE the emitted tokens
            match s.controller.as_mut() {
                Some(c) => c.observe(&StepFeedback {
                    batch: &slot.batch,
                    row: tree.node_row(acc.node),
                    accepted: acc.accepted,
                    emitted: &acc.emitted,
                    model_out: &acc.emitted,
                    k,
                    w,
                    ctx_len,
                }),
                None => s.strategy.observe(&acc.emitted, &acc.emitted),
            }
            s.res.calls += 1;
            for &t in &acc.emitted {
                s.seq.push(t);
                s.res.tokens.push(t);
                if s.res.tokens.len() >= s.cfg.max_new_tokens {
                    break;
                }
            }
            // keep the pool's token mirror current so newly-full pages
            // get sealed into the prefix index (no-op in lane mode)
            self.pool.sync_tokens(kv, &self.active[i].seq);
        }
        if timer.enabled() {
            if let Some(rec) = &self.recorder {
                let live = &slots[..idxs.len()];
                rec.record_step(StepEvent {
                    step: self.steps_done,
                    w: w as u32,
                    rows: packed_nodes as u32,
                    seqs: idxs.len() as u32,
                    phase_us: timer.us,
                    accepted: accepted_total,
                    emitted: emitted_total,
                    wins,
                    accepted_by,
                    tree_nodes: packed_nodes as u32,
                    tree_leaves: live.iter().map(|s| s.tree.leaf_count() as u32).sum(),
                    tree_depth: live.iter().map(|s| s.tree.max_depth() as u32).max().unwrap_or(0),
                    ..StepEvent::default()
                });
            }
        }
        self.draft_scratch = slots;
        Ok(())
    }

    /// Abort one active sequence: drop its state and reclaim its lane (or
    /// pages) immediately, without emitting a result. Returns whether `id`
    /// was active. Packed verification batches rows independently, so
    /// removing one sequence never changes what any co-resident sequence
    /// emits — the scheduler uses this to cancel requests whose client
    /// disconnected mid-stream.
    pub fn abort(&mut self, id: SeqId) -> bool {
        if let Some(i) = self.active.iter().position(|s| s.id == id) {
            let s = self.active.remove(i);
            self.pool.release(s.kv);
            true
        } else {
            false
        }
    }

    /// Retire finished sequences: reclaim lanes, stamp decode time.
    fn sweep_finished(&mut self, finished: &mut Vec<(SeqId, GenResult)>) {
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].finished() {
                let mut s = self.active.remove(i);
                s.res.decode_time = s.t_decode.elapsed();
                self.pool.release(s.kv);
                finished.push((s.id, s.res));
            } else {
                i += 1;
            }
        }
    }
}

/// Drive a whole request set through `eng` to completion: admit while
/// lanes are free, step until every sequence retires, keep admitting as
/// lanes free up. Results come back in request order. Callers own the
/// engine, so traces (`eng.packed_traces`, per-sequence `GenResult`
/// traces) stay inspectable afterwards — the benches and equivalence
/// tests all drive through here; only the scheduler's streaming worker
/// has its own loop (it must interleave queue arrivals).
pub fn generate_all(
    eng: &mut BatchedEngine,
    requests: Vec<(Vec<TokenId>, Box<dyn DraftStrategy>, EngineConfig)>,
) -> Result<Vec<GenResult>> {
    let n = requests.len();
    let mut pending: VecDeque<(usize, (Vec<TokenId>, Box<dyn DraftStrategy>, EngineConfig))> =
        requests.into_iter().enumerate().collect();
    let mut by_id: HashMap<SeqId, usize> = HashMap::new();
    let mut out: Vec<Option<GenResult>> = (0..n).map(|_| None).collect();

    loop {
        while eng.has_capacity() && !pending.is_empty() {
            // Paged-pool backpressure: when the next prompt's distinct
            // pages don't fit right now AND something is still running,
            // wait for retirements instead of erroring. With nothing
            // running, admit anyway so an oversized request fails loudly
            // rather than deadlocking the drive loop. (Lane mode never
            // hits this: has_capacity implies a free lane.)
            {
                let (_, (prompt, _, cfg)) = pending.front().unwrap();
                if !eng.can_admit_prompt(prompt, cfg) && eng.active() > 0 {
                    break;
                }
            }
            let (ridx, (prompt, strategy, cfg)) = pending.pop_front().unwrap();
            let id = eng.admit(&prompt, strategy, cfg)?;
            by_id.insert(id, ridx);
        }
        if eng.active() == 0 && pending.is_empty() {
            break;
        }
        for (id, res) in eng.step()? {
            let ridx = by_id
                .remove(&id)
                .ok_or_else(|| anyhow!("engine returned unknown sequence {id:?}"))?;
            out[ridx] = Some(res);
        }
    }
    out.into_iter()
        .enumerate()
        .map(|(i, o)| o.ok_or_else(|| anyhow!("request {i} never completed")))
        .collect()
}
