//! Speculative decoding engine: the per-sequence decode loop that ties
//! draft strategies (L3), the verification executable (L2+L1 via PJRT) and
//! the shared KV cache together.

pub mod acceptance;

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::EngineConfig;
use crate::draft::{DraftBatch, DraftStrategy, StrategyKind};
use crate::kvcache::SharedKvCache;
use crate::runtime::ModelRuntime;
use crate::tokenizer::TokenId;

/// Per-verification-call trace (feeds the Fig. 4 ablations and the
/// cost-model-simulated wall-times).
#[derive(Debug, Clone)]
pub struct StepTrace {
    /// context length at the time of the call
    pub ctx_len: usize,
    /// actual block shape used
    pub k: usize,
    pub w: usize,
    /// winning row's strategy + rank, accepted length
    pub kind: StrategyKind,
    pub rank: usize,
    pub accepted: usize,
    /// rows allocated per strategy in this call's batch
    pub alloc_context: usize,
    pub alloc_bigram: usize,
    pub alloc_other: usize,
    pub exec_time: Duration,
}

/// Result of generating one sequence.
#[derive(Debug, Clone, Default)]
pub struct GenResult {
    pub tokens: Vec<TokenId>,
    /// number of verification calls (excludes prefill)
    pub calls: usize,
    pub prefill_time: Duration,
    pub decode_time: Duration,
    /// pure model-execution time within decode
    pub exec_time: Duration,
    pub traces: Vec<StepTrace>,
}

impl GenResult {
    /// The paper's "tokens per call" acceptance metric. The first token
    /// comes from the prefill call, so only `len - 1` tokens are charged
    /// to the `calls` verification calls — greedy decoding is exactly 1.0.
    pub fn tokens_per_call(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            (self.tokens.len().saturating_sub(1)) as f64 / self.calls as f64
        }
    }
}

/// Drives speculative decoding for single sequences.
pub struct SpecDecoder<'rt> {
    pub runtime: &'rt ModelRuntime,
    pub strategy: Box<dyn DraftStrategy>,
    pub cfg: EngineConfig,
    /// collect per-step traces (slightly more allocation; on for benches)
    pub collect_traces: bool,
}

impl<'rt> SpecDecoder<'rt> {
    pub fn new(runtime: &'rt ModelRuntime, strategy: Box<dyn DraftStrategy>,
               cfg: EngineConfig) -> Self {
        SpecDecoder { runtime, strategy, cfg, collect_traces: false }
    }

    /// Generate up to `cfg.max_new_tokens` greedy tokens after `prompt`.
    ///
    /// INVARIANT: the returned stream is exactly the model's greedy
    /// continuation of `prompt`, regardless of strategy or (k, w) — wrong
    /// drafts can only cost speed, never correctness.
    pub fn generate(&mut self, prompt: &[TokenId]) -> Result<GenResult> {
        let dims = self.runtime.artifacts().dims.clone();
        let mut cache = SharedKvCache::new(
            dims.n_layers, dims.max_len, dims.n_heads, dims.head_dim);
        self.strategy.reset();

        let mut res = GenResult::default();
        let t0 = Instant::now();
        let pf = self.runtime.prefill(prompt, &mut cache)?;
        res.prefill_time = t0.elapsed();

        // `seq` = prompt ++ generated; the last element is always the
        // current anchor token (KV not yet cached).
        let mut seq: Vec<TokenId> = prompt.to_vec();
        seq.push(pf.next_id);
        res.tokens.push(pf.next_id);

        let tdec = Instant::now();
        while res.tokens.len() < self.cfg.max_new_tokens {
            let room = cache.remaining();
            // pick the largest artifact shape fitting config + cache room
            let Some((k, w)) = self
                .runtime
                .best_fitting_shape(self.cfg.k, self.cfg.w, room)
            else {
                break; // cache exhausted
            };
            let w1 = w + 1;

            // --- draft
            let mut batch = DraftBatch::new(w);
            if w > 0 {
                self.strategy.propose(&seq, k, &mut batch);
            }
            pad_batch(&mut batch, k);

            // --- assemble the (k, w1) block: col 0 = anchor, cols 1.. = drafts
            let anchor = *seq.last().unwrap();
            let mut tokens = Vec::with_capacity(k * w1);
            for row in &batch.rows {
                tokens.push(anchor);
                tokens.extend_from_slice(&row.tokens);
                // short rows pad with anchor repeats (never match outputs
                // except by genuine coincidence; judged like any draft)
                for _ in row.tokens.len()..w {
                    tokens.push(anchor);
                }
            }

            // --- verify
            let out = self.runtime.spec_step(k, w, &tokens, &cache)?;
            res.exec_time += out.exec_time;

            // --- judge + commit
            let acc = acceptance::judge(&batch, &out.next_ids, w1);
            let consumed = acc.accepted + 1; // block tokens whose KV is valid
            cache.commit_tail(&out.k_tail, &out.v_tail, k, w1, acc.row, consumed)?;

            let win = &batch.rows[acc.row];
            if self.collect_traces {
                res.traces.push(StepTrace {
                    ctx_len: cache.len - consumed,
                    k,
                    w,
                    kind: win.kind,
                    rank: win.rank,
                    accepted: acc.accepted,
                    alloc_context: count_kind(&batch, StrategyKind::ContextNgram),
                    alloc_bigram: count_kind(&batch, StrategyKind::ExtendedBigram)
                        + count_kind(&batch, StrategyKind::ModelBigram),
                    alloc_other: batch.rows.len()
                        - count_kind(&batch, StrategyKind::ContextNgram)
                        - count_kind(&batch, StrategyKind::ExtendedBigram)
                        - count_kind(&batch, StrategyKind::ModelBigram),
                    exec_time: out.exec_time,
                });
            }
            self.strategy.observe(&acc.emitted, out.row(acc.row));

            res.calls += 1;
            for &t in &acc.emitted {
                seq.push(t);
                res.tokens.push(t);
                if res.tokens.len() >= self.cfg.max_new_tokens {
                    break;
                }
            }
        }
        res.decode_time = tdec.elapsed();
        Ok(res)
    }
}

/// Duplicate the last row (or an empty-draft row) until the batch has
/// exactly k rows — the verification executable's shape is fixed.
fn pad_batch(batch: &mut DraftBatch, k: usize) {
    batch.rows.truncate(k);
    while batch.rows.len() < k {
        let clone = batch
            .rows
            .last()
            .map(|r| r.tokens.clone())
            .unwrap_or_default();
        batch.push(clone, StrategyKind::Empty, batch.rows.len());
    }
}

fn count_kind(batch: &DraftBatch, kind: StrategyKind) -> usize {
    batch.rows.iter().filter(|r| r.kind == kind).count()
}

/// Plain greedy decoding = speculation with (k, w) = (1, 0). Provided as
/// the wall-time baseline for every speedup number in the benches.
pub fn greedy_config(max_new_tokens: usize) -> EngineConfig {
    EngineConfig { k: 1, w: 0, q: 1, max_new_tokens }
}

/// A strategy that never proposes anything (used for the greedy baseline).
pub struct NoDraft;

impl DraftStrategy for NoDraft {
    fn name(&self) -> &'static str {
        "none"
    }

    fn propose(&mut self, _seq: &[TokenId], _k: usize, _batch: &mut DraftBatch) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::draft::DraftRow;

    #[test]
    fn pad_batch_fills_to_k() {
        let mut b = DraftBatch::new(2);
        b.push(vec![1, 2], StrategyKind::ContextNgram, 0);
        pad_batch(&mut b, 3);
        assert_eq!(b.rows.len(), 3);
        assert_eq!(b.rows[2].tokens, vec![1, 2]);
        assert_eq!(b.rows[2].kind, StrategyKind::Empty);
    }

    #[test]
    fn pad_batch_truncates_overfull() {
        let mut b = DraftBatch::new(1);
        for i in 0..5 {
            b.push(vec![i], StrategyKind::ContextNgram, i as usize);
        }
        pad_batch(&mut b, 2);
        assert_eq!(b.rows.len(), 2);
    }

    #[test]
    fn pad_empty_batch() {
        let mut b = DraftBatch::new(3);
        pad_batch(&mut b, 2);
        assert_eq!(b.rows.len(), 2);
        assert!(b.rows.iter().all(|r: &DraftRow| r.tokens.is_empty()));
    }
}
