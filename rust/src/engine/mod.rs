//! Speculative decoding engines: the decode loops that tie draft
//! strategies (L3), the verification executable (L2+L1) and the KV cache
//! together.
//!
//! Two engines share the same acceptance/commit/trace plumbing:
//!
//! - [`SpecDecoder`] — the paper's setting: one sequence, the model-call
//!   batch dimension spent entirely on that sequence's speculation rows.
//! - [`batched::BatchedEngine`] — continuous batching across requests:
//!   per step, draft rows from ALL active sequences are verified in one
//!   packed (sum of k_i, w+1) call against pooled per-sequence KV lanes,
//!   and sequences are admitted/retired between steps. Same invariant,
//!   spent on both batching axes at once.
//!
//! INVARIANT (both engines): every sequence's output stream is exactly the
//! base model's greedy continuation of its prompt — wrong drafts can only
//! cost speed, never correctness.

pub mod acceptance;
pub mod batched;

pub use batched::{generate_all, AutoBudget, BatchedEngine, PackedTrace, SeqId};

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::adaptive::{SeqController, StepFeedback};
use crate::config::EngineConfig;
use crate::draft::{DraftBatch, DraftStrategy, DraftTree, StrategyKind};
use crate::kvcache::{KvWrite, SharedKvCache};
use crate::runtime::{ModelRuntime, PackedTreeBlock, StepOutput};
use crate::tokenizer::TokenId;
use crate::trace::{FlightRecorder, Phase, PhaseTimer, StepEvent};

use acceptance::{Acceptance, TreeAcceptance};

/// Per-verification-call trace (feeds the Fig. 4 ablations and the
/// cost-model-simulated wall-times).
#[derive(Debug, Clone)]
pub struct StepTrace {
    /// context length at the time of the call (KV positions the verifier
    /// attended over — captured BEFORE the step's tokens are committed)
    pub ctx_len: usize,
    /// actual block shape used
    pub k: usize,
    /// speculation depth of the call
    pub w: usize,
    /// winning row's strategy + rank, accepted length
    pub kind: StrategyKind,
    /// winning row's rank within its producing strategy
    pub rank: usize,
    /// accepted draft-prefix length of the winning row
    pub accepted: usize,
    /// rows allocated per strategy in this call's batch
    pub alloc_context: usize,
    /// rows allocated to the model/extended bigram sources
    pub alloc_bigram: usize,
    /// rows from any other source (incl. anchor-only padding)
    pub alloc_other: usize,
    /// device execution time of the call
    pub exec_time: Duration,
}

/// Result of generating one sequence.
#[derive(Debug, Clone, Default)]
pub struct GenResult {
    /// emitted tokens (the first comes from the prefill call)
    pub tokens: Vec<TokenId>,
    /// number of verification calls (excludes prefill)
    pub calls: usize,
    /// wall time of the prefill call
    pub prefill_time: Duration,
    /// wall time of the decode loop
    pub decode_time: Duration,
    /// pure model-execution time within decode (for a batched run, each
    /// sequence is charged the full latency of every packed call it rode)
    pub exec_time: Duration,
    /// per-call traces (populated when `collect_traces` is on)
    pub traces: Vec<StepTrace>,
}

impl GenResult {
    /// The paper's "tokens per call" acceptance metric. The first token
    /// comes from the prefill call, so only `len - 1` tokens are charged
    /// to the `calls` verification calls — greedy decoding is exactly 1.0.
    pub fn tokens_per_call(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            (self.tokens.len().saturating_sub(1)) as f64 / self.calls as f64
        }
    }
}

/// Drives speculative decoding for single sequences.
///
/// # Example
///
/// Decode a few greedy tokens against the synthetic testkit artifacts
/// (a bare checkout needs no external toolchain for this):
///
/// ```
/// use ngrammys::config::EngineConfig;
/// use ngrammys::engine::{NoDraft, SpecDecoder};
/// use ngrammys::runtime::ModelRuntime;
///
/// let manifest = ngrammys::testkit::manifest();
/// let runtime = ModelRuntime::load(manifest.model("small")?)?;
/// let cfg = EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 8 };
/// let mut dec = SpecDecoder::new(&runtime, Box::new(NoDraft), cfg);
/// let out = dec.generate(&[1, 2, 3])?;
/// assert_eq!(out.tokens.len(), 8);
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct SpecDecoder<'rt> {
    /// the loaded model this decoder executes against
    pub runtime: &'rt ModelRuntime,
    /// draft source (ignored when `controller` is set)
    pub strategy: Box<dyn DraftStrategy>,
    /// block shape + generation limits
    pub cfg: EngineConfig,
    /// collect per-step traces (slightly more allocation; on for benches)
    pub collect_traces: bool,
    /// Adaptive (k, w) + strategy selection (`adaptive` mode). When set,
    /// `strategy` is ignored: the controller's bandit-chosen arm drafts
    /// each step and `cfg.k`/`cfg.w` become CAPS the controller plans
    /// under rather than the fixed shape. Output is unchanged either way —
    /// the acceptance invariant does not depend on what was proposed.
    pub controller: Option<SeqController>,
    /// Flight recorder for per-step phase timings + provenance. `None`
    /// (the default) skips all timing; a disabled recorder costs one
    /// branch per step. Never affects emitted tokens.
    pub recorder: Option<std::sync::Arc<FlightRecorder>>,
    /// Tree speculation (`--tree`): trie-share the drafted rows' common
    /// prefixes, spend the freed node budget on extra candidate rows, and
    /// verify every node in one call with per-node ancestor masks. The
    /// acceptance invariant is unchanged — the judge follows the unique
    /// root-to-leaf path the model's argmax traces, so the output stream
    /// stays byte-identical to greedy (and to flat-row mode).
    pub tree: bool,
}

impl<'rt> SpecDecoder<'rt> {
    /// A decoder for `runtime` drafting with `strategy` under `cfg`.
    pub fn new(runtime: &'rt ModelRuntime, strategy: Box<dyn DraftStrategy>,
               cfg: EngineConfig) -> Self {
        SpecDecoder {
            runtime,
            strategy,
            cfg,
            collect_traces: false,
            controller: None,
            recorder: None,
            tree: false,
        }
    }

    /// An adaptive decoder: `controller` picks each step's (k, w) and
    /// draft source within the `cfg` caps.
    pub fn with_controller(runtime: &'rt ModelRuntime, controller: SeqController,
                           cfg: EngineConfig) -> Self {
        SpecDecoder {
            runtime,
            strategy: Box::new(NoDraft),
            cfg,
            collect_traces: false,
            controller: Some(controller),
            recorder: None,
            tree: false,
        }
    }

    /// Generate up to `cfg.max_new_tokens` greedy tokens after `prompt`.
    ///
    /// INVARIANT: the returned stream is exactly the model's greedy
    /// continuation of `prompt`, regardless of strategy or (k, w) — wrong
    /// drafts can only cost speed, never correctness.
    pub fn generate(&mut self, prompt: &[TokenId]) -> Result<GenResult> {
        let dims = self.runtime.artifacts().dims.clone();
        let mut cache = SharedKvCache::new(
            dims.n_layers, dims.max_len, dims.n_heads, dims.head_dim);
        self.strategy.reset();
        if let Some(c) = self.controller.as_mut() {
            c.reset();
        }
        let shape_grid = self.runtime.artifacts().step_shapes();

        let mut res = GenResult::default();
        let t0 = Instant::now();
        let pf = self.runtime.prefill(prompt, &mut cache)?;
        res.prefill_time = t0.elapsed();

        // `seq` = prompt ++ generated; the last element is always the
        // current anchor token (KV not yet cached).
        let mut seq: Vec<TokenId> = prompt.to_vec();
        seq.push(pf.next_id);
        res.tokens.push(pf.next_id);

        // per-step scratch, reused across the whole decode: the draft
        // batch arena, the assembled block buffer and the speculation
        // trie keep their capacity, so a steady-state step allocates
        // nothing draft-side
        let mut batch = DraftBatch::new(0);
        let mut block: Vec<TokenId> = Vec::new();
        let mut tree = DraftTree::new();

        let tdec = Instant::now();
        while res.tokens.len() < self.cfg.max_new_tokens {
            let room = cache.remaining();
            // adaptive mode plans the next shape under the config caps;
            // static mode uses the caps directly
            let (k_cap, w_cap) = match self.controller.as_mut() {
                Some(c) => c.plan(cache.len, room, &shape_grid, self.cfg.k, self.cfg.w),
                None => (self.cfg.k, self.cfg.w),
            };
            // pick the largest artifact shape fitting the caps + cache room
            let Some((k, w)) = self.runtime.best_fitting_shape(k_cap, w_cap, room)
            else {
                break; // cache exhausted
            };

            // phase stopwatch: inert (never reads the clock) unless a
            // live recorder is attached
            let mut timer = PhaseTimer::new(self.recorder.as_ref().is_some_and(|r| r.enabled()));

            let emitted: Vec<TokenId> = if self.tree {
                // --- draft (trie): overdraft extra candidate rows — the
                // trie's prefix sharing means k rows rarely spend the full
                // k*(w+1) node budget, and the slack buys breadth
                let k_extra = match self.controller.as_ref() {
                    Some(c) => c.tree_overdraft(k),
                    None => k * 2,
                };
                batch.reset(w);
                if w > 0 {
                    match self.controller.as_mut() {
                        Some(c) => c.propose(&seq, k_extra, &mut batch),
                        None => self.strategy.propose(&seq, k_extra, &mut batch),
                    }
                }
                timer.lap(Phase::Draft);
                // trie insertion dedups shared prefixes and enforces the
                // node budget; no pad/assemble — the tree IS the block
                tree.reset(*seq.last().unwrap(), k, w);
                tree.insert_batch(&batch);
                timer.lap(Phase::Pack);

                // --- verify (every node in one masked call)
                let blocks = [PackedTreeBlock { tree: &tree, cache: &cache }];
                let out = self
                    .runtime
                    .spec_step_tree_packed(&blocks)?
                    .pop()
                    .expect("one tree block in, one output out");
                res.exec_time += out.exec_time;
                timer.lap(Phase::Verify);

                // --- judge + commit
                let (acc, ctx_len) = judge_and_commit_tree(&tree, &out, &mut cache, &mut timer)?;
                if self.collect_traces {
                    res.traces
                        .push(make_tree_trace(&batch, &tree, &acc, k, w, ctx_len, out.exec_time));
                }
                if timer.enabled() {
                    if let Some(rec) = &self.recorder {
                        let mut ev = StepEvent {
                            step: res.calls as u64,
                            w: w as u32,
                            rows: tree.len() as u32,
                            seqs: 1,
                            phase_us: timer.us,
                            accepted: acc.accepted as u32,
                            emitted: acc.emitted.len() as u32,
                            tree_nodes: tree.len() as u32,
                            tree_leaves: tree.leaf_count() as u32,
                            tree_depth: tree.max_depth() as u32,
                            ..StepEvent::default()
                        };
                        let kind = if acc.accepted == 0 {
                            StrategyKind::Empty
                        } else {
                            tree.node_kind(acc.node)
                        };
                        ev.wins[kind.index()] = 1;
                        ev.accepted_by[kind.index()] = acc.accepted as u32;
                        rec.record_step(ev);
                    }
                }
                // the model outputs along the accepted path ARE the emitted
                // tokens (each accepted node's prediction is the next path
                // token; the deepest node's prediction is the bonus)
                match self.controller.as_mut() {
                    Some(c) => c.observe(&StepFeedback {
                        batch: &batch,
                        row: tree.node_row(acc.node),
                        accepted: acc.accepted,
                        emitted: &acc.emitted,
                        model_out: &acc.emitted,
                        k,
                        w,
                        ctx_len,
                    }),
                    None => self.strategy.observe(&acc.emitted, &acc.emitted),
                }
                acc.emitted
            } else {
                // --- draft
                batch.reset(w);
                if w > 0 {
                    match self.controller.as_mut() {
                        Some(c) => c.propose(&seq, k, &mut batch),
                        None => self.strategy.propose(&seq, k, &mut batch),
                    }
                }
                pad_batch(&mut batch, k);
                timer.lap(Phase::Draft);
                assemble_block_into(&batch, *seq.last().unwrap(), w, &mut block);
                timer.lap(Phase::Pack);

                // --- verify
                let out = self.runtime.spec_step(k, w, &block, &cache)?;
                res.exec_time += out.exec_time;
                timer.lap(Phase::Verify);

                // --- judge + commit
                let (acc, ctx_len) = judge_and_commit(&batch, &out, &mut cache, &mut timer)?;
                if self.collect_traces {
                    res.traces.push(make_trace(&batch, &acc, k, w, ctx_len, out.exec_time));
                }
                if timer.enabled() {
                    if let Some(rec) = &self.recorder {
                        let mut ev = StepEvent {
                            step: res.calls as u64,
                            w: w as u32,
                            rows: k as u32,
                            seqs: 1,
                            phase_us: timer.us,
                            accepted: acc.accepted as u32,
                            emitted: acc.emitted.len() as u32,
                            ..StepEvent::default()
                        };
                        let kind = if acc.accepted == 0 {
                            StrategyKind::Empty
                        } else {
                            batch.rows()[acc.row].kind
                        };
                        ev.wins[kind.index()] = 1;
                        ev.accepted_by[kind.index()] = acc.accepted as u32;
                        rec.record_step(ev);
                    }
                }
                match self.controller.as_mut() {
                    Some(c) => c.observe(&StepFeedback {
                        batch: &batch,
                        row: acc.row,
                        accepted: acc.accepted,
                        emitted: &acc.emitted,
                        model_out: out.row(acc.row),
                        k,
                        w,
                        ctx_len,
                    }),
                    None => self.strategy.observe(&acc.emitted, out.row(acc.row)),
                }
                acc.emitted
            };

            res.calls += 1;
            for &t in &emitted {
                seq.push(t);
                res.tokens.push(t);
                if res.tokens.len() >= self.cfg.max_new_tokens {
                    break;
                }
            }
        }
        res.decode_time = tdec.elapsed();
        Ok(res)
    }
}

/// Normalize a drafted batch to exactly `k` rows: drop duplicate rows
/// (identical drafts burn verification slots for zero extra acceptance —
/// first occurrence wins, preserving policy order and the judge's
/// lowest-row tie-break), truncate overflow, and pad the remainder with
/// EMPTY (anchor-only) rows rather than clones so the Fig. 4 `alloc_*`
/// accounting reflects real allocations. Operates on the arena-backed
/// batch in place: dedup/truncate touch only row descriptors and padding
/// rows are zero-length arena spans, so no tokens are copied.
pub(crate) fn pad_batch(batch: &mut DraftBatch, k: usize) {
    let mut i = 0;
    while i < batch.k() {
        let dup = (0..i).any(|j| batch.row_tokens(j) == batch.row_tokens(i));
        if dup {
            batch.remove_row(i);
        } else {
            i += 1;
        }
    }
    batch.truncate_rows(k);
    while batch.k() < k {
        batch.begin_row();
        batch.commit_row(StrategyKind::Empty, batch.k());
    }
}

/// Assemble the row-major (k, w+1) token block for a verification call
/// into the reusable `out` buffer: column 0 = anchor (last accepted
/// token), columns 1.. = drafts, straight from the batch arena. Short
/// rows pad with anchor repeats (never match outputs except by genuine
/// coincidence; judged like any draft).
pub(crate) fn assemble_block_into(
    batch: &DraftBatch,
    anchor: TokenId,
    w: usize,
    out: &mut Vec<TokenId>,
) {
    out.clear();
    out.reserve(batch.k() * (w + 1));
    for r in 0..batch.k() {
        out.push(anchor);
        let toks = batch.row_tokens(r);
        out.extend_from_slice(toks);
        for _ in toks.len()..w {
            out.push(anchor);
        }
    }
}

/// [`assemble_block_into`] returning a fresh `Vec` (tests/one-shot callers).
#[cfg(test)]
pub(crate) fn assemble_block(batch: &DraftBatch, anchor: TokenId, w: usize) -> Vec<TokenId> {
    let mut out = Vec::new();
    assemble_block_into(batch, anchor, w, &mut out);
    out
}

/// Judge a verification call and commit the winning row's KV tail.
/// Returns the acceptance and the context length AT CALL TIME (the
/// cache's length before the commit — what the verifier attended over).
/// Works against any [`KvWrite`] target: a contiguous lane or a paged
/// page-table writer commit identically. `timer` (inert unless tracing)
/// attributes the judge and commit spans separately.
pub(crate) fn judge_and_commit(
    batch: &DraftBatch,
    out: &StepOutput,
    cache: &mut dyn KvWrite,
    timer: &mut PhaseTimer,
) -> Result<(Acceptance, usize)> {
    let ctx_len = cache.ctx_len();
    timer.skip(); // bookkeeping between laps is nobody's phase
    let acc = acceptance::judge(batch, &out.next_ids, out.w1);
    timer.lap(Phase::Judge);
    let consumed = acc.accepted + 1; // block tokens whose KV is valid
    cache.commit_tail(&out.k_tail, &out.v_tail, out.k, out.w1, acc.row, consumed)?;
    timer.lap(Phase::Commit);
    Ok((acc, ctx_len))
}

/// Tree-mode twin of [`judge_and_commit`]: walk the argmax path, then
/// commit the accepted chain's KV node by node. The tree [`StepOutput`] is
/// `(n, 1)`-shaped — each node owns exactly one tail position — so
/// committing the root and then each accepted node appends the same
/// `accepted + 1` positions (anchor + accepted drafts, in order) that flat
/// mode commits with a single call.
pub(crate) fn judge_and_commit_tree(
    tree: &DraftTree,
    out: &StepOutput,
    cache: &mut dyn KvWrite,
    timer: &mut PhaseTimer,
) -> Result<(TreeAcceptance, usize)> {
    let ctx_len = cache.ctx_len();
    timer.skip(); // bookkeeping between laps is nobody's phase
    let acc = acceptance::judge_tree(tree, &out.next_ids);
    timer.lap(Phase::Judge);
    cache.commit_tail(&out.k_tail, &out.v_tail, out.k, out.w1, 0, 1)?;
    for &node in &acc.path {
        cache.commit_tail(&out.k_tail, &out.v_tail, out.k, out.w1, node as usize, 1)?;
    }
    timer.lap(Phase::Commit);
    Ok((acc, ctx_len))
}

/// Build the per-call trace record shared by both engines.
pub(crate) fn make_trace(
    batch: &DraftBatch,
    acc: &Acceptance,
    k: usize,
    w: usize,
    ctx_len: usize,
    exec_time: Duration,
) -> StepTrace {
    let win = &batch.rows()[acc.row];
    let n_ctx = count_kind(batch, StrategyKind::ContextNgram);
    let n_big = count_kind(batch, StrategyKind::ExtendedBigram)
        + count_kind(batch, StrategyKind::ModelBigram);
    StepTrace {
        ctx_len,
        k,
        w,
        kind: win.kind,
        rank: win.rank,
        accepted: acc.accepted,
        alloc_context: n_ctx,
        alloc_bigram: n_big,
        alloc_other: batch.k() - n_ctx - n_big,
        exec_time,
    }
}

/// Tree-mode twin of [`make_trace`]: `(k, w)` is the planned source block
/// shape, winner provenance comes from the deepest accepted NODE (root =
/// `Empty`, the zero-accept demotion flat mode applies at the event
/// layer), and the `alloc_*` split still counts the PROPOSED rows — the
/// overdrafted batch the trie was built from — so Fig. 4 keeps reflecting
/// what each strategy was given, not what survived prefix sharing.
pub(crate) fn make_tree_trace(
    batch: &DraftBatch,
    tree: &DraftTree,
    acc: &TreeAcceptance,
    k: usize,
    w: usize,
    ctx_len: usize,
    exec_time: Duration,
) -> StepTrace {
    let n_ctx = count_kind(batch, StrategyKind::ContextNgram);
    let n_big = count_kind(batch, StrategyKind::ExtendedBigram)
        + count_kind(batch, StrategyKind::ModelBigram);
    StepTrace {
        ctx_len,
        k,
        w,
        kind: tree.node_kind(acc.node),
        rank: tree.node_rank(acc.node),
        accepted: acc.accepted,
        alloc_context: n_ctx,
        alloc_bigram: n_big,
        alloc_other: batch.k().saturating_sub(n_ctx + n_big),
        exec_time,
    }
}

fn count_kind(batch: &DraftBatch, kind: StrategyKind) -> usize {
    batch.rows().iter().filter(|r| r.kind == kind).count()
}

/// Plain greedy decoding = speculation with (k, w) = (1, 0). Provided as
/// the wall-time baseline for every speedup number in the benches.
pub fn greedy_config(max_new_tokens: usize) -> EngineConfig {
    EngineConfig { k: 1, w: 0, q: 1, max_new_tokens }
}

/// A strategy that never proposes anything (used for the greedy baseline).
pub struct NoDraft;

impl DraftStrategy for NoDraft {
    fn name(&self) -> &'static str {
        "none"
    }

    fn propose(&mut self, _seq: &[TokenId], _k: usize, _batch: &mut DraftBatch) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_batch_fills_with_empty_rows() {
        let mut b = DraftBatch::new(2);
        b.push(vec![1, 2], StrategyKind::ContextNgram, 0);
        pad_batch(&mut b, 3);
        assert_eq!(b.k(), 3);
        // padding must be anchor-only rows, not clones of the last draft
        assert!(b.rows()[1].is_empty());
        assert!(b.rows()[2].is_empty());
        assert_eq!(b.rows()[1].kind, StrategyKind::Empty);
        assert_eq!(b.rows()[2].kind, StrategyKind::Empty);
    }

    #[test]
    fn pad_batch_dedups_identical_rows() {
        let mut b = DraftBatch::new(2);
        b.push(vec![4, 5], StrategyKind::ContextNgram, 0);
        b.push(vec![4, 5], StrategyKind::ExtendedBigram, 0); // duplicate
        b.push(vec![4, 6], StrategyKind::ExtendedBigram, 1);
        pad_batch(&mut b, 3);
        assert_eq!(b.k(), 3);
        // first occurrence survives, duplicate slot becomes an empty row
        assert_eq!(b.row_tokens(0), vec![4, 5]);
        assert_eq!(b.rows()[0].kind, StrategyKind::ContextNgram);
        assert_eq!(b.row_tokens(1), vec![4, 6]);
        assert_eq!(b.rows()[2].kind, StrategyKind::Empty);
    }

    #[test]
    fn pad_batch_truncates_overfull() {
        let mut b = DraftBatch::new(1);
        for i in 0..5u32 {
            b.push(vec![i], StrategyKind::ContextNgram, i as usize);
        }
        pad_batch(&mut b, 2);
        assert_eq!(b.k(), 2);
    }

    #[test]
    fn pad_empty_batch() {
        let mut b = DraftBatch::new(3);
        pad_batch(&mut b, 2);
        assert_eq!(b.k(), 2);
        assert!(b.rows().iter().all(|r| r.is_empty()));
    }

    #[test]
    fn assemble_block_pads_short_rows_with_anchor() {
        let mut b = DraftBatch::new(3);
        b.push(vec![7], StrategyKind::ContextNgram, 0);
        b.push(vec![8, 9, 10], StrategyKind::ContextNgram, 1);
        let toks = assemble_block(&b, 99, 3);
        assert_eq!(toks, vec![99, 7, 99, 99, 99, 8, 9, 10]);
    }
}
