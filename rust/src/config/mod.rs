//! Typed configuration: artifact manifest, model dims, engine and serving
//! settings. The manifest (written by `python -m compile.aot`) is the single
//! handoff point between the build path and the runtime.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::scheduler::{AutoscaleConfig, EngineScaleConfig, StrategyName};
use crate::util::json::Json;

/// Dimensions of one nano model (mirrors python/compile/configs.py).
#[derive(Debug, Clone)]
pub struct ModelDims {
    /// model name (the manifest key)
    pub name: String,
    /// paper-scale analog this nano model stands in for (cost-model key)
    pub analog: String,
    /// vocabulary size
    pub vocab_size: usize,
    /// residual-stream width
    pub d_model: usize,
    /// transformer layer count
    pub n_layers: usize,
    /// attention head count
    pub n_heads: usize,
    /// per-head dimension
    pub head_dim: usize,
    /// MLP hidden width
    pub mlp_hidden: usize,
    /// maximum sequence length (KV-cache positions)
    pub max_len: usize,
    /// total parameter count
    pub n_params: usize,
}

/// One weight tensor's name + shape, in flat params.bin order.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    /// tensor name
    pub name: String,
    /// tensor shape
    pub shape: Vec<usize>,
}

impl ParamSpec {
    /// Total element count of the tensor.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Everything the runtime needs to know about one model's artifacts.
#[derive(Debug, Clone)]
pub struct ModelArtifacts {
    /// model dimensions
    pub dims: ModelDims,
    /// this model's artifact directory
    pub dir: PathBuf,
    /// flat f32 weight file
    pub params_bin: PathBuf,
    /// tensor name/shape list, in `params_bin` order
    pub param_spec: Vec<ParamSpec>,
    /// (k, w) -> HLO text path for the verification step.
    pub steps: HashMap<(usize, usize), PathBuf>,
    /// prefill bucket P -> HLO text path.
    pub prefills: HashMap<usize, PathBuf>,
    /// (k, w) -> HLO text path for the device-side KV commit (perf path;
    /// may be empty for artifacts built before the commit stage existed).
    pub commits: HashMap<(usize, usize), PathBuf>,
    /// model-derived bigram table path
    pub bigram_table: PathBuf,
    /// model-derived unigram table path
    pub unigram_table: PathBuf,
    /// extended-bigram chain table path
    pub ext_bigram_table: PathBuf,
    /// final training loss recorded by the build (NaN when absent)
    pub train_final_loss: f64,
}

impl ModelArtifacts {
    /// Smallest prefill bucket that fits `len` prompt tokens.
    pub fn prefill_bucket(&self, len: usize) -> Option<usize> {
        self.prefills.keys().copied().filter(|&p| p >= len).min()
    }

    /// All available (k, w) step shapes, sorted.
    pub fn step_shapes(&self) -> Vec<(usize, usize)> {
        let mut v: Vec<_> = self.steps.keys().copied().collect();
        v.sort_unstable();
        v
    }
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// artifacts root directory
    pub root: PathBuf,
    /// shared vocabulary size
    pub vocab_size: usize,
    /// shared tokenizer.json path
    pub tokenizer_path: PathBuf,
    /// task name -> (train corpus path, eval corpus path)
    pub data: HashMap<String, (PathBuf, PathBuf)>,
    /// model name -> artifact set
    pub models: HashMap<String, ModelArtifacts>,
    /// top-k stored per bigram-table row
    pub bigram_topk: usize,
    /// top-k stored in the unigram table
    pub unigram_topk: usize,
    /// chain depth stored in the extended-bigram table
    pub ext_bigram_w: usize,
}

impl Manifest {
    /// Parse `manifest.json` under `artifacts_dir`.
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;

        let root = artifacts_dir.to_path_buf();
        let vocab_size = j.req("vocab_size")?.as_usize().unwrap_or(0);

        let mut data = HashMap::new();
        if let Some(d) = j.get("data").and_then(|d| d.as_obj()) {
            for (task, v) in d {
                let train = root.join(v.req("train")?.as_str().unwrap_or_default());
                let eval = root.join(v.req("eval")?.as_str().unwrap_or_default());
                data.insert(task.clone(), (train, eval));
            }
        }

        let topk = j.req("table_topk")?;
        let bigram_topk = topk.req("bigram")?.as_usize().unwrap_or(0);
        let unigram_topk = topk.req("unigram")?.as_usize().unwrap_or(0);
        let ext_bigram_w = topk.req("ext_bigram_w")?.as_usize().unwrap_or(0);

        let mut models = HashMap::new();
        for (name, m) in j.req("models")?.as_obj().unwrap_or(&[]) {
            models.insert(name.clone(), parse_model(&root, name, m)?);
        }

        Ok(Manifest {
            tokenizer_path: root.join(
                j.req("tokenizer")?.as_str().unwrap_or("tokenizer.json")),
            root,
            vocab_size,
            data,
            models,
            bigram_topk,
            unigram_topk,
            ext_bigram_w,
        })
    }

    /// Look up one model's artifact set by manifest name.
    pub fn model(&self, name: &str) -> Result<&ModelArtifacts> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest (have: {:?})",
                                   self.models.keys().collect::<Vec<_>>()))
    }
}

fn parse_model(root: &Path, name: &str, m: &Json) -> Result<ModelArtifacts> {
    let dir = root.join(m.req("dir")?.as_str().unwrap_or_default());
    let u = |key: &str| -> Result<usize> {
        m.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow!("model {name}: bad '{key}'"))
    };
    let dims = ModelDims {
        name: name.to_string(),
        analog: m.get("analog").and_then(|a| a.as_str()).unwrap_or("").to_string(),
        vocab_size: u("vocab_size")?,
        d_model: u("d_model")?,
        n_layers: u("n_layers")?,
        n_heads: u("n_heads")?,
        head_dim: u("head_dim")?,
        mlp_hidden: u("mlp_hidden")?,
        max_len: u("max_len")?,
        n_params: u("n_params")?,
    };

    let mut param_spec = Vec::new();
    for p in m.req("param_spec")?.as_arr().unwrap_or(&[]) {
        param_spec.push(ParamSpec {
            name: p.req("name")?.as_str().unwrap_or_default().to_string(),
            shape: p
                .req("shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|s| s.as_usize().unwrap_or(0))
                .collect(),
        });
    }

    let mut steps = HashMap::new();
    for (kw, f) in m.req("steps")?.as_obj().unwrap_or(&[]) {
        let (k, w) = kw
            .split_once(',')
            .ok_or_else(|| anyhow!("bad step key {kw}"))?;
        steps.insert(
            (k.parse()?, w.parse()?),
            dir.join(f.as_str().unwrap_or_default()),
        );
    }

    let mut prefills = HashMap::new();
    for (p, f) in m.req("prefills")?.as_obj().unwrap_or(&[]) {
        prefills.insert(p.parse()?, dir.join(f.as_str().unwrap_or_default()));
    }

    let mut commits = HashMap::new();
    if let Some(c) = m.get("commits").and_then(|c| c.as_obj()) {
        for (kw, f) in c {
            if let Some((k, w)) = kw.split_once(',') {
                commits.insert(
                    (k.parse()?, w.parse()?),
                    dir.join(f.as_str().unwrap_or_default()),
                );
            }
        }
    }

    let tables = m.req("tables")?;
    Ok(ModelArtifacts {
        params_bin: dir.join(m.req("params_bin")?.as_str().unwrap_or_default()),
        bigram_table: dir.join(tables.req("bigram")?.as_str().unwrap_or_default()),
        unigram_table: dir.join(tables.req("unigram")?.as_str().unwrap_or_default()),
        ext_bigram_table: dir.join(tables.req("ext_bigram")?.as_str().unwrap_or_default()),
        train_final_loss: m
            .get("train_final_loss")
            .and_then(|v| v.as_f64())
            .unwrap_or(f64::NAN),
        dims,
        dir,
        param_spec,
        steps,
        prefills,
        commits,
    })
}

/// Engine-level settings for one generation run.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// number of batched speculation rows (paper's k)
    pub k: usize,
    /// speculation length (paper's w)
    pub w: usize,
    /// context-n-gram query length (paper's q; q=1 everywhere in §5)
    pub q: usize,
    /// max tokens to emit (the prefill-emitted first token counts)
    pub max_new_tokens: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        // the paper's representative default (k, w) = (10, 10), q = 1
        EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: 128 }
    }
}

/// Bounds for the online `SessionNgramCache` strategy (per-query fanout,
/// stored chain length, total stored chains). Plumbed from the CLI so
/// operators can size the cache to their workload instead of inheriting
/// hardcoded bounds.
#[derive(Debug, Clone)]
pub struct SessionCacheConfig {
    /// max continuations kept per query token
    pub per_query: usize,
    /// max chain length stored per continuation
    pub max_chain: usize,
    /// max total stored chains across the session
    pub cap: usize,
}

impl Default for SessionCacheConfig {
    fn default() -> Self {
        SessionCacheConfig { per_query: 8, max_chain: 12, cap: 100_000 }
    }
}

/// Which connection front-end `serve` runs (`--front-end`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrontEnd {
    /// One blocking thread per connection — the pre-reactor behavior,
    /// kept as the comparison baseline (`bench serve` pins the reactor
    /// against it) and as the fallback on non-Linux targets.
    Threaded,
    /// Readiness-based event loop ([`crate::server::reactor`]): epoll
    /// today behind an io_uring-shaped trait, non-blocking accept/read/
    /// write state machines, one thread for all connections. The default
    /// on Linux; elsewhere `serve` warns and falls back to `Threaded`.
    Reactor,
}

impl FrontEnd {
    /// Parse a `--front-end` value.
    pub fn parse(s: &str) -> Result<FrontEnd> {
        match s {
            "threaded" => Ok(FrontEnd::Threaded),
            "reactor" => Ok(FrontEnd::Reactor),
            _ => Err(anyhow!("unknown front-end '{s}' (have: reactor, threaded)")),
        }
    }

    /// The CLI name (`reactor` / `threaded`).
    pub fn label(self) -> &'static str {
        match self {
            FrontEnd::Threaded => "threaded",
            FrontEnd::Reactor => "reactor",
        }
    }
}

/// How submitted requests reach the engine workers (`--dispatch`),
/// `batch >= 2` only.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatch {
    /// One dispatcher thread owns the scored queue and routes to engine
    /// channels ([`crate::scheduler::pool`]); the only mode with
    /// engine-COUNT autoscaling (spawn/retire needs a single owner).
    Central,
    /// Per-engine scored work queues with idle-engine stealing
    /// ([`crate::scheduler::steal`]): no dispatcher thread between
    /// submit and admit, the full `--engines` fleet runs fixed. The
    /// default.
    Steal,
}

impl Dispatch {
    /// Parse a `--dispatch` value.
    pub fn parse(s: &str) -> Result<Dispatch> {
        match s {
            "central" => Ok(Dispatch::Central),
            "steal" => Ok(Dispatch::Steal),
            _ => Err(anyhow!("unknown dispatch '{s}' (have: steal, central)")),
        }
    }

    /// The CLI name (`steal` / `central`).
    pub fn label(self) -> &'static str {
        match self {
            Dispatch::Central => "central",
            Dispatch::Steal => "steal",
        }
    }
}

/// Whether pool engines share one fleet draft store (`--shared-draft`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SharedDraft {
    /// Every engine keeps private draft state only — the pre-fleet
    /// behavior and the default.
    Off,
    /// All pool engines attach to one sharded
    /// [`crate::draft::SharedDraftStore`]: accepted tokens publish batched
    /// deltas fleet-wide, propose paths fill spare rows from shared
    /// chains, and adaptive requests seed their bandit from
    /// prompt-fingerprint priors. Output streams are byte-identical to
    /// `Off` (shared chains only change which candidates are proposed).
    Fleet,
}

impl SharedDraft {
    /// Parse a `--shared-draft` value.
    pub fn parse(s: &str) -> Result<SharedDraft> {
        match s {
            "off" => Ok(SharedDraft::Off),
            "fleet" => Ok(SharedDraft::Fleet),
            _ => Err(anyhow!("unknown shared-draft mode '{s}' (have: off, fleet)")),
        }
    }

    /// The CLI name (`off` / `fleet`).
    pub fn label(self) -> &'static str {
        match self {
            SharedDraft::Off => "off",
            SharedDraft::Fleet => "fleet",
        }
    }
}

/// Serving-layer settings.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// listen address (host:port; port 0 = ephemeral)
    pub addr: String,
    /// connection front-end (`--front-end reactor|threaded`)
    pub front_end: FrontEnd,
    /// request dispatch arrangement (`--dispatch steal|central`)
    pub dispatch: Dispatch,
    /// Max connections the reactor holds open at once (`--conn-cap N`);
    /// accepts past the cap are answered with the pinned 503 JSON error
    /// and closed instead of queueing unboundedly. The threaded front-end
    /// ignores it (its bound is thread count).
    pub conn_cap: usize,
    /// per-sequence decode workers (the `batch <= 1` mode)
    pub workers: usize,
    /// bounded admission-queue length (backpressure limit)
    pub queue_cap: usize,
    /// Cross-request batching: 0 or 1 = one private decode loop per worker
    /// (request-batch 1); >= 2 = the continuous-batching engine pool.
    /// This is a PER-ENGINE lane count: with `elastic` on (the default)
    /// each engine's autoscaler works within this cap, with it off each
    /// engine pins exactly this many pooled lanes.
    pub batch: usize,
    /// Engine-pool cap (`--engines N`): how many batched engine worker
    /// threads — each with its own `ModelRuntime` and KV lane pool — may
    /// serve behind the shared admission queue. 1 (the default) is the
    /// single-engine behavior. With `elastic` on, engines are
    /// spawned/retired between 1 and this cap by the two-level autoscaler
    /// ([`crate::scheduler::EngineScaler`]); with it off, exactly this
    /// many engines run for the process lifetime. Ignored when
    /// `batch <= 1`.
    pub engines: usize,
    /// Engine-level tuning for the two-level autoscaler (elastic mode).
    /// `max_engines` is overridden by `engines` at scheduler start;
    /// `min_engines` is clamped into its range.
    pub engine_scale: EngineScaleConfig,
    /// Packed-row budget for the batched engine: bounds the per-step packed
    /// batch size `sum k_i` at `max(budget, active)`; rows are distributed
    /// across sequences by marginal expected acceptance. With `elastic` on,
    /// this is a CAP over the budget derived online from the cost model
    /// (`None` = derived value used as-is); with it off, the fixed budget
    /// (`None` = unbudgeted).
    pub budget: Option<usize>,
    /// Elastic batched serving (ignored when `batch <= 1`): the scheduler
    /// autoscales the lane pool between `autoscale.min_lanes` and `batch`
    /// from demand, derives the per-step row budget from
    /// [`crate::costmodel::CostModel::memory_bound_rows`], and orders
    /// admissions by expected accepted-tokens-per-cost. Turn off
    /// (`--no-elastic`) to pin `batch` lanes and the static `budget`, the
    /// pre-elastic behavior. Output streams are identical either way.
    pub elastic: bool,
    /// Autoscaler tuning for elastic mode. `max_lanes` is overridden by
    /// `batch` at scheduler start; `min_lanes` is clamped into its range.
    pub autoscale: AutoscaleConfig,
    /// Slowdown tolerance for the online-derived row budget (elastic
    /// mode): rows are packed while they cost at most this factor over a
    /// one-row call of the same depth on the cost model.
    pub budget_slack: f64,
    /// Default strategy for requests that don't name one (`Adaptive`
    /// turns on the online controller). Typed, so an invalid name fails
    /// at config construction, not silently per request.
    pub default_strategy: StrategyName,
    /// Bounds for the session n-gram cache strategy.
    pub session_cache: SessionCacheConfig,
    /// engine settings for requests that do not override them
    pub default_engine: EngineConfig,
    /// KV page size in positions (`--kv-page-size N`): 0 (the default)
    /// keeps the contiguous per-lane KV pool; N > 0 switches every
    /// batched engine to the paged pool with refcounted copy-on-write
    /// prefix sharing, where admission is charged in distinct pages so
    /// shared-prefix requests pack more lanes into the same KV bytes.
    /// Output streams are byte-identical either way. Ignored when
    /// `batch <= 1`.
    pub kv_page_size: usize,
    /// Paged-pool page budget (`--kv-pages N`, only with
    /// `kv_page_size > 0`): 0 (the default) derives the lane-equivalent
    /// budget `batch * ceil(max_len / page_size)` — the same bytes the
    /// lane pool would pin — so extra admissions come purely from prefix
    /// sharing and right-sized reservations.
    pub kv_pages: usize,
    /// Tree speculation (`--tree`): engines trie-pack each sequence's
    /// draft rows so sibling continuations share their common-prefix
    /// tokens, overdraft extra candidate rows into the freed node budget,
    /// and verify the whole tree in one masked call. Output streams are
    /// byte-identical to flat-row mode either way.
    pub tree: bool,
    /// Fleet-shared draft store (`--shared-draft off|fleet`): whether all
    /// pool engines share one seqlock-snapshotted n-gram chain store plus
    /// prompt-fingerprint adaptive priors ([`crate::draft::shared`]).
    pub shared_draft: SharedDraft,
    /// Shard count for the fleet store (`--shared-draft-shards N`,
    /// floored at 1): more shards = less writer serialization; readers
    /// are lock-free at any count.
    pub shared_draft_shards: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:8077".to_string(),
            front_end: FrontEnd::Reactor,
            dispatch: Dispatch::Steal,
            conn_cap: 1024,
            workers: 1,
            queue_cap: 256,
            batch: 0,
            engines: 1,
            engine_scale: EngineScaleConfig::for_cap(1),
            budget: None,
            elastic: true,
            autoscale: AutoscaleConfig::for_cap(1),
            budget_slack: crate::engine::AutoBudget::DEFAULT_SLACK,
            default_strategy: StrategyName::Mixed,
            session_cache: SessionCacheConfig::default(),
            default_engine: EngineConfig::default(),
            kv_page_size: 0,
            kv_pages: 0,
            tree: false,
            shared_draft: SharedDraft::Off,
            shared_draft_shards: 8,
        }
    }
}

/// Default artifacts directory: $NGRAMMYS_ARTIFACTS, else ./artifacts if a
/// manifest is present, else the synthetic reference-backend tree (built on
/// demand by [`crate::testkit`]) — which is what makes a bare checkout
/// buildable and testable without the python toolchain.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("NGRAMMYS_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let local = PathBuf::from("artifacts");
    if local.join("manifest.json").exists() {
        return local;
    }
    crate::testkit::artifacts_dir()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_default_matches_paper() {
        let e = EngineConfig::default();
        assert_eq!((e.k, e.w, e.q), (10, 10, 1));
    }

    #[test]
    fn manifest_missing_dir_errors() {
        let err = Manifest::load(Path::new("/nonexistent-dir")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
