//! KV-cache management (paper Appendix D, adapted).
//!
//! The paper batches by repeating the context KV `k` times and overwriting
//! all rows with the accepted row after verification. Because the k rows
//! share the context *exactly*, this repo keeps a single **shared** context
//! cache (batch dim 1) and lets the verification kernel treat it as shared
//! (bifurcated attention); only the (w+1)-long speculative tails are
//! per-row, and committing a step means copying the accepted row's tail
//! into the shared cache — the "overwrite all rows / broadcast from k=1"
//! dance collapses into a memcpy.
//!
//! Layout matches the L2 model: (layers, max_len, heads, head_dim) f32,
//! row-major. `SharedKvCache` lives in host memory (CPU PJRT device memory
//! *is* host memory) and is marshalled per call by the runtime.
//!
//! Two physical organizations sit behind one facade ([`KvStore`]):
//!
//! - **Lanes** ([`KvPool`]): one contiguous `SharedKvCache` per sequence.
//!   Simple, contiguous, and the differential-testing oracle.
//! - **Pages** ([`paged::PagedKvPool`]): fixed-size refcounted pages with
//!   copy-on-write prefix sharing — admissions whose prompt prefix matches
//!   resident pages attach them instead of duplicating the KV, so a fixed
//!   byte budget admits more concurrent sequences on shared-system-prompt
//!   traffic (the paper's verification step is memory-bound, so distinct
//!   KV bytes are THE capacity currency).
//!
//! The runtime reads either organization through [`KvRead`] and writes
//! through [`KvWrite`]; byte-identity of the two stores is pinned by
//! `rust/tests/paged_kv.rs`.

use anyhow::{anyhow, Result};

use crate::tokenizer::TokenId;

pub mod paged;

/// Read access to one sequence's committed KV context, independent of the
/// physical organization (contiguous lane vs page table).
///
/// Geometry accessors describe the *install/gather* layout — a dense
/// `(layers, max_ctx, heads, head_dim)` row-major f32 buffer — which is
/// what the prefill executables produce and the PJRT step executables
/// consume, whatever the store does internally.
pub trait KvRead {
    /// Transformer layer count.
    fn layers(&self) -> usize;
    /// Attention head count.
    fn heads(&self) -> usize;
    /// Per-head dimension.
    fn head_dim(&self) -> usize;
    /// Capacity in positions of the dense install/gather geometry.
    fn max_ctx(&self) -> usize;
    /// Number of committed positions.
    fn ctx_len(&self) -> usize;
    /// Positions this sequence may still commit.
    fn remaining(&self) -> usize {
        self.max_ctx() - self.ctx_len()
    }
    /// Elements per cached position within one layer.
    fn pos_stride(&self) -> usize {
        self.heads() * self.head_dim()
    }
    /// Elements per layer in the dense geometry.
    fn layer_stride(&self) -> usize {
        self.max_ctx() * self.pos_stride()
    }
    /// Total elements of each dense K / V buffer.
    fn numel(&self) -> usize {
        self.layers() * self.layer_stride()
    }
    /// One committed position's key vector in `layer`.
    fn k_at(&self, layer: usize, pos: usize) -> &[f32];
    /// One committed position's value vector in `layer`.
    fn v_at(&self, layer: usize, pos: usize) -> &[f32];
    /// Whole-buffer K/V access when the store is physically contiguous in
    /// the dense geometry (lane mode); `None` forces [`KvRead::gather`].
    fn as_contiguous(&self) -> Option<(&[f32], &[f32])> {
        None
    }
    /// Dense K/V copy in the install geometry; positions `>= ctx_len()`
    /// are zeroed. The PJRT marshalling path for paged sequences.
    fn gather(&self) -> (Vec<f32>, Vec<f32>) {
        let ps = self.pos_stride();
        let mut k = vec![0.0f32; self.numel()];
        let mut v = vec![0.0f32; self.numel()];
        for layer in 0..self.layers() {
            let base = layer * self.layer_stride();
            for pos in 0..self.ctx_len() {
                let dst = base + pos * ps;
                k[dst..dst + ps].copy_from_slice(self.k_at(layer, pos));
                v[dst..dst + ps].copy_from_slice(self.v_at(layer, pos));
            }
        }
        (k, v)
    }
}

/// Write access to one sequence's KV context: the three mutations the
/// decode loop performs, with identical semantics across stores.
pub trait KvWrite: KvRead {
    /// Install a freshly prefilled dense cache and set the valid length.
    fn install(&mut self, k_data: Vec<f32>, v_data: Vec<f32>, len: usize) -> Result<()>;
    /// Commit `count` positions from the accepted row of a step's KV tail
    /// (tails are shaped `(layers, k_rows, w1, heads, head_dim)`).
    fn commit_tail(
        &mut self,
        k_tail: &[f32],
        v_tail: &[f32],
        k_rows: usize,
        w1: usize,
        row: usize,
        count: usize,
    ) -> Result<()>;
    /// Rewind to a shorter length (rollback discipline; paged stores drop
    /// or copy-on-write the affected page tail).
    fn truncate(&mut self, len: usize) -> Result<()>;
}

/// Shared-context KV cache for a single sequence.
#[derive(Debug, Clone)]
pub struct SharedKvCache {
    /// key cache, (layers, max_len, heads, head_dim) row-major
    pub k_data: Vec<f32>,
    /// value cache, same layout
    pub v_data: Vec<f32>,
    /// transformer layer count
    pub layers: usize,
    /// cache capacity in positions
    pub max_len: usize,
    /// attention head count
    pub heads: usize,
    /// per-head dimension
    pub head_dim: usize,
    /// number of valid positions (tokens whose KV is committed)
    pub len: usize,
}

impl SharedKvCache {
    /// A zeroed cache of the given geometry (len 0).
    pub fn new(layers: usize, max_len: usize, heads: usize, head_dim: usize) -> Self {
        let n = layers * max_len * heads * head_dim;
        SharedKvCache {
            k_data: vec![0.0; n],
            v_data: vec![0.0; n],
            layers,
            max_len,
            heads,
            head_dim,
            len: 0,
        }
    }

    /// Elements per cached position within one layer.
    #[inline]
    pub fn pos_stride(&self) -> usize {
        self.heads * self.head_dim
    }

    /// Elements per layer.
    #[inline]
    pub fn layer_stride(&self) -> usize {
        self.max_len * self.pos_stride()
    }

    /// Total elements in each of `k_data` / `v_data`.
    pub fn numel(&self) -> usize {
        self.k_data.len()
    }

    /// Remaining capacity in positions.
    pub fn remaining(&self) -> usize {
        self.max_len - self.len
    }

    /// Install a freshly prefetched cache (from the prefill executable's
    /// output) and set the valid length.
    pub fn install(&mut self, k_data: Vec<f32>, v_data: Vec<f32>, len: usize) -> Result<()> {
        if k_data.len() != self.numel() || v_data.len() != self.numel() {
            return Err(anyhow!(
                "cache install size mismatch: got {} / {}, want {}",
                k_data.len(),
                v_data.len(),
                self.numel()
            ));
        }
        if len > self.max_len {
            return Err(anyhow!("cache len {len} > max_len {}", self.max_len));
        }
        self.k_data = k_data;
        self.v_data = v_data;
        self.len = len;
        Ok(())
    }

    /// Commit `count` positions from the accepted row of a step's KV tail.
    ///
    /// Tails are shaped (layers, k_rows, w1, heads, head_dim); this copies
    /// `tail[layer][row][0..count]` into positions `len .. len+count` of
    /// every layer and advances `len`.
    pub fn commit_tail(
        &mut self,
        k_tail: &[f32],
        v_tail: &[f32],
        k_rows: usize,
        w1: usize,
        row: usize,
        count: usize,
    ) -> Result<()> {
        if row >= k_rows || count > w1 {
            return Err(anyhow!("bad commit row={row}/{k_rows} count={count}/{w1}"));
        }
        if self.len + count > self.max_len {
            return Err(anyhow!(
                "cache overflow: len {} + commit {} > max_len {}",
                self.len,
                count,
                self.max_len
            ));
        }
        let ps = self.pos_stride();
        let expect = self.layers * k_rows * w1 * ps;
        if k_tail.len() != expect || v_tail.len() != expect {
            return Err(anyhow!(
                "tail size mismatch: got {}, want {expect}",
                k_tail.len()
            ));
        }
        for layer in 0..self.layers {
            let src_base = (layer * k_rows + row) * w1 * ps;
            let dst_base = layer * self.layer_stride() + self.len * ps;
            let n = count * ps;
            self.k_data[dst_base..dst_base + n]
                .copy_from_slice(&k_tail[src_base..src_base + n]);
            self.v_data[dst_base..dst_base + n]
                .copy_from_slice(&v_tail[src_base..src_base + n]);
        }
        self.len += count;
        Ok(())
    }

    /// Rewind to a shorter length (used by failure-injection tests and
    /// prefix-reuse). KV data beyond `len` becomes garbage-but-masked.
    pub fn truncate(&mut self, len: usize) -> Result<()> {
        if len > self.len {
            return Err(anyhow!("cannot truncate {} -> {len}", self.len));
        }
        self.len = len;
        Ok(())
    }
}

impl KvRead for SharedKvCache {
    fn layers(&self) -> usize {
        self.layers
    }
    fn heads(&self) -> usize {
        self.heads
    }
    fn head_dim(&self) -> usize {
        self.head_dim
    }
    fn max_ctx(&self) -> usize {
        self.max_len
    }
    fn ctx_len(&self) -> usize {
        self.len
    }
    fn k_at(&self, layer: usize, pos: usize) -> &[f32] {
        let ps = self.heads * self.head_dim;
        let off = layer * self.max_len * ps + pos * ps;
        &self.k_data[off..off + ps]
    }
    fn v_at(&self, layer: usize, pos: usize) -> &[f32] {
        let ps = self.heads * self.head_dim;
        let off = layer * self.max_len * ps + pos * ps;
        &self.v_data[off..off + ps]
    }
    fn as_contiguous(&self) -> Option<(&[f32], &[f32])> {
        Some((&self.k_data, &self.v_data))
    }
}

impl KvWrite for SharedKvCache {
    fn install(&mut self, k_data: Vec<f32>, v_data: Vec<f32>, len: usize) -> Result<()> {
        SharedKvCache::install(self, k_data, v_data, len)
    }
    fn commit_tail(
        &mut self,
        k_tail: &[f32],
        v_tail: &[f32],
        k_rows: usize,
        w1: usize,
        row: usize,
        count: usize,
    ) -> Result<()> {
        SharedKvCache::commit_tail(self, k_tail, v_tail, k_rows, w1, row, count)
    }
    fn truncate(&mut self, len: usize) -> Result<()> {
        SharedKvCache::truncate(self, len)
    }
}

/// Handle to one lane of a [`KvPool`]. Opaque outside this module so lanes
/// can only be reached through the pool that owns them.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LaneId(usize);

/// Pool of per-sequence KV lanes for the continuous-batching engine.
///
/// Each admitted sequence acquires a lane (its own `SharedKvCache`),
/// decodes into it for its whole lifetime, and releases it on retirement;
/// the lane is then reclaimed for the next admission. Lanes are physically
/// separate buffers, so one sequence's commits can never touch another's
/// context — the cross-contamination property test in
/// `rust/tests/batched_engine.rs` pins this down.
#[derive(Debug)]
pub struct KvPool {
    lanes: Vec<SharedKvCache>,
    free: Vec<usize>,
    /// lane dimensions, kept so [`Self::resize`] can mint new lanes
    dims: (usize, usize, usize, usize),
}

impl KvPool {
    /// A pool of `n_lanes` lanes, each a `(layers, max_len, heads,
    /// head_dim)` [`SharedKvCache`].
    pub fn new(layers: usize, max_len: usize, heads: usize, head_dim: usize,
               n_lanes: usize) -> Self {
        assert!(n_lanes > 0, "pool needs at least one lane");
        KvPool {
            lanes: (0..n_lanes)
                .map(|_| SharedKvCache::new(layers, max_len, heads, head_dim))
                .collect(),
            free: (0..n_lanes).rev().collect(),
            dims: (layers, max_len, heads, head_dim),
        }
    }

    /// Grow or shrink the pool toward `target` lanes (floored at 1) and
    /// return the resulting capacity — the elastic scheduler's scale knob.
    ///
    /// Growth allocates fresh (zeroed, free) lanes immediately. Shrinking
    /// only ever reclaims FREE lanes, and only from the tail of the lane
    /// array, so every outstanding [`LaneId`] stays valid: a busy lane in
    /// tail position pauses the shrink, and the autoscaler simply re-asks
    /// on a later step once that sequence has retired. Memory for a
    /// reclaimed lane is released outright (lanes are independent buffers).
    pub fn resize(&mut self, target: usize) -> usize {
        let target = target.max(1);
        let (layers, max_len, heads, head_dim) = self.dims;
        while self.lanes.len() < target {
            self.free.push(self.lanes.len());
            self.lanes.push(SharedKvCache::new(layers, max_len, heads, head_dim));
        }
        while self.lanes.len() > target {
            let tail = self.lanes.len() - 1;
            match self.free.iter().position(|&i| i == tail) {
                Some(pos) => {
                    self.free.swap_remove(pos);
                    self.lanes.pop();
                }
                None => break, // tail lane busy; shrink resumes later
            }
        }
        self.lanes.len()
    }

    /// Total number of lanes (the engine's max concurrency).
    pub fn capacity(&self) -> usize {
        self.lanes.len()
    }

    /// Bytes one lane pins (key + value buffers). Lanes are full
    /// `(layers, max_len, heads, head_dim)` f32 caches, so this is what
    /// every scale decision trades against latency.
    pub fn lane_bytes(&self) -> usize {
        let (layers, max_len, heads, head_dim) = self.dims;
        2 * layers * max_len * heads * head_dim * std::mem::size_of::<f32>()
    }

    /// Bytes the whole pool currently pins — what an engine retire or a
    /// lane shrink actually gives back (exported per engine as the
    /// `ngrammys_engine_kv_bytes` gauge).
    pub fn memory_bytes(&self) -> usize {
        self.lanes.len() * self.lane_bytes()
    }

    /// Free lanes.
    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// Lanes currently claimed by sequences.
    pub fn in_use(&self) -> usize {
        self.lanes.len() - self.free.len()
    }

    /// Claim a free lane (length reset to 0), or None under full load —
    /// the admission loop treats that as backpressure.
    ///
    /// Always claims the LOWEST-index free lane, so under steady traffic
    /// the busy lanes pack toward the low end of the pool and the
    /// tail-only shrink in [`Self::resize`] can actually reclaim the high
    /// end — a LIFO free list would hand freshly-grown tail lanes out
    /// first and starve every downscale.
    pub fn acquire(&mut self) -> Option<LaneId> {
        let pos = self
            .free
            .iter()
            .enumerate()
            .min_by_key(|&(_, &lane)| lane)
            .map(|(pos, _)| pos)?;
        let i = self.free.swap_remove(pos);
        self.lanes[i].len = 0;
        Some(LaneId(i))
    }

    /// Return a retired sequence's lane to the free list. Idempotent: a
    /// double release is ignored rather than corrupting the free list.
    pub fn release(&mut self, lane: LaneId) {
        debug_assert!(!self.free.contains(&lane.0), "double lane release");
        if !self.free.contains(&lane.0) {
            self.lanes[lane.0].len = 0;
            self.free.push(lane.0);
        }
    }

    /// Borrow one lane's cache.
    pub fn lane(&self, lane: LaneId) -> &SharedKvCache {
        &self.lanes[lane.0]
    }

    /// Mutably borrow one lane's cache.
    pub fn lane_mut(&mut self, lane: LaneId) -> &mut SharedKvCache {
        &mut self.lanes[lane.0]
    }
}

/// Handle to one sequence's KV context inside a [`KvStore`], whichever
/// physical organization backs it. Opaque outside this module.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct KvSeq(usize);

/// Borrowed read view of one sequence's KV ([`KvStore::slot`]).
pub enum KvSlot<'a> {
    /// contiguous lane
    Lane(&'a SharedKvCache),
    /// page-table view
    Paged(paged::PagedSeqView<'a>),
}

impl KvSlot<'_> {
    /// The view as a dyn [`KvRead`] for the runtime.
    pub fn as_read(&self) -> &dyn KvRead {
        match self {
            KvSlot::Lane(c) => *c,
            KvSlot::Paged(v) => v,
        }
    }
}

/// Borrowed write view of one sequence's KV ([`KvStore::slot_mut`]).
pub enum KvSlotMut<'a> {
    /// contiguous lane
    Lane(&'a mut SharedKvCache),
    /// page-table writer
    Paged(paged::PagedSeqWriter<'a>),
}

impl KvSlotMut<'_> {
    /// The view as a dyn [`KvWrite`] for the runtime and commit path.
    pub fn as_write(&mut self) -> &mut dyn KvWrite {
        match self {
            KvSlotMut::Lane(c) => *c,
            KvSlotMut::Paged(w) => w,
        }
    }
}

/// Per-step page accounting snapshot ([`KvStore::page_stats`]), exported
/// as the `ngrammys_kv_pages{,_free,_shared}` / prefix-hit gauges. The
/// lane store reports lane-equivalent numbers (one "page" per lane, no
/// sharing) so dashboards work in either mode.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PageStats {
    /// distinct pages currently referenced by at least one sequence
    pub live: u64,
    /// pages still admittable against the budget (free minus reservations)
    pub free: u64,
    /// pages referenced by two or more sequences (prefix sharing at work)
    pub shared: u64,
    /// admissions that attached at least one resident shared page
    pub prefix_hits: u64,
}

/// The engine-facing KV facade: a pool of per-sequence contexts backed by
/// either lane-oriented contiguous allocation ([`KvPool`], the oracle) or
/// fixed-size refcounted pages with copy-on-write prefix sharing
/// ([`paged::PagedKvPool`]).
///
/// Both organizations expose identical semantics through [`KvSeq`]
/// handles; the engine's decode loop is store-agnostic, and the two
/// stores are differentially tested against each other.
#[derive(Debug)]
pub enum KvStore {
    /// one contiguous `SharedKvCache` per sequence
    Lanes(KvPool),
    /// refcounted fixed-size pages with prefix sharing
    Paged(paged::PagedKvPool),
}

impl KvStore {
    /// Lane-oriented store of `n_lanes` contiguous caches.
    pub fn lanes(
        layers: usize,
        max_len: usize,
        heads: usize,
        head_dim: usize,
        n_lanes: usize,
    ) -> Self {
        KvStore::Lanes(KvPool::new(layers, max_len, heads, head_dim, n_lanes))
    }

    /// Paged store: `n_pages` pages of `page_size` positions each, with
    /// admission concurrency capped at `seq_cap` sequences.
    pub fn paged(
        layers: usize,
        max_len: usize,
        heads: usize,
        head_dim: usize,
        page_size: usize,
        n_pages: usize,
        seq_cap: usize,
    ) -> Self {
        KvStore::Paged(paged::PagedKvPool::new(
            layers, max_len, heads, head_dim, page_size, n_pages, seq_cap,
        ))
    }

    /// Concurrency capacity: lane count, or the paged admission cap.
    pub fn capacity(&self) -> usize {
        match self {
            KvStore::Lanes(p) => p.capacity(),
            KvStore::Paged(p) => p.seq_cap(),
        }
    }

    /// Scale the concurrency capacity toward `target` (floored at 1) and
    /// return the achieved value — the elastic scheduler's knob.
    pub fn set_capacity(&mut self, target: usize) -> usize {
        match self {
            KvStore::Lanes(p) => p.resize(target),
            KvStore::Paged(p) => p.set_seq_cap(target),
        }
    }

    /// Sequences currently resident.
    pub fn in_use(&self) -> usize {
        match self {
            KvStore::Lanes(p) => p.in_use(),
            KvStore::Paged(p) => p.in_use(),
        }
    }

    /// Bytes of KV the store currently pins.
    pub fn memory_bytes(&self) -> usize {
        match self {
            KvStore::Lanes(p) => p.memory_bytes(),
            KvStore::Paged(p) => p.memory_bytes(),
        }
    }

    /// Whether an admission with this prompt and position reservation
    /// would succeed right now. Lane mode only needs a free lane; paged
    /// mode also accounts distinct new pages after prefix sharing.
    pub fn can_admit(&self, prompt: &[TokenId], max_pos: usize) -> bool {
        match self {
            KvStore::Lanes(p) => p.available() > 0,
            KvStore::Paged(p) => p.can_admit(prompt, max_pos),
        }
    }

    /// Admit a sequence: claim a context sized for `max_pos` positions.
    /// Paged stores attach resident pages matching the prompt prefix
    /// (copy-on-write shared) and reserve page credits for the rest, so a
    /// successful acquire can never fail allocation mid-decode. `None`
    /// means backpressure.
    pub fn acquire(&mut self, prompt: &[TokenId], max_pos: usize) -> Option<KvSeq> {
        match self {
            KvStore::Lanes(p) => p.acquire().map(|l| KvSeq(l.0)),
            KvStore::Paged(p) => p.acquire(prompt, max_pos).map(KvSeq),
        }
    }

    /// Return a retired sequence's context to the store. Idempotent.
    pub fn release(&mut self, seq: KvSeq) {
        match self {
            KvStore::Lanes(p) => p.release(LaneId(seq.0)),
            KvStore::Paged(p) => p.release(seq.0),
        }
    }

    /// Committed positions of one sequence.
    pub fn ctx_len(&self, seq: KvSeq) -> usize {
        match self {
            KvStore::Lanes(p) => p.lane(LaneId(seq.0)).len,
            KvStore::Paged(p) => p.seq_len(seq.0),
        }
    }

    /// Positions one sequence may still commit (identical semantics in
    /// both modes: the model's max context minus the committed length —
    /// paged reservations are sized so they never bind before this).
    pub fn seq_remaining(&self, seq: KvSeq) -> usize {
        match self {
            KvStore::Lanes(p) => p.lane(LaneId(seq.0)).remaining(),
            KvStore::Paged(p) => p.seq_remaining(seq.0),
        }
    }

    /// Borrow one sequence's read view.
    pub fn slot(&self, seq: KvSeq) -> KvSlot<'_> {
        match self {
            KvStore::Lanes(p) => KvSlot::Lane(p.lane(LaneId(seq.0))),
            KvStore::Paged(p) => KvSlot::Paged(p.view(seq.0)),
        }
    }

    /// Borrow one sequence's write view.
    pub fn slot_mut(&mut self, seq: KvSeq) -> KvSlotMut<'_> {
        match self {
            KvStore::Lanes(p) => KvSlotMut::Lane(p.lane_mut(LaneId(seq.0))),
            KvStore::Paged(p) => KvSlotMut::Paged(p.writer(seq.0)),
        }
    }

    /// Reconcile the store's token mirror for one sequence with the
    /// engine's authoritative token stream (prompt + committed tokens).
    /// Paged stores use it to seal full pages into the prefix index; the
    /// lane store ignores it.
    pub fn sync_tokens(&mut self, seq: KvSeq, tokens: &[TokenId]) {
        if let KvStore::Paged(p) = self {
            p.sync_tokens(seq.0, tokens);
        }
    }

    /// Page accounting snapshot for metrics export.
    pub fn page_stats(&self) -> PageStats {
        match self {
            KvStore::Lanes(p) => PageStats {
                live: p.in_use() as u64,
                free: p.available() as u64,
                shared: 0,
                prefix_hits: 0,
            },
            KvStore::Paged(p) => p.page_stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> SharedKvCache {
        SharedKvCache::new(2, 8, 2, 4)
    }

    #[test]
    fn commit_places_rows_correctly() {
        let mut c = mk();
        c.len = 3;
        let (layers, k_rows, w1, ps) = (2, 3, 2, c.pos_stride());
        let n = layers * k_rows * w1 * ps;
        // tail values encode (layer, row, pos) so placement is checkable
        let k_tail: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let v_tail: Vec<f32> = (0..n).map(|i| (i as f32) * 10.0).collect();
        c.commit_tail(&k_tail, &v_tail, k_rows, w1, 1, 2).unwrap();
        assert_eq!(c.len, 5);
        // layer 0, committed position 3 == tail[layer=0][row=1][pos=0]
        let src = (0 * k_rows + 1) * w1 * ps;
        let dst = 0 * c.layer_stride() + 3 * ps;
        assert_eq!(&c.k_data[dst..dst + ps], &k_tail[src..src + ps]);
        // layer 1, committed position 4 == tail[layer=1][row=1][pos=1]
        let src = ((1 * k_rows + 1) * w1 + 1) * ps;
        let dst = 1 * c.layer_stride() + 4 * ps;
        assert_eq!(&c.v_data[dst..dst + ps], &v_tail[src..src + ps]);
    }

    #[test]
    fn commit_overflow_rejected() {
        let mut c = mk();
        c.len = 7;
        let ps = c.pos_stride();
        let n = 2 * 1 * 2 * ps;
        let t = vec![0.0; n];
        assert!(c.commit_tail(&t, &t, 1, 2, 0, 2).is_err());
        assert_eq!(c.len, 7, "failed commit must not advance len");
    }

    #[test]
    fn bad_row_rejected() {
        let mut c = mk();
        let ps = c.pos_stride();
        let t = vec![0.0; 2 * 2 * 2 * ps];
        assert!(c.commit_tail(&t, &t, 2, 2, 2, 1).is_err());
    }

    #[test]
    fn truncate() {
        let mut c = mk();
        c.len = 5;
        c.truncate(2).unwrap();
        assert_eq!(c.len, 2);
        assert!(c.truncate(3).is_err());
    }

    #[test]
    fn kv_pool_acquire_release_cycle() {
        let mut p = KvPool::new(1, 8, 1, 2, 2);
        assert_eq!((p.capacity(), p.available(), p.in_use()), (2, 2, 0));
        let a = p.acquire().unwrap();
        let b = p.acquire().unwrap();
        assert_ne!(a, b);
        assert!(p.acquire().is_none(), "over-capacity acquire must fail");
        p.lane_mut(a).len = 5;
        p.release(a);
        assert_eq!(p.available(), 1);
        let c = p.acquire().unwrap();
        assert_eq!(p.lane(c).len, 0, "reclaimed lane must be reset");
        assert_eq!(p.in_use(), 2);
    }

    #[test]
    fn acquire_prefers_lowest_index_lane() {
        let mut p = KvPool::new(1, 8, 1, 2, 1);
        let a = p.acquire().unwrap();
        assert_eq!(p.resize(4), 4);
        p.release(a);
        // free lanes {0, 1, 2, 3}: the lowest index wins, so the tail
        // stays reclaimable under steady acquire/release churn
        let b = p.acquire().unwrap();
        assert_eq!(b, a, "re-acquire must pick the lowest free lane");
        assert_eq!(p.resize(1), 1, "tail lanes stayed free and shrinkable");
        assert_eq!(p.in_use(), 1);
    }

    #[test]
    fn kv_pool_resize_grows_and_shrinks() {
        let mut p = KvPool::new(1, 8, 1, 2, 2);
        assert_eq!(p.resize(4), 4);
        assert_eq!((p.capacity(), p.available()), (4, 4));
        // new lanes are immediately acquirable
        let ids: Vec<_> = (0..4).map(|_| p.acquire().unwrap()).collect();
        assert_eq!(p.in_use(), 4);
        for id in ids {
            p.release(id);
        }
        assert_eq!(p.resize(1), 1);
        assert_eq!((p.capacity(), p.available()), (1, 1));
        // floor at one lane
        assert_eq!(p.resize(0), 1);
    }

    #[test]
    fn kv_pool_shrink_never_evicts_busy_lanes() {
        let mut p = KvPool::new(1, 8, 1, 2, 4);
        let a = p.acquire().unwrap(); // lane 0 (lowest index first)
        let b = p.acquire().unwrap(); // lane 1
        // lanes 2/3 are free tail lanes: the shrink reclaims them, then
        // stops dead at busy lane 1 instead of evicting it
        assert_eq!(p.resize(1), 2);
        assert_eq!(p.in_use(), 2);
        p.lane_mut(a).len = 3;
        p.release(a);
        // lane 0 is free but lane 1 (the tail) is still busy: no progress
        assert_eq!(p.resize(1), 2);
        p.release(b);
        assert_eq!(p.resize(1), 1);
        // the surviving lane is usable
        let c = p.acquire().unwrap();
        assert_eq!(p.lane(c).len, 0);
    }

    #[test]
    fn kv_pool_lanes_are_distinct_buffers() {
        let mut p = KvPool::new(1, 4, 1, 2, 2);
        let a = p.acquire().unwrap();
        let b = p.acquire().unwrap();
        p.lane_mut(a).k_data[0] = 7.0;
        assert_eq!(p.lane(b).k_data[0], 0.0);
    }

    #[test]
    fn memory_accounting_tracks_resize() {
        let mut p = KvPool::new(2, 8, 2, 4, 3);
        // 2 buffers * layers * max_len * heads * head_dim * 4 bytes
        assert_eq!(p.lane_bytes(), 2 * 2 * 8 * 2 * 4 * 4);
        assert_eq!(p.memory_bytes(), 3 * p.lane_bytes());
        p.resize(1);
        assert_eq!(p.memory_bytes(), p.lane_bytes());
        p.resize(4);
        assert_eq!(p.memory_bytes(), 4 * p.lane_bytes());
    }

    #[test]
    fn kv_store_facade_matches_lane_pool() {
        let mut s = KvStore::lanes(1, 8, 1, 2, 2);
        assert_eq!((s.capacity(), s.in_use()), (2, 0));
        let a = s.acquire(&[1, 2], 8).unwrap();
        assert!(s.can_admit(&[1, 2], 8));
        let b = s.acquire(&[3], 8).unwrap();
        assert!(!s.can_admit(&[4], 8), "full lane store must backpressure");
        assert_eq!(s.ctx_len(a), 0);
        assert_eq!(s.seq_remaining(a), 8);
        let st = s.page_stats();
        assert_eq!((st.live, st.free, st.shared), (2, 0, 0));
        s.release(a);
        s.release(b);
        assert_eq!(s.in_use(), 0);
    }
}
