//! Paged KV pool: fixed-size refcounted pages with copy-on-write prefix
//! sharing (vLLM-style, adapted to this repo's shared-context caches).
//!
//! Physical layout: a page holds `page_size` consecutive positions of ONE
//! sequence's KV, shaped `(layers, page_size, heads, head_dim)` f32
//! row-major per buffer. A sequence is a page table (ordered page
//! indices) plus a committed length; position `p` lives in table entry
//! `p / page_size` at page-local offset `p % page_size`.
//!
//! Sharing: when a page becomes FULL and its tokens are known, it is
//! *sealed* — registered in a prefix index keyed by the chained hash of
//! ALL tokens from position 0 through the page's end (KV at a position
//! depends on the entire prefix, so only whole-prefix matches may share).
//! A later admission whose prompt walks the same chain attaches those
//! pages with a refcount bump instead of duplicating their bytes. Lookup
//! candidates are verified by parent-hash linkage AND a stored-token
//! compare of the page's own span, so a hash collision cannot splice two
//! different prefixes together.
//!
//! Copy-on-write: pages are only ever written at positions `>= len`, so a
//! shared page is immutable while any co-owner's committed length covers
//! it. The engine path is append-only and never triggers COW; rolling
//! back (`truncate`) into a shared region and then diverging does — the
//! writer clones the page into a private copy (charged against the
//! sequence's own reservation) and drops one reference.
//!
//! Admission is *reservation-based*: `acquire` charges the sequence for
//! every page it could ever need (`pages_for(max_pos)` minus attached
//! shared pages) up front, so a successful admission can never fail page
//! allocation mid-decode — the invariant `live + reserved <= budget`
//! holds at all times and `can_admit` is the scheduler's backpressure
//! signal in units of distinct pages, not worst-case lanes.

use std::collections::{HashMap, VecDeque};

use anyhow::{anyhow, ensure, Result};

use super::{KvRead, KvWrite, PageStats};
use crate::tokenizer::TokenId;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn hash_push(h: u64, t: TokenId) -> u64 {
    (h ^ (t as u64).wrapping_add(0x9E37_79B9)).wrapping_mul(FNV_PRIME)
}

/// Chained hash of a whole token prefix (root = FNV offset basis).
fn chain_hash(tokens: &[TokenId]) -> u64 {
    tokens.iter().fold(FNV_OFFSET, |h, &t| hash_push(h, t))
}

/// One fixed-size page of KV positions.
#[derive(Debug)]
struct Page {
    /// key buffer, (layers, page_size, heads, head_dim) row-major
    k: Vec<f32>,
    /// value buffer, same layout
    v: Vec<f32>,
    /// sequences referencing this page (0 = free or cached)
    refs: usize,
    /// chained boundary hash through this page's end (valid when sealed)
    key: u64,
    /// chained boundary hash through the PREVIOUS page's end
    parent: u64,
    /// this page's own tokens (valid when sealed)
    toks: Vec<TokenId>,
    /// registered in the prefix index
    sealed: bool,
    /// sitting in the reclaimable cache (refs == 0, sealed)
    cached: bool,
    /// generation stamp; invalidates stale cache-queue entries
    stamp: u64,
}

/// One sequence's page table + bookkeeping.
#[derive(Debug)]
struct PagedSeq {
    /// page indices covering positions, in order
    table: Vec<usize>,
    /// committed positions
    len: usize,
    /// admission-time position reservation (never exceeded by design)
    max_pos: usize,
    /// page credits this sequence may still materialize
    reserve: usize,
    /// positions covered by shared pages attached at admission
    shared_len: usize,
    /// token mirror of committed positions (drives sealing)
    tokens: Vec<TokenId>,
    /// leading pages of `table` that are sealed/registered
    sealed: usize,
    /// chained hash of tokens[0 .. sealed * page_size]
    boundary: u64,
}

/// Paged KV pool with refcounted copy-on-write prefix sharing.
///
/// See the module docs for the design; the engine reaches it through
/// [`super::KvStore::Paged`] and per-sequence [`PagedSeqView`] /
/// [`PagedSeqWriter`] borrows.
#[derive(Debug)]
pub struct PagedKvPool {
    layers: usize,
    max_len: usize,
    heads: usize,
    head_dim: usize,
    page_size: usize,
    /// hard cap on materialized pages
    budget: usize,
    pages: Vec<Page>,
    free: Vec<usize>,
    /// reclaimable sealed pages (refs == 0), oldest first, with stamps
    cached: VecDeque<(usize, u64)>,
    /// chained boundary hash -> sealed page candidates
    index: HashMap<u64, Vec<usize>>,
    /// pages with refs > 0
    live: usize,
    /// outstanding page credits across all sequences
    reserved: usize,
    seqs: Vec<Option<PagedSeq>>,
    free_sids: Vec<usize>,
    active: usize,
    seq_cap: usize,
    prefix_hits: u64,
    next_stamp: u64,
}

impl PagedKvPool {
    /// A pool of up to `n_pages` pages of `page_size` positions for a
    /// `(layers, max_len, heads, head_dim)` model, admitting at most
    /// `seq_cap` concurrent sequences.
    pub fn new(
        layers: usize,
        max_len: usize,
        heads: usize,
        head_dim: usize,
        page_size: usize,
        n_pages: usize,
        seq_cap: usize,
    ) -> Self {
        assert!(page_size > 0, "page size must be positive");
        assert!(n_pages > 0, "pool needs at least one page");
        PagedKvPool {
            layers,
            max_len,
            heads,
            head_dim,
            page_size,
            budget: n_pages,
            pages: Vec::new(),
            free: Vec::new(),
            cached: VecDeque::new(),
            index: HashMap::new(),
            live: 0,
            reserved: 0,
            seqs: Vec::new(),
            free_sids: Vec::new(),
            active: 0,
            seq_cap: seq_cap.max(1),
            prefix_hits: 0,
            next_stamp: 0,
        }
    }

    /// Positions per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Hard cap on materialized pages.
    pub fn page_budget(&self) -> usize {
        self.budget
    }

    /// Pages needed to hold `positions`.
    pub fn pages_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.page_size)
    }

    /// Bytes one page pins (key + value buffers).
    pub fn page_bytes(&self) -> usize {
        2 * self.layers * self.page_size * self.heads * self.head_dim
            * std::mem::size_of::<f32>()
    }

    /// Bytes currently materialized (live + reclaimable cached pages).
    pub fn memory_bytes(&self) -> usize {
        self.pages.len() * self.page_bytes()
    }

    /// Admission concurrency cap.
    pub fn seq_cap(&self) -> usize {
        self.seq_cap
    }

    /// Scale the admission concurrency cap (floored at 1); resident
    /// sequences are never evicted, so shrinking below `in_use` only
    /// stops new admissions until sequences retire.
    pub fn set_seq_cap(&mut self, target: usize) -> usize {
        self.seq_cap = target.max(1);
        self.seq_cap
    }

    /// Sequences currently resident.
    pub fn in_use(&self) -> usize {
        self.active
    }

    fn stamp(&mut self) -> u64 {
        self.next_stamp += 1;
        self.next_stamp
    }

    /// Walk the prompt's full pages down the prefix index: returns the
    /// longest chain of resident sealed pages matching the prompt prefix
    /// exactly (parent-linked hashes + stored-token verification).
    fn probe(&self, prompt: &[TokenId]) -> Vec<usize> {
        let psz = self.page_size;
        let mut found = Vec::new();
        let mut boundary = FNV_OFFSET;
        let mut j = 0usize;
        while (j + 1) * psz <= prompt.len() {
            let span = &prompt[j * psz..(j + 1) * psz];
            let key = span.iter().fold(boundary, |h, &t| hash_push(h, t));
            let hit = self.index.get(&key).and_then(|cands| {
                cands.iter().copied().find(|&i| {
                    let p = &self.pages[i];
                    p.sealed && p.parent == boundary && p.toks == span
                })
            });
            match hit {
                Some(i) => {
                    found.push(i);
                    boundary = key;
                    j += 1;
                }
                None => break,
            }
        }
        found
    }

    /// Whether a sequence with this prompt and `max_pos` reservation can
    /// be admitted right now: a free sequence slot AND enough page budget
    /// for its distinct (non-shared) pages.
    pub fn can_admit(&self, prompt: &[TokenId], max_pos: usize) -> bool {
        if self.active >= self.seq_cap {
            return false;
        }
        let shared = self.probe(prompt);
        let revive = shared.iter().filter(|&&i| self.pages[i].refs == 0).count();
        let need = self.pages_for(max_pos.min(self.max_len)).saturating_sub(shared.len());
        self.live + self.reserved + need + revive <= self.budget
    }

    /// Admit a sequence: attach resident pages matching the prompt prefix
    /// and reserve credits for every page it could still need. `None` is
    /// backpressure. The returned id is valid until [`Self::release`].
    pub fn acquire(&mut self, prompt: &[TokenId], max_pos: usize) -> Option<usize> {
        let max_pos = max_pos.min(self.max_len);
        if !self.can_admit(prompt, max_pos) {
            return None;
        }
        let shared = self.probe(prompt);
        let need = self.pages_for(max_pos).saturating_sub(shared.len());
        let mut boundary = FNV_OFFSET;
        for &i in &shared {
            let s = self.stamp();
            let p = &mut self.pages[i];
            if p.refs == 0 {
                self.live += 1;
                p.cached = false;
                p.stamp = s;
            }
            p.refs += 1;
            boundary = p.key;
        }
        if !shared.is_empty() {
            self.prefix_hits += 1;
        }
        self.reserved += need;
        // attached pages are already sealed/registered: adopt them as this
        // sequence's sealed prefix
        let seq = PagedSeq {
            shared_len: shared.len() * self.page_size,
            sealed: shared.len(),
            table: shared,
            len: 0,
            max_pos,
            reserve: need,
            tokens: prompt.to_vec(),
            boundary,
        };
        let sid = match self.free_sids.pop() {
            Some(s) => {
                self.seqs[s] = Some(seq);
                s
            }
            None => {
                self.seqs.push(Some(seq));
                self.seqs.len() - 1
            }
        };
        self.active += 1;
        Some(sid)
    }

    /// Retire a sequence: drop one reference from each of its pages
    /// (sealed pages with no owners left stay materialized in the
    /// reclaim cache for future prefix hits) and return its unused
    /// reservation. Idempotent.
    pub fn release(&mut self, sid: usize) {
        let Some(seq) = self.seqs.get_mut(sid).and_then(Option::take) else {
            return;
        };
        for &i in &seq.table {
            self.unref_page(i);
        }
        self.reserved -= seq.reserve;
        self.free_sids.push(sid);
        self.active -= 1;
    }

    fn unref_page(&mut self, i: usize) {
        let s = self.stamp();
        let p = &mut self.pages[i];
        debug_assert!(p.refs > 0, "unref of unreferenced page");
        p.refs -= 1;
        if p.refs == 0 {
            self.live -= 1;
            if p.sealed {
                p.cached = true;
                p.stamp = s;
                self.cached.push_back((i, s));
            } else {
                self.free.push(i);
            }
        }
    }

    /// Materialize one blank page: free list first, then fresh
    /// allocation within budget, then eviction of the oldest reclaimable
    /// cached page (unregistering it from the prefix index).
    fn alloc_page(&mut self) -> Result<usize> {
        if let Some(i) = self.free.pop() {
            self.reset_page(i);
            return Ok(i);
        }
        if self.pages.len() < self.budget {
            let n = self.layers * self.page_size * self.heads * self.head_dim;
            self.pages.push(Page {
                k: vec![0.0; n],
                v: vec![0.0; n],
                refs: 0,
                key: 0,
                parent: 0,
                toks: Vec::new(),
                sealed: false,
                cached: false,
                stamp: 0,
            });
            return Ok(self.pages.len() - 1);
        }
        while let Some((i, stamp)) = self.cached.pop_front() {
            let p = &self.pages[i];
            if p.cached && p.refs == 0 && p.stamp == stamp {
                self.unregister_page(i);
                self.reset_page(i);
                return Ok(i);
            }
            // stale entry: the page was revived or already recycled
        }
        Err(anyhow!(
            "out of KV pages: {} live + {} reserved of {}",
            self.live,
            self.reserved,
            self.budget
        ))
    }

    fn reset_page(&mut self, i: usize) {
        let s = self.stamp();
        let p = &mut self.pages[i];
        p.k.fill(0.0);
        p.v.fill(0.0);
        p.refs = 0;
        p.sealed = false;
        p.cached = false;
        p.toks.clear();
        p.stamp = s;
    }

    fn unregister_page(&mut self, i: usize) {
        let key = self.pages[i].key;
        if let Some(v) = self.index.get_mut(&key) {
            v.retain(|&c| c != i);
            if v.is_empty() {
                self.index.remove(&key);
            }
        }
        self.pages[i].sealed = false;
        self.pages[i].cached = false;
    }

    /// Make position `pos` of `sid` writable: extend the table with a
    /// fresh page (consuming reservation), copy-on-write a shared page,
    /// or unseal a registered exclusive page about to change.
    fn ensure_pos_writable(&mut self, sid: usize, pos: usize) -> Result<()> {
        let psz = self.page_size;
        let j = pos / psz;
        loop {
            let seq = self.seqs[sid].as_ref().expect("writable: released seq");
            if seq.table.len() > j {
                break;
            }
            ensure!(
                self.seqs[sid].as_ref().unwrap().reserve > 0,
                "KV reservation exhausted (pos {pos} beyond max_pos {})",
                self.seqs[sid].as_ref().unwrap().max_pos
            );
            let i = self.alloc_page()?;
            self.pages[i].refs = 1;
            self.live += 1;
            self.reserved -= 1;
            let seq = self.seqs[sid].as_mut().unwrap();
            seq.reserve -= 1;
            seq.table.push(i);
        }
        let i = self.seqs[sid].as_ref().unwrap().table[j];
        if self.pages[i].refs > 1 {
            // copy-on-write: diverging from a shared page
            ensure!(
                self.seqs[sid].as_ref().unwrap().reserve > 0,
                "KV reservation exhausted for copy-on-write at pos {pos}"
            );
            let n = self.alloc_page()?;
            let (k, v) = (self.pages[i].k.clone(), self.pages[i].v.clone());
            self.pages[n].k.copy_from_slice(&k);
            self.pages[n].v.copy_from_slice(&v);
            self.pages[n].refs = 1;
            self.live += 1;
            self.reserved -= 1;
            self.pages[i].refs -= 1;
            let seq = self.seqs[sid].as_mut().unwrap();
            seq.reserve -= 1;
            seq.table[j] = n;
            self.rewind_seal(sid, j);
        } else if self.pages[i].sealed {
            // exclusive but registered: its content is about to change
            self.unregister_page(i);
            self.rewind_seal(sid, j);
        }
        Ok(())
    }

    /// Shrink a sequence's sealed prefix below page `j` and recompute its
    /// boundary hash from the token mirror.
    fn rewind_seal(&mut self, sid: usize, j: usize) {
        let psz = self.page_size;
        let seq = self.seqs[sid].as_mut().unwrap();
        if seq.sealed > j {
            seq.sealed = j;
            let upto = (j * psz).min(seq.tokens.len());
            seq.boundary = chain_hash(&seq.tokens[..upto]);
        }
    }

    /// Seal every newly-full page whose tokens are known: register it in
    /// the prefix index so later admissions can share it.
    fn try_seal(&mut self, sid: usize) {
        let psz = self.page_size;
        loop {
            let seq = self.seqs[sid].as_ref().expect("seal: released seq");
            let covered = seq.len.min(seq.tokens.len());
            let full = (covered / psz).min(seq.table.len());
            if seq.sealed >= full {
                break;
            }
            let j = seq.sealed;
            let i = seq.table[j];
            let boundary = seq.boundary;
            if self.pages[i].sealed {
                // attached shared page (or re-adopted after rollback):
                // already registered, just adopt its key
                let key = self.pages[i].key;
                let seq = self.seqs[sid].as_mut().unwrap();
                seq.boundary = key;
                seq.sealed += 1;
                continue;
            }
            let span: Vec<TokenId> =
                self.seqs[sid].as_ref().unwrap().tokens[j * psz..(j + 1) * psz].to_vec();
            let key = span.iter().fold(boundary, |h, &t| hash_push(h, t));
            let p = &mut self.pages[i];
            p.parent = boundary;
            p.key = key;
            p.toks = span;
            p.sealed = true;
            self.index.entry(key).or_default().push(i);
            let seq = self.seqs[sid].as_mut().unwrap();
            seq.boundary = key;
            seq.sealed += 1;
        }
    }

    /// Reconcile the token mirror with the engine's authoritative stream
    /// (prompt + committed tokens) and seal any newly-full pages.
    pub fn sync_tokens(&mut self, sid: usize, tokens: &[TokenId]) {
        let seq = self.seqs[sid].as_mut().expect("sync of released seq");
        let n = seq.len.min(tokens.len());
        seq.tokens.clear();
        seq.tokens.extend_from_slice(&tokens[..n]);
        self.try_seal(sid);
    }

    /// Committed positions of one sequence.
    pub fn seq_len(&self, sid: usize) -> usize {
        self.seqs[sid].as_ref().expect("len of released seq").len
    }

    /// Positions one sequence may still commit. Deliberately the MODEL
    /// bound (`max_len - len`), identical to lane mode, so shape planning
    /// sees the same room either way; the admission reservation is sized
    /// to never bind before it.
    pub fn seq_remaining(&self, sid: usize) -> usize {
        self.max_len - self.seq_len(sid)
    }

    /// Borrow one sequence's read view.
    pub fn view(&self, sid: usize) -> PagedSeqView<'_> {
        debug_assert!(self.seqs[sid].is_some(), "view of released seq");
        PagedSeqView { pool: self, sid }
    }

    /// Borrow one sequence's write view.
    pub fn writer(&mut self, sid: usize) -> PagedSeqWriter<'_> {
        debug_assert!(self.seqs[sid].is_some(), "writer of released seq");
        PagedSeqWriter { pool: self, sid }
    }

    /// Page accounting snapshot for metrics/admission dashboards.
    pub fn page_stats(&self) -> PageStats {
        PageStats {
            live: self.live as u64,
            free: (self.budget - self.live - self.reserved) as u64,
            shared: self.pages.iter().filter(|p| p.refs > 1).count() as u64,
            prefix_hits: self.prefix_hits,
        }
    }

    /// Exhaustive invariant check for tests: refcounts match the union of
    /// page tables, accounting counters match reality, and the budget
    /// invariant holds.
    pub fn audit(&self) -> Result<()> {
        let mut refs = vec![0usize; self.pages.len()];
        let mut reserve_sum = 0usize;
        let mut active = 0usize;
        for seq in self.seqs.iter().flatten() {
            active += 1;
            reserve_sum += seq.reserve;
            for &i in &seq.table {
                refs[i] += 1;
            }
            ensure!(
                seq.table.len() >= seq.len.div_ceil(self.page_size),
                "seq table too short for len {}",
                seq.len
            );
        }
        ensure!(active == self.active, "active {} != counted {active}", self.active);
        ensure!(
            reserve_sum == self.reserved,
            "reserved {} != sum of seq reserves {reserve_sum}",
            self.reserved
        );
        for (i, p) in self.pages.iter().enumerate() {
            ensure!(
                p.refs == refs[i],
                "page {i}: refs {} but {} table references",
                p.refs,
                refs[i]
            );
            if p.cached {
                ensure!(p.refs == 0 && p.sealed, "page {i}: cached but refs/sealed wrong");
            }
        }
        for &i in &self.free {
            ensure!(refs[i] == 0, "page {i} on free list but referenced");
            ensure!(!self.pages[i].cached, "page {i} free AND cached");
        }
        let live = refs.iter().filter(|&&r| r > 0).count();
        ensure!(live == self.live, "live {} != counted {live}", self.live);
        ensure!(
            self.live + self.reserved <= self.budget,
            "budget invariant violated: {} live + {} reserved > {}",
            self.live,
            self.reserved,
            self.budget
        );
        Ok(())
    }

    fn k_slice(&self, sid: usize, layer: usize, pos: usize) -> &[f32] {
        let (i, off, ps) = self.locate(sid, layer, pos);
        &self.pages[i].k[off..off + ps]
    }

    fn v_slice(&self, sid: usize, layer: usize, pos: usize) -> &[f32] {
        let (i, off, ps) = self.locate(sid, layer, pos);
        &self.pages[i].v[off..off + ps]
    }

    fn locate(&self, sid: usize, layer: usize, pos: usize) -> (usize, usize, usize) {
        let psz = self.page_size;
        let ps = self.heads * self.head_dim;
        let seq = self.seqs[sid].as_ref().expect("read of released seq");
        let i = seq.table[pos / psz];
        let off = layer * psz * ps + (pos % psz) * ps;
        (i, off, ps)
    }

    /// Dense-install a prefilled cache into a sequence's pages. Positions
    /// below the attached shared prefix are NOT rewritten — the shared
    /// pages already hold exactly those bytes (token-verified at attach).
    fn seq_install(
        &mut self,
        sid: usize,
        k_data: Vec<f32>,
        v_data: Vec<f32>,
        len: usize,
    ) -> Result<()> {
        let ps = self.heads * self.head_dim;
        let numel = self.layers * self.max_len * ps;
        if k_data.len() != numel || v_data.len() != numel {
            return Err(anyhow!(
                "cache install size mismatch: got {} / {}, want {}",
                k_data.len(),
                v_data.len(),
                numel
            ));
        }
        if len > self.max_len {
            return Err(anyhow!("cache len {len} > max_len {}", self.max_len));
        }
        let start = self.seqs[sid].as_ref().expect("install into released seq").shared_len;
        let psz = self.page_size;
        for pos in start.min(len)..len {
            self.ensure_pos_writable(sid, pos)?;
            let seq = self.seqs[sid].as_ref().unwrap();
            let i = seq.table[pos / psz];
            for layer in 0..self.layers {
                let src = layer * self.max_len * ps + pos * ps;
                let dst = layer * psz * ps + (pos % psz) * ps;
                self.pages[i].k[dst..dst + ps].copy_from_slice(&k_data[src..src + ps]);
                self.pages[i].v[dst..dst + ps].copy_from_slice(&v_data[src..src + ps]);
            }
        }
        self.seqs[sid].as_mut().unwrap().len = len;
        self.try_seal(sid);
        Ok(())
    }

    /// Commit the accepted row of a step tail into a sequence's pages
    /// (`tail` = (k_tail, v_tail), `shape` = (k_rows, w1, row, count)).
    fn seq_commit(
        &mut self,
        sid: usize,
        tail: (&[f32], &[f32]),
        shape: (usize, usize, usize, usize),
    ) -> Result<()> {
        let (k_tail, v_tail) = tail;
        let (k_rows, w1, row, count) = shape;
        if row >= k_rows || count > w1 {
            return Err(anyhow!("bad commit row={row}/{k_rows} count={count}/{w1}"));
        }
        let len = self.seq_len(sid);
        if len + count > self.max_len {
            return Err(anyhow!(
                "cache overflow: len {len} + commit {count} > max_len {}",
                self.max_len
            ));
        }
        let ps = self.heads * self.head_dim;
        let expect = self.layers * k_rows * w1 * ps;
        if k_tail.len() != expect || v_tail.len() != expect {
            return Err(anyhow!("tail size mismatch: got {}, want {expect}", k_tail.len()));
        }
        let psz = self.page_size;
        for d in 0..count {
            let pos = len + d;
            self.ensure_pos_writable(sid, pos)?;
            let seq = self.seqs[sid].as_ref().unwrap();
            let i = seq.table[pos / psz];
            for layer in 0..self.layers {
                let src = ((layer * k_rows + row) * w1 + d) * ps;
                let dst = layer * psz * ps + (pos % psz) * ps;
                self.pages[i].k[dst..dst + ps].copy_from_slice(&k_tail[src..src + ps]);
                self.pages[i].v[dst..dst + ps].copy_from_slice(&v_tail[src..src + ps]);
            }
        }
        self.seqs[sid].as_mut().unwrap().len = len + count;
        Ok(())
    }

    /// Rollback: drop pages wholly past the new length (refunding
    /// reservation for exclusively-owned ones) and rewind the sealed
    /// prefix. A partially-cut sealed page stays registered — its content
    /// is still valid for sharing until something overwrites it.
    fn seq_truncate(&mut self, sid: usize, new_len: usize) -> Result<()> {
        let len = self.seq_len(sid);
        if new_len > len {
            return Err(anyhow!("cannot truncate {len} -> {new_len}"));
        }
        let psz = self.page_size;
        let keep = new_len.div_ceil(psz);
        loop {
            let seq = self.seqs[sid].as_mut().unwrap();
            if seq.table.len() <= keep {
                break;
            }
            let i = seq.table.pop().unwrap();
            let exclusive = self.pages[i].refs == 1;
            self.unref_page(i);
            let seq = self.seqs[sid].as_mut().unwrap();
            if exclusive {
                // the page was charged to this sequence: credit it back
                seq.reserve += 1;
                self.reserved += 1;
            }
        }
        let seq = self.seqs[sid].as_mut().unwrap();
        seq.len = new_len;
        seq.tokens.truncate(new_len);
        let sealed_cap = (new_len / psz).min(seq.table.len());
        if seq.sealed > sealed_cap {
            seq.sealed = sealed_cap;
            let upto = (sealed_cap * psz).min(seq.tokens.len());
            seq.boundary = chain_hash(&seq.tokens[..upto]);
        }
        Ok(())
    }
}

/// Immutable per-sequence view of a [`PagedKvPool`] ([`KvRead`]).
#[derive(Debug, Clone, Copy)]
pub struct PagedSeqView<'a> {
    pool: &'a PagedKvPool,
    sid: usize,
}

macro_rules! impl_paged_read {
    ($ty:ty) => {
        impl KvRead for $ty {
            fn layers(&self) -> usize {
                self.pool.layers
            }
            fn heads(&self) -> usize {
                self.pool.heads
            }
            fn head_dim(&self) -> usize {
                self.pool.head_dim
            }
            fn max_ctx(&self) -> usize {
                self.pool.max_len
            }
            fn ctx_len(&self) -> usize {
                self.pool.seq_len(self.sid)
            }
            fn k_at(&self, layer: usize, pos: usize) -> &[f32] {
                self.pool.k_slice(self.sid, layer, pos)
            }
            fn v_at(&self, layer: usize, pos: usize) -> &[f32] {
                self.pool.v_slice(self.sid, layer, pos)
            }
        }
    };
}

impl_paged_read!(PagedSeqView<'_>);
impl_paged_read!(PagedSeqWriter<'_>);

/// Mutable per-sequence view of a [`PagedKvPool`] ([`KvWrite`]).
#[derive(Debug)]
pub struct PagedSeqWriter<'a> {
    pool: &'a mut PagedKvPool,
    sid: usize,
}

impl KvWrite for PagedSeqWriter<'_> {
    fn install(&mut self, k_data: Vec<f32>, v_data: Vec<f32>, len: usize) -> Result<()> {
        self.pool.seq_install(self.sid, k_data, v_data, len)
    }
    fn commit_tail(
        &mut self,
        k_tail: &[f32],
        v_tail: &[f32],
        k_rows: usize,
        w1: usize,
        row: usize,
        count: usize,
    ) -> Result<()> {
        self.pool.seq_commit(self.sid, (k_tail, v_tail), (k_rows, w1, row, count))
    }
    fn truncate(&mut self, len: usize) -> Result<()> {
        self.pool.seq_truncate(self.sid, len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::SharedKvCache;

    /// Dense install buffers whose values encode (layer, pos, elem) from
    /// the token ids, mirroring the reference backend's cache honesty.
    fn dense(tokens: &[TokenId], layers: usize, max_len: usize, ps: usize) -> (Vec<f32>, Vec<f32>) {
        let n = layers * max_len * ps;
        let (mut k, mut v) = (vec![0.0f32; n], vec![0.0f32; n]);
        for layer in 0..layers {
            for (pos, &t) in tokens.iter().enumerate() {
                let base = layer * max_len * ps + pos * ps;
                for e in 0..ps {
                    k[base + e] = t as f32 + e as f32;
                    v[base + e] = -(t as f32) - 1.0 - e as f32;
                }
            }
        }
        (k, v)
    }

    fn pool() -> PagedKvPool {
        // 2 layers, max_len 32, 1 head, dim 2, pages of 4 positions
        PagedKvPool::new(2, 32, 1, 2, 4, 16, 8)
    }

    #[test]
    fn install_then_gather_matches_lane_oracle() {
        let mut p = pool();
        let prompt: Vec<TokenId> = (10..23).collect(); // 13 tokens
        let sid = p.acquire(&prompt, 20).unwrap();
        let (k, v) = dense(&prompt, 2, 32, 2);
        let mut oracle = SharedKvCache::new(2, 32, 1, 2);
        SharedKvCache::install(&mut oracle, k.clone(), v.clone(), prompt.len()).unwrap();
        p.writer(sid).install(k, v, prompt.len()).unwrap();
        let (gk, gv) = p.view(sid).gather();
        let (ok_, ov) = KvRead::gather(&oracle);
        assert_eq!(gk, ok_);
        assert_eq!(gv, ov);
        assert_eq!(p.view(sid).ctx_len(), 13);
        p.audit().unwrap();
    }

    #[test]
    fn second_admission_shares_prompt_prefix_pages() {
        let mut p = pool();
        let prompt: Vec<TokenId> = (0..12).collect(); // 3 full pages
        let (k, v) = dense(&prompt, 2, 32, 2);
        let a = p.acquire(&prompt, 16).unwrap();
        p.writer(a).install(k.clone(), v.clone(), prompt.len()).unwrap();
        let live_before = p.page_stats().live;
        let b = p.acquire(&prompt, 16).unwrap();
        assert_eq!(p.page_stats().prefix_hits, 1);
        p.writer(b).install(k, v, prompt.len()).unwrap();
        // the 3 full prompt pages are shared and cover the whole prompt:
        // b's install materializes no new page at all
        assert_eq!(p.page_stats().shared, 3);
        assert_eq!(p.page_stats().live, live_before, "prefix hit duplicated pages");
        let (ga, _) = p.view(a).gather();
        let (gb, _) = p.view(b).gather();
        assert_eq!(ga, gb);
        p.audit().unwrap();
        p.release(a);
        p.release(b);
        p.audit().unwrap();
        assert_eq!(p.in_use(), 0);
    }

    #[test]
    fn cow_on_divergence_after_rollback_preserves_the_other_sequence() {
        let mut p = pool();
        let prompt: Vec<TokenId> = (0..8).collect(); // 2 full pages
        let (k, v) = dense(&prompt, 2, 32, 2);
        let a = p.acquire(&prompt, 16).unwrap();
        p.writer(a).install(k.clone(), v.clone(), prompt.len()).unwrap();
        let b = p.acquire(&prompt, 16).unwrap();
        p.writer(b).install(k, v, prompt.len()).unwrap();
        assert_eq!(p.page_stats().shared, 2);
        let (ka_before, va_before) = p.view(a).gather();
        // b rolls back INTO the shared region and rewrites: must COW
        p.writer(b).truncate(6).unwrap();
        let n = 2 * 2 * 2; // layers * k_rows * w1 * pos_stride
        let tail: Vec<f32> = vec![99.0; n];
        p.writer(b).commit_tail(&tail, &tail, 1, 2, 0, 2).unwrap();
        let (ka_after, va_after) = p.view(a).gather();
        assert_eq!(ka_before, ka_after, "shared page mutated through b's write");
        assert_eq!(va_before, va_after);
        assert_eq!(p.view(b).k_at(0, 6)[0], 99.0);
        p.audit().unwrap();
    }

    #[test]
    fn released_pages_stay_reclaimable_and_evictable() {
        let mut p = PagedKvPool::new(1, 32, 1, 2, 4, 4, 8);
        let prompt: Vec<TokenId> = (0..8).collect(); // 2 full pages
        let (k, v) = dense(&prompt, 1, 32, 2);
        let a = p.acquire(&prompt, 8).unwrap();
        p.writer(a).install(k.clone(), v.clone(), 8).unwrap();
        p.release(a);
        p.audit().unwrap();
        // pages survive in the reclaim cache: a re-admission hits them
        let b = p.acquire(&prompt, 8).unwrap();
        assert_eq!(p.page_stats().prefix_hits, 1);
        p.writer(b).install(k.clone(), v.clone(), 8).unwrap();
        p.release(b);
        // a disjoint prompt needing all 4 pages evicts the cached ones
        let other: Vec<TokenId> = (100..116).collect();
        let (k2, v2) = dense(&other, 1, 32, 2);
        let c = p.acquire(&other, 16).unwrap();
        p.writer(c).install(k2, v2, 16).unwrap();
        p.audit().unwrap();
        // the old chain is gone from the index now
        assert!(p.probe(&prompt).is_empty());
    }

    #[test]
    fn admission_accounting_backpressures_on_distinct_pages() {
        let mut p = PagedKvPool::new(1, 32, 1, 2, 4, 6, 8);
        let shared: Vec<TokenId> = (0..8).collect(); // 2 full pages
        let (k, v) = dense(&shared, 1, 32, 2);
        // first admission: reserves 3 pages (max_pos 12)
        let a = p.acquire(&shared, 12).unwrap();
        p.writer(a).install(k.clone(), v.clone(), 8).unwrap();
        // second shared admission only needs 1 distinct page
        assert!(p.can_admit(&shared, 12));
        let b = p.acquire(&shared, 12).unwrap();
        // 3 + 1 charged of 6: a third shared admission still fits
        assert!(p.can_admit(&shared, 12));
        let c = p.acquire(&shared, 12).unwrap();
        // but a DISJOINT prompt needing 3 pages does not (5 charged, 1 free)
        let other: Vec<TokenId> = (50..58).collect();
        assert!(!p.can_admit(&other, 12));
        assert!(p.acquire(&other, 12).is_none());
        p.audit().unwrap();
        p.release(b);
        p.release(c);
        assert!(p.can_admit(&other, 12));
        p.audit().unwrap();
    }

    #[test]
    fn truncate_refunds_exclusive_pages_only() {
        let mut p = pool();
        let prompt: Vec<TokenId> = (0..10).collect();
        let (k, v) = dense(&prompt, 2, 32, 2);
        let a = p.acquire(&prompt, 16).unwrap(); // 4 pages reserved
        p.writer(a).install(k, v, 10).unwrap(); // 3 pages materialized
        let free0 = p.page_stats().free;
        p.writer(a).truncate(2).unwrap(); // drops pages 1 and 2
        assert_eq!(p.page_stats().free, free0, "refund moves credit, not budget");
        p.audit().unwrap();
        // the freed room is reusable: commits walk forward again
        let n = 2 * 3 * 2; // layers * k_rows * w1 * pos_stride
        let tail: Vec<f32> = vec![7.0; n];
        p.writer(a).commit_tail(&tail, &tail, 1, 3, 0, 3).unwrap();
        assert_eq!(p.seq_len(a), 5);
        p.audit().unwrap();
    }
}
