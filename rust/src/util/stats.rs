//! Small statistics helpers shared by the bench harness and metrics.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0.0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile by linear interpolation; q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median (the 50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Simple fixed-bucket histogram for integer observations.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// per-bucket counts (last bucket catches overflow)
    pub buckets: Vec<u64>,
    /// total observations
    pub count: u64,
    /// sum of observed values
    pub sum: f64,
}

impl Histogram {
    /// An empty histogram with `n_buckets` buckets.
    pub fn new(n_buckets: usize) -> Self {
        Histogram { buckets: vec![0; n_buckets], count: 0, sum: 0.0 }
    }

    /// Record `v`, clamped into the last bucket.
    pub fn record(&mut self, v: usize) {
        let idx = v.min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += v as f64;
    }

    /// Mean observed value (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Normalized distribution (sums to 1 unless empty).
    pub fn pmf(&self) -> Vec<f64> {
        if self.count == 0 {
            return vec![0.0; self.buckets.len()];
        }
        self.buckets.iter().map(|&b| b as f64 / self.count as f64).collect()
    }

    /// Add another histogram's counts (shapes must match).
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.buckets.len(), other.buckets.len());
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram() {
        let mut h = Histogram::new(4);
        for v in [0, 1, 1, 9] {
            h.record(v);
        }
        assert_eq!(h.buckets, vec![1, 2, 0, 1]); // 9 clamps to last
        assert_eq!(h.count, 4);
        let p = h.pmf();
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }
}
