//! Minimal JSON substrate (no serde available offline).
//!
//! Full JSON spec for parsing (objects, arrays, strings with escapes,
//! numbers, bools, null); serialization covers everything the repo emits.
//! Object key order is preserved (insertion order) so emitted files diff
//! cleanly across runs.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// number (integers emit without a decimal point)
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object, insertion-ordered key/value pairs
    Obj(Vec<(String, Json)>),
}

/// A parse failure with its byte position.
#[derive(Debug)]
pub struct JsonError {
    /// what went wrong
    pub msg: String,
    /// byte offset of the failure
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing data is an error).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Parse a JSON file from disk (the `ci-bench-check` gate reads both
    /// the committed baseline and the emitted `BENCH_*.json` through
    /// this, so parse errors carry the path).
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}"))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))
    }

    // -- typed accessors ---------------------------------------------------
    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// [`Self::get`] that errors on a missing key.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Key/value slice, if this is an object.
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Convenience: object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Indented serialization (2 spaces per level).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent.map(|d| d + 1));
                    v.write(out, indent.map(|d| d + 1));
                }
                if !a.is_empty() {
                    nl(out, indent);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent.map(|d| d + 1));
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent.map(|d| d + 1));
                }
                if !o.is_empty() {
                    nl(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn nl(out: &mut String, indent: Option<usize>) {
    if let Some(d) = indent {
        out.push('\n');
        for _ in 0..d {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        if self.peek() != Some(b'"') {
            return Err(self.err("expected string"));
        }
        self.i += 1;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // surrogate pairs
                            let c = if (0xd800..0xdc00).contains(&cp) {
                                if self.b.get(self.i + 1..self.i + 3) == Some(b"\\u") {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 3..self.i + 7)
                                        .ok_or_else(|| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad surrogate"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                cp
                            };
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.i += 1; // [
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.i += 1; // {
        let mut o = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            if self.peek() != Some(b':') {
                return Err(self.err("expected ':'"));
            }
            self.i += 1;
            self.skip_ws();
            let v = self.value()?;
            o.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(o));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Map-style view for lookups into big objects.
pub fn to_map(j: &Json) -> BTreeMap<String, Json> {
    match j {
        Json::Obj(o) => o.iter().cloned().collect(),
        _ => BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a"]);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
    }
}
