//! Deterministic PRNG substrate (no rand crate offline): SplitMix64 seeding
//! a xoshiro256** core. Used by workload generation, property tests and the
//! bench harness; every consumer takes an explicit seed so runs reproduce.

/// xoshiro256** generator seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// A seeded generator (same seed, same stream).
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire's multiply-shift rejection for unbiased sampling
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul128(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform choice from a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Exponentially-distributed f64 with the given mean (for arrival times).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = self.f64().max(1e-12);
        -mean * u.ln()
    }
}

fn mul128(a: u64, b: u64) -> (u64, u64) {
    let w = (a as u128) * (b as u128);
    ((w >> 64) as u64, w as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
