//! Minimal CLI argument parser substrate (no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Each binary declares its options inline; `Args::usage_err` renders help.

use std::collections::HashMap;

/// Parsed command line: positionals, `--key value` options, `--flag`s.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// positional arguments, in order
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options
    pub options: HashMap<String, String>,
    /// bare `--flag`s that take no value
    pub flags: Vec<String>,
}

impl Args {
    /// Parse argv (without the program name). `flag_names` lists options
    /// that take no value.
    pub fn parse(argv: &[String], flag_names: &[&str]) -> Result<Args, String> {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    a.flags.push(rest.to_string());
                } else {
                    i += 1;
                    let v = argv
                        .get(i)
                        .ok_or_else(|| format!("option --{rest} needs a value"))?;
                    a.options.insert(rest.to_string(), v.clone());
                }
            } else {
                a.positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(a)
    }

    /// [`Self::parse`] over the process arguments.
    pub fn from_env(flag_names: &[&str]) -> Result<Args, String> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv, flag_names)
    }

    /// Whether `--name` was passed as a flag.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Option value for `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Option value with a default.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Integer option with a default; `Err` on unparsable input.
    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Float option with a default; `Err` on unparsable input.
    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Parse a comma-separated list of integers, e.g. "1,5,10".
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, String> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| format!("--{name}: bad integer '{s}'"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(&sv(&["run", "--k", "10", "--fast", "--w=5", "x"]), &["fast"]).unwrap();
        assert_eq!(a.positional, vec!["run", "x"]);
        assert_eq!(a.get("k"), Some("10"));
        assert_eq!(a.get("w"), Some("5"));
        assert!(a.has_flag("fast"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["--k"]), &[]).is_err());
    }

    #[test]
    fn usize_list() {
        let a = Args::parse(&sv(&["--ks", "1,5,10"]), &[]).unwrap();
        assert_eq!(a.get_usize_list("ks", &[]).unwrap(), vec![1, 5, 10]);
        assert_eq!(a.get_usize_list("ws", &[2, 4]).unwrap(), vec![2, 4]);
    }
}
