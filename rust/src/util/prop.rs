//! Property-based-testing substrate (proptest is not available offline).
//!
//! `check` runs a property over N random cases from a seeded RNG; on
//! failure it retries with a simple shrink schedule (halving integer
//! parameters via the case's `Shrink` hook) and reports the seed so the
//! failure replays deterministically:
//!
//! ```ignore
//! prop::check(200, |rng| {
//!     let n = rng.range(0, 100);
//!     let xs = prop::vec_u32(rng, n, 0..512);
//!     my_invariant(&xs)
//! });
//! ```

use super::rng::Rng;
use std::ops::Range;

/// Run `prop` over `cases` random inputs. Panics (with the failing seed)
/// if any case returns false. The per-case RNG is derived from the case
/// index, so failures replay independently of the others.
pub fn check<F: FnMut(&mut Rng) -> bool>(cases: u64, mut prop: F) {
    check_seeded(N_GRAMMYS_SEED, cases, &mut prop);
}

const N_GRAMMYS_SEED: u64 = 0x6772616d6d7973; // "grammys"

/// [`check`] with an explicit base seed, for replaying a failing run.
pub fn check_seeded<F: FnMut(&mut Rng) -> bool>(base_seed: u64, cases: u64, prop: &mut F) {
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if !prop(&mut rng) {
            panic!(
                "property failed at case {case} (replay with seed {seed:#x})"
            );
        }
    }
}

/// Random vector of u32 drawn from `range`.
pub fn vec_u32(rng: &mut Rng, len: usize, range: Range<u32>) -> Vec<u32> {
    (0..len)
        .map(|_| range.start + rng.below((range.end - range.start) as usize) as u32)
        .collect()
}

/// Random vector of f32 in [-1, 1].
pub fn vec_f32(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(100, |rng| {
            let n = rng.range(0, 50);
            let v = vec_u32(rng, n, 0..10);
            v.iter().all(|&x| x < 10)
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_loudly() {
        check(100, |rng| rng.below(10) != 3);
    }
}
