//! Criterion-style micro-benchmark harness substrate (criterion is not
//! available offline). Warmup + timed iterations, mean/std/median report,
//! and a `black_box` to defeat constant folding.

use std::hint;
use std::time::{Duration, Instant};

use super::stats;

/// Opaque identity to defeat constant folding in benches.
pub fn black_box<T>(x: T) -> T {
    hint::black_box(x)
}

/// Summary statistics of one micro-benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// benchmark label
    pub name: String,
    /// timed iterations
    pub iters: u64,
    /// mean ns per iteration
    pub mean_ns: f64,
    /// sample std dev, ns
    pub std_ns: f64,
    /// median ns per iteration
    pub median_ns: f64,
    /// fastest iteration, ns
    pub min_ns: f64,
}

impl BenchResult {
    /// One-line human-readable summary.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}  ± {:>10}  (median {:>12}, min {:>12}, n={})",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.min_ns),
            self.iters
        )
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Micro-benchmark runner: warmup, then timed iterations.
pub struct Bencher {
    /// warmup duration before timing starts
    pub warmup: Duration,
    /// target total timed duration
    pub target: Duration,
    /// hard iteration cap
    pub max_iters: u64,
    /// accumulated results, in run order
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(300),
            target: Duration::from_secs(1),
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// Short-run configuration for smoke benches.
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            target: Duration::from_millis(300),
            max_iters: 100_000,
            ..Default::default()
        }
    }

    /// Run `f` repeatedly; one sample = one call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // warmup + calibrate cost of one call
        let wstart = Instant::now();
        let mut calls = 0u64;
        while wstart.elapsed() < self.warmup || calls < 3 {
            f();
            calls += 1;
        }
        let per_call = wstart.elapsed().as_nanos() as f64 / calls as f64;
        let n = ((self.target.as_nanos() as f64 / per_call.max(1.0)) as u64)
            .clamp(10, self.max_iters);

        // sample in batches so per-sample timer overhead is amortized
        let batches = 20u64.min(n);
        let per_batch = (n / batches).max(1);
        let mut samples = Vec::with_capacity(batches as usize);
        for _ in 0..batches {
            let t = Instant::now();
            for _ in 0..per_batch {
                f();
            }
            samples.push(t.elapsed().as_nanos() as f64 / per_batch as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: batches * per_batch,
            mean_ns: stats::mean(&samples),
            std_ns: stats::std_dev(&samples),
            median_ns: stats::median(&samples),
            min_ns: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher {
            warmup: Duration::from_millis(5),
            target: Duration::from_millis(20),
            ..Default::default()
        };
        let r = b.bench("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters >= 10);
    }
}
