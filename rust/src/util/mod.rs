//! From-scratch utility substrates (the default build depends only on
//! `anyhow`, so JSON / CLI / RNG / bench / property testing are implemented
//! here — see DESIGN.md §System-inventory S14).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
