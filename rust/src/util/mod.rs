//! From-scratch utility substrates (the offline environment ships only the
//! `xla` crate's dependency closure, so JSON / CLI / RNG / bench / property
//! testing are implemented here — see DESIGN.md §System-inventory S14).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
